"""Physics constants of the realistic example (paper §III / §VIII).

These mirror `rust/src/edm/constants.rs`; `aot.py` embeds them in
`artifacts/manifest.json` so the Rust side reads one source of truth.

The scenario: an N x N grid of sensors of NUM_SENSOR_TYPES types measures
particle energy deposits.  Raw counts are calibrated to energies with
per-sensor constants; particles are seeded at sensors whose significance
(energy / noise) exceeds SEED_SIGNIFICANCE and which are the local maximum
of their 5x5 neighbourhood; particle properties are accumulated over that
neighbourhood, keeping per-sensor-type tallies and the jagged list of
contributing sensors (significance > CONTRIB_SIGNIFICANCE).
"""

# Number of distinct sensor types (paper: SensorType::Num).
NUM_SENSOR_TYPES = 3

# Neighbourhood window is WINDOW x WINDOW around the seed (paper: 5x5).
WINDOW = 5
HALO = WINDOW // 2  # 2

# A sensor seeds a particle when significance > SEED_SIGNIFICANCE and it is
# the maximum of its window.
SEED_SIGNIFICANCE = 4.0

# A sensor contributes to a particle's jagged sensor list (and to the
# contributor count plane) when its significance exceeds this.
CONTRIB_SIGNIFICANCE = 2.0

# Stacked plane indices produced by the particle stage box-sum.
# Layout of the C=15 channel tensor fed to the box-sum stencil:
#   0: e          energy
#   1: e*x        energy-weighted column coordinate
#   2: e*y        energy-weighted row coordinate
#   3: e*x^2
#   4: e*y^2
#   5..7:   e * (type == t)          per-type energy contribution
#   8..10:  sig * (type == t)        per-type significance
#   11..13: noisy * (type == t)      per-type noisy-sensor count
#   14: contrib   contributor count (sig > CONTRIB_SIGNIFICANCE)
PLANE_E = 0
PLANE_EX = 1
PLANE_EY = 2
PLANE_EXX = 3
PLANE_EYY = 4
PLANE_E_TYPE = 5  # .. 5 + NUM_SENSOR_TYPES - 1
PLANE_SIG_TYPE = 5 + NUM_SENSOR_TYPES  # 8..10
PLANE_NOISY_TYPE = 5 + 2 * NUM_SENSOR_TYPES  # 11..13
PLANE_CONTRIB = 5 + 3 * NUM_SENSOR_TYPES  # 14
NUM_PLANES = 6 + 3 * NUM_SENSOR_TYPES  # 15

CONSTANTS = {
    "num_sensor_types": NUM_SENSOR_TYPES,
    "window": WINDOW,
    "halo": HALO,
    "seed_significance": SEED_SIGNIFICANCE,
    "contrib_significance": CONTRIB_SIGNIFICANCE,
    "num_planes": NUM_PLANES,
}

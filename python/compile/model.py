"""L2: the realistic-example compute graph in JAX, calling the L1 kernels.

Two device stages mirror the paper's Figures 1 and 2:

  * ``sensor_stage``   — calibrate raw counts to (energy, noise, sig).
  * ``particle_stage`` — seed particles (5x5 local maxima above the
    significance cut) and produce the NUM_PLANES per-cell window sums the
    host gathers particle properties from.
  * ``full_event``     — both fused in one executable, keeping the
    intermediate planes on-device (paper §VIII: "sidestepping unnecessary
    conversions").

All shapes are static: `aot.py` lowers one artifact per grid bucket.  The
dynamic part of the problem (how many particles an event yields) lives on
the Rust side, which gathers the seed positions from the dense mask —
exactly how the paper keeps the device code free of dynamic allocation.
"""

import jax
import jax.numpy as jnp

from .kernels.calibrate import calibrate
from .kernels.stencil import boxmax, boxsum
from .physics import (CONTRIB_SIGNIFICANCE, NUM_PLANES, NUM_SENSOR_TYPES,
                      SEED_SIGNIFICANCE)


def _make_planes(energy, sig, types, noisy):
    """Build the C=NUM_PLANES channel stack for the box-sum stencil.

    Cheap element-wise ops: XLA fuses these into the pallas-lowered loop's
    producers, so they do not warrant a dedicated kernel (DESIGN §Perf L2).
    """
    rows, cols = energy.shape
    x = jnp.broadcast_to(jnp.arange(cols, dtype=jnp.float32)[None, :],
                         (rows, cols))
    y = jnp.broadcast_to(jnp.arange(rows, dtype=jnp.float32)[:, None],
                         (rows, cols))
    planes = [energy, energy * x, energy * y,
              energy * x * x, energy * y * y]
    for t in range(NUM_SENSOR_TYPES):
        planes.append(jnp.where(types == t, energy, 0.0))
    for t in range(NUM_SENSOR_TYPES):
        planes.append(jnp.where(types == t, sig, 0.0))
    for t in range(NUM_SENSOR_TYPES):
        planes.append(jnp.where((types == t) & (noisy != 0), 1.0, 0.0))
    planes.append((sig > CONTRIB_SIGNIFICANCE).astype(jnp.float32))
    out = jnp.stack(planes)
    assert out.shape[0] == NUM_PLANES
    return out


def sensor_stage(counts, a, b, na, nb, noisy):
    """Figure-1 device stage: calibrate the grid.

    Args: counts int32[R,C]; a,b,na,nb float32[R,C]; noisy int32[R,C].
    Returns: (energy, noise, sig) float32[R,C].
    """
    return calibrate(counts, a, b, na, nb, noisy)


def particle_stage(energy, sig, types, noisy):
    """Figure-2 device stage: seed mask + window sums.

    Args: energy, sig float32[R,C]; types, noisy int32[R,C].
    Returns: (seeds int32[R,C], sums float32[NUM_PLANES,R,C]).
    """
    win_max = boxmax(energy)
    seeds = ((sig > SEED_SIGNIFICANCE) & (energy >= win_max)).astype(
        jnp.int32)
    sums = boxsum(_make_planes(energy, sig, types, noisy))
    return seeds, sums


def full_event(counts, a, b, na, nb, noisy, types):
    """Fused pipeline: raw counts straight to seeds + sums, the
    intermediate calibration planes never leaving the device."""
    energy, noise, sig = sensor_stage(counts, a, b, na, nb, noisy)
    seeds, sums = particle_stage(energy, sig, types, noisy)
    return energy, noise, sig, seeds, sums


# ---------------------------------------------------------------------------
# AOT entry points: name -> (function, input-spec builder).
# Input dtypes must match what rust/src/runtime/executor.rs marshals.
# ---------------------------------------------------------------------------

def _f32(rows, cols):
    return jax.ShapeDtypeStruct((rows, cols), jnp.float32)


def _i32(rows, cols):
    return jax.ShapeDtypeStruct((rows, cols), jnp.int32)


def sensor_stage_specs(rows, cols):
    return [_i32(rows, cols)] + [_f32(rows, cols)] * 4 + [_i32(rows, cols)]


def particle_stage_specs(rows, cols):
    return [_f32(rows, cols)] * 2 + [_i32(rows, cols)] * 2


def full_event_specs(rows, cols):
    return ([_i32(rows, cols)] + [_f32(rows, cols)] * 4
            + [_i32(rows, cols)] * 2)


ENTRY_POINTS = {
    "sensor_stage": (sensor_stage, sensor_stage_specs),
    "particle_stage": (particle_stage, particle_stage_specs),
    "full_event": (full_event, full_event_specs),
}

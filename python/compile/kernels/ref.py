"""Pure-jnp oracles for every L1 kernel and both L2 stages.

This module is the single correctness reference: pytest + hypothesis sweep
the Pallas kernels against these functions, and the Rust host algorithms
are validated against the same semantics through the golden-vector test
(`aot.py --golden` writes reference outputs the Rust tests replay).
No Pallas, no jit requirements — just jnp.
"""

import jax.numpy as jnp

from ..physics import (CONTRIB_SIGNIFICANCE, HALO, NUM_PLANES,
                       NUM_SENSOR_TYPES, SEED_SIGNIFICANCE, WINDOW)


def calibrate_ref(counts, a, b, na, nb, noisy):
    """Reference for kernels.calibrate.calibrate."""
    raw = a * counts.astype(jnp.float32) + b
    energy = jnp.where(noisy != 0, jnp.float32(0.0), raw)
    noise = jnp.maximum(na + nb * jnp.sqrt(jnp.maximum(energy, 0.0)), 1e-6)
    return energy, noise, energy / noise


def boxsum_ref(planes):
    """Reference for kernels.stencil.boxsum (zero-padded 5x5 box sum)."""
    ch, rows, cols = planes.shape
    padded = jnp.pad(planes, ((0, 0), (HALO, HALO), (HALO, HALO)))
    acc = jnp.zeros_like(planes)
    for dr in range(WINDOW):
        for dc in range(WINDOW):
            acc = acc + padded[:, dr:dr + rows, dc:dc + cols]
    return acc


def boxmax_ref(plane):
    """Reference for kernels.stencil.boxmax (-inf padded 5x5 box max)."""
    rows, cols = plane.shape
    padded = jnp.pad(plane, ((HALO, HALO), (HALO, HALO)),
                     constant_values=-jnp.inf)
    acc = jnp.full_like(plane, -jnp.inf)
    for dr in range(WINDOW):
        for dc in range(WINDOW):
            acc = jnp.maximum(acc, padded[dr:dr + rows, dc:dc + cols])
    return acc


def make_planes_ref(energy, sig, types, noisy):
    """Reference for model._make_planes: the C=NUM_PLANES channel stack fed
    to the box-sum stencil."""
    rows, cols = energy.shape
    x = jnp.broadcast_to(jnp.arange(cols, dtype=jnp.float32)[None, :],
                         (rows, cols))
    y = jnp.broadcast_to(jnp.arange(rows, dtype=jnp.float32)[:, None],
                         (rows, cols))
    planes = [energy, energy * x, energy * y,
              energy * x * x, energy * y * y]
    for t in range(NUM_SENSOR_TYPES):
        planes.append(jnp.where(types == t, energy, 0.0))
    for t in range(NUM_SENSOR_TYPES):
        planes.append(jnp.where(types == t, sig, 0.0))
    for t in range(NUM_SENSOR_TYPES):
        planes.append(jnp.where((types == t) & (noisy != 0), 1.0, 0.0))
    planes.append((sig > CONTRIB_SIGNIFICANCE).astype(jnp.float32))
    out = jnp.stack(planes)
    assert out.shape[0] == NUM_PLANES
    return out


def sensor_stage_ref(counts, a, b, na, nb, noisy):
    """Reference for model.sensor_stage."""
    return calibrate_ref(counts, a, b, na, nb, noisy)


def particle_stage_ref(energy, sig, types, noisy):
    """Reference for model.particle_stage.

    Returns (seeds int32[R,C], sums float32[NUM_PLANES,R,C]).
    A sensor seeds a particle when sig > SEED_SIGNIFICANCE and its energy
    attains the 5x5 box-max at its position.
    """
    win_max = boxmax_ref(energy)
    seeds = ((sig > SEED_SIGNIFICANCE) & (energy >= win_max)).astype(
        jnp.int32)
    sums = boxsum_ref(make_planes_ref(energy, sig, types, noisy))
    return seeds, sums


def full_event_ref(counts, a, b, na, nb, noisy, types):
    """Reference for model.full_event: both stages fused (the paper's
    'sidestepping unnecessary conversions' path)."""
    energy, noise, sig = sensor_stage_ref(counts, a, b, na, nb, noisy)
    seeds, sums = particle_stage_ref(energy, sig, types, noisy)
    return energy, noise, sig, seeds, sums

"""L1 Pallas kernels: 5x5 neighbourhood stencils (box-sum and box-max).

Paper analogue: particle finding over the 5x5 neighbourhood of each
energetic sensor (realistic_example.cu, particle stage of Figure 2). The
CUDA version assigns threadblocks to grid tiles with shared-memory halos;
the Pallas re-think expresses the same schedule as:

  * the *output* is blocked into row slabs via BlockSpec — each grid step
    owns TILE_ROWS output rows;
  * the *input* ref stays unblocked (paper: global memory / HBM) and the
    kernel dynamically slices the (TILE_ROWS + 2*HALO)-row halo slab it
    needs — the HBM->VMEM copy that CUDA did via shared-memory staging;
  * the separable 5x5 box reduction is computed as five shifted adds along
    columns then five along rows (VPU-friendly, no gather/scatter and no
    CUDA-style atomics).

VMEM estimate per step for the sum kernel (C channels, N columns):
`C * (TILE_ROWS + 4) * (N + 4) * 4` input bytes + `C * TILE_ROWS * N * 4`
output; for C=15, N=1024, TILE_ROWS=32 that is ~4.3 MiB — see DESIGN §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..physics import HALO, WINDOW

TILE_ROWS = 32


def _boxsum_kernel(rows, cols, tile, p_ref, o_ref):
    """One (1, tile, cols) output block of the 5x5 box-sum.

    p_ref is the unblocked padded input (C, rows + 2*HALO, cols + 2*HALO);
    the channel is selected by grid axis 0 and the row slab by grid axis 1.
    """
    c = pl.program_id(0)
    i = pl.program_id(1)
    slab = p_ref[c, pl.dslice(i * tile, tile + 2 * HALO),
                 pl.dslice(0, cols + 2 * HALO)]
    # Separable box filter: columns first, then rows.
    cs = sum(slab[:, k:k + cols] for k in range(WINDOW))
    rs = sum(cs[k:k + tile, :] for k in range(WINDOW))
    o_ref[...] = rs[None, :, :]


def _boxmax_kernel(rows, cols, tile, p_ref, o_ref):
    """One (tile, cols) output block of the 5x5 box-max over a 2D plane."""
    i = pl.program_id(0)
    slab = p_ref[pl.dslice(i * tile, tile + 2 * HALO),
                 pl.dslice(0, cols + 2 * HALO)]
    cm = slab[:, 0:cols]
    for k in range(1, WINDOW):
        cm = jnp.maximum(cm, slab[:, k:k + cols])
    rm = cm[0:tile, :]
    for k in range(1, WINDOW):
        rm = jnp.maximum(rm, cm[k:k + tile, :])
    o_ref[...] = rm


def _row_tile(rows: int) -> int:
    return min(TILE_ROWS, rows)


@jax.jit
def boxsum(planes):
    """5x5 box-sum of float32[C, R, Cn] with zero padding at the borders."""
    ch, rows, cols = planes.shape
    tile = _row_tile(rows)
    assert rows % tile == 0, (rows, tile)
    padded = jnp.pad(planes, ((0, 0), (HALO, HALO), (HALO, HALO)))
    return pl.pallas_call(
        functools.partial(_boxsum_kernel, rows, cols, tile),
        grid=(ch, rows // tile),
        in_specs=[pl.BlockSpec(block_shape=None)],
        out_specs=pl.BlockSpec((1, tile, cols), lambda c, i: (c, i, 0)),
        out_shape=jax.ShapeDtypeStruct((ch, rows, cols), jnp.float32),
        interpret=True,
    )(padded)


@jax.jit
def boxmax(plane):
    """5x5 box-max of float32[R, C]; borders padded with -inf so that the
    maximum is always attained inside the grid."""
    rows, cols = plane.shape
    tile = _row_tile(rows)
    assert rows % tile == 0, (rows, tile)
    padded = jnp.pad(plane, ((HALO, HALO), (HALO, HALO)),
                     constant_values=-jnp.inf)
    return pl.pallas_call(
        functools.partial(_boxmax_kernel, rows, cols, tile),
        grid=(rows // tile,),
        in_specs=[pl.BlockSpec(block_shape=None)],
        out_specs=pl.BlockSpec((tile, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(padded)

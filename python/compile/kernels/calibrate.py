"""L1 Pallas kernel: per-sensor energy calibration.

Paper analogue: `Sensor::calibrate_energy()` / `get_noise()` run over the
whole grid on the device (realistic_example.cu, sensor stage of Figure 1).

The kernel is a pure element-wise VPU computation; the BlockSpec tiles the
grid into row slabs of TILE_ROWS rows so each step touches
`7 * TILE_ROWS * N * 4` bytes of input + `3 * TILE_ROWS * N * 4` of output.
For N = 1024 and TILE_ROWS = 128 that is a ~5 MiB working set, comfortably
inside a 16 MiB TPU VMEM with double buffering (see DESIGN.md §Perf).

interpret=True is mandatory on this image: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers the kernel body to plain HLO
that compiles natively (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-slab height. Must divide the padded row count chosen by
# `_grid_rows`; 64 keeps even the N=16 bucket on a single-digit grid.
TILE_ROWS = 64


def _calibrate_kernel(counts_ref, a_ref, b_ref, na_ref, nb_ref, noisy_ref,
                      energy_ref, noise_ref, sig_ref):
    """energy = noisy ? 0 : a*counts + b;  noise = na + nb*sqrt(max(e,0));
    sig = energy / noise."""
    counts = counts_ref[...].astype(jnp.float32)
    a = a_ref[...]
    b = b_ref[...]
    na = na_ref[...]
    nb = nb_ref[...]
    noisy = noisy_ref[...]

    raw = a * counts + b
    energy = jnp.where(noisy != 0, 0.0, raw)
    noise = na + nb * jnp.sqrt(jnp.maximum(energy, 0.0))
    # na > 0 by construction (generator guarantees), but guard anyway so the
    # kernel never emits inf/nan for degenerate calibrations.
    safe_noise = jnp.maximum(noise, 1e-6)
    energy_ref[...] = energy
    noise_ref[...] = safe_noise
    sig_ref[...] = energy / safe_noise


def _row_tile(n_rows: int) -> int:
    return min(TILE_ROWS, n_rows)


@functools.partial(jax.jit, static_argnames=())
def calibrate(counts, a, b, na, nb, noisy):
    """Calibrate an (R, C) grid.

    Args:
      counts: int32[R, C] raw sensor counts.
      a, b:   float32[R, C] per-sensor calibration constants.
      na, nb: float32[R, C] per-sensor noise constants.
      noisy:  int32[R, C] noisy-sensor flags (0/1).

    Returns:
      (energy, noise, sig): three float32[R, C] planes.
    """
    rows, cols = counts.shape
    tile = _row_tile(rows)
    # Row counts are powers of two >= 16 in every AOT bucket, so `tile`
    # always divides `rows`; assert to catch misuse from tests.
    assert rows % tile == 0, (rows, tile)
    grid = (rows // tile,)
    spec = pl.BlockSpec((tile, cols), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    return pl.pallas_call(
        _calibrate_kernel,
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=(spec, spec, spec),
        out_shape=(out_shape, out_shape, out_shape),
        interpret=True,
    )(counts, a, b, na, nb, noisy)

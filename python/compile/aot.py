"""AOT compile path: lower the L2 graph to HLO text artifacts for Rust.

Emits HLO *text*, NOT a serialized HloModuleProto: jax >= 0.5 emits protos
with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under --out-dir (default ../artifacts):
  * ``<entry>_<R>x<C>.hlo.txt``  one per (entry point, grid bucket)
  * ``manifest.json``            physics constants + artifact index the
                                 Rust runtime::artifact module loads
  * ``golden/...`` (with --golden)  reference vectors for Rust tests

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, physics
from .kernels import ref

DEFAULT_GRIDS = [16, 32, 64, 128, 256, 512, 1024]
MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, see load_hlo.rs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec_json(s):
    return {"dtype": s.dtype.name, "shape": list(s.shape)}


def lower_entry(name, rows, cols):
    """Lower one entry point for one grid bucket; returns (hlo, record)."""
    fn, spec_builder = model.ENTRY_POINTS[name]
    specs = spec_builder(rows, cols)
    lowered = jax.jit(fn).lower(*specs)
    hlo = to_hlo_text(lowered)
    out_specs = jax.eval_shape(fn, *specs)
    out_flat = jax.tree_util.tree_leaves(out_specs)
    record = {
        "entry": name,
        "rows": rows,
        "cols": cols,
        "inputs": [_spec_json(s) for s in specs],
        "outputs": [_spec_json(s) for s in out_flat],
    }
    return hlo, record


def generate_event(rng, rows, cols, n_particles):
    """Synthetic event generator (numpy twin of rust edm::generator).

    Injects `n_particles` Gaussian energy deposits onto a noisy grid of
    mixed-type sensors; returns the raw-sensor input planes.
    """
    types = rng.integers(0, physics.NUM_SENSOR_TYPES, (rows, cols),
                         dtype=np.int32)
    # Per-type calibration constants, perturbed per sensor.
    a_tab = np.array([0.5, 1.0, 2.0], dtype=np.float32)
    b_tab = np.array([0.0, 5.0, -3.0], dtype=np.float32)
    na_tab = np.array([2.0, 3.0, 5.0], dtype=np.float32)
    nb_tab = np.array([0.10, 0.05, 0.20], dtype=np.float32)
    jitter = 1.0 + rng.normal(0, 0.01, (rows, cols)).astype(np.float32)
    a = a_tab[types] * jitter
    b = b_tab[types].astype(np.float32)
    na = na_tab[types].astype(np.float32)
    nb = nb_tab[types].astype(np.float32)
    noisy = (rng.random((rows, cols)) < 0.01).astype(np.int32)

    # Background counts + particle deposits.
    counts = rng.poisson(3.0, (rows, cols)).astype(np.float32)
    for _ in range(n_particles):
        r = rng.integers(2, max(3, rows - 2))
        c = rng.integers(2, max(3, cols - 2))
        amp = rng.uniform(200.0, 2000.0)
        rr, cc = np.meshgrid(np.arange(rows), np.arange(cols),
                             indexing="ij")
        sigma = rng.uniform(0.6, 1.2)
        counts += amp * np.exp(-((rr - r) ** 2 + (cc - c) ** 2)
                               / (2 * sigma ** 2))
    counts = counts.astype(np.int32)
    return {"counts": counts, "a": a, "b": b, "na": na, "nb": nb,
            "noisy": noisy, "types": types}


def write_golden(out_dir, rows=32, cols=32, n_particles=5, seed=7):
    """Write golden vectors: inputs + full_event_ref outputs, raw little-
    endian binary + a JSON descriptor, replayed by Rust integration tests
    and by python/tests/test_golden.py."""
    golden_dir = os.path.join(out_dir, "golden")
    os.makedirs(golden_dir, exist_ok=True)
    rng = np.random.default_rng(seed)
    ev = generate_event(rng, rows, cols, n_particles)
    energy, noise, sig, seeds, sums = ref.full_event_ref(
        jnp.asarray(ev["counts"]), jnp.asarray(ev["a"]),
        jnp.asarray(ev["b"]), jnp.asarray(ev["na"]), jnp.asarray(ev["nb"]),
        jnp.asarray(ev["noisy"]), jnp.asarray(ev["types"]))
    tensors = dict(ev)
    tensors.update({"energy": np.asarray(energy),
                    "noise": np.asarray(noise),
                    "sig": np.asarray(sig),
                    "seeds": np.asarray(seeds),
                    "sums": np.asarray(sums)})
    desc = {"rows": rows, "cols": cols, "n_particles": n_particles,
            "seed": seed, "tensors": {}}
    for name, arr in tensors.items():
        fname = f"{name}.bin"
        arr = np.ascontiguousarray(arr)
        arr.tofile(os.path.join(golden_dir, fname))
        desc["tensors"][name] = {"file": fname, "dtype": arr.dtype.name,
                                 "shape": list(arr.shape)}
    with open(os.path.join(golden_dir, "golden.json"), "w") as f:
        json.dump(desc, f, indent=1)
    print(f"golden vectors -> {golden_dir} ({len(tensors)} tensors)")


def report_vmem(grids):
    """DESIGN §Perf L1: static VMEM-footprint estimate per kernel/bucket."""
    from .kernels import calibrate as ck
    from .kernels import stencil as sk
    rows = []
    for n in grids:
        t_cal = min(ck.TILE_ROWS, n)
        cal = (6 + 3) * t_cal * n * 4
        t_st = min(sk.TILE_ROWS, n)
        halo = 2 * physics.HALO
        bsum = ((t_st + halo) * (n + halo) + t_st * n) * 4  # per channel
        bmax = ((t_st + halo) * (n + halo) + t_st * n) * 4
        rows.append((n, cal, bsum, bmax))
    print(f"{'grid':>6} {'calibrate':>12} {'boxsum/ch':>12} {'boxmax':>12}")
    for n, cal, bsum, bmax in rows:
        print(f"{n:>6} {cal/2**20:>10.2f}Mi {bsum/2**20:>10.2f}Mi "
              f"{bmax/2**20:>10.2f}Mi")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--grids", type=int, nargs="*", default=DEFAULT_GRIDS)
    ap.add_argument("--entries", nargs="*",
                    default=list(model.ENTRY_POINTS.keys()))
    ap.add_argument("--golden", action="store_true",
                    help="also write golden test vectors")
    ap.add_argument("--report-vmem", action="store_true")
    args = ap.parse_args()

    if args.report_vmem:
        report_vmem(args.grids)
        return

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []
    for n in args.grids:
        for entry in args.entries:
            fname = f"{entry}_{n}x{n}.hlo.txt"
            hlo, record = lower_entry(entry, n, n)
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(hlo)
            record["file"] = fname
            record["sha256"] = hashlib.sha256(hlo.encode()).hexdigest()
            artifacts.append(record)
            print(f"  {fname}: {len(hlo)} chars")
    manifest = {
        "version": MANIFEST_VERSION,
        "constants": physics.CONSTANTS,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest.json: {len(artifacts)} artifacts -> {out_dir}")

    write_golden(out_dir)


if __name__ == "__main__":
    main()

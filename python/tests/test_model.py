"""L2 model tests: stage composition, shapes, and physics sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, physics
from compile.aot import generate_event
from compile.kernels import ref


def _event(rows=64, cols=64, particles=4, seed=1):
    ev = generate_event(np.random.default_rng(seed), rows, cols, particles)
    return {k: jnp.asarray(v) for k, v in ev.items()}


class TestSensorStage:
    def test_matches_ref(self):
        ev = _event()
        got = model.sensor_stage(ev["counts"], ev["a"], ev["b"], ev["na"],
                                 ev["nb"], ev["noisy"])
        want = ref.sensor_stage_ref(ev["counts"], ev["a"], ev["b"],
                                    ev["na"], ev["nb"], ev["noisy"])
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-6)

    def test_output_shapes(self):
        ev = _event(32, 32)
        out = model.sensor_stage(ev["counts"], ev["a"], ev["b"], ev["na"],
                                 ev["nb"], ev["noisy"])
        assert all(o.shape == (32, 32) and o.dtype == jnp.float32
                   for o in out)


class TestParticleStage:
    def test_matches_ref(self):
        ev = _event()
        energy, noise, sig = ref.sensor_stage_ref(
            ev["counts"], ev["a"], ev["b"], ev["na"], ev["nb"], ev["noisy"])
        seeds, sums = model.particle_stage(energy, sig, ev["types"],
                                           ev["noisy"])
        rseeds, rsums = ref.particle_stage_ref(energy, sig, ev["types"],
                                               ev["noisy"])
        np.testing.assert_array_equal(seeds, rseeds)
        np.testing.assert_allclose(sums, rsums, rtol=1e-5, atol=1e-4)

    def test_finds_injected_particles(self):
        """Events with injected deposits must yield at least one seed and
        plausible window energies at the seeds."""
        ev = _event(64, 64, particles=3, seed=3)
        energy, noise, sig = ref.sensor_stage_ref(
            ev["counts"], ev["a"], ev["b"], ev["na"], ev["nb"], ev["noisy"])
        seeds, sums = model.particle_stage(energy, sig, ev["types"],
                                           ev["noisy"])
        n = int(jnp.sum(seeds))
        assert n >= 1
        rr, cc = np.nonzero(np.asarray(seeds))
        e_plane = np.asarray(sums)[physics.PLANE_E]
        for r, c in zip(rr, cc):
            assert e_plane[r, c] > 0.0

    def test_empty_grid_no_seeds(self):
        z = jnp.zeros((32, 32), jnp.float32)
        zi = jnp.zeros((32, 32), jnp.int32)
        seeds, sums = model.particle_stage(z, z, zi, zi)
        assert int(jnp.sum(seeds)) == 0
        np.testing.assert_allclose(sums, 0.0)

    def test_per_type_planes_partition_energy(self):
        """Sum of the per-type energy planes equals the total energy plane
        (types partition the window)."""
        ev = _event(64, 64, particles=2, seed=5)
        energy, _, sig = ref.sensor_stage_ref(
            ev["counts"], ev["a"], ev["b"], ev["na"], ev["nb"], ev["noisy"])
        _, sums = model.particle_stage(energy, sig, ev["types"],
                                       ev["noisy"])
        sums = np.asarray(sums)
        per_type = sums[physics.PLANE_E_TYPE:
                        physics.PLANE_E_TYPE + physics.NUM_SENSOR_TYPES]
        np.testing.assert_allclose(per_type.sum(axis=0),
                                   sums[physics.PLANE_E],
                                   rtol=1e-4, atol=1e-3)


class TestFullEvent:
    def test_fused_equals_staged(self):
        ev = _event(64, 64, particles=3, seed=9)
        fused = model.full_event(ev["counts"], ev["a"], ev["b"], ev["na"],
                                 ev["nb"], ev["noisy"], ev["types"])
        want = ref.full_event_ref(ev["counts"], ev["a"], ev["b"], ev["na"],
                                  ev["nb"], ev["noisy"], ev["types"])
        for g, w in zip(fused, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-4)

    @settings(deadline=None, max_examples=8)
    @given(seed=st.integers(0, 2**31 - 1),
           particles=st.integers(0, 8))
    def test_fused_equals_staged_swept(self, seed, particles):
        ev = _event(32, 32, particles=particles, seed=seed)
        fused = model.full_event(ev["counts"], ev["a"], ev["b"], ev["na"],
                                 ev["nb"], ev["noisy"], ev["types"])
        want = ref.full_event_ref(ev["counts"], ev["a"], ev["b"], ev["na"],
                                  ev["nb"], ev["noisy"], ev["types"])
        for g, w in zip(fused, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-4)


class TestEventGenerator:
    def test_deterministic(self):
        a = generate_event(np.random.default_rng(3), 32, 32, 4)
        b = generate_event(np.random.default_rng(3), 32, 32, 4)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])

    def test_types_in_range(self):
        ev = generate_event(np.random.default_rng(0), 32, 32, 2)
        assert ev["types"].min() >= 0
        assert ev["types"].max() < physics.NUM_SENSOR_TYPES

    def test_particles_raise_counts(self):
        quiet = generate_event(np.random.default_rng(1), 64, 64, 0)
        busy = generate_event(np.random.default_rng(1), 64, 64, 10)
        assert busy["counts"].sum() > quiet["counts"].sum()

"""AOT path tests: HLO text emission, manifest integrity, goldens."""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from compile import aot, model, physics


class TestLowering:
    @pytest.mark.parametrize("entry", list(model.ENTRY_POINTS))
    def test_lowers_to_hlo_text(self, entry):
        hlo, record = aot.lower_entry(entry, 16, 16)
        assert hlo.startswith("HloModule")
        assert record["entry"] == entry
        assert record["rows"] == record["cols"] == 16
        assert len(record["inputs"]) >= 4
        assert len(record["outputs"]) >= 2

    def test_sensor_stage_io_spec(self):
        _, rec = aot.lower_entry("sensor_stage", 16, 16)
        assert [i["dtype"] for i in rec["inputs"]] == [
            "int32", "float32", "float32", "float32", "float32", "int32"]
        assert [o["dtype"] for o in rec["outputs"]] == ["float32"] * 3

    def test_particle_stage_io_spec(self):
        _, rec = aot.lower_entry("particle_stage", 16, 16)
        assert [o["dtype"] for o in rec["outputs"]] == ["int32", "float32"]
        assert rec["outputs"][1]["shape"] == [physics.NUM_PLANES, 16, 16]

    def test_deterministic_lowering(self):
        """Two lowerings of the same bucket yield identical HLO text —
        the basis of the identical-artifact zero-cost check."""
        h1, _ = aot.lower_entry("sensor_stage", 16, 16)
        h2, _ = aot.lower_entry("sensor_stage", 16, 16)
        assert h1 == h2


class TestManifest:
    def test_end_to_end_emission(self, tmp_path):
        out = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir",
             str(tmp_path), "--grids", "16", "--entries", "sensor_stage",
             "particle_stage"],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            capture_output=True, text=True)
        assert out.returncode == 0, out.stderr
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["version"] == aot.MANIFEST_VERSION
        assert manifest["constants"]["window"] == physics.WINDOW
        assert len(manifest["artifacts"]) == 2
        for rec in manifest["artifacts"]:
            assert (tmp_path / rec["file"]).exists()
        golden = json.loads(
            (tmp_path / "golden" / "golden.json").read_text())
        assert "sums" in golden["tensors"]

    def test_golden_roundtrip(self, tmp_path):
        aot.write_golden(str(tmp_path), rows=16, cols=16, n_particles=2)
        desc = json.loads((tmp_path / "golden" / "golden.json").read_text())
        for name, meta in desc["tensors"].items():
            arr = np.fromfile(tmp_path / "golden" / meta["file"],
                              dtype=meta["dtype"]).reshape(meta["shape"])
            assert arr.size > 0, name
        sums = np.fromfile(tmp_path / "golden" / "sums.bin",
                           dtype="float32")
        assert sums.shape[0] == physics.NUM_PLANES * 16 * 16


class TestVmemReport:
    def test_report_runs(self, capsys):
        aot.report_vmem([16, 1024])
        out = capsys.readouterr().out
        assert "calibrate" in out
        assert "1024" in out

    def test_within_vmem_budget(self):
        """Design target: every kernel's per-step working set <= 16 MiB."""
        from compile.kernels import calibrate as ck
        from compile.kernels import stencil as sk
        n = 1024
        cal = 9 * min(ck.TILE_ROWS, n) * n * 4
        t = min(sk.TILE_ROWS, n)
        halo = 2 * physics.HALO
        st = ((t + halo) * (n + halo) + t * n) * 4
        assert cal <= 16 * 2**20
        assert st <= 16 * 2**20

"""Pallas kernels vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes and value distributions; every kernel must match
ref.py to float32 tolerance on every drawn case.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.calibrate import calibrate
from compile.kernels.stencil import boxmax, boxsum
from compile.physics import NUM_PLANES, NUM_SENSOR_TYPES

# Grid buckets are powers of two >= 16; tests also sweep non-square shapes.
ROWS = st.sampled_from([16, 32, 64, 128])
COLS = st.sampled_from([16, 32, 48, 64, 96, 128])


def _rng(seed):
    return np.random.default_rng(seed)


def _grid_inputs(rng, rows, cols):
    counts = rng.integers(0, 5000, (rows, cols)).astype(np.int32)
    a = rng.uniform(0.1, 3.0, (rows, cols)).astype(np.float32)
    b = rng.uniform(-5.0, 5.0, (rows, cols)).astype(np.float32)
    na = rng.uniform(0.5, 5.0, (rows, cols)).astype(np.float32)
    nb = rng.uniform(0.0, 0.3, (rows, cols)).astype(np.float32)
    noisy = (rng.random((rows, cols)) < 0.05).astype(np.int32)
    return counts, a, b, na, nb, noisy


class TestCalibrate:
    @settings(deadline=None, max_examples=20)
    @given(rows=ROWS, cols=COLS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, rows, cols, seed):
        args = _grid_inputs(_rng(seed), rows, cols)
        got = calibrate(*map(jnp.asarray, args))
        want = ref.calibrate_ref(*map(jnp.asarray, args))
        for g, w, name in zip(got, want, ["energy", "noise", "sig"]):
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-6,
                                       err_msg=name)

    def test_noisy_sensors_zeroed(self):
        rows = cols = 16
        counts = np.full((rows, cols), 100, np.int32)
        ones = np.ones((rows, cols), np.float32)
        noisy = np.zeros((rows, cols), np.int32)
        noisy[3, 7] = 1
        energy, noise, sig = calibrate(*map(jnp.asarray, (
            counts, ones, ones * 0, ones, ones * 0.1, noisy)))
        assert energy[3, 7] == 0.0
        assert energy[0, 0] == 100.0
        assert sig[3, 7] == 0.0

    def test_zero_noise_guarded(self):
        """na = nb = 0 must not produce inf/nan significance."""
        rows = cols = 16
        z = np.zeros((rows, cols), np.float32)
        counts = np.full((rows, cols), 10, np.int32)
        energy, noise, sig = calibrate(*map(jnp.asarray, (
            counts, z + 1, z, z, z, np.zeros((rows, cols), np.int32))))
        assert np.all(np.isfinite(np.asarray(sig)))
        assert np.all(np.asarray(noise) >= 1e-6)

    def test_negative_energy_noise(self):
        """Negative calibrated energy: sqrt clamps at 0, noise = na."""
        rows = cols = 16
        counts = np.full((rows, cols), 1, np.int32)
        a = np.full((rows, cols), -5.0, np.float32)
        z = np.zeros((rows, cols), np.float32)
        na = np.full((rows, cols), 2.0, np.float32)
        nb = np.full((rows, cols), 0.5, np.float32)
        energy, noise, _ = calibrate(*map(jnp.asarray, (
            counts, a, z, na, nb, np.zeros((rows, cols), np.int32))))
        np.testing.assert_allclose(energy, -5.0)
        np.testing.assert_allclose(noise, 2.0)


class TestBoxSum:
    @settings(deadline=None, max_examples=20)
    @given(rows=ROWS, cols=COLS, ch=st.integers(1, 4),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, rows, cols, ch, seed):
        x = _rng(seed).normal(0, 10, (ch, rows, cols)).astype(np.float32)
        got = boxsum(jnp.asarray(x))
        want = ref.boxsum_ref(jnp.asarray(x))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_full_plane_count(self):
        """The real workload uses NUM_PLANES channels."""
        x = _rng(0).normal(0, 1, (NUM_PLANES, 32, 32)).astype(np.float32)
        np.testing.assert_allclose(boxsum(jnp.asarray(x)),
                                   ref.boxsum_ref(jnp.asarray(x)),
                                   rtol=1e-5, atol=1e-5)

    def test_impulse_response(self):
        """A unit impulse spreads to exactly the 5x5 window."""
        x = np.zeros((1, 32, 32), np.float32)
        x[0, 10, 20] = 1.0
        out = np.array(boxsum(jnp.asarray(x)))
        assert out.sum() == 25.0
        assert np.all(out[0, 8:13, 18:23] == 1.0)
        out[0, 8:13, 18:23] = 0.0
        assert np.all(out == 0.0)

    def test_border_zero_padded(self):
        x = np.ones((1, 16, 16), np.float32)
        out = np.asarray(boxsum(jnp.asarray(x)))
        assert out[0, 0, 0] == 9.0      # 3x3 of the window lands in-grid
        assert out[0, 8, 8] == 25.0
        assert out[0, 0, 8] == 15.0     # 3 rows x 5 cols


class TestBoxMax:
    @settings(deadline=None, max_examples=20)
    @given(rows=ROWS, cols=COLS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, rows, cols, seed):
        x = _rng(seed).normal(0, 10, (rows, cols)).astype(np.float32)
        got = boxmax(jnp.asarray(x))
        want = ref.boxmax_ref(jnp.asarray(x))
        np.testing.assert_allclose(got, want)

    def test_peak_dominates_window(self):
        x = np.zeros((32, 32), np.float32)
        x[5, 5] = 100.0
        out = np.asarray(boxmax(jnp.asarray(x)))
        assert np.all(out[3:8, 3:8] == 100.0)
        assert out[5, 8] == 0.0  # outside the window of the peak

    def test_negative_values_border(self):
        """-inf padding must not leak: all-negative plane keeps its max."""
        x = np.full((16, 16), -5.0, np.float32)
        out = np.asarray(boxmax(jnp.asarray(x)))
        assert np.all(out == -5.0)

//! Bench: the **zero-cost abstraction** claim (§VIII, "Marionette and
//! the equivalent handwritten solution display exactly the same
//! performance"; the PTX-identity claim, host edition).
//!
//! Compares per-element read and calibrate times between handwritten
//! structures and Marionette collections for every layout — including
//! the borrowed typed views (`m-*-view` series), which must cost the
//! same as the owned accessors — and asserts the matched pairs are
//! within tolerance. The device-side twin of the claim is structural:
//! both "handwritten" and "Marionette" device paths execute the *same*
//! AOT artifact (identical HLO, identical SHA-256 in the manifest).

use marionette::bench_support::figures::zero_cost;
use marionette::bench_support::{rel_diff, Harness};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("MARIONETTE_BENCH_QUICK").is_ok();
    let grid = if quick { 128 } else { 512 };
    let h = if quick { Harness::quick() } else { Harness::default() };
    let table = zero_cost(grid, h)?;
    println!("{}", table.render());
    let path = table.save_csv("zero_cost")?;
    println!("csv -> {}", path.display());

    // Matched-pair check (informational; hard assertions live in
    // tests/zero_cost.rs with a generous threshold for noisy machines).
    let find = |label: &str| {
        table
            .series
            .iter()
            .find(|s| s.label == label)
            .expect("series")
            .points
            .clone()
    };
    for (hw, m) in [
        ("hw-aos", "m-aos"),
        ("hw-soa", "m-soavec"),
        // Borrowed views vs the owned accessor baselines (the
        // attach-once, raw-offset-reads claim of the interface layer).
        ("m-aos-accessor", "m-aos-view"),
        ("m-soavec-accessor", "m-soavec-view"),
    ] {
        let (hws, ms) = (find(hw), find(m));
        for ((_, a), (op, b)) in hws.iter().zip(ms.iter()) {
            let d = rel_diff(*a, *b);
            println!(
                "{hw} vs {m} op{op}: hw={:.1}us m={:.1}us rel={:.1}%",
                a.as_secs_f64() * 1e6,
                b.as_secs_f64() * 1e6,
                d * 100.0
            );
        }
    }
    Ok(())
}

//! Bench: regenerate **Figure 1** — sensor-stage time (fill + transfer +
//! calibrate) vs grid side, series {CPU-AoS, CPU-SoA} × {handwritten,
//! Marionette} + device.
//!
//! Paper shape to verify: device overhead dominates below ~100×100, then
//! a fixed gap (transfer-bound); CPU-AoS ≈ CPU-SoA (all fields used);
//! Marionette ≡ handwritten everywhere.
//!
//! `cargo bench --bench fig1` (set MARIONETTE_BENCH_RUNS=10 for a quick
//! pass; full grids up to 1024).

use marionette::bench_support::figures::{fig1, FigOpts};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("MARIONETTE_BENCH_QUICK").is_ok();
    let opts = if quick {
        FigOpts::quick()
    } else {
        FigOpts {
            grids: vec![16, 32, 64, 128, 256, 512, 1024],
            ..FigOpts::default()
        }
    };
    let table = fig1(&opts)?;
    println!("{}", table.render());
    let path = table.save_csv("fig1")?;
    println!("csv -> {}", path.display());
    Ok(())
}

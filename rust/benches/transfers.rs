//! Bench: **transfer machinery** (§VII-A/B) — layout-conversion ladder
//! (plane / strided / element-wise rungs), host→staging uploads with DMA
//! accounting, raw `memcopy_with_context` bandwidth, and the
//! plan-amortisation comparison (one cached `TransferPlan` executed N
//! times vs the per-call ladder walk).

use marionette::bench_support::figures::{transfers, PLANNED_SERIES, UNPLANNED_SERIES};
use marionette::bench_support::Harness;
use marionette::marionette::transfer::plan_cache_stats;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("MARIONETTE_BENCH_QUICK").is_ok();
    let grid = if quick { 64 } else { 256 };
    let h = if quick { Harness::quick() } else { Harness::default() };
    let table = transfers(grid, h)?;
    println!("{}", table.render());

    // Plan amortisation: compiled-once execution vs walking the ladder
    // on every call (the paper's compile-time TransferSpecification
    // claim, §VII-B).
    let time_of = |label: &str| {
        table
            .series
            .iter()
            .find(|s| s.label == label)
            .and_then(|s| s.points.first())
            .map(|&(_, t)| t)
    };
    if let (Some(unplanned), Some(planned)) =
        (time_of(UNPLANNED_SERIES), time_of(PLANNED_SERIES))
    {
        let ratio = unplanned.as_secs_f64() / planned.as_secs_f64().max(1e-12);
        println!(
            "plan amortisation (SoAVec -> staging SoABlob): \
             ladder {:.1}us vs planned {:.1}us -> {ratio:.2}x",
            unplanned.as_secs_f64() * 1e6,
            planned.as_secs_f64() * 1e6,
        );
    }
    let cache = plan_cache_stats();
    println!(
        "plan cache: {} entries, {} hits, {} misses",
        cache.entries, cache.hits, cache.misses
    );

    let path = table.save_csv("transfers")?;
    println!("csv -> {}", path.display());
    Ok(())
}

//! Bench: **transfer machinery** (§VII-A/B) — layout-conversion ladder
//! (plane / strided / element-wise rungs), host→staging uploads with DMA
//! accounting, and raw `memcopy_with_context` bandwidth.

use marionette::bench_support::figures::transfers;
use marionette::bench_support::Harness;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("MARIONETTE_BENCH_QUICK").is_ok();
    let grid = if quick { 64 } else { 256 };
    let h = if quick { Harness::quick() } else { Harness::default() };
    let table = transfers(grid, h)?;
    println!("{}", table.render());
    let path = table.save_csv("transfers")?;
    println!("csv -> {}", path.display());
    Ok(())
}

//! Bench: regenerate **Figure 2** — particle-stage time (reconstruct +
//! transfer back + fill the original AoS) vs injected particle count at
//! a fixed grid.
//!
//! Paper shape to verify: device wins; transfer/conversion overhead
//! grows past ~10⁴ particles; the CPU-SoA advantage shrinks with
//! particle count; Marionette ≡ handwritten.
//!
//! Grid defaults to 1024² (the paper used 5000²; see DESIGN.md
//! substitutions). `MARIONETTE_FIG2_GRID=512` overrides.

use marionette::bench_support::figures::{fig2, FigOpts};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("MARIONETTE_BENCH_QUICK").is_ok();
    let mut opts = if quick { FigOpts::quick() } else { FigOpts::default() };
    if let Ok(g) = std::env::var("MARIONETTE_FIG2_GRID") {
        opts.fig2_grid = g.parse()?;
    }
    let table = fig2(&opts)?;
    println!("{}", table.render());
    let path = table.save_csv("fig2")?;
    println!("csv -> {}", path.display());
    Ok(())
}

//! Bench: **ablations** of the design choices DESIGN.md calls out:
//!
//! 1. layout sweep (SoA-vec / AoS / SoA-blob / AoSoA-K) over both host
//!    algorithms — the paper's "experiment with different data layouts"
//!    motivation;
//! 2. fused `full_event` vs staged `sensor_stage`+`particle_stage` on
//!    the device — the "sidestepping unnecessary conversions" claim;
//! 3. routing policies through the full coordinator.

use marionette::bench_support::figures::{ablation_fused, ablation_layouts, ablation_routing};
use marionette::bench_support::Harness;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("MARIONETTE_BENCH_QUICK").is_ok();
    let h = if quick { Harness::quick() } else { Harness::default() };
    let grid = if quick { 64 } else { 256 };

    let t = ablation_layouts(grid, (grid / 32).max(1).pow(2), h)?;
    println!("{}", t.render());
    t.save_csv("ablation_layouts")?;

    match ablation_fused(
        if quick { &[16, 32, 64] } else { &[64, 128, 256, 512] },
        h,
    ) {
        Ok(t) => {
            println!("{}", t.render());
            t.save_csv("ablation_fused")?;
        }
        Err(e) => eprintln!("fused ablation skipped: {e:#}"),
    }

    match ablation_routing(grid, if quick { 8 } else { 32 }) {
        Ok(t) => {
            println!("{}", t.render());
            t.save_csv("ablation_routing")?;
        }
        Err(e) => eprintln!("routing ablation skipped: {e:#}"),
    }
    Ok(())
}

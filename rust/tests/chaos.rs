//! Chaos-harness integration tests (DESIGN.md §10): under seeded fault
//! injection — device-worker kills, injected engine errors, allocation
//! and transfer faults — the pipeline must lose nothing (every event
//! completes or is reported quarantined), completed events must match
//! the clean run's physics, and the fired fault schedule must be a
//! pure function of the plan (same seed ⇒ bit-identical counters).
//!
//! All tests pin one host and one device worker: every injector
//! triggers on a *count* (Nth allocation, Kth dequeue, Nth transfer
//! execution), so a single-worker run makes the schedule — and the
//! counters the determinism test compares — independent of thread
//! timing.

use std::sync::Mutex;

use marionette::coordinator::{
    run_pipeline, FaultPlan, PipelineConfig, PipelineError, PipelineReport, RoutePolicy,
};
use marionette::edm::generator::EventConfig;

/// The transfer-fault hook is process-global, so tests in this binary
/// that run armed plans must not overlap; everything serialises here.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn chaos_lock() -> std::sync::MutexGuard<'static, ()> {
    // An assert failure in another test must not cascade as poison.
    CHAOS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

const EVENTS: usize = 24;

fn chaos_cfg(seed: u64, plan: FaultPlan) -> PipelineConfig {
    let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 3), EVENTS);
    cfg.device = true;
    cfg.policy = RoutePolicy::DeviceOnly;
    cfg.host_workers = 1;
    cfg.device_workers = 1;
    cfg.seed = seed;
    cfg.fault = Some(plan);
    cfg
}

fn clean_cfg(seed: u64) -> PipelineConfig {
    let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 3), EVENTS);
    cfg.device = false;
    cfg.policy = RoutePolicy::HostOnly;
    cfg.host_workers = 1;
    cfg.seed = seed;
    cfg
}

fn fault_counters(rep: &PipelineReport) -> (u64, u64, u64, u64, u64) {
    let m = &rep.metrics;
    (
        m.fault_injected,
        m.fault_recovered,
        m.fault_requeued,
        m.fault_quarantined,
        m.fault_respawns,
    )
}

/// Property: for randomized-but-seeded fault plans, every submitted
/// event lands in exactly one of {completed, quarantined}, and every
/// completed event carries the clean run's physics.
#[test]
fn randomized_fault_plans_never_lose_events() {
    let _g = chaos_lock();
    for seed in 0..8u64 {
        let plan = FaultPlan::from_seed(seed);
        let golden = run_pipeline(&clean_cfg(seed)).unwrap();
        let rep = run_pipeline(&chaos_cfg(seed, plan.clone()))
            .unwrap_or_else(|e| panic!("seed {seed} plan {plan:?}: {e:#}"));

        let mut seen: Vec<u64> = rep.results.iter().map(|r| r.event_id).collect();
        seen.extend(rep.quarantined.iter().copied());
        seen.sort_unstable();
        assert_eq!(
            seen,
            (0..EVENTS as u64).collect::<Vec<u64>>(),
            "seed {seed}: exactly-once violated ({} completed + {} quarantined, \
             plan {plan:?})",
            rep.results.len(),
            rep.quarantined.len(),
        );

        for r in &rep.results {
            let g = &golden.results[r.event_id as usize];
            assert_eq!(g.event_id, r.event_id);
            assert_eq!(
                g.n_particles, r.n_particles,
                "seed {seed} event {}: particle count diverged from clean run",
                r.event_id
            );
            let rel =
                (g.total_energy - r.total_energy).abs() / g.total_energy.abs().max(1.0);
            assert!(
                rel < 1e-3,
                "seed {seed} event {}: energy drift {rel} vs clean run",
                r.event_id
            );
        }
    }
}

/// Determinism: the same seed and plan must fire the identical fault
/// schedule — all five counters, the quarantine list, and the surviving
/// results agree bit-for-bit between runs.
#[test]
fn same_seed_runs_produce_identical_fault_counters() {
    let _g = chaos_lock();
    let plan = FaultPlan::new(11)
        .kill_device_at(4)
        .alloc_fail_every(7)
        .transfer_fail_every(11)
        .retry_budget(2);
    let a = run_pipeline(&chaos_cfg(11, plan.clone())).unwrap();
    let b = run_pipeline(&chaos_cfg(11, plan)).unwrap();

    let (ca, cb) = (fault_counters(&a), fault_counters(&b));
    assert_eq!(ca, cb, "fault counters diverged between same-seed runs");
    assert!(ca.0 >= 1, "plan armed three injectors but nothing fired");
    assert_eq!(a.quarantined, b.quarantined, "quarantine lists diverged");
    assert_eq!(a.results.len(), b.results.len());
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.event_id, y.event_id);
        assert_eq!(x.n_particles, y.n_particles, "event {}", x.event_id);
    }
}

/// `worker_abort` lets the kill escape supervision: the run must come
/// back as a typed error with the partial metrics intact — and the
/// process must stay healthy (global hooks disarmed) for the next run.
#[test]
fn worker_abort_is_reported_and_process_survives() {
    let _g = chaos_lock();
    let plan = FaultPlan::new(5).kill_device_at(2).worker_abort(true);
    let err = run_pipeline(&chaos_cfg(5, plan)).unwrap_err();
    let pe = err
        .downcast_ref::<PipelineError>()
        .expect("worker panic must downcast to PipelineError");
    assert_eq!(pe.panicked_workers, 1);
    assert_eq!(pe.report.metrics.events_in, EVENTS, "partial metrics lost");
    assert!(pe.report.metrics.fault_injected >= 1, "kill not counted");

    // A clean run right after completes fully: nothing leaked from the
    // aborted run's armed state.
    let rep = run_pipeline(&clean_cfg(5)).unwrap();
    assert_eq!(rep.results.len(), EVENTS);
    assert!(rep.quarantined.is_empty());
    assert_eq!(fault_counters(&rep), (0, 0, 0, 0, 0), "clean run booked faults");
}

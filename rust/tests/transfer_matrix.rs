//! Rung-matrix test for the compiled transfer plans (§VII-B).
//!
//! For **every** (SoAVec, AoS, SoABlob, AoSoA<4>) × (Host, Aligned<64>,
//! Arena, Counting, Staging) source/destination pair — the full 20×20
//! cross product — this asserts:
//!
//! * the `TransferPriority` the compiled plan resolves to (rung
//!   selection is a property of the layout pair, never of the contexts);
//! * the plan's op count after coalescing (identical blob layouts
//!   collapse to one block copy per size tag — fewer memcpy ops than
//!   field-lanes);
//! * round-trip equality src → dst → src, jagged fields included.
//!
//! The schema exercises every field kind: per-item scalars, a
//! fixed-extent array, a jagged vector (prefix + values), and a global.

#![allow(dead_code)] // the generated typed twin exposes more than the tests touch

use std::sync::Arc;

use marionette::marionette::collection::RawCollection;
use marionette::marionette::layout::{AoS, AoSoA, Layout, SoABlob, SoAVec};
use marionette::marionette::memory::{
    AlignedContext, ArenaContext, CountingContext, HostContext, MemoryContext, PoolContext,
    PoolInfo, StagingContext,
};
use marionette::marionette::schema::Schema;
use marionette::marionette::transfer::{
    copy_collection, copy_collection_stats, plan_for, TransferPriority,
};
use marionette::marionette_collection;

marionette_collection! {
    /// Typed twin of the matrix schema: its generated view attaches to
    /// the runtime-built collections below, so pool-recycled rows can
    /// be read through the borrowed typed interface.
    pub collection MatrixCollection, object MatrixObj, record MatrixRecord,
        columns MatrixColumns, refs MatrixRef / MatrixMut,
        views MatrixView / MatrixViewMut,
        props MatrixProps, schema "matrix" {
        per_item e / set_e / E: f32;
        per_item t / set_t / T: i32;
        array sig / set_sig / SIG: [f32; 2];
        jagged cells / set_cells / CELLS: u64, prefix u32;
        global ev / set_ev / EV: u64;
    }
}

/// The blocked layout with its context still open (macro-friendly).
type AoSoA4<C> = AoSoA<4, C>;

/// The pooled context rows are exercised under.
type PoolHost = PoolContext<HostContext>;

/// Field-lane count of the test schema: e + t + sig[2 lanes] +
/// cells prefix + cells values + ev = 7.
const FIELD_LANES: usize = 7;
/// Non-empty size tags: Items, ItemsPlusOne, Global, Values(0).
const TAGS: usize = 4;

fn schema() -> Arc<Schema> {
    Arc::new(
        Schema::builder("matrix")
            .per_item::<f32>("e")
            .per_item::<i32>("t")
            .array::<f32>("sig", 2)
            .jagged::<u64, u32>("cells")
            .global::<u64>("ev")
            .build(),
    )
}

fn build_src<L: Layout>(s: &Arc<Schema>) -> RawCollection<L>
where
    <L::Ctx as MemoryContext>::Info: Default,
{
    let m_e = s.meta(s.field_by_name("e").unwrap());
    let m_t = s.meta(s.field_by_name("t").unwrap());
    let m_sig = s.meta(s.field_by_name("sig").unwrap());
    let m_cells = s.meta(s.field_by_name("cells").unwrap());
    let m_ev = s.meta(s.field_by_name("ev").unwrap());
    let mut c = RawCollection::<L>::new(s.clone());
    c.set_global::<u64>(m_ev, 77);
    for i in 0..6 {
        c.push_default();
        c.set::<f32>(m_e, i, i as f32 * 1.25);
        c.set::<i32>(m_t, i, 3 - i as i32);
        c.set_k::<f32>(m_sig, i, 0, i as f32);
        c.set_k::<f32>(m_sig, i, 1, -(i as f32));
        let v0 = c.append_values(0, i % 3);
        for n in 0..(i % 3) {
            c.set_value::<u64>(m_cells, v0 + n, (100 * i + n) as u64);
        }
    }
    c
}

fn check_equal<LA: Layout, LB: Layout>(a: &RawCollection<LA>, b: &RawCollection<LB>) {
    let s = a.schema();
    let m_e = s.meta(s.field_by_name("e").unwrap());
    let m_t = s.meta(s.field_by_name("t").unwrap());
    let m_sig = s.meta(s.field_by_name("sig").unwrap());
    let m_cells = s.meta(s.field_by_name("cells").unwrap());
    let m_ev = s.meta(s.field_by_name("ev").unwrap());
    assert_eq!(a.len(), b.len());
    assert_eq!(a.get_global::<u64>(m_ev), b.get_global::<u64>(m_ev));
    for i in 0..a.len() {
        assert_eq!(a.get::<f32>(m_e, i), b.get::<f32>(m_e, i));
        assert_eq!(a.get::<i32>(m_t, i), b.get::<i32>(m_t, i));
        for k in 0..2 {
            assert_eq!(a.get_k::<f32>(m_sig, i, k), b.get_k::<f32>(m_sig, i, k));
        }
        assert_eq!(
            a.jagged_view::<u64>(m_cells, 0, i).to_vec(),
            b.jagged_view::<u64>(m_cells, 0, i).to_vec(),
        );
    }
}

/// One (layout+context) → (layout+context) combination: plan
/// introspection + forward copy + round trip.
macro_rules! combo {
    ($s:expr, $L1:ident, $C1:ty, $L2:ident, $C2:ty, $prio:expr, $ops:expr) => {{
        let src = build_src::<$L1<$C1>>($s);
        let plan = plan_for::<$L1<$C1>, $L2<$C2>>(src.schema());
        assert_eq!(plan.priority(), $prio, "{}", plan.describe());
        assert_eq!(plan.num_ops(), $ops, "{}", plan.describe());
        assert_eq!(plan.field_lane_ops(), FIELD_LANES, "{}", plan.describe());
        if $ops < FIELD_LANES {
            // Coalesced: adjacent planes collapsed below one-per-lane.
            assert!(plan.num_ops() < plan.field_lane_ops(), "{}", plan.describe());
        }
        let mut dst = RawCollection::<$L2<$C2>>::new(src.schema().clone());
        let p = copy_collection(&src, &mut dst);
        assert_eq!(p, $prio, "{}", plan.describe());
        check_equal(&src, &dst);
        let mut back = RawCollection::<$L1<$C1>>::new(src.schema().clone());
        copy_collection(&dst, &mut back);
        check_equal(&src, &back);
    }};
}

/// Expand a layout pair across every destination context.
macro_rules! with_dst_ctx {
    ($s:expr, $L1:ident, $C1:ty, $L2:ident, $prio:expr, $ops:expr) => {
        combo!($s, $L1, $C1, $L2, HostContext, $prio, $ops);
        combo!($s, $L1, $C1, $L2, AlignedContext<64>, $prio, $ops);
        combo!($s, $L1, $C1, $L2, ArenaContext, $prio, $ops);
        combo!($s, $L1, $C1, $L2, CountingContext, $prio, $ops);
        combo!($s, $L1, $C1, $L2, StagingContext, $prio, $ops);
    };
}

/// Expand a layout pair across every (src, dst) context pair.
macro_rules! with_ctx_pairs {
    ($s:expr, $L1:ident, $L2:ident, $prio:expr, $ops:expr) => {
        with_dst_ctx!($s, $L1, HostContext, $L2, $prio, $ops);
        with_dst_ctx!($s, $L1, AlignedContext<64>, $L2, $prio, $ops);
        with_dst_ctx!($s, $L1, ArenaContext, $L2, $prio, $ops);
        with_dst_ctx!($s, $L1, CountingContext, $L2, $prio, $ops);
        with_dst_ctx!($s, $L1, StagingContext, $L2, $prio, $ops);
    };
}

#[test]
fn matrix_from_soavec() {
    let s = schema();
    with_ctx_pairs!(&s, SoAVec, SoAVec, TransferPriority::Plane, FIELD_LANES);
    with_ctx_pairs!(&s, SoAVec, AoS, TransferPriority::Strided, FIELD_LANES);
    with_ctx_pairs!(&s, SoAVec, SoABlob, TransferPriority::Plane, FIELD_LANES);
    with_ctx_pairs!(&s, SoAVec, AoSoA4, TransferPriority::Elementwise, FIELD_LANES);
}

#[test]
fn matrix_from_aos() {
    let s = schema();
    with_ctx_pairs!(&s, AoS, SoAVec, TransferPriority::Strided, FIELD_LANES);
    // Identical record layout on both sides: every plane of a tag is
    // byte-adjacent and the plan coalesces to one block copy per tag.
    with_ctx_pairs!(&s, AoS, AoS, TransferPriority::Plane, TAGS);
    with_ctx_pairs!(&s, AoS, SoABlob, TransferPriority::Strided, FIELD_LANES);
    with_ctx_pairs!(&s, AoS, AoSoA4, TransferPriority::Elementwise, FIELD_LANES);
}

#[test]
fn matrix_from_soablob() {
    let s = schema();
    with_ctx_pairs!(&s, SoABlob, SoAVec, TransferPriority::Plane, FIELD_LANES);
    with_ctx_pairs!(&s, SoABlob, AoS, TransferPriority::Strided, FIELD_LANES);
    with_ctx_pairs!(&s, SoABlob, SoABlob, TransferPriority::Plane, FIELD_LANES);
    with_ctx_pairs!(&s, SoABlob, AoSoA4, TransferPriority::Elementwise, FIELD_LANES);
}

#[test]
fn matrix_from_aosoa() {
    let s = schema();
    with_ctx_pairs!(&s, AoSoA4, SoAVec, TransferPriority::Elementwise, FIELD_LANES);
    with_ctx_pairs!(&s, AoSoA4, AoS, TransferPriority::Elementwise, FIELD_LANES);
    with_ctx_pairs!(&s, AoSoA4, SoABlob, TransferPriority::Elementwise, FIELD_LANES);
    // Same block size both sides: byte-identical blobs, one block copy
    // per tag.
    with_ctx_pairs!(&s, AoSoA4, AoSoA4, TransferPriority::Plane, TAGS);
}

/// Pool-backed rows: [`PoolHost`] as the source context across every
/// destination context, and as the destination across every source
/// context. Rung selection and coalesced-op counts are properties of
/// the *layout* pair — pooling the context must not change them.
macro_rules! pool_rows {
    ($s:expr, $L1:ident, $L2:ident, $prio:expr, $ops:expr) => {
        with_dst_ctx!($s, $L1, PoolHost, $L2, $prio, $ops);
        combo!($s, $L1, HostContext, $L2, PoolHost, $prio, $ops);
        combo!($s, $L1, AlignedContext<64>, $L2, PoolHost, $prio, $ops);
        combo!($s, $L1, ArenaContext, $L2, PoolHost, $prio, $ops);
        combo!($s, $L1, CountingContext, $L2, PoolHost, $prio, $ops);
        combo!($s, $L1, StagingContext, $L2, PoolHost, $prio, $ops);
        combo!($s, $L1, PoolHost, $L2, PoolHost, $prio, $ops);
    };
}

#[test]
fn matrix_pool_rows() {
    let s = schema();
    pool_rows!(&s, SoAVec, SoAVec, TransferPriority::Plane, FIELD_LANES);
    pool_rows!(&s, SoAVec, AoS, TransferPriority::Strided, FIELD_LANES);
    pool_rows!(&s, AoS, SoAVec, TransferPriority::Strided, FIELD_LANES);
    pool_rows!(&s, AoS, AoS, TransferPriority::Plane, TAGS);
    pool_rows!(&s, AoSoA4, AoSoA4, TransferPriority::Plane, TAGS);
    pool_rows!(&s, SoAVec, AoSoA4, TransferPriority::Elementwise, FIELD_LANES);
    pool_rows!(&s, SoABlob, SoABlob, TransferPriority::Plane, FIELD_LANES);
}

/// The stale-capacity reuse hazard in isolation: a destination built
/// entirely from *recycled* blocks (same pool, second build replays the
/// first build's growth ladder off the free lists) must still select
/// the coalesced rung, issue the same op count, and round-trip — and a
/// smaller re-execute into its now-oversized storage must not leak
/// stale elements.
#[test]
fn recycled_destination_with_stale_capacity_roundtrips() {
    let s = schema();
    let info = PoolInfo::<HostContext>::default();
    let src = build_src::<AoS<HostContext>>(&s);

    // First build: populates the pool's size classes, then returns every
    // block on drop.
    {
        let mut dst =
            RawCollection::<AoS<PoolHost>>::new_in(s.clone(), info.clone());
        copy_collection(&src, &mut dst);
        check_equal(&src, &dst);
    }
    let warmed = info.0.stats();
    assert!(warmed.misses > 0);
    assert_eq!(warmed.outstanding, 0, "drop must check every block back in");

    // Second build: identical growth ladder, now running on recycled
    // blocks only — the coalesced plan and its op count are unchanged.
    let mut dst = RawCollection::<AoS<PoolHost>>::new_in(s.clone(), info.clone());
    let stats = copy_collection_stats(&src, &mut dst);
    assert_eq!(stats.priority, TransferPriority::Plane);
    assert_eq!(stats.ops, TAGS);
    check_equal(&src, &dst);
    let recycled = info.0.stats();
    assert_eq!(recycled.misses, warmed.misses, "recycled build must not allocate");
    assert!(recycled.hits > warmed.hits);

    // Shrink the source and re-execute into the oversized recycled
    // destination: lengths, prefix sums and values must all track the
    // small source (stale-capacity bytes stay invisible).
    let mut small = RawCollection::<AoS<HostContext>>::new(s.clone());
    small.resize(2);
    let m_e = s.meta(s.field_by_name("e").unwrap());
    small.set::<f32>(m_e, 0, 41.5);
    small.set::<f32>(m_e, 1, -7.25);
    copy_collection(&small, &mut dst);
    check_equal(&small, &dst);
    assert_eq!(dst.len(), 2);
    assert_eq!(dst.values_len(0), 0);

    // The same stale-capacity row read *through the borrowed typed
    // view*: the view's attach-time length tracks the shrunken item
    // count, its reads match the owned accessors, and the recycled
    // block's stale tail never leaks into a jagged range.
    let v = MatrixView::attach(&dst).expect("view attaches to the pooled store");
    assert_eq!(v.len(), 2);
    assert_eq!(v.e(0), 41.5);
    assert_eq!(v.e(1), -7.25);
    assert_eq!(v.cells(0).len(), 0);
    assert_eq!(v.cells(1).len(), 0);
    assert_eq!(v.ev(), 0);
}

/// The coalescing claim in isolation: same-layout blob pairs use fewer
/// memcpy ops than the schema has field-lanes, and still round-trip.
#[test]
fn coalescing_beats_per_field_ops() {
    let s = schema();
    let aos = plan_for::<AoS, AoS>(&s);
    assert_eq!(aos.num_ops(), TAGS);
    assert!(aos.num_ops() < aos.field_lane_ops());
    let blocked = plan_for::<AoSoA4<HostContext>, AoSoA4<HostContext>>(&s);
    assert_eq!(blocked.num_ops(), TAGS);
    assert!(blocked.num_ops() < blocked.field_lane_ops());
    // Mixed block sizes must NOT coalesce (different byte layouts).
    let mixed = plan_for::<AoSoA<4>, AoSoA<16>>(&s);
    assert_eq!(mixed.priority(), TransferPriority::Elementwise);
    assert_eq!(mixed.num_ops(), FIELD_LANES);
}

/// Plans for the matrix are compiled once per (schema, pair) tuple: the
/// second lookup of any combination is a cache hit.
#[test]
fn matrix_lookups_hit_the_cache() {
    let s = schema();
    let p1 = plan_for::<SoAVec<CountingContext>, SoABlob<StagingContext>>(&s);
    let before = marionette::marionette::transfer::plan_cache_stats();
    let p2 = plan_for::<SoAVec<CountingContext>, SoABlob<StagingContext>>(&s);
    let after = marionette::marionette::transfer::plan_cache_stats();
    assert!(Arc::ptr_eq(&p1, &p2));
    assert!(after.hits > before.hits);
}

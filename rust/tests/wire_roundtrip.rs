//! Tier-1 coverage for the wire subsystem (DESIGN.md §11): frame
//! round-trips from every layout family, the jagged `Particle`
//! collection, deliberate header/body corruption surfacing every
//! [`WireError`] variant, the zero-copy attach contract, and the
//! multi-process socketpair ingest path against the in-process golden.

use marionette::coordinator::{
    golden_compare, run_socketpair_ingest, verify_exactly_once, ServeOpts,
};
use marionette::edm::{
    EventConfig, EventGenerator, Particle, ParticleCollection, ParticleProps, ParticleView,
    SensorCollection, SensorProps, SensorView, NUM_SENSOR_TYPES,
};
use marionette::marionette::collection::InfoOf;
use marionette::marionette::wire::FIXED_HEADER;
use marionette::prelude::{
    crc32, encode_frame, schema_hash, AoS, AoSoA, Frame, Layout, LayoutChoice, PlaneSource,
    SoABlob, SoAVec, WireError, WIRE_VERSION,
};

// ---------------------------------------------------------------------
// Round-trips: every layout family normalizes to the same dense-plane
// body, and a view attached over the received frame reads back exactly
// what the source collection held.
// ---------------------------------------------------------------------

fn sensor_roundtrip<L: Layout>(expect_layout_code: u32)
where
    InfoOf<L>: Default,
{
    let ev = EventGenerator::new(EventConfig::grid(12, 12, 3), 7).generate();
    let mut c = SensorCollection::<L>::new();
    ev.fill_collection(&mut c);

    let frame = Frame::decode(encode_frame(&c, ev.event_id)).unwrap();
    assert_eq!(frame.frame_id(), ev.event_id);
    assert_eq!(frame.items(), c.len());
    assert_eq!(frame.layout_code(), expect_layout_code);
    let schema = SensorProps::schema();
    assert_eq!(frame.schema_hash(), schema_hash(&schema));

    let fs = frame.source(&schema).unwrap();
    let v = SensorView::attach(&fs).unwrap();
    assert_eq!(v.len(), c.len());
    for i in 0..c.len() {
        assert_eq!(v.type_id(i), c.type_id(i));
        assert_eq!(v.counts(i), c.counts(i));
        assert_eq!(v.energy(i).to_bits(), c.energy(i).to_bits());
        assert_eq!(v.noise(i).to_bits(), c.noise(i).to_bits());
        assert_eq!(v.sig(i).to_bits(), c.sig(i).to_bits());
    }
    assert_eq!(v.rows(), c.rows());
    assert_eq!(v.cols(), c.cols());
    assert_eq!(v.event_id(), c.event_id());

    // Zero-copy attach contract: planes handed out by the source point
    // into the frame's own receive buffer — nothing was copied out.
    let m = schema.meta(schema.field_by_name("counts").unwrap());
    let p = fs.plane(m, 0).unwrap();
    let range = frame.as_bytes().as_ptr_range();
    assert!(p.base >= range.start && p.base < range.end);
}

#[test]
fn sensor_frames_roundtrip_from_every_layout() {
    sensor_roundtrip::<SoAVec>(1);
    sensor_roundtrip::<AoS>(2);
    sensor_roundtrip::<SoABlob>(3);
    sensor_roundtrip::<AoSoA<8>>(4);
}

fn particle_roundtrip<L: Layout>()
where
    InfoOf<L>: Default,
{
    let mut c = ParticleCollection::<L>::new();
    c.set_event_id(4242);
    let mut p = Particle {
        energy: 120.0,
        x: 3.5,
        y: 7.25,
        x_variance: 0.5,
        y_variance: 0.75,
        origin: 9,
        significance: [5.0, 2.0, 0.5],
        e_contribution: [80.0, 30.0, 10.0],
        noisy_count: [0, 1, 2],
        sensors: vec![41, 42, 43, 52],
    };
    c.push(&p);
    p.sensors = vec![7];
    p.energy = 50.0;
    c.push(&p);
    p.sensors = vec![]; // empty jagged entry must survive the wire
    p.energy = 0.25;
    c.push(&p);
    p.sensors = (0..9).collect();
    c.push(&p);

    let frame = Frame::decode(encode_frame(&c, 4242)).unwrap();
    let schema = ParticleProps::schema();
    let fs = frame.source(&schema).unwrap();
    let v = ParticleView::attach(&fs).unwrap();
    assert_eq!(v.len(), c.len());
    for i in 0..c.len() {
        assert_eq!(v.energy(i).to_bits(), c.energy(i).to_bits());
        assert_eq!(v.x(i).to_bits(), c.x(i).to_bits());
        assert_eq!(v.origin(i), c.origin(i));
        for k in 0..NUM_SENSOR_TYPES {
            assert_eq!(v.significance(i, k).to_bits(), c.significance(i, k).to_bits());
            assert_eq!(v.e_contribution(i, k).to_bits(), c.e_contribution(i, k).to_bits());
            assert_eq!(v.noisy_count(i, k), c.noisy_count(i, k));
        }
        assert_eq!(v.sensors(i).to_vec(), c.sensors(i).to_vec());
    }
    assert_eq!(v.event_id(), 4242);
}

#[test]
fn jagged_particle_frames_roundtrip_from_every_layout() {
    particle_roundtrip::<SoAVec>();
    particle_roundtrip::<AoS>();
    particle_roundtrip::<SoABlob>();
    particle_roundtrip::<AoSoA<8>>();
}

// ---------------------------------------------------------------------
// Corruption: every WireError variant is reachable from a poisoned
// buffer, and none of them panics.
// ---------------------------------------------------------------------

fn sensor_frame_bytes() -> Vec<u8> {
    let ev = EventGenerator::new(EventConfig::grid(8, 8, 3), 3).generate();
    let mut c = SensorCollection::<SoAVec>::new();
    ev.fill_collection(&mut c);
    encode_frame(&c, ev.event_id).as_slice().to_vec()
}

/// Recompute the checksum after deliberately corrupting covered bytes,
/// so the test reaches the validation layers *behind* the CRC.
fn repatch_crc(b: &mut [u8]) {
    let c = crc32(&b[16..]);
    b[8..12].copy_from_slice(&c.to_le_bytes());
}

#[test]
fn every_wire_error_variant_surfaces() {
    let good = sensor_frame_bytes();
    assert!(Frame::decode_slice(&good).is_ok());

    // Truncated, at both layers: inside the fixed header, and mid-body.
    match Frame::decode_slice(&good[..10]) {
        Err(WireError::Truncated { need, have }) => {
            assert_eq!(need, FIXED_HEADER);
            assert_eq!(have, 10);
        }
        r => panic!("expected Truncated, got {:?}", r.err()),
    }
    match Frame::decode_slice(&good[..good.len() - 8]) {
        Err(WireError::Truncated { need, have }) => {
            assert_eq!(need, good.len());
            assert_eq!(have, good.len() - 8);
        }
        r => panic!("expected Truncated, got {:?}", r.err()),
    }

    // BadMagic: the magic sits outside CRC coverage — a direct flip.
    let mut bad = good.clone();
    bad[0] = b'X';
    assert!(matches!(Frame::decode_slice(&bad), Err(WireError::BadMagic { .. })));

    // VersionSkew: also outside CRC coverage; hard reject, never a
    // silent cross-version reinterpret.
    let mut bad = good.clone();
    bad[4..8].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
    match Frame::decode_slice(&bad) {
        Err(WireError::VersionSkew { got, want }) => {
            assert_eq!(got, WIRE_VERSION + 1);
            assert_eq!(want, WIRE_VERSION);
        }
        r => panic!("expected VersionSkew, got {:?}", r.err()),
    }

    // Crc: any covered byte flips the checksum.
    let mut bad = good.clone();
    let n = bad.len();
    bad[n - 1] ^= 0x40;
    assert!(matches!(Frame::decode_slice(&bad), Err(WireError::Crc { .. })));

    // Malformed #1: trailing bytes after a complete frame.
    let mut bad = good.clone();
    bad.extend_from_slice(&[0u8; 8]);
    assert!(matches!(Frame::decode_slice(&bad), Err(WireError::Malformed { .. })));

    // Malformed #2: unknown dtype code in the field table. The table is
    // CRC-covered, so the checksum is re-patched to prove the deeper
    // validation fires on its own.
    let mut bad = good.clone();
    let num_tags = u32::from_le_bytes(bad[48..52].try_into().unwrap()) as usize;
    bad[FIXED_HEADER + num_tags * 8] = 0xEE;
    repatch_crc(&mut bad);
    match Frame::decode_slice(&bad) {
        Err(WireError::Malformed { what }) => assert!(what.contains("dtype"), "{what}"),
        r => panic!("expected Malformed, got {:?}", r.err()),
    }

    // Malformed #3: misaligned header_len (checked before the CRC).
    let mut bad = good.clone();
    let hl = u32::from_le_bytes(bad[16..20].try_into().unwrap());
    bad[16..20].copy_from_slice(&(hl + 4).to_le_bytes());
    assert!(matches!(Frame::decode_slice(&bad), Err(WireError::Malformed { .. })));

    // SchemaMismatch: a valid sensor frame refuses a particle schema.
    let frame = Frame::decode_slice(&good).unwrap();
    let wrong = ParticleProps::schema();
    match frame.source(&wrong) {
        Err(WireError::SchemaMismatch { want, got }) => {
            assert_eq!(want, schema_hash(&wrong));
            assert_eq!(got, schema_hash(&SensorProps::schema()));
        }
        r => panic!("expected SchemaMismatch, got {:?}", r.err().map(|e| e.to_string())),
    }
}

// ---------------------------------------------------------------------
// Multi-process ingest: N striped senders over real sockets reconstruct
// bit-identically to the single-sender and in-process runs, exactly
// once per event.
// ---------------------------------------------------------------------

#[test]
fn socketpair_multi_process_matches_single_process() {
    let event = EventConfig::grid(20, 20, 3);
    let (n_events, seed) = (36, 0xBEEF);

    let single = run_socketpair_ingest(&event, n_events, seed, 1, &ServeOpts::default()).unwrap();
    verify_exactly_once(&single, n_events).unwrap();
    golden_compare(&single, &event, n_events, seed).unwrap();

    let multi = run_socketpair_ingest(&event, n_events, seed, 3, &ServeOpts::default()).unwrap();
    verify_exactly_once(&multi, n_events).unwrap();
    golden_compare(&multi, &event, n_events, seed).unwrap();

    assert_eq!(single.results.len(), multi.results.len());
    for (a, b) in single.results.iter().zip(&multi.results) {
        assert_eq!(a.event_id, b.event_id);
        assert_eq!(a.n_particles, b.n_particles);
        assert_eq!(a.total_energy.to_bits(), b.total_energy.to_bits());
    }

    // Zero-copy accounting: the only booked copy on the receive path
    // is the particle staging transfer — byte-for-byte the same bytes
    // the in-process path books. The sensor planes (the bulk of every
    // frame) attach in place and never appear in any transfer stats.
    use marionette::coordinator::pipeline::process_host_staged;
    let mut gen = EventGenerator::new(event.clone(), seed);
    let mut staged = ParticleCollection::<AoS>::new();
    for _ in 0..n_events {
        let ev = gen.generate();
        let (_, _, host_bytes) = process_host_staged(&ev, &mut staged);
        let got = single.results.iter().find(|r| r.event_id == ev.event_id).unwrap();
        assert_eq!(got.staged_bytes, host_bytes, "event {}", ev.event_id);
    }
}

#[test]
fn socketpair_with_selected_staging_layout_stays_golden() {
    // Satellite cross-check: the autotuner's layout choice routed into
    // the live receive path must not change the physics.
    let event = EventConfig::grid(16, 16, 3);
    let opts = ServeOpts { staging: Some(LayoutChoice::AoSoA8), ..ServeOpts::default() };
    let report = run_socketpair_ingest(&event, 24, 7, 2, &opts).unwrap();
    golden_compare(&report, &event, 24, 7).unwrap();
}

//! Property-based invariant tests for the Marionette core.
//!
//! Random operation programs run against every layout simultaneously and
//! against a simple `Vec`-based model; after every step all five
//! representations must agree exactly and the jagged prefix sums must be
//! monotone. This is the deep-coverage test for the holder machinery
//! (resize/insert/erase interactions with planes, blobs, and size tags).

#![allow(dead_code)] // the generated typed twin exposes more than the tests touch

use std::sync::Arc;

use marionette::marionette::collection::{InfoOf, RawCollection};
use marionette::marionette::interface::{AttachError, SourceJagged};
use marionette::marionette::layout::{AoS, AoSoA, Layout, SoABlob, SoAVec};
use marionette::marionette::schema::{FieldMeta, Schema};
use marionette::marionette_collection;
use marionette::util::prop::Cases;

marionette_collection! {
    /// Typed twin of the property-test schema: its generated view
    /// attaches to the runtime-built `RawCollection`s below (structural
    /// schema equality), so view reads can be checked against the
    /// owned accessors over randomized programs.
    pub collection PropCollection, object PropObj, record PropRecord,
        columns PropColumns, refs PropRefP / PropMutP,
        views PropView / PropViewMut,
        props PropProps, schema "prop" {
        per_item e / set_e / E: f32;
        per_item flag / set_flag / FLAG: u8;
        array arr / set_arr / ARR: [i32; 3];
        jagged cells / set_cells / CELLS: u64, prefix u32;
        global g / set_g / G: u64;
    }
}

/// Vec-based model of the schema used below.
#[derive(Clone, Debug, Default, PartialEq)]
struct Model {
    e: Vec<f32>,
    flag: Vec<u8>,
    arr: Vec<[i32; 3]>,
    cells: Vec<Vec<u64>>,
    global: u64,
}

struct Metas {
    e: FieldMeta,
    flag: FieldMeta,
    arr: FieldMeta,
    cells: FieldMeta,
    global: FieldMeta,
}

fn schema() -> (Arc<Schema>, Metas) {
    let s = Arc::new(
        Schema::builder("prop")
            .per_item::<f32>("e")
            .per_item::<u8>("flag")
            .array::<i32>("arr", 3)
            .jagged::<u64, u32>("cells")
            .global::<u64>("g")
            .build(),
    );
    let metas = Metas {
        e: s.meta(s.field_by_name("e").unwrap()),
        flag: s.meta(s.field_by_name("flag").unwrap()),
        arr: s.meta(s.field_by_name("arr").unwrap()),
        cells: s.meta(s.field_by_name("cells").unwrap()),
        global: s.meta(s.field_by_name("g").unwrap()),
    };
    (s, metas)
}

/// Apply one op (decoded from a u64) to model + collection.
fn apply<L: Layout>(
    op: u64,
    m: &mut Model,
    c: &mut RawCollection<L>,
    metas: &Metas,
) {
    let kind = op % 8;
    let a = ((op >> 3) % 1024) as usize;
    let b = ((op >> 13) % 64) as usize;
    let val = (op >> 19) as u32;
    let len = m.e.len();
    match kind {
        0 => {
            // push
            m.e.push(0.0);
            m.flag.push(0);
            m.arr.push([0; 3]);
            m.cells.push(Vec::new());
            c.push_default();
        }
        1 => {
            // resize to a % 257 (bounded)
            let n = a % 257;
            m.e.resize(n, 0.0);
            m.flag.resize(n, 0);
            m.arr.resize(n, [0; 3]);
            m.cells.resize(n, Vec::new());
            c.resize(n);
        }
        2 if len > 0 => {
            // set scalar + array lanes
            let i = a % len;
            m.e[i] = val as f32;
            m.flag[i] = val as u8;
            m.arr[i][b % 3] = val as i32;
            c.set::<f32>(metas.e, i, val as f32);
            c.set::<u8>(metas.flag, i, val as u8);
            c.set_k::<i32>(metas.arr, i, b % 3, val as i32);
        }
        3 if len > 0 => {
            // insert up to b items at a
            let at = a % (len + 1);
            let n = b % 5;
            for _ in 0..n {
                m.e.insert(at, 0.0);
                m.flag.insert(at, 0);
                m.arr.insert(at, [0; 3]);
                m.cells.insert(at, Vec::new());
            }
            c.insert_items(at, n);
        }
        4 if len > 0 => {
            // erase up to b items at a
            let at = a % len;
            let n = (b % 4).min(len - at);
            for _ in 0..n {
                m.e.remove(at);
                m.flag.remove(at);
                m.arr.remove(at);
                m.cells.remove(at);
            }
            c.erase_items(at, n);
        }
        5 if len > 0 => {
            // replace item i's jagged vector with b values
            let i = a % len;
            let vals: Vec<u64> = (0..b % 7).map(|n| val as u64 + n as u64).collect();
            m.cells[i] = vals.clone();
            c.set_jagged_count(0, i, vals.len());
            let r = c.jagged_range(0, i);
            for (n, v) in vals.iter().enumerate() {
                c.set_value::<u64>(metas.cells, r.start + n, *v);
            }
        }
        6 if len > 0 => {
            // append values to the LAST item (builder pattern)
            let n = b % 5;
            let v0 = c.append_values(0, n);
            for k in 0..n {
                let v = val as u64 ^ k as u64;
                m.cells.last_mut().unwrap().push(v);
                c.set_value::<u64>(metas.cells, v0 + k, v);
            }
        }
        7 => {
            // set global; occasionally shrink/clear bookkeeping paths
            m.global = op;
            c.set_global::<u64>(metas.global, op);
            if a % 17 == 0 {
                c.shrink_to_fit();
            }
        }
        _ => {}
    }
}

fn check<L: Layout>(m: &Model, c: &RawCollection<L>, metas: &Metas) -> Result<(), String> {
    if c.len() != m.e.len() {
        return Err(format!("len {} != {}", c.len(), m.e.len()));
    }
    if c.get_global::<u64>(metas.global) != m.global {
        return Err("global mismatch".into());
    }
    // Prefix sums monotone + total matches.
    let mut prev = 0;
    for i in 0..=c.len() {
        let p = c.prefix_at(0, i);
        if p < prev {
            return Err(format!("prefix not monotone at {i}"));
        }
        prev = p;
    }
    if c.values_len(0) != m.cells.iter().map(|v| v.len()).sum::<usize>() {
        return Err("values_len mismatch".into());
    }
    for i in 0..c.len() {
        if c.get::<f32>(metas.e, i) != m.e[i] {
            return Err(format!("e[{i}] mismatch"));
        }
        if c.get::<u8>(metas.flag, i) != m.flag[i] {
            return Err(format!("flag[{i}] mismatch"));
        }
        for k in 0..3 {
            if c.get_k::<i32>(metas.arr, i, k) != m.arr[i][k] {
                return Err(format!("arr[{i}][{k}] mismatch"));
            }
        }
        let got = c.jagged_view::<u64>(metas.cells, 0, i).to_vec();
        if got != m.cells[i] {
            return Err(format!("cells[{i}]: {got:?} != {:?}", m.cells[i]));
        }
    }
    Ok(())
}

fn run_program<L: Layout>(program: &[u64]) -> Result<(), String>
where
    marionette::marionette::collection::InfoOf<L>: Default,
{
    let (s, metas) = schema();
    let mut m = Model::default();
    let mut c = RawCollection::<L>::new(s);
    for (step, &op) in program.iter().enumerate() {
        apply(op, &mut m, &mut c, &metas);
        check(&m, &c, &metas).map_err(|e| format!("step {step}: {e}"))?;
    }
    Ok(())
}

#[test]
fn soavec_matches_model() {
    Cases::new(48).shrinkable("soavec-model", 48, run_program::<SoAVec>);
}

#[test]
fn aos_matches_model() {
    Cases::new(48).shrinkable("aos-model", 48, run_program::<AoS>);
}

#[test]
fn soablob_matches_model() {
    Cases::new(48).shrinkable("soablob-model", 48, run_program::<SoABlob>);
}

#[test]
fn aosoa_matches_model() {
    Cases::new(32).shrinkable("aosoa4-model", 48, run_program::<AoSoA<4>>);
    Cases::new(32).shrinkable("aosoa16-model", 48, run_program::<AoSoA<16>>);
}

/// Cross-layout transfers after a random program preserve everything.
#[test]
fn transfer_after_program_roundtrips() {
    Cases::new(32).shrinkable("transfer-roundtrip", 32, |program| {
        let (s, metas) = schema();
        let mut m = Model::default();
        let mut c = RawCollection::<SoAVec>::new(s.clone());
        for &op in program {
            apply(op, &mut m, &mut c, &metas);
        }
        let mut aos = RawCollection::<AoS>::new(s.clone());
        marionette::marionette::transfer::copy_collection(&c, &mut aos);
        check(&m, &aos, &metas).map_err(|e| format!("aos: {e}"))?;
        let mut blocked = RawCollection::<AoSoA<8>>::new(s.clone());
        marionette::marionette::transfer::copy_collection(&aos, &mut blocked);
        check(&m, &blocked, &metas).map_err(|e| format!("aosoa: {e}"))?;
        let mut back = RawCollection::<SoABlob>::new(s);
        marionette::marionette::transfer::copy_collection(&blocked, &mut back);
        check(&m, &back, &metas).map_err(|e| format!("soablob: {e}"))
    });
}

/// Satellite invariant of the interface layer: after an arbitrary
/// operation program, the borrowed typed view's reads equal the owned
/// accessors' reads on every field kind — on all four layouts.
fn check_view_equals_owned<L: Layout>(program: &[u64]) -> Result<(), String>
where
    InfoOf<L>: Default,
{
    let (s, metas) = schema();
    let mut m = Model::default();
    let mut c = RawCollection::<L>::new(s);
    for &op in program {
        apply(op, &mut m, &mut c, &metas);
    }
    let v = PropView::attach(&c).map_err(|e| format!("attach failed: {e}"))?;
    if v.len() != c.len() {
        return Err(format!("view len {} != owned len {}", v.len(), c.len()));
    }
    if v.g() != c.get_global::<u64>(metas.global) {
        return Err("view global mismatch".into());
    }
    for i in 0..c.len() {
        if v.e(i) != c.get::<f32>(metas.e, i) {
            return Err(format!("view e[{i}] mismatch"));
        }
        if v.flag(i) != c.get::<u8>(metas.flag, i) {
            return Err(format!("view flag[{i}] mismatch"));
        }
        for k in 0..3 {
            if v.arr(i, k) != c.get_k::<i32>(metas.arr, i, k) {
                return Err(format!("view arr[{i}][{k}] mismatch"));
            }
        }
        let vj = v.cells(i).to_vec();
        let oj = c.jagged_view::<u64>(metas.cells, 0, i).to_vec();
        if vj != oj {
            return Err(format!("view cells[{i}]: {vj:?} != {oj:?}"));
        }
    }
    Ok(())
}

#[test]
fn view_reads_equal_owned_reads_all_layouts() {
    Cases::new(32).shrinkable("view-owned-equal", 40, |program| {
        check_view_equals_owned::<SoAVec>(program)?;
        check_view_equals_owned::<AoS>(program)?;
        check_view_equals_owned::<SoABlob>(program)?;
        check_view_equals_owned::<AoSoA<4>>(program)
    });
}

/// First coverage of the jagged view-layer primitives: after a random
/// program (ops 0/5/6 grow items with random multiplicities), a
/// hand-constructed `SourceJagged` over the raw collection — with its
/// range resolved through `JaggedProp`'s prefix meta, exactly as the
/// generated views do — must agree with the owned `jagged_view`, the
/// generated view accessor, and the model, on every layout.
fn check_source_jagged<L: Layout>(program: &[u64]) -> Result<(), String>
where
    InfoOf<L>: Default,
{
    let (s, metas) = schema();
    let mut m = Model::default();
    let mut c = RawCollection::<L>::new(s);
    for &op in program {
        apply(op, &mut m, &mut c, &metas);
    }
    let v = PropView::attach(&c).map_err(|e| format!("attach failed: {e}"))?;

    // Prefix-meta consistency: the per-item ranges tile the values tag
    // (no gaps, no overlap) and reproduce the model's multiplicities.
    let mut expect_lo = 0usize;
    for i in 0..c.len() {
        let lo = c.prefix_at(0, i);
        let hi = c.prefix_at(0, i + 1);
        if lo != expect_lo {
            return Err(format!("prefix gap at item {i}: {lo} != {expect_lo}"));
        }
        if hi - lo != m.cells[i].len() {
            return Err(format!(
                "multiplicity[{i}]: prefix says {}, model says {}",
                hi - lo,
                m.cells[i].len()
            ));
        }
        expect_lo = hi;

        let j = SourceJagged::<u64, _>::new(&c, PropProps::CELLS.values, lo..hi);
        if j.len() != m.cells[i].len() || j.is_empty() != m.cells[i].is_empty() {
            return Err(format!("source jagged len[{i}]: {} != {}", j.len(), m.cells[i].len()));
        }
        for (n, &want) in m.cells[i].iter().enumerate() {
            if j.get(n) != want {
                return Err(format!("source jagged get({i}, {n}) != model"));
            }
        }
        let iterated: Vec<u64> = j.iter().collect();
        if iterated != m.cells[i] {
            return Err(format!("source jagged iter[{i}] != model"));
        }
        if j.to_vec() != c.jagged_view::<u64>(metas.cells, 0, i).to_vec() {
            return Err(format!("source jagged[{i}] != owned jagged_view"));
        }
        if j.to_vec() != v.cells(i).to_vec() {
            return Err(format!("source jagged[{i}] != generated view accessor"));
        }
        // Dense sources may hand out a borrowed slice; when they do it
        // must be the same values.
        if let Some(slice) = j.as_slice() {
            if slice != m.cells[i].as_slice() {
                return Err(format!("as_slice[{i}] disagrees with model"));
            }
        }
    }
    if expect_lo != c.values_len(0) {
        return Err(format!(
            "prefix total {expect_lo} != values_len {}",
            c.values_len(0)
        ));
    }
    Ok(())
}

#[test]
fn source_jagged_roundtrips_all_layouts() {
    Cases::new(32).shrinkable("source-jagged", 40, |program| {
        check_source_jagged::<SoAVec>(program)?;
        check_source_jagged::<AoS>(program)?;
        check_source_jagged::<SoABlob>(program)?;
        check_source_jagged::<AoSoA<4>>(program)
    });
}

/// Attach failure modes are typed errors, never later panics: a
/// structurally different schema and a dtype-flipped near-miss both
/// fail cleanly.
#[test]
fn view_attach_mismatches_fail_cleanly() {
    let other = Arc::new(Schema::builder("x").per_item::<f32>("y").build());
    let c = RawCollection::<SoAVec>::new(other);
    match PropView::attach(&c) {
        Err(AttachError::SchemaMismatch { .. }) => {}
        r => panic!("expected SchemaMismatch, got {:?}", r.err()),
    }

    let near = Arc::new(
        Schema::builder("prop")
            .per_item::<f64>("e") // flipped dtype, otherwise identical
            .per_item::<u8>("flag")
            .array::<i32>("arr", 3)
            .jagged::<u64, u32>("cells")
            .global::<u64>("g")
            .build(),
    );
    let c = RawCollection::<SoAVec>::new(near);
    match PropView::attach(&c) {
        Err(AttachError::DtypeMismatch { field, .. }) => assert_eq!(field, "e"),
        r => panic!("expected DtypeMismatch, got {:?}", r.err()),
    }
}

/// Reusing a dirty destination must fully overwrite previous content.
#[test]
fn transfer_into_dirty_destination() {
    Cases::new(24).shrinkable("dirty-dst", 24, |program| {
        let (s, metas) = schema();
        // Dirty destination from the first half of the program...
        let mut m1 = Model::default();
        let mut dst = RawCollection::<AoS>::new(s.clone());
        for &op in &program[..program.len() / 2] {
            apply(op, &mut m1, &mut dst, &metas);
        }
        // ...source from the second half.
        let mut m2 = Model::default();
        let mut src = RawCollection::<SoAVec>::new(s);
        for &op in &program[program.len() / 2..] {
            apply(op, &mut m2, &mut src, &metas);
        }
        marionette::marionette::transfer::copy_collection(&src, &mut dst);
        check(&m2, &dst, &metas)
    });
}

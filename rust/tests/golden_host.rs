//! Cross-language equivalence: the Rust host algorithms must reproduce
//! the Python reference (`ref.py:full_event_ref`) on the golden event
//! written by `python -m compile.aot`.
//!
//! This pins the physics *definition* across the three layers: ref.py
//! (oracle) = Pallas kernels (tested in pytest) = Rust host algorithms
//! (tested here) = device executables (tested in runtime::executor).

use marionette::edm::constants::*;
use marionette::edm::generator::RawEvent;
use marionette::edm::golden::load_golden;
use marionette::edm::{calib, reco};
use marionette::marionette::layout::{AoS, SoAVec};

fn golden_event() -> Option<(RawEvent, marionette::edm::golden::GoldenEvent)> {
    let g = load_golden()?;
    let ev = RawEvent {
        event_id: 7,
        rows: g.rows,
        cols: g.cols,
        counts: g.tensor("counts").as_i32(),
        types: g.tensor("types").as_i32(),
        noisy: g.tensor("noisy").as_i32().iter().map(|&x| x as u8).collect(),
        a: g.tensor("a").as_f32(),
        b: g.tensor("b").as_f32(),
        na: g.tensor("na").as_f32(),
        nb: g.tensor("nb").as_f32(),
        truth: vec![],
    };
    Some((ev, g))
}

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * b.abs().max(1.0)
}

#[test]
fn calibration_matches_python_reference() {
    let Some((ev, g)) = golden_event() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut col = ev.to_collection::<SoAVec>();
    calib::calibrate_collection(&mut col);
    let energy = g.tensor("energy").as_f32();
    let noise = g.tensor("noise").as_f32();
    let sig = g.tensor("sig").as_f32();
    for i in 0..ev.num_sensors() {
        assert!(close(col.energy(i), energy[i], 1e-6), "energy[{i}]");
        assert!(close(col.noise(i), noise[i], 1e-6), "noise[{i}]");
        assert!(close(col.sig(i), sig[i], 1e-5), "sig[{i}]");
    }
}

#[test]
fn seeds_match_python_reference() {
    let Some((ev, g)) = golden_event() else { return };
    let mut col = ev.to_collection::<AoS>();
    calib::calibrate_collection(&mut col);
    let particles = reco::reconstruct_collection(&col);
    let seeds = g.tensor("seeds").as_i32();
    let want: Vec<usize> = seeds
        .iter()
        .enumerate()
        .filter(|(_, &s)| s != 0)
        .map(|(i, _)| i)
        .collect();
    let got: Vec<usize> = particles.iter().map(|p| p.origin as usize).collect();
    assert_eq!(got, want, "seed positions differ from ref.py");
}

#[test]
fn window_sums_match_python_reference() {
    let Some((ev, g)) = golden_event() else { return };
    let mut col = ev.to_collection::<SoAVec>();
    calib::calibrate_collection(&mut col);
    let particles = reco::reconstruct_collection(&col);
    let sums = g.tensor("sums").as_f32();
    let n = ev.num_sensors();
    let plane = |p: usize, i: usize| sums[p * n + i];
    for p in &particles {
        let i = p.origin as usize;
        assert!(close(p.energy, plane(PLANE_E, i), 1e-4), "E at {i}");
        let x = plane(PLANE_EX, i) / plane(PLANE_E, i);
        let y = plane(PLANE_EY, i) / plane(PLANE_E, i);
        assert!(close(p.x, x, 1e-4), "x at {i}");
        assert!(close(p.y, y, 1e-4), "y at {i}");
        for t in 0..NUM_SENSOR_TYPES {
            assert!(
                close(p.e_contribution[t], plane(PLANE_E_TYPE + t, i), 1e-3),
                "e_t[{t}] at {i}"
            );
            assert!(
                close(p.significance[t], plane(PLANE_SIG_TYPE + t, i), 1e-3),
                "sig_t[{t}] at {i}"
            );
            assert_eq!(
                p.noisy_count[t] as f32,
                plane(PLANE_NOISY_TYPE + t, i),
                "noisy_t[{t}] at {i}"
            );
        }
        assert_eq!(
            p.sensors.len() as f32,
            plane(PLANE_CONTRIB, i),
            "contributor count at {i}"
        );
    }
}

#[test]
fn device_gather_equals_host_reco_on_golden() {
    let Some((ev, g)) = golden_event() else { return };
    let mut col = ev.to_collection::<SoAVec>();
    calib::calibrate_collection(&mut col);
    let host = reco::reconstruct_collection(&col);

    let sig: Vec<f32> = g.tensor("sig").as_f32();
    let dev = reco::particles_from_planes::<SoAVec>(
        ev.rows,
        ev.cols,
        ev.event_id,
        &g.tensor("seeds").as_i32(),
        &g.tensor("sums").as_f32(),
        &sig,
    );
    assert_eq!(dev.len(), host.len());
    for (i, hp) in host.iter().enumerate() {
        assert_eq!(dev.origin(i), hp.origin);
        assert_eq!(dev.sensors(i).to_vec(), hp.sensors);
        assert!(close(dev.energy(i), hp.energy, 1e-3));
        assert!(close(dev.x_variance(i), hp.x_variance, 1e-2));
    }
}

//! Coordinator integration tests: mixed routing, backpressure, scale,
//! cross-path physics consistency, and the steady-state zero-alloc
//! invariant of the pooled staging subsystem.

use marionette::coordinator::{run_pipeline, PipelineConfig, Route, RoutePolicy, StagePool};
use marionette::edm::generator::EventConfig;
use marionette::runtime::Engine;

fn have_artifacts() -> bool {
    Engine::load_default().is_ok()
}

/// The PR's acceptance invariant: after a warmup batch, processing 100+
/// further events draws every staging destination warm from the pool —
/// zero pool misses at both levels and no net allocation growth on the
/// pool's counting heap (`CountingStats::live_allocs`).
#[test]
fn steady_state_zero_alloc_after_warmup() {
    // A private pool: isolated from every other test's pipeline runs.
    let pool = StagePool::new();
    let mk = |n: usize| {
        let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 3), n);
        cfg.device = false;
        cfg.policy = RoutePolicy::HostOnly;
        // One worker -> one pooled collection sees the whole stream, so
        // warmup deterministically covers every capacity class the
        // measured run needs.
        cfg.host_workers = 1;
        cfg.seed = 20260730;
        cfg.stage_pool = Some(pool.clone());
        cfg
    };

    // Warmup: same seed and config as the measured run, so capacities
    // grow to exactly the workload's shape.
    run_pipeline(&mk(120)).unwrap();
    let warm_bytes = pool.byte_stats();
    let warm_cols = pool.collection_stats();
    let warm_live = pool.live_allocs();
    assert!(warm_bytes.misses > 0, "warmup must populate the pool");

    let rep = run_pipeline(&mk(120)).unwrap();
    assert_eq!(rep.results.len(), 120);

    let bytes = pool.byte_stats();
    let cols = pool.collection_stats();
    // Zero pool misses after warmup, at both levels...
    assert_eq!(
        cols.misses, warm_cols.misses,
        "steady state built fresh staging collections"
    );
    assert_eq!(bytes.misses, warm_bytes.misses, "steady state missed the byte pool");
    // ...every event was served by a warm checkout...
    assert!(
        cols.hits >= warm_cols.hits + 120,
        "expected >= 120 warm checkouts, got {} -> {}",
        warm_cols.hits,
        cols.hits
    );
    // ...and the counting heap saw no net allocation growth.
    assert_eq!(pool.live_allocs(), warm_live, "net allocations in steady state");
    // Nothing is checked out after shutdown beyond what idle warm
    // collections legitimately hold.
    assert_eq!(bytes.outstanding, warm_bytes.outstanding);
    // The report surfaces the same pool counters.
    assert_eq!(rep.metrics.pool_misses, bytes.misses);
    assert_eq!(rep.metrics.stage_misses, cols.misses);
    assert_eq!(rep.metrics.pool_live_allocs, pool.live_allocs() as i64);
    assert!(rep.report().contains("pool: stage"));
}

#[test]
fn plan_cache_steady_state_hits() {
    let before = marionette::marionette::transfer::plan_cache_stats();
    let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 2), 30);
    cfg.device = false;
    cfg.policy = RoutePolicy::HostOnly;
    cfg.host_workers = 2;
    let rep = run_pipeline(&cfg).unwrap();
    assert_eq!(rep.results.len(), 30);
    // Every event runs exactly one planned staging transfer...
    assert_eq!(rep.metrics.planned_transfers, 30);
    assert!(rep.metrics.planned_bytes > 0);
    // ...and the plan is compiled at most once (warmed at pipeline
    // startup): each per-event lookup is a cache hit — at least one hit
    // per steady-state event. (Counters are process-global and only
    // ever increase, so concurrent tests cannot deflate the delta.)
    let after = marionette::marionette::transfer::plan_cache_stats();
    assert!(
        after.hits - before.hits >= 30,
        "plan-cache hits {} -> {}",
        before.hits,
        after.hits
    );
}

#[test]
fn hundred_events_host_only() {
    let mut cfg = PipelineConfig::new(EventConfig::grid(48, 48, 2), 100);
    cfg.device = false;
    cfg.policy = RoutePolicy::HostOnly;
    cfg.host_workers = 4;
    let rep = run_pipeline(&cfg).unwrap();
    assert_eq!(rep.results.len(), 100);
    assert_eq!(rep.metrics.events_in, 100);
    assert_eq!(rep.metrics.events_host, 100);
    // Deterministic event ids, no drops, no duplicates.
    for (i, r) in rep.results.iter().enumerate() {
        assert_eq!(r.event_id, i as u64);
    }
}

#[test]
fn deterministic_physics_across_runs() {
    let mk = || {
        let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 3), 20);
        cfg.device = false;
        cfg.policy = RoutePolicy::HostOnly;
        cfg.seed = 99;
        run_pipeline(&cfg).unwrap()
    };
    let (a, b) = (mk(), mk());
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.n_particles, y.n_particles);
        assert_eq!(x.total_energy, y.total_energy);
    }
}

#[test]
fn tight_backpressure_still_completes() {
    let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 2), 40);
    cfg.device = false;
    cfg.policy = RoutePolicy::HostOnly;
    cfg.queue_depth = 1; // maximum backpressure
    cfg.host_workers = 1;
    let rep = run_pipeline(&cfg).unwrap();
    assert_eq!(rep.results.len(), 40);
}

#[test]
fn single_worker_single_event() {
    let mut cfg = PipelineConfig::new(EventConfig::grid(16, 16, 1), 1);
    cfg.device = false;
    cfg.host_workers = 1;
    let rep = run_pipeline(&cfg).unwrap();
    assert_eq!(rep.results.len(), 1);
}

#[test]
fn zero_events_clean_shutdown() {
    let mut cfg = PipelineConfig::new(EventConfig::grid(16, 16, 1), 0);
    cfg.device = false;
    let rep = run_pipeline(&cfg).unwrap();
    assert!(rep.results.is_empty());
}

#[test]
fn mixed_routing_consistent_physics() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Same workload through host-only and device-only must agree.
    let run = |policy, device| {
        let mut cfg = PipelineConfig::new(EventConfig::grid(64, 64, 4), 10);
        cfg.policy = policy;
        cfg.device = device;
        cfg.seed = 1234;
        run_pipeline(&cfg).unwrap()
    };
    let host = run(RoutePolicy::HostOnly, false);
    let dev = run(RoutePolicy::DeviceOnly, true);
    assert!(dev.results.iter().all(|r| r.route == Route::Device));
    for (h, d) in host.results.iter().zip(&dev.results) {
        assert_eq!(h.n_particles, d.n_particles, "event {}", h.event_id);
        let rel = (h.total_energy - d.total_energy).abs() / h.total_energy.max(1.0);
        assert!(rel < 1e-3, "event {} energy drift {rel}", h.event_id);
    }

    // Auto policy with crossover below 64x64: everything goes device.
    let auto = run(
        RoutePolicy::Auto { min_device_cells: 32 * 32, max_device_queue: 1000 },
        true,
    );
    assert_eq!(auto.metrics.events_device, 10);
}

#[test]
fn device_batching_counts_batches() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 2), 12);
    cfg.policy = RoutePolicy::DeviceOnly;
    cfg.max_batch = 4;
    let rep = run_pipeline(&cfg).unwrap();
    assert_eq!(rep.metrics.events_device, 12);
    assert!(rep.metrics.device_batches >= 3, "batches {}", rep.metrics.device_batches);
    assert!(rep.metrics.device_execute > std::time::Duration::ZERO);
}

//! Tier-1 guard for the BENCH trajectory: the reporter's JSON schema
//! round-trips through the in-tree parser, a fresh quick run stays
//! within tolerance of the committed `BENCH_baseline.json`, and the
//! gate demonstrably fails when a series degrades beyond tolerance.

use std::path::PathBuf;

use marionette::bench_support::report::{
    self, BenchReport, ReportOpts, REQUIRED_SERIES, SERIES_ADAPTIVE, SERIES_ADAPTIVE_P99,
    SERIES_DEGRADED, SERIES_INGEST, SERIES_PIPELINE, SERIES_PLAN_CACHE, SERIES_SATURATION,
    SERIES_SATURATION_P99, SERIES_TRANSFER, SERIES_VIEW_RATIO, SERIES_WIRE,
};

fn baseline_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_baseline.json")
}

/// Emit a `BENCH_*.json`, re-parse it, and assert the required series,
/// keys and units are present with finite values.
#[test]
fn bench_json_schema_round_trips() {
    let run = report::collect(&ReportOpts::quick()).unwrap();
    let path = std::env::temp_dir().join("BENCH_roundtrip_test.json");
    run.save(&path).unwrap();
    let parsed = BenchReport::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    parsed.validate().unwrap();
    assert!(parsed.quick);
    assert_eq!(parsed.provenance, "measured");
    for name in REQUIRED_SERIES {
        let s = parsed.series(name).unwrap_or_else(|| panic!("missing series {name}"));
        assert!(!s.points.is_empty(), "series {name} has no points");
        for p in &s.points {
            assert!(p.value.is_finite(), "{name}/{}: {}", p.label, p.value);
            assert!(p.value >= 0.0, "{name}/{}: negative", p.label);
        }
    }
    assert_eq!(parsed.series(SERIES_PLAN_CACHE).unwrap().unit, "ratio");
    assert_eq!(parsed.series(SERIES_TRANSFER).unwrap().unit, "bytes_per_sec");
    assert_eq!(parsed.series(SERIES_PIPELINE).unwrap().unit, "events_per_sec");
    assert_eq!(parsed.series(SERIES_VIEW_RATIO).unwrap().unit, "ratio");
    assert_eq!(parsed.series(SERIES_SATURATION).unwrap().unit, "events_per_sec");
    assert_eq!(parsed.series(SERIES_SATURATION_P99).unwrap().unit, "microseconds");
    assert_eq!(parsed.series(SERIES_ADAPTIVE).unwrap().unit, "events_per_sec");
    assert_eq!(parsed.series(SERIES_ADAPTIVE_P99).unwrap().unit, "microseconds");
    assert_eq!(parsed.series(SERIES_DEGRADED).unwrap().unit, "events_per_sec");
    assert_eq!(parsed.series(SERIES_WIRE).unwrap().unit, "bytes_per_sec");
    assert_eq!(parsed.series(SERIES_INGEST).unwrap().unit, "events_per_sec");
    // The p99 tail series are informational — they must never hard-gate.
    assert_eq!(parsed.series(SERIES_SATURATION_P99).unwrap().tolerance, 0.0);
    assert_eq!(parsed.series(SERIES_ADAPTIVE_P99).unwrap().tolerance, 0.0);

    // The degraded-mode series gates (it is the chaos harness's
    // throughput contract) and carries both the clean and the
    // kill-at-50% points.
    let degraded = parsed.series(SERIES_DEGRADED).unwrap();
    assert!(degraded.tolerance > 0.0, "degraded series must hard-gate");
    for label in ["clean", "kill-at-50%"] {
        assert!(
            degraded.points.iter().any(|p| p.label == label),
            "degraded series missing point {label}"
        );
    }

    // Both wire series gate (they are the new subsystem's throughput
    // contract) and carry their single- vs multi-process points.
    let wire = parsed.series(SERIES_WIRE).unwrap();
    assert!(wire.tolerance > 0.0, "wire series must hard-gate");
    for label in ["encode", "decode-attach"] {
        assert!(
            wire.points.iter().any(|p| p.label == label),
            "wire series missing point {label}"
        );
    }
    let ingest = parsed.series(SERIES_INGEST).unwrap();
    assert!(ingest.tolerance > 0.0, "ingest series must hard-gate");
    for label in ["procs=1", "procs=2"] {
        assert!(
            ingest.points.iter().any(|p| p.label == label),
            "ingest series missing point {label}"
        );
    }

    // The trajectory's headline points are all present.
    let pipeline = parsed.series(SERIES_PIPELINE).unwrap();
    assert!(pipeline.points.iter().any(|p| p.label == "workers=1"));
    let transfer = parsed.series(SERIES_TRANSFER).unwrap();
    for route in ["soavec->aos", "host->staging", "planned-exec", "raw-memcpy"] {
        assert!(
            transfer.points.iter().any(|p| p.label == route),
            "transfer series missing route {route}"
        );
    }
}

/// A fresh quick run must stay within the committed baseline's
/// per-series tolerances — this is the tier-1 regression gate.
#[test]
fn quick_run_within_committed_baseline() {
    let baseline = BenchReport::load(&baseline_path()).unwrap();
    let run = report::collect(&ReportOpts::quick()).unwrap();
    let failures = report::compare(&run, &baseline);
    assert!(failures.is_empty(), "BENCH regressions vs baseline:\n{}", failures.join("\n"));
}

/// The gate has teeth: degrade each gated series beyond its tolerance
/// and the comparison must report a regression.
#[test]
fn gate_fails_on_degraded_series() {
    let baseline = BenchReport::load(&baseline_path()).unwrap();

    // Higher-is-better series collapses.
    let mut bad = baseline.clone();
    let s = bad
        .series
        .iter_mut()
        .find(|s| s.name == SERIES_PLAN_CACHE)
        .expect("baseline has plan-cache series");
    for p in &mut s.points {
        p.value *= 0.1;
    }
    let failures = report::compare(&bad, &baseline);
    assert!(
        failures.iter().any(|f| f.contains(SERIES_PLAN_CACHE)),
        "degraded hit rate not flagged: {failures:?}"
    );

    // Lower-is-better series balloons.
    let mut slow = baseline.clone();
    let s = slow
        .series
        .iter_mut()
        .find(|s| s.name == SERIES_VIEW_RATIO)
        .expect("baseline has view-ratio series");
    for p in &mut s.points {
        p.value *= 10.0;
    }
    let failures = report::compare(&slow, &baseline);
    assert!(
        failures.iter().any(|f| f.contains(SERIES_VIEW_RATIO)),
        "degraded view ratio not flagged: {failures:?}"
    );

    // Degraded-mode throughput collapsing must be flagged: losing a
    // device worker is allowed to cost throughput, but not 10x.
    let mut dead = baseline.clone();
    let s = dead
        .series
        .iter_mut()
        .find(|s| s.name == SERIES_DEGRADED)
        .expect("baseline has degraded series");
    for p in &mut s.points {
        p.value *= 0.1;
    }
    let failures = report::compare(&dead, &baseline);
    assert!(
        failures.iter().any(|f| f.contains(SERIES_DEGRADED)),
        "collapsed degraded throughput not flagged: {failures:?}"
    );

    // A vanished series is a regression too.
    let mut missing = baseline.clone();
    missing.series.retain(|s| s.name != SERIES_PIPELINE);
    assert!(!report::compare(&missing, &baseline).is_empty());
}

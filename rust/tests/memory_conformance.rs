//! MemoryContext conformance harness.
//!
//! One generic checker, instantiated for every in-tree context (Host,
//! Aligned, Counting, Arena, Staging, Pool, Tracing, disarmed
//! Faulty): property-style programs of
//! randomized allocate / fill / verify / free / grow / rehome steps are
//! decoded from `u64` ops exactly like `prop_marionette.rs` decodes its
//! collection programs (`util::prop::Cases::shrinkable`), and every
//! context must uphold the same invariants:
//!
//! * **alignment** — `allocate` honours the requested alignment;
//! * **isolation** — live allocations never overlap (each slot carries
//!   a fill pattern that must survive until its free);
//! * **grow** — `RawBuf::grow_exact` preserves the retained prefix,
//!   shrink included;
//! * **rehome** — moving a buffer onto other context info preserves
//!   contents and books the release on the source;
//! * **drop-balance** — after every allocation is released, the
//!   context's observable ledgers are balanced (counting: live
//!   allocs/bytes; arena: live bytes + resettable; pool: nothing
//!   outstanding, checkouts all returned).

use std::alloc::Layout as AllocLayout;
use std::ptr::NonNull;
use std::sync::atomic::Ordering;

use marionette::marionette::buffer::{ContextAwareVec, RawBuf};
use marionette::marionette::memory::{
    AlignedContext, ArenaContext, ArenaInfo, CountingContext, CountingInfo, FaultyContext,
    FaultyInfo, HostContext, MemoryContext, PoolContext, PoolInfo, StagingContext,
    StagingInfo, TraceInfo, TracingContext,
};
use marionette::util::prop::Cases;

/// The pooled instantiation checked by the harness: recycling over a
/// counting heap, so drop-balance is observable end to end.
type PoolCtx = PoolContext<CountingContext>;

struct Slot {
    ptr: NonNull<u8>,
    layout: AllocLayout,
    pattern: u8,
}

fn verify_slot<C: MemoryContext>(info: &C::Info, s: &Slot) -> Result<(), String> {
    if s.layout.size() == 0 {
        return Ok(());
    }
    let mut out = vec![0u8; s.layout.size()];
    unsafe { C::copy_out(info, s.ptr.as_ptr(), out.as_mut_ptr(), out.len()) };
    match out.iter().position(|&b| b != s.pattern) {
        None => Ok(()),
        Some(i) => Err(format!(
            "slot pattern {:#04x} corrupted at byte {i} (size {}, align {}): {:#04x}",
            s.pattern,
            s.layout.size(),
            s.layout.align(),
            out[i]
        )),
    }
}

/// Run one decoded program against context `C`.
fn run_program<C: MemoryContext>(
    program: &[u64],
    fresh: &impl Fn() -> C::Info,
    after: &impl Fn(&C::Info) -> Result<(), String>,
) -> Result<(), String> {
    let info = fresh();
    let mut slots: Vec<Slot> = Vec::new();
    for (step, &op) in program.iter().enumerate() {
        let size = ((op >> 2) % 2049) as usize; // 0..=2048
        let align = 1usize << ((op >> 14) % 7); // 1..=64
        let pattern = (op >> 24) as u8;
        let pick = (op >> 32) as usize;
        match op % 4 {
            0 => {
                // Allocate, fill with this slot's pattern.
                let layout = AllocLayout::from_size_align(size, align)
                    .map_err(|e| format!("step {step}: bad layout: {e}"))?;
                let ptr = C::allocate(&info, layout);
                if ptr.as_ptr() as usize % align != 0 {
                    return Err(format!(
                        "step {step}: allocation not {align}-aligned: {ptr:p}"
                    ));
                }
                unsafe { C::memset(&info, ptr.as_ptr(), size, pattern) };
                slots.push(Slot { ptr, layout, pattern });
            }
            1 if !slots.is_empty() => {
                // Verify one live slot's pattern, then free it. The
                // verify is what catches overlapping live allocations
                // (a recycling bug would hand the same block out twice
                // and the second fill would corrupt the first pattern).
                let s = slots.swap_remove(pick % slots.len());
                verify_slot::<C>(&info, &s).map_err(|e| format!("step {step}: {e}"))?;
                unsafe { C::deallocate(&info, s.ptr, s.layout) };
            }
            2 => {
                // Grow/shrink invariant: a context-allocated RawBuf
                // keeps its retained prefix across capacity changes.
                let first = (size + 1).min(512);
                let mut buf = RawBuf::<C>::with_capacity(first, align, info.clone());
                unsafe { C::memset(&info, buf.as_mut_ptr(), first, pattern) };
                buf.grow_exact(first * 2 + 8);
                let shrink = first / 2 + 1;
                buf.grow_exact(shrink); // shrink keeps the prefix too
                let mut out = vec![0u8; shrink];
                unsafe { C::copy_out(&info, buf.as_ptr(), out.as_mut_ptr(), shrink) };
                if out.iter().any(|&b| b != pattern) {
                    return Err(format!("step {step}: grow/shrink lost the prefix"));
                }
            }
            3 => {
                // Rehome invariant: contents survive the move to new
                // info, and the source books the release (checked by
                // `after` once everything is freed).
                let n = (size + 1).min(256);
                let mut buf = RawBuf::<C>::with_capacity(n, align, info.clone());
                unsafe { C::memset(&info, buf.as_mut_ptr(), n, pattern) };
                let dst_info = fresh();
                buf.rehome(dst_info.clone());
                let mut out = vec![0u8; n];
                unsafe { C::copy_out(&dst_info, buf.as_ptr(), out.as_mut_ptr(), n) };
                if out.iter().any(|&b| b != pattern) {
                    return Err(format!("step {step}: rehome lost contents"));
                }
                drop(buf);
                after(&dst_info).map_err(|e| format!("step {step}: rehome dst: {e}"))?;
            }
            _ => {}
        }
    }
    // Drain: every surviving slot must still hold its pattern.
    for s in slots.drain(..) {
        verify_slot::<C>(&info, &s).map_err(|e| format!("drain: {e}"))?;
        unsafe { C::deallocate(&info, s.ptr, s.layout) };
    }
    after(&info).map_err(|e| format!("drop-balance: {e}"))
}

/// The generic harness entry: randomized programs over context `C`.
fn check_context<C: MemoryContext>(
    name: &str,
    fresh: impl Fn() -> C::Info,
    after: impl Fn(&C::Info) -> Result<(), String>,
) {
    Cases::new(24).shrinkable(name, 48, |program| run_program::<C>(program, &fresh, &after));
    typed_vec_exercise::<C>(&fresh);
}

/// Deterministic typed-vector exercise: the container stack over `C`
/// (push/pop, zero-fill resize, insert/erase shifts, shrink).
fn typed_vec_exercise<C: MemoryContext>(fresh: &impl Fn() -> C::Info) {
    let mut v = ContextAwareVec::<u32, C>::new_in(fresh());
    for i in 0..500u32 {
        v.push(i);
    }
    assert_eq!(v.len(), 500);
    assert_eq!(v[499], 499);
    v.resize_zeroed(600);
    assert_eq!(v[550], 0);
    v.insert_zeroed(10, 3);
    assert_eq!(v[9], 9);
    assert_eq!(v[10], 0);
    assert_eq!(v[13], 10);
    v.erase(10, 3);
    assert_eq!(v[10], 10);
    assert_eq!(v.pop(), Some(0));
    v.shrink_to_fit();
    assert_eq!(v.len(), 599);
    assert_eq!(v[0], 0);
    assert_eq!(v[42], 42);
}

fn ok<I>(_: &I) -> Result<(), String> {
    Ok(())
}

#[test]
fn host_conforms() {
    check_context::<HostContext>("conformance-host", || (), ok);
}

#[test]
fn aligned_conforms() {
    check_context::<AlignedContext<64>>("conformance-aligned", || (), ok);
}

#[test]
fn counting_conforms() {
    check_context::<CountingContext>("conformance-counting", CountingInfo::default, |info| {
        if info.0.live_allocs() != 0 {
            return Err(format!("live allocs {} != 0", info.0.live_allocs()));
        }
        if info.0.live_bytes() != 0 {
            return Err(format!("live bytes {} != 0", info.0.live_bytes()));
        }
        Ok(())
    });
}

#[test]
fn arena_conforms() {
    check_context::<ArenaContext>("conformance-arena", ArenaInfo::default, |info| {
        if info.0.live_bytes() != 0 {
            return Err(format!("arena live bytes {} != 0", info.0.live_bytes()));
        }
        if !info.0.reset() {
            return Err("balanced arena refused to reset".into());
        }
        if info.0.capacity() != 0 {
            return Err(format!("capacity {} after reset", info.0.capacity()));
        }
        Ok(())
    });
}

#[test]
fn staging_conforms() {
    check_context::<StagingContext>("conformance-staging", StagingInfo::default, ok);
}

#[test]
fn pool_conforms() {
    check_context::<PoolCtx>("conformance-pool", PoolInfo::default, |info| {
        let s = info.0.stats();
        if s.outstanding != 0 {
            return Err(format!("{} blocks still outstanding", s.outstanding));
        }
        if s.returns != s.hits + s.misses {
            return Err(format!(
                "checkout/return imbalance: {} + {} taken, {} returned",
                s.hits, s.misses, s.returns
            ));
        }
        // Parked blocks are the only live inner allocations: every
        // distinct block came from one miss, minus what trimming freed.
        let inner = info.0.inner();
        let parked = s.misses - s.trims;
        if inner.0.live_allocs() != parked as isize {
            return Err(format!(
                "inner live allocs {} != parked {parked}",
                inner.0.live_allocs()
            ));
        }
        Ok(())
    });
}

/// The tracing decorator is a pure pass-through: it must conform like
/// its inner context, with a balanced call ledger of its own.
#[test]
fn tracing_conforms() {
    check_context::<TracingContext<CountingContext>>(
        "conformance-tracing",
        TraceInfo::<CountingContext>::default,
        |info| {
            let allocs = info.stats.allocs.load(Ordering::Relaxed);
            let deallocs = info.stats.deallocs.load(Ordering::Relaxed);
            if allocs != deallocs {
                return Err(format!(
                    "trace ledger imbalance: {allocs} allocs vs {deallocs} deallocs"
                ));
            }
            if info.inner.0.live_allocs() != 0 {
                return Err(format!(
                    "inner live allocs {} != 0",
                    info.inner.0.live_allocs()
                ));
            }
            Ok(())
        },
    );
}

/// With injection disarmed (the default), the chaos harness's faulty
/// decorator must be indistinguishable from its inner context — and its
/// fault cell must never fire.
#[test]
fn faulty_disabled_conforms() {
    check_context::<FaultyContext<CountingContext>>(
        "conformance-faulty-disarmed",
        FaultyInfo::<CountingContext>::default,
        |info| {
            if info.faults.injected() != 0 {
                return Err(format!(
                    "disarmed fault cell fired {} times",
                    info.faults.injected()
                ));
            }
            if info.inner.0.live_allocs() != 0 {
                return Err(format!(
                    "inner live allocs {} != 0",
                    info.inner.0.live_allocs()
                ));
            }
            Ok(())
        },
    );
}

/// The pool must actually recycle under the harness workload: replaying
/// one program against one shared pool twice serves the second pass
/// largely from the free lists.
#[test]
fn pool_recycles_across_program_replays() {
    let info = PoolInfo::<CountingContext>::default();
    let program: Vec<u64> = (0..40u64)
        .map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(11))
        .collect();
    let fresh = || info.clone();
    run_program::<PoolCtx>(&program, &fresh, &ok).unwrap();
    let warm = info.0.stats();
    run_program::<PoolCtx>(&program, &fresh, &ok).unwrap();
    let replay = info.0.stats();
    assert_eq!(replay.misses, warm.misses, "identical replay must be all hits");
    assert!(replay.hits > warm.hits);
}

//! Scale-out integration tests for the sharded, task-parallel
//! coordinator: cross-thread plan-cache behaviour (shard-summed hit
//! rate + plan identity), the steady-state zero-shared-lock invariant
//! of `stage_into`, multi-device-worker pipelines, the saturation
//! harness's scheduler/latency metrics, and the work-stealing pool
//! through the crate's public API.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use marionette::bench_support::report;
use marionette::coordinator::{run_pipeline, PipelineConfig, RoutePolicy};
use marionette::edm::convert::register_edm_specializations;
use marionette::edm::generator::{EventConfig, EventGenerator};
use marionette::edm::sensor::{SensorCollection, SensorProps};
use marionette::marionette::layout::{AoS, SoABlob, SoAVec};
use marionette::marionette::transfer::{
    local_plan_handle_stats, plan_cache_generation, plan_cache_shard_stats, plan_cache_stats,
    plan_for,
};
use marionette::ThreadPool;

/// The four (source, destination) layout pairs the stress test mixes;
/// returns the cached plan's identity (`Arc` pointer) for each.
fn plan_identities(schema: &Arc<marionette::marionette::schema::Schema>) -> [usize; 4] {
    [
        Arc::as_ptr(&plan_for::<SoAVec, AoS>(schema)) as usize,
        Arc::as_ptr(&plan_for::<AoS, SoAVec>(schema)) as usize,
        Arc::as_ptr(&plan_for::<SoAVec, SoABlob>(schema)) as usize,
        Arc::as_ptr(&plan_for::<SoABlob, AoS>(schema)) as usize,
    ]
}

/// 16 threads hammer the sharded plan cache with a mix of four keys.
/// Every thread must resolve the *same* `Arc<TransferPlan>` per key
/// (identity, not just equality), and the shard-summed hit counters
/// must absorb essentially the whole workload: at most one shared miss
/// or lookup per (thread, key) — everything else is a hit.
#[test]
fn plan_cache_cross_thread_stress() {
    // Fire the EDM's Once-guarded specialized registrations *before*
    // measuring: registration bumps the cache generation and evicts the
    // sensor pairs, which must not happen mid-stress.
    register_edm_specializations();
    let schema = SensorProps::schema();
    let expected = plan_identities(&schema);

    let before = plan_cache_stats();
    const THREADS: usize = 16;
    const REPS: usize = 100;
    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let schema = schema.clone();
            thread::spawn(move || {
                let mut last = [0usize; 4];
                for _ in 0..REPS {
                    last = plan_identities(&schema);
                }
                last
            })
        })
        .collect();
    for h in handles {
        let got = h.join().expect("stress thread panicked");
        assert_eq!(got, expected, "a thread resolved a different plan instance");
    }

    let after = plan_cache_stats();
    // 16 threads x 100 reps x 4 keys lookups; only the first lookup per
    // (thread, key) may go to the shared map (and at most 4 of those can
    // miss). Counters are process-global and monotonic, so concurrent
    // tests can only inflate the delta, never deflate it.
    let total = (THREADS * REPS * 4) as u64;
    let floor = total - (THREADS * 4) as u64;
    assert!(
        after.hits - before.hits >= floor,
        "shard-summed hits {} -> {} (< {floor} new hits for {total} lookups)",
        before.hits,
        after.hits
    );
    assert!(after.entries >= 4, "stress keys not resident: {} entries", after.entries);
}

/// The PR's acceptance invariant: once a thread's local `PlanHandle` is
/// warm, `stage_into` performs zero shared-lock acquisitions — its
/// shared-lookup count stays flat and (in a quiet window) so does the
/// global shard-lock counter, while local hits absorb every iteration.
#[test]
fn steady_state_stage_into_zero_shared_locks() {
    register_edm_specializations();
    // A fresh thread gets a fresh thread-local handle, so the warm/warm
    // bookkeeping below is exact.
    thread::spawn(|| {
        let ev = EventGenerator::new(EventConfig::grid(24, 24, 2), 7).generate();
        let src = ev.to_collection::<SoAVec>();
        let mut dst = SensorCollection::<AoS>::new();
        src.stage_into(&mut dst); // warm this thread's handle

        let lock_sum =
            || plan_cache_shard_stats().iter().map(|s| s.lock_acquisitions).sum::<u64>();
        let mut attempts = 0;
        loop {
            attempts += 1;
            let gen0 = plan_cache_generation();
            let h0 = local_plan_handle_stats();
            let locks0 = lock_sum();
            for _ in 0..100 {
                src.stage_into(&mut dst);
            }
            let h1 = local_plan_handle_stats();
            let locks1 = lock_sum();
            if plan_cache_generation() != gen0 {
                // A registration elsewhere invalidated handles mid-window;
                // measure again.
                continue;
            }
            assert_eq!(
                h1.shared_lookups, h0.shared_lookups,
                "warm stage_into fell back to the shared cache"
            );
            assert!(
                h1.local_hits >= h0.local_hits + 100,
                "local hits {} -> {}",
                h0.local_hits,
                h1.local_hits
            );
            if locks1 == locks0 {
                break; // quiet window: zero shard-lock acquisitions process-wide
            }
            // Another test's cold lookup raced this window; the
            // handle-local assertions above already passed, retry for
            // the global counter.
            assert!(
                attempts < 50,
                "no quiet window for shard-lock counters ({locks0} -> {locks1})"
            );
            thread::sleep(Duration::from_millis(10));
        }
    })
    .join()
    .expect("steady-state thread panicked");
}

/// Multiple device workers drain the full stream with nothing lost or
/// duplicated, whether or not the AOT artifacts are present (each
/// worker falls back to host processing when its engine fails to load).
#[test]
fn multiple_device_workers_complete_and_account() {
    let mut cfg = PipelineConfig::new(EventConfig::grid(16, 16, 1), 8);
    cfg.policy = RoutePolicy::DeviceOnly;
    cfg.device = true;
    cfg.device_workers = 2;
    cfg.seed = 4242;
    let rep = run_pipeline(&cfg).unwrap();
    assert_eq!(rep.results.len(), 8);
    for (i, r) in rep.results.iter().enumerate() {
        assert_eq!(r.event_id, i as u64, "results not dense/sorted");
    }
    assert_eq!(rep.metrics.events_in, 8);
    assert_eq!(
        rep.metrics.events_host + rep.metrics.events_device,
        8,
        "every event is accounted to exactly one path"
    );
}

/// With device workers the physics stays deterministic: one worker and
/// two workers produce identical per-event results.
#[test]
fn device_worker_count_does_not_change_physics() {
    let run = |workers: usize| {
        let mut cfg = PipelineConfig::new(EventConfig::grid(24, 24, 2), 12);
        cfg.policy = RoutePolicy::DeviceOnly;
        cfg.device = true;
        cfg.device_workers = workers;
        cfg.seed = 808;
        run_pipeline(&cfg).unwrap()
    };
    let (one, two) = (run(1), run(2));
    assert_eq!(one.results.len(), two.results.len());
    for (a, b) in one.results.iter().zip(&two.results) {
        assert_eq!(a.event_id, b.event_id);
        assert_eq!(a.n_particles, b.n_particles);
        assert_eq!(a.total_energy, b.total_energy, "event {}", a.event_id);
    }
}

/// The saturation harness feeds the new scheduler and tail-latency
/// metrics: host tasks are injected (source thread is not a pool
/// worker), and the latency quantiles are ordered and non-trivial.
#[test]
fn saturation_run_reports_sched_and_latency() {
    let rep = report::run_saturation(24, 60, 2).unwrap();
    assert_eq!(rep.results.len(), 60);
    assert_eq!(rep.metrics.events_host, 60);
    assert_eq!(rep.metrics.sched_injected, 60, "one injector submission per host event");
    assert!(rep.metrics.e2e_p50 <= rep.metrics.e2e_p95);
    assert!(rep.metrics.e2e_p95 <= rep.metrics.e2e_p99);
    assert!(rep.metrics.e2e_p99 > Duration::ZERO);
    // The hot-shard summary is surfaced in the human-readable report.
    assert!(rep.report().contains("cache-shards"), "{}", rep.report());
}

/// Work stealing through the crate's public API: a producer job fans
/// out skewed children onto its own deque; idle siblings must steal to
/// finish, nothing is lost, and the counters prove it.
#[test]
fn work_stealing_balances_skewed_tasks() {
    let pool = Arc::new(ThreadPool::new(4));
    let done = Arc::new(AtomicUsize::new(0));
    const CHILDREN: usize = 48;
    let (p2, d2) = (pool.clone(), done.clone());
    pool.spawn(move || {
        for i in 0..CHILDREN {
            let d = d2.clone();
            let heavy = i % 8 == 0; // skewed sizes: every 8th child is slow
            p2.spawn(move || {
                if heavy {
                    thread::sleep(Duration::from_millis(5));
                }
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
    });
    let deadline = Instant::now() + Duration::from_secs(30);
    while done.load(Ordering::Relaxed) < CHILDREN {
        assert!(Instant::now() < deadline, "pool lost tasks: {:?}", pool.stats());
        thread::sleep(Duration::from_millis(1));
    }
    let s = pool.stats();
    assert!(s.local_pushes >= CHILDREN, "children bypassed the local deque: {s:?}");
    assert!(s.steals > 0, "no sibling stole from the producer: {s:?}");
    assert_eq!(s.panicked, 0, "{s:?}");
    assert!(s.executed >= CHILDREN + 1, "{s:?}");
}

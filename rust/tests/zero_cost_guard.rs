//! Regression guard for the zero-cost claim (§VIII).
//!
//! Hard assertions with a generous threshold (machines under test load
//! are noisy; the tight comparison lives in `benches/zero_cost.rs` and
//! EXPERIMENTS.md §ZC): Marionette accessors must stay within 1.6x of
//! the handwritten equivalent on the matched layouts, and the borrowed
//! typed views must stay within the same bound of the owned accessors
//! (the interface layer's attach-once, raw-offset-reads claim).

use marionette::bench_support::figures::zero_cost;
use marionette::bench_support::{rel_diff, Harness};

#[test]
fn marionette_is_zero_cost_within_noise() {
    let h = Harness { runs: 30, keep: 10, warmup: 3 };
    let table = zero_cost(256, h).unwrap();
    let series = |label: &str| {
        table
            .series
            .iter()
            .find(|s| s.label == label)
            .unwrap_or_else(|| panic!("missing series {label}"))
    };
    for (hw, m) in [
        ("hw-aos", "m-aos"),
        ("hw-soa", "m-soavec"),
        // Views vs owned accessors: the accessor series are the apples-
        // to-apples baselines (same per-element loop, owned storage).
        ("m-aos-accessor", "m-aos-view"),
        ("m-soavec-accessor", "m-soavec-view"),
    ] {
        let hws = series(hw);
        let ms = series(m);
        for ((op, a), (_, b)) in hws.points.iter().zip(&ms.points) {
            let ratio = b.as_secs_f64() / a.as_secs_f64();
            eprintln!(
                "{m} vs {hw} op{op}: {:.1}us vs {:.1}us (x{ratio:.2}, rel {:.1}%)",
                b.as_secs_f64() * 1e6,
                a.as_secs_f64() * 1e6,
                rel_diff(*a, *b) * 100.0
            );
            assert!(
                ratio < 1.6,
                "{m} is {ratio:.2}x of {hw} on op {op} — zero-cost regression"
            );
        }
    }
}

/// The device-side zero-cost claim is structural: "handwritten" and
/// "Marionette" device paths run the same artifact. Verify the manifest
/// hash exists and the file content matches it.
#[test]
fn device_artifact_identity() {
    let Ok(m) = marionette::runtime::Manifest::load_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rec = m.get("sensor_stage", 64, 64).unwrap();
    assert!(!rec.sha256.is_empty());
    let text = std::fs::read_to_string(&rec.file).unwrap();
    assert!(text.starts_with("HloModule"));
    // No second artifact variant exists for "handwritten": identical by
    // construction — both API spellings dispatch to this one program.
    let all: Vec<_> = m
        .records()
        .filter(|r| r.entry == "sensor_stage" && r.rows == 64)
        .collect();
    assert_eq!(all.len(), 1);
}

//! Layouts: the first template parameter of the paper's `Collection`.
//!
//! A [`Layout`] pairs a storage engine (a [`LayoutHolder`] implementation)
//! with a memory context. Collections are generic over the layout, so the
//! same property list and interface can be materialised as:
//!
//! * [`SoAVec<C>`] — one context-aware vector per property (paper:
//!   `VectorLikePerProperty`); the layout the device path consumes.
//! * [`AoS<C>`] — one blob of records per size tag (paper: `DynamicStruct`
//!   with AoS ordering); byte-compatible with handwritten `#[repr(C)]`
//!   struct vectors.
//! * [`SoABlob<C>`] — one blob per tag, field-major.
//! * [`AoSoA<K, C>`] — one blob per tag, K-wide blocked hybrid.

use super::blob::{AoSScheme, AoSoAScheme, BlobHolder, SoABlobScheme};
use super::holder::LayoutHolder;
use super::memory::{HostContext, MemoryContext};
use super::soavec::SoAVecHolder;

/// A way of storing a collection: holder + memory context (paper §V, the
/// first template parameter of `Collection`).
pub trait Layout: 'static {
    type Ctx: MemoryContext;
    type Holder: LayoutHolder<Ctx = Self::Ctx>;

    /// Label used in diagnostics and bench tables.
    const NAME: &'static str;
}

/// Vector-per-property storage (the default).
pub struct SoAVec<C: MemoryContext = HostContext>(std::marker::PhantomData<C>);

impl<C: MemoryContext> Layout for SoAVec<C> {
    type Ctx = C;
    type Holder = SoAVecHolder<C>;
    const NAME: &'static str = "soa-vec";
}

/// Array-of-structures blob storage.
pub struct AoS<C: MemoryContext = HostContext>(std::marker::PhantomData<C>);

impl<C: MemoryContext> Layout for AoS<C> {
    type Ctx = C;
    type Holder = BlobHolder<AoSScheme, C>;
    const NAME: &'static str = "aos";
}

/// Structure-of-arrays blob storage.
pub struct SoABlob<C: MemoryContext = HostContext>(std::marker::PhantomData<C>);

impl<C: MemoryContext> Layout for SoABlob<C> {
    type Ctx = C;
    type Holder = BlobHolder<SoABlobScheme, C>;
    const NAME: &'static str = "soa-blob";
}

/// Blocked AoSoA storage with block size `K`.
pub struct AoSoA<const K: usize, C: MemoryContext = HostContext>(std::marker::PhantomData<C>);

impl<const K: usize, C: MemoryContext> Layout for AoSoA<K, C> {
    type Ctx = C;
    type Holder = BlobHolder<AoSoAScheme<K>, C>;
    const NAME: &'static str = "aosoa";
}

//! Layouts: the first template parameter of the paper's `Collection`.
//!
//! A [`Layout`] pairs a storage engine (a [`LayoutHolder`] implementation)
//! with a memory context. Collections are generic over the layout, so the
//! same property list and interface can be materialised as:
//!
//! * [`SoAVec<C>`] — one context-aware vector per property (paper:
//!   `VectorLikePerProperty`); the layout the device path consumes.
//! * [`AoS<C>`] — one blob of records per size tag (paper: `DynamicStruct`
//!   with AoS ordering); byte-compatible with handwritten `#[repr(C)]`
//!   struct vectors.
//! * [`SoABlob<C>`] — one blob per tag, field-major.
//! * [`AoSoA<K, C>`] — one blob per tag, K-wide blocked hybrid.
//!
//! Beyond the holder, a layout also exposes its *static geometry* —
//! [`Layout::plane_shape`] and [`Layout::BLOB_IDENTITY`] — which the
//! transfer engine uses to compile a [`TransferPlan`] once per
//! (schema, layouts, contexts) tuple instead of re-deriving the copy
//! strategy field-by-field on every call (paper §VII-B: the
//! `TransferSpecification` ladder is resolved at compile time).
//!
//! [`TransferPlan`]: super::transfer::TransferPlan

use super::blob::{AoSScheme, AoSoAScheme, BlobHolder, BlobLayoutKind, SoABlobScheme};
use super::holder::LayoutHolder;
use super::memory::{HostContext, MemoryContext};
use super::schema::FieldMeta;
use super::soavec::SoAVecHolder;

/// Capacity-independent description of how a layout stores one plane
/// (field, array lane). Mirrors what [`LayoutHolder::plane`] returns at
/// runtime — the agreement is pinned by the transfer tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaneShape {
    /// The plane exists with this byte stride at any capacity.
    Regular { stride: usize },
    /// No regular plane: element-wise access only (e.g. AoSoA lanes).
    Irregular,
}

/// A way of storing a collection: holder + memory context (paper §V, the
/// first template parameter of `Collection`).
pub trait Layout: 'static {
    type Ctx: MemoryContext;
    type Holder: LayoutHolder<Ctx = Self::Ctx>;

    /// Label used in diagnostics and bench tables.
    const NAME: &'static str;

    /// Capacity-independent blob identity. Two layouts with equal
    /// identities store a size tag's used element prefix byte-identically
    /// in one contiguous region, so a whole-tag transfer collapses to a
    /// single block copy (plan coalescing). `None` for per-field storage
    /// ([`SoAVec`]) and capacity-dependent blobs ([`SoABlob`], whose
    /// plane bases move with capacity).
    const BLOB_IDENTITY: Option<BlobLayoutKind> = None;

    /// Static geometry of plane `(meta, k)`; must agree with what the
    /// holder's `plane` reports at runtime for every capacity.
    fn plane_shape(meta: FieldMeta, k: usize) -> PlaneShape;
}

/// Vector-per-property storage (the default).
pub struct SoAVec<C: MemoryContext = HostContext>(std::marker::PhantomData<C>);

impl<C: MemoryContext> Layout for SoAVec<C> {
    type Ctx = C;
    type Holder = SoAVecHolder<C>;
    const NAME: &'static str = "soa-vec";

    fn plane_shape(meta: FieldMeta, _k: usize) -> PlaneShape {
        PlaneShape::Regular { stride: meta.size as usize }
    }
}

/// Array-of-structures blob storage.
pub struct AoS<C: MemoryContext = HostContext>(std::marker::PhantomData<C>);

impl<C: MemoryContext> Layout for AoS<C> {
    type Ctx = C;
    type Holder = BlobHolder<AoSScheme, C>;
    const NAME: &'static str = "aos";
    const BLOB_IDENTITY: Option<BlobLayoutKind> = Some(BlobLayoutKind::AoS);

    fn plane_shape(meta: FieldMeta, _k: usize) -> PlaneShape {
        PlaneShape::Regular { stride: meta.record_size as usize }
    }
}

/// Structure-of-arrays blob storage.
pub struct SoABlob<C: MemoryContext = HostContext>(std::marker::PhantomData<C>);

impl<C: MemoryContext> Layout for SoABlob<C> {
    type Ctx = C;
    type Holder = BlobHolder<SoABlobScheme, C>;
    const NAME: &'static str = "soa-blob";

    fn plane_shape(meta: FieldMeta, _k: usize) -> PlaneShape {
        PlaneShape::Regular { stride: meta.size as usize }
    }
}

/// Blocked AoSoA storage with block size `K`.
pub struct AoSoA<const K: usize, C: MemoryContext = HostContext>(std::marker::PhantomData<C>);

impl<const K: usize, C: MemoryContext> Layout for AoSoA<K, C> {
    type Ctx = C;
    type Holder = BlobHolder<AoSoAScheme<K>, C>;
    const NAME: &'static str = "aosoa";
    const BLOB_IDENTITY: Option<BlobLayoutKind> = Some(BlobLayoutKind::AoSoA(K));

    fn plane_shape(_meta: FieldMeta, _k: usize) -> PlaneShape {
        // Lanes jump at block boundaries: no single regular stride.
        PlaneShape::Irregular
    }
}

#[cfg(test)]
mod tests {
    use super::super::schema::Schema;
    use super::*;
    use std::sync::Arc;

    /// `plane_shape` must agree with the holder's runtime `plane` view.
    #[test]
    fn static_geometry_matches_runtime_planes() {
        let s = Arc::new(
            Schema::builder("geom")
                .per_item::<f32>("a")
                .per_item::<u8>("b")
                .array::<i32>("arr", 2)
                .jagged::<u64, u32>("j")
                .global::<u64>("g")
                .build(),
        );

        fn check<L: Layout>(s: &Arc<Schema>)
        where
            <L::Ctx as MemoryContext>::Info: Default,
        {
            use super::super::collection::RawCollection;
            let mut c = RawCollection::<L>::new(s.clone());
            c.resize(10);
            c.append_values(0, 4);
            for (fid, _f) in s.fields() {
                let meta = s.meta(fid);
                for k in 0..meta.extent as usize {
                    match L::plane_shape(meta, k) {
                        PlaneShape::Regular { stride } => {
                            let p = c.plane(meta, k).expect("plane promised by shape");
                            assert_eq!(p.stride, stride, "{} field {fid:?}", L::NAME);
                        }
                        PlaneShape::Irregular => {
                            assert!(c.plane(meta, k).is_none(), "{} field {fid:?}", L::NAME);
                        }
                    }
                }
            }
        }

        check::<SoAVec>(&s);
        check::<AoS>(&s);
        check::<SoABlob>(&s);
        check::<AoSoA<4>>(&s);
        check::<AoSoA<16>>(&s);
    }

    #[test]
    fn blob_identities() {
        assert_eq!(<AoS as Layout>::BLOB_IDENTITY, Some(BlobLayoutKind::AoS));
        assert_eq!(
            <AoSoA<8> as Layout>::BLOB_IDENTITY,
            Some(BlobLayoutKind::AoSoA(8))
        );
        assert_ne!(
            <AoSoA<8> as Layout>::BLOB_IDENTITY,
            <AoSoA<4> as Layout>::BLOB_IDENTITY
        );
        assert_eq!(<SoAVec as Layout>::BLOB_IDENTITY, None);
        assert_eq!(<SoABlob as Layout>::BLOB_IDENTITY, None);
    }
}

//! `marionette_collection!` — the typed interface generator.
//!
//! The analogue of the paper's `MARIONETTE_DECLARE_*` macro family plus the
//! `PropertyList` alias (§VI): one declaration produces, for a property
//! list,
//!
//! * a **props struct** holding compile-time [`FieldMeta`] constants for
//!   every property (the property-description classes of the paper), all
//!   offsets resolved by `const` evaluation — the zero-cost guarantee;
//! * a **collection struct**, generic over [`Layout`], with the
//!   `std::vector`-like interface, typed accessors/mutators per property,
//!   jagged-vector views, global properties, and layout/context transfers —
//!   plus the fluent entry points: `build()` (the
//!   [`Build`](crate::marionette::interface::Build)er chain),
//!   `convert_to::<L2>()` / `stage_into(&mut dst)` (conversion sugar over
//!   the cached [`TransferPlan`]), and `view()` / `view_mut()`;
//! * **borrowed typed views** (`View`/`ViewMut`), generic over any
//!   schema-matching [`PlaneSource`] — the same accessor interface
//!   *detached from ownership*, so one description serves the owned
//!   collection, pooled staging collections, and schema-shaped slice
//!   stores such as downloaded device planes (see
//!   [`interface`](crate::marionette::interface));
//! * an **owned object struct** (the paper's standalone `Object`) plus
//!   **proxy types** (`Ref`/`Mut`, the paper's objects-in-collections) and
//!   **sub-group views**;
//! * iteration over object proxies.
//!
//! Like the paper's macros, property names are given in both accessor
//! (lowercase) and property-description (CONST) form because Rust macros
//! cannot derive new identifiers. Arbitrary extra interface functions (the
//! paper's *no-property* properties) are plain inherent `impl` blocks on
//! the generated types — see `edm::sensor` for the worked example.
//!
//! Grammar:
//!
//! ```text
//! marionette_collection! {
//!     /// docs…
//!     pub collection Sensors, object Sensor, record SensorRec,
//!         columns SensorCols, refs SensorRef/SensorMut,
//!         views SensorsView/SensorsViewMut,
//!         props SensorProps, schema "sensor" {
//!         per_item energy / set_energy / ENERGY: f32;
//!         group calibration / CalibView / CalibViewMut {
//!             per_item noisy / set_noisy / NOISY: u8;
//!         }
//!         array significance / set_significance / SIGNIFICANCE: [f32; 3];
//!         jagged cells / set_cells / CELLS: u64, prefix u32;
//!         global event_id / set_event_id / EVENT_ID: u64;
//!     }
//! }
//! ```
//!
//! Restrictions vs the paper (documented scope): groups hold per-item
//! scalars only and do not nest (group members surface as flat accessors
//! on the views); jagged properties have a single value field (the
//! paper's `*_SIMPLE_*` form — multi-payload jagged vectors are available
//! through the runtime [`SchemaBuilder`] API); borrowed views read and
//! rewrite elements in place but never change the collection's shape
//! (structural mutation stays with the owner).
//!
//! [`FieldMeta`]: crate::marionette::schema::FieldMeta
//! [`Layout`]: crate::marionette::layout::Layout
//! [`SchemaBuilder`]: crate::marionette::schema::SchemaBuilder
//! [`PlaneSource`]: crate::marionette::interface::PlaneSource
//! [`TransferPlan`]: crate::marionette::transfer::TransferPlan

/// Declare a typed Marionette collection. See the [module docs](self).
#[macro_export]
macro_rules! marionette_collection {
    (
        $(#[$docs:meta])*
        pub collection $Col:ident, object $Obj:ident, record $Rec:ident,
            columns $Cols:ident, refs $Ref:ident / $Mut:ident,
            views $View:ident / $ViewMut:ident,
            props $Props:ident, schema $sname:literal {
            $($body:tt)*
        }
    ) => {
        $crate::marionette_collection!(@parse
            docs=[$(#[$docs])*], col=$Col, obj=$Obj, rec=$Rec, cols=$Cols, r=$Ref, m=$Mut,
            v=$View, vm=$ViewMut,
            props=$Props, sname=$sname,
            pis=[], arrs=[], jags=[], globs=[], groups=[],
            rest=[$($body)*]
        );
    };

    // ---------------- parsing: munch one declaration at a time ----------
    (@parse
        docs=[$($docs:tt)*], col=$Col:ident, obj=$Obj:ident, rec=$Rec:ident, cols=$Cols:ident, r=$Ref:ident, m=$Mut:ident,
        v=$View:ident, vm=$ViewMut:ident,
        props=$Props:ident, sname=$sname:literal,
        pis=[$($pis:tt)*], arrs=[$($arrs:tt)*], jags=[$($jags:tt)*],
        globs=[$($globs:tt)*], groups=[$($groups:tt)*],
        rest=[per_item $g:ident / $s:ident / $C:ident : $ty:ty ; $($rest:tt)*]
    ) => {
        $crate::marionette_collection!(@parse
            docs=[$($docs)*], col=$Col, obj=$Obj, rec=$Rec, cols=$Cols, r=$Ref, m=$Mut,
            v=$View, vm=$ViewMut,
            props=$Props, sname=$sname,
            pis=[$($pis)* [$g $s $C ($ty)]], arrs=[$($arrs)*], jags=[$($jags)*],
            globs=[$($globs)*], groups=[$($groups)*],
            rest=[$($rest)*]
        );
    };
    (@parse
        docs=[$($docs:tt)*], col=$Col:ident, obj=$Obj:ident, rec=$Rec:ident, cols=$Cols:ident, r=$Ref:ident, m=$Mut:ident,
        v=$View:ident, vm=$ViewMut:ident,
        props=$Props:ident, sname=$sname:literal,
        pis=[$($pis:tt)*], arrs=[$($arrs:tt)*], jags=[$($jags:tt)*],
        globs=[$($globs:tt)*], groups=[$($groups:tt)*],
        rest=[group $g:ident / $GV:ident / $GM:ident {
            $(per_item $ig:ident / $is:ident / $IC:ident : $ity:ty ;)*
        } $($rest:tt)*]
    ) => {
        $crate::marionette_collection!(@parse
            docs=[$($docs)*], col=$Col, obj=$Obj, rec=$Rec, cols=$Cols, r=$Ref, m=$Mut,
            v=$View, vm=$ViewMut,
            props=$Props, sname=$sname,
            pis=[$($pis)* $([$ig $is $IC ($ity)])*], arrs=[$($arrs)*], jags=[$($jags)*],
            globs=[$($globs)*],
            groups=[$($groups)* [$g $GV $GM [$([$ig $is $IC ($ity)])*]]],
            rest=[$($rest)*]
        );
    };
    (@parse
        docs=[$($docs:tt)*], col=$Col:ident, obj=$Obj:ident, rec=$Rec:ident, cols=$Cols:ident, r=$Ref:ident, m=$Mut:ident,
        v=$View:ident, vm=$ViewMut:ident,
        props=$Props:ident, sname=$sname:literal,
        pis=[$($pis:tt)*], arrs=[$($arrs:tt)*], jags=[$($jags:tt)*],
        globs=[$($globs:tt)*], groups=[$($groups:tt)*],
        rest=[array $g:ident / $s:ident / $C:ident : [$ty:ty ; $e:expr] ; $($rest:tt)*]
    ) => {
        $crate::marionette_collection!(@parse
            docs=[$($docs)*], col=$Col, obj=$Obj, rec=$Rec, cols=$Cols, r=$Ref, m=$Mut,
            v=$View, vm=$ViewMut,
            props=$Props, sname=$sname,
            pis=[$($pis)*], arrs=[$($arrs)* [$g $s $C ($ty) ($e)]], jags=[$($jags)*],
            globs=[$($globs)*], groups=[$($groups)*],
            rest=[$($rest)*]
        );
    };
    (@parse
        docs=[$($docs:tt)*], col=$Col:ident, obj=$Obj:ident, rec=$Rec:ident, cols=$Cols:ident, r=$Ref:ident, m=$Mut:ident,
        v=$View:ident, vm=$ViewMut:ident,
        props=$Props:ident, sname=$sname:literal,
        pis=[$($pis:tt)*], arrs=[$($arrs:tt)*], jags=[$($jags:tt)*],
        globs=[$($globs:tt)*], groups=[$($groups:tt)*],
        rest=[jagged $g:ident / $s:ident / $C:ident : $ty:ty , prefix $pty:ty ; $($rest:tt)*]
    ) => {
        $crate::marionette_collection!(@parse
            docs=[$($docs)*], col=$Col, obj=$Obj, rec=$Rec, cols=$Cols, r=$Ref, m=$Mut,
            v=$View, vm=$ViewMut,
            props=$Props, sname=$sname,
            pis=[$($pis)*], arrs=[$($arrs)*], jags=[$($jags)* [$g $s $C ($ty) ($pty)]],
            globs=[$($globs)*], groups=[$($groups)*],
            rest=[$($rest)*]
        );
    };
    (@parse
        docs=[$($docs:tt)*], col=$Col:ident, obj=$Obj:ident, rec=$Rec:ident, cols=$Cols:ident, r=$Ref:ident, m=$Mut:ident,
        v=$View:ident, vm=$ViewMut:ident,
        props=$Props:ident, sname=$sname:literal,
        pis=[$($pis:tt)*], arrs=[$($arrs:tt)*], jags=[$($jags:tt)*],
        globs=[$($globs:tt)*], groups=[$($groups:tt)*],
        rest=[global $g:ident / $s:ident / $C:ident : $ty:ty ; $($rest:tt)*]
    ) => {
        $crate::marionette_collection!(@parse
            docs=[$($docs)*], col=$Col, obj=$Obj, rec=$Rec, cols=$Cols, r=$Ref, m=$Mut,
            v=$View, vm=$ViewMut,
            props=$Props, sname=$sname,
            pis=[$($pis)*], arrs=[$($arrs)*], jags=[$($jags)*],
            globs=[$($globs)* [$g $s $C ($ty)]], groups=[$($groups)*],
            rest=[$($rest)*]
        );
    };

    // ---------------- emission ------------------------------------------
    (@parse
        docs=[$($docs:tt)*], col=$Col:ident, obj=$Obj:ident, rec=$Rec:ident, cols=$Cols:ident, r=$Ref:ident, m=$Mut:ident,
        v=$View:ident, vm=$ViewMut:ident,
        props=$Props:ident, sname=$sname:literal,
        pis=[$([$pig:ident $pis_:ident $PIC:ident ($pity:ty)])*],
        arrs=[$([$ag:ident $as_:ident $AC:ident ($aty:ty) ($aext:expr)])*],
        jags=[$([$jg:ident $js_:ident $JC:ident ($jty:ty) ($jpty:ty)])*],
        globs=[$([$gg:ident $gs_:ident $GC:ident ($gty:ty)])*],
        groups=[$([$grg:ident $GRV:ident $GRM:ident
                   [$([$gig:ident $gis_:ident $GIC:ident ($gity:ty)])*]])*],
        rest=[]
    ) => {
        /// Property descriptions of the collection: compile-time
        /// `FieldMeta` constants (all offsets const-folded) plus the
        /// runtime `Schema`.
        pub struct $Props;

        #[allow(dead_code)]
        impl $Props {
            /// Field names, in schema order (per-items, arrays, jagged
            /// prefix/value pairs, globals).
            pub const NAMES: &'static [&'static str] = &[
                $(stringify!($pig),)*
                $(stringify!($ag),)*
                $(concat!(stringify!($jg), "__prefix"), stringify!($jg),)*
                $(stringify!($gg),)*
            ];

            pub const NUM_FIELDS: usize = Self::NAMES.len();

            pub const DESCS: [$crate::marionette::schema::FieldDesc; Self::NUM_FIELDS] = [
                $($crate::marionette::schema::FieldDesc::per_item(
                    <$pity as $crate::marionette::pod::Pod>::DTYPE),)*
                $($crate::marionette::schema::FieldDesc::array(
                    <$aty as $crate::marionette::pod::Pod>::DTYPE, $aext as u32),)*
                $($crate::marionette::schema::FieldDesc::jagged_prefix(
                    <$jpty as $crate::marionette::pod::Pod>::DTYPE),
                  $crate::marionette::schema::FieldDesc::jagged_values(
                    <$jty as $crate::marionette::pod::Pod>::DTYPE),)*
                $($crate::marionette::schema::FieldDesc::global(
                    <$gty as $crate::marionette::pod::Pod>::DTYPE),)*
            ];

            pub const METAS: [$crate::marionette::schema::FieldMeta; Self::NUM_FIELDS] =
                $crate::marionette::schema::compute_metas(Self::DESCS);

            /// Meta of the first `Items`-tag field (record-view anchor).
            pub const FIRST_ITEM_META: $crate::marionette::schema::FieldMeta =
                Self::METAS[0];

            $(pub const $PIC: $crate::marionette::schema::FieldMeta =
                $crate::marionette::schema::meta_by_name(
                    &Self::METAS, Self::NAMES, stringify!($pig));)*
            $(pub const $AC: $crate::marionette::schema::FieldMeta =
                $crate::marionette::schema::meta_by_name(
                    &Self::METAS, Self::NAMES, stringify!($ag));)*
            $(pub const $JC: $crate::marionette::schema::JaggedProp =
                $crate::marionette::schema::JaggedProp::from_metas(
                    $crate::marionette::schema::meta_by_name(
                        &Self::METAS, Self::NAMES,
                        concat!(stringify!($jg), "__prefix")),
                    $crate::marionette::schema::meta_by_name(
                        &Self::METAS, Self::NAMES, stringify!($jg)));)*
            $(pub const $GC: $crate::marionette::schema::FieldMeta =
                $crate::marionette::schema::meta_by_name(
                    &Self::METAS, Self::NAMES, stringify!($gg));)*

            /// The shared runtime schema (memoised; structurally identical
            /// to the const metas, checked at collection construction).
            pub fn schema() -> ::std::sync::Arc<$crate::marionette::schema::Schema> {
                static S: ::std::sync::OnceLock<
                    ::std::sync::Arc<$crate::marionette::schema::Schema>,
                > = ::std::sync::OnceLock::new();
                S.get_or_init(|| {
                    let b = $crate::marionette::schema::Schema::builder($sname)
                        $(.per_item::<$pity>(stringify!($pig)))*
                        $(.array::<$aty>(stringify!($ag), $aext as u32))*
                        $(.jagged::<$jty, $jpty>(stringify!($jg)))*
                        $(.global::<$gty>(stringify!($gg)))*;
                    ::std::sync::Arc::new(b.build())
                })
                .clone()
            }
        }

        /// The family hook behind the fluent builder: one declaration =
        /// one family, materialisable under any layout.
        impl $crate::marionette::interface::CollectionFamily for $Props {
            type Typed<L: $crate::marionette::layout::Layout> = $Col<L>;

            fn family_schema() -> ::std::sync::Arc<$crate::marionette::schema::Schema> {
                $Props::schema()
            }

            fn from_raw<L: $crate::marionette::layout::Layout>(
                raw: $crate::marionette::collection::RawCollection<L>,
            ) -> $Col<L> {
                debug_assert_eq!(&$Props::METAS[..], raw.schema().metas());
                $Col { raw }
            }
        }

        $($docs)*
        pub struct $Col<L: $crate::marionette::layout::Layout =
            $crate::marionette::layout::SoAVec<$crate::marionette::memory::HostContext>>
        {
            raw: $crate::marionette::collection::RawCollection<L>,
        }

        impl<L: $crate::marionette::layout::Layout> $Col<L>
        where
            $crate::marionette::collection::InfoOf<L>: Default,
        {
            /// Empty collection with default context info.
            pub fn new() -> Self {
                Self::new_in(Default::default())
            }
        }

        impl<L: $crate::marionette::layout::Layout> Default for $Col<L>
        where
            $crate::marionette::collection::InfoOf<L>: Default,
        {
            fn default() -> Self {
                Self::new()
            }
        }

        #[allow(dead_code)]
        impl $Col {
            /// Start a fluent build of this collection family, beginning
            /// in the default layout (`SoAVec<HostContext>`):
            ///
            /// ```text
            /// let col = Collection::build()
            ///     .layout::<AoS<_>>()   // re-target layout + context
            ///     .context(info)        // pin the context info
            ///     .capacity(n)          // pre-reserve
            ///     .finish();
            /// ```
            pub fn build() -> $crate::marionette::interface::Build<$Props> {
                $crate::marionette::interface::Build::new()
            }
        }

        #[allow(dead_code)]
        impl<L: $crate::marionette::layout::Layout> $Col<L> {
            /// Empty collection with explicit context info.
            pub fn new_in(info: $crate::marionette::collection::InfoOf<L>) -> Self {
                let raw = $crate::marionette::collection::RawCollection::<L>::new_in(
                    $Props::schema(),
                    info,
                );
                // The const metas and the runtime schema are produced by
                // two implementations of the same layout algorithm; pin
                // them against each other once per construction in debug.
                debug_assert_eq!(&$Props::METAS[..], raw.schema().metas());
                Self { raw }
            }

            // ---- vector-like interface ------------------------------

            #[inline(always)]
            pub fn len(&self) -> usize { self.raw.len() }
            pub fn is_empty(&self) -> bool { self.raw.is_empty() }
            pub fn capacity(&self) -> usize { self.raw.capacity() }
            pub fn reserve(&mut self, extra: usize) { self.raw.reserve(extra) }
            pub fn resize(&mut self, n: usize) { self.raw.resize(n) }
            pub fn clear(&mut self) { self.raw.clear() }
            pub fn shrink_to_fit(&mut self) { self.raw.shrink_to_fit() }
            pub fn push_default(&mut self) -> usize { self.raw.push_default() }
            pub fn insert_items(&mut self, at: usize, n: usize) {
                self.raw.insert_items(at, n)
            }
            pub fn erase_items(&mut self, at: usize, n: usize) {
                self.raw.erase_items(at, n)
            }

            // ---- escape hatches & management ------------------------

            /// The underlying layout-generic engine.
            pub fn raw(&self) -> &$crate::marionette::collection::RawCollection<L> {
                &self.raw
            }
            pub fn raw_mut(
                &mut self,
            ) -> &mut $crate::marionette::collection::RawCollection<L> {
                &mut self.raw
            }
            pub fn schema(&self) -> &::std::sync::Arc<$crate::marionette::schema::Schema> {
                self.raw.schema()
            }
            pub fn layout_name(&self) -> &'static str { self.raw.layout_name() }
            pub fn context_name(&self) -> &'static str { self.raw.context_name() }
            pub fn context_info(&self) -> &$crate::marionette::collection::InfoOf<L> {
                self.raw.context_info()
            }
            /// Paper: `update_memory_context_info` — reallocate under new
            /// context info, copying contents.
            pub fn update_memory_context_info(
                &mut self,
                info: $crate::marionette::collection::InfoOf<L>,
            ) {
                self.raw.update_memory_context_info(info)
            }

            // ---- typed views (borrowed, source-erased) --------------

            /// Borrowed typed view over this collection's own storage
            /// (the owned special case of attaching to any
            /// `PlaneSource`).
            ///
            /// # Panics
            /// If the collection's memory context is not host-readable.
            pub fn view(
                &self,
            ) -> $View<'_, $crate::marionette::collection::RawCollection<L>> {
                $View::attach(&self.raw)
                    .expect("owned collection always schema-matches its own view")
            }

            /// Mutable borrowed view; see [`Self::view`].
            pub fn view_mut(
                &mut self,
            ) -> $ViewMut<'_, $crate::marionette::collection::RawCollection<L>> {
                $ViewMut::attach(&mut self.raw)
                    .expect("owned collection always schema-matches its own view")
            }

            // ---- conversions (fluent, plan-cache routed) ------------

            /// Materialise this collection under layout `L2` (default
            /// context info) through the cached
            /// [`TransferPlan`](crate::marionette::transfer::TransferPlan).
            pub fn convert_to<L2: $crate::marionette::layout::Layout>(&self) -> $Col<L2> {
                self.convert_to_in(Default::default())
            }

            /// As [`Self::convert_to`], with explicit context info.
            pub fn convert_to_in<L2: $crate::marionette::layout::Layout>(
                &self,
                info: $crate::marionette::collection::InfoOf<L2>,
            ) -> $Col<L2> {
                let mut dst = $Col::<L2>::new_in(info);
                let plan =
                    $crate::marionette::transfer::plan_for::<L, L2>(self.raw.schema());
                plan.execute(&self.raw, &mut dst.raw);
                dst
            }

            /// Stage this collection into a reusable destination through
            /// the cached plan, returning full execution stats (bytes
            /// moved, copy ops issued, rung). The ladder is resolved
            /// once per (schema, layouts, contexts) tuple and reused by
            /// every later copy; this is the single staging entry point
            /// alongside [`Self::convert_to`] (the allocating spelling).
            pub fn stage_into<L2: $crate::marionette::layout::Layout>(
                &self,
                dst: &mut $Col<L2>,
            ) -> $crate::marionette::transfer::TransferStats {
                let plan =
                    $crate::marionette::transfer::plan_for::<L, L2>(self.raw.schema());
                plan.execute(&self.raw, &mut dst.raw)
            }

            /// The cached transfer plan used when copying *from* a
            /// collection of layout `L2` into this collection's layout
            /// (compiled on first request, then shared). Typed
            /// collections of one declaration all share the memoised
            /// `Props::schema()` instance, so this resolves to exactly
            /// the plan `src.stage_into(self)` executes.
            pub fn transfer_plan_from<L2: $crate::marionette::layout::Layout>(
                &self,
            ) -> ::std::sync::Arc<$crate::marionette::transfer::TransferPlan> {
                $crate::marionette::transfer::plan_for::<L2, L>(self.raw.schema())
            }

            /// Wrap this collection in an access-tracing source: attach
            /// a view to the result and every accessor call is booked
            /// on `tape` (reads; see
            /// [`Self::traced_mut`] for writes) before resolving
            /// against the underlying storage. The tape must have been
            /// built over this collection's schema. Tracing is per-call
            /// opt-in — views attached to `&self` directly are
            /// unaffected (DESIGN.md §9).
            pub fn traced<'a>(
                &'a self,
                tape: &'a $crate::marionette::trace::TraceTape,
            ) -> $crate::marionette::interface::TracingSource<
                'a,
                $crate::marionette::collection::RawCollection<L>,
            > {
                $crate::marionette::interface::TracingSource::new(&self.raw, tape)
            }

            /// Mutable twin of [`Self::traced`]: wraps the collection
            /// for a `ViewMut`, booking reads and writes on `tape`.
            pub fn traced_mut<'a>(
                &'a mut self,
                tape: &'a $crate::marionette::trace::TraceTape,
            ) -> $crate::marionette::interface::TracingSourceMut<
                'a,
                $crate::marionette::collection::RawCollection<L>,
            > {
                $crate::marionette::interface::TracingSourceMut::new(&mut self.raw, tape)
            }

            // ---- per-item scalar accessors --------------------------

            $(
                #[inline(always)]
                pub fn $pig(&self, i: usize) -> $pity {
                    self.raw.get::<$pity>($Props::$PIC, i)
                }
                #[inline(always)]
                pub fn $pis_(&mut self, i: usize, v: $pity) {
                    self.raw.set::<$pity>($Props::$PIC, i, v)
                }
            )*

            // ---- array accessors ------------------------------------

            $(
                #[inline(always)]
                pub fn $ag(&self, i: usize, k: usize) -> $aty {
                    self.raw.get_k::<$aty>($Props::$AC, i, k)
                }
                #[inline(always)]
                pub fn $as_(&mut self, i: usize, k: usize, v: $aty) {
                    self.raw.set_k::<$aty>($Props::$AC, i, k, v)
                }
            )*

            // ---- jagged accessors -----------------------------------

            $(
                /// Values of this item's jagged vector.
                #[inline]
                pub fn $jg(
                    &self,
                    i: usize,
                ) -> $crate::marionette::collection::JaggedView<'_, $jty, L> {
                    self.raw.jagged_view::<$jty>($Props::$JC.values, $Props::$JC.j, i)
                }
                /// Replace this item's jagged vector (resizes + copies;
                /// shifts later items' values).
                pub fn $js_(&mut self, i: usize, vals: &[$jty]) {
                    self.raw.set_jagged_count($Props::$JC.j, i, vals.len());
                    let r = self.raw.jagged_range($Props::$JC.j, i);
                    for (n, v) in vals.iter().enumerate() {
                        self.raw.set_value::<$jty>($Props::$JC.values, r.start + n, *v);
                    }
                }
            )*

            // ---- global accessors -----------------------------------

            $(
                #[inline(always)]
                pub fn $gg(&self) -> $gty {
                    self.raw.get_global::<$gty>($Props::$GC)
                }
                #[inline(always)]
                pub fn $gs_(&mut self, v: $gty) {
                    self.raw.set_global::<$gty>($Props::$GC, v)
                }
            )*

            // ---- objects & proxies ----------------------------------

            /// Append an owned object.
            pub fn push(&mut self, o: &$Obj) -> usize {
                let i = self.raw.push_default();
                $(self.raw.set::<$pity>($Props::$PIC, i, o.$pig);)*
                $(
                    for k in 0..($aext as usize) {
                        self.raw.set_k::<$aty>($Props::$AC, i, k, o.$ag[k]);
                    }
                )*
                $(
                    {
                        let v0 = self.raw.append_values($Props::$JC.j, o.$jg.len());
                        for (n, v) in o.$jg.iter().enumerate() {
                            self.raw.set_value::<$jty>($Props::$JC.values, v0 + n, *v);
                        }
                    }
                )*
                i
            }

            /// Materialise item `i` as an owned object.
            pub fn get_owned(&self, i: usize) -> $Obj {
                $Obj {
                    $($pig: self.raw.get::<$pity>($Props::$PIC, i),)*
                    $($ag: {
                        let mut a = [<$aty as Default>::default(); $aext as usize];
                        for k in 0..($aext as usize) {
                            a[k] = self.raw.get_k::<$aty>($Props::$AC, i, k);
                        }
                        a
                    },)*
                    $($jg: self
                        .raw
                        .jagged_view::<$jty>($Props::$JC.values, $Props::$JC.j, i)
                        .to_vec(),)*
                }
            }

            /// Immutable proxy into item `i` (paper: object proxies).
            #[inline]
            pub fn obj(&self, i: usize) -> $Ref<'_, L> {
                assert!(i < self.len(), "object index out of bounds");
                $Ref { col: self, i }
            }

            /// Mutable proxy into item `i`.
            #[inline]
            pub fn obj_mut(&mut self, i: usize) -> $Mut<'_, L> {
                assert!(i < self.len(), "object index out of bounds");
                $Mut { col: self, i }
            }

            /// Iterate object proxies.
            pub fn iter(&self) -> impl Iterator<Item = $Ref<'_, L>> {
                (0..self.len()).map(move |i| $Ref { col: self, i })
            }
        }

        /// The typed collection is itself a
        /// [`PlaneSource`](crate::marionette::interface::PlaneSource):
        /// views attach to it directly, pooled or not.
        impl<L: $crate::marionette::layout::Layout>
            $crate::marionette::interface::PlaneSource for $Col<L>
        {
            fn schema(&self) -> &::std::sync::Arc<$crate::marionette::schema::Schema> {
                self.raw.schema()
            }

            fn tag_len(&self, tag: $crate::marionette::schema::TagId) -> usize {
                $crate::marionette::interface::PlaneSource::tag_len(&self.raw, tag)
            }

            fn host_readable(&self) -> bool {
                $crate::marionette::interface::PlaneSource::host_readable(&self.raw)
            }

            fn source_name(&self) -> &'static str {
                $crate::marionette::interface::PlaneSource::source_name(&self.raw)
            }

            #[inline(always)]
            unsafe fn elem_ptr(
                &self,
                meta: $crate::marionette::schema::FieldMeta,
                i: usize,
                k: usize,
            ) -> *const u8 {
                $crate::marionette::interface::PlaneSource::elem_ptr(&self.raw, meta, i, k)
            }

            fn plane(
                &self,
                meta: $crate::marionette::schema::FieldMeta,
                k: usize,
            ) -> Option<$crate::marionette::holder::PlaneView> {
                $crate::marionette::interface::PlaneSource::plane(&self.raw, meta, k)
            }
        }

        impl<L: $crate::marionette::layout::Layout>
            $crate::marionette::interface::PlaneSourceMut for $Col<L>
        {
            #[inline(always)]
            unsafe fn elem_ptr_mut(
                &mut self,
                meta: $crate::marionette::schema::FieldMeta,
                i: usize,
                k: usize,
            ) -> *mut u8 {
                $crate::marionette::interface::PlaneSourceMut::elem_ptr_mut(
                    &mut self.raw, meta, i, k,
                )
            }
        }

        /// Borrowed typed view over **any** schema-matching
        /// [`PlaneSource`](crate::marionette::interface::PlaneSource):
        /// the collection's accessor interface detached from ownership.
        /// Attach once (schema-checked; dense per-item planes are
        /// resolved to cached spans), then every accessor is a
        /// raw-offset read — zero per-element dispatch, at
        /// dense-slice speed on regular layouts and owned-accessor
        /// speed on irregular ones.
        pub struct $View<'a, S: $crate::marionette::interface::PlaneSource> {
            src: &'a S,
            len: usize,
            $($pig: Option<$crate::marionette::interface::PlaneSpan>,)*
        }

        #[allow(dead_code)]
        impl<'a, S: $crate::marionette::interface::PlaneSource> $View<'a, S> {
            /// Attach to a schema-matching source. Fails cleanly on
            /// structural or dtype mismatch, unbound fields, or
            /// non-host-readable storage.
            pub fn attach(
                src: &'a S,
            ) -> Result<Self, $crate::marionette::interface::AttachError> {
                $crate::marionette::interface::check_attach(src, &$Props::schema())?;
                let len = $crate::marionette::interface::PlaneSource::tag_len(
                    src,
                    $crate::marionette::schema::TagId::ITEMS,
                );
                debug_assert_eq!(
                    $crate::marionette::interface::PlaneSource::tag_len(
                        src,
                        $crate::marionette::schema::TagId::ITEMS_PLUS_ONE,
                    ),
                    len + 1,
                    "source's prefix tag disagrees with its item count",
                );
                Ok($View {
                    src,
                    len,
                    $($pig: $crate::marionette::interface::resolve_span(
                        src,
                        $Props::$PIC,
                        0,
                    ),)*
                })
            }

            #[inline(always)]
            pub fn len(&self) -> usize { self.len }
            pub fn is_empty(&self) -> bool { self.len == 0 }

            /// The attached source.
            pub fn source(&self) -> &'a S { self.src }

            // ---- per-item scalar reads ------------------------------

            $(
                #[inline(always)]
                pub fn $pig(&self, i: usize) -> $pity {
                    assert!(i < self.len, "view index out of bounds");
                    // SAFETY: attach checked the schema and i is
                    // bounded; a cached span is the dense plane of this
                    // field on this same source (base stays valid for
                    // the view's borrow, offsets stay aligned because
                    // plane strides are multiples of the field align).
                    unsafe {
                        match self.$pig {
                            Some(p) => *(p.base.add(i * p.stride) as *const $pity),
                            None => $crate::marionette::interface::read::<$pity, S>(
                                self.src, $Props::$PIC, i, 0,
                            ),
                        }
                    }
                }
            )*

            // ---- array reads ----------------------------------------

            $(
                #[inline(always)]
                pub fn $ag(&self, i: usize, k: usize) -> $aty {
                    assert!(i < self.len, "view index out of bounds");
                    assert!(k < ($aext as usize), "view lane out of extent");
                    // SAFETY: attach checked the schema; i, k bounded.
                    unsafe {
                        $crate::marionette::interface::read::<$aty, S>(
                            self.src, $Props::$AC, i, k,
                        )
                    }
                }
            )*

            // ---- jagged reads ---------------------------------------

            $(
                /// Values of this item's jagged vector, read through the
                /// source.
                #[inline]
                pub fn $jg(
                    &self,
                    i: usize,
                ) -> $crate::marionette::interface::SourceJagged<'a, $jty, S> {
                    assert!(i < self.len, "view index out of bounds");
                    // SAFETY: attach pinned the prefix tag at len + 1,
                    // so i and i + 1 are valid prefix indices.
                    let lo = unsafe {
                        $crate::marionette::interface::read::<$jpty, S>(
                            self.src, $Props::$JC.prefix, i, 0,
                        )
                    } as usize;
                    let hi = unsafe {
                        $crate::marionette::interface::read::<$jpty, S>(
                            self.src, $Props::$JC.prefix, i + 1, 0,
                        )
                    } as usize;
                    $crate::marionette::interface::SourceJagged::new(
                        self.src, $Props::$JC.values, lo..hi,
                    )
                }
            )*

            // ---- global reads ---------------------------------------

            $(
                #[inline(always)]
                pub fn $gg(&self) -> $gty {
                    // SAFETY: the Global tag always holds one record.
                    unsafe {
                        $crate::marionette::interface::read::<$gty, S>(
                            self.src, $Props::$GC, 0, 0,
                        )
                    }
                }
            )*
        }

        /// Mutable borrowed typed view over any schema-matching
        /// [`PlaneSourceMut`](crate::marionette::interface::PlaneSourceMut).
        /// Rewrites elements in place; structural mutation (resize,
        /// jagged growth) stays with the owner.
        pub struct $ViewMut<'a, S: $crate::marionette::interface::PlaneSourceMut> {
            src: &'a mut S,
            len: usize,
        }

        #[allow(dead_code)]
        impl<'a, S: $crate::marionette::interface::PlaneSourceMut> $ViewMut<'a, S> {
            /// Attach mutably; see the immutable view's `attach`.
            pub fn attach(
                src: &'a mut S,
            ) -> Result<Self, $crate::marionette::interface::AttachError> {
                $crate::marionette::interface::check_attach(&*src, &$Props::schema())?;
                let len = $crate::marionette::interface::PlaneSource::tag_len(
                    &*src,
                    $crate::marionette::schema::TagId::ITEMS,
                );
                Ok($ViewMut { src, len })
            }

            #[inline(always)]
            pub fn len(&self) -> usize { self.len }
            pub fn is_empty(&self) -> bool { self.len == 0 }

            // ---- per-item scalars -----------------------------------

            $(
                #[inline(always)]
                pub fn $pig(&self, i: usize) -> $pity {
                    assert!(i < self.len, "view index out of bounds");
                    // SAFETY: attach checked the schema; i is bounded.
                    unsafe {
                        $crate::marionette::interface::read::<$pity, S>(
                            &*self.src, $Props::$PIC, i, 0,
                        )
                    }
                }
                #[inline(always)]
                pub fn $pis_(&mut self, i: usize, v: $pity) {
                    assert!(i < self.len, "view index out of bounds");
                    // SAFETY: as the getter, through the mutable source.
                    unsafe {
                        $crate::marionette::interface::write::<$pity, S>(
                            self.src, $Props::$PIC, i, 0, v,
                        )
                    }
                }
            )*

            // ---- arrays ---------------------------------------------

            $(
                #[inline(always)]
                pub fn $ag(&self, i: usize, k: usize) -> $aty {
                    assert!(i < self.len, "view index out of bounds");
                    assert!(k < ($aext as usize), "view lane out of extent");
                    // SAFETY: attach checked the schema; i, k bounded.
                    unsafe {
                        $crate::marionette::interface::read::<$aty, S>(
                            &*self.src, $Props::$AC, i, k,
                        )
                    }
                }
                #[inline(always)]
                pub fn $as_(&mut self, i: usize, k: usize, v: $aty) {
                    assert!(i < self.len, "view index out of bounds");
                    assert!(k < ($aext as usize), "view lane out of extent");
                    // SAFETY: as the getter, through the mutable source.
                    unsafe {
                        $crate::marionette::interface::write::<$aty, S>(
                            self.src, $Props::$AC, i, k, v,
                        )
                    }
                }
            )*

            // ---- jagged reads (in-place value rewrites only) --------

            $(
                /// Values of this item's jagged vector (read-only; the
                /// vector's *shape* belongs to the owner).
                #[inline]
                pub fn $jg(
                    &self,
                    i: usize,
                ) -> $crate::marionette::interface::SourceJagged<'_, $jty, S> {
                    assert!(i < self.len, "view index out of bounds");
                    // SAFETY: prefix tag holds len + 1 entries.
                    let lo = unsafe {
                        $crate::marionette::interface::read::<$jpty, S>(
                            &*self.src, $Props::$JC.prefix, i, 0,
                        )
                    } as usize;
                    let hi = unsafe {
                        $crate::marionette::interface::read::<$jpty, S>(
                            &*self.src, $Props::$JC.prefix, i + 1, 0,
                        )
                    } as usize;
                    $crate::marionette::interface::SourceJagged::new(
                        &*self.src, $Props::$JC.values, lo..hi,
                    )
                }
            )*

            // ---- globals --------------------------------------------

            $(
                #[inline(always)]
                pub fn $gg(&self) -> $gty {
                    // SAFETY: the Global tag always holds one record.
                    unsafe {
                        $crate::marionette::interface::read::<$gty, S>(
                            &*self.src, $Props::$GC, 0, 0,
                        )
                    }
                }
                #[inline(always)]
                pub fn $gs_(&mut self, v: $gty) {
                    // SAFETY: as the getter, through the mutable source.
                    unsafe {
                        $crate::marionette::interface::write::<$gty, S>(
                            self.src, $Props::$GC, 0, 0, v,
                        )
                    }
                }
            )*
        }

        /// The AoS record of the `Items` tag: byte-identical to what the
        /// `AoS` blob layout stores (the layout algorithm is `repr(C)`,
        /// pinned by `blob::tests::aos_matches_handwritten_repr_c`).
        #[repr(C)]
        #[derive(Clone, Copy, Debug, Default, PartialEq)]
        pub struct $Rec {
            $(pub $pig: $pity,)*
            $(pub $ag: [$aty; $aext as usize],)*
        }

        /// Split-borrowed whole-property columns (the paper's
        /// collection-level accessors, listing 3: `energy()` returns the
        /// entire column). Only layouts that store every per-item scalar
        /// densely (SoA family) can produce this view.
        /// Array properties appear as lane-major plane arrays: field
        /// `name[k]` is the dense plane of lane `k`.
        pub struct $Cols<'a> {
            $(pub $pig: &'a mut [$pity],)*
            $(pub $ag: [&'a mut [$aty]; $aext as usize],)*
        }

        #[allow(dead_code)]
        impl<L: $crate::marionette::layout::Layout> $Col<L> {
            /// Dense record view (AoS layouts): the whole `Items` tag as
            /// a `&[Record]` — exactly a handwritten `Vec<Record>` view.
            /// `None` when the layout is not record-dense.
            pub fn records(&self) -> Option<&[$Rec]> {
                let meta = $Props::FIRST_ITEM_META;
                if (meta.record_size as usize) != ::std::mem::size_of::<$Rec>() {
                    return None;
                }
                let p = self.raw.plane(meta, 0)?;
                if p.stride != ::std::mem::size_of::<$Rec>() {
                    return None;
                }
                let base = unsafe { p.base.sub(meta.aos_offset as usize) };
                Some(unsafe {
                    ::std::slice::from_raw_parts(base as *const $Rec, self.len())
                })
            }

            /// Mutable record view; see [`Self::records`].
            pub fn records_mut(&mut self) -> Option<&mut [$Rec]> {
                let meta = $Props::FIRST_ITEM_META;
                if (meta.record_size as usize) != ::std::mem::size_of::<$Rec>() {
                    return None;
                }
                let len = self.len();
                let p = self.raw.plane_mut(meta, 0)?;
                if p.stride != ::std::mem::size_of::<$Rec>() {
                    return None;
                }
                let base = unsafe { (p.base as *mut u8).sub(meta.aos_offset as usize) };
                Some(unsafe {
                    ::std::slice::from_raw_parts_mut(base as *mut $Rec, len)
                })
            }

            /// Dense column view (SoA layouts): every per-item property as
            /// a plain slice, split-borrowed simultaneously. `None` when
            /// any per-item plane is not dense.
            ///
            /// Soundness: distinct fields (and distinct lanes of an array
            /// property) occupy disjoint storage in every dense layout
            /// (separate buffers in `SoAVec`, disjoint blob regions in
            /// `SoABlob`), so handing out one `&mut` slice per plane from
            /// one `&mut self` borrow cannot alias.
            pub fn columns_mut(&mut self) -> Option<$Cols<'_>> {
                let len = self.len();
                $(
                    let $pig = self.raw.plane_mut($Props::$PIC, 0)?;
                    if $pig.stride != ::std::mem::size_of::<$pity>() {
                        return None;
                    }
                )*
                $(
                    let mut $ag: [&mut [$aty]; $aext as usize] =
                        ::std::array::from_fn(|_| Default::default());
                    for k in 0..($aext as usize) {
                        let p = self.raw.plane_mut($Props::$AC, k)?;
                        if p.stride != ::std::mem::size_of::<$aty>() {
                            return None;
                        }
                        $ag[k] = unsafe {
                            ::std::slice::from_raw_parts_mut(p.base as *mut $aty, len)
                        };
                    }
                )*
                Some($Cols {
                    $($pig: unsafe {
                        ::std::slice::from_raw_parts_mut($pig.base as *mut $pity, len)
                    },)*
                    $($ag,)*
                })
            }
        }

        /// Owned object form (paper: `Object` with an owning layout).
        #[derive(Clone, Debug, PartialEq)]
        pub struct $Obj {
            $(pub $pig: $pity,)*
            $(pub $ag: [$aty; $aext as usize],)*
            $(pub $jg: ::std::vec::Vec<$jty>,)*
        }

        impl Default for $Obj {
            fn default() -> Self {
                Self {
                    $($pig: <$pity as Default>::default(),)*
                    $($ag: [<$aty as Default>::default(); $aext as usize],)*
                    $($jg: ::std::vec::Vec::new(),)*
                }
            }
        }

        /// Immutable object proxy (paper: proxy objects into collections).
        #[derive(Clone, Copy)]
        pub struct $Ref<'a, L: $crate::marionette::layout::Layout> {
            col: &'a $Col<L>,
            i: usize,
        }

        #[allow(dead_code)]
        impl<'a, L: $crate::marionette::layout::Layout> $Ref<'a, L> {
            #[inline(always)]
            pub fn index(&self) -> usize { self.i }

            $(
                #[inline(always)]
                pub fn $pig(&self) -> $pity { self.col.$pig(self.i) }
            )*
            $(
                #[inline(always)]
                pub fn $ag(&self, k: usize) -> $aty { self.col.$ag(self.i, k) }
            )*
            $(
                #[inline]
                pub fn $jg(
                    &self,
                ) -> $crate::marionette::collection::JaggedView<'a, $jty, L> {
                    self.col.raw.jagged_view::<$jty>(
                        $Props::$JC.values, $Props::$JC.j, self.i)
                }
            )*
            $(
                /// Sub-group view (paper: sub-group properties).
                #[inline]
                pub fn $grg(&self) -> $GRV<'a, L> {
                    $GRV { col: self.col, i: self.i }
                }
            )*

            /// Materialise as an owned object.
            pub fn to_owned(&self) -> $Obj { self.col.get_owned(self.i) }
        }

        /// Mutable object proxy.
        pub struct $Mut<'a, L: $crate::marionette::layout::Layout> {
            col: &'a mut $Col<L>,
            i: usize,
        }

        #[allow(dead_code)]
        impl<'a, L: $crate::marionette::layout::Layout> $Mut<'a, L> {
            #[inline(always)]
            pub fn index(&self) -> usize { self.i }

            $(
                #[inline(always)]
                pub fn $pig(&self) -> $pity { self.col.$pig(self.i) }
                #[inline(always)]
                pub fn $pis_(&mut self, v: $pity) {
                    let i = self.i;
                    self.col.$pis_(i, v)
                }
            )*
            $(
                #[inline(always)]
                pub fn $ag(&self, k: usize) -> $aty { self.col.$ag(self.i, k) }
                #[inline(always)]
                pub fn $as_(&mut self, k: usize, v: $aty) {
                    let i = self.i;
                    self.col.$as_(i, k, v)
                }
            )*
            $(
                pub fn $js_(&mut self, vals: &[$jty]) {
                    let i = self.i;
                    self.col.$js_(i, vals)
                }
            )*
            $(
                /// Mutable sub-group view.
                #[inline]
                pub fn $grg(&mut self) -> $GRM<'_, L> {
                    $GRM { col: &mut *self.col, i: self.i }
                }
            )*
        }

        $(
            /// Immutable sub-group view.
            #[derive(Clone, Copy)]
            pub struct $GRV<'a, L: $crate::marionette::layout::Layout> {
                col: &'a $Col<L>,
                i: usize,
            }

            #[allow(dead_code)]
            impl<'a, L: $crate::marionette::layout::Layout> $GRV<'a, L> {
                $(
                    #[inline(always)]
                    pub fn $gig(&self) -> $gity { self.col.$gig(self.i) }
                )*
            }

            /// Mutable sub-group view.
            pub struct $GRM<'a, L: $crate::marionette::layout::Layout> {
                col: &'a mut $Col<L>,
                i: usize,
            }

            #[allow(dead_code)]
            impl<'a, L: $crate::marionette::layout::Layout> $GRM<'a, L> {
                $(
                    #[inline(always)]
                    pub fn $gig(&self) -> $gity { self.col.$gig(self.i) }
                    #[inline(always)]
                    pub fn $gis_(&mut self, v: $gity) {
                        let i = self.i;
                        self.col.$gis_(i, v)
                    }
                )*
            }
        )*
    };
}

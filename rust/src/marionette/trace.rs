//! Access-pattern instrumentation for the layout autotuner (DESIGN.md §9).
//!
//! A [`TraceTape`] records per-field/per-lane read and write counts plus
//! stride transitions for one *route* (e.g. sensor staging, device
//! gather, host reco). It is fed by [`super::interface::TracingSource`]
//! wrappers — attach a generated view to `col.traced(&tape)` instead of
//! `&col` and every accessor call lands on the tape via `elem_ptr`.
//! Untraced code paths never see the tape: the generated views keep
//! their cached-plane fast paths and the zero-cost guard keeps holding
//! (`tests/zero_cost_guard.rs`).
//!
//! The tape classifies each access against the previous one:
//!
//! * **field-sequential** — same field, index advanced by one: the
//!   column-wise traversal SoA-family layouts are built for;
//! * **record-coherent** — different field, same index: the whole-record
//!   traversal AoS is built for.
//!
//! [`recommend_layout`] turns the measured fractions into a
//! [`LayoutChoice`], and [`warm_staging_plan`] pre-compiles the matching
//! `TransferPlan` specialization so the chosen route pays no first-use
//! plan build.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::layout::{AoS, AoSoA, SoABlob, SoAVec};
use super::memory::HostContext;
use super::schema::{FieldId, FieldMeta, Schema};
use super::transfer::prewarm_plan;

/// Counters of one (field, lane) cell of a [`TraceTape`].
#[derive(Debug, Default)]
struct TraceCell {
    reads: AtomicU64,
    writes: AtomicU64,
    /// Accesses whose item index was exactly `last index + 1` for this
    /// cell (per-cell sequential stride).
    seq: AtomicU64,
    /// Last item index accessed through this cell, stored as `i + 1`
    /// (`0` = never accessed).
    last_idx: AtomicU64,
}

/// Per-route access tape: one cell per (field, lane), plus tape-level
/// stride classification. All counters are relaxed atomics — recording
/// is lock-free and safe from concurrent workers, at the cost of
/// transition classification being approximate under interleaving
/// (fine: the autotuner consumes aggregate fractions, not exact runs).
pub struct TraceTape {
    route: &'static str,
    schema: Arc<Schema>,
    /// First cell of each field (cumulative extents), plus total.
    lane_base: Vec<u32>,
    cells: Vec<TraceCell>,
    /// Previous access, packed as `(field_index << 32) | (i + 1)`
    /// (`0` = none).
    last_global: AtomicU64,
    accesses: AtomicU64,
    /// Same field, index advanced by one.
    field_seq: AtomicU64,
    /// Different field, same index.
    record_coherent: AtomicU64,
}

impl TraceTape {
    pub fn new(route: &'static str, schema: &Arc<Schema>) -> TraceTape {
        let mut lane_base = Vec::with_capacity(schema.num_fields() + 1);
        let mut total = 0u32;
        for m in schema.metas() {
            lane_base.push(total);
            total += m.extent.max(1);
        }
        lane_base.push(total);
        let cells = (0..total).map(|_| TraceCell::default()).collect();
        TraceTape {
            route,
            schema: schema.clone(),
            lane_base,
            cells,
            last_global: AtomicU64::new(0),
            accesses: AtomicU64::new(0),
            field_seq: AtomicU64::new(0),
            record_coherent: AtomicU64::new(0),
        }
    }

    pub fn route(&self) -> &'static str {
        self.route
    }

    pub fn schema(&self) -> &Arc<Schema> {
        &self.schema
    }

    /// Whether anything has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.accesses.load(Ordering::Relaxed) == 0
    }

    #[inline]
    fn cell(&self, meta: FieldMeta, k: usize) -> &TraceCell {
        let base = self.lane_base[meta.index as usize] as usize;
        let lanes = meta.extent.max(1) as usize;
        &self.cells[base + k.min(lanes - 1)]
    }

    #[inline]
    fn classify(&self, meta: FieldMeta, i: usize) {
        self.accesses.fetch_add(1, Ordering::Relaxed);
        let packed = ((meta.index as u64) << 32) | (i as u64 + 1);
        let prev = self.last_global.swap(packed, Ordering::Relaxed);
        if prev == 0 {
            return;
        }
        let prev_field = prev >> 32;
        let prev_idx = prev & 0xFFFF_FFFF; // i + 1
        if prev_field == meta.index as u64 && (i as u64 + 1) == prev_idx + 1 {
            self.field_seq.fetch_add(1, Ordering::Relaxed);
        } else if prev_field != meta.index as u64 && (i as u64 + 1) == prev_idx {
            self.record_coherent.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Book one element read of `meta`, item `i`, lane `k`.
    #[inline]
    pub fn record_read(&self, meta: FieldMeta, i: usize, k: usize) {
        let cell = self.cell(meta, k);
        cell.reads.fetch_add(1, Ordering::Relaxed);
        let prev = cell.last_idx.swap(i as u64 + 1, Ordering::Relaxed);
        if prev != 0 && i as u64 + 1 == prev + 1 {
            cell.seq.fetch_add(1, Ordering::Relaxed);
        }
        self.classify(meta, i);
    }

    /// Book one element write of `meta`, item `i`, lane `k`.
    #[inline]
    pub fn record_write(&self, meta: FieldMeta, i: usize, k: usize) {
        let cell = self.cell(meta, k);
        cell.writes.fetch_add(1, Ordering::Relaxed);
        let prev = cell.last_idx.swap(i as u64 + 1, Ordering::Relaxed);
        if prev != 0 && i as u64 + 1 == prev + 1 {
            cell.seq.fetch_add(1, Ordering::Relaxed);
        }
        self.classify(meta, i);
    }

    /// Clear every counter (reuse the tape for another measurement).
    pub fn reset(&self) {
        for c in &self.cells {
            c.reads.store(0, Ordering::Relaxed);
            c.writes.store(0, Ordering::Relaxed);
            c.seq.store(0, Ordering::Relaxed);
            c.last_idx.store(0, Ordering::Relaxed);
        }
        self.last_global.store(0, Ordering::Relaxed);
        self.accesses.store(0, Ordering::Relaxed);
        self.field_seq.store(0, Ordering::Relaxed);
        self.record_coherent.store(0, Ordering::Relaxed);
    }

    /// Aggregate the counters into a plain-data summary (heatmap rows +
    /// stride fractions + the recommended layout).
    pub fn snapshot(&self) -> RouteTraceSummary {
        let mut per_field = Vec::new();
        let mut total_reads = 0u64;
        let mut total_writes = 0u64;
        for m in self.schema.metas() {
            let name = self.schema.field(FieldId(m.index)).name.clone();
            let base = self.lane_base[m.index as usize] as usize;
            for k in 0..m.extent.max(1) as usize {
                let cell = &self.cells[base + k];
                let reads = cell.reads.load(Ordering::Relaxed);
                let writes = cell.writes.load(Ordering::Relaxed);
                total_reads += reads;
                total_writes += writes;
                let touched = reads + writes;
                let seq = cell.seq.load(Ordering::Relaxed);
                per_field.push(FieldTraceSummary {
                    name: if m.extent > 1 { format!("{name}[{k}]") } else { name.clone() },
                    lane: k as u32,
                    reads,
                    writes,
                    seq_fraction: if touched > 0 { seq as f64 / touched as f64 } else { 0.0 },
                });
            }
        }
        let accesses = self.accesses.load(Ordering::Relaxed).max(1);
        let mut summary = RouteTraceSummary {
            route: self.route,
            total_reads,
            total_writes,
            seq_fraction: self.field_seq.load(Ordering::Relaxed) as f64 / accesses as f64,
            record_fraction: self.record_coherent.load(Ordering::Relaxed) as f64
                / accesses as f64,
            per_field,
            choice: LayoutChoice::SoAVec,
        };
        summary.choice = recommend_layout(&summary);
        summary
    }
}

impl std::fmt::Debug for TraceTape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TraceTape({} schema={} accesses={})",
            self.route,
            self.schema.name(),
            self.accesses.load(Ordering::Relaxed)
        )
    }
}

/// Heatmap row: one (field, lane) cell of a route's tape.
#[derive(Clone, Debug, PartialEq)]
pub struct FieldTraceSummary {
    pub name: String,
    pub lane: u32,
    pub reads: u64,
    pub writes: u64,
    /// Fraction of this cell's accesses at stride exactly +1.
    pub seq_fraction: f64,
}

/// Plain-data summary of one route's measured access pattern.
#[derive(Clone, Debug, PartialEq)]
pub struct RouteTraceSummary {
    pub route: &'static str,
    pub total_reads: u64,
    pub total_writes: u64,
    /// Fraction of accesses that were field-sequential (column-wise).
    pub seq_fraction: f64,
    /// Fraction of accesses that were record-coherent (row-wise).
    pub record_fraction: f64,
    pub per_field: Vec<FieldTraceSummary>,
    /// Layout recommended from the fractions above.
    pub choice: LayoutChoice,
}

/// A staging-layout recommendation (the autotuner's decision space —
/// the four in-tree layout families with AoSoA fixed at K=8, one cache
/// line of f32 lanes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayoutChoice {
    AoS,
    SoAVec,
    SoABlob,
    AoSoA8,
}

impl LayoutChoice {
    pub fn as_str(self) -> &'static str {
        match self {
            LayoutChoice::AoS => "aos",
            LayoutChoice::SoAVec => "soavec",
            LayoutChoice::SoABlob => "soablob",
            LayoutChoice::AoSoA8 => "aosoa8",
        }
    }

    /// Inverse of [`LayoutChoice::as_str`] (CLI flag parsing).
    pub fn from_name(s: &str) -> Option<LayoutChoice> {
        Some(match s {
            "aos" => LayoutChoice::AoS,
            "soavec" => LayoutChoice::SoAVec,
            "soablob" => LayoutChoice::SoABlob,
            "aosoa8" => LayoutChoice::AoSoA8,
            _ => return None,
        })
    }
}

/// Layout-selection policy (DESIGN.md §9): whole-record traversal wants
/// records contiguous (AoS); field-sequential traversal wants planes
/// contiguous (SoA); mixed/strided traffic takes the blocked middle
/// ground (AoSoA<8>). Thresholds at 0.5 — the dominant pattern wins.
pub fn recommend_layout(s: &RouteTraceSummary) -> LayoutChoice {
    if s.record_fraction >= 0.5 {
        LayoutChoice::AoS
    } else if s.seq_fraction >= 0.5 {
        LayoutChoice::SoAVec
    } else {
        LayoutChoice::AoSoA8
    }
}

/// Pre-compile the `SoAVec → choice` staging `TransferPlan` for the
/// recommended layout so the first event on the retuned route pays no
/// plan build. Returns whether the plan was already cached.
pub fn warm_staging_plan(choice: LayoutChoice, schema: &Arc<Schema>) -> bool {
    match choice {
        LayoutChoice::AoS => prewarm_plan::<SoAVec<HostContext>, AoS<HostContext>>(schema),
        LayoutChoice::SoAVec => {
            prewarm_plan::<SoAVec<HostContext>, SoAVec<HostContext>>(schema)
        }
        LayoutChoice::SoABlob => {
            prewarm_plan::<SoAVec<HostContext>, SoABlob<HostContext>>(schema)
        }
        LayoutChoice::AoSoA8 => {
            prewarm_plan::<SoAVec<HostContext>, AoSoA<8, HostContext>>(schema)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_field_schema() -> Arc<Schema> {
        Arc::new(Schema::builder("trace-test").per_item::<f32>("a").per_item::<f32>("b").build())
    }

    fn meta_of(schema: &Arc<Schema>, name: &str) -> FieldMeta {
        let (id, _) = schema.fields().find(|(_, f)| f.name == name).unwrap();
        schema.meta(id)
    }

    #[test]
    fn column_scan_reads_as_sequential() {
        let schema = two_field_schema();
        let tape = TraceTape::new("test", &schema);
        assert!(tape.is_empty());
        let a = meta_of(&schema, "a");
        let b = meta_of(&schema, "b");
        for i in 0..100 {
            tape.record_read(a, i, 0);
        }
        for i in 0..100 {
            tape.record_read(b, i, 0);
        }
        let s = tape.snapshot();
        assert_eq!(s.total_reads, 200);
        assert!(s.seq_fraction > 0.9, "seq={}", s.seq_fraction);
        assert!(s.record_fraction < 0.1, "rec={}", s.record_fraction);
        assert_eq!(s.choice, LayoutChoice::SoAVec);
        assert_eq!(recommend_layout(&s), LayoutChoice::SoAVec);
    }

    #[test]
    fn record_scan_reads_as_coherent() {
        let schema = two_field_schema();
        let tape = TraceTape::new("test", &schema);
        let a = meta_of(&schema, "a");
        let b = meta_of(&schema, "b");
        for i in 0..100 {
            tape.record_read(a, i, 0);
            tape.record_write(b, i, 0);
        }
        let s = tape.snapshot();
        assert_eq!(s.total_reads, 100);
        assert_eq!(s.total_writes, 100);
        assert!(s.record_fraction >= 0.45, "rec={}", s.record_fraction);
        assert_eq!(s.choice, LayoutChoice::AoS);
        // Per-field rows carry the heatmap data.
        let row_a = s.per_field.iter().find(|r| r.name == "a").unwrap();
        assert_eq!((row_a.reads, row_a.writes), (100, 0));
        // Reset wipes everything.
        tape.reset();
        assert!(tape.is_empty());
        assert_eq!(tape.snapshot().total_reads, 0);
    }

    #[test]
    fn random_access_takes_blocked_middle_ground() {
        let schema = two_field_schema();
        let tape = TraceTape::new("test", &schema);
        let a = meta_of(&schema, "a");
        // Stride-7 scatter: neither field-sequential nor record-coherent.
        let mut i = 0usize;
        for _ in 0..100 {
            tape.record_read(a, i % 101, 0);
            i += 7;
        }
        let s = tape.snapshot();
        assert_eq!(s.choice, LayoutChoice::AoSoA8);
    }

    #[test]
    fn warm_staging_plan_caches_each_choice() {
        let schema = two_field_schema();
        for choice in
            [LayoutChoice::AoS, LayoutChoice::SoAVec, LayoutChoice::SoABlob, LayoutChoice::AoSoA8]
        {
            // First warm may or may not find it (other tests share the
            // process-wide cache); the second must.
            let _ = warm_staging_plan(choice, &schema);
            assert!(warm_staging_plan(choice, &schema), "{choice:?} not cached");
        }
    }
}

//! Single-blob layout holders (paper: the `DynamicStruct` layout family).
//!
//! One allocation per size tag holds all of that tag's fields; a
//! [`BlobScheme`] decides the ordering inside the blob:
//!
//! * [`AoSScheme`] — array-of-structures: element `i` of field `f` at
//!   `i * record_size + aos_offset(f)`. Identical byte layout to a
//!   handwritten `#[repr(C)]` record vector.
//! * [`SoABlobScheme`] — structure-of-arrays in one blob: each field
//!   (plane) occupies a contiguous `cap`-element region.
//! * [`AoSoAScheme<K>`] — blocked hybrid: K-element mini-SoA blocks, the
//!   classic SIMD-friendly AoSoA.
//!
//! AoS and AoSoA byte layouts do not depend on capacity, so growth is a
//! single context memcpy; SoA-blob plane bases move with capacity, so
//! growth copies plane by plane.

use std::sync::Arc;

use super::buffer::RawBuf;
use super::holder::{LayoutHolder, PlaneView};
use super::memory::MemoryContext;
use super::schema::{align_up, FieldMeta, Schema, TagId};

/// Which blob ordering a scheme implements (diagnostics / bench labels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlobLayoutKind {
    AoS,
    SoABlob,
    AoSoA(usize),
}

/// Byte-ordering strategy within a tag blob.
pub trait BlobScheme: Send + 'static {
    const KIND: BlobLayoutKind;

    /// Whether element offsets are independent of capacity (AoS, AoSoA).
    /// If true, growth relocates with one bulk copy.
    const CAP_INDEPENDENT: bool;

    /// Byte offset of element `(i, k)` of `meta`. `base` is the field's
    /// precomputed plane base (0 for capacity-independent schemes).
    fn elem_offset(meta: FieldMeta, base: usize, cap: usize, i: usize, k: usize) -> usize;

    /// Plane base offsets for every field of a tag at capacity `cap`,
    /// in tag-slot order, plus the total blob size in bytes.
    fn bases(metas: &[FieldMeta], cap: usize) -> (Vec<usize>, usize);

    /// Regular-stride view of plane `(meta, k)` if the scheme stores it
    /// regularly.
    fn plane(meta: FieldMeta, base: usize, cap: usize, k: usize) -> Option<(usize, usize)>;
}

/// Array-of-structures ordering.
pub struct AoSScheme;

impl BlobScheme for AoSScheme {
    const KIND: BlobLayoutKind = BlobLayoutKind::AoS;
    const CAP_INDEPENDENT: bool = true;

    #[inline(always)]
    fn elem_offset(meta: FieldMeta, _base: usize, _cap: usize, i: usize, k: usize) -> usize {
        i * meta.record_size as usize + meta.aos_offset as usize + k * meta.size as usize
    }

    fn bases(metas: &[FieldMeta], cap: usize) -> (Vec<usize>, usize) {
        let rec = metas.first().map_or(0, |m| m.record_size as usize);
        (vec![0; metas.len()], cap * rec)
    }

    #[inline]
    fn plane(meta: FieldMeta, _base: usize, _cap: usize, k: usize) -> Option<(usize, usize)> {
        Some((
            meta.aos_offset as usize + k * meta.size as usize,
            meta.record_size as usize,
        ))
    }
}

/// Structure-of-arrays-in-one-blob ordering.
pub struct SoABlobScheme;

impl BlobScheme for SoABlobScheme {
    const KIND: BlobLayoutKind = BlobLayoutKind::SoABlob;
    const CAP_INDEPENDENT: bool = false;

    #[inline(always)]
    fn elem_offset(meta: FieldMeta, base: usize, cap: usize, i: usize, k: usize) -> usize {
        base + (k * cap + i) * meta.size as usize
    }

    fn bases(metas: &[FieldMeta], cap: usize) -> (Vec<usize>, usize) {
        let mut bases = Vec::with_capacity(metas.len());
        let mut cursor = 0usize;
        for m in metas {
            cursor = align_up(cursor, m.align as usize);
            bases.push(cursor);
            cursor += cap * m.extent as usize * m.size as usize;
        }
        (bases, cursor)
    }

    #[inline]
    fn plane(meta: FieldMeta, base: usize, cap: usize, k: usize) -> Option<(usize, usize)> {
        Some((base + k * cap * meta.size as usize, meta.size as usize))
    }
}

/// Blocked AoSoA ordering with block size `K`.
pub struct AoSoAScheme<const K: usize>;

impl<const K: usize> BlobScheme for AoSoAScheme<K> {
    const KIND: BlobLayoutKind = BlobLayoutKind::AoSoA(K);
    const CAP_INDEPENDENT: bool = true;

    #[inline(always)]
    fn elem_offset(meta: FieldMeta, _base: usize, _cap: usize, i: usize, k: usize) -> usize {
        let block = i / K;
        let lane = i % K;
        block * K * meta.record_size as usize
            + K * meta.aos_offset as usize
            + (k * K + lane) * meta.size as usize
    }

    fn bases(metas: &[FieldMeta], cap: usize) -> (Vec<usize>, usize) {
        let rec = metas.first().map_or(0, |m| m.record_size as usize);
        let blocks = cap.div_ceil(K);
        (vec![0; metas.len()], blocks * K * rec)
    }

    #[inline]
    fn plane(_meta: FieldMeta, _base: usize, _cap: usize, _k: usize) -> Option<(usize, usize)> {
        // Lanes jump at block boundaries: no single regular stride.
        None
    }
}

/// Per-tag state of a [`BlobHolder`].
struct TagBlob<C: MemoryContext> {
    buf: RawBuf<C>,
    len: usize,
    cap: usize,
    /// Plane base per field of this tag (tag-slot order).
    bases: Vec<usize>,
    /// Metas of this tag's fields (tag-slot order), cached.
    metas: Vec<FieldMeta>,
    record_align: usize,
}

/// Blob layout holder parameterised by ordering scheme `S`.
pub struct BlobHolder<S: BlobScheme, C: MemoryContext> {
    schema: Arc<Schema>,
    info: C::Info,
    tags: Vec<TagBlob<C>>,
    /// Field index -> plane base (mirror of per-tag `bases` for O(1) use).
    field_bases: Vec<usize>,
    _s: std::marker::PhantomData<S>,
}

impl<S: BlobScheme, C: MemoryContext> BlobHolder<S, C> {
    fn refresh_field_bases(&mut self) {
        for tb in &self.tags {
            for (slot, m) in tb.metas.iter().enumerate() {
                self.field_bases[m.index as usize] = tb.bases[slot];
            }
        }
    }

    fn regrow_tag(&mut self, t: usize, new_cap: usize) {
        let tb = &mut self.tags[t];
        let (new_bases, new_bytes) = S::bases(&tb.metas, new_cap);
        let mut nb =
            RawBuf::<C>::with_capacity(new_bytes, tb.record_align.max(1), self.info.clone());
        unsafe {
            // Start from zeroed storage; growth must expose zeros.
            nb.zero_range(0, new_bytes);
        }
        if tb.len > 0 {
            if S::CAP_INDEPENDENT {
                // Identical byte layout: one bulk copy of the used prefix.
                let used = used_bytes::<S>(&tb.metas, tb.len);
                unsafe {
                    C::copy_within(&self.info, nb.as_mut_ptr(), tb.buf.as_ptr(), used);
                }
            } else {
                // Plane-by-plane relocation.
                for (slot, m) in tb.metas.iter().enumerate() {
                    for k in 0..m.extent as usize {
                        let (src_off, src_stride) =
                            S::plane(*m, tb.bases[slot], tb.cap, k).expect("regular plane");
                        let (dst_off, dst_stride) =
                            S::plane(*m, new_bases[slot], new_cap, k).expect("regular plane");
                        debug_assert_eq!(src_stride, m.size as usize);
                        debug_assert_eq!(dst_stride, m.size as usize);
                        unsafe {
                            C::copy_within(
                                &self.info,
                                nb.as_mut_ptr().add(dst_off),
                                tb.buf.as_ptr().add(src_off),
                                tb.len * m.size as usize,
                            );
                        }
                    }
                }
            }
        }
        tb.buf = nb;
        tb.cap = new_cap;
        tb.bases = new_bases;
        self.refresh_field_bases();
    }

    /// Move elements `[from, from+n)` of every field of tag `t` to
    /// position `to` (element-granular; handles any scheme).
    fn move_elems(&mut self, t: usize, from: usize, to: usize, n: usize) {
        if n == 0 || from == to {
            return;
        }
        let tb = &mut self.tags[t];
        let cap = tb.cap;
        // Iterate in an order that never overwrites unread elements.
        let forward = to < from;
        for slot in 0..tb.metas.len() {
            let m = tb.metas[slot];
            let base = tb.bases[slot];
            let esz = m.size as usize;
            for k in 0..m.extent as usize {
                for step in 0..n {
                    let j = if forward { step } else { n - 1 - step };
                    let src = S::elem_offset(m, base, cap, from + j, k);
                    let dst = S::elem_offset(m, base, cap, to + j, k);
                    unsafe {
                        let p = tb.buf.as_mut_ptr();
                        C::copy_within(&self.info, p.add(dst), p.add(src), esz);
                    }
                }
            }
        }
    }

    /// Zero elements `[at, at+n)` of every field of tag `t`.
    fn zero_elems(&mut self, t: usize, at: usize, n: usize) {
        let tb = &mut self.tags[t];
        let cap = tb.cap;
        if let BlobLayoutKind::AoS = S::KIND {
            // Whole records are contiguous: one memset.
            let rec = tb.metas.first().map_or(0, |m| m.record_size as usize);
            unsafe { tb.buf.zero_range(at * rec, n * rec) };
            return;
        }
        for slot in 0..tb.metas.len() {
            let m = tb.metas[slot];
            let base = tb.bases[slot];
            let esz = m.size as usize;
            for k in 0..m.extent as usize {
                for i in at..at + n {
                    let off = S::elem_offset(m, base, cap, i, k);
                    unsafe { tb.buf.zero_range(off, esz) };
                }
            }
        }
    }
}

/// Bytes of the used prefix for capacity-independent schemes.
fn used_bytes<S: BlobScheme>(metas: &[FieldMeta], len: usize) -> usize {
    let rec = metas.first().map_or(0, |m| m.record_size as usize);
    match S::KIND {
        BlobLayoutKind::AoS => len * rec,
        BlobLayoutKind::AoSoA(k) => len.div_ceil(k) * k * rec,
        BlobLayoutKind::SoABlob => unreachable!("SoABlob is capacity-dependent"),
    }
}

impl<S: BlobScheme, C: MemoryContext> LayoutHolder for BlobHolder<S, C> {
    type Ctx = C;

    fn new(schema: Arc<Schema>, info: C::Info) -> Self {
        let tags = schema
            .tag_layouts()
            .iter()
            .map(|tl| {
                let metas: Vec<FieldMeta> =
                    tl.fields.iter().map(|&f| schema.meta(f)).collect();
                let (bases, _) = S::bases(&metas, 0);
                TagBlob {
                    buf: RawBuf::new(tl.record_align.max(1), info.clone()),
                    len: 0,
                    cap: 0,
                    bases,
                    metas,
                    record_align: tl.record_align,
                }
            })
            .collect::<Vec<_>>();
        let mut h = BlobHolder {
            field_bases: vec![0; schema.num_fields()],
            schema,
            info,
            tags,
            _s: std::marker::PhantomData,
        };
        h.refresh_field_bases();
        h
    }

    fn schema(&self) -> &Arc<Schema> {
        &self.schema
    }

    fn info(&self) -> &C::Info {
        &self.info
    }

    fn set_info(&mut self, info: C::Info) {
        for tb in &mut self.tags {
            tb.buf.rehome(info.clone());
        }
        self.info = info;
    }

    fn tag_len(&self, tag: TagId) -> usize {
        self.tags[tag.index()].len
    }

    fn tag_capacity(&self, tag: TagId) -> usize {
        self.tags[tag.index()].cap
    }

    fn resize_tag(&mut self, tag: TagId, len: usize) {
        let t = tag.index();
        let old_len = self.tags[t].len;
        if len > self.tags[t].cap {
            let new_cap = len.max(self.tags[t].cap * 2).max(8);
            self.regrow_tag(t, new_cap);
        } else if len > old_len {
            self.zero_elems(t, old_len, len - old_len);
        }
        self.tags[t].len = len;
    }

    fn reserve_tag(&mut self, tag: TagId, cap: usize) {
        let t = tag.index();
        if cap > self.tags[t].cap {
            self.regrow_tag(t, cap);
        }
    }

    fn clear(&mut self) {
        for tb in &mut self.tags {
            tb.len = 0;
        }
    }

    fn shrink_to_fit(&mut self) {
        for t in 0..self.tags.len() {
            if self.tags[t].cap > self.tags[t].len {
                let len = self.tags[t].len;
                self.regrow_tag(t, len);
            }
        }
    }

    fn insert_gap(&mut self, tag: TagId, at: usize, n: usize) {
        let t = tag.index();
        let old_len = self.tags[t].len;
        debug_assert!(at <= old_len);
        self.resize_tag(tag, old_len + n);
        self.tags[t].len = old_len + n;
        // Shift tail right (iterate back-to-front).
        self.move_elems(t, at, at + n, old_len - at);
        self.zero_elems(t, at, n);
    }

    fn erase_range(&mut self, tag: TagId, at: usize, n: usize) {
        let t = tag.index();
        let old_len = self.tags[t].len;
        debug_assert!(at + n <= old_len);
        self.move_elems(t, at + n, at, old_len - at - n);
        self.zero_elems(t, old_len - n, n);
        self.tags[t].len = old_len - n;
    }

    #[inline(always)]
    unsafe fn elem_ptr(&self, meta: FieldMeta, i: usize, k: usize) -> *const u8 {
        let tb = self.tags.get_unchecked(meta.tag as usize);
        debug_assert!(i < tb.len);
        debug_assert!(k < meta.extent as usize);
        let base = *self.field_bases.get_unchecked(meta.index as usize);
        tb.buf.as_ptr().add(S::elem_offset(meta, base, tb.cap, i, k))
    }

    #[inline(always)]
    unsafe fn elem_ptr_mut(&mut self, meta: FieldMeta, i: usize, k: usize) -> *mut u8 {
        let base = *self.field_bases.get_unchecked(meta.index as usize);
        let tb = self.tags.get_unchecked_mut(meta.tag as usize);
        debug_assert!(i < tb.len);
        debug_assert!(k < meta.extent as usize);
        tb.buf.as_mut_ptr().add(S::elem_offset(meta, base, tb.cap, i, k))
    }

    fn plane(&self, meta: FieldMeta, k: usize) -> Option<PlaneView> {
        let tb = &self.tags[meta.tag as usize];
        let base = self.field_bases[meta.index as usize];
        S::plane(meta, base, tb.cap, k).map(|(off, stride)| PlaneView {
            base: unsafe { tb.buf.as_ptr().add(off) },
            stride,
            len: tb.len,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::holder::{read, write};
    use super::super::memory::HostContext;
    use super::*;

    fn schema() -> Arc<Schema> {
        Arc::new(
            Schema::builder("t")
                .per_item::<i32>("a")
                .per_item::<u8>("b")
                .per_item::<f64>("c")
                .array::<f32>("arr", 2)
                .build(),
        )
    }

    fn fill<H: LayoutHolder>(h: &mut H, n: usize, s: &Schema) {
        h.resize_tag(TagId::ITEMS, n);
        let ma = s.meta(s.field_by_name("a").unwrap());
        let mb = s.meta(s.field_by_name("b").unwrap());
        let mc = s.meta(s.field_by_name("c").unwrap());
        let mr = s.meta(s.field_by_name("arr").unwrap());
        for i in 0..n {
            unsafe {
                write::<i32, _>(h, ma, i, 0, i as i32);
                write::<u8, _>(h, mb, i, 0, (i % 256) as u8);
                write::<f64, _>(h, mc, i, 0, i as f64 * 0.5);
                write::<f32, _>(h, mr, i, 0, i as f32);
                write::<f32, _>(h, mr, i, 1, -(i as f32));
            }
        }
    }

    fn check<H: LayoutHolder>(h: &H, n: usize, s: &Schema) {
        let ma = s.meta(s.field_by_name("a").unwrap());
        let mb = s.meta(s.field_by_name("b").unwrap());
        let mc = s.meta(s.field_by_name("c").unwrap());
        let mr = s.meta(s.field_by_name("arr").unwrap());
        for i in 0..n {
            unsafe {
                assert_eq!(read::<i32, _>(h, ma, i, 0), i as i32);
                assert_eq!(read::<u8, _>(h, mb, i, 0), (i % 256) as u8);
                assert_eq!(read::<f64, _>(h, mc, i, 0), i as f64 * 0.5);
                assert_eq!(read::<f32, _>(h, mr, i, 0), i as f32);
                assert_eq!(read::<f32, _>(h, mr, i, 1), -(i as f32));
            }
        }
    }

    fn roundtrip<S: BlobScheme>() {
        let s = schema();
        let mut h = BlobHolder::<S, HostContext>::new(s.clone(), ());
        fill(&mut h, 100, &s);
        check(&h, 100, &s);
        // Force several regrows.
        h.resize_tag(TagId::ITEMS, 1000);
        check(&h, 100, &s);
        let ma = s.meta(s.field_by_name("a").unwrap());
        unsafe { assert_eq!(read::<i32, _>(&h, ma, 999, 0), 0) };
        h.shrink_to_fit();
        check(&h, 100, &s);
    }

    #[test]
    fn aos_roundtrip() {
        roundtrip::<AoSScheme>();
    }

    #[test]
    fn soablob_roundtrip() {
        roundtrip::<SoABlobScheme>();
    }

    #[test]
    fn aosoa_roundtrip() {
        roundtrip::<AoSoAScheme<8>>();
    }

    #[test]
    fn aos_matches_handwritten_repr_c() {
        // The AoS blob must be byte-identical to a #[repr(C)] struct vec.
        #[repr(C)]
        #[derive(Clone, Copy)]
        struct Rec {
            a: i32,
            b: u8,
            c: f64,
            arr: [f32; 2],
        }
        let s = schema();
        // Rust repr(C): a@0, b@4, c@8 (align 8), arr@16, size 24.
        let m = s.meta(s.field_by_name("c").unwrap());
        assert_eq!(m.aos_offset as usize, std::mem::offset_of!(Rec, c));
        assert_eq!(
            s.meta(s.field_by_name("arr").unwrap()).aos_offset as usize,
            std::mem::offset_of!(Rec, arr)
        );
        assert_eq!(m.record_size as usize, std::mem::size_of::<Rec>());
        let mut h = BlobHolder::<AoSScheme, HostContext>::new(s.clone(), ());
        fill(&mut h, 4, &s);
        // Read back through the handwritten struct view.
        let p = h.plane(s.meta(s.field_by_name("a").unwrap()), 0).unwrap();
        let recs = unsafe {
            std::slice::from_raw_parts(p.base as *const Rec, 4)
        };
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.a, i as i32);
            assert_eq!(r.c, i as f64 * 0.5);
            assert_eq!(r.arr, [i as f32, -(i as f32)]);
        }
    }

    #[test]
    fn insert_erase_all_schemes() {
        fn go<S: BlobScheme>() {
            let s = schema();
            let ma = s.meta(s.field_by_name("a").unwrap());
            let mut h = BlobHolder::<S, HostContext>::new(s.clone(), ());
            fill(&mut h, 10, &s);
            h.insert_gap(TagId::ITEMS, 3, 4);
            unsafe {
                assert_eq!(read::<i32, _>(&h, ma, 2, 0), 2);
                assert_eq!(read::<i32, _>(&h, ma, 3, 0), 0);
                assert_eq!(read::<i32, _>(&h, ma, 6, 0), 0);
                assert_eq!(read::<i32, _>(&h, ma, 7, 0), 3);
                assert_eq!(read::<i32, _>(&h, ma, 13, 0), 9);
            }
            h.erase_range(TagId::ITEMS, 3, 4);
            unsafe {
                for i in 0..10 {
                    assert_eq!(read::<i32, _>(&h, ma, i, 0), i as i32);
                }
            }
        }
        go::<AoSScheme>();
        go::<SoABlobScheme>();
        go::<AoSoAScheme<4>>();
    }

    #[test]
    fn soablob_planes_contiguous_aosoa_not() {
        let s = schema();
        let mr = s.meta(s.field_by_name("arr").unwrap());
        let mut h = BlobHolder::<SoABlobScheme, HostContext>::new(s.clone(), ());
        h.resize_tag(TagId::ITEMS, 10);
        let p = h.plane(mr, 1).unwrap();
        assert_eq!(p.stride, 4);
        let mut h2 = BlobHolder::<AoSoAScheme<8>, HostContext>::new(s, ());
        h2.resize_tag(TagId::ITEMS, 10);
        assert!(h2.plane(mr, 1).is_none());
    }

    #[test]
    fn aosoa_block_structure() {
        // For K=4, items 0..3 share a block; lanes of field `a` adjacent.
        let s = Arc::new(Schema::builder("t").per_item::<i32>("a").per_item::<i32>("b").build());
        let ma = s.meta(s.field_by_name("a").unwrap());
        let mb = s.meta(s.field_by_name("b").unwrap());
        let mut h = BlobHolder::<AoSoAScheme<4>, HostContext>::new(s, ());
        h.resize_tag(TagId::ITEMS, 8);
        unsafe {
            let p0 = h.elem_ptr(ma, 0, 0) as usize;
            let p1 = h.elem_ptr(ma, 1, 0) as usize;
            let b0 = h.elem_ptr(mb, 0, 0) as usize;
            let a4 = h.elem_ptr(ma, 4, 0) as usize;
            assert_eq!(p1 - p0, 4); // lanes adjacent
            assert_eq!(b0 - p0, 16); // b-lane group after 4 a-lanes
            assert_eq!(a4 - p0, 32); // next block after K*record
        }
    }
}

//! Memory contexts: where bytes live and how they are managed (paper §VII-A).
//!
//! A [`MemoryContext`] encapsulates allocate / deallocate / memset plus
//! directional copies, parameterised by a per-allocation
//! [`MemoryContext::Info`] (the paper's `ContextInfo`). Every collection
//! carries the context info of its layout's context and can swap it at
//! runtime via `update_memory_context_info` (reallocate + copy + free, as
//! the paper describes).
//!
//! Provided contexts:
//!
//! * [`HostContext`] — plain host heap; the default.
//! * [`AlignedContext`] — host heap with a minimum alignment (SIMD/page).
//! * [`ArenaContext`] — bump allocation out of a shared arena; frees are
//!   deferred to arena reset (typical per-event allocation pattern in
//!   event processing frameworks).
//! * [`CountingContext`] — host heap with full allocation/copy accounting;
//!   used by tests, metrics and the transfer benchmarks.
//! * [`StagingContext`] — the accelerator *staging* context of this
//!   reproduction: host-accessible memory whose in/out copies are counted
//!   as H2D/D2H DMA traffic. Device-resident data proper lives behind the
//!   PJRT boundary (`runtime::devmem`); staging is the pinned-buffer
//!   analogue the figures' transfer costs flow through (DESIGN.md §2).
//! * [`PoolContext<Inner>`] — a recycling memory resource: power-of-two
//!   size-class free lists over any inner context, with high-water-mark
//!   trimming and hit/miss/outstanding statistics. Buffers check
//!   themselves back in on drop (their `deallocate` routes to the pool),
//!   so steady-state workloads stop touching the inner allocator
//!   entirely (DESIGN.md §5).
//!
//! All methods are associated functions taking `&Info`, mirroring the
//! paper's static, compile-time dispatch (no `dyn` anywhere on hot paths).

use std::alloc::Layout as AllocLayout;
use std::collections::HashMap;
use std::fmt;
use std::marker::PhantomData;
use std::ptr::NonNull;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Abstraction over a way of managing memory (paper: memory context).
///
/// # Safety-relevant contract
/// `allocate(info, layout)` returns memory valid for `layout.size()` bytes
/// with `layout.align()` alignment, or a dangling pointer for zero-size
/// requests; `deallocate` must be called with the same layout.
pub trait MemoryContext: 'static {
    /// Runtime information carried by each allocation (paper: ContextInfo).
    type Info: Clone + Default + Send + Sync + fmt::Debug;

    /// Human-readable context name (diagnostics, bench labels).
    const NAME: &'static str;

    /// Whether the CPU may dereference pointers from this context
    /// directly. All in-tree contexts are host-accessible; the PJRT
    /// device residency in `runtime::devmem` is not expressed as a
    /// `MemoryContext` (it has no stable byte pointers at all).
    const HOST_ACCESSIBLE: bool = true;

    fn allocate(info: &Self::Info, layout: AllocLayout) -> NonNull<u8>;

    /// # Safety
    /// `ptr` must have been returned by `allocate` with the same `layout`.
    unsafe fn deallocate(info: &Self::Info, ptr: NonNull<u8>, layout: AllocLayout);

    /// # Safety
    /// `[ptr, ptr+len)` must be writable memory of this context.
    unsafe fn memset(info: &Self::Info, ptr: *mut u8, len: usize, value: u8) {
        let _ = info;
        std::ptr::write_bytes(ptr, value, len);
    }

    /// Copy host memory into this context ("upload").
    ///
    /// # Safety
    /// `src..src+len` readable host memory, `dst..dst+len` writable memory
    /// of this context; ranges must not overlap.
    unsafe fn copy_in(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        let _ = info;
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    /// Copy memory of this context out to host memory ("download").
    ///
    /// # Safety
    /// As `copy_in`, with directions swapped.
    unsafe fn copy_out(info: &Self::Info, src: *const u8, dst: *mut u8, len: usize) {
        let _ = info;
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    /// Copy within this context; ranges may overlap (used by the
    /// overlapping-range transfer variants that back insert/erase).
    ///
    /// # Safety
    /// Both ranges must be valid memory of this context.
    unsafe fn copy_within(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        let _ = info;
        std::ptr::copy(src, dst, len);
    }

    /// Accounting-only hook: `len` bytes of this context were read by a
    /// cross-context transfer whose byte movement was performed by the
    /// destination's `copy_in`. Default: no accounting.
    ///
    /// Accounting contract (pinned by `transfer::tests`): every
    /// cross-context transfer books exactly one read on the source side
    /// (`copy_out` *or* `note_read`) and exactly one write on the
    /// destination side (`copy_in` *or* `note_write`), whichever route
    /// the transfer takes.
    fn note_read(info: &Self::Info, len: usize) {
        let _ = (info, len);
    }

    /// Accounting-only hook, mirror of [`Self::note_read`]: `len` bytes
    /// of this context were written by a cross-context transfer whose
    /// byte movement was performed by the source's `copy_out`. Default:
    /// no accounting.
    fn note_write(info: &Self::Info, len: usize) {
        let _ = (info, len);
    }
}

fn host_alloc(layout: AllocLayout) -> NonNull<u8> {
    if layout.size() == 0 {
        // Zero-size: dangling, suitably aligned.
        return unsafe { NonNull::new_unchecked(layout.align() as *mut u8) };
    }
    let ptr = unsafe { std::alloc::alloc(layout) };
    NonNull::new(ptr).unwrap_or_else(|| std::alloc::handle_alloc_error(layout))
}

unsafe fn host_dealloc(ptr: NonNull<u8>, layout: AllocLayout) {
    if layout.size() != 0 {
        std::alloc::dealloc(ptr.as_ptr(), layout);
    }
}

/// Plain host heap. The default context of every layout.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HostContext;

impl MemoryContext for HostContext {
    type Info = ();
    const NAME: &'static str = "host";

    fn allocate(_: &(), layout: AllocLayout) -> NonNull<u8> {
        host_alloc(layout)
    }

    unsafe fn deallocate(_: &(), ptr: NonNull<u8>, layout: AllocLayout) {
        host_dealloc(ptr, layout);
    }
}

/// Host heap with a minimum alignment `A` (e.g. 64 for cache lines /
/// AVX-512, 4096 for pages). `A` must be a power of two.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlignedContext<const A: usize>;

impl<const A: usize> MemoryContext for AlignedContext<A> {
    type Info = ();
    const NAME: &'static str = "aligned";

    fn allocate(_: &(), layout: AllocLayout) -> NonNull<u8> {
        let layout = layout.align_to(A).expect("invalid alignment");
        host_alloc(layout)
    }

    unsafe fn deallocate(_: &(), ptr: NonNull<u8>, layout: AllocLayout) {
        let layout = layout.align_to(A).expect("invalid alignment");
        host_dealloc(ptr, layout);
    }
}

/// Allocation statistics shared by [`CountingContext`] allocations.
#[derive(Debug, Default)]
pub struct CountingStats {
    pub allocs: AtomicUsize,
    pub deallocs: AtomicUsize,
    pub bytes_allocated: AtomicUsize,
    /// Bytes released back (the source-side booking of every
    /// deallocation, including releases caused by `RawBuf::rehome`
    /// moving a buffer onto other context info).
    pub bytes_deallocated: AtomicUsize,
    pub bytes_copied_in: AtomicUsize,
    pub bytes_copied_out: AtomicUsize,
    pub memsets: AtomicUsize,
}

impl CountingStats {
    pub fn live_allocs(&self) -> isize {
        self.allocs.load(Ordering::Relaxed) as isize
            - self.deallocs.load(Ordering::Relaxed) as isize
    }

    /// Net bytes currently allocated (allocated − deallocated). Zero
    /// after every allocation has been released, rehomes included.
    pub fn live_bytes(&self) -> isize {
        self.bytes_allocated.load(Ordering::Relaxed) as isize
            - self.bytes_deallocated.load(Ordering::Relaxed) as isize
    }
}

/// Context info of [`CountingContext`]: a shared stats block.
#[derive(Clone, Debug, Default)]
pub struct CountingInfo(pub Arc<CountingStats>);

/// Host heap with allocation/copy accounting (tests, metrics, benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct CountingContext;

impl MemoryContext for CountingContext {
    type Info = CountingInfo;
    const NAME: &'static str = "counting";

    fn allocate(info: &CountingInfo, layout: AllocLayout) -> NonNull<u8> {
        info.0.allocs.fetch_add(1, Ordering::Relaxed);
        info.0.bytes_allocated.fetch_add(layout.size(), Ordering::Relaxed);
        host_alloc(layout)
    }

    unsafe fn deallocate(info: &CountingInfo, ptr: NonNull<u8>, layout: AllocLayout) {
        info.0.deallocs.fetch_add(1, Ordering::Relaxed);
        info.0.bytes_deallocated.fetch_add(layout.size(), Ordering::Relaxed);
        host_dealloc(ptr, layout);
    }

    unsafe fn memset(info: &CountingInfo, ptr: *mut u8, len: usize, value: u8) {
        info.0.memsets.fetch_add(1, Ordering::Relaxed);
        std::ptr::write_bytes(ptr, value, len);
    }

    unsafe fn copy_in(info: &CountingInfo, dst: *mut u8, src: *const u8, len: usize) {
        info.0.bytes_copied_in.fetch_add(len, Ordering::Relaxed);
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    unsafe fn copy_out(info: &CountingInfo, src: *const u8, dst: *mut u8, len: usize) {
        info.0.bytes_copied_out.fetch_add(len, Ordering::Relaxed);
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    fn note_read(info: &CountingInfo, len: usize) {
        info.0.bytes_copied_out.fetch_add(len, Ordering::Relaxed);
    }

    fn note_write(info: &CountingInfo, len: usize) {
        info.0.bytes_copied_in.fetch_add(len, Ordering::Relaxed);
    }
}

/// A bump arena: allocations are O(1) pointer bumps; individual frees
/// don't return memory, but they *are* booked in a live-byte ledger so
/// the arena knows when everything handed out has been released
/// (rehomes to another context included) and [`Arena::reset`] may
/// reclaim the chunks. Without the ledger, `capacity()` drifts upward
/// forever relative to what is actually in use.
#[derive(Debug, Default)]
pub struct Arena {
    chunks: Mutex<ArenaChunks>,
    /// Bytes handed out (sum of allocation sizes).
    allocated: AtomicUsize,
    /// Bytes released back (sum of deallocation sizes).
    released: AtomicUsize,
}

#[derive(Debug, Default)]
struct ArenaChunks {
    chunks: Vec<(NonNull<u8>, AllocLayout, usize)>, // (base, layout, used)
}

// SAFETY: chunk bookkeeping is protected by the mutex; handed-out pointers
// carry their own aliasing discipline (same as any allocator).
unsafe impl Send for ArenaChunks {}

const ARENA_CHUNK: usize = 1 << 20; // 1 MiB

impl Arena {
    pub fn new() -> Arc<Arena> {
        Arc::new(Arena::default())
    }

    fn bump(&self, layout: AllocLayout) -> NonNull<u8> {
        let mut g = self.chunks.lock().unwrap();
        // Booked under the chunk lock so `reset`'s live check cannot
        // race a concurrent allocation.
        self.allocated.fetch_add(layout.size(), Ordering::Relaxed);
        if let Some((base, chunk_layout, used)) = g.chunks.last_mut() {
            // Align the absolute address, not just the offset: the chunk
            // base may be less aligned than this request.
            let addr = base.as_ptr() as usize + *used;
            let off = super::schema::align_up(addr, layout.align()) - base.as_ptr() as usize;
            if off + layout.size() <= chunk_layout.size() {
                *used = off + layout.size();
                return unsafe { NonNull::new_unchecked(base.as_ptr().add(off)) };
            }
        }
        let chunk_size = ARENA_CHUNK.max(layout.size());
        let chunk_layout =
            AllocLayout::from_size_align(chunk_size, layout.align().max(16)).unwrap();
        let base = host_alloc(chunk_layout);
        g.chunks.push((base, chunk_layout, layout.size()));
        base
    }

    /// Bytes currently parked in the arena (sum of chunk sizes).
    pub fn capacity(&self) -> usize {
        self.chunks.lock().unwrap().chunks.iter().map(|(_, l, _)| l.size()).sum()
    }

    /// Book `bytes` as released without going through `deallocate`
    /// (accounting hook; byte movement already happened elsewhere).
    /// Booked under the chunk lock so [`Arena::reset`]'s live check
    /// synchronises with the releasing thread's last use of the memory
    /// — a lock-free booking would let `reset` free a chunk while the
    /// releaser's prior writes are still unordered against it.
    pub fn note_release(&self, bytes: usize) {
        let _g = self.chunks.lock().unwrap();
        self.released.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Net bytes still checked out of the arena (allocated − released).
    /// Zero once every allocation has been deallocated or rehomed away.
    pub fn live_bytes(&self) -> usize {
        self.allocated
            .load(Ordering::Relaxed)
            .saturating_sub(self.released.load(Ordering::Relaxed))
    }

    /// Free every chunk — but only when the live ledger shows nothing
    /// outstanding. Returns whether the reset happened. This is the
    /// reclamation step the release bookings exist for: after buffers
    /// rehome to another context (or drop), `live_bytes()` reaches zero
    /// and the arena's capacity can be returned to the heap.
    pub fn reset(&self) -> bool {
        let mut g = self.chunks.lock().unwrap();
        if self.live_bytes() != 0 {
            return false;
        }
        for (ptr, layout, _) in g.chunks.drain(..) {
            unsafe { host_dealloc(ptr, layout) };
        }
        true
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        let g = self.chunks.get_mut().unwrap();
        for (ptr, layout, _) in g.chunks.drain(..) {
            unsafe { host_dealloc(ptr, layout) };
        }
    }
}

/// Context info of [`ArenaContext`]: which arena to bump from.
#[derive(Clone, Debug)]
pub struct ArenaInfo(pub Arc<Arena>);

impl Default for ArenaInfo {
    fn default() -> Self {
        ArenaInfo(Arena::new())
    }
}

/// Bump allocation out of a shared [`Arena`]; deallocation is deferred.
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaContext;

impl MemoryContext for ArenaContext {
    type Info = ArenaInfo;
    const NAME: &'static str = "arena";

    fn allocate(info: &ArenaInfo, layout: AllocLayout) -> NonNull<u8> {
        if layout.size() == 0 {
            return unsafe { NonNull::new_unchecked(layout.align() as *mut u8) };
        }
        info.0.bump(layout)
    }

    unsafe fn deallocate(info: &ArenaInfo, _ptr: NonNull<u8>, layout: AllocLayout) {
        // Memory reclamation is deferred to arena drop/reset, but the
        // release IS booked so the live ledger balances (fixes the
        // capacity drift when `RawBuf::rehome` moves buffers out).
        info.0.note_release(layout.size());
    }
}

/// DMA accounting shared by [`StagingContext`] allocations.
#[derive(Debug, Default)]
pub struct TransferCounters {
    pub h2d_bytes: AtomicUsize,
    pub d2h_bytes: AtomicUsize,
    pub h2d_calls: AtomicUsize,
    pub d2h_calls: AtomicUsize,
}

/// Context info of [`StagingContext`].
#[derive(Clone, Debug, Default)]
pub struct StagingInfo {
    pub counters: Arc<TransferCounters>,
}

/// The accelerator staging context: host-accessible pinned-buffer analogue
/// whose directional copies are accounted as DMA traffic. Collections in
/// this context are what `runtime::executor` uploads to the PJRT device.
#[derive(Clone, Copy, Debug, Default)]
pub struct StagingContext;

impl MemoryContext for StagingContext {
    type Info = StagingInfo;
    const NAME: &'static str = "staging";

    fn allocate(info: &StagingInfo, layout: AllocLayout) -> NonNull<u8> {
        let _ = info;
        // Page-align staging buffers, as a pinned allocator would.
        let layout = layout.align_to(64).expect("invalid alignment");
        host_alloc(layout)
    }

    unsafe fn deallocate(_: &StagingInfo, ptr: NonNull<u8>, layout: AllocLayout) {
        let layout = layout.align_to(64).expect("invalid alignment");
        host_dealloc(ptr, layout);
    }

    unsafe fn copy_in(info: &StagingInfo, dst: *mut u8, src: *const u8, len: usize) {
        info.counters.h2d_bytes.fetch_add(len, Ordering::Relaxed);
        info.counters.h2d_calls.fetch_add(1, Ordering::Relaxed);
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    unsafe fn copy_out(info: &StagingInfo, src: *const u8, dst: *mut u8, len: usize) {
        info.counters.d2h_bytes.fetch_add(len, Ordering::Relaxed);
        info.counters.d2h_calls.fetch_add(1, Ordering::Relaxed);
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    fn note_read(info: &StagingInfo, len: usize) {
        info.counters.d2h_bytes.fetch_add(len, Ordering::Relaxed);
        info.counters.d2h_calls.fetch_add(1, Ordering::Relaxed);
    }

    fn note_write(info: &StagingInfo, len: usize) {
        info.counters.h2d_bytes.fetch_add(len, Ordering::Relaxed);
        info.counters.h2d_calls.fetch_add(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------
// PoolContext: recycling size-class pool over any inner context
// ---------------------------------------------------------------------

/// Smallest pool size class in bytes; requests round up to the next
/// power of two at or above this.
pub const POOL_MIN_CLASS: usize = 64;

/// Default idle-byte high-water mark: exceeding it on a return trims
/// the free lists back down (largest classes first).
pub const POOL_DEFAULT_HIGH_WATER: usize = 512 << 20; // 512 MiB

#[inline]
fn pool_class(bytes: usize) -> usize {
    bytes.max(POOL_MIN_CLASS).next_power_of_two()
}

/// Counters of one [`Pool`] (monotone except `outstanding`/`held_bytes`).
#[derive(Debug, Default)]
pub struct PoolStats {
    /// Allocations served from a free list (no inner allocator call).
    pub hits: AtomicUsize,
    /// Allocations that fell through to the inner context.
    pub misses: AtomicUsize,
    /// Blocks checked back in.
    pub returns: AtomicUsize,
    /// Blocks released to the inner context by high-water trimming.
    pub trims: AtomicUsize,
    /// Blocks currently checked out.
    pub outstanding: AtomicUsize,
    /// Idle bytes currently parked in the free lists.
    pub held_bytes: AtomicUsize,
}

/// Plain-data snapshot of a pool's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolSnapshot {
    pub hits: usize,
    pub misses: usize,
    pub returns: usize,
    pub trims: usize,
    pub outstanding: usize,
    pub held_bytes: usize,
}

/// Free blocks, keyed by (class bytes, alignment).
#[derive(Default)]
struct PoolShelves {
    shelves: HashMap<(usize, usize), Vec<NonNull<u8>>>,
}

// SAFETY: the shelves only park exclusively-owned blocks between a
// `deallocate` and the next `allocate`; access is mutex-guarded.
unsafe impl Send for PoolShelves {}

/// A recycling memory resource: size-class free lists over an inner
/// [`MemoryContext`]. `deallocate` parks blocks instead of freeing, so
/// a steady-state workload whose capacity classes have all been seen
/// stops calling the inner allocator entirely — the amortisation that
/// makes per-event staging allocation-free after warmup.
pub struct Pool<Inner: MemoryContext = HostContext> {
    inner: Inner::Info,
    state: Mutex<PoolShelves>,
    high_water: AtomicUsize,
    stats: PoolStats,
}

impl<Inner: MemoryContext> Pool<Inner> {
    /// Pool over explicit inner context info with the default high water.
    pub fn with_inner(inner: Inner::Info) -> Arc<Pool<Inner>> {
        Self::with_config(inner, POOL_DEFAULT_HIGH_WATER)
    }

    /// Pool with an explicit idle-byte high-water mark.
    pub fn with_config(inner: Inner::Info, high_water: usize) -> Arc<Pool<Inner>> {
        Arc::new(Pool {
            inner,
            state: Mutex::new(PoolShelves::default()),
            high_water: AtomicUsize::new(high_water),
            stats: PoolStats::default(),
        })
    }

    /// The inner context info pooled blocks are drawn from.
    pub fn inner(&self) -> &Inner::Info {
        &self.inner
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> PoolSnapshot {
        PoolSnapshot {
            hits: self.stats.hits.load(Ordering::Relaxed),
            misses: self.stats.misses.load(Ordering::Relaxed),
            returns: self.stats.returns.load(Ordering::Relaxed),
            trims: self.stats.trims.load(Ordering::Relaxed),
            outstanding: self.stats.outstanding.load(Ordering::Relaxed),
            held_bytes: self.stats.held_bytes.load(Ordering::Relaxed),
        }
    }

    /// Blocks currently checked out.
    pub fn outstanding(&self) -> usize {
        self.stats.outstanding.load(Ordering::Relaxed)
    }

    /// Idle bytes parked in the free lists.
    pub fn held_bytes(&self) -> usize {
        self.stats.held_bytes.load(Ordering::Relaxed)
    }

    /// Change the idle-byte high-water mark and trim down to it.
    pub fn set_high_water(&self, bytes: usize) {
        self.high_water.store(bytes, Ordering::Relaxed);
        self.trim_to(bytes);
    }

    /// Release idle blocks (largest classes first) until at most
    /// `target` idle bytes remain. Returns the bytes released.
    pub fn trim_to(&self, target: usize) -> usize {
        let mut g = self.state.lock().unwrap();
        self.trim_locked(&mut g, target)
    }

    /// Trim with the shelf lock already held (`held_bytes` only mutates
    /// under the lock, so it always matches the shelf contents).
    fn trim_locked(&self, g: &mut PoolShelves, target: usize) -> usize {
        let mut held = self.stats.held_bytes.load(Ordering::Relaxed);
        if held <= target {
            return 0;
        }
        let mut keys: Vec<(usize, usize)> = g.shelves.keys().copied().collect();
        keys.sort_unstable_by(|a, b| b.0.cmp(&a.0)); // largest class first
        let mut released = 0usize;
        'outer: for key in keys {
            let Some(list) = g.shelves.get_mut(&key) else { continue };
            while let Some(ptr) = list.pop() {
                let layout = AllocLayout::from_size_align(key.0, key.1)
                    .expect("pool shelf layout");
                unsafe { Inner::deallocate(&self.inner, ptr, layout) };
                self.stats.trims.fetch_add(1, Ordering::Relaxed);
                self.stats.held_bytes.fetch_sub(key.0, Ordering::Relaxed);
                released += key.0;
                held = held.saturating_sub(key.0);
                if held <= target {
                    break 'outer;
                }
            }
        }
        released
    }

    fn take(&self, layout: AllocLayout) -> NonNull<u8> {
        let class = pool_class(layout.size());
        let key = (class, layout.align());
        let recycled = {
            let mut g = self.state.lock().unwrap();
            let hit = g.shelves.get_mut(&key).and_then(|v| v.pop());
            if hit.is_some() {
                self.stats.held_bytes.fetch_sub(class, Ordering::Relaxed);
            }
            hit
        };
        let ptr = match recycled {
            Some(p) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                p
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                let inner_layout = AllocLayout::from_size_align(class, layout.align())
                    .expect("pool class layout");
                Inner::allocate(&self.inner, inner_layout)
            }
        };
        self.stats.outstanding.fetch_add(1, Ordering::Relaxed);
        ptr
    }

    /// # Safety
    /// `ptr` must come from [`Self::take`] with the same layout.
    unsafe fn put(&self, ptr: NonNull<u8>, layout: AllocLayout) {
        let class = pool_class(layout.size());
        let key = (class, layout.align());
        self.stats.outstanding.fetch_sub(1, Ordering::Relaxed);
        self.stats.returns.fetch_add(1, Ordering::Relaxed);
        let mut g = self.state.lock().unwrap();
        g.shelves.entry(key).or_default().push(ptr);
        let held = self.stats.held_bytes.fetch_add(class, Ordering::Relaxed) + class;
        let high = self.high_water.load(Ordering::Relaxed);
        if held > high {
            self.trim_locked(&mut g, high);
        }
    }
}

impl<Inner: MemoryContext> Drop for Pool<Inner> {
    fn drop(&mut self) {
        let g = self.state.get_mut().unwrap();
        for ((class, align), list) in g.shelves.drain() {
            let layout = AllocLayout::from_size_align(class, align).expect("pool layout");
            for ptr in list {
                unsafe { Inner::deallocate(&self.inner, ptr, layout) };
            }
        }
    }
}

impl<Inner: MemoryContext> fmt::Debug for Pool<Inner> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.stats();
        write!(
            f,
            "Pool<{}>(hits={} misses={} outstanding={} held={}B)",
            Inner::NAME,
            s.hits,
            s.misses,
            s.outstanding,
            s.held_bytes
        )
    }
}

/// Context info of [`PoolContext`]: which pool to draw from.
pub struct PoolInfo<Inner: MemoryContext = HostContext>(pub Arc<Pool<Inner>>);

impl<Inner: MemoryContext> Clone for PoolInfo<Inner> {
    fn clone(&self) -> Self {
        PoolInfo(self.0.clone())
    }
}

impl<Inner: MemoryContext> Default for PoolInfo<Inner> {
    fn default() -> Self {
        PoolInfo(Pool::with_inner(Inner::Info::default()))
    }
}

impl<Inner: MemoryContext> fmt::Debug for PoolInfo<Inner> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PoolInfo({:?})", self.0)
    }
}

/// Pooled, recycling allocation over an inner context. Copies, memsets
/// and accounting hooks delegate to the inner context unchanged — the
/// pool only intercepts allocate/deallocate.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolContext<Inner: MemoryContext = HostContext>(PhantomData<Inner>);

impl<Inner: MemoryContext> MemoryContext for PoolContext<Inner> {
    type Info = PoolInfo<Inner>;
    const NAME: &'static str = "pool";
    const HOST_ACCESSIBLE: bool = Inner::HOST_ACCESSIBLE;

    fn allocate(info: &Self::Info, layout: AllocLayout) -> NonNull<u8> {
        if layout.size() == 0 {
            return unsafe { NonNull::new_unchecked(layout.align() as *mut u8) };
        }
        info.0.take(layout)
    }

    unsafe fn deallocate(info: &Self::Info, ptr: NonNull<u8>, layout: AllocLayout) {
        if layout.size() == 0 {
            return;
        }
        info.0.put(ptr, layout);
    }

    unsafe fn memset(info: &Self::Info, ptr: *mut u8, len: usize, value: u8) {
        Inner::memset(&info.0.inner, ptr, len, value);
    }

    unsafe fn copy_in(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        Inner::copy_in(&info.0.inner, dst, src, len);
    }

    unsafe fn copy_out(info: &Self::Info, src: *const u8, dst: *mut u8, len: usize) {
        Inner::copy_out(&info.0.inner, src, dst, len);
    }

    unsafe fn copy_within(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        Inner::copy_within(&info.0.inner, dst, src, len);
    }

    fn note_read(info: &Self::Info, len: usize) {
        Inner::note_read(&info.0.inner, len);
    }

    fn note_write(info: &Self::Info, len: usize) {
        Inner::note_write(&info.0.inner, len);
    }
}

// ---------------------------------------------------------------------
// TracingContext: byte-level access accounting over any inner context
// ---------------------------------------------------------------------

/// Byte/call counters recorded by a [`TracingContext`] (DESIGN.md §9).
/// All counters are monotone and relaxed — the tracer observes, it never
/// synchronises.
#[derive(Debug, Default)]
pub struct CtxTraceStats {
    pub allocs: AtomicUsize,
    pub deallocs: AtomicUsize,
    pub memset_calls: AtomicUsize,
    pub memset_bytes: AtomicUsize,
    pub copy_in_calls: AtomicUsize,
    pub copy_in_bytes: AtomicUsize,
    pub copy_out_calls: AtomicUsize,
    pub copy_out_bytes: AtomicUsize,
    pub copy_within_calls: AtomicUsize,
    pub copy_within_bytes: AtomicUsize,
    pub noted_read_bytes: AtomicUsize,
    pub noted_write_bytes: AtomicUsize,
}

impl CtxTraceStats {
    /// Total bytes that moved through this context in either direction
    /// (copies + memsets; accounting-only notes excluded).
    pub fn moved_bytes(&self) -> usize {
        self.copy_in_bytes.load(Ordering::Relaxed)
            + self.copy_out_bytes.load(Ordering::Relaxed)
            + self.copy_within_bytes.load(Ordering::Relaxed)
            + self.memset_bytes.load(Ordering::Relaxed)
    }
}

/// Context info of [`TracingContext`]: the inner info plus a shared
/// trace-stats block.
pub struct TraceInfo<Inner: MemoryContext = HostContext> {
    pub inner: Inner::Info,
    pub stats: Arc<CtxTraceStats>,
}

impl<Inner: MemoryContext> Clone for TraceInfo<Inner> {
    fn clone(&self) -> Self {
        TraceInfo { inner: self.inner.clone(), stats: self.stats.clone() }
    }
}

impl<Inner: MemoryContext> Default for TraceInfo<Inner> {
    fn default() -> Self {
        TraceInfo { inner: Inner::Info::default(), stats: Arc::new(CtxTraceStats::default()) }
    }
}

impl<Inner: MemoryContext> fmt::Debug for TraceInfo<Inner> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "TraceInfo<{}>(in={}B out={}B within={}B memset={}B)",
            Inner::NAME,
            self.stats.copy_in_bytes.load(Ordering::Relaxed),
            self.stats.copy_out_bytes.load(Ordering::Relaxed),
            self.stats.copy_within_bytes.load(Ordering::Relaxed),
            self.stats.memset_bytes.load(Ordering::Relaxed),
        )
    }
}

/// Access-tracing memory context: every allocation, copy, memset and
/// accounting note is booked in a shared [`CtxTraceStats`] block, then
/// delegated to the inner context unchanged. This is the context half
/// of the autotuner's instrumentation (the view half is
/// `interface::TracingSource`): opt in by building a collection over
/// `TracingContext<Inner>`; code that doesn't is untouched — there is
/// no global flag and no cost on untraced paths (DESIGN.md §9).
#[derive(Clone, Copy, Debug, Default)]
pub struct TracingContext<Inner: MemoryContext = HostContext>(PhantomData<Inner>);

impl<Inner: MemoryContext> MemoryContext for TracingContext<Inner> {
    type Info = TraceInfo<Inner>;
    const NAME: &'static str = "tracing";
    const HOST_ACCESSIBLE: bool = Inner::HOST_ACCESSIBLE;

    fn allocate(info: &Self::Info, layout: AllocLayout) -> NonNull<u8> {
        info.stats.allocs.fetch_add(1, Ordering::Relaxed);
        Inner::allocate(&info.inner, layout)
    }

    unsafe fn deallocate(info: &Self::Info, ptr: NonNull<u8>, layout: AllocLayout) {
        info.stats.deallocs.fetch_add(1, Ordering::Relaxed);
        Inner::deallocate(&info.inner, ptr, layout);
    }

    unsafe fn memset(info: &Self::Info, ptr: *mut u8, len: usize, value: u8) {
        info.stats.memset_calls.fetch_add(1, Ordering::Relaxed);
        info.stats.memset_bytes.fetch_add(len, Ordering::Relaxed);
        Inner::memset(&info.inner, ptr, len, value);
    }

    unsafe fn copy_in(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        info.stats.copy_in_calls.fetch_add(1, Ordering::Relaxed);
        info.stats.copy_in_bytes.fetch_add(len, Ordering::Relaxed);
        Inner::copy_in(&info.inner, dst, src, len);
    }

    unsafe fn copy_out(info: &Self::Info, src: *const u8, dst: *mut u8, len: usize) {
        info.stats.copy_out_calls.fetch_add(1, Ordering::Relaxed);
        info.stats.copy_out_bytes.fetch_add(len, Ordering::Relaxed);
        Inner::copy_out(&info.inner, src, dst, len);
    }

    unsafe fn copy_within(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        info.stats.copy_within_calls.fetch_add(1, Ordering::Relaxed);
        info.stats.copy_within_bytes.fetch_add(len, Ordering::Relaxed);
        Inner::copy_within(&info.inner, dst, src, len);
    }

    fn note_read(info: &Self::Info, len: usize) {
        info.stats.noted_read_bytes.fetch_add(len, Ordering::Relaxed);
        Inner::note_read(&info.inner, len);
    }

    fn note_write(info: &Self::Info, len: usize) {
        info.stats.noted_write_bytes.fetch_add(len, Ordering::Relaxed);
        Inner::note_write(&info.inner, len);
    }
}

// ---------------------------------------------------------------------
// FaultyContext: schedule-driven allocation-fault injection
// ---------------------------------------------------------------------

/// Shared trigger of a [`FaultyContext`]: fires (panics) on every
/// `every`-th `allocate` call while armed. The trigger is a plain
/// global counter over the cell — schedule-driven, never time- or
/// race-driven — so with a fixed allocation sequence the set of fired
/// faults is deterministic (DESIGN.md §10).
///
/// The cell panics *before* delegating to the inner allocator, so a
/// fired fault never leaks inner-context state: the collection under
/// construction unwinds and drops whatever it already owned.
#[derive(Debug, Default)]
pub struct FaultCell {
    armed: AtomicBool,
    every: AtomicU64,
    count: AtomicU64,
    injected: AtomicU64,
}

impl FaultCell {
    /// A cell that never fires (injection disabled).
    pub fn disarmed() -> Arc<FaultCell> {
        Arc::new(FaultCell::default())
    }

    /// A cell armed to fire on every `every`-th allocation (0 disarms).
    pub fn armed_every(every: u64) -> Arc<FaultCell> {
        let cell = FaultCell::default();
        cell.arm(every);
        Arc::new(cell)
    }

    /// Arm (or re-arm) the cell; resets the allocation counter.
    pub fn arm(&self, every: u64) {
        self.count.store(0, Ordering::Relaxed);
        self.every.store(every, Ordering::Relaxed);
        self.armed.store(every > 0, Ordering::Relaxed);
    }

    /// Disarm without resetting the injected-fault count.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::Relaxed);
    }

    /// Number of faults this cell has fired since creation.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Count one allocation; true when the fault must fire.
    fn trip(&self) -> bool {
        if !self.armed.load(Ordering::Relaxed) {
            return false;
        }
        let every = self.every.load(Ordering::Relaxed);
        if every == 0 {
            return false;
        }
        let n = self.count.fetch_add(1, Ordering::Relaxed) + 1;
        if n % every == 0 {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }
}

/// Context info of [`FaultyContext`]: the inner info plus the shared
/// fault trigger.
pub struct FaultyInfo<Inner: MemoryContext = HostContext> {
    pub inner: Inner::Info,
    pub faults: Arc<FaultCell>,
}

impl<Inner: MemoryContext> Clone for FaultyInfo<Inner> {
    fn clone(&self) -> Self {
        FaultyInfo { inner: self.inner.clone(), faults: self.faults.clone() }
    }
}

impl<Inner: MemoryContext> Default for FaultyInfo<Inner> {
    fn default() -> Self {
        FaultyInfo { inner: Inner::Info::default(), faults: FaultCell::disarmed() }
    }
}

impl<Inner: MemoryContext> fmt::Debug for FaultyInfo<Inner> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "FaultyInfo<{}>(armed={} injected={})",
            Inner::NAME,
            self.faults.armed.load(Ordering::Relaxed),
            self.faults.injected(),
        )
    }
}

/// Fault-injecting memory context: counts `allocate` calls against a
/// shared [`FaultCell`] and panics with a recognisable message when the
/// schedule says so; everything else delegates to the inner context
/// unchanged. Disarmed, it is a transparent wrapper (one relaxed load
/// per allocation) and passes the full conformance harness. The chaos
/// pipeline stages recovered events into `FaultyContext` collections so
/// allocation faults land mid-`stage_into`, where the per-event
/// `catch_unwind` in `coordinator/pipeline.rs` must contain them
/// (DESIGN.md §10).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultyContext<Inner: MemoryContext = HostContext>(PhantomData<Inner>);

impl<Inner: MemoryContext> MemoryContext for FaultyContext<Inner> {
    type Info = FaultyInfo<Inner>;
    const NAME: &'static str = "faulty";
    const HOST_ACCESSIBLE: bool = Inner::HOST_ACCESSIBLE;

    fn allocate(info: &Self::Info, layout: AllocLayout) -> NonNull<u8> {
        if info.faults.trip() {
            panic!(
                "injected allocation fault #{} ({} bytes)",
                info.faults.injected(),
                layout.size()
            );
        }
        Inner::allocate(&info.inner, layout)
    }

    unsafe fn deallocate(info: &Self::Info, ptr: NonNull<u8>, layout: AllocLayout) {
        Inner::deallocate(&info.inner, ptr, layout);
    }

    unsafe fn memset(info: &Self::Info, ptr: *mut u8, len: usize, value: u8) {
        Inner::memset(&info.inner, ptr, len, value);
    }

    unsafe fn copy_in(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        Inner::copy_in(&info.inner, dst, src, len);
    }

    unsafe fn copy_out(info: &Self::Info, src: *const u8, dst: *mut u8, len: usize) {
        Inner::copy_out(&info.inner, src, dst, len);
    }

    unsafe fn copy_within(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        Inner::copy_within(&info.inner, dst, src, len);
    }

    fn note_read(info: &Self::Info, len: usize) {
        Inner::note_read(&info.inner, len);
    }

    fn note_write(info: &Self::Info, len: usize) {
        Inner::note_write(&info.inner, len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<C: MemoryContext>(info: &C::Info) {
        let layout = AllocLayout::from_size_align(1024, 8).unwrap();
        let ptr = C::allocate(info, layout);
        unsafe {
            C::memset(info, ptr.as_ptr(), 1024, 0xAB);
            let src: Vec<u8> = (0..=255u8).collect();
            C::copy_in(info, ptr.as_ptr(), src.as_ptr(), 256);
            let mut out = vec![0u8; 1024];
            C::copy_out(info, ptr.as_ptr(), out.as_mut_ptr(), 1024);
            assert_eq!(&out[..256], &src[..]);
            assert!(out[256..].iter().all(|&b| b == 0xAB));
            C::deallocate(info, ptr, layout);
        }
    }

    #[test]
    fn host_roundtrip() {
        roundtrip::<HostContext>(&());
    }

    #[test]
    fn aligned_returns_aligned() {
        let layout = AllocLayout::from_size_align(100, 4).unwrap();
        let ptr = AlignedContext::<4096>::allocate(&(), layout);
        assert_eq!(ptr.as_ptr() as usize % 4096, 0);
        unsafe { AlignedContext::<4096>::deallocate(&(), ptr, layout) };
        roundtrip::<AlignedContext<64>>(&());
    }

    #[test]
    fn counting_counts() {
        let info = CountingInfo::default();
        roundtrip::<CountingContext>(&info);
        assert_eq!(info.0.allocs.load(Ordering::Relaxed), 1);
        assert_eq!(info.0.deallocs.load(Ordering::Relaxed), 1);
        assert_eq!(info.0.bytes_allocated.load(Ordering::Relaxed), 1024);
        assert_eq!(info.0.bytes_copied_in.load(Ordering::Relaxed), 256);
        assert_eq!(info.0.bytes_copied_out.load(Ordering::Relaxed), 1024);
        assert_eq!(info.0.live_allocs(), 0);
    }

    #[test]
    fn arena_bump_and_reuse() {
        let info = ArenaInfo::default();
        roundtrip::<ArenaContext>(&info);
        let l8 = AllocLayout::from_size_align(8, 8).unwrap();
        let a = ArenaContext::allocate(&info, l8);
        let b = ArenaContext::allocate(&info, l8);
        // Consecutive bumps are adjacent.
        assert_eq!(b.as_ptr() as usize - a.as_ptr() as usize, 8);
        // One chunk serves both.
        assert_eq!(info.0.capacity(), ARENA_CHUNK);
        // Oversized allocations get their own chunk.
        let big = AllocLayout::from_size_align(2 * ARENA_CHUNK, 8).unwrap();
        let c = ArenaContext::allocate(&info, big);
        let _ = c; // allocation succeeded (would have aborted otherwise)
        assert_eq!(info.0.capacity(), 3 * ARENA_CHUNK);
    }

    #[test]
    fn arena_alignment_respected() {
        let info = ArenaInfo::default();
        let _ = ArenaContext::allocate(&info, AllocLayout::from_size_align(3, 1).unwrap());
        let p = ArenaContext::allocate(&info, AllocLayout::from_size_align(64, 64).unwrap());
        assert_eq!(p.as_ptr() as usize % 64, 0);
    }

    #[test]
    fn staging_accounts_dma() {
        let info = StagingInfo::default();
        roundtrip::<StagingContext>(&info);
        assert_eq!(info.counters.h2d_bytes.load(Ordering::Relaxed), 256);
        assert_eq!(info.counters.d2h_bytes.load(Ordering::Relaxed), 1024);
        assert_eq!(info.counters.h2d_calls.load(Ordering::Relaxed), 1);
        assert_eq!(info.counters.d2h_calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn zero_size_allocations_are_dangling() {
        let layout = AllocLayout::from_size_align(0, 8).unwrap();
        let p = HostContext::allocate(&(), layout);
        assert_eq!(p.as_ptr() as usize, 8);
        unsafe { HostContext::deallocate(&(), p, layout) };
    }

    #[test]
    fn arena_ledger_balances_and_resets() {
        let info = ArenaInfo::default();
        let l = AllocLayout::from_size_align(256, 8).unwrap();
        let a = ArenaContext::allocate(&info, l);
        let b = ArenaContext::allocate(&info, l);
        assert_eq!(info.0.live_bytes(), 512);
        // Live allocations block reset; capacity is retained.
        assert!(!info.0.reset());
        assert_eq!(info.0.capacity(), ARENA_CHUNK);
        unsafe {
            ArenaContext::deallocate(&info, a, l);
            ArenaContext::deallocate(&info, b, l);
        }
        assert_eq!(info.0.live_bytes(), 0);
        // Everything released: reset reclaims the chunks.
        assert!(info.0.reset());
        assert_eq!(info.0.capacity(), 0);
        // The arena is usable again after a reset.
        let c = ArenaContext::allocate(&info, l);
        unsafe { ArenaContext::deallocate(&info, c, l) };
        assert_eq!(info.0.live_bytes(), 0);
    }

    #[test]
    fn counting_books_released_bytes() {
        let info = CountingInfo::default();
        let l = AllocLayout::from_size_align(100, 8).unwrap();
        let p = CountingContext::allocate(&info, l);
        assert_eq!(info.0.live_bytes(), 100);
        unsafe { CountingContext::deallocate(&info, p, l) };
        assert_eq!(info.0.live_bytes(), 0);
        assert_eq!(info.0.bytes_deallocated.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_roundtrip_and_delegated_copies() {
        let info = PoolInfo::<CountingContext>::default();
        roundtrip::<PoolContext<CountingContext>>(&info);
        // Copies/memsets were booked on the inner context.
        let inner = info.0.inner().clone();
        assert_eq!(inner.0.bytes_copied_in.load(Ordering::Relaxed), 256);
        assert_eq!(inner.0.bytes_copied_out.load(Ordering::Relaxed), 1024);
        assert_eq!(inner.0.memsets.load(Ordering::Relaxed), 1);
        // The block was parked, not freed.
        assert_eq!(info.0.outstanding(), 0);
        assert_eq!(inner.0.live_allocs(), 1);
        assert_eq!(info.0.held_bytes(), pool_class(1024));
    }

    #[test]
    fn pool_recycles_by_size_class() {
        let info = PoolInfo::<CountingContext>::default();
        let inner = info.0.inner().clone();
        let l = AllocLayout::from_size_align(100, 8).unwrap();
        let p1 = PoolContext::<CountingContext>::allocate(&info, l);
        unsafe { PoolContext::<CountingContext>::deallocate(&info, p1, l) };
        // Any request in the same (class, align) recycles the block.
        let l2 = AllocLayout::from_size_align(128, 8).unwrap();
        let p2 = PoolContext::<CountingContext>::allocate(&info, l2);
        assert_eq!(p1.as_ptr(), p2.as_ptr());
        let s = info.0.stats();
        assert_eq!((s.hits, s.misses, s.outstanding), (1, 1, 1));
        // One inner allocation total, of the rounded class size.
        assert_eq!(inner.0.allocs.load(Ordering::Relaxed), 1);
        assert_eq!(inner.0.bytes_allocated.load(Ordering::Relaxed), 128);
        unsafe { PoolContext::<CountingContext>::deallocate(&info, p2, l2) };
        assert_eq!(info.0.outstanding(), 0);
    }

    #[test]
    fn pool_high_water_trims_idle_blocks() {
        let inner_info = CountingInfo::default();
        // High water below two parked 1 KiB-class blocks.
        let pool = Pool::<CountingContext>::with_config(inner_info.clone(), 1024);
        let info = PoolInfo(pool);
        let l = AllocLayout::from_size_align(1000, 8).unwrap();
        let a = PoolContext::<CountingContext>::allocate(&info, l);
        let b = PoolContext::<CountingContext>::allocate(&info, l);
        unsafe {
            PoolContext::<CountingContext>::deallocate(&info, a, l);
            // First return parks 1024 held bytes (at the high water).
            assert_eq!(info.0.held_bytes(), 1024);
            // Second return exceeds it and trims back down.
            PoolContext::<CountingContext>::deallocate(&info, b, l);
        }
        let s = info.0.stats();
        assert!(s.trims >= 1, "expected a trim, got {s:?}");
        assert!(info.0.held_bytes() <= 1024);
        assert_eq!(inner_info.0.deallocs.load(Ordering::Relaxed), s.trims);
        // Dropping the pool releases whatever is still parked.
        drop(info);
        assert_eq!(inner_info.0.live_allocs(), 0);
        assert_eq!(inner_info.0.live_bytes(), 0);
    }

    #[test]
    fn pool_distinguishes_alignment() {
        let info = PoolInfo::<HostContext>::default();
        let l8 = AllocLayout::from_size_align(64, 8).unwrap();
        let l64 = AllocLayout::from_size_align(64, 64).unwrap();
        let p = PoolContext::<HostContext>::allocate(&info, l8);
        unsafe { PoolContext::<HostContext>::deallocate(&info, p, l8) };
        // Same class, stricter alignment: must NOT recycle the 8-aligned
        // block.
        let q = PoolContext::<HostContext>::allocate(&info, l64);
        assert_eq!(q.as_ptr() as usize % 64, 0);
        assert_eq!(info.0.stats().hits, 0);
        unsafe { PoolContext::<HostContext>::deallocate(&info, q, l64) };
    }

    #[test]
    fn tracing_books_and_delegates() {
        let info = TraceInfo::<CountingContext>::default();
        roundtrip::<TracingContext<CountingContext>>(&info);
        // The tracer booked everything...
        assert_eq!(info.stats.allocs.load(Ordering::Relaxed), 1);
        assert_eq!(info.stats.deallocs.load(Ordering::Relaxed), 1);
        assert_eq!(info.stats.copy_in_bytes.load(Ordering::Relaxed), 256);
        assert_eq!(info.stats.copy_out_bytes.load(Ordering::Relaxed), 1024);
        assert_eq!(info.stats.memset_bytes.load(Ordering::Relaxed), 1024);
        assert_eq!(info.stats.moved_bytes(), 256 + 1024 + 1024);
        // ...and the inner context still saw identical traffic.
        assert_eq!(info.inner.0.allocs.load(Ordering::Relaxed), 1);
        assert_eq!(info.inner.0.bytes_copied_in.load(Ordering::Relaxed), 256);
        assert_eq!(info.inner.0.bytes_copied_out.load(Ordering::Relaxed), 1024);
        assert_eq!(info.inner.0.live_allocs(), 0);
        // Accounting notes pass through and are booked separately.
        TracingContext::<CountingContext>::note_read(&info, 10);
        TracingContext::<CountingContext>::note_write(&info, 20);
        assert_eq!(info.stats.noted_read_bytes.load(Ordering::Relaxed), 10);
        assert_eq!(info.stats.noted_write_bytes.load(Ordering::Relaxed), 20);
        assert_eq!(info.inner.0.bytes_copied_out.load(Ordering::Relaxed), 1034);
    }

    #[test]
    fn faulty_disarmed_is_transparent() {
        let info = FaultyInfo::<CountingContext>::default();
        roundtrip::<FaultyContext<CountingContext>>(&info);
        assert_eq!(info.faults.injected(), 0);
        assert_eq!(info.inner.0.allocs.load(Ordering::Relaxed), 1);
        assert_eq!(info.inner.0.live_allocs(), 0);
    }

    #[test]
    fn faulty_fires_on_schedule_and_leaks_no_inner_state() {
        let info =
            FaultyInfo::<CountingContext> { inner: Default::default(), faults: FaultCell::armed_every(3) };
        let layout = AllocLayout::from_size_align(64, 8).unwrap();
        // Allocations 1 and 2 succeed, 3 must panic before touching the
        // inner allocator.
        for _ in 0..2 {
            let p = FaultyContext::<CountingContext>::allocate(&info, layout);
            unsafe { FaultyContext::<CountingContext>::deallocate(&info, p, layout) };
        }
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            FaultyContext::<CountingContext>::allocate(&info, layout)
        }));
        assert!(caught.is_err(), "third allocation must fire the fault");
        assert_eq!(info.faults.injected(), 1);
        // The panic fired pre-delegation: inner booked only the two good
        // allocations and none are live.
        assert_eq!(info.inner.0.allocs.load(Ordering::Relaxed), 2);
        assert_eq!(info.inner.0.live_allocs(), 0);
        // Disarm and the same info allocates normally again.
        info.faults.disarm();
        let p = FaultyContext::<CountingContext>::allocate(&info, layout);
        unsafe { FaultyContext::<CountingContext>::deallocate(&info, p, layout) };
        assert_eq!(info.faults.injected(), 1);
    }

    #[test]
    fn pool_zero_size_skips_the_pool() {
        let info = PoolInfo::<CountingContext>::default();
        let l = AllocLayout::from_size_align(0, 16).unwrap();
        let p = PoolContext::<CountingContext>::allocate(&info, l);
        assert_eq!(p.as_ptr() as usize, 16);
        unsafe { PoolContext::<CountingContext>::deallocate(&info, p, l) };
        let s = info.0.stats();
        assert_eq!((s.hits, s.misses, s.returns, s.outstanding), (0, 0, 0, 0));
    }
}

//! Memory contexts: where bytes live and how they are managed (paper §VII-A).
//!
//! A [`MemoryContext`] encapsulates allocate / deallocate / memset plus
//! directional copies, parameterised by a per-allocation
//! [`MemoryContext::Info`] (the paper's `ContextInfo`). Every collection
//! carries the context info of its layout's context and can swap it at
//! runtime via `update_memory_context_info` (reallocate + copy + free, as
//! the paper describes).
//!
//! Provided contexts:
//!
//! * [`HostContext`] — plain host heap; the default.
//! * [`AlignedContext`] — host heap with a minimum alignment (SIMD/page).
//! * [`ArenaContext`] — bump allocation out of a shared arena; frees are
//!   deferred to arena reset (typical per-event allocation pattern in
//!   event processing frameworks).
//! * [`CountingContext`] — host heap with full allocation/copy accounting;
//!   used by tests, metrics and the transfer benchmarks.
//! * [`StagingContext`] — the accelerator *staging* context of this
//!   reproduction: host-accessible memory whose in/out copies are counted
//!   as H2D/D2H DMA traffic. Device-resident data proper lives behind the
//!   PJRT boundary (`runtime::devmem`); staging is the pinned-buffer
//!   analogue the figures' transfer costs flow through (DESIGN.md §2).
//!
//! All methods are associated functions taking `&Info`, mirroring the
//! paper's static, compile-time dispatch (no `dyn` anywhere on hot paths).

use std::alloc::Layout as AllocLayout;
use std::fmt;
use std::ptr::NonNull;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Abstraction over a way of managing memory (paper: memory context).
///
/// # Safety-relevant contract
/// `allocate(info, layout)` returns memory valid for `layout.size()` bytes
/// with `layout.align()` alignment, or a dangling pointer for zero-size
/// requests; `deallocate` must be called with the same layout.
pub trait MemoryContext: 'static {
    /// Runtime information carried by each allocation (paper: ContextInfo).
    type Info: Clone + Default + Send + Sync + fmt::Debug;

    /// Human-readable context name (diagnostics, bench labels).
    const NAME: &'static str;

    /// Whether the CPU may dereference pointers from this context
    /// directly. All in-tree contexts are host-accessible; the PJRT
    /// device residency in `runtime::devmem` is not expressed as a
    /// `MemoryContext` (it has no stable byte pointers at all).
    const HOST_ACCESSIBLE: bool = true;

    fn allocate(info: &Self::Info, layout: AllocLayout) -> NonNull<u8>;

    /// # Safety
    /// `ptr` must have been returned by `allocate` with the same `layout`.
    unsafe fn deallocate(info: &Self::Info, ptr: NonNull<u8>, layout: AllocLayout);

    /// # Safety
    /// `[ptr, ptr+len)` must be writable memory of this context.
    unsafe fn memset(info: &Self::Info, ptr: *mut u8, len: usize, value: u8) {
        let _ = info;
        std::ptr::write_bytes(ptr, value, len);
    }

    /// Copy host memory into this context ("upload").
    ///
    /// # Safety
    /// `src..src+len` readable host memory, `dst..dst+len` writable memory
    /// of this context; ranges must not overlap.
    unsafe fn copy_in(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        let _ = info;
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    /// Copy memory of this context out to host memory ("download").
    ///
    /// # Safety
    /// As `copy_in`, with directions swapped.
    unsafe fn copy_out(info: &Self::Info, src: *const u8, dst: *mut u8, len: usize) {
        let _ = info;
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    /// Copy within this context; ranges may overlap (used by the
    /// overlapping-range transfer variants that back insert/erase).
    ///
    /// # Safety
    /// Both ranges must be valid memory of this context.
    unsafe fn copy_within(info: &Self::Info, dst: *mut u8, src: *const u8, len: usize) {
        let _ = info;
        std::ptr::copy(src, dst, len);
    }

    /// Accounting-only hook: `len` bytes of this context were read by a
    /// cross-context transfer whose byte movement was performed by the
    /// destination's `copy_in`. Default: no accounting.
    ///
    /// Accounting contract (pinned by `transfer::tests`): every
    /// cross-context transfer books exactly one read on the source side
    /// (`copy_out` *or* `note_read`) and exactly one write on the
    /// destination side (`copy_in` *or* `note_write`), whichever route
    /// the transfer takes.
    fn note_read(info: &Self::Info, len: usize) {
        let _ = (info, len);
    }

    /// Accounting-only hook, mirror of [`Self::note_read`]: `len` bytes
    /// of this context were written by a cross-context transfer whose
    /// byte movement was performed by the source's `copy_out`. Default:
    /// no accounting.
    fn note_write(info: &Self::Info, len: usize) {
        let _ = (info, len);
    }
}

fn host_alloc(layout: AllocLayout) -> NonNull<u8> {
    if layout.size() == 0 {
        // Zero-size: dangling, suitably aligned.
        return unsafe { NonNull::new_unchecked(layout.align() as *mut u8) };
    }
    let ptr = unsafe { std::alloc::alloc(layout) };
    NonNull::new(ptr).unwrap_or_else(|| std::alloc::handle_alloc_error(layout))
}

unsafe fn host_dealloc(ptr: NonNull<u8>, layout: AllocLayout) {
    if layout.size() != 0 {
        std::alloc::dealloc(ptr.as_ptr(), layout);
    }
}

/// Plain host heap. The default context of every layout.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HostContext;

impl MemoryContext for HostContext {
    type Info = ();
    const NAME: &'static str = "host";

    fn allocate(_: &(), layout: AllocLayout) -> NonNull<u8> {
        host_alloc(layout)
    }

    unsafe fn deallocate(_: &(), ptr: NonNull<u8>, layout: AllocLayout) {
        host_dealloc(ptr, layout);
    }
}

/// Host heap with a minimum alignment `A` (e.g. 64 for cache lines /
/// AVX-512, 4096 for pages). `A` must be a power of two.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlignedContext<const A: usize>;

impl<const A: usize> MemoryContext for AlignedContext<A> {
    type Info = ();
    const NAME: &'static str = "aligned";

    fn allocate(_: &(), layout: AllocLayout) -> NonNull<u8> {
        let layout = layout.align_to(A).expect("invalid alignment");
        host_alloc(layout)
    }

    unsafe fn deallocate(_: &(), ptr: NonNull<u8>, layout: AllocLayout) {
        let layout = layout.align_to(A).expect("invalid alignment");
        host_dealloc(ptr, layout);
    }
}

/// Allocation statistics shared by [`CountingContext`] allocations.
#[derive(Debug, Default)]
pub struct CountingStats {
    pub allocs: AtomicUsize,
    pub deallocs: AtomicUsize,
    pub bytes_allocated: AtomicUsize,
    pub bytes_copied_in: AtomicUsize,
    pub bytes_copied_out: AtomicUsize,
    pub memsets: AtomicUsize,
}

impl CountingStats {
    pub fn live_allocs(&self) -> isize {
        self.allocs.load(Ordering::Relaxed) as isize
            - self.deallocs.load(Ordering::Relaxed) as isize
    }
}

/// Context info of [`CountingContext`]: a shared stats block.
#[derive(Clone, Debug, Default)]
pub struct CountingInfo(pub Arc<CountingStats>);

/// Host heap with allocation/copy accounting (tests, metrics, benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct CountingContext;

impl MemoryContext for CountingContext {
    type Info = CountingInfo;
    const NAME: &'static str = "counting";

    fn allocate(info: &CountingInfo, layout: AllocLayout) -> NonNull<u8> {
        info.0.allocs.fetch_add(1, Ordering::Relaxed);
        info.0.bytes_allocated.fetch_add(layout.size(), Ordering::Relaxed);
        host_alloc(layout)
    }

    unsafe fn deallocate(info: &CountingInfo, ptr: NonNull<u8>, layout: AllocLayout) {
        info.0.deallocs.fetch_add(1, Ordering::Relaxed);
        host_dealloc(ptr, layout);
    }

    unsafe fn memset(info: &CountingInfo, ptr: *mut u8, len: usize, value: u8) {
        info.0.memsets.fetch_add(1, Ordering::Relaxed);
        std::ptr::write_bytes(ptr, value, len);
    }

    unsafe fn copy_in(info: &CountingInfo, dst: *mut u8, src: *const u8, len: usize) {
        info.0.bytes_copied_in.fetch_add(len, Ordering::Relaxed);
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    unsafe fn copy_out(info: &CountingInfo, src: *const u8, dst: *mut u8, len: usize) {
        info.0.bytes_copied_out.fetch_add(len, Ordering::Relaxed);
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    fn note_read(info: &CountingInfo, len: usize) {
        info.0.bytes_copied_out.fetch_add(len, Ordering::Relaxed);
    }

    fn note_write(info: &CountingInfo, len: usize) {
        info.0.bytes_copied_in.fetch_add(len, Ordering::Relaxed);
    }
}

/// A bump arena: allocations are O(1) pointer bumps; individual frees are
/// no-ops; all memory is released when the arena is dropped (or `reset`).
#[derive(Debug, Default)]
pub struct Arena {
    chunks: Mutex<ArenaChunks>,
}

#[derive(Debug, Default)]
struct ArenaChunks {
    chunks: Vec<(NonNull<u8>, AllocLayout, usize)>, // (base, layout, used)
}

// SAFETY: chunk bookkeeping is protected by the mutex; handed-out pointers
// carry their own aliasing discipline (same as any allocator).
unsafe impl Send for ArenaChunks {}

const ARENA_CHUNK: usize = 1 << 20; // 1 MiB

impl Arena {
    pub fn new() -> Arc<Arena> {
        Arc::new(Arena::default())
    }

    fn bump(&self, layout: AllocLayout) -> NonNull<u8> {
        let mut g = self.chunks.lock().unwrap();
        if let Some((base, chunk_layout, used)) = g.chunks.last_mut() {
            // Align the absolute address, not just the offset: the chunk
            // base may be less aligned than this request.
            let addr = base.as_ptr() as usize + *used;
            let off = super::schema::align_up(addr, layout.align()) - base.as_ptr() as usize;
            if off + layout.size() <= chunk_layout.size() {
                *used = off + layout.size();
                return unsafe { NonNull::new_unchecked(base.as_ptr().add(off)) };
            }
        }
        let chunk_size = ARENA_CHUNK.max(layout.size());
        let chunk_layout =
            AllocLayout::from_size_align(chunk_size, layout.align().max(16)).unwrap();
        let base = host_alloc(chunk_layout);
        g.chunks.push((base, chunk_layout, layout.size()));
        base
    }

    /// Bytes currently parked in the arena (sum of chunk sizes).
    pub fn capacity(&self) -> usize {
        self.chunks.lock().unwrap().chunks.iter().map(|(_, l, _)| l.size()).sum()
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        let g = self.chunks.get_mut().unwrap();
        for (ptr, layout, _) in g.chunks.drain(..) {
            unsafe { host_dealloc(ptr, layout) };
        }
    }
}

/// Context info of [`ArenaContext`]: which arena to bump from.
#[derive(Clone, Debug)]
pub struct ArenaInfo(pub Arc<Arena>);

impl Default for ArenaInfo {
    fn default() -> Self {
        ArenaInfo(Arena::new())
    }
}

/// Bump allocation out of a shared [`Arena`]; deallocation is deferred.
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaContext;

impl MemoryContext for ArenaContext {
    type Info = ArenaInfo;
    const NAME: &'static str = "arena";

    fn allocate(info: &ArenaInfo, layout: AllocLayout) -> NonNull<u8> {
        if layout.size() == 0 {
            return unsafe { NonNull::new_unchecked(layout.align() as *mut u8) };
        }
        info.0.bump(layout)
    }

    unsafe fn deallocate(_: &ArenaInfo, _ptr: NonNull<u8>, _layout: AllocLayout) {
        // Deferred to arena drop/reset.
    }
}

/// DMA accounting shared by [`StagingContext`] allocations.
#[derive(Debug, Default)]
pub struct TransferCounters {
    pub h2d_bytes: AtomicUsize,
    pub d2h_bytes: AtomicUsize,
    pub h2d_calls: AtomicUsize,
    pub d2h_calls: AtomicUsize,
}

/// Context info of [`StagingContext`].
#[derive(Clone, Debug, Default)]
pub struct StagingInfo {
    pub counters: Arc<TransferCounters>,
}

/// The accelerator staging context: host-accessible pinned-buffer analogue
/// whose directional copies are accounted as DMA traffic. Collections in
/// this context are what `runtime::executor` uploads to the PJRT device.
#[derive(Clone, Copy, Debug, Default)]
pub struct StagingContext;

impl MemoryContext for StagingContext {
    type Info = StagingInfo;
    const NAME: &'static str = "staging";

    fn allocate(info: &StagingInfo, layout: AllocLayout) -> NonNull<u8> {
        let _ = info;
        // Page-align staging buffers, as a pinned allocator would.
        let layout = layout.align_to(64).expect("invalid alignment");
        host_alloc(layout)
    }

    unsafe fn deallocate(_: &StagingInfo, ptr: NonNull<u8>, layout: AllocLayout) {
        let layout = layout.align_to(64).expect("invalid alignment");
        host_dealloc(ptr, layout);
    }

    unsafe fn copy_in(info: &StagingInfo, dst: *mut u8, src: *const u8, len: usize) {
        info.counters.h2d_bytes.fetch_add(len, Ordering::Relaxed);
        info.counters.h2d_calls.fetch_add(1, Ordering::Relaxed);
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    unsafe fn copy_out(info: &StagingInfo, src: *const u8, dst: *mut u8, len: usize) {
        info.counters.d2h_bytes.fetch_add(len, Ordering::Relaxed);
        info.counters.d2h_calls.fetch_add(1, Ordering::Relaxed);
        std::ptr::copy_nonoverlapping(src, dst, len);
    }

    fn note_read(info: &StagingInfo, len: usize) {
        info.counters.d2h_bytes.fetch_add(len, Ordering::Relaxed);
        info.counters.d2h_calls.fetch_add(1, Ordering::Relaxed);
    }

    fn note_write(info: &StagingInfo, len: usize) {
        info.counters.h2d_bytes.fetch_add(len, Ordering::Relaxed);
        info.counters.h2d_calls.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<C: MemoryContext>(info: &C::Info) {
        let layout = AllocLayout::from_size_align(1024, 8).unwrap();
        let ptr = C::allocate(info, layout);
        unsafe {
            C::memset(info, ptr.as_ptr(), 1024, 0xAB);
            let src: Vec<u8> = (0..=255u8).collect();
            C::copy_in(info, ptr.as_ptr(), src.as_ptr(), 256);
            let mut out = vec![0u8; 1024];
            C::copy_out(info, ptr.as_ptr(), out.as_mut_ptr(), 1024);
            assert_eq!(&out[..256], &src[..]);
            assert!(out[256..].iter().all(|&b| b == 0xAB));
            C::deallocate(info, ptr, layout);
        }
    }

    #[test]
    fn host_roundtrip() {
        roundtrip::<HostContext>(&());
    }

    #[test]
    fn aligned_returns_aligned() {
        let layout = AllocLayout::from_size_align(100, 4).unwrap();
        let ptr = AlignedContext::<4096>::allocate(&(), layout);
        assert_eq!(ptr.as_ptr() as usize % 4096, 0);
        unsafe { AlignedContext::<4096>::deallocate(&(), ptr, layout) };
        roundtrip::<AlignedContext<64>>(&());
    }

    #[test]
    fn counting_counts() {
        let info = CountingInfo::default();
        roundtrip::<CountingContext>(&info);
        assert_eq!(info.0.allocs.load(Ordering::Relaxed), 1);
        assert_eq!(info.0.deallocs.load(Ordering::Relaxed), 1);
        assert_eq!(info.0.bytes_allocated.load(Ordering::Relaxed), 1024);
        assert_eq!(info.0.bytes_copied_in.load(Ordering::Relaxed), 256);
        assert_eq!(info.0.bytes_copied_out.load(Ordering::Relaxed), 1024);
        assert_eq!(info.0.live_allocs(), 0);
    }

    #[test]
    fn arena_bump_and_reuse() {
        let info = ArenaInfo::default();
        roundtrip::<ArenaContext>(&info);
        let l8 = AllocLayout::from_size_align(8, 8).unwrap();
        let a = ArenaContext::allocate(&info, l8);
        let b = ArenaContext::allocate(&info, l8);
        // Consecutive bumps are adjacent.
        assert_eq!(b.as_ptr() as usize - a.as_ptr() as usize, 8);
        // One chunk serves both.
        assert_eq!(info.0.capacity(), ARENA_CHUNK);
        // Oversized allocations get their own chunk.
        let big = AllocLayout::from_size_align(2 * ARENA_CHUNK, 8).unwrap();
        let c = ArenaContext::allocate(&info, big);
        let _ = c; // allocation succeeded (would have aborted otherwise)
        assert_eq!(info.0.capacity(), 3 * ARENA_CHUNK);
    }

    #[test]
    fn arena_alignment_respected() {
        let info = ArenaInfo::default();
        let _ = ArenaContext::allocate(&info, AllocLayout::from_size_align(3, 1).unwrap());
        let p = ArenaContext::allocate(&info, AllocLayout::from_size_align(64, 64).unwrap());
        assert_eq!(p.as_ptr() as usize % 64, 0);
    }

    #[test]
    fn staging_accounts_dma() {
        let info = StagingInfo::default();
        roundtrip::<StagingContext>(&info);
        assert_eq!(info.counters.h2d_bytes.load(Ordering::Relaxed), 256);
        assert_eq!(info.counters.d2h_bytes.load(Ordering::Relaxed), 1024);
        assert_eq!(info.counters.h2d_calls.load(Ordering::Relaxed), 1);
        assert_eq!(info.counters.d2h_calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn zero_size_allocations_are_dangling() {
        let layout = AllocLayout::from_size_align(0, 8).unwrap();
        let p = HostContext::allocate(&(), layout);
        assert_eq!(p.as_ptr() as usize, 8);
        unsafe { HostContext::deallocate(&(), p, layout) };
    }
}

//! Transfers between collections, layouts and memory contexts (paper
//! §VII-A/§VII-B).
//!
//! [`copy_collection`] copies a source collection into a destination with
//! the *same schema* but possibly different layout and/or context, walking
//! a priority ladder (the paper's `TransferSpecification` /
//! `TransferPriority` mechanism):
//!
//! 1. [`TransferPriority::Specialized`] — a user-registered fast path for
//!    a concrete (src, dst) pair (e.g. the EDM's handwritten-AoS → staging
//!    SoA converter). Implemented at the typed-collection level; the
//!    generic ladder starts below.
//! 2. `Plane` — both layouts expose a dense plane for a field: one
//!    `memcopy_with_context` per plane.
//! 3. `Strided` — both expose regular strides: strided copy loop.
//! 4. `Elementwise` — fully general fallback via `elem_ptr`.
//!
//! `memcopy_with_context` and the overlapping-range variants are the free
//! functions the paper describes for raw context-to-context byte movement.

use super::collection::RawCollection;
use super::holder::LayoutHolder;
use super::layout::Layout;
use super::memory::MemoryContext;
use super::schema::TagId;

/// Which rung of the ladder a transfer used (reported for tests/benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TransferPriority {
    Specialized,
    Plane,
    Strided,
    Elementwise,
}

/// Copy `len` bytes from `src` (in context `Src`) to `dst` (in context
/// `Dst`). The copy is routed host-side: `Src::copy_out` then
/// `Dst::copy_in` collapse to a single `memcpy` when both contexts are
/// host-accessible and at most one needs accounting.
///
/// # Safety
/// `src`/`dst` must be valid for `len` bytes in their contexts and must
/// not overlap.
pub unsafe fn memcopy_with_context<Src: MemoryContext, Dst: MemoryContext>(
    src_info: &Src::Info,
    src: *const u8,
    dst_info: &Dst::Info,
    dst: *mut u8,
    len: usize,
) {
    // Both in-tree context families are host-accessible; the general
    // device route (out to a host bounce buffer, then in) is only needed
    // when either side refuses direct access.
    if Src::HOST_ACCESSIBLE {
        Dst::copy_in(dst_info, dst, src, len);
        Src::note_read(src_info, len); // accounting only, no byte movement
    } else if Dst::HOST_ACCESSIBLE {
        Src::copy_out(src_info, src, dst, len);
    } else {
        let mut bounce = vec![0u8; len];
        Src::copy_out(src_info, src, bounce.as_mut_ptr(), len);
        Dst::copy_in(dst_info, dst, bounce.as_ptr(), len);
    }
}

/// Overlap-tolerant copy within one context: safe for a destination range
/// that overlaps the source to the *left* (shift-left, used by erase).
///
/// # Safety
/// Both ranges valid in the context.
pub unsafe fn memmove_left_with_context<C: MemoryContext>(
    info: &C::Info,
    dst: *mut u8,
    src: *const u8,
    len: usize,
) {
    debug_assert!((dst as usize) <= (src as usize));
    C::copy_within(info, dst, src, len);
}

/// Overlap-tolerant copy within one context: safe for a destination range
/// that overlaps the source to the *right* (shift-right, used by insert).
///
/// # Safety
/// Both ranges valid in the context.
pub unsafe fn memmove_right_with_context<C: MemoryContext>(
    info: &C::Info,
    dst: *mut u8,
    src: *const u8,
    len: usize,
) {
    debug_assert!((dst as usize) >= (src as usize));
    C::copy_within(info, dst, src, len);
}

/// Copy every property of `src` into `dst` (same schema structure
/// required; layouts and contexts may differ). `dst` is resized to match.
/// Returns the *lowest* rung the transfer had to descend to.
pub fn copy_collection<LS: Layout, LD: Layout>(
    src: &RawCollection<LS>,
    dst: &mut RawCollection<LD>,
) -> TransferPriority {
    assert!(
        src.schema().same_structure(dst.schema()),
        "transfer requires structurally equal schemas ({} vs {})",
        src.schema().name(),
        dst.schema().name(),
    );

    // Size the destination: drop any previous content (and its jagged
    // values), then match the item count and each values-tag length; the
    // raw field copy below replicates the actual prefix sums.
    dst.resize(0);
    dst.resize(src.len());
    if dst.len() > 0 {
        let last = dst.len() - 1;
        for j in 0..src.num_jagged() as u32 {
            let n = src.values_len(j);
            if n > 0 {
                dst.set_jagged_count(j, last, n);
            }
        }
    }

    let schema = src.schema().clone();
    let sinfo = src.context_info().clone();
    let dinfo = dst.context_info().clone();
    let mut worst = TransferPriority::Plane;
    for (fid, _field) in schema.fields() {
        let meta = schema.meta(fid);
        let tag = meta.tag_id();
        let len = match tag {
            TagId::GLOBAL => 1,
            t if t == TagId::ITEMS => src.len(),
            t if t == TagId::ITEMS_PLUS_ONE => src.len() + 1,
            t => src.values_len(t.0 - 3),
        };
        for k in 0..meta.extent as usize {
            let esz = meta.size as usize;
            let sp = src.plane(meta, k);
            let dp = dst.plane_mut(meta, k);
            match (sp, dp) {
                (Some(s), Some(d)) if s.stride == esz && d.stride == esz => {
                    // Dense <-> dense: single context copy per plane.
                    unsafe {
                        memcopy_with_context::<LS::Ctx, LD::Ctx>(
                            &sinfo,
                            s.base,
                            &dinfo,
                            d.base as *mut u8,
                            len * esz,
                        );
                    }
                }
                (Some(s), Some(d)) => {
                    // Regular strides: strided copy loop.
                    worst = worst.max(TransferPriority::Strided);
                    unsafe {
                        for i in 0..len {
                            memcopy_with_context::<LS::Ctx, LD::Ctx>(
                                &sinfo,
                                s.base.add(i * s.stride),
                                &dinfo,
                                (d.base as *mut u8).add(i * d.stride),
                                esz,
                            );
                        }
                    }
                }
                _ => {
                    // Irregular (AoSoA planes): element-wise.
                    worst = worst.max(TransferPriority::Elementwise);
                    for i in 0..len {
                        unsafe {
                            let s = src.holder().elem_ptr(meta, i, k);
                            let d = dst.holder_mut().elem_ptr_mut(meta, i, k);
                            memcopy_with_context::<LS::Ctx, LD::Ctx>(
                                &sinfo, s, &dinfo, d, esz,
                            );
                        }
                    }
                }
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::super::layout::{AoS, AoSoA, SoABlob, SoAVec};
    use super::super::memory::{CountingContext, CountingInfo, StagingContext, StagingInfo};
    use super::super::schema::Schema;
    use super::*;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    fn schema() -> Arc<Schema> {
        Arc::new(
            Schema::builder("s")
                .per_item::<f32>("e")
                .per_item::<i32>("t")
                .array::<f32>("sig", 2)
                .jagged::<u64, u32>("cells")
                .global::<u64>("ev")
                .build(),
        )
    }

    fn build_src<L: Layout>() -> RawCollection<L>
    where
        <L::Ctx as MemoryContext>::Info: Default,
    {
        let s = schema();
        let m_e = s.meta(s.field_by_name("e").unwrap());
        let m_t = s.meta(s.field_by_name("t").unwrap());
        let m_sig = s.meta(s.field_by_name("sig").unwrap());
        let m_cells = s.meta(s.field_by_name("cells").unwrap());
        let m_ev = s.meta(s.field_by_name("ev").unwrap());
        let mut c = RawCollection::<L>::new(s);
        c.set_global::<u64>(m_ev, 7);
        for i in 0..5 {
            c.push_default();
            c.set::<f32>(m_e, i, i as f32 * 1.5);
            c.set::<i32>(m_t, i, i as i32 - 2);
            c.set_k::<f32>(m_sig, i, 0, i as f32);
            c.set_k::<f32>(m_sig, i, 1, -(i as f32));
            let v0 = c.append_values(0, i % 3);
            for n in 0..(i % 3) {
                c.set_value::<u64>(m_cells, v0 + n, (i * 10 + n) as u64);
            }
        }
        c
    }

    fn check_equal<LA: Layout, LB: Layout>(a: &RawCollection<LA>, b: &RawCollection<LB>) {
        let s = a.schema();
        let m_e = s.meta(s.field_by_name("e").unwrap());
        let m_t = s.meta(s.field_by_name("t").unwrap());
        let m_sig = s.meta(s.field_by_name("sig").unwrap());
        let m_cells = s.meta(s.field_by_name("cells").unwrap());
        let m_ev = s.meta(s.field_by_name("ev").unwrap());
        assert_eq!(a.len(), b.len());
        assert_eq!(a.get_global::<u64>(m_ev), b.get_global::<u64>(m_ev));
        for i in 0..a.len() {
            assert_eq!(a.get::<f32>(m_e, i), b.get::<f32>(m_e, i));
            assert_eq!(a.get::<i32>(m_t, i), b.get::<i32>(m_t, i));
            for k in 0..2 {
                assert_eq!(a.get_k::<f32>(m_sig, i, k), b.get_k::<f32>(m_sig, i, k));
            }
            assert_eq!(
                a.jagged_view::<u64>(m_cells, 0, i).to_vec(),
                b.jagged_view::<u64>(m_cells, 0, i).to_vec()
            );
        }
    }

    #[test]
    fn soavec_to_aos_and_back() {
        let src = build_src::<SoAVec>();
        let mut aos = RawCollection::<AoS>::new(src.schema().clone());
        let p = copy_collection(&src, &mut aos);
        check_equal(&src, &aos);
        assert!(p <= TransferPriority::Strided);
        let mut back = RawCollection::<SoAVec>::new(src.schema().clone());
        copy_collection(&aos, &mut back);
        check_equal(&src, &back);
    }

    #[test]
    fn all_layout_pairs_roundtrip() {
        let src = build_src::<SoAVec>();
        macro_rules! pair {
            ($mid:ty) => {{
                let mut mid = RawCollection::<$mid>::new(src.schema().clone());
                copy_collection(&src, &mut mid);
                let mut back = RawCollection::<SoAVec>::new(src.schema().clone());
                copy_collection(&mid, &mut back);
                check_equal(&src, &back);
            }};
        }
        pair!(AoS);
        pair!(SoABlob);
        pair!(AoSoA<4>);
        pair!(AoSoA<16>);
    }

    #[test]
    fn aosoa_is_elementwise() {
        let src = build_src::<SoAVec>();
        let mut dst = RawCollection::<AoSoA<8>>::new(src.schema().clone());
        let p = copy_collection(&src, &mut dst);
        assert_eq!(p, TransferPriority::Elementwise);
    }

    #[test]
    fn soavec_pair_is_plane() {
        let src = build_src::<SoAVec>();
        let mut dst = RawCollection::<SoAVec>::new(src.schema().clone());
        let p = copy_collection(&src, &mut dst);
        assert_eq!(p, TransferPriority::Plane);
    }

    #[test]
    fn cross_context_accounts_dma() {
        let src = build_src::<SoAVec>();
        let info = StagingInfo::default();
        let mut dst = RawCollection::<SoAVec<StagingContext>>::new_in(
            src.schema().clone(),
            info.clone(),
        );
        copy_collection(&src, &mut dst);
        check_equal(&src, &dst);
        // Every plane upload is H2D traffic.
        assert!(info.counters.h2d_bytes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn counting_context_observes_copy_out() {
        let s = schema();
        let m_e = s.meta(s.field_by_name("e").unwrap());
        let info = CountingInfo::default();
        let mut src =
            RawCollection::<SoAVec<CountingContext>>::new_in(s.clone(), info.clone());
        src.resize(4);
        src.set::<f32>(m_e, 2, 5.0);
        let mut dst = RawCollection::<SoAVec>::new(s);
        copy_collection(&src, &mut dst);
        assert_eq!(dst.get::<f32>(m_e, 2), 5.0);
    }

    #[test]
    #[should_panic(expected = "structurally equal")]
    fn schema_mismatch_rejected() {
        let a = build_src::<SoAVec>();
        let other = Arc::new(Schema::builder("x").per_item::<f32>("y").build());
        let mut b = RawCollection::<SoAVec>::new(other);
        copy_collection(&a, &mut b);
    }

    #[test]
    fn raw_memcopy_between_contexts() {
        let staging = StagingInfo::default();
        let src: Vec<u8> = (0..100).collect();
        let mut dst = vec![0u8; 100];
        unsafe {
            memcopy_with_context::<super::super::memory::HostContext, StagingContext>(
                &(),
                src.as_ptr(),
                &staging,
                dst.as_mut_ptr(),
                100,
            );
        }
        assert_eq!(src, dst);
        assert_eq!(staging.counters.h2d_bytes.load(Ordering::Relaxed), 100);
    }
}

//! Transfers between collections, layouts and memory contexts (paper
//! §VII-A/§VII-B) — compiled once, executed many times.
//!
//! The paper's `TransferSpecification` / `TransferPriority` mechanism
//! resolves the copy strategy for a (source, destination) pair at
//! *compile time*, so repeated transfers cost no more than handwritten
//! memcpys. This module mirrors that with a **plan/execute** split:
//!
//! * [`TransferPlan`] — compiled once per (schema, src layout, src
//!   context, dst layout, dst context) tuple from the layouts' *static*
//!   geometry ([`Layout::plane_shape`], [`Layout::BLOB_IDENTITY`]).
//!   Compilation resolves every field to its ladder rung, **coalesces
//!   byte-adjacent planes of identically-stored tags into single
//!   whole-tag block copies**, and records symbolic lengths resolved at
//!   execution time.
//! * [`plan_for`] — the keyed plan cache: the first request compiles,
//!   every later request is a hash lookup ([`plan_cache_stats`] exposes
//!   hit/miss counters; the pipeline asserts steady-state hits).
//! * [`TransferPlan::execute`] — runs the op list against concrete
//!   collections; [`TransferPlan::execute_par`] additionally splits
//!   large contiguous copies into chunks across the in-tree
//!   [`ThreadPool`].
//! * [`register_specialized`] — registers a user fast path for a
//!   concrete (schema, layouts, contexts) tuple as the `Specialized`
//!   rung *inside* the plan (the EDM's handwritten converters use this;
//!   see `edm::convert`).
//!
//! The ladder, top rung first:
//!
//! 1. [`TransferPriority::Specialized`] — registered fast path.
//! 2. `Plane` — dense plane on both sides (or a coalesced whole-tag
//!    block): one `memcopy_with_context` per op.
//! 3. `Strided` — regular strides on both sides: strided copy loop.
//! 4. `Elementwise` — fully general fallback via `elem_ptr`.
//!
//! The *preferred* call forms live on the generated typed collections:
//! `src.convert_to::<L2>()` / `src.stage_into(&mut dst)` (DESIGN.md
//! §6). [`copy_collection`] keeps the original one-call API on top of
//! the cache as a compatibility shim — deprecated in docs, kept green —
//! and is route-equivalent to the fluent path (identical plan object,
//! identical [`TransferStats`]; pinned by the
//! `shims_route_through_identical_plans` unit test).
//! [`copy_collection_unplanned`] preserves the historical
//! walk-the-ladder-every-call implementation as the benchmark baseline
//! (`benches/transfers.rs` measures the amortisation win).
//!
//! `memcopy_with_context` and the overlapping-range variants are the free
//! functions the paper describes for raw context-to-context byte
//! movement. Accounting contract: every cross-context copy books exactly
//! one read on the source (`copy_out` or `note_read`) and one write on
//! the destination (`copy_in` or `note_write`), whichever route is taken.

use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::collection::RawCollection;
use super::holder::LayoutHolder;
use super::layout::{Layout, PlaneShape};
use super::memory::MemoryContext;
use super::schema::{FieldMeta, Schema, TagId};
use crate::util::pool::ThreadPool;

/// Which rung of the ladder a transfer used (reported for tests/benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TransferPriority {
    Specialized,
    Plane,
    Strided,
    Elementwise,
}

/// Copy `len` bytes from `src` (in context `Src`) to `dst` (in context
/// `Dst`). The copy is routed host-side: when both contexts are
/// host-accessible it collapses to a single `memcpy` plus accounting
/// hooks; otherwise it bounces through a host buffer. Whatever the
/// route, the source books one read and the destination one write.
///
/// # Safety
/// `src`/`dst` must be valid for `len` bytes in their contexts and must
/// not overlap.
pub unsafe fn memcopy_with_context<Src: MemoryContext, Dst: MemoryContext>(
    src_info: &Src::Info,
    src: *const u8,
    dst_info: &Dst::Info,
    dst: *mut u8,
    len: usize,
) {
    // Both in-tree context families are host-accessible; the general
    // device route (out to a host bounce buffer, then in) is only needed
    // when either side refuses direct access.
    if Src::HOST_ACCESSIBLE {
        Dst::copy_in(dst_info, dst, src, len);
        Src::note_read(src_info, len); // accounting only, no byte movement
    } else if Dst::HOST_ACCESSIBLE {
        Src::copy_out(src_info, src, dst, len);
        Dst::note_write(dst_info, len); // accounting only, no byte movement
    } else {
        // SAFETY: the scratch covers `len` bytes; src/dst validity is
        // this function's own contract.
        with_bounce_scratch(len, |bounce| unsafe {
            Src::copy_out(src_info, src, bounce.as_mut_ptr(), len);
            Dst::copy_in(dst_info, dst, bounce.as_ptr(), len);
        });
    }
}

/// Bounce scratch shards: threads hash onto a shard, so concurrent
/// device workers never contend on one shelf mutex (DESIGN.md §8).
const BOUNCE_SHARDS: usize = 8;

/// How many bounce scratch buffers may idle **per shard**; chunked
/// `execute_par` copies use at most one per worker at a time.
const BOUNCE_SHARD_MAX_IDLE: usize = 4;

/// High-water cap on idle bytes **per shard** (mirroring the byte
/// pool's `PoolContext` trimming): scratch only ever grows, so without
/// a byte bound one burst of large copies would park its high-water
/// mark in a process-wide static forever. Returns that push a shard
/// over the cap trim the largest parked buffers back under it.
const BOUNCE_SHARD_HELD_HIGH_WATER: usize = 8 << 20; // 8 MiB x 8 shards

static BOUNCE_HITS: AtomicU64 = AtomicU64::new(0);
static BOUNCE_MISSES: AtomicU64 = AtomicU64::new(0);
static BOUNCE_TRIMS: AtomicU64 = AtomicU64::new(0);
static BOUNCE_HELD_BYTES: AtomicUsize = AtomicUsize::new(0);

// ---------------------------------------------------------------------
// Transfer-rung fault injection (chaos harness, DESIGN.md §10)
// ---------------------------------------------------------------------

/// Process-global transfer fault schedule: while armed, every
/// `TRANSFER_FAULT_EVERY`-th plan execution panics before copying a
/// byte. The counter is global and schedule-driven, so the number of
/// fired faults for a fixed transfer sequence is deterministic and
/// independent of thread interleaving.
///
/// Because the hook is process-global, callers that arm it (the chaos
/// pipeline via `FaultPlan::transfer_fail_every`, `tests/chaos.rs`)
/// must serialise against other transfer-running work in the same
/// process; in-tree chaos tests take a shared lock for this.
static TRANSFER_FAULT_EVERY: AtomicU64 = AtomicU64::new(0);
static TRANSFER_FAULT_COUNT: AtomicU64 = AtomicU64::new(0);
static TRANSFER_FAULT_INJECTED: AtomicU64 = AtomicU64::new(0);

/// Arm the transfer fault hook: every `every`-th plan execution panics
/// (0 disarms). Resets the execution counter so equal-seed chaos runs
/// fire identical schedules.
pub fn arm_transfer_fault(every: u64) {
    TRANSFER_FAULT_COUNT.store(0, Ordering::Relaxed);
    TRANSFER_FAULT_EVERY.store(every, Ordering::Relaxed);
}

/// Disarm the transfer fault hook (the injected-fault total persists).
pub fn disarm_transfer_fault() {
    TRANSFER_FAULT_EVERY.store(0, Ordering::Relaxed);
}

/// Total transfer faults fired since process start (monotone; chaos
/// runs difference it around a run to get the per-run count).
pub fn transfer_faults_injected() -> u64 {
    TRANSFER_FAULT_INJECTED.load(Ordering::Relaxed)
}

#[inline]
fn maybe_inject_transfer_fault() {
    let every = TRANSFER_FAULT_EVERY.load(Ordering::Relaxed);
    if every == 0 {
        return;
    }
    let n = TRANSFER_FAULT_COUNT.fetch_add(1, Ordering::Relaxed) + 1;
    if n % every == 0 {
        TRANSFER_FAULT_INJECTED.fetch_add(1, Ordering::Relaxed);
        panic!("injected transfer fault (plan execution #{n})");
    }
}

#[derive(Default)]
struct BounceShelf {
    bufs: Vec<Vec<u8>>,
    held: usize,
}

fn bounce_pool() -> &'static [Mutex<BounceShelf>; BOUNCE_SHARDS] {
    static POOL: OnceLock<[Mutex<BounceShelf>; BOUNCE_SHARDS]> = OnceLock::new();
    POOL.get_or_init(|| std::array::from_fn(|_| Mutex::new(BounceShelf::default())))
}

/// This thread's bounce shard: assigned round-robin at first use, so a
/// worker keeps hitting the same (usually uncontended) shelf.
fn bounce_shard() -> &'static Mutex<BounceShelf> {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: usize = NEXT.fetch_add(1, Ordering::Relaxed) % BOUNCE_SHARDS;
    }
    &bounce_pool()[SHARD.with(|s| *s)]
}

/// Run `f` over a recycled host bounce buffer of at least `len` bytes.
/// Plans whose copies must stage through the host (neither context is
/// host-accessible) borrow scratch planes here instead of allocating
/// one per copy — with `execute_par` chunking, that would otherwise be
/// one fresh allocation per chunk per event. `RawBuf::rehome`'s bounce
/// route borrows from the same shelf.
pub(crate) fn with_bounce_scratch<R>(len: usize, f: impl FnOnce(&mut [u8]) -> R) -> R {
    let shard = bounce_shard();
    let recycled = {
        let mut g = shard.lock().unwrap();
        let b = g.bufs.pop();
        if let Some(b) = &b {
            g.held -= b.len();
            BOUNCE_HELD_BYTES.fetch_sub(b.len(), Ordering::Relaxed);
        }
        b
    };
    let mut buf = match recycled {
        Some(b) => {
            BOUNCE_HITS.fetch_add(1, Ordering::Relaxed);
            b
        }
        None => {
            BOUNCE_MISSES.fetch_add(1, Ordering::Relaxed);
            Vec::new()
        }
    };
    if buf.len() < len {
        buf.resize(len, 0);
    }
    let r = f(&mut buf[..len]);
    let mut g = shard.lock().unwrap();
    g.held += buf.len();
    BOUNCE_HELD_BYTES.fetch_add(buf.len(), Ordering::Relaxed);
    g.bufs.push(buf);
    // High-water trim: drop the largest parked buffers until the shard
    // is back under both its byte and count bounds.
    while g.held > BOUNCE_SHARD_HELD_HIGH_WATER || g.bufs.len() > BOUNCE_SHARD_MAX_IDLE {
        let fattest = g
            .bufs
            .iter()
            .enumerate()
            .max_by_key(|(_, b)| b.len())
            .map(|(i, _)| i)
            .expect("non-empty shelf while over bounds");
        let dropped = g.bufs.swap_remove(fattest);
        g.held -= dropped.len();
        BOUNCE_HELD_BYTES.fetch_sub(dropped.len(), Ordering::Relaxed);
        BOUNCE_TRIMS.fetch_add(1, Ordering::Relaxed);
    }
    r
}

/// Counters of the sharded bounce-scratch shelf. `hits`/`misses`/
/// `trims` are process-wide and monotone; `held_bytes` is a
/// point-in-time gauge summed over the shards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BounceScratchStats {
    /// Checkouts served from a shard's shelf.
    pub hits: u64,
    /// Checkouts that allocated a fresh buffer.
    pub misses: u64,
    /// Parked buffers dropped by high-water trimming.
    pub trims: u64,
    /// Idle bytes currently parked across all shards.
    pub held_bytes: usize,
}

/// Snapshot the bounce-scratch pool counters.
pub fn bounce_scratch_stats() -> BounceScratchStats {
    BounceScratchStats {
        hits: BOUNCE_HITS.load(Ordering::Relaxed),
        misses: BOUNCE_MISSES.load(Ordering::Relaxed),
        trims: BOUNCE_TRIMS.load(Ordering::Relaxed),
        held_bytes: BOUNCE_HELD_BYTES.load(Ordering::Relaxed),
    }
}

/// Overlap-tolerant copy within one context: safe for a destination range
/// that overlaps the source to the *left* (shift-left, used by erase).
///
/// # Safety
/// Both ranges valid in the context.
pub unsafe fn memmove_left_with_context<C: MemoryContext>(
    info: &C::Info,
    dst: *mut u8,
    src: *const u8,
    len: usize,
) {
    debug_assert!((dst as usize) <= (src as usize));
    C::copy_within(info, dst, src, len);
}

/// Overlap-tolerant copy within one context: safe for a destination range
/// that overlaps the source to the *right* (shift-right, used by insert).
///
/// # Safety
/// Both ranges valid in the context.
pub unsafe fn memmove_right_with_context<C: MemoryContext>(
    info: &C::Info,
    dst: *mut u8,
    src: *const u8,
    len: usize,
) {
    debug_assert!((dst as usize) >= (src as usize));
    C::copy_within(info, dst, src, len);
}

// ---------------------------------------------------------------------
// Plan representation
// ---------------------------------------------------------------------

/// Symbolic element count of one plan op, resolved against the source
/// collection at execution time (plans are size-independent).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpLen {
    /// Always 1 (`Global` tag).
    Global,
    /// `src.len()` (`Items` tag).
    Items,
    /// `src.len() + 1` (`ItemsPlusOne` tag).
    ItemsPlusOne,
    /// `src.values_len(j)` (jagged values tag `j`).
    Values(u32),
}

fn op_len_of(tag: TagId) -> OpLen {
    match tag {
        TagId::GLOBAL => OpLen::Global,
        t if t == TagId::ITEMS => OpLen::Items,
        t if t == TagId::ITEMS_PLUS_ONE => OpLen::ItemsPlusOne,
        t => OpLen::Values(t.0 - 3),
    }
}

#[inline]
fn resolve_len<L: Layout>(len: OpLen, src: &RawCollection<L>) -> usize {
    match len {
        OpLen::Global => 1,
        OpLen::Items => src.len(),
        OpLen::ItemsPlusOne => src.len() + 1,
        OpLen::Values(j) => src.values_len(j),
    }
}

/// One precompiled copy operation of a [`TransferPlan`].
#[derive(Clone, Copy, Debug)]
pub enum PlanOp {
    /// Dense plane on both sides: one memcpy of `len * width` bytes.
    Plane { meta: FieldMeta, k: u32, len: OpLen, width: u32 },
    /// Coalesced whole-tag block copy: both layouts store the tag's used
    /// element prefix byte-identically (equal [`Layout::BLOB_IDENTITY`]),
    /// so every plane of the tag collapses into one memcpy of
    /// `round_up(len, round_to) * record` bytes. `anchor` is the tag's
    /// first field (offset 0 in the blob), used to resolve the region
    /// base at execution time.
    TagBlock { anchor: FieldMeta, len: OpLen, record: u32, round_to: u32 },
    /// Regular strides on both sides, byte layouts differ: strided loop.
    Strided { meta: FieldMeta, k: u32, len: OpLen, width: u32 },
    /// Irregular on at least one side: element-wise copies.
    Elementwise { meta: FieldMeta, k: u32, len: OpLen, width: u32 },
    /// The whole transfer is delegated to a registered converter.
    Specialized,
}

/// What one plan execution actually moved.
#[derive(Clone, Copy, Debug)]
pub struct TransferStats {
    /// Payload bytes copied (specialized converters report their own).
    pub bytes: usize,
    /// Individual context-copy calls issued.
    pub ops: usize,
    /// The plan's ladder rung (lowest rung any field descended to).
    pub priority: TransferPriority,
}

type SpecFn = Arc<dyn Fn(&dyn Any, &mut dyn Any) -> usize + Send + Sync>;

/// A compiled transfer strategy for one (schema, src layout, src
/// context, dst layout, dst context) tuple. Compile once (via
/// [`plan_for`]), execute per event/batch.
pub struct TransferPlan {
    schema: Arc<Schema>,
    src_layout: &'static str,
    dst_layout: &'static str,
    src_context: &'static str,
    dst_context: &'static str,
    ops: Vec<PlanOp>,
    priority: TransferPriority,
    /// Op count before coalescing (one per field-lane), for diagnostics
    /// and the coalescing assertions in the rung-matrix test.
    field_lane_ops: usize,
    specialized: Option<SpecFn>,
}

/// Bulk copies at or above this size are split across the thread pool
/// by [`TransferPlan::execute_par`].
pub const PAR_MIN_BYTES: usize = 1 << 20;

impl TransferPlan {
    fn compile<LS: Layout, LD: Layout>(
        schema: Arc<Schema>,
        specialized: Option<SpecFn>,
    ) -> TransferPlan {
        let field_lane_ops: usize = schema
            .fields()
            .map(|(fid, _)| schema.meta(fid).extent as usize)
            .sum();
        let mut plan = TransferPlan {
            schema,
            src_layout: LS::NAME,
            dst_layout: LD::NAME,
            src_context: <LS::Ctx as MemoryContext>::NAME,
            dst_context: <LD::Ctx as MemoryContext>::NAME,
            ops: Vec::new(),
            priority: TransferPriority::Plane,
            field_lane_ops,
            specialized,
        };
        if plan.specialized.is_some() {
            plan.ops.push(PlanOp::Specialized);
            plan.priority = TransferPriority::Specialized;
            return plan;
        }

        // Whole-tag coalescing: identical capacity-independent blob
        // storage on both sides means every plane of a tag is
        // byte-adjacent in one contiguous region on both sides — the
        // per-field ladder collapses to one memcpy per size tag.
        let same_blob = match (LS::BLOB_IDENTITY, LD::BLOB_IDENTITY) {
            (Some(a), Some(b)) => a == b,
            _ => false,
        };
        if same_blob {
            let round_to = match LS::BLOB_IDENTITY {
                Some(super::blob::BlobLayoutKind::AoSoA(k)) => k as u32,
                _ => 1,
            };
            let schema = plan.schema.clone();
            for (t, tl) in schema.tag_layouts().iter().enumerate() {
                let Some(&first) = tl.fields.first() else { continue };
                let anchor = schema.meta(first);
                debug_assert_eq!(anchor.aos_offset, 0, "tag anchor must lead its record");
                plan.ops.push(PlanOp::TagBlock {
                    anchor,
                    len: op_len_of(TagId(t as u32)),
                    record: anchor.record_size,
                    round_to,
                });
            }
            return plan;
        }

        // Generic ladder, resolved per field-lane from static geometry.
        let schema = plan.schema.clone();
        for (fid, _field) in schema.fields() {
            let meta = schema.meta(fid);
            let len = op_len_of(meta.tag_id());
            let esz = meta.size as usize;
            for k in 0..meta.extent {
                let sp = LS::plane_shape(meta, k as usize);
                let dp = LD::plane_shape(meta, k as usize);
                match (sp, dp) {
                    (PlaneShape::Regular { stride: ss }, PlaneShape::Regular { stride: ds })
                        if ss == esz && ds == esz =>
                    {
                        plan.ops.push(PlanOp::Plane { meta, k, len, width: meta.size });
                    }
                    (PlaneShape::Regular { .. }, PlaneShape::Regular { .. }) => {
                        plan.priority = plan.priority.max(TransferPriority::Strided);
                        plan.ops.push(PlanOp::Strided { meta, k, len, width: meta.size });
                    }
                    _ => {
                        plan.priority = plan.priority.max(TransferPriority::Elementwise);
                        plan.ops.push(PlanOp::Elementwise { meta, k, len, width: meta.size });
                    }
                }
            }
        }
        plan
    }

    /// The rung this plan resolves to.
    pub fn priority(&self) -> TransferPriority {
        self.priority
    }

    /// The compiled op list.
    pub fn ops(&self) -> &[PlanOp] {
        &self.ops
    }

    /// Ops in the compiled plan (after coalescing).
    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// Ops an uncoalesced per-field-lane walk would issue.
    pub fn field_lane_ops(&self) -> usize {
        self.field_lane_ops
    }

    /// Whether the plan delegates to a registered specialized converter.
    pub fn is_specialized(&self) -> bool {
        self.specialized.is_some()
    }

    /// The schema this plan was compiled for.
    pub fn schema(&self) -> &Arc<Schema> {
        &self.schema
    }

    /// One-line description for diagnostics and bench labels.
    pub fn describe(&self) -> String {
        format!(
            "{}[{}] -> {}[{}]: {:?}, {} ops ({} field-lanes)",
            self.src_layout,
            self.src_context,
            self.dst_layout,
            self.dst_context,
            self.priority,
            self.ops.len(),
            self.field_lane_ops,
        )
    }

    /// Execute the plan: copy every property of `src` into `dst`,
    /// resizing `dst` to match. `LS`/`LD` must be the layouts the plan
    /// was compiled for (the cache key guarantees this for plans from
    /// [`plan_for`]).
    pub fn execute<LS: Layout, LD: Layout>(
        &self,
        src: &RawCollection<LS>,
        dst: &mut RawCollection<LD>,
    ) -> TransferStats {
        self.execute_inner(src, dst, None)
    }

    /// As [`Self::execute`], but splits contiguous copies of at least
    /// [`PAR_MIN_BYTES`] into chunks across `pool`. Strided and
    /// element-wise rungs stay serial (their per-element dispatch does
    /// not amortise a fork/join).
    pub fn execute_par<LS: Layout, LD: Layout>(
        &self,
        src: &RawCollection<LS>,
        dst: &mut RawCollection<LD>,
        pool: &ThreadPool,
    ) -> TransferStats {
        self.execute_inner(src, dst, Some(pool))
    }

    fn execute_inner<LS: Layout, LD: Layout>(
        &self,
        src: &RawCollection<LS>,
        dst: &mut RawCollection<LD>,
        pool: Option<&ThreadPool>,
    ) -> TransferStats {
        // Chaos hook: fires before any byte moves or any dst resize, so
        // a fired fault leaves src untouched and dst structurally intact.
        maybe_inject_transfer_fault();
        assert!(
            src.schema().same_structure(dst.schema()),
            "transfer requires structurally equal schemas ({} vs {})",
            src.schema().name(),
            dst.schema().name(),
        );
        debug_assert_eq!(self.src_layout, LS::NAME, "plan executed with wrong src layout");
        debug_assert_eq!(self.dst_layout, LD::NAME, "plan executed with wrong dst layout");

        if let Some(f) = &self.specialized {
            let bytes = f(src as &dyn Any, dst as &mut dyn Any);
            return TransferStats { bytes, ops: 1, priority: TransferPriority::Specialized };
        }

        // Size the destination. Only where it differs: re-executing into
        // a reused staging buffer of the right shape skips the
        // resize-to-zero / zero-fill churn entirely (every field is
        // fully overwritten by the ops below).
        if dst.len() != src.len() {
            dst.resize(0);
            dst.resize(src.len());
        }
        for j in 0..src.num_jagged() as u32 {
            let want = src.values_len(j);
            if dst.values_len(j) != want {
                dst.holder_mut().resize_tag(TagId::values(j), want);
            }
        }

        let sinfo = src.context_info().clone();
        let dinfo = dst.context_info().clone();
        let mut bytes = 0usize;
        let mut ops = 0usize;
        for op in &self.ops {
            match *op {
                PlanOp::Plane { meta, k, len, width } => {
                    let n = resolve_len(len, src);
                    if n == 0 {
                        continue;
                    }
                    let total = n * width as usize;
                    let sp = src.plane(meta, k as usize).expect("planned dense src plane");
                    let dp = dst.plane_mut(meta, k as usize).expect("planned dense dst plane");
                    debug_assert_eq!(sp.stride, width as usize);
                    debug_assert_eq!(dp.stride, width as usize);
                    bulk_copy::<LS::Ctx, LD::Ctx>(
                        &sinfo,
                        sp.base,
                        &dinfo,
                        dp.base as *mut u8,
                        total,
                        pool,
                    );
                    bytes += total;
                    ops += 1;
                }
                PlanOp::TagBlock { anchor, len, record, round_to } => {
                    let n = resolve_len(len, src);
                    if n == 0 {
                        continue;
                    }
                    let rounded = n.div_ceil(round_to as usize) * round_to as usize;
                    let total = rounded * record as usize;
                    // SAFETY: `n >= 1` elements exist on both sides;
                    // `anchor` is the tag's first field (blob offset 0),
                    // and both blobs hold at least `rounded` zero-
                    // initialised records (capacity >= length).
                    unsafe {
                        let s = src.holder().elem_ptr(anchor, 0, 0);
                        let d = dst.holder_mut().elem_ptr_mut(anchor, 0, 0);
                        bulk_copy::<LS::Ctx, LD::Ctx>(&sinfo, s, &dinfo, d, total, pool);
                    }
                    bytes += total;
                    ops += 1;
                }
                PlanOp::Strided { meta, k, len, width } => {
                    let n = resolve_len(len, src);
                    if n == 0 {
                        continue;
                    }
                    let esz = width as usize;
                    let sp = src.plane(meta, k as usize).expect("planned strided src plane");
                    let dp = dst.plane_mut(meta, k as usize).expect("planned strided dst plane");
                    unsafe {
                        for i in 0..n {
                            memcopy_with_context::<LS::Ctx, LD::Ctx>(
                                &sinfo,
                                sp.base.add(i * sp.stride),
                                &dinfo,
                                (dp.base as *mut u8).add(i * dp.stride),
                                esz,
                            );
                        }
                    }
                    bytes += n * esz;
                    ops += n;
                }
                PlanOp::Elementwise { meta, k, len, width } => {
                    let n = resolve_len(len, src);
                    let esz = width as usize;
                    for i in 0..n {
                        unsafe {
                            let s = src.holder().elem_ptr(meta, i, k as usize);
                            let d = dst.holder_mut().elem_ptr_mut(meta, i, k as usize);
                            memcopy_with_context::<LS::Ctx, LD::Ctx>(&sinfo, s, &dinfo, d, esz);
                        }
                    }
                    bytes += n * esz;
                    ops += n;
                }
                PlanOp::Specialized => unreachable!("specialized plans return early"),
            }
        }
        TransferStats { bytes, ops, priority: self.priority }
    }
}

struct SendConstPtr(*const u8);
// SAFETY: the pointer is only dereferenced for reads inside the scoped
// batch that created it, over a range no other job touches.
unsafe impl Send for SendConstPtr {}

struct SendMutPtr(*mut u8);
// SAFETY: as above, for disjoint writes.
unsafe impl Send for SendMutPtr {}

/// One contiguous context copy, optionally chunked across the pool.
fn bulk_copy<SC: MemoryContext, DC: MemoryContext>(
    sinfo: &SC::Info,
    src: *const u8,
    dinfo: &DC::Info,
    dst: *mut u8,
    len: usize,
    pool: Option<&ThreadPool>,
) {
    if let Some(pool) = pool {
        if len >= PAR_MIN_BYTES && pool.workers() > 1 {
            let chunks = pool.workers().min(len / (PAR_MIN_BYTES / 2)).max(2);
            let chunk = len.div_ceil(chunks);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..chunks)
                .filter(|c| c * chunk < len)
                .map(|c| {
                    let lo = c * chunk;
                    let hi = (lo + chunk).min(len);
                    let s = SendConstPtr(unsafe { src.add(lo) });
                    let d = SendMutPtr(unsafe { dst.add(lo) });
                    Box::new(move || unsafe {
                        memcopy_with_context::<SC, DC>(sinfo, s.0, dinfo, d.0, hi - lo);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scoped(jobs);
            return;
        }
    }
    unsafe { memcopy_with_context::<SC, DC>(sinfo, src, dinfo, dst, len) };
}

// ---------------------------------------------------------------------
// Plan cache + specialized-rung registry
// ---------------------------------------------------------------------

/// Cache key: the (src layout+context, dst layout+context) type pair
/// plus the schema instance. Plans hold their schema `Arc`, so the
/// address in the key can never be reused while the entry lives.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct PlanKey {
    pair: TypeId,
    schema: usize,
}

fn plan_key<LS: Layout, LD: Layout>(schema: &Arc<Schema>) -> PlanKey {
    PlanKey { pair: TypeId::of::<(LS, LD)>(), schema: Arc::as_ptr(schema) as usize }
}

/// Shard count of the shared plan cache. Power of two; keys spread by
/// their hash, so unrelated (schema, layout-pair) tuples resolve on
/// different mutexes (DESIGN.md §8).
pub const PLAN_CACHE_SHARDS: usize = 8;

struct CacheShard {
    plans: Mutex<HashMap<PlanKey, Arc<TransferPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Times the shard mutex was taken by `plan_for` (NOT bumped by
    /// per-thread `PlanHandle` hits — the flat-across-warm-iterations
    /// contract the coordinator-scale tests pin).
    lock_acquisitions: AtomicU64,
}

struct PlanCache {
    shards: [CacheShard; PLAN_CACHE_SHARDS],
    /// Registered user fast paths (cold path: read only on a shard
    /// miss, written by `register_specialized`).
    specialized: Mutex<HashMap<PlanKey, SpecFn>>,
    /// Bumped by [`clear_plan_cache`] and [`register_specialized`];
    /// per-thread handles compare against it and drop their local maps
    /// when stale.
    generation: AtomicU64,
}

fn cache() -> &'static PlanCache {
    static CACHE: OnceLock<PlanCache> = OnceLock::new();
    CACHE.get_or_init(|| PlanCache {
        shards: std::array::from_fn(|_| CacheShard {
            plans: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            lock_acquisitions: AtomicU64::new(0),
        }),
        specialized: Mutex::new(HashMap::new()),
        generation: AtomicU64::new(0),
    })
}

fn shard_of(key: &PlanKey) -> &'static CacheShard {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    &cache().shards[(h.finish() as usize) % PLAN_CACHE_SHARDS]
}

/// Process-wide plan-cache counters (monotone), summed over the shards.
/// Per-thread [`PlanHandle`] hits count as cache hits here (they are
/// served from a plan the shared cache resolved earlier).
#[derive(Clone, Copy, Debug)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

/// Per-shard plan-cache counters (diagnostics + the contention tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanCacheShardStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    /// Shard-mutex acquisitions: flat across warm steady-state lookups
    /// (those are served lock-free from per-thread handles).
    pub lock_acquisitions: u64,
}

/// Snapshot the plan-cache counters (shard-summed).
pub fn plan_cache_stats() -> PlanCacheStats {
    let mut s = PlanCacheStats { hits: 0, misses: 0, entries: 0 };
    for sh in plan_cache_shard_stats() {
        s.hits += sh.hits;
        s.misses += sh.misses;
        s.entries += sh.entries;
    }
    s
}

/// Snapshot every shard's counters, in shard order.
pub fn plan_cache_shard_stats() -> [PlanCacheShardStats; PLAN_CACHE_SHARDS] {
    std::array::from_fn(|i| {
        let sh = &cache().shards[i];
        PlanCacheShardStats {
            hits: sh.hits.load(Ordering::Relaxed),
            misses: sh.misses.load(Ordering::Relaxed),
            entries: sh.plans.lock().unwrap().len(),
            lock_acquisitions: sh.lock_acquisitions.load(Ordering::Relaxed),
        }
    })
}

/// The cache invalidation generation. Bumped by [`clear_plan_cache`]
/// and [`register_specialized`]; per-thread handles revalidate against
/// it with one atomic load per lookup.
pub fn plan_cache_generation() -> u64 {
    cache().generation.load(Ordering::Acquire)
}

/// Drop every cached plan (registered specializations survive; the next
/// `plan_for` recompiles). Per-thread [`PlanHandle`]s notice via the
/// generation counter and drop their local maps on their next lookup.
/// Intended for tests and tooling.
pub fn clear_plan_cache() {
    for sh in &cache().shards {
        sh.plans.lock().unwrap().clear();
    }
    cache().generation.fetch_add(1, Ordering::AcqRel);
}

/// A per-worker local plan cache: a small map of `Arc<TransferPlan>`
/// resolved through the shared sharded cache once, then served with no
/// shared-lock acquisition at all (one atomic generation load per
/// lookup). [`plan_for`] routes through a thread-local handle
/// automatically, so every steady-state `stage_into`/`convert_to` on a
/// warm thread touches no shared mutex; embed an explicit handle only
/// when thread identity is unsuitable (e.g. a migrating task).
#[derive(Default)]
pub struct PlanHandle {
    generation: u64,
    plans: HashMap<PlanKey, Arc<TransferPlan>>,
    local_hits: u64,
    shared_lookups: u64,
}

/// Counters of one [`PlanHandle`] (monotone per handle).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanHandleStats {
    /// Lookups served from the handle's local map (no shared lock).
    pub local_hits: u64,
    /// Lookups that fell through to the shared sharded cache.
    pub shared_lookups: u64,
}

impl PlanHandle {
    pub fn new() -> PlanHandle {
        PlanHandle::default()
    }

    pub fn stats(&self) -> PlanHandleStats {
        PlanHandleStats { local_hits: self.local_hits, shared_lookups: self.shared_lookups }
    }

    /// The cached plan for `(LS, LD, schema)`: local map first (lock
    /// free), shared shard on a local miss. Invalidation: if the global
    /// generation moved since the last lookup, the local map is stale
    /// (a `clear_plan_cache` or specialization registration happened)
    /// and is dropped before resolving.
    pub fn plan_for<LS: Layout, LD: Layout>(&mut self, schema: &Arc<Schema>) -> Arc<TransferPlan> {
        let key = plan_key::<LS, LD>(schema);
        let now = cache().generation.load(Ordering::Acquire);
        if now != self.generation {
            self.plans.clear();
            self.generation = now;
        }
        if let Some(p) = self.plans.get(&key) {
            self.local_hits += 1;
            // A local hit is still a process-wide cache hit: the shard
            // counter is an atomic, not a lock.
            shard_of(&key).hits.fetch_add(1, Ordering::Relaxed);
            return p.clone();
        }
        self.shared_lookups += 1;
        let plan = resolve_shared::<LS, LD>(key, schema);
        self.plans.insert(key, plan.clone());
        plan
    }
}

thread_local! {
    static LOCAL_PLANS: std::cell::RefCell<PlanHandle> =
        std::cell::RefCell::new(PlanHandle::new());
}

/// This thread's [`PlanHandle`] counters — deterministic per thread, so
/// tests pin the zero-shared-lock steady state without racing other
/// threads' traffic.
pub fn local_plan_handle_stats() -> PlanHandleStats {
    LOCAL_PLANS.with(|h| h.borrow().stats())
}

/// Shared-cache lookup under the key's shard mutex: hit returns the
/// cached plan, miss compiles (consulting the specialized registry)
/// and inserts. Holding the shard lock across the specialized read and
/// the insert keeps registration linearizable: a concurrent
/// `register_specialized` either sees our generic entry and removes
/// it, or its registration is visible to our compile.
fn resolve_shared<LS: Layout, LD: Layout>(
    key: PlanKey,
    schema: &Arc<Schema>,
) -> Arc<TransferPlan> {
    let shard = shard_of(&key);
    shard.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
    let mut g = shard.plans.lock().unwrap();
    if let Some(p) = g.get(&key) {
        shard.hits.fetch_add(1, Ordering::Relaxed);
        return p.clone();
    }
    shard.misses.fetch_add(1, Ordering::Relaxed);
    let spec = cache().specialized.lock().unwrap().get(&key).cloned();
    let plan = Arc::new(TransferPlan::compile::<LS, LD>(schema.clone(), spec));
    g.insert(key, plan.clone());
    plan
}

/// The cached [`TransferPlan`] for copying a `RawCollection<LS>` into a
/// `RawCollection<LD>` under `schema`. The first request on a thread
/// resolves through the sharded shared cache (compiling on a global
/// first request); every later request on that thread is a lock-free
/// lookup in its thread-local [`PlanHandle`] returning the shared plan.
pub fn plan_for<LS: Layout, LD: Layout>(schema: &Arc<Schema>) -> Arc<TransferPlan> {
    LOCAL_PLANS.with(|h| h.borrow_mut().plan_for::<LS, LD>(schema))
}

/// Ensure the `(LS, LD, schema)` plan is compiled and resident in the
/// shared cache without executing anything — the autotuner calls this
/// for the layout it just chose so the first event on the retuned route
/// pays no plan build. Returns whether the plan was already cached
/// (true = warm call was a no-op).
pub fn prewarm_plan<LS: Layout, LD: Layout>(schema: &Arc<Schema>) -> bool {
    let key = plan_key::<LS, LD>(schema);
    let already = {
        let shard = shard_of(&key);
        shard.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        let g = shard.plans.lock().unwrap();
        g.contains_key(&key)
    };
    let _ = plan_for::<LS, LD>(schema);
    already
}

/// Register a specialized converter for the concrete (schema, `LS`,
/// `LD`) tuple. Future plans for that tuple consist of a single
/// `Specialized` op delegating to `f` (which must size `dst` itself and
/// returns the payload bytes it moved); any already-cached plan for the
/// tuple is invalidated — and the generation bump flushes every
/// per-thread [`PlanHandle`] — so the registration takes effect
/// immediately. Register once at startup (the EDM guards its
/// registrations with a `Once`): every call invalidates all local
/// handles process-wide.
pub fn register_specialized<LS, LD, F>(schema: &Arc<Schema>, f: F)
where
    LS: Layout,
    LD: Layout,
    F: Fn(&RawCollection<LS>, &mut RawCollection<LD>) -> usize + Send + Sync + 'static,
{
    let key = plan_key::<LS, LD>(schema);
    let wrapped: SpecFn = Arc::new(move |s: &dyn Any, d: &mut dyn Any| {
        let s = s.downcast_ref::<RawCollection<LS>>().expect("specialized src type");
        let d = d.downcast_mut::<RawCollection<LD>>().expect("specialized dst type");
        f(s, d)
    });
    let c = cache();
    // Specialized guard dropped at the semicolon; never held across the
    // shard lock (resolve_shared locks in the opposite order).
    c.specialized.lock().unwrap().insert(key, wrapped);
    shard_of(&key).plans.lock().unwrap().remove(&key);
    c.generation.fetch_add(1, Ordering::AcqRel);
}

// ---------------------------------------------------------------------
// One-call conveniences
// ---------------------------------------------------------------------

/// Copy every property of `src` into `dst` (same schema structure
/// required; layouts and contexts may differ) through the cached
/// [`TransferPlan`]. Returns the *lowest* rung the transfer descends to.
pub fn copy_collection<LS: Layout, LD: Layout>(
    src: &RawCollection<LS>,
    dst: &mut RawCollection<LD>,
) -> TransferPriority {
    copy_collection_stats(src, dst).priority
}

/// As [`copy_collection`], returning full execution stats.
pub fn copy_collection_stats<LS: Layout, LD: Layout>(
    src: &RawCollection<LS>,
    dst: &mut RawCollection<LD>,
) -> TransferStats {
    assert!(
        src.schema().same_structure(dst.schema()),
        "transfer requires structurally equal schemas ({} vs {})",
        src.schema().name(),
        dst.schema().name(),
    );
    let plan = plan_for::<LS, LD>(src.schema());
    plan.execute(src, dst)
}

/// The historical implementation: re-derive the ladder rung from actual
/// plane views on every call, field by field. Kept as the baseline the
/// transfers bench compares plan amortisation against; prefer
/// [`copy_collection`] everywhere else.
pub fn copy_collection_unplanned<LS: Layout, LD: Layout>(
    src: &RawCollection<LS>,
    dst: &mut RawCollection<LD>,
) -> TransferPriority {
    assert!(
        src.schema().same_structure(dst.schema()),
        "transfer requires structurally equal schemas ({} vs {})",
        src.schema().name(),
        dst.schema().name(),
    );

    // Size the destination: drop any previous content (and its jagged
    // values), then match the item count and each values-tag length; the
    // raw field copy below replicates the actual prefix sums.
    dst.resize(0);
    dst.resize(src.len());
    if dst.len() > 0 {
        let last = dst.len() - 1;
        for j in 0..src.num_jagged() as u32 {
            let n = src.values_len(j);
            if n > 0 {
                dst.set_jagged_count(j, last, n);
            }
        }
    }

    let schema = src.schema().clone();
    let sinfo = src.context_info().clone();
    let dinfo = dst.context_info().clone();
    let mut worst = TransferPriority::Plane;
    for (fid, _field) in schema.fields() {
        let meta = schema.meta(fid);
        let tag = meta.tag_id();
        let len = match tag {
            TagId::GLOBAL => 1,
            t if t == TagId::ITEMS => src.len(),
            t if t == TagId::ITEMS_PLUS_ONE => src.len() + 1,
            t => src.values_len(t.0 - 3),
        };
        for k in 0..meta.extent as usize {
            let esz = meta.size as usize;
            let sp = src.plane(meta, k);
            let dp = dst.plane_mut(meta, k);
            match (sp, dp) {
                (Some(s), Some(d)) if s.stride == esz && d.stride == esz => {
                    // Dense <-> dense: single context copy per plane.
                    unsafe {
                        memcopy_with_context::<LS::Ctx, LD::Ctx>(
                            &sinfo,
                            s.base,
                            &dinfo,
                            d.base as *mut u8,
                            len * esz,
                        );
                    }
                }
                (Some(s), Some(d)) => {
                    // Regular strides: strided copy loop.
                    worst = worst.max(TransferPriority::Strided);
                    unsafe {
                        for i in 0..len {
                            memcopy_with_context::<LS::Ctx, LD::Ctx>(
                                &sinfo,
                                s.base.add(i * s.stride),
                                &dinfo,
                                (d.base as *mut u8).add(i * d.stride),
                                esz,
                            );
                        }
                    }
                }
                _ => {
                    // Irregular (AoSoA planes): element-wise.
                    worst = worst.max(TransferPriority::Elementwise);
                    for i in 0..len {
                        unsafe {
                            let s = src.holder().elem_ptr(meta, i, k);
                            let d = dst.holder_mut().elem_ptr_mut(meta, i, k);
                            memcopy_with_context::<LS::Ctx, LD::Ctx>(
                                &sinfo, s, &dinfo, d, esz,
                            );
                        }
                    }
                }
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::super::layout::{AoS, AoSoA, SoABlob, SoAVec};
    use super::super::memory::{
        CountingContext, CountingInfo, HostContext, StagingContext, StagingInfo,
    };
    use super::super::schema::Schema;
    use super::*;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    fn schema() -> Arc<Schema> {
        Arc::new(
            Schema::builder("s")
                .per_item::<f32>("e")
                .per_item::<i32>("t")
                .array::<f32>("sig", 2)
                .jagged::<u64, u32>("cells")
                .global::<u64>("ev")
                .build(),
        )
    }

    fn build_src<L: Layout>() -> RawCollection<L>
    where
        <L::Ctx as MemoryContext>::Info: Default,
    {
        let s = schema();
        let m_e = s.meta(s.field_by_name("e").unwrap());
        let m_t = s.meta(s.field_by_name("t").unwrap());
        let m_sig = s.meta(s.field_by_name("sig").unwrap());
        let m_cells = s.meta(s.field_by_name("cells").unwrap());
        let m_ev = s.meta(s.field_by_name("ev").unwrap());
        let mut c = RawCollection::<L>::new(s);
        c.set_global::<u64>(m_ev, 7);
        for i in 0..5 {
            c.push_default();
            c.set::<f32>(m_e, i, i as f32 * 1.5);
            c.set::<i32>(m_t, i, i as i32 - 2);
            c.set_k::<f32>(m_sig, i, 0, i as f32);
            c.set_k::<f32>(m_sig, i, 1, -(i as f32));
            let v0 = c.append_values(0, i % 3);
            for n in 0..(i % 3) {
                c.set_value::<u64>(m_cells, v0 + n, (i * 10 + n) as u64);
            }
        }
        c
    }

    fn check_equal<LA: Layout, LB: Layout>(a: &RawCollection<LA>, b: &RawCollection<LB>) {
        let s = a.schema();
        let m_e = s.meta(s.field_by_name("e").unwrap());
        let m_t = s.meta(s.field_by_name("t").unwrap());
        let m_sig = s.meta(s.field_by_name("sig").unwrap());
        let m_cells = s.meta(s.field_by_name("cells").unwrap());
        let m_ev = s.meta(s.field_by_name("ev").unwrap());
        assert_eq!(a.len(), b.len());
        assert_eq!(a.get_global::<u64>(m_ev), b.get_global::<u64>(m_ev));
        for i in 0..a.len() {
            assert_eq!(a.get::<f32>(m_e, i), b.get::<f32>(m_e, i));
            assert_eq!(a.get::<i32>(m_t, i), b.get::<i32>(m_t, i));
            for k in 0..2 {
                assert_eq!(a.get_k::<f32>(m_sig, i, k), b.get_k::<f32>(m_sig, i, k));
            }
            assert_eq!(
                a.jagged_view::<u64>(m_cells, 0, i).to_vec(),
                b.jagged_view::<u64>(m_cells, 0, i).to_vec()
            );
        }
    }

    #[test]
    fn soavec_to_aos_and_back() {
        let src = build_src::<SoAVec>();
        let mut aos = RawCollection::<AoS>::new(src.schema().clone());
        let p = copy_collection(&src, &mut aos);
        check_equal(&src, &aos);
        assert!(p <= TransferPriority::Strided);
        let mut back = RawCollection::<SoAVec>::new(src.schema().clone());
        copy_collection(&aos, &mut back);
        check_equal(&src, &back);
    }

    #[test]
    fn all_layout_pairs_roundtrip() {
        let src = build_src::<SoAVec>();
        macro_rules! pair {
            ($mid:ty) => {{
                let mut mid = RawCollection::<$mid>::new(src.schema().clone());
                copy_collection(&src, &mut mid);
                let mut back = RawCollection::<SoAVec>::new(src.schema().clone());
                copy_collection(&mid, &mut back);
                check_equal(&src, &back);
            }};
        }
        pair!(AoS);
        pair!(SoABlob);
        pair!(AoSoA<4>);
        pair!(AoSoA<16>);
    }

    #[test]
    fn aosoa_is_elementwise() {
        let src = build_src::<SoAVec>();
        let mut dst = RawCollection::<AoSoA<8>>::new(src.schema().clone());
        let p = copy_collection(&src, &mut dst);
        assert_eq!(p, TransferPriority::Elementwise);
    }

    #[test]
    fn soavec_pair_is_plane() {
        let src = build_src::<SoAVec>();
        let mut dst = RawCollection::<SoAVec>::new(src.schema().clone());
        let p = copy_collection(&src, &mut dst);
        assert_eq!(p, TransferPriority::Plane);
    }

    #[test]
    fn cross_context_accounts_dma() {
        let src = build_src::<SoAVec>();
        let info = StagingInfo::default();
        let mut dst = RawCollection::<SoAVec<StagingContext>>::new_in(
            src.schema().clone(),
            info.clone(),
        );
        copy_collection(&src, &mut dst);
        check_equal(&src, &dst);
        // Every plane upload is H2D traffic.
        assert!(info.counters.h2d_bytes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn counting_context_observes_copy_out() {
        let s = schema();
        let m_e = s.meta(s.field_by_name("e").unwrap());
        let info = CountingInfo::default();
        let mut src =
            RawCollection::<SoAVec<CountingContext>>::new_in(s.clone(), info.clone());
        src.resize(4);
        src.set::<f32>(m_e, 2, 5.0);
        let mut dst = RawCollection::<SoAVec>::new(s);
        copy_collection(&src, &mut dst);
        assert_eq!(dst.get::<f32>(m_e, 2), 5.0);
    }

    #[test]
    #[should_panic(expected = "structurally equal")]
    fn schema_mismatch_rejected() {
        let a = build_src::<SoAVec>();
        let other = Arc::new(Schema::builder("x").per_item::<f32>("y").build());
        let mut b = RawCollection::<SoAVec>::new(other);
        copy_collection(&a, &mut b);
    }

    #[test]
    fn raw_memcopy_between_contexts() {
        let staging = StagingInfo::default();
        let src: Vec<u8> = (0..100).collect();
        let mut dst = vec![0u8; 100];
        unsafe {
            memcopy_with_context::<HostContext, StagingContext>(
                &(),
                src.as_ptr(),
                &staging,
                dst.as_mut_ptr(),
                100,
            );
        }
        assert_eq!(src, dst);
        assert_eq!(staging.counters.h2d_bytes.load(Ordering::Relaxed), 100);
    }

    // -- plan engine ---------------------------------------------------

    #[test]
    fn plan_cache_compiles_once_then_hits() {
        let s = schema();
        let before = plan_cache_stats();
        let p1 = plan_for::<SoAVec, AoS>(&s);
        let p2 = plan_for::<SoAVec, AoS>(&s);
        let after = plan_cache_stats();
        assert!(Arc::ptr_eq(&p1, &p2), "same schema+pair must share one plan");
        assert!(after.hits >= before.hits + 1);
        assert!(after.misses >= before.misses + 1);
        // A different layout pair under the same schema is a new entry.
        let p3 = plan_for::<AoS, SoAVec>(&s);
        assert!(!Arc::ptr_eq(&p1, &p3));
    }

    #[test]
    fn identical_blob_layouts_coalesce_to_tag_blocks() {
        let s = schema();
        // 6 fields, 7 field-lanes (sig has extent 2); 4 non-empty tags.
        let aos = plan_for::<AoS, AoS>(&s);
        assert_eq!(aos.priority(), TransferPriority::Plane);
        assert_eq!(aos.field_lane_ops(), 7);
        assert_eq!(aos.num_ops(), 4, "{}", aos.describe());
        assert!(aos.num_ops() < aos.field_lane_ops());

        let blocked = plan_for::<AoSoA<8>, AoSoA<8>>(&s);
        assert_eq!(blocked.priority(), TransferPriority::Plane);
        assert_eq!(blocked.num_ops(), 4);

        // Different block sizes store bytes differently: no coalescing.
        let mixed = plan_for::<AoSoA<8>, AoSoA<4>>(&s);
        assert_eq!(mixed.priority(), TransferPriority::Elementwise);
        assert_eq!(mixed.num_ops(), 7);
    }

    #[test]
    fn coalesced_plans_copy_correctly() {
        let src = build_src::<AoS>();
        let mut dst = RawCollection::<AoS>::new(src.schema().clone());
        let p = copy_collection(&src, &mut dst);
        assert_eq!(p, TransferPriority::Plane);
        check_equal(&src, &dst);

        let src = build_src::<AoSoA<8>>();
        let mut dst = RawCollection::<AoSoA<8>>::new(src.schema().clone());
        let stats = copy_collection_stats(&src, &mut dst);
        assert_eq!(stats.priority, TransferPriority::Plane);
        assert_eq!(stats.ops, 4);
        check_equal(&src, &dst);
    }

    #[test]
    fn repeated_execute_reuses_sized_destination() {
        let src = build_src::<SoAVec>();
        let plan = plan_for::<SoAVec, SoABlob>(src.schema());
        let mut dst = RawCollection::<SoABlob>::new(src.schema().clone());
        for _ in 0..3 {
            plan.execute(&src, &mut dst);
            check_equal(&src, &dst);
        }
        // Shrinking and growing the source keeps the reused dst correct.
        let mut small = RawCollection::<SoAVec>::new(src.schema().clone());
        small.resize(2);
        plan.execute(&small, &mut dst);
        check_equal(&small, &dst);
        plan.execute(&src, &mut dst);
        check_equal(&src, &dst);
    }

    #[test]
    fn empty_source_transfers() {
        let s = schema();
        let src = RawCollection::<SoAVec>::new(s.clone());
        let mut dst = RawCollection::<AoS>::new(s.clone());
        copy_collection(&src, &mut dst);
        assert_eq!(dst.len(), 0);
        let mut blocked = RawCollection::<AoSoA<4>>::new(s.clone());
        let src2 = RawCollection::<AoSoA<4>>::new(s);
        copy_collection(&src2, &mut blocked);
        assert_eq!(blocked.len(), 0);
    }

    #[test]
    fn parallel_execute_matches_serial() {
        let s = schema();
        let m_e = s.meta(s.field_by_name("e").unwrap());
        let mut src = RawCollection::<SoAVec>::new(s.clone());
        // Large enough that the f32 planes cross PAR_MIN_BYTES.
        let n = (PAR_MIN_BYTES / 4) * 2;
        src.resize(n);
        for i in (0..n).step_by(997) {
            src.set::<f32>(m_e, i, i as f32);
        }
        let plan = plan_for::<SoAVec, SoAVec>(&s);
        let pool = ThreadPool::new(4);
        let mut par = RawCollection::<SoAVec>::new(s.clone());
        let stats = plan.execute_par(&src, &mut par, &pool);
        assert!(stats.bytes > PAR_MIN_BYTES);
        let mut ser = RawCollection::<SoAVec>::new(s);
        plan.execute(&src, &mut ser);
        for i in (0..n).step_by(997) {
            assert_eq!(par.get::<f32>(m_e, i), ser.get::<f32>(m_e, i));
        }
    }

    #[test]
    fn specialized_rung_registers_inside_plans() {
        // A private schema instance so the registration cannot leak into
        // other tests (the cache is keyed by schema identity).
        let s = Arc::new(
            Schema::builder("spec")
                .per_item::<f32>("x")
                .global::<u64>("g")
                .build(),
        );
        let m_x = s.meta(s.field_by_name("x").unwrap());
        let m_g = s.meta(s.field_by_name("g").unwrap());

        // Before registration: the generic ladder.
        let p = plan_for::<SoAVec, AoS>(&s);
        assert!(!p.is_specialized());

        register_specialized::<SoAVec, AoS, _>(&s, |src, dst| {
            copy_collection_unplanned(src, dst);
            usize::MAX // marker: bytes reported by the converter
        });

        // Registration invalidates the cached plan.
        let p = plan_for::<SoAVec, AoS>(&s);
        assert!(p.is_specialized());
        assert_eq!(p.priority(), TransferPriority::Specialized);
        assert_eq!(p.num_ops(), 1);

        let mut src = RawCollection::<SoAVec>::new(s.clone());
        src.resize(3);
        src.set::<f32>(m_x, 1, 4.5);
        src.set_global::<u64>(m_g, 11);
        let mut dst = RawCollection::<AoS>::new(s.clone());
        let stats = copy_collection_stats(&src, &mut dst);
        assert_eq!(stats.priority, TransferPriority::Specialized);
        assert_eq!(stats.bytes, usize::MAX);
        assert_eq!(dst.get::<f32>(m_x, 1), 4.5);
        assert_eq!(dst.get_global::<u64>(m_g), 11);

        // The sibling direction stays generic.
        let back = plan_for::<AoS, SoAVec>(&s);
        assert!(!back.is_specialized());
    }

    #[test]
    fn planned_and_unplanned_agree_everywhere() {
        macro_rules! agree {
            ($src:ty, $dst:ty) => {{
                let src = build_src::<$src>();
                let mut a = RawCollection::<$dst>::new(src.schema().clone());
                let pa = copy_collection(&src, &mut a);
                let mut b = RawCollection::<$dst>::new(src.schema().clone());
                let pb = copy_collection_unplanned(&src, &mut b);
                check_equal(&a, &b);
                // The plan may climb rungs via coalescing, never descend.
                assert!(pa <= pb, "{pa:?} vs {pb:?}");
            }};
        }
        agree!(SoAVec, SoAVec);
        agree!(SoAVec, AoS);
        agree!(AoS, AoS);
        agree!(AoS, SoABlob);
        agree!(SoABlob, AoSoA<4>);
        agree!(AoSoA<8>, AoSoA<8>);
    }

    /// Route equivalence of the compatibility shims (API-redesign
    /// contract): the one-call [`copy_collection`] /
    /// [`copy_collection_stats`] wrappers resolve to the *identical*
    /// cached plan as the fluent direct-execute path (`stage_into`),
    /// book byte-for-byte identical [`TransferStats`], and register as
    /// plan cache hits (never a recompilation).
    #[test]
    fn shims_route_through_identical_plans() {
        let src = build_src::<SoAVec>();
        let s = src.schema().clone();

        // Fluent path: resolve the plan once, execute directly.
        let plan = plan_for::<SoAVec, AoS>(&s);
        let mut direct = RawCollection::<AoS>::new(s.clone());
        let direct_stats = plan.execute(&src, &mut direct);

        // Shim path: the one-call wrapper on a fresh destination.
        let before = plan_cache_stats();
        let mut shim = RawCollection::<AoS>::new(s.clone());
        let shim_stats = copy_collection_stats(&src, &mut shim);
        let after = plan_cache_stats();

        check_equal(&direct, &shim);
        assert_eq!(direct_stats.bytes, shim_stats.bytes, "shim booked different bytes");
        assert_eq!(direct_stats.ops, shim_stats.ops, "shim issued different op count");
        assert_eq!(direct_stats.priority, shim_stats.priority, "shim used different rung");
        // The shim's lookup is a cache hit on the very same plan object.
        assert!(after.hits > before.hits, "shim missed the plan cache");
        assert!(
            Arc::ptr_eq(&plan, &plan_for::<SoAVec, AoS>(&s)),
            "shim and fluent path must share one compiled plan"
        );

        // Re-running the shim into the already-sized destination stays
        // stats-identical (steady-state staging contract).
        let again = copy_collection_stats(&src, &mut shim);
        assert_eq!(again.bytes, shim_stats.bytes);
        assert_eq!(again.ops, shim_stats.ops);
    }

    // -- accounting contract -------------------------------------------

    /// Test-only context that refuses direct host access, to exercise
    /// the `copy_out` + `note_write` and bounce-buffer routes.
    #[derive(Clone, Copy, Debug, Default)]
    struct OpaqueContext;

    impl MemoryContext for OpaqueContext {
        type Info = CountingInfo;
        const NAME: &'static str = "opaque";
        const HOST_ACCESSIBLE: bool = false;

        fn allocate(info: &CountingInfo, layout: std::alloc::Layout) -> std::ptr::NonNull<u8> {
            CountingContext::allocate(info, layout)
        }

        unsafe fn deallocate(
            info: &CountingInfo,
            ptr: std::ptr::NonNull<u8>,
            layout: std::alloc::Layout,
        ) {
            CountingContext::deallocate(info, ptr, layout)
        }

        unsafe fn copy_in(info: &CountingInfo, dst: *mut u8, src: *const u8, len: usize) {
            CountingContext::copy_in(info, dst, src, len)
        }

        unsafe fn copy_out(info: &CountingInfo, src: *const u8, dst: *mut u8, len: usize) {
            CountingContext::copy_out(info, src, dst, len)
        }

        fn note_read(info: &CountingInfo, len: usize) {
            CountingContext::note_read(info, len)
        }

        fn note_write(info: &CountingInfo, len: usize) {
            CountingContext::note_write(info, len)
        }
    }

    /// Every route books exactly one read on the source and one write on
    /// the destination — no double accounting on either side.
    #[test]
    fn accounting_contract_is_route_independent() {
        let src_buf: Vec<u8> = (0..64).collect();
        let mut dst_buf = vec![0u8; 64];

        // Fast path: dst copy_in moves bytes, src note_read accounts.
        let (si, di) = (CountingInfo::default(), CountingInfo::default());
        unsafe {
            memcopy_with_context::<CountingContext, CountingContext>(
                &si,
                src_buf.as_ptr(),
                &di,
                dst_buf.as_mut_ptr(),
                64,
            );
        }
        assert_eq!(si.0.bytes_copied_out.load(Ordering::Relaxed), 64);
        assert_eq!(si.0.bytes_copied_in.load(Ordering::Relaxed), 0);
        assert_eq!(di.0.bytes_copied_in.load(Ordering::Relaxed), 64);
        assert_eq!(di.0.bytes_copied_out.load(Ordering::Relaxed), 0);

        // Opaque source: src copy_out moves bytes, dst note_write
        // accounts (the side the pre-plan code forgot to book).
        let (si, di) = (CountingInfo::default(), CountingInfo::default());
        unsafe {
            memcopy_with_context::<OpaqueContext, CountingContext>(
                &si,
                src_buf.as_ptr(),
                &di,
                dst_buf.as_mut_ptr(),
                64,
            );
        }
        assert_eq!(si.0.bytes_copied_out.load(Ordering::Relaxed), 64);
        assert_eq!(di.0.bytes_copied_in.load(Ordering::Relaxed), 64);

        // Bounce route: both sides move bytes themselves.
        let (si, di) = (CountingInfo::default(), CountingInfo::default());
        unsafe {
            memcopy_with_context::<OpaqueContext, OpaqueContext>(
                &si,
                src_buf.as_ptr(),
                &di,
                dst_buf.as_mut_ptr(),
                64,
            );
        }
        assert_eq!(si.0.bytes_copied_out.load(Ordering::Relaxed), 64);
        assert_eq!(si.0.bytes_copied_in.load(Ordering::Relaxed), 0);
        assert_eq!(di.0.bytes_copied_in.load(Ordering::Relaxed), 64);
        assert_eq!(di.0.bytes_copied_out.load(Ordering::Relaxed), 0);
        assert_eq!(dst_buf, src_buf);
    }

    /// The bounce route draws its host staging buffer from the scratch
    /// pool: repeated opaque↔opaque copies recycle instead of allocating.
    #[test]
    fn bounce_route_recycles_scratch() {
        let src_buf: Vec<u8> = (0..128).collect();
        let mut dst_buf = vec![0u8; 128];
        let (si, di) = (CountingInfo::default(), CountingInfo::default());
        let one_copy = |dst: &mut [u8]| unsafe {
            memcopy_with_context::<OpaqueContext, OpaqueContext>(
                &si,
                src_buf.as_ptr(),
                &di,
                dst.as_mut_ptr(),
                128,
            );
        };
        one_copy(&mut dst_buf);
        let hits0 = bounce_scratch_stats().hits;
        for _ in 0..4 {
            one_copy(&mut dst_buf);
        }
        let hits1 = bounce_scratch_stats().hits;
        // Lower bound of one: the shelf is process-global, so a
        // concurrently-running bounce test may momentarily hold the
        // parked buffer — but four sequential copies cannot all miss.
        assert!(hits1 > hits0, "bounce scratch not recycled: {hits0} -> {hits1}");
        assert_eq!(dst_buf, src_buf);
    }

    /// The Counting→Counting collection copy books each side once.
    #[test]
    fn counting_pair_books_each_side_once() {
        let s = schema();
        let si = CountingInfo::default();
        let mut src =
            RawCollection::<SoAVec<CountingContext>>::new_in(s.clone(), si.clone());
        src.resize(8);
        let di = CountingInfo::default();
        let mut dst = RawCollection::<SoAVec<CountingContext>>::new_in(s, di.clone());
        let in_before = di.0.bytes_copied_in.load(Ordering::Relaxed);
        copy_collection(&src, &mut dst);
        let out = si.0.bytes_copied_out.load(Ordering::Relaxed);
        let inn = di.0.bytes_copied_in.load(Ordering::Relaxed) - in_before;
        assert!(out > 0);
        // Transfer traffic is symmetric: src read == dst written. (dst
        // allocation growth books no copy_in; only the transfer does.)
        assert_eq!(out, inn, "src read {out} != dst written {inn}");
    }
}

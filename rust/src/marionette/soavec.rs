//! The vector-per-property layout holder (paper: `VectorLikePerProperty`).
//!
//! Every field owns one context-allocated buffer. Fields with `extent > 1`
//! (array properties) store their lanes *plane-major*: lane `k` of all
//! items is contiguous — "stored in separate arrays for each type" as the
//! paper specifies for array properties — so the element address is
//! `buf + (k * cap + i) * size`, with `cap` the tag capacity.

use std::sync::Arc;

use super::buffer::RawBuf;
use super::holder::{LayoutHolder, PlaneView};
use super::memory::MemoryContext;
use super::schema::{FieldMeta, Schema, TagId};

pub struct SoAVecHolder<C: MemoryContext> {
    schema: Arc<Schema>,
    info: C::Info,
    /// One buffer per field (indexed by `FieldMeta::index`).
    bufs: Vec<RawBuf<C>>,
    /// Length per tag slot.
    lens: Vec<usize>,
    /// Capacity (elements) per tag slot.
    caps: Vec<usize>,
}

impl<C: MemoryContext> SoAVecHolder<C> {
    #[inline(always)]
    fn cap_of(&self, meta: FieldMeta) -> usize {
        self.caps[meta.tag as usize]
    }

    /// Grow every buffer of `tag` to capacity `new_cap`, moving planes.
    fn regrow_tag(&mut self, tag: usize, new_cap: usize) {
        let old_cap = self.caps[tag];
        let len = self.lens[tag];
        let metas: Vec<FieldMeta> = self
            .schema
            .tag_layout(TagId(tag as u32))
            .fields
            .iter()
            .map(|&f| self.schema.meta(f))
            .collect();
        for m in metas {
            let esz = m.size as usize;
            let mut nb = RawBuf::<C>::with_capacity(
                new_cap * m.extent as usize * esz,
                m.align as usize,
                self.info.clone(),
            );
            let ob = &self.bufs[m.index as usize];
            for k in 0..m.extent as usize {
                unsafe {
                    if len > 0 {
                        C::copy_within(
                            &self.info,
                            nb.as_mut_ptr().add(k * new_cap * esz),
                            ob.as_ptr().add(k * old_cap * esz),
                            len * esz,
                        );
                    }
                    // Zero the free region of the plane so future growth
                    // within capacity exposes zeros.
                    nb.zero_range(
                        (k * new_cap + len) * esz,
                        (new_cap - len) * esz,
                    );
                }
            }
            self.bufs[m.index as usize] = nb;
        }
        self.caps[tag] = new_cap;
    }
}

impl<C: MemoryContext> LayoutHolder for SoAVecHolder<C> {
    type Ctx = C;

    fn new(schema: Arc<Schema>, info: C::Info) -> Self {
        let bufs = schema
            .metas()
            .iter()
            .map(|m| RawBuf::new(m.align as usize, info.clone()))
            .collect();
        let nt = schema.num_tags();
        SoAVecHolder { schema, info, bufs, lens: vec![0; nt], caps: vec![0; nt] }
    }

    fn schema(&self) -> &Arc<Schema> {
        &self.schema
    }

    fn info(&self) -> &C::Info {
        &self.info
    }

    fn set_info(&mut self, info: C::Info) {
        for b in &mut self.bufs {
            b.rehome(info.clone());
        }
        self.info = info;
    }

    fn tag_len(&self, tag: TagId) -> usize {
        self.lens[tag.index()]
    }

    fn tag_capacity(&self, tag: TagId) -> usize {
        self.caps[tag.index()]
    }

    fn resize_tag(&mut self, tag: TagId, len: usize) {
        let t = tag.index();
        let old_len = self.lens[t];
        if len > self.caps[t] {
            let new_cap = len.max(self.caps[t] * 2).max(8);
            self.regrow_tag(t, new_cap);
        } else if len > old_len {
            // Within capacity: planes keep zeroed free regions only if no
            // erase/shrink dirtied them; zero explicitly to be safe.
            let metas: Vec<FieldMeta> = self
                .schema
                .tag_layout(tag)
                .fields
                .iter()
                .map(|&f| self.schema.meta(f))
                .collect();
            let cap = self.caps[t];
            for m in metas {
                let esz = m.size as usize;
                for k in 0..m.extent as usize {
                    unsafe {
                        self.bufs[m.index as usize]
                            .zero_range((k * cap + old_len) * esz, (len - old_len) * esz);
                    }
                }
            }
        }
        self.lens[t] = len;
    }

    fn reserve_tag(&mut self, tag: TagId, cap: usize) {
        let t = tag.index();
        if cap > self.caps[t] {
            self.regrow_tag(t, cap);
        }
    }

    fn clear(&mut self) {
        for l in &mut self.lens {
            *l = 0;
        }
    }

    fn shrink_to_fit(&mut self) {
        for t in 0..self.lens.len() {
            if self.caps[t] > self.lens[t] {
                self.regrow_tag(t, self.lens[t]);
            }
        }
    }

    fn insert_gap(&mut self, tag: TagId, at: usize, n: usize) {
        let t = tag.index();
        let old_len = self.lens[t];
        debug_assert!(at <= old_len);
        self.resize_tag(tag, old_len + n);
        let cap = self.caps[t];
        let metas: Vec<FieldMeta> = self
            .schema
            .tag_layout(tag)
            .fields
            .iter()
            .map(|&f| self.schema.meta(f))
            .collect();
        for m in metas {
            let esz = m.size as usize;
            let buf = &mut self.bufs[m.index as usize];
            for k in 0..m.extent as usize {
                let plane = k * cap;
                unsafe {
                    let base = buf.as_mut_ptr();
                    C::copy_within(
                        &self.info,
                        base.add((plane + at + n) * esz),
                        base.add((plane + at) * esz),
                        (old_len - at) * esz,
                    );
                    buf.zero_range((plane + at) * esz, n * esz);
                }
            }
        }
    }

    fn erase_range(&mut self, tag: TagId, at: usize, n: usize) {
        let t = tag.index();
        let old_len = self.lens[t];
        debug_assert!(at + n <= old_len);
        let cap = self.caps[t];
        let metas: Vec<FieldMeta> = self
            .schema
            .tag_layout(tag)
            .fields
            .iter()
            .map(|&f| self.schema.meta(f))
            .collect();
        for m in metas {
            let esz = m.size as usize;
            let buf = &mut self.bufs[m.index as usize];
            for k in 0..m.extent as usize {
                let plane = k * cap;
                unsafe {
                    let base = buf.as_mut_ptr();
                    C::copy_within(
                        &self.info,
                        base.add((plane + at) * esz),
                        base.add((plane + at + n) * esz),
                        (old_len - at - n) * esz,
                    );
                    // Zero the vacated tail so growth-within-capacity
                    // exposes zeros.
                    buf.zero_range((plane + old_len - n) * esz, n * esz);
                }
            }
        }
        self.lens[t] = old_len - n;
    }

    #[inline(always)]
    unsafe fn elem_ptr(&self, meta: FieldMeta, i: usize, k: usize) -> *const u8 {
        debug_assert!(i < self.lens[meta.tag as usize]);
        debug_assert!(k < meta.extent as usize);
        let cap = self.cap_of(meta);
        self.bufs
            .get_unchecked(meta.index as usize)
            .as_ptr()
            .add((k * cap + i) * meta.size as usize)
    }

    #[inline(always)]
    unsafe fn elem_ptr_mut(&mut self, meta: FieldMeta, i: usize, k: usize) -> *mut u8 {
        debug_assert!(i < self.lens[meta.tag as usize]);
        debug_assert!(k < meta.extent as usize);
        let cap = self.cap_of(meta);
        self.bufs
            .get_unchecked_mut(meta.index as usize)
            .as_mut_ptr()
            .add((k * cap + i) * meta.size as usize)
    }

    fn plane(&self, meta: FieldMeta, k: usize) -> Option<PlaneView> {
        let cap = self.cap_of(meta);
        Some(PlaneView {
            base: unsafe {
                self.bufs[meta.index as usize]
                    .as_ptr()
                    .add(k * cap * meta.size as usize)
            },
            stride: meta.size as usize,
            len: self.lens[meta.tag as usize],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::holder::{read, write};
    use super::super::memory::HostContext;
    use super::*;

    fn schema() -> Arc<Schema> {
        Arc::new(
            Schema::builder("t")
                .per_item::<f32>("e")
                .per_item::<u8>("flag")
                .array::<i32>("arr", 3)
                .build(),
        )
    }

    #[test]
    fn resize_and_access() {
        let s = schema();
        let me = s.meta(s.field_by_name("e").unwrap());
        let ma = s.meta(s.field_by_name("arr").unwrap());
        let mut h = SoAVecHolder::<HostContext>::new(s, ());
        h.resize_tag(TagId::ITEMS, 100);
        assert_eq!(h.tag_len(TagId::ITEMS), 100);
        unsafe {
            // Growth is zero-filled.
            assert_eq!(read::<f32, _>(&h, me, 50, 0), 0.0);
            write::<f32, _>(&mut h, me, 50, 0, 2.5);
            assert_eq!(read::<f32, _>(&h, me, 50, 0), 2.5);
            write::<i32, _>(&mut h, ma, 7, 2, -9);
            assert_eq!(read::<i32, _>(&h, ma, 7, 2), -9);
            assert_eq!(read::<i32, _>(&h, ma, 7, 1), 0);
        }
    }

    #[test]
    fn growth_preserves_planes() {
        let s = schema();
        let ma = s.meta(s.field_by_name("arr").unwrap());
        let mut h = SoAVecHolder::<HostContext>::new(s, ());
        h.resize_tag(TagId::ITEMS, 4);
        for i in 0..4 {
            for k in 0..3 {
                unsafe { write::<i32, _>(&mut h, ma, i, k, (10 * k + i) as i32) };
            }
        }
        h.resize_tag(TagId::ITEMS, 1000); // forces regrow + plane moves
        for i in 0..4 {
            for k in 0..3 {
                unsafe {
                    assert_eq!(read::<i32, _>(&h, ma, i, k), (10 * k + i) as i32);
                }
            }
        }
        unsafe { assert_eq!(read::<i32, _>(&h, ma, 999, 2), 0) };
    }

    #[test]
    fn planes_are_contiguous() {
        let s = schema();
        let ma = s.meta(s.field_by_name("arr").unwrap());
        let mut h = SoAVecHolder::<HostContext>::new(s, ());
        h.resize_tag(TagId::ITEMS, 10);
        let p = h.plane(ma, 1).unwrap();
        assert_eq!(p.stride, 4);
        assert_eq!(p.len, 10);
    }

    #[test]
    fn insert_erase_roundtrip() {
        let s = schema();
        let me = s.meta(s.field_by_name("e").unwrap());
        let mut h = SoAVecHolder::<HostContext>::new(s, ());
        h.resize_tag(TagId::ITEMS, 4);
        for i in 0..4 {
            unsafe { write::<f32, _>(&mut h, me, i, 0, i as f32 + 1.0) };
        }
        h.insert_gap(TagId::ITEMS, 2, 2);
        let vals: Vec<f32> =
            (0..6).map(|i| unsafe { read::<f32, _>(&h, me, i, 0) }).collect();
        assert_eq!(vals, [1.0, 2.0, 0.0, 0.0, 3.0, 4.0]);
        h.erase_range(TagId::ITEMS, 1, 3);
        // Erasing [1, 4) from [1, 2, 0, 0, 3, 4] leaves [1, 3, 4].
        let vals: Vec<f32> =
            (0..3).map(|i| unsafe { read::<f32, _>(&h, me, i, 0) }).collect();
        assert_eq!(vals, [1.0, 3.0, 4.0]);
    }

    #[test]
    fn erase_then_grow_exposes_zeros() {
        let s = schema();
        let me = s.meta(s.field_by_name("e").unwrap());
        let mut h = SoAVecHolder::<HostContext>::new(s, ());
        h.resize_tag(TagId::ITEMS, 3);
        for i in 0..3 {
            unsafe { write::<f32, _>(&mut h, me, i, 0, 7.0) };
        }
        h.erase_range(TagId::ITEMS, 0, 3);
        h.resize_tag(TagId::ITEMS, 3);
        for i in 0..3 {
            unsafe { assert_eq!(read::<f32, _>(&h, me, i, 0), 0.0) };
        }
    }

    #[test]
    fn shrink_to_fit_reduces_capacity() {
        let s = schema();
        let mut h = SoAVecHolder::<HostContext>::new(s, ());
        h.resize_tag(TagId::ITEMS, 100);
        h.resize_tag(TagId::ITEMS, 5);
        assert!(h.tag_capacity(TagId::ITEMS) >= 100);
        h.shrink_to_fit();
        assert_eq!(h.tag_capacity(TagId::ITEMS), 5);
        assert_eq!(h.tag_len(TagId::ITEMS), 5);
    }
}

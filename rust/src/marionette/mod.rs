//! The Marionette core library: data structure description and management.
//!
//! The design mirrors the paper (§V–§VII):
//!
//! * a collection is described by a list of **properties** — per-item
//!   scalars, fixed-extent arrays, jagged vectors, globals — captured in a
//!   [`schema::Schema`];
//! * a **layout** ([`layout::Layout`]) decides how those properties are
//!   materialised in memory: one growable array per property
//!   ([`layout::SoAVec`], the paper's `VectorLikePerProperty`), or a single
//!   blob per size tag with array-of-structures ([`layout::AoS`]),
//!   structure-of-arrays ([`layout::SoABlob`]) or blocked AoSoA
//!   ([`layout::AoSoA`]) ordering (the paper's `DynamicStruct` family);
//! * a **memory context** ([`memory::MemoryContext`]) decides where the
//!   bytes live and how they are allocated, set and copied (paper §VII-A);
//! * **transfers** ([`transfer`]) copy collections across layouts and
//!   contexts through a priority ladder that falls back from single-memcpy
//!   fast paths to element-wise copies (the paper's
//!   `TransferSpecification` / `TransferPriority`);
//! * the **interface layer** ([`interface`]) decouples the typed interface
//!   from the backing store: borrowed views attach to any schema-matching
//!   [`interface::PlaneSource`] (owned collections, pooled staging
//!   collections, downloaded device planes via
//!   [`interface::SlicePlanes`]), and the fluent [`interface::Build`]er
//!   plus the generated `convert_to` / `stage_into` sugar are the
//!   streamlined entry points of §VI (DESIGN.md §6);
//! * the [`crate::marionette_collection!`] macro generates a typed,
//!   object-oriented interface (collection accessors, object proxies,
//!   owned objects, sub-group views, borrowed source-erased views) over
//!   any layout — the analogue of the paper's `MARIONETTE_DECLARE_*`
//!   macros — with all offsets computed at compile time so the generated
//!   code matches handwritten structures (paper §VIII; validated in
//!   `benches/zero_cost.rs`).
//!
//! Everything is resolved statically: no virtual dispatch on the element
//! access paths, no allocation beyond the underlying storage.

pub mod blob;
pub mod buffer;
pub mod collection;
pub mod holder;
pub mod interface;
pub mod layout;
pub mod macros;
pub mod memory;
pub mod pod;
pub mod schema;
pub mod soavec;
pub mod trace;
pub mod transfer;
pub mod wire;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use super::blob::{AoSScheme, AoSoAScheme, BlobLayoutKind, SoABlobScheme};
    pub use super::collection::{JaggedView, RawCollection};
    pub use super::holder::LayoutHolder;
    pub use super::interface::{
        check_attach, AttachError, Build, CollectionFamily, PlaneSource, PlaneSourceMut,
        SlicePlanes, SourceJagged, TracingSource, TracingSourceMut,
    };
    pub use super::layout::{AoS, AoSoA, Layout, PlaneShape, SoABlob, SoAVec};
    pub use super::memory::{
        AlignedContext, ArenaContext, ArenaInfo, CountingContext, CountingInfo, CtxTraceStats,
        FaultCell, FaultyContext, FaultyInfo, HostContext, MemoryContext, Pool, PoolContext,
        PoolInfo, PoolSnapshot, StagingContext, StagingInfo, TraceInfo, TracingContext,
    };
    pub use super::trace::{
        recommend_layout, warm_staging_plan, FieldTraceSummary, LayoutChoice, RouteTraceSummary,
        TraceTape,
    };
    pub use super::pod::{Dtype, Pod};
    pub use super::schema::{
        compute_metas, meta_by_name, DescKind, FieldDesc, FieldId, FieldKind, FieldMeta,
        JaggedProp, Schema, SchemaBuilder, TagId,
    };
    pub use super::transfer::{
        arm_transfer_fault, bounce_scratch_stats, copy_collection, copy_collection_stats,
        copy_collection_unplanned, disarm_transfer_fault, local_plan_handle_stats,
        memcopy_with_context, plan_cache_generation, plan_cache_shard_stats, plan_cache_stats,
        plan_for, prewarm_plan, register_specialized, transfer_faults_injected, BounceScratchStats,
        PlanCacheShardStats, PlanCacheStats, PlanHandle, PlanHandleStats, PlanOp, TransferPlan,
        TransferPriority, TransferStats, PLAN_CACHE_SHARDS,
    };
    pub use super::wire::{
        crc32, encode_frame, schema_hash, AlignedBytes, Frame, FrameSource, FrameSourceMut,
        WireError, WIRE_VERSION,
    };
}

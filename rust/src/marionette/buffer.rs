//! Context-aware buffers: the storage primitives under every layout.
//!
//! [`RawBuf`] is an untyped, context-allocated byte buffer with geometric
//! growth; [`ContextAwareVec`] is the typed, `Vec<T>`-like container on top
//! (the paper's `ContextAwareVector` built on `ContextAwareAllocator`).

use std::alloc::Layout as AllocLayout;
use std::marker::PhantomData;
use std::ptr::NonNull;

use super::memory::MemoryContext;
use super::pod::Pod;

/// An untyped byte buffer allocated from a memory context.
pub struct RawBuf<C: MemoryContext> {
    ptr: NonNull<u8>,
    cap: usize,
    align: usize,
    info: C::Info,
}

// SAFETY: RawBuf owns its allocation exclusively; C::Info is Send + Sync.
unsafe impl<C: MemoryContext> Send for RawBuf<C> {}
unsafe impl<C: MemoryContext> Sync for RawBuf<C> {}

impl<C: MemoryContext> RawBuf<C> {
    pub fn new(align: usize, info: C::Info) -> Self {
        let layout = AllocLayout::from_size_align(0, align).expect("bad align");
        let ptr = C::allocate(&info, layout);
        RawBuf { ptr, cap: 0, align, info }
    }

    pub fn with_capacity(bytes: usize, align: usize, info: C::Info) -> Self {
        let mut b = Self::new(align, info);
        b.grow_exact(bytes);
        b
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn align(&self) -> usize {
        self.align
    }

    pub fn info(&self) -> &C::Info {
        &self.info
    }

    pub fn as_ptr(&self) -> *const u8 {
        self.ptr.as_ptr()
    }

    pub fn as_mut_ptr(&mut self) -> *mut u8 {
        self.ptr.as_ptr()
    }

    fn layout_for(&self, bytes: usize) -> AllocLayout {
        AllocLayout::from_size_align(bytes, self.align).expect("capacity overflow")
    }

    /// Grow to exactly `new_cap` bytes, preserving current contents.
    /// Shrinks are honoured too (used by `shrink_to_fit`).
    pub fn grow_exact(&mut self, new_cap: usize) {
        if new_cap == self.cap {
            return;
        }
        let new_ptr = C::allocate(&self.info, self.layout_for(new_cap));
        let keep = self.cap.min(new_cap);
        if keep > 0 {
            // Same-context relocation.
            unsafe { C::copy_within(&self.info, new_ptr.as_ptr(), self.ptr.as_ptr(), keep) };
        }
        let old_layout = self.layout_for(self.cap);
        unsafe { C::deallocate(&self.info, self.ptr, old_layout) };
        self.ptr = new_ptr;
        self.cap = new_cap;
    }

    /// Ensure capacity for at least `needed` bytes (geometric growth).
    pub fn reserve_total(&mut self, needed: usize) {
        if needed > self.cap {
            let target = needed.max(self.cap * 2).max(64);
            self.grow_exact(target);
        }
    }

    /// Zero-fill the byte range `[at, at + len)`.
    ///
    /// # Safety
    /// The range must be within capacity.
    pub unsafe fn zero_range(&mut self, at: usize, len: usize) {
        C::memset(&self.info, self.ptr.as_ptr().add(at), len, 0);
    }

    /// Re-home this buffer onto new context info (the paper's
    /// `update_memory_context_info`: allocate with the new info, copy,
    /// free the old allocation).
    ///
    /// Accounting follows the cross-context transfer contract
    /// (`transfer.rs`): the move books one read on the *source* info and
    /// one write on the *destination* info, and the release of the old
    /// allocation is booked against the source info — so a counting
    /// source sees its bytes go away and an arena source's live ledger
    /// balances instead of drifting.
    pub fn rehome(&mut self, new_info: C::Info) {
        let layout = self.layout_for(self.cap);
        let new_ptr = C::allocate(&new_info, layout);
        if self.cap > 0 {
            if C::HOST_ACCESSIBLE {
                unsafe {
                    C::copy_in(&new_info, new_ptr.as_ptr(), self.ptr.as_ptr(), self.cap);
                }
                C::note_read(&self.info, self.cap);
            } else {
                // Neither side is directly addressable: bounce via the
                // recycled host scratch shelf (transfer.rs).
                let cap = self.cap;
                let src = self.ptr.as_ptr();
                let src_info = &self.info;
                // SAFETY: both buffers are valid for `cap` bytes in
                // their contexts; the scratch covers `cap`.
                super::transfer::with_bounce_scratch(cap, |bounce| unsafe {
                    C::copy_out(src_info, src, bounce.as_mut_ptr(), cap);
                    C::copy_in(&new_info, new_ptr.as_ptr(), bounce.as_ptr(), cap);
                });
            }
        }
        unsafe { C::deallocate(&self.info, self.ptr, layout) };
        self.ptr = new_ptr;
        self.info = new_info;
    }
}

impl<C: MemoryContext> Drop for RawBuf<C> {
    fn drop(&mut self) {
        let layout = self.layout_for(self.cap);
        unsafe { C::deallocate(&self.info, self.ptr, layout) };
    }
}

/// A typed, growable, context-allocated vector.
pub struct ContextAwareVec<T: Pod, C: MemoryContext = super::memory::HostContext> {
    buf: RawBuf<C>,
    len: usize,
    _t: PhantomData<T>,
}

impl<T: Pod, C: MemoryContext> ContextAwareVec<T, C> {
    pub fn new_in(info: C::Info) -> Self {
        ContextAwareVec {
            buf: RawBuf::new(std::mem::align_of::<T>(), info),
            len: 0,
            _t: PhantomData,
        }
    }

    pub fn with_capacity_in(cap: usize, info: C::Info) -> Self {
        let mut v = Self::new_in(info);
        v.reserve(cap);
        v
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.buf.capacity() / std::mem::size_of::<T>().max(1)
    }

    pub fn info(&self) -> &C::Info {
        self.buf.info()
    }

    pub fn reserve(&mut self, extra: usize) {
        self.buf
            .reserve_total((self.len + extra) * std::mem::size_of::<T>());
    }

    pub fn push(&mut self, v: T) {
        self.reserve(1);
        unsafe {
            let dst = (self.buf.as_mut_ptr() as *mut T).add(self.len);
            std::ptr::write(dst, v);
        }
        self.len += 1;
    }

    pub fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        self.len -= 1;
        Some(unsafe { std::ptr::read((self.buf.as_ptr() as *const T).add(self.len)) })
    }

    /// Resize, zero-filling new elements (all `Pod` zero patterns are
    /// valid values).
    pub fn resize_zeroed(&mut self, new_len: usize) {
        if new_len > self.len {
            self.reserve(new_len - self.len);
            unsafe {
                self.buf.zero_range(
                    self.len * std::mem::size_of::<T>(),
                    (new_len - self.len) * std::mem::size_of::<T>(),
                );
            }
        }
        self.len = new_len;
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    pub fn shrink_to_fit(&mut self) {
        self.buf.grow_exact(self.len * std::mem::size_of::<T>());
    }

    /// Insert `n` zeroed elements at `at`, shifting the tail right.
    pub fn insert_zeroed(&mut self, at: usize, n: usize) {
        assert!(at <= self.len, "insert out of bounds");
        self.reserve(n);
        let esz = std::mem::size_of::<T>();
        unsafe {
            let base = self.buf.as_mut_ptr();
            C::copy_within(
                self.buf.info(),
                base.add((at + n) * esz),
                base.add(at * esz),
                (self.len - at) * esz,
            );
            self.buf.zero_range(at * esz, n * esz);
        }
        self.len += n;
    }

    /// Erase `n` elements starting at `at`, shifting the tail left.
    pub fn erase(&mut self, at: usize, n: usize) {
        assert!(at + n <= self.len, "erase out of bounds");
        let esz = std::mem::size_of::<T>();
        unsafe {
            let base = self.buf.as_mut_ptr();
            C::copy_within(
                self.buf.info(),
                base.add(at * esz),
                base.add((at + n) * esz),
                (self.len - at - n) * esz,
            );
        }
        self.len -= n;
    }

    pub fn as_slice(&self) -> &[T] {
        unsafe { std::slice::from_raw_parts(self.buf.as_ptr() as *const T, self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        unsafe {
            std::slice::from_raw_parts_mut(self.buf.as_mut_ptr() as *mut T, self.len)
        }
    }

    pub fn rehome(&mut self, info: C::Info) {
        self.buf.rehome(info);
    }
}

impl<T: Pod> ContextAwareVec<T, super::memory::HostContext> {
    pub fn new() -> Self {
        Self::new_in(())
    }

    pub fn from_slice(s: &[T]) -> Self {
        let mut v = Self::new();
        v.extend_from_slice(s);
        v
    }
}

impl<T: Pod> Default for ContextAwareVec<T, super::memory::HostContext> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Pod, C: MemoryContext> ContextAwareVec<T, C> {
    pub fn extend_from_slice(&mut self, s: &[T]) {
        self.reserve(s.len());
        unsafe {
            let dst = (self.buf.as_mut_ptr() as *mut T).add(self.len);
            std::ptr::copy_nonoverlapping(s.as_ptr(), dst, s.len());
        }
        self.len += s.len();
    }
}

impl<T: Pod, C: MemoryContext> std::ops::Deref for ContextAwareVec<T, C> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod, C: MemoryContext> std::ops::DerefMut for ContextAwareVec<T, C> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

impl<T: Pod, C: MemoryContext> std::fmt::Debug for ContextAwareVec<T, C> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::memory::{ArenaInfo, CountingContext, CountingInfo, HostContext};
    use super::*;

    #[test]
    fn push_pop_index() {
        let mut v = ContextAwareVec::<u32>::new();
        for i in 0..1000 {
            v.push(i);
        }
        assert_eq!(v.len(), 1000);
        assert_eq!(v[999], 999);
        assert_eq!(v.pop(), Some(999));
        assert_eq!(v.len(), 999);
    }

    #[test]
    fn resize_zeroes_new_tail() {
        let mut v = ContextAwareVec::<f32>::from_slice(&[1.0, 2.0]);
        v.resize_zeroed(5);
        assert_eq!(v.as_slice(), &[1.0, 2.0, 0.0, 0.0, 0.0]);
        v.resize_zeroed(1);
        assert_eq!(v.as_slice(), &[1.0]);
        // Grow again: previously truncated bytes must be re-zeroed.
        v.resize_zeroed(3);
        assert_eq!(v.as_slice(), &[1.0, 0.0, 0.0]);
    }

    #[test]
    fn insert_erase_shift() {
        let mut v = ContextAwareVec::<u16>::from_slice(&[1, 2, 3, 4]);
        v.insert_zeroed(2, 2);
        assert_eq!(v.as_slice(), &[1, 2, 0, 0, 3, 4]);
        v.erase(1, 3);
        assert_eq!(v.as_slice(), &[1, 3, 4]);
        v.erase(0, 3);
        assert!(v.is_empty());
    }

    #[test]
    #[should_panic(expected = "erase out of bounds")]
    fn erase_oob_panics() {
        let mut v = ContextAwareVec::<u8>::from_slice(&[1]);
        v.erase(0, 2);
    }

    #[test]
    fn shrink_to_fit_keeps_data() {
        let mut v = ContextAwareVec::<u64>::new();
        v.reserve(1000);
        v.extend_from_slice(&[7, 8, 9]);
        assert!(v.capacity() >= 1000);
        v.shrink_to_fit();
        assert_eq!(v.capacity(), 3);
        assert_eq!(v.as_slice(), &[7, 8, 9]);
    }

    #[test]
    fn counting_context_tracks_growth() {
        let info = CountingInfo::default();
        let mut v = ContextAwareVec::<u8, CountingContext>::new_in(info.clone());
        for i in 0..10_000u32 {
            v.push(i as u8);
        }
        drop(v);
        // Geometric growth: allocations are O(log n), and every alloc has
        // a matching dealloc after drop. (+1: the empty initial alloc.)
        let allocs = info.0.allocs.load(std::sync::atomic::Ordering::Relaxed);
        assert!(allocs <= 12, "expected geometric growth, got {allocs} allocs");
        assert_eq!(info.0.live_allocs(), 0);
    }

    #[test]
    fn arena_vec_works() {
        let info = ArenaInfo::default();
        let mut v =
            ContextAwareVec::<f64, super::super::memory::ArenaContext>::new_in(info);
        for i in 0..100 {
            v.push(i as f64);
        }
        assert_eq!(v[99], 99.0);
    }

    #[test]
    fn rehome_preserves_contents() {
        let info_a = CountingInfo::default();
        let info_b = CountingInfo::default();
        let mut v = ContextAwareVec::<u32, CountingContext>::new_in(info_a.clone());
        v.extend_from_slice(&[1, 2, 3]);
        v.rehome(info_b.clone());
        assert_eq!(v.as_slice(), &[1, 2, 3]);
        // New info owns the allocation now.
        assert!(info_b.0.allocs.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        drop(v);
        assert_eq!(info_a.0.live_allocs(), 0);
    }

    #[test]
    fn raw_buf_zero_capacity_roundtrip() {
        let b = RawBuf::<HostContext>::new(8, ());
        assert_eq!(b.capacity(), 0);
        drop(b);
    }

    #[test]
    fn rehome_books_transfer_and_release_on_both_sides() {
        use std::sync::atomic::Ordering;
        let info_a = CountingInfo::default();
        let info_b = CountingInfo::default();
        let mut b = RawBuf::<CountingContext>::with_capacity(256, 8, info_a.clone());
        assert_eq!(info_a.0.live_bytes(), 256);
        b.rehome(info_b.clone());
        // The move reads the source once and writes the destination once
        // (the cross-context accounting contract)...
        assert_eq!(info_a.0.bytes_copied_out.load(Ordering::Relaxed), 256);
        assert_eq!(info_b.0.bytes_copied_in.load(Ordering::Relaxed), 256);
        // ...and the source books the release: no live bytes left behind.
        assert_eq!(info_a.0.live_allocs(), 0);
        assert_eq!(info_a.0.live_bytes(), 0);
        assert_eq!(info_b.0.live_bytes(), 256);
        drop(b);
        assert_eq!(info_b.0.live_bytes(), 0);
    }

    #[test]
    fn rehome_out_of_arena_balances_its_ledger() {
        use super::super::memory::{Arena, ArenaContext};
        let from = ArenaInfo(Arena::new());
        let to = ArenaInfo(Arena::new());
        let mut b = RawBuf::<ArenaContext>::with_capacity(512, 16, from.clone());
        unsafe { b.zero_range(0, 512) };
        assert_eq!(from.0.live_bytes(), 512);
        b.rehome(to.clone());
        // The source arena saw the release and can reclaim its chunks.
        assert_eq!(from.0.live_bytes(), 0);
        assert!(from.0.reset());
        assert_eq!(from.0.capacity(), 0);
        assert_eq!(to.0.live_bytes(), 512);
        drop(b);
        assert_eq!(to.0.live_bytes(), 0);
    }

    #[test]
    fn pooled_vec_checks_buffers_back_in_on_drop() {
        use super::super::memory::{PoolContext, PoolInfo};
        let info = PoolInfo::<CountingContext>::default();
        let inner = info.0.inner().clone();
        {
            let mut v =
                ContextAwareVec::<u64, PoolContext<CountingContext>>::new_in(info.clone());
            for i in 0..1000u64 {
                v.push(i);
            }
            assert!(info.0.outstanding() >= 1);
        } // drop: capacity parks in the pool instead of being freed
        assert_eq!(info.0.outstanding(), 0);
        assert!(info.0.held_bytes() >= 1000 * 8);
        let misses_before = info.0.stats().misses;
        // A second vec replays the same growth ladder entirely from the
        // recycled blocks: zero new inner allocations.
        let inner_allocs = inner.0.allocs.load(std::sync::atomic::Ordering::Relaxed);
        let mut v2 =
            ContextAwareVec::<u64, PoolContext<CountingContext>>::new_in(info.clone());
        for i in 0..1000u64 {
            v2.push(i);
        }
        assert_eq!(v2[999], 999);
        assert_eq!(info.0.stats().misses, misses_before);
        assert_eq!(
            inner.0.allocs.load(std::sync::atomic::Ordering::Relaxed),
            inner_allocs,
            "steady-state growth must not touch the inner allocator"
        );
    }
}

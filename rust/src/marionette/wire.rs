//! Versioned, zero-copy wire format for Marionette collections
//! (DESIGN.md §11).
//!
//! Marionette blobs are already schema-stamped, contiguous, and
//! layout-described, so crossing a process boundary needs no
//! per-element re-serialization: a frame is a small self-describing
//! header followed by the coalesced per-(field, lane) planes the
//! TransferPlan engine already computes. On receipt the buffer is
//! *attached*, not parsed — [`FrameSource`] implements [`PlaneSource`]
//! directly over the received bytes, so the PR 5 view machinery (and
//! `check_attach`) reads sensor data straight out of the socket buffer
//! with zero plane copies.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! off  size  field
//!   0     4  magic        "MRN1" (0x314E524D)
//!   4     4  version      WIRE_VERSION
//!   8     4  crc32        IEEE CRC over bytes [16..total]
//!  12     4  reserved     0
//!  16     4  header_len   bytes 0..body start, 8-aligned
//!  20     4  layout_code  source layout family (diagnostic only)
//!  24     8  body_len     plane bytes
//!  32     8  schema_hash  FNV-1a over the schema structure
//!  40     8  frame_id     caller sequence / event id
//!  48     4  num_tags     size-tag count
//!  52     4  num_fields   field count
//!  56   8*T  tag_lens     per-tag element counts
//!   .  16*F  field table  {dtype u8, tag u8, pad u16, extent u32, offset u64}
//!   .        zero pad to header_len
//!  hl    bl  body         dense planes, each field 8-aligned;
//!                         lane k of field f at offset[f] + k*len*size
//! ```
//!
//! Compatibility rule: a frame attaches only to a schema whose
//! structural hash ([`schema_hash`]: field names, dtypes, kinds,
//! extents — the same relation as `Schema::same_structure`) equals the
//! header's hash. Version skew is an error, never a silent reinterpret:
//! readers reject any `version != WIRE_VERSION` with
//! [`WireError::VersionSkew`].

use std::fmt;
use std::sync::Arc;

use super::interface::{PlaneSource, PlaneSourceMut};
use super::pod::Dtype;
use super::schema::{FieldKind, FieldMeta, Schema, TagId, MAX_TAGS};
use crate::marionette::holder::PlaneView;

/// Wire protocol version. Bump on any incompatible header/body change;
/// readers hard-reject other versions (no cross-version decoding).
pub const WIRE_VERSION: u32 = 1;

/// Frame magic, "MRN1" read as little-endian u32.
pub const WIRE_MAGIC: u32 = 0x314E_524D;

/// Size of the fixed header prefix (through `num_fields`).
pub const FIXED_HEADER: usize = 56;

/// Typed wire failures. Every decode/attach error is one of these —
/// a poisoned frame must never panic the reconstruction process (it is
/// quarantined, mirroring the PR 9 retry/quarantine contract).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The buffer (or stream) ended before a complete frame.
    Truncated { need: usize, have: usize },
    /// The first four bytes are not the frame magic.
    BadMagic { got: u32 },
    /// The frame was written by a different protocol version.
    VersionSkew { got: u32, want: u32 },
    /// The frame's schema hash does not match the receiver's schema.
    SchemaMismatch { want: u64, got: u64 },
    /// Body/header checksum mismatch (bit rot or mid-frame corruption).
    Crc { want: u32, got: u32 },
    /// Structurally invalid header (bad lengths, offsets, codes).
    Malformed { what: String },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "wire: truncated frame (need {need} bytes, have {have})")
            }
            WireError::BadMagic { got } => {
                write!(f, "wire: bad magic {got:#010x} (want {WIRE_MAGIC:#010x})")
            }
            WireError::VersionSkew { got, want } => {
                write!(f, "wire: version skew (frame v{got}, reader v{want})")
            }
            WireError::SchemaMismatch { want, got } => {
                write!(f, "wire: schema hash mismatch (want {want:#018x}, got {got:#018x})")
            }
            WireError::Crc { want, got } => {
                write!(f, "wire: CRC mismatch (header {want:#010x}, computed {got:#010x})")
            }
            WireError::Malformed { what } => write!(f, "wire: malformed frame: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------
// CRC32 (IEEE, reflected) — hand-rolled table, no external crates.
// ---------------------------------------------------------------------

const fn make_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = make_crc_table();

/// IEEE CRC32 of `bytes` (the checksum the frame header carries).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Schema hash — FNV-1a over the structural relation `same_structure`
// compares: per-field name, dtype, kind (with jagged group), extent.
// ---------------------------------------------------------------------

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h = (*h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
}

/// Structural hash of a schema. Two schemas hash equal iff (modulo
/// collisions) `Schema::same_structure` would accept them — the wire
/// compatibility rule is exactly the in-process attach rule.
pub fn schema_hash(schema: &Schema) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv(&mut h, &(schema.num_fields() as u64).to_le_bytes());
    for (_, field) in schema.fields() {
        fnv(&mut h, field.name.as_bytes());
        fnv(&mut h, &[0xFF, dtype_code(field.dtype)]);
        let (kc, kj) = kind_code(field.kind);
        fnv(&mut h, &[kc]);
        fnv(&mut h, &kj.to_le_bytes());
        fnv(&mut h, &field.extent.to_le_bytes());
    }
    h
}

fn kind_code(kind: FieldKind) -> (u8, u32) {
    match kind {
        FieldKind::PerItem => (0, 0),
        FieldKind::JaggedPrefix(j) => (1, j),
        FieldKind::JaggedValues(j) => (2, j),
        FieldKind::Global => (3, 0),
    }
}

/// Stable wire code for a dtype (declaration order; never reorder).
pub fn dtype_code(d: Dtype) -> u8 {
    match d {
        Dtype::F32 => 0,
        Dtype::F64 => 1,
        Dtype::I8 => 2,
        Dtype::U8 => 3,
        Dtype::I16 => 4,
        Dtype::U16 => 5,
        Dtype::I32 => 6,
        Dtype::U32 => 7,
        Dtype::I64 => 8,
        Dtype::U64 => 9,
    }
}

/// Inverse of [`dtype_code`].
pub fn dtype_from_code(c: u8) -> Option<Dtype> {
    Some(match c {
        0 => Dtype::F32,
        1 => Dtype::F64,
        2 => Dtype::I8,
        3 => Dtype::U8,
        4 => Dtype::I16,
        5 => Dtype::U16,
        6 => Dtype::I32,
        7 => Dtype::U32,
        8 => Dtype::I64,
        9 => Dtype::U64,
        _ => return None,
    })
}

/// Diagnostic layout-family code stamped into the header (the body is
/// always normalized dense planes regardless of the source layout).
pub fn layout_code_for(source_name: &str) -> u32 {
    match source_name {
        "soa-vec" => 1,
        "aos" => 2,
        "soa-blob" => 3,
        "aosoa" => 4,
        _ => 0,
    }
}

// ---------------------------------------------------------------------
// 8-aligned byte buffer — frames must live in 8-aligned storage so the
// typed planes inside the body can be read in place. `Vec<u8>` only
// guarantees byte alignment; this wrapper is backed by `Vec<u64>`.
// ---------------------------------------------------------------------

/// An owned byte buffer whose base address is 8-aligned. Sockets read
/// directly into it; [`Frame::decode`] takes it over without copying.
pub struct AlignedBytes {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBytes {
    /// A zeroed buffer of `len` bytes.
    pub fn with_len(len: usize) -> AlignedBytes {
        AlignedBytes { words: vec![0u64; len.div_ceil(8)], len }
    }

    /// Copy a plain slice into aligned storage (tests and re-framing).
    pub fn from_slice(b: &[u8]) -> AlignedBytes {
        let mut a = AlignedBytes::with_len(b.len());
        a.as_mut_slice().copy_from_slice(b);
        a
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        // SAFETY: words owns at least len bytes of initialized storage.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        // SAFETY: as as_slice; exclusive borrow of self.
        unsafe { std::slice::from_raw_parts_mut(self.words.as_mut_ptr() as *mut u8, self.len) }
    }
}

impl Clone for AlignedBytes {
    fn clone(&self) -> AlignedBytes {
        AlignedBytes { words: self.words.clone(), len: self.len }
    }
}

impl fmt::Debug for AlignedBytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "AlignedBytes({} bytes)", self.len)
    }
}

// ---------------------------------------------------------------------
// Little-endian field helpers.
// ---------------------------------------------------------------------

fn put_u32(b: &mut [u8], off: usize, v: u32) {
    b[off..off + 4].copy_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut [u8], off: usize, v: u64) {
    b[off..off + 8].copy_from_slice(&v.to_le_bytes());
}

fn get_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
}

fn get_u64(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

fn align8(n: usize) -> usize {
    (n + 7) & !7
}

/// Validate the fixed prefix and return the frame's total byte length.
/// Transports use this to size the receive buffer before the body
/// arrives; it checks everything checkable from the first
/// [`FIXED_HEADER`] bytes (magic, version, length sanity).
pub fn peek_total_len(head: &[u8]) -> Result<usize, WireError> {
    if head.len() < FIXED_HEADER {
        return Err(WireError::Truncated { need: FIXED_HEADER, have: head.len() });
    }
    let magic = get_u32(head, 0);
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic { got: magic });
    }
    let version = get_u32(head, 4);
    if version != WIRE_VERSION {
        return Err(WireError::VersionSkew { got: version, want: WIRE_VERSION });
    }
    let header_len = get_u32(head, 16) as usize;
    let body_len = get_u64(head, 24) as usize;
    let num_tags = get_u32(head, 48) as usize;
    let num_fields = get_u32(head, 52) as usize;
    if header_len % 8 != 0 || num_tags > MAX_TAGS {
        return Err(WireError::Malformed {
            what: format!("header_len {header_len} / num_tags {num_tags}"),
        });
    }
    let table_end = FIXED_HEADER + num_tags * 8 + num_fields * 16;
    if header_len < table_end {
        return Err(WireError::Malformed {
            what: format!("header_len {header_len} < table end {table_end}"),
        });
    }
    header_len.checked_add(body_len).ok_or(WireError::Malformed {
        what: "frame length overflow".to_string(),
    })
}

// ---------------------------------------------------------------------
// Encoding.
// ---------------------------------------------------------------------

/// Serialize any [`PlaneSource`] into a wire frame. The body is written
/// as dense per-(field, lane) planes: one bulk copy per plane when the
/// source's cached plane is already dense, a strided sweep otherwise —
/// never a per-element re-serialization.
pub fn encode_frame<S: PlaneSource + ?Sized>(src: &S, frame_id: u64) -> AlignedBytes {
    let schema = src.schema().clone();
    let num_tags = schema.num_tags();
    let num_fields = schema.num_fields();

    let mut tag_lens = vec![0u64; num_tags];
    for (t, len) in tag_lens.iter_mut().enumerate() {
        *len = src.tag_len(TagId(t as u32)) as u64;
    }

    // Body layout: fields in schema order, each 8-aligned; lanes of one
    // field packed contiguously (lane stride = plane_len * elem size,
    // which preserves element alignment since every dtype size divides 8).
    let metas = schema.metas();
    let mut offsets = vec![0u64; num_fields];
    let mut body_len = 0usize;
    for (i, meta) in metas.iter().enumerate() {
        body_len = align8(body_len);
        offsets[i] = body_len as u64;
        let plane_len = tag_lens[meta.tag as usize] as usize;
        body_len += meta.extent as usize * plane_len * meta.size as usize;
    }
    body_len = align8(body_len);

    let header_len = align8(FIXED_HEADER + num_tags * 8 + num_fields * 16);
    let total = header_len + body_len;
    let mut out = AlignedBytes::with_len(total);
    let layout_code = layout_code_for(src.source_name());
    let hash = schema_hash(&schema);
    {
        let b = out.as_mut_slice();
        put_u32(b, 0, WIRE_MAGIC);
        put_u32(b, 4, WIRE_VERSION);
        // crc at 8 patched last; reserved at 12 stays 0.
        put_u32(b, 16, header_len as u32);
        put_u32(b, 20, layout_code);
        put_u64(b, 24, body_len as u64);
        put_u64(b, 32, hash);
        put_u64(b, 40, frame_id);
        put_u32(b, 48, num_tags as u32);
        put_u32(b, 52, num_fields as u32);
        for (t, len) in tag_lens.iter().enumerate() {
            put_u64(b, FIXED_HEADER + t * 8, *len);
        }
        let table = FIXED_HEADER + num_tags * 8;
        for (i, meta) in metas.iter().enumerate() {
            let e = table + i * 16;
            let field = schema.field(meta.field_id());
            b[e] = dtype_code(field.dtype);
            b[e + 1] = meta.tag as u8;
            // b[e+2..e+4] pad
            put_u32(b, e + 4, meta.extent);
            put_u64(b, e + 8, offsets[i]);
        }
    }

    // Planes. Raw pointer writes into the body region.
    for (i, meta) in metas.iter().enumerate() {
        let plane_len = tag_lens[meta.tag as usize] as usize;
        let esz = meta.size as usize;
        if plane_len == 0 || esz == 0 {
            continue;
        }
        for k in 0..meta.extent as usize {
            let dst_off = header_len + offsets[i] as usize + k * plane_len * esz;
            let b = out.as_mut_slice();
            match src.plane(*meta, k) {
                Some(p) if p.stride == esz => {
                    // Already-coalesced plane: one bulk copy.
                    // SAFETY: source guarantees plane_len elements; the
                    // destination range was sized above.
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            p.base,
                            b.as_mut_ptr().add(dst_off),
                            plane_len * esz,
                        );
                    }
                }
                Some(p) => {
                    // Regular but strided (AoS records): gather sweep.
                    for idx in 0..plane_len {
                        // SAFETY: idx < plane_len, stride from the source.
                        unsafe {
                            std::ptr::copy_nonoverlapping(
                                p.base.add(idx * p.stride),
                                b.as_mut_ptr().add(dst_off + idx * esz),
                                esz,
                            );
                        }
                    }
                }
                None => {
                    // Irregular layouts (AoSoA): per-element pointers.
                    for idx in 0..plane_len {
                        // SAFETY: idx < tag_len, k < extent.
                        unsafe {
                            std::ptr::copy_nonoverlapping(
                                src.elem_ptr(*meta, idx, k),
                                b.as_mut_ptr().add(dst_off + idx * esz),
                                esz,
                            );
                        }
                    }
                }
            }
        }
    }

    let c = crc32(&out.as_slice()[16..]);
    put_u32(out.as_mut_slice(), 8, c);
    out
}

// ---------------------------------------------------------------------
// Decoding.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct WireField {
    dtype: Dtype,
    tag: u8,
    extent: u32,
    offset: usize,
}

/// A validated received frame: owns the 8-aligned buffer, knows where
/// every plane lives. Attach a typed view via [`Frame::source`] /
/// [`Frame::source_mut`] — the planes are read (and calibrated) in
/// place; the bytes are never copied out.
pub struct Frame {
    bytes: AlignedBytes,
    header_len: usize,
    frame_id: u64,
    layout_code: u32,
    schema_hash: u64,
    tag_lens: [usize; MAX_TAGS],
    num_tags: usize,
    fields: Vec<WireField>,
}

impl Frame {
    /// Validate and take over a received buffer. Checks, in order:
    /// length, magic, version, header sanity, total length, CRC, and
    /// the field table (offsets in bounds, dtype codes valid).
    pub fn decode(bytes: AlignedBytes) -> Result<Frame, WireError> {
        let total = peek_total_len(bytes.as_slice())?;
        let have = bytes.len();
        if have < total {
            return Err(WireError::Truncated { need: total, have });
        }
        if have > total {
            return Err(WireError::Malformed {
                what: format!("{} trailing bytes after frame", have - total),
            });
        }
        let b = bytes.as_slice();
        let want_crc = get_u32(b, 8);
        let got_crc = crc32(&b[16..total]);
        if want_crc != got_crc {
            return Err(WireError::Crc { want: want_crc, got: got_crc });
        }

        let header_len = get_u32(b, 16) as usize;
        let layout_code = get_u32(b, 20);
        let body_len = get_u64(b, 24) as usize;
        let schema_hash = get_u64(b, 32);
        let frame_id = get_u64(b, 40);
        let num_tags = get_u32(b, 48) as usize;
        let num_fields = get_u32(b, 52) as usize;

        let mut tag_lens = [0usize; MAX_TAGS];
        for (t, len) in tag_lens.iter_mut().enumerate().take(num_tags) {
            *len = get_u64(b, FIXED_HEADER + t * 8) as usize;
        }

        let table = FIXED_HEADER + num_tags * 8;
        let mut fields = Vec::with_capacity(num_fields);
        for i in 0..num_fields {
            let e = table + i * 16;
            let dtype = dtype_from_code(b[e]).ok_or_else(|| WireError::Malformed {
                what: format!("field {i}: unknown dtype code {}", b[e]),
            })?;
            let tag = b[e + 1];
            let extent = get_u32(b, e + 4);
            let offset = get_u64(b, e + 8) as usize;
            if tag as usize >= num_tags {
                return Err(WireError::Malformed {
                    what: format!("field {i}: tag {tag} out of range"),
                });
            }
            let plane_len = tag_lens[tag as usize];
            let span = (extent as usize)
                .checked_mul(plane_len)
                .and_then(|n| n.checked_mul(dtype.size()))
                .ok_or_else(|| WireError::Malformed {
                    what: format!("field {i}: plane size overflow"),
                })?;
            if offset % dtype.align() != 0 || offset.saturating_add(span) > body_len {
                return Err(WireError::Malformed {
                    what: format!("field {i}: plane [{offset}, +{span}) outside body {body_len}"),
                });
            }
            fields.push(WireField { dtype, tag, extent, offset });
        }

        Ok(Frame {
            bytes,
            header_len,
            frame_id,
            layout_code,
            schema_hash,
            tag_lens,
            num_tags,
            fields,
        })
    }

    /// Convenience for tests: copy a plain slice into aligned storage
    /// and decode it.
    pub fn decode_slice(b: &[u8]) -> Result<Frame, WireError> {
        Frame::decode(AlignedBytes::from_slice(b))
    }

    pub fn frame_id(&self) -> u64 {
        self.frame_id
    }

    pub fn schema_hash(&self) -> u64 {
        self.schema_hash
    }

    pub fn layout_code(&self) -> u32 {
        self.layout_code
    }

    /// Item count (the ITEMS tag length).
    pub fn items(&self) -> usize {
        self.tag_lens[TagId::ITEMS.index()]
    }

    pub fn byte_len(&self) -> usize {
        self.bytes.len()
    }

    pub fn as_bytes(&self) -> &[u8] {
        self.bytes.as_slice()
    }

    pub fn into_bytes(self) -> AlignedBytes {
        self.bytes
    }

    fn check_schema(&self, schema: &Schema) -> Result<(), WireError> {
        let want = schema_hash(schema);
        if want != self.schema_hash {
            return Err(WireError::SchemaMismatch { want, got: self.schema_hash });
        }
        // The hash already pins the structure; these defensive checks
        // catch a crafted frame whose table disagrees with its hash.
        if schema.num_fields() != self.fields.len() || schema.num_tags() != self.num_tags {
            return Err(WireError::Malformed {
                what: "field/tag table disagrees with schema hash".to_string(),
            });
        }
        for (meta, wf) in schema.metas().iter().zip(&self.fields) {
            let field = schema.field(meta.field_id());
            if field.dtype != wf.dtype || meta.extent != wf.extent || meta.tag != wf.tag as u32 {
                return Err(WireError::Malformed {
                    what: format!("field table disagrees with schema at {:?}", field.name),
                });
            }
        }
        Ok(())
    }

    /// Attach a read-only [`PlaneSource`] over the frame body. Fails
    /// with [`WireError::SchemaMismatch`] unless the receiver's schema
    /// hashes to the frame's hash (the wire twin of `check_attach`).
    pub fn source(&self, schema: &Arc<Schema>) -> Result<FrameSource<'_>, WireError> {
        self.check_schema(schema)?;
        Ok(FrameSource { frame: self, schema: schema.clone() })
    }

    /// Attach a mutable source: in-place compute (e.g. calibration)
    /// writes straight into the received buffer.
    pub fn source_mut(&mut self, schema: &Arc<Schema>) -> Result<FrameSourceMut<'_>, WireError> {
        self.check_schema(schema)?;
        let schema = schema.clone();
        Ok(FrameSourceMut { frame: self, schema })
    }

    #[inline(always)]
    fn plane_base(&self, meta: FieldMeta, k: usize) -> *const u8 {
        let wf = &self.fields[meta.index as usize];
        let plane_len = self.tag_lens[wf.tag as usize];
        let off = self.header_len + wf.offset + k * plane_len * meta.size as usize;
        // SAFETY: decode bounds-checked every field's plane span.
        unsafe { self.bytes.as_slice().as_ptr().add(off) }
    }
}

/// Read-only [`PlaneSource`] over a received frame — the zero-copy
/// attach point: `plane()` hands out views whose base pointers lie
/// inside the frame's own buffer.
pub struct FrameSource<'a> {
    frame: &'a Frame,
    schema: Arc<Schema>,
}

impl PlaneSource for FrameSource<'_> {
    fn schema(&self) -> &Arc<Schema> {
        &self.schema
    }

    fn tag_len(&self, tag: TagId) -> usize {
        self.frame.tag_lens[tag.index()]
    }

    fn source_name(&self) -> &'static str {
        "wire-frame"
    }

    unsafe fn elem_ptr(&self, meta: FieldMeta, i: usize, k: usize) -> *const u8 {
        self.frame.plane_base(meta, k).add(i * meta.size as usize)
    }

    fn plane(&self, meta: FieldMeta, k: usize) -> Option<PlaneView> {
        Some(PlaneView {
            base: self.frame.plane_base(meta, k),
            stride: meta.size as usize,
            len: self.frame.tag_lens[meta.tag as usize],
        })
    }
}

/// Mutable twin of [`FrameSource`]: calibration and other in-place
/// passes write their results directly into the received bytes.
pub struct FrameSourceMut<'a> {
    frame: &'a mut Frame,
    schema: Arc<Schema>,
}

impl PlaneSource for FrameSourceMut<'_> {
    fn schema(&self) -> &Arc<Schema> {
        &self.schema
    }

    fn tag_len(&self, tag: TagId) -> usize {
        self.frame.tag_lens[tag.index()]
    }

    fn source_name(&self) -> &'static str {
        "wire-frame"
    }

    unsafe fn elem_ptr(&self, meta: FieldMeta, i: usize, k: usize) -> *const u8 {
        self.frame.plane_base(meta, k).add(i * meta.size as usize)
    }

    fn plane(&self, meta: FieldMeta, k: usize) -> Option<PlaneView> {
        Some(PlaneView {
            base: self.frame.plane_base(meta, k),
            stride: meta.size as usize,
            len: self.frame.tag_lens[meta.tag as usize],
        })
    }
}

impl PlaneSourceMut for FrameSourceMut<'_> {
    unsafe fn elem_ptr_mut(&mut self, meta: FieldMeta, i: usize, k: usize) -> *mut u8 {
        (self.frame.plane_base(meta, k) as *mut u8).add(i * meta.size as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marionette::interface::SlicePlanes;
    use crate::marionette::schema::Schema;

    fn toy_schema() -> Arc<Schema> {
        Arc::new(
            Schema::builder("toy")
                .per_item::<f32>("energy")
                .per_item::<i32>("counts")
                .global::<u64>("event_id")
                .build(),
        )
    }

    fn toy_frame(id: u64) -> AlignedBytes {
        let schema = toy_schema();
        let energy = [1.5f32, 2.5, 3.5];
        let counts = [10i32, 20, 30];
        let src = SlicePlanes::new(schema, 3)
            .bind("energy", &energy)
            .unwrap()
            .bind("counts", &counts)
            .unwrap()
            .set_global("event_id", 77u64)
            .unwrap();
        encode_frame(&src, id)
    }

    #[test]
    fn round_trips_through_a_slice_source() {
        let bytes = toy_frame(9);
        let frame = Frame::decode(bytes).unwrap();
        assert_eq!(frame.frame_id(), 9);
        assert_eq!(frame.items(), 3);
        let schema = toy_schema();
        let fs = frame.source(&schema).unwrap();
        let m_energy = schema.meta(schema.field_by_name("energy").unwrap());
        let m_counts = schema.meta(schema.field_by_name("counts").unwrap());
        let m_ev = schema.meta(schema.field_by_name("event_id").unwrap());
        unsafe {
            assert_eq!(crate::marionette::interface::read::<f32, _>(&fs, m_energy, 1, 0), 2.5);
            assert_eq!(crate::marionette::interface::read::<i32, _>(&fs, m_counts, 2, 0), 30);
            assert_eq!(crate::marionette::interface::read::<u64, _>(&fs, m_ev, 0, 0), 77);
        }
        // Zero-copy contract: the plane points into the frame's buffer.
        let p = fs.plane(m_energy, 0).unwrap();
        let range = frame.as_bytes().as_ptr_range();
        assert!(p.base >= range.start && p.base < range.end);
    }

    #[test]
    fn crc_catches_body_corruption() {
        let mut bytes = toy_frame(1);
        let n = bytes.len();
        bytes.as_mut_slice()[n - 1] ^= 0x40;
        match Frame::decode(bytes) {
            Err(WireError::Crc { .. }) => {}
            r => panic!("expected Crc, got {:?}", r.err()),
        }
    }

    #[test]
    fn version_skew_and_magic_rejected() {
        let mut bytes = toy_frame(1);
        put_u32(bytes.as_mut_slice(), 4, WIRE_VERSION + 1);
        match Frame::decode(bytes) {
            Err(WireError::VersionSkew { got, want }) => {
                assert_eq!(got, WIRE_VERSION + 1);
                assert_eq!(want, WIRE_VERSION);
            }
            r => panic!("expected VersionSkew, got {:?}", r.err()),
        }
        let mut bytes = toy_frame(1);
        bytes.as_mut_slice()[0] = b'X';
        match Frame::decode(bytes) {
            Err(WireError::BadMagic { .. }) => {}
            r => panic!("expected BadMagic, got {:?}", r.err()),
        }
    }

    #[test]
    fn schema_hash_pins_structure() {
        let a = toy_schema();
        let b = toy_schema();
        assert_eq!(schema_hash(&a), schema_hash(&b));
        let c = Arc::new(
            Schema::builder("toy")
                .per_item::<f64>("energy") // different dtype
                .per_item::<i32>("counts")
                .global::<u64>("event_id")
                .build(),
        );
        assert_ne!(schema_hash(&a), schema_hash(&c));

        let frame = Frame::decode(toy_frame(1)).unwrap();
        match frame.source(&c) {
            Err(WireError::SchemaMismatch { .. }) => {}
            r => panic!("expected SchemaMismatch, got {:?}", r.err().map(|e| e.to_string())),
        }
    }

    #[test]
    fn truncation_detected_at_both_layers() {
        let bytes = toy_frame(1);
        let s = bytes.as_slice();
        match Frame::decode_slice(&s[..10]) {
            Err(WireError::Truncated { .. }) => {}
            r => panic!("expected Truncated, got {:?}", r.err()),
        }
        match Frame::decode_slice(&s[..s.len() - 4]) {
            Err(WireError::Truncated { need, have }) => {
                assert_eq!(need, s.len());
                assert_eq!(have, s.len() - 4);
            }
            r => panic!("expected Truncated, got {:?}", r.err()),
        }
    }
}

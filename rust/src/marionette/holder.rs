//! The layout-holder contract (paper §VII-B, `layout_holder`).
//!
//! A holder owns the actual storage of all fields of a schema, organised
//! however the layout chooses, and exposes:
//!
//! * size-changing operations per *size tag* (`resize`, `reserve`,
//!   `clear`, `shrink_to_fit`, `insert_gap`, `erase_range`);
//! * element addressing (`elem_ptr`) given a [`FieldMeta`] — the "arrays
//!   need not be contiguous, only a mapping from an index to a variable"
//!   contract of the paper;
//! * optional regular-stride *plane* views ([`LayoutHolder::plane`]) that
//!   transfers use to fall back from memcpy to strided to element-wise
//!   copies.
//!
//! All bounds checking happens in [`super::collection::RawCollection`];
//! holders trust their inputs (and `debug_assert!` them).

use std::sync::Arc;

use super::memory::MemoryContext;
use super::pod::Pod;
use super::schema::{FieldMeta, Schema, TagId};

/// A regular-stride view of one plane (field, array-lane) of storage.
#[derive(Clone, Copy, Debug)]
pub struct PlaneView {
    /// First element of the plane.
    pub base: *const u8,
    /// Byte stride between consecutive elements.
    pub stride: usize,
    /// Number of valid elements (the tag's length).
    pub len: usize,
}

/// Storage engine for one layout family (paper: `layout_holder`).
pub trait LayoutHolder: Send + 'static {
    type Ctx: MemoryContext;

    fn new(schema: Arc<Schema>, info: <Self::Ctx as MemoryContext>::Info) -> Self;

    fn schema(&self) -> &Arc<Schema>;

    fn info(&self) -> &<Self::Ctx as MemoryContext>::Info;

    /// Swap the context info, re-homing every allocation (paper:
    /// `update_memory_context_info`).
    fn set_info(&mut self, info: <Self::Ctx as MemoryContext>::Info);

    /// Current length of a size tag.
    fn tag_len(&self, tag: TagId) -> usize;

    /// Current capacity of a size tag (elements).
    fn tag_capacity(&self, tag: TagId) -> usize;

    /// Resize a tag; growth zero-fills ([`Pod`] zero patterns are valid).
    fn resize_tag(&mut self, tag: TagId, len: usize);

    /// Ensure capacity for at least `cap` elements of a tag.
    fn reserve_tag(&mut self, tag: TagId, cap: usize);

    /// Set every tag's length to zero (capacity retained).
    fn clear(&mut self);

    /// Release excess capacity on every tag.
    fn shrink_to_fit(&mut self);

    /// Insert `n` zeroed elements at `at` within a tag, shifting the tail.
    fn insert_gap(&mut self, tag: TagId, at: usize, n: usize);

    /// Erase `[at, at + n)` within a tag, shifting the tail left.
    fn erase_range(&mut self, tag: TagId, at: usize, n: usize);

    /// Address of element `i`, lane `k` of the field described by `meta`.
    ///
    /// # Safety
    /// `i < tag_len(meta.tag)`, `k < meta.extent`, and `meta` must come
    /// from this holder's schema.
    unsafe fn elem_ptr(&self, meta: FieldMeta, i: usize, k: usize) -> *const u8;

    /// Mutable variant of [`Self::elem_ptr`].
    ///
    /// # Safety
    /// As [`Self::elem_ptr`].
    unsafe fn elem_ptr_mut(&mut self, meta: FieldMeta, i: usize, k: usize) -> *mut u8;

    /// Regular-stride view of plane (field, `k`), if the layout stores it
    /// regularly. `None` forces element-wise access (e.g. AoSoA planes).
    fn plane(&self, meta: FieldMeta, k: usize) -> Option<PlaneView>;
}

/// Typed read (bounds are the caller's responsibility — see
/// `RawCollection` for the checked API).
///
/// # Safety
/// As [`LayoutHolder::elem_ptr`]; additionally `T::DTYPE` must match the
/// field's dtype.
#[inline(always)]
pub unsafe fn read<T: Pod, H: LayoutHolder>(h: &H, meta: FieldMeta, i: usize, k: usize) -> T {
    debug_assert_eq!(meta.size as usize, std::mem::size_of::<T>());
    *(h.elem_ptr(meta, i, k) as *const T)
}

/// Typed write; see [`read`].
///
/// # Safety
/// As [`read`].
#[inline(always)]
pub unsafe fn write<T: Pod, H: LayoutHolder>(
    h: &mut H,
    meta: FieldMeta,
    i: usize,
    k: usize,
    v: T,
) {
    debug_assert_eq!(meta.size as usize, std::mem::size_of::<T>());
    *(h.elem_ptr_mut(meta, i, k) as *mut T) = v;
}

//! Plain-old-data element types storable in Marionette collections.
//!
//! The paper's properties store native C++ types; here the same role is
//! played by [`Pod`] — types that are `Copy`, have a stable byte
//! representation, and map onto a [`Dtype`] the device runtime understands
//! (the AOT artifacts' input/output dtypes, see `runtime::artifact`).

/// Element type tags. The numeric ones match the dtype names emitted by
/// `python/compile/aot.py` into `artifacts/manifest.json`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    F64,
    I8,
    U8,
    I16,
    U16,
    I32,
    U32,
    I64,
    U64,
}

impl Dtype {
    /// Size of one element in bytes.
    pub const fn size(self) -> usize {
        match self {
            Dtype::I8 | Dtype::U8 => 1,
            Dtype::I16 | Dtype::U16 => 2,
            Dtype::F32 | Dtype::I32 | Dtype::U32 => 4,
            Dtype::F64 | Dtype::I64 | Dtype::U64 => 8,
        }
    }

    /// Alignment of one element in bytes (same as size for primitives).
    pub const fn align(self) -> usize {
        self.size()
    }

    /// The manifest name of this dtype (`numpy` convention).
    pub const fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "float32",
            Dtype::F64 => "float64",
            Dtype::I8 => "int8",
            Dtype::U8 => "uint8",
            Dtype::I16 => "int16",
            Dtype::U16 => "uint16",
            Dtype::I32 => "int32",
            Dtype::U32 => "uint32",
            Dtype::I64 => "int64",
            Dtype::U64 => "uint64",
        }
    }

    /// Parse a manifest dtype name.
    pub fn from_name(name: &str) -> Option<Dtype> {
        Some(match name {
            "float32" => Dtype::F32,
            "float64" => Dtype::F64,
            "int8" => Dtype::I8,
            "uint8" => Dtype::U8,
            "int16" => Dtype::I16,
            "uint16" => Dtype::U16,
            "int32" => Dtype::I32,
            "uint32" => Dtype::U32,
            "int64" => Dtype::I64,
            "uint64" => Dtype::U64,
            _ => return None,
        })
    }
}

/// Types storable as Marionette property elements.
///
/// # Safety
/// Implementors must be inhabited `Copy` types with no padding, no
/// interior mutability and no invalid bit patterns, whose size and
/// alignment equal `DTYPE.size()` / `DTYPE.align()`.
pub unsafe trait Pod:
    Copy + Default + Send + Sync + PartialEq + std::fmt::Debug + 'static
{
    /// Runtime type tag for this element type.
    const DTYPE: Dtype;
}

macro_rules! impl_pod {
    ($($t:ty => $d:expr),* $(,)?) => {
        $(
            unsafe impl Pod for $t {
                const DTYPE: Dtype = $d;
            }
        )*
    };
}

impl_pod! {
    f32 => Dtype::F32,
    f64 => Dtype::F64,
    i8  => Dtype::I8,
    u8  => Dtype::U8,
    i16 => Dtype::I16,
    u16 => Dtype::U16,
    i32 => Dtype::I32,
    u32 => Dtype::U32,
    i64 => Dtype::I64,
    u64 => Dtype::U64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_rust_layout() {
        assert_eq!(Dtype::F32.size(), std::mem::size_of::<f32>());
        assert_eq!(Dtype::U8.size(), std::mem::size_of::<u8>());
        assert_eq!(Dtype::I64.size(), std::mem::size_of::<i64>());
        assert_eq!(Dtype::U16.size(), std::mem::size_of::<u16>());
        assert_eq!(<f32 as Pod>::DTYPE, Dtype::F32);
        assert_eq!(<u64 as Pod>::DTYPE, Dtype::U64);
    }

    #[test]
    fn alignment_equals_size_for_primitives() {
        for d in [
            Dtype::F32,
            Dtype::F64,
            Dtype::I8,
            Dtype::U8,
            Dtype::I16,
            Dtype::U16,
            Dtype::I32,
            Dtype::U32,
            Dtype::I64,
            Dtype::U64,
        ] {
            assert_eq!(d.align(), d.size());
        }
    }

    #[test]
    fn dtype_names_roundtrip() {
        for d in [
            Dtype::F32,
            Dtype::F64,
            Dtype::I8,
            Dtype::U8,
            Dtype::I16,
            Dtype::U16,
            Dtype::I32,
            Dtype::U32,
            Dtype::I64,
            Dtype::U64,
        ] {
            assert_eq!(Dtype::from_name(d.name()), Some(d));
        }
        assert_eq!(Dtype::from_name("complex64"), None);
    }
}

//! Property schemas: the compile-time description of a collection.
//!
//! A schema is the flattened form of the paper's property list (§V–§VI):
//! sub-groups are flattened into their parents, every per-item scalar or
//! fixed array becomes one [`Field`], jagged vectors contribute a
//! prefix-sum field plus a values field under a dedicated *size tag*, and
//! global properties live under the `Global` tag.
//!
//! Size tags (paper §VI, "differently sized arrays may coexist within a
//! collection"): each field belongs to exactly one tag, and all fields of
//! a tag share one logical length:
//!
//! | tag            | length                      | used by                |
//! |----------------|-----------------------------|------------------------|
//! | `Items`        | number of objects           | per-item + array props |
//! | `ItemsPlusOne` | objects + 1                 | jagged prefix sums     |
//! | `Global`       | 1                           | global properties      |
//! | `Values(j)`    | total values of jagged *j*  | jagged value arrays    |
//!
//! [`FieldMeta`] carries everything a layout holder needs to address an
//! element: element size, extent, offset within the tag's AoS record, the
//! record size, and the field's slot within its tag. The same computation
//! exists twice on purpose: a `const fn` path ([`compute_metas`]) used by
//! `marionette_collection!` so generated accessors see compile-time
//! constants, and a runtime path used by [`SchemaBuilder`]; a unit test
//! pins them equal.

use super::pod::{Dtype, Pod};

/// Identifies a field within a schema (index into `Schema::fields`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FieldId(pub u32);

/// A size-tag slot. `Items = 0`, `ItemsPlusOne = 1`, `Global = 2`,
/// `Values(j) = 3 + j`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TagId(pub u32);

impl TagId {
    pub const ITEMS: TagId = TagId(0);
    pub const ITEMS_PLUS_ONE: TagId = TagId(1);
    pub const GLOBAL: TagId = TagId(2);

    /// Tag of the values of jagged property `j`.
    pub const fn values(j: u32) -> TagId {
        TagId(3 + j)
    }

    pub const fn index(self) -> usize {
        self.0 as usize
    }

    /// Is this a jagged-values tag?
    pub const fn is_values(self) -> bool {
        self.0 >= 3
    }
}

/// Semantic kind of a field (drives collection-level maintenance such as
/// prefix-sum fix-ups on insert/erase).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FieldKind {
    /// One element (or `extent` elements) per object.
    PerItem,
    /// Prefix-sum of jagged property `j` (length = items + 1).
    JaggedPrefix(u32),
    /// Values of jagged property `j` (length = total values of `j`).
    JaggedValues(u32),
    /// One element per collection.
    Global,
}

/// Maximum number of size tags (3 fixed + up to 13 jagged properties).
pub const MAX_TAGS: usize = 16;

/// Kind of a [`FieldDesc`] (jagged tags are assigned by [`compute_metas`]
/// in declaration order, so descriptions never carry explicit indices).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DescKind {
    PerItem,
    JaggedPrefix,
    JaggedValues,
    Global,
}

/// Compile-time description of one field, input to the layout computation.
#[derive(Clone, Copy, Debug)]
pub struct FieldDesc {
    pub dtype: Dtype,
    pub kind: DescKind,
    pub extent: u32,
}

impl FieldDesc {
    pub const fn per_item(dtype: Dtype) -> FieldDesc {
        FieldDesc { dtype, kind: DescKind::PerItem, extent: 1 }
    }

    pub const fn array(dtype: Dtype, extent: u32) -> FieldDesc {
        FieldDesc { dtype, kind: DescKind::PerItem, extent }
    }

    /// Prefix-sum field; must immediately precede its values field(s).
    pub const fn jagged_prefix(dtype: Dtype) -> FieldDesc {
        FieldDesc { dtype, kind: DescKind::JaggedPrefix, extent: 1 }
    }

    /// Values field of the most recently declared jagged prefix.
    pub const fn jagged_values(dtype: Dtype) -> FieldDesc {
        FieldDesc { dtype, kind: DescKind::JaggedValues, extent: 1 }
    }

    pub const fn global(dtype: Dtype) -> FieldDesc {
        FieldDesc { dtype, kind: DescKind::Global, extent: 1 }
    }

    /// Tag this desc lands in, given how many jagged prefixes precede it
    /// (inclusive of itself for values fields).
    const fn tag(self, jagged_seen: u32) -> TagId {
        match self.kind {
            DescKind::PerItem => TagId::ITEMS,
            DescKind::JaggedPrefix => TagId::ITEMS_PLUS_ONE,
            DescKind::JaggedValues => TagId::values(jagged_seen - 1),
            DescKind::Global => TagId::GLOBAL,
        }
    }
}

/// Everything a layout holder needs to address elements of one field.
///
/// Addressing conventions (element `i`, array lane `k`, capacity `cap`):
///
/// * AoS blob:    `i * record_size + aos_offset + k * size`
/// * AoSoA blob:  `(i / K) * K * record_size + K * aos_offset
///                 + (k * K + i % K) * size`
/// * SoA vec:     buffer `index`, offset `(k * cap + i) * size`
/// * SoA blob:    `base[soa_slot] + (k * cap + i) * size` with `base`
///                recomputed per capacity (see `blob::SoABlobScheme`)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FieldMeta {
    /// Global field slot within the schema.
    pub index: u32,
    /// Size-tag slot.
    pub tag: u32,
    /// Element size in bytes.
    pub size: u32,
    /// Element alignment in bytes.
    pub align: u32,
    /// Array extent (1 for scalars).
    pub extent: u32,
    /// Byte offset of the field's first element within the tag's AoS record.
    pub aos_offset: u32,
    /// Padded AoS record size of the field's tag.
    pub record_size: u32,
    /// Slot of this field within its tag's field list.
    pub tag_slot: u32,
}

impl FieldMeta {
    pub const ZERO: FieldMeta = FieldMeta {
        index: 0,
        tag: 0,
        size: 0,
        align: 0,
        extent: 0,
        aos_offset: 0,
        record_size: 0,
        tag_slot: 0,
    };

    pub const fn tag_id(&self) -> TagId {
        TagId(self.tag)
    }

    pub const fn field_id(&self) -> FieldId {
        FieldId(self.index)
    }

    /// Bytes one element contributes to its tag's AoS record.
    pub const fn record_bytes(&self) -> usize {
        (self.size * self.extent) as usize
    }
}

pub const fn align_up(x: usize, a: usize) -> usize {
    (x + a - 1) / a * a
}

/// Const layout computation for `marionette_collection!`: identical to the
/// runtime path in [`SchemaBuilder::build`] (pinned by a test below).
pub const fn compute_metas<const N: usize>(descs: [FieldDesc; N]) -> [FieldMeta; N] {
    let mut metas = [FieldMeta::ZERO; N];
    let mut tag_cursor = [0usize; MAX_TAGS];
    let mut tag_align = [1usize; MAX_TAGS];
    let mut tag_slots = [0u32; MAX_TAGS];
    let mut jagged_seen = 0u32;

    // First pass: assign offsets within each tag's record.
    let mut f = 0;
    while f < N {
        let d = descs[f];
        if matches!(d.kind, DescKind::JaggedPrefix) {
            jagged_seen += 1;
        }
        let tag = d.tag(jagged_seen);
        let t = tag.index();
        assert!(t < MAX_TAGS, "too many jagged properties");
        let size = d.dtype.size();
        let align = d.dtype.align();
        let off = align_up(tag_cursor[t], align);
        metas[f] = FieldMeta {
            index: f as u32,
            tag: tag.0,
            size: size as u32,
            align: align as u32,
            extent: d.extent,
            aos_offset: off as u32,
            record_size: 0, // second pass
            tag_slot: tag_slots[t],
        };
        tag_cursor[t] = off + size * d.extent as usize;
        if align > tag_align[t] {
            tag_align[t] = align;
        }
        tag_slots[t] += 1;
        f += 1;
    }

    // Second pass: pad each tag's record to its alignment.
    let mut f = 0;
    while f < N {
        let t = metas[f].tag as usize;
        metas[f].record_size = align_up(tag_cursor[t], tag_align[t]) as u32;
        f += 1;
    }
    metas
}

/// Const string equality (for [`meta_by_name`]).
const fn str_eq(a: &str, b: &str) -> bool {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    if a.len() != b.len() {
        return false;
    }
    let mut i = 0;
    while i < a.len() {
        if a[i] != b[i] {
            return false;
        }
        i += 1;
    }
    true
}

/// Look up a field's meta by name at compile time (used by the property
/// constants generated by `marionette_collection!`). Panics (a compile
/// error in const context) if the name is absent.
pub const fn meta_by_name(metas: &[FieldMeta], names: &[&str], name: &str) -> FieldMeta {
    let mut i = 0;
    while i < names.len() {
        if str_eq(names[i], name) {
            return metas[i];
        }
        i += 1;
    }
    panic!("marionette: no field with the requested name");
}

/// Handle to a jagged property: its prefix-sum and values field metas
/// plus the jagged index (recovered from the values tag). Carrying the
/// prefix meta lets borrowed views resolve an item's value range with
/// two raw reads and no schema lookup (see
/// [`interface`](super::interface)).
#[derive(Clone, Copy, Debug)]
pub struct JaggedProp {
    pub values: FieldMeta,
    pub prefix: FieldMeta,
    pub j: u32,
}

impl JaggedProp {
    pub const fn from_metas(prefix: FieldMeta, values: FieldMeta) -> JaggedProp {
        JaggedProp { values, prefix, j: values.tag - 3 }
    }
}

/// One flattened property.
#[derive(Clone, Debug)]
pub struct Field {
    pub name: String,
    pub dtype: Dtype,
    pub kind: FieldKind,
    pub extent: u32,
}

impl Field {
    pub const fn tag(&self) -> TagId {
        match self.kind {
            FieldKind::PerItem => TagId::ITEMS,
            FieldKind::JaggedPrefix(_) => TagId::ITEMS_PLUS_ONE,
            FieldKind::JaggedValues(j) => TagId::values(j),
            FieldKind::Global => TagId::GLOBAL,
        }
    }
}

/// Per-tag record layout, shared by all blob schemes.
#[derive(Clone, Debug, Default)]
pub struct TagLayout {
    /// Fields of this tag, in declaration order.
    pub fields: Vec<FieldId>,
    /// Padded record size in bytes (0 if the tag has no fields).
    pub record_size: usize,
    /// Record alignment in bytes.
    pub record_align: usize,
}

/// A complete, immutable collection description.
#[derive(Debug)]
pub struct Schema {
    fields: Vec<Field>,
    metas: Vec<FieldMeta>,
    tags: Vec<TagLayout>,
    /// Jagged property index -> (prefix field, values fields).
    jagged: Vec<(FieldId, Vec<FieldId>)>,
    name: String,
}

impl Schema {
    pub fn builder(name: &str) -> SchemaBuilder {
        SchemaBuilder { name: name.to_string(), fields: Vec::new(), num_jagged: 0 }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn num_fields(&self) -> usize {
        self.fields.len()
    }

    pub fn num_tags(&self) -> usize {
        self.tags.len()
    }

    pub fn num_jagged(&self) -> usize {
        self.jagged.len()
    }

    pub fn field(&self, id: FieldId) -> &Field {
        &self.fields[id.0 as usize]
    }

    pub fn fields(&self) -> impl Iterator<Item = (FieldId, &Field)> {
        self.fields.iter().enumerate().map(|(i, f)| (FieldId(i as u32), f))
    }

    pub fn meta(&self, id: FieldId) -> FieldMeta {
        self.metas[id.0 as usize]
    }

    pub fn metas(&self) -> &[FieldMeta] {
        &self.metas
    }

    pub fn tag_layout(&self, tag: TagId) -> &TagLayout {
        &self.tags[tag.index()]
    }

    pub fn tag_layouts(&self) -> &[TagLayout] {
        &self.tags
    }

    /// Prefix-sum field of jagged property `j`.
    pub fn jagged_prefix(&self, j: u32) -> FieldId {
        self.jagged[j as usize].0
    }

    /// Value fields of jagged property `j`.
    pub fn jagged_values(&self, j: u32) -> &[FieldId] {
        &self.jagged[j as usize].1
    }

    /// Field id by name (linear scan; not for hot paths).
    pub fn field_by_name(&self, name: &str) -> Option<FieldId> {
        self.fields.iter().position(|f| f.name == name).map(|i| FieldId(i as u32))
    }

    /// Structural equality: same field names, dtypes, kinds and extents.
    /// Collections may only be transferred between structurally equal
    /// schemas (paper: transfers connect representations of the *same*
    /// property list).
    pub fn same_structure(&self, other: &Schema) -> bool {
        self.fields.len() == other.fields.len()
            && self
                .fields
                .iter()
                .zip(&other.fields)
                .all(|(a, b)| {
                    a.name == b.name
                        && a.dtype == b.dtype
                        && a.kind == b.kind
                        && a.extent == b.extent
                })
    }
}

/// Builds a [`Schema`] at runtime (the dynamic twin of the macro's const
/// path; used by `RawCollection` tests, tooling and the transfer tests).
pub struct SchemaBuilder {
    name: String,
    fields: Vec<Field>,
    num_jagged: u32,
}

impl SchemaBuilder {
    /// Add a per-item scalar property.
    pub fn per_item<T: Pod>(mut self, name: &str) -> Self {
        self.fields.push(Field {
            name: name.to_string(),
            dtype: T::DTYPE,
            kind: FieldKind::PerItem,
            extent: 1,
        });
        self
    }

    /// Add a fixed-extent array property (stored as `extent` separate
    /// arrays in SoA layouts, inline `[T; extent]` in AoS records).
    pub fn array<T: Pod>(mut self, name: &str, extent: u32) -> Self {
        assert!(extent >= 1, "array extent must be >= 1");
        self.fields.push(Field {
            name: name.to_string(),
            dtype: T::DTYPE,
            kind: FieldKind::PerItem,
            extent,
        });
        self
    }

    /// Add a simple jagged vector property: a dynamic number of `T` values
    /// per object, with `Idx`-typed prefix sums. Returns the builder; the
    /// jagged index is assigned in declaration order.
    pub fn jagged<T: Pod, Idx: Pod>(mut self, name: &str) -> Self {
        let j = self.num_jagged;
        self.fields.push(Field {
            name: format!("{name}__prefix"),
            dtype: Idx::DTYPE,
            kind: FieldKind::JaggedPrefix(j),
            extent: 1,
        });
        self.fields.push(Field {
            name: name.to_string(),
            dtype: T::DTYPE,
            kind: FieldKind::JaggedValues(j),
            extent: 1,
        });
        self.num_jagged += 1;
        self
    }

    /// Add an extra value field to the *most recently declared* jagged
    /// property (the paper's general jagged form, where the per-value
    /// payload is itself a property list).
    pub fn jagged_extra<T: Pod>(mut self, name: &str) -> Self {
        assert!(self.num_jagged > 0, "jagged_extra requires a prior jagged()");
        let j = self.num_jagged - 1;
        self.fields.push(Field {
            name: name.to_string(),
            dtype: T::DTYPE,
            kind: FieldKind::JaggedValues(j),
            extent: 1,
        });
        self
    }

    /// Add a global (collection-level) property.
    pub fn global<T: Pod>(mut self, name: &str) -> Self {
        self.fields.push(Field {
            name: name.to_string(),
            dtype: T::DTYPE,
            kind: FieldKind::Global,
            extent: 1,
        });
        self
    }

    pub fn build(self) -> Schema {
        let num_tags = 3 + self.num_jagged as usize;
        assert!(num_tags <= MAX_TAGS, "too many jagged properties");
        for (i, f) in self.fields.iter().enumerate() {
            assert!(
                !self.fields[..i].iter().any(|g| g.name == f.name),
                "duplicate field name {:?}",
                f.name
            );
        }
        let mut tags = vec![TagLayout::default(); num_tags];
        for t in &mut tags {
            t.record_align = 1;
        }
        let mut metas = Vec::with_capacity(self.fields.len());

        // Identical algorithm to `compute_metas` (pinned by a test).
        for (i, f) in self.fields.iter().enumerate() {
            let tag = f.tag();
            let t = &mut tags[tag.index()];
            let size = f.dtype.size();
            let align = f.dtype.align();
            let off = align_up(t.record_size, align);
            metas.push(FieldMeta {
                index: i as u32,
                tag: tag.0,
                size: size as u32,
                align: align as u32,
                extent: f.extent,
                aos_offset: off as u32,
                record_size: 0,
                tag_slot: t.fields.len() as u32,
            });
            t.fields.push(FieldId(i as u32));
            t.record_size = off + size * f.extent as usize;
            t.record_align = t.record_align.max(align);
        }
        for t in &mut tags {
            t.record_size = align_up(t.record_size, t.record_align);
        }
        for m in &mut metas {
            m.record_size = tags[m.tag as usize].record_size as u32;
        }

        let mut jagged = vec![(FieldId(0), Vec::new()); self.num_jagged as usize];
        for (i, f) in self.fields.iter().enumerate() {
            match f.kind {
                FieldKind::JaggedPrefix(j) => jagged[j as usize].0 = FieldId(i as u32),
                FieldKind::JaggedValues(j) => {
                    jagged[j as usize].1.push(FieldId(i as u32))
                }
                _ => {}
            }
        }

        Schema { fields: self.fields, metas, tags, jagged, name: self.name }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> Schema {
        Schema::builder("sensor")
            .per_item::<i32>("type")
            .per_item::<u64>("counts")
            .per_item::<f32>("energy")
            .per_item::<u8>("noisy")
            .array::<f32>("significance", 3)
            .jagged::<u64, u32>("cells")
            .global::<u64>("event_id")
            .build()
    }

    #[test]
    fn record_layout_matches_handwritten_struct() {
        // Equivalent handwritten AoS record:
        // struct Rec { type: i32, counts: u64, energy: f32, noisy: u8,
        //              significance: [f32; 3] }  (repr C-ish, decl order)
        let s = example();
        let m_type = s.meta(s.field_by_name("type").unwrap());
        let m_counts = s.meta(s.field_by_name("counts").unwrap());
        let m_energy = s.meta(s.field_by_name("energy").unwrap());
        let m_noisy = s.meta(s.field_by_name("noisy").unwrap());
        let m_sig = s.meta(s.field_by_name("significance").unwrap());
        assert_eq!(m_type.aos_offset, 0);
        assert_eq!(m_counts.aos_offset, 8); // aligned up from 4
        assert_eq!(m_energy.aos_offset, 16);
        assert_eq!(m_noisy.aos_offset, 20);
        assert_eq!(m_sig.aos_offset, 24); // f32-aligned after the u8
        assert_eq!(m_sig.extent, 3);
        // 24 + 12 = 36, padded to align 8 -> 40.
        assert_eq!(m_type.record_size, 40);
        assert_eq!(s.tag_layout(TagId::ITEMS).record_align, 8);
    }

    #[test]
    fn tags_are_partitioned() {
        let s = example();
        assert_eq!(s.num_tags(), 4); // Items, Items+1, Global, Values(0)
        assert_eq!(s.tag_layout(TagId::ITEMS).fields.len(), 5);
        assert_eq!(s.tag_layout(TagId::ITEMS_PLUS_ONE).fields.len(), 1);
        assert_eq!(s.tag_layout(TagId::GLOBAL).fields.len(), 1);
        assert_eq!(s.tag_layout(TagId::values(0)).fields.len(), 1);
        let prefix = s.jagged_prefix(0);
        assert_eq!(s.field(prefix).dtype, Dtype::U32);
        assert_eq!(s.jagged_values(0).len(), 1);
    }

    #[test]
    fn const_and_runtime_paths_agree() {
        let s = example();
        const DESCS: [FieldDesc; 8] = [
            FieldDesc::per_item(Dtype::I32),
            FieldDesc::per_item(Dtype::U64),
            FieldDesc::per_item(Dtype::F32),
            FieldDesc::per_item(Dtype::U8),
            FieldDesc::array(Dtype::F32, 3),
            FieldDesc::jagged_prefix(Dtype::U32),
            FieldDesc::jagged_values(Dtype::U64),
            FieldDesc::global(Dtype::U64),
        ];
        const METAS: [FieldMeta; 8] = compute_metas(DESCS);
        assert_eq!(&METAS[..], s.metas());
    }

    #[test]
    fn multi_payload_jagged() {
        let s = Schema::builder("tracks")
            .per_item::<f32>("pt")
            .jagged::<u32, u32>("hits")
            .jagged_extra::<f32>("hit_charge")
            .build();
        assert_eq!(s.jagged_values(0).len(), 2);
        let vals = s.jagged_values(0);
        // Both value fields share the Values(0) tag and its record.
        let m0 = s.meta(vals[0]);
        let m1 = s.meta(vals[1]);
        assert_eq!(m0.tag, m1.tag);
        assert_eq!(m0.record_size, 8); // u32 + f32
        assert_eq!(m1.aos_offset, 4);
    }

    #[test]
    fn structural_equality() {
        let a = example();
        let b = example();
        assert!(a.same_structure(&b));
        let c = Schema::builder("sensor").per_item::<i32>("type").build();
        assert!(!a.same_structure(&c));
    }

    #[test]
    fn align_up_properties() {
        assert_eq!(align_up(0, 8), 0);
        assert_eq!(align_up(1, 8), 8);
        assert_eq!(align_up(8, 8), 8);
        assert_eq!(align_up(9, 4), 12);
    }
}

//! Host calibration algorithms (Figure 1's compute stage).
//!
//! One physics definition — `ref.py:calibrate_ref` — implemented four
//! times with identical semantics: over the Marionette collection (both
//! through the object-oriented no-property interface and through direct
//! accessors) and over the two handwritten baselines. The zero-cost bench
//! compares these; the figure benches use them as the CPU series.

use crate::marionette::interface::PlaneSourceMut;
use crate::marionette::layout::Layout;

use super::constants::NOISE_FLOOR;
use super::handwritten::{HwSensorsAoS, HwSensorsSoA};
use super::sensor::{SensorCollection, SensorViewMut};

#[inline(always)]
fn kernel(
    noisy: u8,
    counts: i32,
    a: f32,
    b: f32,
    na: f32,
    nb: f32,
) -> (f32, f32, f32) {
    let e = if noisy != 0 { 0.0 } else { a * counts as f32 + b };
    let noise = (na + nb * e.max(0.0).sqrt()).max(NOISE_FLOOR);
    (e, noise, e / noise)
}

/// Calibrate a Marionette collection.
///
/// Uses the collection-level interface the paper's listing 3 exposes
/// (`energy()` on a collection returns the whole column): the dense
/// record view for AoS layouts, the split-borrowed column view for SoA
/// layouts, and the per-element accessors for irregular layouts
/// (AoSoA). All three paths run the identical [`kernel`]; the view
/// selection is what makes the Marionette series match the handwritten
/// one in `benches/zero_cost.rs` (EXPERIMENTS.md §Perf).
pub fn calibrate_collection<L: Layout>(s: &mut SensorCollection<L>) {
    if let Some(recs) = s.records_mut() {
        for r in recs {
            let (e, noise, sig) =
                kernel(r.noisy, r.counts, r.param_a, r.param_b, r.noise_a, r.noise_b);
            r.energy = e;
            r.noise = noise;
            r.sig = sig;
        }
        return;
    }
    if let Some(c) = s.columns_mut() {
        for i in 0..c.counts.len() {
            let (e, noise, sig) = kernel(
                c.noisy[i],
                c.counts[i],
                c.param_a[i],
                c.param_b[i],
                c.noise_a[i],
                c.noise_b[i],
            );
            c.energy[i] = e;
            c.noise[i] = noise;
            c.sig[i] = sig;
        }
        return;
    }
    calibrate_collection_accessors(s);
}

/// Calibrate through the per-element generated accessors only (the
/// fallback path for irregular layouts; also benchmarked standalone in
/// the ablation to quantify the accessor abstraction penalty).
pub fn calibrate_collection_accessors<L: Layout>(s: &mut SensorCollection<L>) {
    for i in 0..s.len() {
        let (e, noise, sig) = kernel(
            s.noisy(i),
            s.counts(i),
            s.param_a(i),
            s.param_b(i),
            s.noise_a(i),
            s.noise_b(i),
        );
        s.set_energy(i, e);
        s.set_noise(i, noise);
        s.set_sig(i, sig);
    }
}

/// Calibrate through a borrowed mutable view — the source-erased twin
/// of [`calibrate_collection_accessors`]: the same per-element loop,
/// but runnable against *any* schema-matching mutable store (owned,
/// pooled, recycled). The zero-cost guard pins this path to
/// owned-accessor speed (`tests/zero_cost_guard.rs`).
pub fn calibrate_view<S: PlaneSourceMut>(v: &mut SensorViewMut<'_, S>) {
    for i in 0..v.len() {
        let (e, noise, sig) = kernel(
            v.noisy(i),
            v.counts(i),
            v.param_a(i),
            v.param_b(i),
            v.noise_a(i),
            v.noise_b(i),
        );
        v.set_energy(i, e);
        v.set_noise(i, noise);
        v.set_sig(i, sig);
    }
}

/// Calibrate through the object-oriented no-property interface (paper:
/// `sensor.calibrate_energy()` written against the class API).
pub fn calibrate_collection_oo<L: Layout>(s: &mut SensorCollection<L>) {
    for i in 0..s.len() {
        s.calibrate_energy(i);
    }
}

/// Calibrate the handwritten AoS baseline.
pub fn calibrate_hw_aos(s: &mut HwSensorsAoS) {
    for rec in &mut s.data {
        let (e, noise, sig) = kernel(
            rec.noisy,
            rec.counts,
            rec.param_a,
            rec.param_b,
            rec.noise_a,
            rec.noise_b,
        );
        rec.energy = e;
        rec.noise = noise;
        rec.sig = sig;
    }
}

/// Calibrate the handwritten SoA baseline.
pub fn calibrate_hw_soa(s: &mut HwSensorsSoA) {
    for i in 0..s.len() {
        let (e, noise, sig) = kernel(
            s.noisy[i],
            s.counts[i],
            s.param_a[i],
            s.param_b[i],
            s.noise_a[i],
            s.noise_b[i],
        );
        s.energy[i] = e;
        s.noise[i] = noise;
        s.sig[i] = sig;
    }
}

#[cfg(test)]
mod tests {
    use super::super::generator::{EventConfig, EventGenerator};
    use super::*;
    use crate::marionette::layout::{AoS, AoSoA, SoABlob, SoAVec};

    /// All four implementations produce bit-identical planes.
    #[test]
    fn implementations_agree() {
        let ev = EventGenerator::new(EventConfig::grid(32, 32, 4), 11).generate();

        let mut aos = Default::default();
        ev.fill_hw_aos(&mut aos);
        calibrate_hw_aos(&mut aos);

        let mut soa = Default::default();
        ev.fill_hw_soa(&mut soa);
        calibrate_hw_soa(&mut soa);

        let mut col = ev.to_collection::<SoAVec>();
        calibrate_collection(&mut col);

        let mut col_oo = ev.to_collection::<AoS>();
        calibrate_collection_oo(&mut col_oo);

        let mut col_view = ev.to_collection::<AoS>();
        calibrate_view(&mut col_view.view_mut());

        for i in 0..ev.num_sensors() {
            assert_eq!(aos.data[i].energy, soa.energy[i]);
            assert_eq!(aos.data[i].energy, col.energy(i));
            assert_eq!(aos.data[i].energy, col_oo.energy(i));
            assert_eq!(aos.data[i].energy, col_view.energy(i));
            assert_eq!(aos.data[i].noise, col.noise(i));
            assert_eq!(aos.data[i].noise, col_view.noise(i));
            assert_eq!(aos.data[i].sig, col_oo.sig(i));
            assert_eq!(aos.data[i].sig, col_view.sig(i));
        }
    }

    /// The collection algorithm is layout-independent.
    #[test]
    fn layout_independent() {
        let ev = EventGenerator::new(EventConfig::grid(24, 40, 3), 13).generate();
        let mut a = ev.to_collection::<SoAVec>();
        let mut b = ev.to_collection::<AoS>();
        let mut c = ev.to_collection::<SoABlob>();
        let mut d = ev.to_collection::<AoSoA<8>>();
        calibrate_collection(&mut a);
        calibrate_collection(&mut b);
        calibrate_collection(&mut c);
        calibrate_collection(&mut d);
        for i in 0..ev.num_sensors() {
            assert_eq!(a.sig(i), b.sig(i));
            assert_eq!(a.sig(i), c.sig(i));
            assert_eq!(a.sig(i), d.sig(i));
        }
    }

    #[test]
    fn noisy_sensor_semantics() {
        let mut ev = EventGenerator::new(EventConfig::grid(8, 8, 0), 1).generate();
        ev.noisy[10] = 1;
        ev.counts[10] = 100_000; // must be masked
        let mut col = ev.to_collection::<SoAVec>();
        calibrate_collection(&mut col);
        assert_eq!(col.energy(10), 0.0);
        assert_eq!(col.noise(10), col.noise_a(10));
        assert_eq!(col.sig(10), 0.0);
    }
}

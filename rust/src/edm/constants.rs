//! Physics constants of the realistic example.
//!
//! Mirror of `python/compile/physics.py`; `runtime::artifact` re-checks
//! these against `artifacts/manifest.json` at load time so the two
//! languages can never drift silently.

/// Number of distinct sensor types (paper: `SensorType::Num`).
pub const NUM_SENSOR_TYPES: usize = 3;

/// Reconstruction window is `WINDOW x WINDOW` around the seed (paper: 5×5).
pub const WINDOW: usize = 5;
pub const HALO: usize = WINDOW / 2;

/// Seeding cut: a sensor seeds a particle when `sig > SEED_SIGNIFICANCE`
/// and it attains the window maximum of energy.
pub const SEED_SIGNIFICANCE: f32 = 4.0;

/// Contribution cut: a sensor joins a particle's jagged sensor list when
/// `sig > CONTRIB_SIGNIFICANCE`.
pub const CONTRIB_SIGNIFICANCE: f32 = 2.0;

/// Guard for degenerate calibrations (matches `ref.py`).
pub const NOISE_FLOOR: f32 = 1e-6;

/// Stacked plane indices produced by the device particle stage
/// (`python/compile/physics.py` plane layout).
pub const PLANE_E: usize = 0;
pub const PLANE_EX: usize = 1;
pub const PLANE_EY: usize = 2;
pub const PLANE_EXX: usize = 3;
pub const PLANE_EYY: usize = 4;
pub const PLANE_E_TYPE: usize = 5;
pub const PLANE_SIG_TYPE: usize = 5 + NUM_SENSOR_TYPES;
pub const PLANE_NOISY_TYPE: usize = 5 + 2 * NUM_SENSOR_TYPES;
pub const PLANE_CONTRIB: usize = 5 + 3 * NUM_SENSOR_TYPES;
pub const NUM_PLANES: usize = 6 + 3 * NUM_SENSOR_TYPES;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plane_layout_is_contiguous() {
        assert_eq!(PLANE_E_TYPE, 5);
        assert_eq!(PLANE_SIG_TYPE, 8);
        assert_eq!(PLANE_NOISY_TYPE, 11);
        assert_eq!(PLANE_CONTRIB, 14);
        assert_eq!(NUM_PLANES, 15);
    }
}

//! Golden-vector loader: replays the reference tensors written by
//! `python -m compile.aot` (`artifacts/golden/`) for cross-language
//! equivalence tests — the Rust host algorithms must reproduce
//! `ref.py:full_event_ref` bit-for-bit (modulo f32 rounding).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json;

/// One golden tensor: raw little-endian bytes + dtype + shape.
#[derive(Debug)]
pub struct GoldenTensor {
    pub dtype: String,
    pub shape: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl GoldenTensor {
    pub fn num_elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn elems<T: Copy>(&self) -> Vec<T> {
        let esz = std::mem::size_of::<T>();
        assert_eq!(self.bytes.len(), self.num_elems() * esz, "tensor size");
        let mut out = Vec::with_capacity(self.num_elems());
        for chunk in self.bytes.chunks_exact(esz) {
            out.push(unsafe { std::ptr::read_unaligned(chunk.as_ptr() as *const T) });
        }
        out
    }

    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, "float32", "dtype {}", self.dtype);
        self.elems::<f32>()
    }

    pub fn as_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, "int32", "dtype {}", self.dtype);
        self.elems::<i32>()
    }
}

/// A loaded golden event: inputs + reference outputs.
#[derive(Debug)]
pub struct GoldenEvent {
    pub rows: usize,
    pub cols: usize,
    pub tensors: BTreeMap<String, GoldenTensor>,
}

impl GoldenEvent {
    pub fn tensor(&self, name: &str) -> &GoldenTensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("golden tensor {name:?} missing"))
    }
}

/// Default artifacts directory: `$MARIONETTE_ARTIFACTS` or
/// `<crate>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("MARIONETTE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Load the golden event from `<artifacts>/golden`. Returns `None` when
/// the artifacts have not been built (tests then skip).
pub fn load_golden() -> Option<GoldenEvent> {
    load_golden_from(&artifacts_dir().join("golden"))
}

pub fn load_golden_from(dir: &Path) -> Option<GoldenEvent> {
    let desc = std::fs::read_to_string(dir.join("golden.json")).ok()?;
    let v = json::parse(&desc).expect("golden.json must parse");
    let rows = v.req("rows").unwrap().as_usize().unwrap();
    let cols = v.req("cols").unwrap().as_usize().unwrap();
    let mut tensors = BTreeMap::new();
    for (name, meta) in v.req("tensors").unwrap().as_obj().unwrap() {
        let file = meta.req("file").unwrap().as_str().unwrap();
        let bytes = std::fs::read(dir.join(file)).expect("golden tensor file");
        tensors.insert(
            name.clone(),
            GoldenTensor {
                dtype: meta.req("dtype").unwrap().as_str().unwrap().to_string(),
                shape: meta
                    .req("shape")
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|s| s.as_usize().unwrap())
                    .collect(),
                bytes,
            },
        );
    }
    Some(GoldenEvent { rows, cols, tensors })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_if_built() {
        let Some(g) = load_golden() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(g.rows, 32);
        let counts = g.tensor("counts").as_i32();
        assert_eq!(counts.len(), g.rows * g.cols);
        let sums = g.tensor("sums");
        assert_eq!(sums.shape[0], super::super::constants::NUM_PLANES);
        assert_eq!(sums.as_f32().len(), sums.num_elems());
    }
}

//! Handwritten baselines (paper §VIII: "the equivalent handwritten
//! solution").
//!
//! These are the structures a programmer would write by hand for the
//! motivating example — a plain array-of-structs and a plain
//! struct-of-arrays, for both sensors and particles — with no Marionette
//! machinery anywhere. The zero-cost benches (`benches/zero_cost.rs`) and
//! the figure benches run the *same algorithms* over these and over the
//! Marionette collections; the paper's claim is that the two are
//! indistinguishable in performance.

use super::constants::NUM_SENSOR_TYPES;

/// Handwritten AoS sensor record (paper listing 1, flattened).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C)]
pub struct HwSensor {
    pub type_id: i32,
    pub counts: i32,
    pub energy: f32,
    pub noise: f32,
    pub sig: f32,
    pub noisy: u8,
    pub param_a: f32,
    pub param_b: f32,
    pub noise_a: f32,
    pub noise_b: f32,
}

/// Handwritten array-of-structures sensor grid.
#[derive(Clone, Debug, Default)]
pub struct HwSensorsAoS {
    pub rows: u32,
    pub cols: u32,
    pub event_id: u64,
    pub data: Vec<HwSensor>,
}

impl HwSensorsAoS {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> usize {
        r * self.cols as usize + c
    }
}

/// Handwritten structure-of-arrays sensor grid.
#[derive(Clone, Debug, Default)]
pub struct HwSensorsSoA {
    pub rows: u32,
    pub cols: u32,
    pub event_id: u64,
    pub type_id: Vec<i32>,
    pub counts: Vec<i32>,
    pub energy: Vec<f32>,
    pub noise: Vec<f32>,
    pub sig: Vec<f32>,
    pub noisy: Vec<u8>,
    pub param_a: Vec<f32>,
    pub param_b: Vec<f32>,
    pub noise_a: Vec<f32>,
    pub noise_b: Vec<f32>,
}

impl HwSensorsSoA {
    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    pub fn resize(&mut self, n: usize) {
        self.type_id.resize(n, 0);
        self.counts.resize(n, 0);
        self.energy.resize(n, 0.0);
        self.noise.resize(n, 0.0);
        self.sig.resize(n, 0.0);
        self.noisy.resize(n, 0);
        self.param_a.resize(n, 0.0);
        self.param_b.resize(n, 0.0);
        self.noise_a.resize(n, 0.0);
        self.noise_b.resize(n, 0.0);
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> usize {
        r * self.cols as usize + c
    }
}

// The handwritten baselines implement the reconstruction grid-view
// trait next to their structs (the Marionette side has exactly one
// impl — the borrowed `SensorView` — in `reco`).

impl super::reco::SensorGridView for HwSensorsAoS {
    fn rows(&self) -> usize {
        self.rows as usize
    }
    fn cols(&self) -> usize {
        self.cols as usize
    }
    #[inline(always)]
    fn energy_at(&self, i: usize) -> f32 {
        self.data[i].energy
    }
    #[inline(always)]
    fn sig_at(&self, i: usize) -> f32 {
        self.data[i].sig
    }
    #[inline(always)]
    fn type_at(&self, i: usize) -> i32 {
        self.data[i].type_id
    }
    #[inline(always)]
    fn noisy_at(&self, i: usize) -> bool {
        self.data[i].noisy != 0
    }
    fn event_id(&self) -> u64 {
        self.event_id
    }
}

impl super::reco::SensorGridView for HwSensorsSoA {
    fn rows(&self) -> usize {
        self.rows as usize
    }
    fn cols(&self) -> usize {
        self.cols as usize
    }
    #[inline(always)]
    fn energy_at(&self, i: usize) -> f32 {
        self.energy[i]
    }
    #[inline(always)]
    fn sig_at(&self, i: usize) -> f32 {
        self.sig[i]
    }
    #[inline(always)]
    fn type_at(&self, i: usize) -> i32 {
        self.type_id[i]
    }
    #[inline(always)]
    fn noisy_at(&self, i: usize) -> bool {
        self.noisy[i] != 0
    }
    fn event_id(&self) -> u64 {
        self.event_id
    }
}

/// Handwritten particle record (paper listing 2).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HwParticle {
    pub energy: f32,
    pub x: f32,
    pub y: f32,
    pub x_variance: f32,
    pub y_variance: f32,
    pub origin: u64,
    pub significance: [f32; NUM_SENSOR_TYPES],
    pub e_contribution: [f32; NUM_SENSOR_TYPES],
    pub noisy_count: [u8; NUM_SENSOR_TYPES],
    pub sensors: Vec<u64>,
}

/// Handwritten array-of-structures particle list ("the original data
/// structures" that Figure 2's final fill-back step targets).
#[derive(Clone, Debug, Default)]
pub struct HwParticlesAoS {
    pub event_id: u64,
    pub data: Vec<HwParticle>,
}

/// Handwritten structure-of-arrays particle list, jagged sensors stored
/// the classic way: a prefix-sum plus a flat value array.
#[derive(Clone, Debug, Default)]
pub struct HwParticlesSoA {
    pub event_id: u64,
    pub energy: Vec<f32>,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub x_variance: Vec<f32>,
    pub y_variance: Vec<f32>,
    pub origin: Vec<u64>,
    /// Plane-major per-type arrays (`[t][i]`).
    pub significance: [Vec<f32>; NUM_SENSOR_TYPES],
    pub e_contribution: [Vec<f32>; NUM_SENSOR_TYPES],
    pub noisy_count: [Vec<u8>; NUM_SENSOR_TYPES],
    pub sensors_prefix: Vec<u32>,
    pub sensors_values: Vec<u64>,
}

impl HwParticlesSoA {
    pub fn new() -> Self {
        Self { sensors_prefix: vec![0], ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.energy.len()
    }

    pub fn is_empty(&self) -> bool {
        self.energy.is_empty()
    }

    pub fn push(&mut self, p: &HwParticle) {
        self.energy.push(p.energy);
        self.x.push(p.x);
        self.y.push(p.y);
        self.x_variance.push(p.x_variance);
        self.y_variance.push(p.y_variance);
        self.origin.push(p.origin);
        for t in 0..NUM_SENSOR_TYPES {
            self.significance[t].push(p.significance[t]);
            self.e_contribution[t].push(p.e_contribution[t]);
            self.noisy_count[t].push(p.noisy_count[t]);
        }
        self.sensors_values.extend_from_slice(&p.sensors);
        self.sensors_prefix.push(self.sensors_values.len() as u32);
    }

    pub fn sensors(&self, i: usize) -> &[u64] {
        let lo = self.sensors_prefix[i] as usize;
        let hi = self.sensors_prefix[i + 1] as usize;
        &self.sensors_values[lo..hi]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soa_particles_jagged() {
        let mut s = HwParticlesSoA::new();
        let mut p = HwParticle { sensors: vec![1, 2, 3], ..Default::default() };
        s.push(&p);
        p.sensors = vec![9];
        s.push(&p);
        assert_eq!(s.sensors(0), &[1, 2, 3]);
        assert_eq!(s.sensors(1), &[9]);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn soa_sensors_resize() {
        let mut s = HwSensorsSoA { rows: 2, cols: 2, ..Default::default() };
        s.resize(4);
        assert_eq!(s.len(), 4);
        assert_eq!(s.at(1, 1), 3);
    }
}

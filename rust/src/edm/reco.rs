//! Host particle reconstruction (Figure 2's compute stage).
//!
//! Physics definition = `ref.py:particle_stage_ref`: a sensor seeds a
//! particle when its significance exceeds [`SEED_SIGNIFICANCE`] and its
//! energy attains the 5×5 window maximum (window clipped at the grid
//! border, matching the reference's −∞ padding); particle properties are
//! window sums; contributing sensors are those with significance above
//! [`CONTRIB_SIGNIFICANCE`], collected row-major.
//!
//! The algorithm is written once over the [`SensorGridView`] trait and
//! monomorphised for every store — the paper's setup, where the same
//! algorithmic code runs against either data structure. On the
//! Marionette side there is exactly **one** impl: the borrowed
//! [`SensorView`] over any [`PlaneSource`], which covers the owned
//! collection of every layout, pool-recycled staging collections, and
//! schema-shaped slice stores such as downloaded device planes
//! ([`SlicePlanes`](crate::marionette::interface::SlicePlanes)). The
//! handwritten baselines implement the trait next to their structs in
//! [`handwritten`](super::handwritten). [`particles_from_download`] is
//! the device-path twin: it gathers the same quantities from the AOT
//! executable's seed mask + window-sum planes through a sensor view.

use crate::marionette::collection::InfoOf;
use crate::marionette::interface::PlaneSource;
use crate::marionette::layout::Layout;

use super::constants::*;
use super::handwritten::{HwParticle, HwParticlesAoS, HwParticlesSoA, HwSensorsSoA};
use super::particle::{Particle, ParticleCollection};
use super::sensor::{SensorCollection, SensorView};

/// Read-only grid view: what reconstruction needs from a sensor store.
pub trait SensorGridView {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn energy_at(&self, i: usize) -> f32;
    fn sig_at(&self, i: usize) -> f32;
    fn type_at(&self, i: usize) -> i32;
    fn noisy_at(&self, i: usize) -> bool;
    fn event_id(&self) -> u64;
}

/// The one Marionette-side impl: the borrowed typed view over **any**
/// schema-matching source — owned collections of every layout, pooled
/// staging collections, downloaded device planes. Accessors are
/// raw-offset reads resolved at attach; monomorphisation keeps the
/// stencil loop free of per-element dispatch.
impl<S: PlaneSource> SensorGridView for SensorView<'_, S> {
    fn rows(&self) -> usize {
        SensorView::rows(self) as usize
    }
    fn cols(&self) -> usize {
        SensorView::cols(self) as usize
    }
    #[inline(always)]
    fn energy_at(&self, i: usize) -> f32 {
        SensorView::energy(self, i)
    }
    #[inline(always)]
    fn sig_at(&self, i: usize) -> f32 {
        SensorView::sig(self, i)
    }
    #[inline(always)]
    fn type_at(&self, i: usize) -> i32 {
        SensorView::type_id(self, i)
    }
    #[inline(always)]
    fn noisy_at(&self, i: usize) -> bool {
        SensorView::noisy(self, i) != 0
    }
    fn event_id(&self) -> u64 {
        SensorView::event_id(self)
    }
}

#[inline]
fn window(r: usize, n: usize) -> (usize, usize) {
    (r.saturating_sub(HALO), (r + HALO + 1).min(n))
}

/// Is cell `(r, c)` a seed? (significance cut + window max of energy)
#[inline]
fn is_seed<G: SensorGridView>(g: &G, r: usize, c: usize) -> bool {
    let cols = g.cols();
    let i = r * cols + c;
    if g.sig_at(i) <= SEED_SIGNIFICANCE {
        return false;
    }
    let e = g.energy_at(i);
    let (rlo, rhi) = window(r, g.rows());
    let (clo, chi) = window(c, cols);
    for rr in rlo..rhi {
        for cc in clo..chi {
            if g.energy_at(rr * cols + cc) > e {
                return false;
            }
        }
    }
    true
}

/// Accumulate one particle from the window around seed `(r, c)`.
fn build_particle<G: SensorGridView>(g: &G, r: usize, c: usize) -> HwParticle {
    let cols = g.cols();
    let (rlo, rhi) = window(r, g.rows());
    let (clo, chi) = window(c, cols);
    let (mut e_sum, mut ex, mut ey, mut exx, mut eyy) = (0f32, 0f32, 0f32, 0f32, 0f32);
    let mut e_t = [0f32; NUM_SENSOR_TYPES];
    let mut sig_t = [0f32; NUM_SENSOR_TYPES];
    let mut noisy_t = [0u8; NUM_SENSOR_TYPES];
    let mut sensors = Vec::new();
    for rr in rlo..rhi {
        for cc in clo..chi {
            let i = rr * cols + cc;
            let e = g.energy_at(i);
            let sig = g.sig_at(i);
            let t = g.type_at(i) as usize;
            let (x, y) = (cc as f32, rr as f32);
            e_sum += e;
            ex += e * x;
            ey += e * y;
            exx += e * x * x;
            eyy += e * y * y;
            e_t[t] += e;
            sig_t[t] += sig;
            if g.noisy_at(i) {
                noisy_t[t] += 1;
            }
            if sig > CONTRIB_SIGNIFICANCE {
                sensors.push(i as u64);
            }
        }
    }
    let x_mean = ex / e_sum;
    let y_mean = ey / e_sum;
    HwParticle {
        energy: e_sum,
        x: x_mean,
        y: y_mean,
        x_variance: exx / e_sum - x_mean * x_mean,
        y_variance: eyy / e_sum - y_mean * y_mean,
        origin: (r * cols + c) as u64,
        significance: sig_t,
        e_contribution: e_t,
        noisy_count: noisy_t,
        sensors,
    }
}

/// Reconstruct all particles of a calibrated grid (row-major seed order).
///
/// For Marionette collections use [`reconstruct_collection`] (or attach
/// a [`SensorView`] yourself and pass it here): the view resolves dense
/// per-item planes once at attach, so the scan runs at dense-slice
/// speed on regular layouts and owned-accessor speed on irregular ones.
pub fn reconstruct<G: SensorGridView>(g: &G) -> Vec<HwParticle> {
    let (rows, cols) = (g.rows(), g.cols());
    let mut out = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if is_seed(g, r, c) {
                out.push(build_particle(g, r, c));
            }
        }
    }
    out
}

/// Reconstruct a Marionette sensor collection through its borrowed
/// typed view (the owned special case of the one view-generic
/// [`SensorGridView`] impl).
pub fn reconstruct_collection<L: Layout>(s: &SensorCollection<L>) -> Vec<HwParticle> {
    reconstruct(&s.view())
}

/// Fill reconstruction output into a Marionette particle collection.
///
/// Bulk path: size once, write the scalar payload through the dense
/// record or column views, then append the jagged sensor lists — the
/// collection-interface analogue of a handwritten fill loop. Falls back
/// to object pushes on irregular layouts.
pub fn into_collection<L: Layout>(
    event_id: u64,
    particles: &[HwParticle],
) -> ParticleCollection<L>
where
    InfoOf<L>: Default,
{
    let mut col = ParticleCollection::<L>::new();
    col.set_event_id(event_id);
    col.resize(particles.len());

    let bulk_scalars = if let Some(recs) = col.records_mut() {
        for (r, p) in recs.iter_mut().zip(particles) {
            r.energy = p.energy;
            r.x = p.x;
            r.y = p.y;
            r.x_variance = p.x_variance;
            r.y_variance = p.y_variance;
            r.origin = p.origin;
            r.significance = p.significance;
            r.e_contribution = p.e_contribution;
            r.noisy_count = p.noisy_count;
        }
        true
    } else if let Some(c) = col.columns_mut() {
        for (i, p) in particles.iter().enumerate() {
            c.energy[i] = p.energy;
            c.x[i] = p.x;
            c.y[i] = p.y;
            c.x_variance[i] = p.x_variance;
            c.y_variance[i] = p.y_variance;
            c.origin[i] = p.origin;
            for t in 0..NUM_SENSOR_TYPES {
                c.significance[t][i] = p.significance[t];
                c.e_contribution[t][i] = p.e_contribution[t];
                c.noisy_count[t][i] = p.noisy_count[t];
            }
        }
        true
    } else {
        false
    };

    if !bulk_scalars {
        col.resize(0);
        for p in particles {
            col.push(&Particle {
                energy: p.energy,
                x: p.x,
                y: p.y,
                x_variance: p.x_variance,
                y_variance: p.y_variance,
                origin: p.origin,
                significance: p.significance,
                e_contribution: p.e_contribution,
                noisy_count: p.noisy_count,
                sensors: p.sensors.clone(),
            });
        }
        return col;
    }

    // Jagged sensor lists: rebuild the prefix once, then write values.
    let lens: Vec<usize> = particles.iter().map(|p| p.sensors.len()).collect();
    let j = super::particle::ParticleProps::SENSORS.j;
    let vmeta = super::particle::ParticleProps::SENSORS.values;
    col.raw_mut().set_jagged_lengths(j, &lens);
    let mut v = 0usize;
    for p in particles {
        for &s in &p.sensors {
            col.raw_mut().set_value::<u64>(vmeta, v, s);
            v += 1;
        }
    }
    col
}

/// Reconstruct straight into a Marionette particle collection (no
/// intermediate `Vec<HwParticle>`; the device path and benches use this).
pub fn reconstruct_into_collection<L: Layout>(
    s: &SensorCollection<L>,
) -> ParticleCollection<L>
where
    InfoOf<L>: Default,
{
    // Reuse the view-based scan of `reconstruct_collection`; pushes are
    // O(#particles), far off the critical path of the grid scan.
    let particles = reconstruct_collection(s);
    into_collection(s.event_id(), &particles)
}

/// Final step of Figure 2: fill the pre-existing handwritten AoS from a
/// Marionette particle collection ("the original data structures").
/// When the collection is AoS-dense, the scalar payload is read through
/// the generated record view (one pass, no per-field accessor calls).
pub fn fill_back_aos<L: Layout>(col: &ParticleCollection<L>) -> HwParticlesAoS {
    let mut out = HwParticlesAoS { event_id: col.event_id(), data: Vec::with_capacity(col.len()) };
    if let Some(recs) = col.records() {
        for (i, r) in recs.iter().enumerate() {
            out.data.push(HwParticle {
                energy: r.energy,
                x: r.x,
                y: r.y,
                x_variance: r.x_variance,
                y_variance: r.y_variance,
                origin: r.origin,
                significance: r.significance,
                e_contribution: r.e_contribution,
                noisy_count: r.noisy_count,
                sensors: col.sensors(i).to_vec(),
            });
        }
        return out;
    }
    for i in 0..col.len() {
        let mut sig = [0f32; NUM_SENSOR_TYPES];
        let mut e_c = [0f32; NUM_SENSOR_TYPES];
        let mut nc = [0u8; NUM_SENSOR_TYPES];
        for t in 0..NUM_SENSOR_TYPES {
            sig[t] = col.significance(i, t);
            e_c[t] = col.e_contribution(i, t);
            nc[t] = col.noisy_count(i, t);
        }
        out.data.push(HwParticle {
            energy: col.energy(i),
            x: col.x(i),
            y: col.y(i),
            x_variance: col.x_variance(i),
            y_variance: col.y_variance(i),
            origin: col.origin(i),
            significance: sig,
            e_contribution: e_c,
            noisy_count: nc,
            sensors: col.sensors(i).to_vec(),
        });
    }
    out
}

/// Fill the original AoS from the handwritten SoA particle structure
/// (the conversion step of the handwritten CPU-SoA series in Figure 2).
pub fn hw_soa_fill_back_aos(p: &HwParticlesSoA) -> HwParticlesAoS {
    let mut out = HwParticlesAoS { event_id: p.event_id, data: Vec::with_capacity(p.len()) };
    for i in 0..p.len() {
        let mut sig = [0f32; NUM_SENSOR_TYPES];
        let mut e_c = [0f32; NUM_SENSOR_TYPES];
        let mut nc = [0u8; NUM_SENSOR_TYPES];
        for t in 0..NUM_SENSOR_TYPES {
            sig[t] = p.significance[t][i];
            e_c[t] = p.e_contribution[t][i];
            nc[t] = p.noisy_count[t][i];
        }
        out.data.push(HwParticle {
            energy: p.energy[i],
            x: p.x[i],
            y: p.y[i],
            x_variance: p.x_variance[i],
            y_variance: p.y_variance[i],
            origin: p.origin[i],
            significance: sig,
            e_contribution: e_c,
            noisy_count: nc,
            sensors: p.sensors(i).to_vec(),
        });
    }
    out
}

/// Handwritten-SoA reconstruction output (CPU-SoA series of Figure 2).
pub fn reconstruct_to_hw_soa(g: &HwSensorsSoA) -> HwParticlesSoA {
    let mut out = HwParticlesSoA::new();
    out.event_id = g.event_id;
    for p in reconstruct(g) {
        out.push(&p);
    }
    out
}

/// The shared device-path gather: build the particle collection from
/// the AOT executable's outputs (`seeds` mask, `sums` =
/// `[NUM_PLANES][rows*cols]` window-sum planes) plus a host-readable
/// significance lookup for the jagged contributor lists.
fn particles_from_planes_core<L: Layout>(
    rows: usize,
    cols: usize,
    event_id: u64,
    seeds: &[i32],
    sums: &[f32],
    sig_at: impl Fn(usize) -> f32,
) -> ParticleCollection<L>
where
    InfoOf<L>: Default,
{
    let n = rows * cols;
    assert_eq!(seeds.len(), n, "seed mask size");
    assert_eq!(sums.len(), NUM_PLANES * n, "sums planes size");
    let plane = |p: usize, i: usize| sums[p * n + i];

    let mut col = ParticleCollection::<L>::new();
    col.set_event_id(event_id);
    let mut sensors = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            let i = r * cols + c;
            if seeds[i] == 0 {
                continue;
            }
            let e_sum = plane(PLANE_E, i);
            let x_mean = plane(PLANE_EX, i) / e_sum;
            let y_mean = plane(PLANE_EY, i) / e_sum;

            sensors.clear();
            let (rlo, rhi) = window(r, rows);
            let (clo, chi) = window(c, cols);
            for rr in rlo..rhi {
                for cc in clo..chi {
                    let j = rr * cols + cc;
                    if sig_at(j) > CONTRIB_SIGNIFICANCE {
                        sensors.push(j as u64);
                    }
                }
            }
            debug_assert_eq!(
                sensors.len(),
                plane(PLANE_CONTRIB, i).round() as usize,
                "host contributor scan disagrees with device plane at {i}"
            );

            let mut p = Particle {
                energy: e_sum,
                x: x_mean,
                y: y_mean,
                x_variance: plane(PLANE_EXX, i) / e_sum - x_mean * x_mean,
                y_variance: plane(PLANE_EYY, i) / e_sum - y_mean * y_mean,
                origin: i as u64,
                significance: [0.0; NUM_SENSOR_TYPES],
                e_contribution: [0.0; NUM_SENSOR_TYPES],
                noisy_count: [0; NUM_SENSOR_TYPES],
                sensors: sensors.clone(),
            };
            for t in 0..NUM_SENSOR_TYPES {
                p.significance[t] = plane(PLANE_SIG_TYPE + t, i);
                p.e_contribution[t] = plane(PLANE_E_TYPE + t, i);
                p.noisy_count[t] = plane(PLANE_NOISY_TYPE + t, i).round() as u8;
            }
            col.push(&p);
        }
    }
    col
}

/// Device-path gather over a downloaded sensor **view** (the pipeline's
/// route: `runtime::devmem::downloaded_planes` assembles the
/// schema-shaped slice store, the attached [`SensorView`] serves the
/// significance lookups and the grid geometry).
pub fn particles_from_download<L: Layout, S: PlaneSource>(
    g: &SensorView<'_, S>,
    seeds: &[i32],
    sums: &[f32],
) -> ParticleCollection<L>
where
    InfoOf<L>: Default,
{
    particles_from_planes_core(
        SensorGridView::rows(g),
        SensorGridView::cols(g),
        SensorView::event_id(g),
        seeds,
        sums,
        |i| SensorView::sig(g, i),
    )
}

/// Legacy slice-based spelling of the device-path gather. Deprecated:
/// prefer [`particles_from_download`], which reads geometry and
/// significance through the one sensor view; this shim remains for
/// callers that only hold the raw planes.
pub fn particles_from_planes<L: Layout>(
    rows: usize,
    cols: usize,
    event_id: u64,
    seeds: &[i32],
    sums: &[f32],
    sig: &[f32],
) -> ParticleCollection<L>
where
    InfoOf<L>: Default,
{
    assert_eq!(sig.len(), rows * cols, "sig plane size");
    particles_from_planes_core(rows, cols, event_id, seeds, sums, |i| sig[i])
}

#[cfg(test)]
mod tests {
    use super::super::calib;
    use super::super::generator::{EventConfig, EventGenerator};
    use super::super::handwritten::{HwSensorsAoS, HwSensorsSoA};
    use super::*;
    use crate::marionette::interface::SlicePlanes;
    use crate::marionette::layout::{AoS, AoSoA, SoAVec};

    fn calibrated_event(seed: u64) -> (SensorCollection<SoAVec>, HwSensorsAoS, HwSensorsSoA) {
        let ev = EventGenerator::new(EventConfig::grid(48, 48, 5), seed).generate();
        let mut col = ev.to_collection::<SoAVec>();
        calib::calibrate_collection(&mut col);
        let mut aos = Default::default();
        ev.fill_hw_aos(&mut aos);
        calib::calibrate_hw_aos(&mut aos);
        let mut soa = Default::default();
        ev.fill_hw_soa(&mut soa);
        calib::calibrate_hw_soa(&mut soa);
        (col, aos, soa)
    }

    #[test]
    fn all_views_reconstruct_identically() {
        let (col, aos, soa) = calibrated_event(21);
        let a = reconstruct(&col.view());
        let b = reconstruct(&aos);
        let c = reconstruct(&soa);
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert!(!a.is_empty(), "expected particles from 5 deposits");
    }

    /// The one view-generic impl serves every Marionette store: owned
    /// collections of regular and irregular layouts, a pooled staging
    /// copy, and a slice store standing in for downloaded device planes
    /// — all reconstruct bit-identically.
    #[test]
    fn one_view_impl_covers_owned_pooled_and_download_sources() {
        use crate::marionette::memory::{HostContext, PoolContext, PoolInfo};
        let (col, _, soa) = calibrated_event(34);
        let want = reconstruct_collection(&col);
        assert!(!want.is_empty());

        // Owned, irregular layout (no dense planes anywhere).
        let blocked = col.convert_to::<AoSoA<8>>();
        assert_eq!(reconstruct_collection(&blocked), want);

        // Pool-recycled staging collection.
        let info = PoolInfo::<HostContext>::default();
        let mut pooled =
            SensorCollection::<AoS<PoolContext<HostContext>>>::new_in(info);
        col.stage_into(&mut pooled);
        assert_eq!(reconstruct(&pooled.view()), want);

        // Download-shaped source: schema-matching borrowed slices (the
        // handwritten SoA's columns double as the downloaded planes).
        let rows = soa.rows;
        let cols = soa.cols;
        let planes = SlicePlanes::new(super::super::sensor::SensorProps::schema(), soa.len())
            .bind("type_id", &soa.type_id)
            .unwrap()
            .bind("counts", &soa.counts)
            .unwrap()
            .bind("energy", &soa.energy)
            .unwrap()
            .bind("noise", &soa.noise)
            .unwrap()
            .bind("sig", &soa.sig)
            .unwrap()
            .bind("noisy", &soa.noisy)
            .unwrap()
            .bind("param_a", &soa.param_a)
            .unwrap()
            .bind("param_b", &soa.param_b)
            .unwrap()
            .bind("noise_a", &soa.noise_a)
            .unwrap()
            .bind("noise_b", &soa.noise_b)
            .unwrap()
            .set_global("rows", rows)
            .unwrap()
            .set_global("cols", cols)
            .unwrap()
            .set_global("event_id", soa.event_id)
            .unwrap();
        let v = SensorView::attach(&planes).unwrap();
        assert_eq!(reconstruct(&v), want);
    }

    #[test]
    fn finds_injected_deposits() {
        let ev = EventGenerator::new(EventConfig::grid(64, 64, 4), 33).generate();
        let mut col = ev.to_collection::<SoAVec>();
        calib::calibrate_collection(&mut col);
        let particles = reconstruct_collection(&col);
        // Every isolated truth deposit should have a particle within 2
        // cells (deposits can merge, so require >= half found).
        let mut found = 0;
        for &(r, c) in &ev.truth {
            if particles.iter().any(|p| {
                (p.y - r as f32).abs() <= 2.0 && (p.x - c as f32).abs() <= 2.0
            }) {
                found += 1;
            }
        }
        assert!(
            found * 2 >= ev.truth.len(),
            "found {found}/{} deposits",
            ev.truth.len()
        );
    }

    #[test]
    fn particle_physics_sane() {
        let (col, _, _) = calibrated_event(5);
        for p in reconstruct_collection(&col) {
            assert!(p.energy > 0.0);
            assert!(p.x >= 0.0 && p.x < 48.0);
            assert!(p.y >= 0.0 && p.y < 48.0);
            // Per-type energies partition the window total.
            let sum: f32 = p.e_contribution.iter().sum();
            assert!((sum - p.energy).abs() <= 1e-3 * p.energy.abs().max(1.0));
            // Every contributing sensor is inside the window of origin.
            let (r, c) = ((p.origin / 48) as i64, (p.origin % 48) as i64);
            for &s in &p.sensors {
                let (sr, sc) = ((s / 48) as i64, (s % 48) as i64);
                assert!((sr - r).abs() <= 2 && (sc - c).abs() <= 2);
            }
        }
    }

    #[test]
    fn collection_roundtrip_and_fill_back() {
        let (col, _, _) = calibrated_event(8);
        let ps = reconstruct_collection(&col);
        let pc = into_collection::<AoS>(col.event_id(), &ps);
        assert_eq!(pc.len(), ps.len());
        let back = fill_back_aos(&pc);
        assert_eq!(back.data, ps);
        assert_eq!(back.event_id, col.event_id());
    }

    #[test]
    fn empty_grid_no_particles() {
        let mut s = SensorCollection::<SoAVec>::new();
        s.set_rows(8);
        s.set_cols(8);
        s.resize(64);
        assert!(reconstruct_collection(&s).is_empty());
    }

    #[test]
    fn border_seeds_use_clipped_windows() {
        // A single strong deposit in the corner: window must clip.
        let mut s = SensorCollection::<SoAVec>::new();
        s.set_rows(8);
        s.set_cols(8);
        s.resize(64);
        for i in 0..64 {
            s.set_noise_a(i, 1.0);
            s.set_param_a(i, 1.0);
        }
        s.set_counts(0, 1000);
        calib::calibrate_collection(&mut s);
        let ps = reconstruct_collection(&s);
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].origin, 0);
        assert_eq!(ps[0].energy, 1000.0);
        // Window is 3x3 at the corner: 9 cells max.
        assert!(ps[0].sensors.len() <= 9);
    }
}

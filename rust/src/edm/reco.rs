//! Host particle reconstruction (Figure 2's compute stage).
//!
//! Physics definition = `ref.py:particle_stage_ref`: a sensor seeds a
//! particle when its significance exceeds [`SEED_SIGNIFICANCE`] and its
//! energy attains the 5×5 window maximum (window clipped at the grid
//! border, matching the reference's −∞ padding); particle properties are
//! window sums; contributing sensors are those with significance above
//! [`CONTRIB_SIGNIFICANCE`], collected row-major.
//!
//! The algorithm is written once over the [`SensorGridView`] trait and
//! monomorphised for the Marionette collection and both handwritten
//! baselines — the paper's setup, where the same algorithmic code runs
//! against either data structure. [`particles_from_planes`] is the
//! device-path twin: it gathers the same quantities from the AOT
//! executable's seed mask + window-sum planes.

use crate::marionette::collection::InfoOf;
use crate::marionette::layout::Layout;

use super::constants::*;
use super::handwritten::{
    HwParticle, HwParticlesAoS, HwParticlesSoA, HwSensorsAoS, HwSensorsSoA,
};
use super::particle::{Particle, ParticleCollection};
use super::sensor::SensorCollection;

/// Read-only grid view: what reconstruction needs from a sensor store.
pub trait SensorGridView {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn energy_at(&self, i: usize) -> f32;
    fn sig_at(&self, i: usize) -> f32;
    fn type_at(&self, i: usize) -> i32;
    fn noisy_at(&self, i: usize) -> bool;
    fn event_id(&self) -> u64;
}

impl<L: Layout> SensorGridView for SensorCollection<L> {
    fn rows(&self) -> usize {
        SensorCollection::rows(self) as usize
    }
    fn cols(&self) -> usize {
        SensorCollection::cols(self) as usize
    }
    #[inline(always)]
    fn energy_at(&self, i: usize) -> f32 {
        self.energy(i)
    }
    #[inline(always)]
    fn sig_at(&self, i: usize) -> f32 {
        self.sig(i)
    }
    #[inline(always)]
    fn type_at(&self, i: usize) -> i32 {
        self.type_id(i)
    }
    #[inline(always)]
    fn noisy_at(&self, i: usize) -> bool {
        self.noisy(i) != 0
    }
    fn event_id(&self) -> u64 {
        SensorCollection::event_id(self)
    }
}

impl SensorGridView for HwSensorsAoS {
    fn rows(&self) -> usize {
        self.rows as usize
    }
    fn cols(&self) -> usize {
        self.cols as usize
    }
    #[inline(always)]
    fn energy_at(&self, i: usize) -> f32 {
        self.data[i].energy
    }
    #[inline(always)]
    fn sig_at(&self, i: usize) -> f32 {
        self.data[i].sig
    }
    #[inline(always)]
    fn type_at(&self, i: usize) -> i32 {
        self.data[i].type_id
    }
    #[inline(always)]
    fn noisy_at(&self, i: usize) -> bool {
        self.data[i].noisy != 0
    }
    fn event_id(&self) -> u64 {
        self.event_id
    }
}

impl SensorGridView for HwSensorsSoA {
    fn rows(&self) -> usize {
        self.rows as usize
    }
    fn cols(&self) -> usize {
        self.cols as usize
    }
    #[inline(always)]
    fn energy_at(&self, i: usize) -> f32 {
        self.energy[i]
    }
    #[inline(always)]
    fn sig_at(&self, i: usize) -> f32 {
        self.sig[i]
    }
    #[inline(always)]
    fn type_at(&self, i: usize) -> i32 {
        self.type_id[i]
    }
    #[inline(always)]
    fn noisy_at(&self, i: usize) -> bool {
        self.noisy[i] != 0
    }
    fn event_id(&self) -> u64 {
        self.event_id
    }
}

#[inline]
fn window(r: usize, n: usize) -> (usize, usize) {
    (r.saturating_sub(HALO), (r + HALO + 1).min(n))
}

/// Is cell `(r, c)` a seed? (significance cut + window max of energy)
#[inline]
fn is_seed<G: SensorGridView>(g: &G, r: usize, c: usize) -> bool {
    let cols = g.cols();
    let i = r * cols + c;
    if g.sig_at(i) <= SEED_SIGNIFICANCE {
        return false;
    }
    let e = g.energy_at(i);
    let (rlo, rhi) = window(r, g.rows());
    let (clo, chi) = window(c, cols);
    for rr in rlo..rhi {
        for cc in clo..chi {
            if g.energy_at(rr * cols + cc) > e {
                return false;
            }
        }
    }
    true
}

/// Accumulate one particle from the window around seed `(r, c)`.
fn build_particle<G: SensorGridView>(g: &G, r: usize, c: usize) -> HwParticle {
    let cols = g.cols();
    let (rlo, rhi) = window(r, g.rows());
    let (clo, chi) = window(c, cols);
    let (mut e_sum, mut ex, mut ey, mut exx, mut eyy) = (0f32, 0f32, 0f32, 0f32, 0f32);
    let mut e_t = [0f32; NUM_SENSOR_TYPES];
    let mut sig_t = [0f32; NUM_SENSOR_TYPES];
    let mut noisy_t = [0u8; NUM_SENSOR_TYPES];
    let mut sensors = Vec::new();
    for rr in rlo..rhi {
        for cc in clo..chi {
            let i = rr * cols + cc;
            let e = g.energy_at(i);
            let sig = g.sig_at(i);
            let t = g.type_at(i) as usize;
            let (x, y) = (cc as f32, rr as f32);
            e_sum += e;
            ex += e * x;
            ey += e * y;
            exx += e * x * x;
            eyy += e * y * y;
            e_t[t] += e;
            sig_t[t] += sig;
            if g.noisy_at(i) {
                noisy_t[t] += 1;
            }
            if sig > CONTRIB_SIGNIFICANCE {
                sensors.push(i as u64);
            }
        }
    }
    let x_mean = ex / e_sum;
    let y_mean = ey / e_sum;
    HwParticle {
        energy: e_sum,
        x: x_mean,
        y: y_mean,
        x_variance: exx / e_sum - x_mean * x_mean,
        y_variance: eyy / e_sum - y_mean * y_mean,
        origin: (r * cols + c) as u64,
        significance: sig_t,
        e_contribution: e_t,
        noisy_count: noisy_t,
        sensors,
    }
}

/// Reconstruct all particles of a calibrated grid (row-major seed order).
///
/// For Marionette collections prefer [`reconstruct_collection`], which
/// routes the scan through the collection's dense record/column views
/// (paper listing 3's collection-level accessors) instead of per-element
/// accessors — same results, handwritten-equal speed (EXPERIMENTS §Perf).
pub fn reconstruct<G: SensorGridView>(g: &G) -> Vec<HwParticle> {
    let (rows, cols) = (g.rows(), g.cols());
    let mut out = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if is_seed(g, r, c) {
                out.push(build_particle(g, r, c));
            }
        }
    }
    out
}

/// Dense-slice grid view (SoA layouts via plane slices).
struct SliceGrid<'a> {
    rows: usize,
    cols: usize,
    event_id: u64,
    energy: &'a [f32],
    sig: &'a [f32],
    types: &'a [i32],
    noisy: &'a [u8],
}

impl SensorGridView for SliceGrid<'_> {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    #[inline(always)]
    fn energy_at(&self, i: usize) -> f32 {
        self.energy[i]
    }
    #[inline(always)]
    fn sig_at(&self, i: usize) -> f32 {
        self.sig[i]
    }
    #[inline(always)]
    fn type_at(&self, i: usize) -> i32 {
        self.types[i]
    }
    #[inline(always)]
    fn noisy_at(&self, i: usize) -> bool {
        self.noisy[i] != 0
    }
    fn event_id(&self) -> u64 {
        self.event_id
    }
}

/// Dense-record grid view (AoS layouts via the generated record slice).
struct RecGrid<'a> {
    rows: usize,
    cols: usize,
    event_id: u64,
    recs: &'a [super::sensor::SensorRecord],
}

impl SensorGridView for RecGrid<'_> {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    #[inline(always)]
    fn energy_at(&self, i: usize) -> f32 {
        self.recs[i].energy
    }
    #[inline(always)]
    fn sig_at(&self, i: usize) -> f32 {
        self.recs[i].sig
    }
    #[inline(always)]
    fn type_at(&self, i: usize) -> i32 {
        self.recs[i].type_id
    }
    #[inline(always)]
    fn noisy_at(&self, i: usize) -> bool {
        self.recs[i].noisy != 0
    }
    fn event_id(&self) -> u64 {
        self.event_id
    }
}

/// Reconstruct a Marionette sensor collection through its densest
/// available view: records (AoS), plane slices (SoA family), or the
/// per-element accessors (irregular layouts).
pub fn reconstruct_collection<L: Layout>(s: &SensorCollection<L>) -> Vec<HwParticle> {
    use super::sensor::SensorProps as P;
    let (rows, cols) = (SensorGridView::rows(s), SensorGridView::cols(s));
    if let Some(recs) = s.records() {
        return reconstruct(&RecGrid { rows, cols, event_id: s.event_id(), recs });
    }
    let raw = s.raw();
    if let (Some(energy), Some(sig), Some(types), Some(noisy)) = (
        raw.field_slice::<f32>(P::ENERGY),
        raw.field_slice::<f32>(P::SIG),
        raw.field_slice::<i32>(P::TYPE_ID),
        raw.field_slice::<u8>(P::NOISY),
    ) {
        return reconstruct(&SliceGrid {
            rows,
            cols,
            event_id: s.event_id(),
            energy,
            sig,
            types,
            noisy,
        });
    }
    reconstruct(s)
}

/// Fill reconstruction output into a Marionette particle collection.
///
/// Bulk path: size once, write the scalar payload through the dense
/// record or column views, then append the jagged sensor lists — the
/// collection-interface analogue of a handwritten fill loop. Falls back
/// to object pushes on irregular layouts.
pub fn into_collection<L: Layout>(
    event_id: u64,
    particles: &[HwParticle],
) -> ParticleCollection<L>
where
    InfoOf<L>: Default,
{
    let mut col = ParticleCollection::<L>::new();
    col.set_event_id(event_id);
    col.resize(particles.len());

    let bulk_scalars = if let Some(recs) = col.records_mut() {
        for (r, p) in recs.iter_mut().zip(particles) {
            r.energy = p.energy;
            r.x = p.x;
            r.y = p.y;
            r.x_variance = p.x_variance;
            r.y_variance = p.y_variance;
            r.origin = p.origin;
            r.significance = p.significance;
            r.e_contribution = p.e_contribution;
            r.noisy_count = p.noisy_count;
        }
        true
    } else if let Some(c) = col.columns_mut() {
        for (i, p) in particles.iter().enumerate() {
            c.energy[i] = p.energy;
            c.x[i] = p.x;
            c.y[i] = p.y;
            c.x_variance[i] = p.x_variance;
            c.y_variance[i] = p.y_variance;
            c.origin[i] = p.origin;
            for t in 0..NUM_SENSOR_TYPES {
                c.significance[t][i] = p.significance[t];
                c.e_contribution[t][i] = p.e_contribution[t];
                c.noisy_count[t][i] = p.noisy_count[t];
            }
        }
        true
    } else {
        false
    };

    if !bulk_scalars {
        col.resize(0);
        for p in particles {
            col.push(&Particle {
                energy: p.energy,
                x: p.x,
                y: p.y,
                x_variance: p.x_variance,
                y_variance: p.y_variance,
                origin: p.origin,
                significance: p.significance,
                e_contribution: p.e_contribution,
                noisy_count: p.noisy_count,
                sensors: p.sensors.clone(),
            });
        }
        return col;
    }

    // Jagged sensor lists: rebuild the prefix once, then write values.
    let lens: Vec<usize> = particles.iter().map(|p| p.sensors.len()).collect();
    let j = super::particle::ParticleProps::SENSORS.j;
    let vmeta = super::particle::ParticleProps::SENSORS.values;
    col.raw_mut().set_jagged_lengths(j, &lens);
    let mut v = 0usize;
    for p in particles {
        for &s in &p.sensors {
            col.raw_mut().set_value::<u64>(vmeta, v, s);
            v += 1;
        }
    }
    col
}

/// Reconstruct straight into a Marionette particle collection (no
/// intermediate `Vec<HwParticle>`; the device path and benches use this).
pub fn reconstruct_into_collection<L: Layout>(
    s: &SensorCollection<L>,
) -> ParticleCollection<L>
where
    InfoOf<L>: Default,
{
    // Reuse the view-selection of `reconstruct_collection`; pushes are
    // O(#particles), far off the critical path of the grid scan.
    let particles = reconstruct_collection(s);
    into_collection(SensorGridView::event_id(s), &particles)
}

/// Final step of Figure 2: fill the pre-existing handwritten AoS from a
/// Marionette particle collection ("the original data structures").
/// When the collection is AoS-dense, the scalar payload is read through
/// the generated record view (one pass, no per-field accessor calls).
pub fn fill_back_aos<L: Layout>(col: &ParticleCollection<L>) -> HwParticlesAoS {
    let mut out = HwParticlesAoS { event_id: col.event_id(), data: Vec::with_capacity(col.len()) };
    if let Some(recs) = col.records() {
        for (i, r) in recs.iter().enumerate() {
            out.data.push(HwParticle {
                energy: r.energy,
                x: r.x,
                y: r.y,
                x_variance: r.x_variance,
                y_variance: r.y_variance,
                origin: r.origin,
                significance: r.significance,
                e_contribution: r.e_contribution,
                noisy_count: r.noisy_count,
                sensors: col.sensors(i).to_vec(),
            });
        }
        return out;
    }
    for i in 0..col.len() {
        let mut sig = [0f32; NUM_SENSOR_TYPES];
        let mut e_c = [0f32; NUM_SENSOR_TYPES];
        let mut nc = [0u8; NUM_SENSOR_TYPES];
        for t in 0..NUM_SENSOR_TYPES {
            sig[t] = col.significance(i, t);
            e_c[t] = col.e_contribution(i, t);
            nc[t] = col.noisy_count(i, t);
        }
        out.data.push(HwParticle {
            energy: col.energy(i),
            x: col.x(i),
            y: col.y(i),
            x_variance: col.x_variance(i),
            y_variance: col.y_variance(i),
            origin: col.origin(i),
            significance: sig,
            e_contribution: e_c,
            noisy_count: nc,
            sensors: col.sensors(i).to_vec(),
        });
    }
    out
}

/// Fill the original AoS from the handwritten SoA particle structure
/// (the conversion step of the handwritten CPU-SoA series in Figure 2).
pub fn hw_soa_fill_back_aos(p: &HwParticlesSoA) -> HwParticlesAoS {
    let mut out = HwParticlesAoS { event_id: p.event_id, data: Vec::with_capacity(p.len()) };
    for i in 0..p.len() {
        let mut sig = [0f32; NUM_SENSOR_TYPES];
        let mut e_c = [0f32; NUM_SENSOR_TYPES];
        let mut nc = [0u8; NUM_SENSOR_TYPES];
        for t in 0..NUM_SENSOR_TYPES {
            sig[t] = p.significance[t][i];
            e_c[t] = p.e_contribution[t][i];
            nc[t] = p.noisy_count[t][i];
        }
        out.data.push(HwParticle {
            energy: p.energy[i],
            x: p.x[i],
            y: p.y[i],
            x_variance: p.x_variance[i],
            y_variance: p.y_variance[i],
            origin: p.origin[i],
            significance: sig,
            e_contribution: e_c,
            noisy_count: nc,
            sensors: p.sensors(i).to_vec(),
        });
    }
    out
}

/// Handwritten-SoA reconstruction output (CPU-SoA series of Figure 2).
pub fn reconstruct_to_hw_soa(g: &HwSensorsSoA) -> HwParticlesSoA {
    let mut out = HwParticlesSoA::new();
    out.event_id = g.event_id;
    for p in reconstruct(g) {
        out.push(&p);
    }
    out
}

/// Device-path gather: build the particle collection from the AOT
/// executable's outputs (`seeds` mask, `sums` = `[NUM_PLANES][rows*cols]`
/// window-sum planes) plus the host-resident significance plane for the
/// jagged contributor lists.
pub fn particles_from_planes<L: Layout>(
    rows: usize,
    cols: usize,
    event_id: u64,
    seeds: &[i32],
    sums: &[f32],
    sig: &[f32],
) -> ParticleCollection<L>
where
    InfoOf<L>: Default,
{
    let n = rows * cols;
    assert_eq!(seeds.len(), n, "seed mask size");
    assert_eq!(sums.len(), NUM_PLANES * n, "sums planes size");
    assert_eq!(sig.len(), n, "sig plane size");
    let plane = |p: usize, i: usize| sums[p * n + i];

    let mut col = ParticleCollection::<L>::new();
    col.set_event_id(event_id);
    let mut sensors = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            let i = r * cols + c;
            if seeds[i] == 0 {
                continue;
            }
            let e_sum = plane(PLANE_E, i);
            let x_mean = plane(PLANE_EX, i) / e_sum;
            let y_mean = plane(PLANE_EY, i) / e_sum;

            sensors.clear();
            let (rlo, rhi) = window(r, rows);
            let (clo, chi) = window(c, cols);
            for rr in rlo..rhi {
                for cc in clo..chi {
                    let j = rr * cols + cc;
                    if sig[j] > CONTRIB_SIGNIFICANCE {
                        sensors.push(j as u64);
                    }
                }
            }
            debug_assert_eq!(
                sensors.len(),
                plane(PLANE_CONTRIB, i).round() as usize,
                "host contributor scan disagrees with device plane at {i}"
            );

            let mut p = Particle {
                energy: e_sum,
                x: x_mean,
                y: y_mean,
                x_variance: plane(PLANE_EXX, i) / e_sum - x_mean * x_mean,
                y_variance: plane(PLANE_EYY, i) / e_sum - y_mean * y_mean,
                origin: i as u64,
                significance: [0.0; NUM_SENSOR_TYPES],
                e_contribution: [0.0; NUM_SENSOR_TYPES],
                noisy_count: [0; NUM_SENSOR_TYPES],
                sensors: sensors.clone(),
            };
            for t in 0..NUM_SENSOR_TYPES {
                p.significance[t] = plane(PLANE_SIG_TYPE + t, i);
                p.e_contribution[t] = plane(PLANE_E_TYPE + t, i);
                p.noisy_count[t] = plane(PLANE_NOISY_TYPE + t, i).round() as u8;
            }
            col.push(&p);
        }
    }
    col
}

#[cfg(test)]
mod tests {
    use super::super::calib;
    use super::super::generator::{EventConfig, EventGenerator};
    use super::*;
    use crate::marionette::layout::{AoS, SoAVec};

    fn calibrated_event(seed: u64) -> (SensorCollection<SoAVec>, HwSensorsAoS, HwSensorsSoA) {
        let ev = EventGenerator::new(EventConfig::grid(48, 48, 5), seed).generate();
        let mut col = ev.to_collection::<SoAVec>();
        calib::calibrate_collection(&mut col);
        let mut aos = Default::default();
        ev.fill_hw_aos(&mut aos);
        calib::calibrate_hw_aos(&mut aos);
        let mut soa = Default::default();
        ev.fill_hw_soa(&mut soa);
        calib::calibrate_hw_soa(&mut soa);
        (col, aos, soa)
    }

    #[test]
    fn all_views_reconstruct_identically() {
        let (col, aos, soa) = calibrated_event(21);
        let a = reconstruct(&col);
        let b = reconstruct(&aos);
        let c = reconstruct(&soa);
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert!(!a.is_empty(), "expected particles from 5 deposits");
    }

    #[test]
    fn finds_injected_deposits() {
        let ev = EventGenerator::new(EventConfig::grid(64, 64, 4), 33).generate();
        let mut col = ev.to_collection::<SoAVec>();
        calib::calibrate_collection(&mut col);
        let particles = reconstruct(&col);
        // Every isolated truth deposit should have a particle within 2
        // cells (deposits can merge, so require >= half found).
        let mut found = 0;
        for &(r, c) in &ev.truth {
            if particles.iter().any(|p| {
                (p.y - r as f32).abs() <= 2.0 && (p.x - c as f32).abs() <= 2.0
            }) {
                found += 1;
            }
        }
        assert!(
            found * 2 >= ev.truth.len(),
            "found {found}/{} deposits",
            ev.truth.len()
        );
    }

    #[test]
    fn particle_physics_sane() {
        let (col, _, _) = calibrated_event(5);
        for p in reconstruct(&col) {
            assert!(p.energy > 0.0);
            assert!(p.x >= 0.0 && p.x < 48.0);
            assert!(p.y >= 0.0 && p.y < 48.0);
            // Per-type energies partition the window total.
            let sum: f32 = p.e_contribution.iter().sum();
            assert!((sum - p.energy).abs() <= 1e-3 * p.energy.abs().max(1.0));
            // Every contributing sensor is inside the window of origin.
            let (r, c) = ((p.origin / 48) as i64, (p.origin % 48) as i64);
            for &s in &p.sensors {
                let (sr, sc) = ((s / 48) as i64, (s % 48) as i64);
                assert!((sr - r).abs() <= 2 && (sc - c).abs() <= 2);
            }
        }
    }

    #[test]
    fn collection_roundtrip_and_fill_back() {
        let (col, _, _) = calibrated_event(8);
        let ps = reconstruct(&col);
        let pc = into_collection::<AoS>(col.event_id(), &ps);
        assert_eq!(pc.len(), ps.len());
        let back = fill_back_aos(&pc);
        assert_eq!(back.data, ps);
        assert_eq!(back.event_id, col.event_id());
    }

    #[test]
    fn empty_grid_no_particles() {
        let mut s = SensorCollection::<SoAVec>::new();
        s.set_rows(8);
        s.set_cols(8);
        s.resize(64);
        assert!(reconstruct(&s).is_empty());
    }

    #[test]
    fn border_seeds_use_clipped_windows() {
        // A single strong deposit in the corner: window must clip.
        let mut s = SensorCollection::<SoAVec>::new();
        s.set_rows(8);
        s.set_cols(8);
        s.resize(64);
        for i in 0..64 {
            s.set_noise_a(i, 1.0);
            s.set_param_a(i, 1.0);
        }
        s.set_counts(0, 1000);
        calib::calibrate_collection(&mut s);
        let ps = reconstruct(&s);
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].origin, 0);
        assert_eq!(ps[0].energy, 1000.0);
        // Window is 3x3 at the corner: 9 cells max.
        assert!(ps[0].sensors.len() <= 9);
    }
}

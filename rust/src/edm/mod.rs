//! The event data model of the paper's motivating example (§III).
//!
//! A 2D grid of sensors of several types measures particle energy
//! deposits; raw counts are calibrated to energies with per-sensor
//! constants; particles are reconstructed from the 5×5 neighbourhood of
//! sufficiently significant local maxima, tracking per-sensor-type
//! properties and the jagged list of contributing sensors.
//!
//! * [`sensor`] / [`particle`] — the Marionette collections (via
//!   `marionette_collection!`), including the paper's *no-property*
//!   interface extensions (`calibrate_energy`, `get_noise`).
//! * [`handwritten`] — the handwritten AoS and SoA baselines the paper
//!   benchmarks against (single source of truth for "what a programmer
//!   would have written by hand").
//! * [`generator`] — synthetic event generation (Gaussian deposits over a
//!   noisy grid; the Rust twin of `python/compile/aot.py:generate_event`).
//! * [`calib`] / [`reco`] — the host algorithms (Figure 1's sensor stage
//!   and Figure 2's particle stage), each implemented over Marionette
//!   collections *and* over the handwritten baselines with identical
//!   semantics, matching `python/compile/kernels/ref.py`.
//! * [`convert`] — the handwritten AoS↔SoA sensor conversions registered
//!   as `Specialized` rungs inside the transfer plans (paper's
//!   `TransferSpecification` user fast paths).
//! * [`golden`] — loads the Python-generated golden vectors for
//!   cross-language equivalence tests.

pub mod calib;
pub mod constants;
pub mod convert;
pub mod generator;
pub mod golden;
pub mod handwritten;
pub mod particle;
pub mod reco;
pub mod sensor;

pub use constants::*;
pub use generator::{EventConfig, EventGenerator, RawEvent};
pub use particle::{
    Particle, ParticleCollection, ParticleProps, ParticleRecord, ParticleView, ParticleViewMut,
};
pub use sensor::{
    Sensor, SensorCollection, SensorColumns, SensorProps, SensorRecord, SensorView,
    SensorViewMut,
};

//! The `Sensor` collection (paper listing 1/4), declared in Marionette.
//!
//! Per-item raw data (`type_id`, `counts`), computed planes (`energy`,
//! `noise`, `sig`), the calibration sub-group (paper:
//! `calibration_data`), grid geometry globals, and the *no-property*
//! interface extension (`calibrate_energy` / `get_noise`, implemented as
//! an ordinary inherent impl on the generated collection, exactly as the
//! paper's `ObjectFunctions`/`CollectionFunctions` splice functions into
//! the final type).

use crate::marionette::layout::Layout;
use crate::marionette_collection;

use super::constants::NOISE_FLOOR;

marionette_collection! {
    /// A 2D grid of sensors stored row-major (`i = r * cols + c`).
    pub collection SensorCollection, object Sensor, record SensorRecord,
        columns SensorColumns, refs SensorRef / SensorMut,
        views SensorView / SensorViewMut,
        props SensorProps, schema "sensor" {
        per_item type_id / set_type_id / TYPE_ID: i32;
        per_item counts / set_counts / COUNTS: i32;
        per_item energy / set_energy / ENERGY: f32;
        per_item noise / set_noise / NOISE: f32;
        per_item sig / set_sig / SIG: f32;
        group calibration / CalibrationView / CalibrationViewMut {
            per_item noisy / set_noisy / NOISY: u8;
            per_item param_a / set_param_a / PARAM_A: f32;
            per_item param_b / set_param_b / PARAM_B: f32;
            per_item noise_a / set_noise_a / NOISE_A: f32;
            per_item noise_b / set_noise_b / NOISE_B: f32;
        }
        global rows / set_rows / ROWS: u32;
        global cols / set_cols / COLS: u32;
        global event_id / set_event_id / EVENT_ID: u64;
    }
}

/// The paper's *no-property* interface extension: arbitrary functions
/// spliced into the collection interface without associated storage.
impl<L: Layout> SensorCollection<L> {
    /// Calibrate one sensor in place (paper: `Sensor::calibrate_energy`).
    /// Matches `python/compile/kernels/ref.py:calibrate_ref` exactly.
    #[inline]
    pub fn calibrate_energy(&mut self, i: usize) {
        let e = if self.noisy(i) != 0 {
            0.0
        } else {
            self.param_a(i) * self.counts(i) as f32 + self.param_b(i)
        };
        let noise = (self.noise_a(i) + self.noise_b(i) * e.max(0.0).sqrt()).max(NOISE_FLOOR);
        self.set_energy(i, e);
        self.set_noise(i, noise);
        self.set_sig(i, e / noise);
    }

    /// Noise estimate for sensor `i` (paper: `Sensor::get_noise`),
    /// computed from the calibration group without touching stored state.
    #[inline]
    pub fn get_noise(&self, i: usize) -> f32 {
        let e = if self.noisy(i) != 0 {
            0.0
        } else {
            self.param_a(i) * self.counts(i) as f32 + self.param_b(i)
        };
        (self.noise_a(i) + self.noise_b(i) * e.max(0.0).sqrt()).max(NOISE_FLOOR)
    }

    /// Row-major index of the sensor at `(r, c)`.
    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> usize {
        r * self.cols() as usize + c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marionette::layout::{AoS, AoSoA, SoABlob, SoAVec};

    fn build<L: Layout>() -> SensorCollection<L>
    where
        crate::marionette::collection::InfoOf<L>: Default,
    {
        let mut s = SensorCollection::<L>::new();
        s.set_rows(2);
        s.set_cols(3);
        s.set_event_id(99);
        s.resize(6);
        for i in 0..6 {
            s.set_type_id(i, (i % 3) as i32);
            s.set_counts(i, 100 * (i as i32 + 1));
            s.set_param_a(i, 0.5);
            s.set_param_b(i, 1.0);
            s.set_noise_a(i, 2.0);
            s.set_noise_b(i, 0.1);
            s.set_noisy(i, u8::from(i == 4));
        }
        s
    }

    fn check_calibration<L: Layout>()
    where
        crate::marionette::collection::InfoOf<L>: Default,
    {
        let mut s = build::<L>();
        for i in 0..s.len() {
            s.calibrate_energy(i);
        }
        // i=0: e = 0.5*100 + 1 = 51; noise = 2 + 0.1*sqrt(51)
        let e = s.energy(0);
        assert_eq!(e, 51.0);
        let noise = 2.0 + 0.1 * 51f32.sqrt();
        assert!((s.noise(0) - noise).abs() < 1e-6);
        assert!((s.sig(0) - e / noise).abs() < 1e-6);
        // noisy sensor: zero energy, noise = noise_a.
        assert_eq!(s.energy(4), 0.0);
        assert_eq!(s.noise(4), 2.0);
        assert_eq!(s.sig(4), 0.0);
        // get_noise agrees with stored noise after calibration.
        for i in 0..s.len() {
            assert_eq!(s.get_noise(i), s.noise(i));
        }
    }

    #[test]
    fn calibration_all_layouts() {
        check_calibration::<SoAVec>();
        check_calibration::<AoS>();
        check_calibration::<SoABlob>();
        check_calibration::<AoSoA<8>>();
    }

    #[test]
    fn subgroup_proxies() {
        let s = build::<SoAVec>();
        let obj = s.obj(4);
        assert_eq!(obj.calibration().noisy(), 1);
        assert_eq!(obj.calibration().param_a(), 0.5);
        let mut s = build::<AoS>();
        let mut m = s.obj_mut(2);
        m.calibration().set_param_b(7.0);
        assert_eq!(s.param_b(2), 7.0);
    }

    #[test]
    fn owned_object_roundtrip() {
        let s = build::<SoAVec>();
        let o = s.get_owned(3);
        assert_eq!(o.type_id, 0);
        assert_eq!(o.counts, 400);
        let mut t = SensorCollection::<AoS>::new();
        t.set_cols(3);
        let i = t.push(&o);
        assert_eq!(t.counts(i), 400);
        assert_eq!(t.get_owned(i), o);
    }

    #[test]
    fn grid_indexing() {
        let s = build::<SoAVec>();
        assert_eq!(s.at(1, 2), 5);
        assert_eq!(s.at(0, 0), 0);
    }

    #[test]
    fn record_view_is_handwritten_aos() {
        let mut s = build::<AoS>();
        // Dense record view exists for AoS and matches accessors.
        assert_eq!(
            std::mem::size_of::<SensorRecord>(),
            SensorProps::FIRST_ITEM_META.record_size as usize
        );
        {
            let recs = s.records().expect("AoS must be record-dense");
            assert_eq!(recs.len(), 6);
            assert_eq!(recs[1].counts, 200);
            assert_eq!(recs[4].noisy, 1);
        }
        // Writes through the record view land in the collection.
        s.records_mut().unwrap()[2].energy = 123.0;
        assert_eq!(s.energy(2), 123.0);
        // SoA layouts have no record view, but do have columns.
        let mut soa = build::<SoAVec>();
        assert!(soa.records().is_none());
        let c = soa.columns_mut().expect("SoAVec must be column-dense");
        assert_eq!(c.counts, &[100, 200, 300, 400, 500, 600]);
        c.energy[5] = 9.0;
        assert_eq!(soa.energy(5), 9.0);
        // AoSoA has neither dense view.
        let mut blocked = build::<AoSoA<8>>();
        assert!(blocked.records().is_none());
        assert!(blocked.columns_mut().is_none());
    }

    #[test]
    fn soablob_columns_dense() {
        let mut s = build::<SoABlob>();
        let c = s.columns_mut().expect("SoABlob is column-dense");
        assert_eq!(c.param_a.len(), 6);
        c.param_a[0] = 7.5;
        assert_eq!(s.param_a(0), 7.5);
    }

    #[test]
    fn layout_transfer_preserves_everything() {
        let mut src = build::<SoAVec>();
        for i in 0..src.len() {
            src.calibrate_energy(i);
        }
        let mut dst = SensorCollection::<AoSoA<4>>::new();
        src.stage_into(&mut dst);
        assert_eq!(dst.rows(), 2);
        assert_eq!(dst.event_id(), 99);
        for i in 0..src.len() {
            assert_eq!(src.energy(i), dst.energy(i));
            assert_eq!(src.noisy(i), dst.noisy(i));
        }
    }

    /// One view description serves every Marionette-backed store: the
    /// owned collection (any layout), a pool-recycled staging
    /// collection, and the raw engine underneath — with identical reads.
    #[test]
    fn views_attach_to_owned_and_pooled_sources() {
        use crate::marionette::memory::{PoolContext, PoolInfo};

        fn check_view<S: crate::marionette::interface::PlaneSource>(
            v: &SensorView<'_, S>,
        ) {
            assert_eq!(v.len(), 6);
            assert_eq!(v.rows(), 2);
            assert_eq!(v.event_id(), 99);
            assert_eq!(v.counts(3), 400);
            assert_eq!(v.noisy(4), 1);
            assert_eq!(v.param_a(0), 0.5);
        }

        // Owned, across layouts (including the irregular AoSoA).
        check_view(&build::<SoAVec>().view());
        check_view(&build::<AoS>().view());
        check_view(&build::<AoSoA<8>>().view());

        // Pool-recycled staging collection: same view, same reads.
        let info = PoolInfo::<crate::marionette::memory::HostContext>::default();
        let owned = build::<SoAVec>();
        let mut pooled = SensorCollection::<
            AoS<PoolContext<crate::marionette::memory::HostContext>>,
        >::new_in(info);
        owned.stage_into(&mut pooled);
        check_view(&pooled.view());
        // Attach straight to the typed collection (it is a PlaneSource).
        check_view(&SensorView::attach(&pooled).unwrap());
        // And to the raw engine underneath.
        check_view(&SensorView::attach(pooled.raw()).unwrap());
    }

    /// Mutable views rewrite elements in place through any source.
    #[test]
    fn view_mut_writes_land_in_the_collection() {
        let mut s = build::<AoS>();
        {
            let mut v = s.view_mut();
            v.set_energy(2, 123.5);
            v.set_noisy(1, 1);
            assert_eq!(v.energy(2), 123.5);
        }
        assert_eq!(s.energy(2), 123.5);
        assert_eq!(s.noisy(1), 1);
    }

    /// Attach fails cleanly across schemas (the particle view cannot
    /// attach to a sensor store).
    #[test]
    fn view_attach_schema_checked() {
        use crate::marionette::interface::AttachError;
        let s = build::<SoAVec>();
        match super::super::particle::ParticleView::attach(s.raw()) {
            Err(AttachError::SchemaMismatch { .. }) => {}
            r => panic!("expected SchemaMismatch, got {:?}", r.err()),
        }
    }

    /// The fluent builder + conversion sugar: build, convert, stage —
    /// all routed through the cached transfer plans.
    #[test]
    fn fluent_build_convert_stage() {
        use crate::marionette::memory::{CountingContext, CountingInfo};
        use crate::marionette::transfer::TransferPriority;

        let mut src = SensorCollection::build().capacity(8).finish();
        assert!(src.capacity() >= 8);
        src.set_rows(1);
        src.set_cols(4);
        src.resize(4);
        for i in 0..4 {
            src.set_counts(i, 10 * (i as i32 + 1));
        }

        // convert_to: same data, new layout.
        let aos = src.convert_to::<AoS>();
        assert_eq!(aos.counts(2), 30);
        assert_eq!(aos.rows(), 1);

        // Builder with explicit layout + context + pre-size.
        let info = CountingInfo::default();
        let mut counted = SensorCollection::build()
            .layout::<AoS<CountingContext>>()
            .context(info.clone())
            .capacity(4)
            .finish();
        let stats = src.stage_into(&mut counted);
        assert!(stats.bytes > 0);
        assert_eq!(stats.priority, TransferPriority::Strided);
        assert_eq!(counted.counts(3), 40);

        // Route equivalence: a second stage_into through the same
        // cached plan books identical stats into a fresh destination.
        let mut again = SensorCollection::<AoS<CountingContext>>::new_in(info);
        let again_stats = src.stage_into(&mut again);
        assert_eq!(stats.bytes, again_stats.bytes);
        assert_eq!(stats.ops, again_stats.ops);
        assert_eq!(stats.priority, again_stats.priority);
    }
}

//! The `Particle` collection (paper listing 2/4), declared in Marionette.
//!
//! Demonstrates every remaining property kind of the paper: array
//! properties tracked per sensor type (`significance`, `e_contribution`,
//! `noisy_count` — stored as separate per-type arrays in SoA layouts,
//! inline `[T; N]` in AoS records), and the jagged `sensors` vector (the
//! dynamic list of contributing sensor indices, backed by a prefix sum
//! under its own size tag).

use crate::marionette::layout::Layout;
use crate::marionette_collection;

use super::constants::NUM_SENSOR_TYPES;

marionette_collection! {
    /// Reconstructed particles of one event.
    pub collection ParticleCollection, object Particle, record ParticleRecord,
        columns ParticleColumns, refs ParticleRef / ParticleMut,
        views ParticleView / ParticleViewMut,
        props ParticleProps, schema "particle" {
        per_item energy / set_energy / ENERGY: f32;
        per_item x / set_x / X: f32;
        per_item y / set_y / Y: f32;
        per_item x_variance / set_x_variance / X_VARIANCE: f32;
        per_item y_variance / set_y_variance / Y_VARIANCE: f32;
        per_item origin / set_origin / ORIGIN: u64;
        array significance / set_significance / SIGNIFICANCE: [f32; NUM_SENSOR_TYPES];
        array e_contribution / set_e_contribution / E_CONTRIBUTION: [f32; NUM_SENSOR_TYPES];
        array noisy_count / set_noisy_count / NOISY_COUNT: [u8; NUM_SENSOR_TYPES];
        jagged sensors / set_sensors / SENSORS: u64, prefix u32;
        global event_id / set_event_id / EVENT_ID: u64;
    }
}

impl<L: Layout> ParticleCollection<L> {
    /// Total energy of all particles (used by physics sanity checks).
    pub fn total_energy(&self) -> f64 {
        (0..self.len()).map(|i| self.energy(i) as f64).sum()
    }

    /// Index of the most energetic particle, if any.
    pub fn leading(&self) -> Option<usize> {
        (0..self.len()).max_by(|&a, &b| {
            self.energy(a)
                .partial_cmp(&self.energy(b))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marionette::layout::{AoS, SoAVec};

    fn sample() -> Particle {
        Particle {
            energy: 120.0,
            x: 3.5,
            y: 7.2,
            x_variance: 0.4,
            y_variance: 0.6,
            origin: 42,
            significance: [5.0, 2.0, 0.5],
            e_contribution: [80.0, 30.0, 10.0],
            noisy_count: [0, 1, 0],
            sensors: vec![41, 42, 43, 52],
        }
    }

    #[test]
    fn push_and_read_back() {
        let mut c = ParticleCollection::<SoAVec>::new();
        c.set_event_id(1);
        let i = c.push(&sample());
        assert_eq!(c.energy(i), 120.0);
        assert_eq!(c.significance(i, 0), 5.0);
        assert_eq!(c.noisy_count(i, 1), 1);
        assert_eq!(c.sensors(i).to_vec(), vec![41, 42, 43, 52]);
        assert_eq!(c.get_owned(i), sample());
    }

    #[test]
    fn jagged_sensors_across_particles() {
        let mut c = ParticleCollection::<AoS>::new();
        let mut p = sample();
        c.push(&p);
        p.sensors = vec![7];
        p.energy = 50.0;
        c.push(&p);
        p.sensors = vec![];
        c.push(&p);
        assert_eq!(c.sensors(0).len(), 4);
        assert_eq!(c.sensors(1).to_vec(), vec![7]);
        assert_eq!(c.sensors(2).len(), 0);
        // Flat view spans all particles (paper: single continuous vector).
        let flat = c
            .raw()
            .jagged_flat::<u64>(ParticleProps::SENSORS.values, ParticleProps::SENSORS.j);
        assert_eq!(flat.len(), 5);
        assert_eq!(flat.to_vec(), vec![41, 42, 43, 52, 7]);
    }

    #[test]
    fn set_sensors_shifts_later_particles() {
        let mut c = ParticleCollection::<SoAVec>::new();
        c.push(&sample());
        c.push(&sample());
        c.set_sensors(0, &[1, 2]);
        assert_eq!(c.sensors(0).to_vec(), vec![1, 2]);
        assert_eq!(c.sensors(1).to_vec(), vec![41, 42, 43, 52]);
    }

    #[test]
    fn array_planes_in_columns() {
        // Array properties appear lane-major in the column view.
        let mut c = ParticleCollection::<SoAVec>::new();
        c.push(&sample());
        c.push(&sample());
        let cols = c.columns_mut().unwrap();
        assert_eq!(cols.significance[0], &[5.0, 5.0]);
        assert_eq!(cols.significance[2], &[0.5, 0.5]);
        cols.e_contribution[1][1] = 99.0;
        assert_eq!(c.e_contribution(1, 1), 99.0);
    }

    #[test]
    fn bulk_jagged_rebuild() {
        let mut c = ParticleCollection::<SoAVec>::new();
        c.resize(4);
        c.raw_mut().set_jagged_lengths(0, &[2, 0, 3, 1]);
        assert_eq!(c.sensors(0).len(), 2);
        assert_eq!(c.sensors(1).len(), 0);
        assert_eq!(c.sensors(2).len(), 3);
        assert_eq!(c.raw().values_len(0), 6);
        // Values are zeroed and writable through the flat index space.
        let vm = ParticleProps::SENSORS.values;
        c.raw_mut().set_value::<u64>(vm, 5, 42);
        assert_eq!(c.sensors(3).to_vec(), vec![42]);
    }

    #[test]
    fn helpers() {
        let mut c = ParticleCollection::<SoAVec>::new();
        assert!(c.leading().is_none());
        let mut p = sample();
        c.push(&p);
        p.energy = 300.0;
        c.push(&p);
        assert_eq!(c.leading(), Some(1));
        assert!((c.total_energy() - 420.0).abs() < 1e-9);
    }
}

//! Synthetic event generation (the Rust twin of
//! `python/compile/aot.py:generate_event`).
//!
//! The paper's evaluation uses ATLAS-like events that we do not have; per
//! the substitution rule (DESIGN.md §2) the generator injects Gaussian
//! energy deposits onto a Poisson-background grid of mixed-type sensors
//! with per-type calibration constants — exercising the same code paths
//! (noisy sensors, per-type tallies, jagged contributor lists).
//!
//! Deposits are truncated at ±4σ (the Python twin evaluates the full
//! grid; beyond 4σ the contribution is < 1 count, so the physics is
//! identical — goldens come from the Python side regardless).

use crate::marionette::collection::InfoOf;
use crate::marionette::layout::Layout;
use crate::util::rng::Rng;

use super::handwritten::{HwSensorsAoS, HwSensorsSoA};
use super::sensor::SensorCollection;

/// Per-type calibration tables (mirrors `aot.py`).
pub const A_TAB: [f32; 3] = [0.5, 1.0, 2.0];
pub const B_TAB: [f32; 3] = [0.0, 5.0, -3.0];
pub const NA_TAB: [f32; 3] = [2.0, 3.0, 5.0];
pub const NB_TAB: [f32; 3] = [0.10, 0.05, 0.20];

/// Event generation parameters.
#[derive(Clone, Debug)]
pub struct EventConfig {
    pub rows: usize,
    pub cols: usize,
    /// Particles injected per event.
    pub n_particles: usize,
    /// Probability that a sensor is flagged noisy.
    pub noisy_fraction: f64,
    /// Poisson mean of the count background.
    pub background: f64,
    /// Deposit amplitude range (raw counts at the core).
    pub amplitude: (f64, f64),
    /// Deposit width range (sensors).
    pub sigma: (f64, f64),
}

impl EventConfig {
    pub fn grid(rows: usize, cols: usize, n_particles: usize) -> Self {
        EventConfig {
            rows,
            cols,
            n_particles,
            noisy_fraction: 0.01,
            background: 3.0,
            amplitude: (200.0, 2000.0),
            sigma: (0.6, 1.2),
        }
    }
}

/// Raw per-sensor planes of one generated event (pre-calibration), the
/// exact inputs of the device `sensor_stage`.
#[derive(Clone, Debug)]
pub struct RawEvent {
    pub event_id: u64,
    pub rows: usize,
    pub cols: usize,
    pub counts: Vec<i32>,
    pub types: Vec<i32>,
    pub noisy: Vec<u8>,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub na: Vec<f32>,
    pub nb: Vec<f32>,
    /// (row, col) of each injected deposit (ground truth for sanity
    /// checks; not visible to the reconstruction).
    pub truth: Vec<(usize, usize)>,
}

impl RawEvent {
    pub fn num_sensors(&self) -> usize {
        self.rows * self.cols
    }

    /// Fill a Marionette sensor collection (any layout/context), using
    /// the collection's dense record/column views where the layout
    /// provides them (same bulk interface the handwritten fill uses;
    /// falls back to per-element accessors on irregular layouts).
    pub fn fill_collection<L: Layout>(&self, s: &mut SensorCollection<L>) {
        // Only re-size when the shape changed: resize zero-fills, and
        // every field is overwritten below anyway.
        if s.len() != self.num_sensors() {
            s.clear();
            s.resize(self.num_sensors());
        }
        s.set_rows(self.rows as u32);
        s.set_cols(self.cols as u32);
        s.set_event_id(self.event_id);
        if let Some(recs) = s.records_mut() {
            for (i, r) in recs.iter_mut().enumerate() {
                r.type_id = self.types[i];
                r.counts = self.counts[i];
                r.noisy = self.noisy[i];
                r.param_a = self.a[i];
                r.param_b = self.b[i];
                r.noise_a = self.na[i];
                r.noise_b = self.nb[i];
                r.energy = 0.0;
                r.noise = 0.0;
                r.sig = 0.0;
            }
            return;
        }
        if let Some(c) = s.columns_mut() {
            c.type_id.copy_from_slice(&self.types);
            c.counts.copy_from_slice(&self.counts);
            c.noisy.copy_from_slice(&self.noisy);
            c.param_a.copy_from_slice(&self.a);
            c.param_b.copy_from_slice(&self.b);
            c.noise_a.copy_from_slice(&self.na);
            c.noise_b.copy_from_slice(&self.nb);
            c.energy.fill(0.0);
            c.noise.fill(0.0);
            c.sig.fill(0.0);
            return;
        }
        for i in 0..self.num_sensors() {
            s.set_type_id(i, self.types[i]);
            s.set_counts(i, self.counts[i]);
            s.set_noisy(i, self.noisy[i]);
            s.set_param_a(i, self.a[i]);
            s.set_param_b(i, self.b[i]);
            s.set_noise_a(i, self.na[i]);
            s.set_noise_b(i, self.nb[i]);
        }
    }

    /// Build a fresh Marionette collection in the given layout.
    pub fn to_collection<L: Layout>(&self) -> SensorCollection<L>
    where
        InfoOf<L>: Default,
    {
        let mut s = SensorCollection::<L>::new();
        self.fill_collection(&mut s);
        s
    }

    /// Fill the handwritten AoS baseline.
    pub fn fill_hw_aos(&self, s: &mut HwSensorsAoS) {
        s.rows = self.rows as u32;
        s.cols = self.cols as u32;
        s.event_id = self.event_id;
        if s.data.len() != self.num_sensors() {
            s.data.clear();
            s.data.resize(self.num_sensors(), Default::default());
        }
        for (i, rec) in s.data.iter_mut().enumerate() {
            rec.type_id = self.types[i];
            rec.counts = self.counts[i];
            rec.noisy = self.noisy[i];
            rec.param_a = self.a[i];
            rec.param_b = self.b[i];
            rec.noise_a = self.na[i];
            rec.noise_b = self.nb[i];
            rec.energy = 0.0;
            rec.noise = 0.0;
            rec.sig = 0.0;
        }
    }

    /// Fill the handwritten SoA baseline.
    pub fn fill_hw_soa(&self, s: &mut HwSensorsSoA) {
        s.rows = self.rows as u32;
        s.cols = self.cols as u32;
        s.event_id = self.event_id;
        s.resize(self.num_sensors());
        s.type_id.copy_from_slice(&self.types);
        s.counts.copy_from_slice(&self.counts);
        s.noisy.copy_from_slice(&self.noisy);
        s.param_a.copy_from_slice(&self.a);
        s.param_b.copy_from_slice(&self.b);
        s.noise_a.copy_from_slice(&self.na);
        s.noise_b.copy_from_slice(&self.nb);
        s.energy.fill(0.0);
        s.noise.fill(0.0);
        s.sig.fill(0.0);
    }
}

/// Deterministic stream of synthetic events.
pub struct EventGenerator {
    pub config: EventConfig,
    rng: Rng,
    next_id: u64,
}

impl EventGenerator {
    pub fn new(config: EventConfig, seed: u64) -> Self {
        EventGenerator { config, rng: Rng::seed_from_u64(seed), next_id: 0 }
    }

    /// Generate the next event.
    pub fn generate(&mut self) -> RawEvent {
        let cfg = &self.config;
        let (rows, cols) = (cfg.rows, cfg.cols);
        let n = rows * cols;
        let mut counts_f = vec![0.0f64; n];
        let mut types = vec![0i32; n];
        let mut noisy = vec![0u8; n];
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        let mut na = vec![0.0f32; n];
        let mut nb = vec![0.0f32; n];

        for i in 0..n {
            let t = self.rng.range_usize(0, 3);
            types[i] = t as i32;
            let jitter = 1.0 + 0.01 * self.rng.normal() as f32;
            a[i] = A_TAB[t] * jitter;
            b[i] = B_TAB[t];
            na[i] = NA_TAB[t];
            nb[i] = NB_TAB[t];
            noisy[i] = u8::from(self.rng.bool(cfg.noisy_fraction));
            counts_f[i] = self.rng.poisson(cfg.background) as f64;
        }

        // Inject particles as truncated 2D Gaussians.
        let mut truth = Vec::with_capacity(cfg.n_particles);
        for _ in 0..cfg.n_particles {
            let r0 = self.rng.range_usize(2, rows.saturating_sub(2).max(3));
            let c0 = self.rng.range_usize(2, cols.saturating_sub(2).max(3));
            let amp = self.rng.uniform(cfg.amplitude.0, cfg.amplitude.1);
            let sigma = self.rng.uniform(cfg.sigma.0, cfg.sigma.1);
            truth.push((r0, c0));
            let reach = (4.0 * sigma).ceil() as usize;
            let rlo = r0.saturating_sub(reach);
            let rhi = (r0 + reach + 1).min(rows);
            let clo = c0.saturating_sub(reach);
            let chi = (c0 + reach + 1).min(cols);
            for r in rlo..rhi {
                for c in clo..chi {
                    let d2 = (r as f64 - r0 as f64).powi(2) + (c as f64 - c0 as f64).powi(2);
                    counts_f[r * cols + c] += amp * (-d2 / (2.0 * sigma * sigma)).exp();
                }
            }
        }

        let counts = counts_f.iter().map(|&x| x as i32).collect();
        let id = self.next_id;
        self.next_id += 1;
        RawEvent { event_id: id, rows, cols, counts, types, noisy, a, b, na, nb, truth }
    }
}

impl Iterator for EventGenerator {
    type Item = RawEvent;

    fn next(&mut self) -> Option<RawEvent> {
        Some(self.generate())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marionette::layout::SoAVec;

    #[test]
    fn deterministic_given_seed() {
        let mut g1 = EventGenerator::new(EventConfig::grid(32, 32, 4), 7);
        let mut g2 = EventGenerator::new(EventConfig::grid(32, 32, 4), 7);
        let (a, b) = (g1.generate(), g2.generate());
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.types, b.types);
        assert_eq!(a.truth, b.truth);
        let c = g1.generate();
        assert_eq!(c.event_id, 1);
        assert_ne!(a.counts, c.counts);
    }

    #[test]
    fn particles_raise_counts() {
        let quiet =
            EventGenerator::new(EventConfig::grid(64, 64, 0), 1).generate();
        let busy =
            EventGenerator::new(EventConfig::grid(64, 64, 10), 1).generate();
        let sq: i64 = quiet.counts.iter().map(|&x| x as i64).sum();
        let sb: i64 = busy.counts.iter().map(|&x| x as i64).sum();
        assert!(sb > sq + 1000, "quiet {sq} busy {sb}");
    }

    #[test]
    fn deposits_are_local_maxima() {
        let ev = EventGenerator::new(EventConfig::grid(64, 64, 3), 3).generate();
        for &(r, c) in &ev.truth {
            let center = ev.counts[r * 64 + c];
            // Center clearly above background unless two deposits overlap.
            assert!(center > 50, "deposit at ({r},{c}) too weak: {center}");
        }
    }

    #[test]
    fn fills_agree_across_targets() {
        let ev = EventGenerator::new(EventConfig::grid(16, 16, 2), 5).generate();
        let col = ev.to_collection::<SoAVec>();
        let mut aos = HwSensorsAoS::default();
        ev.fill_hw_aos(&mut aos);
        let mut soa = HwSensorsSoA::default();
        ev.fill_hw_soa(&mut soa);
        for i in 0..ev.num_sensors() {
            assert_eq!(col.counts(i), aos.data[i].counts);
            assert_eq!(col.counts(i), soa.counts[i]);
            assert_eq!(col.param_a(i), aos.data[i].param_a);
            assert_eq!(col.noisy(i), soa.noisy[i]);
        }
        assert_eq!(col.rows(), 16);
        assert_eq!(aos.event_id, col.event_id());
    }

    #[test]
    fn types_in_range() {
        let ev = EventGenerator::new(EventConfig::grid(32, 32, 0), 9).generate();
        assert!(ev.types.iter().all(|&t| (0..3).contains(&t)));
    }
}

//! Specialized transfer rungs for the EDM (paper §VII-B).
//!
//! The paper's `TransferSpecification` lets users register a fast path
//! for a concrete (source, destination) pair that outranks the generic
//! ladder. Here the handwritten sensor AoS↔SoA conversions — the code a
//! programmer would write by hand to move between listing-1-style
//! records and per-property arrays — are registered as `Specialized`
//! rungs *inside* the transfer plans for the sensor schema, so
//! `stage_into` / `copy_collection` dispatch to them automatically
//! instead of bypassing the ladder.
//!
//! The converters are one-pass: dense column slices on the SoA side,
//! the `#[repr(C)]` record view on the AoS side (byte-identical to
//! `HwSensor`, pinned by `blob::tests::aos_matches_handwritten_repr_c`).

use std::sync::Once;

use crate::marionette::collection::RawCollection;
use crate::marionette::layout::{AoS, SoAVec};
use crate::marionette::transfer::register_specialized;

use super::sensor::{SensorProps, SensorRecord};

/// Register the EDM's specialized converters (idempotent). Call before
/// the first sensor-collection transfer whose pair should take the fast
/// path; the pipeline does this at startup.
pub fn register_edm_specializations() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let schema = SensorProps::schema();
        register_specialized::<SoAVec, AoS, _>(&schema, soavec_sensors_to_aos);
        register_specialized::<AoS, SoAVec, _>(&schema, aos_sensors_to_soavec);
    });
}

fn copy_globals<LS, LD>(src: &RawCollection<LS>, dst: &mut RawCollection<LD>)
where
    LS: crate::marionette::layout::Layout,
    LD: crate::marionette::layout::Layout,
{
    dst.set_global::<u32>(SensorProps::ROWS, src.get_global::<u32>(SensorProps::ROWS));
    dst.set_global::<u32>(SensorProps::COLS, src.get_global::<u32>(SensorProps::COLS));
    dst.set_global::<u64>(
        SensorProps::EVENT_ID,
        src.get_global::<u64>(SensorProps::EVENT_ID),
    );
}

/// Bytes a whole-collection sensor conversion moves (records + globals).
fn sensor_bytes(n: usize) -> usize {
    n * std::mem::size_of::<SensorRecord>() + 2 * 4 + 8
}

/// Handwritten one-pass SoA → AoS: read every dense column, write whole
/// records (exactly the loop `RawEvent::fill_hw_aos` runs by hand).
fn soavec_sensors_to_aos(src: &RawCollection<SoAVec>, dst: &mut RawCollection<AoS>) -> usize {
    let n = src.len();
    if dst.len() != n {
        dst.resize(0);
        dst.resize(n);
    }
    copy_globals(src, dst);
    if n == 0 {
        return sensor_bytes(0);
    }

    let type_id = src.field_slice::<i32>(SensorProps::TYPE_ID).expect("soa-vec dense");
    let counts = src.field_slice::<i32>(SensorProps::COUNTS).expect("soa-vec dense");
    let energy = src.field_slice::<f32>(SensorProps::ENERGY).expect("soa-vec dense");
    let noise = src.field_slice::<f32>(SensorProps::NOISE).expect("soa-vec dense");
    let sig = src.field_slice::<f32>(SensorProps::SIG).expect("soa-vec dense");
    let noisy = src.field_slice::<u8>(SensorProps::NOISY).expect("soa-vec dense");
    let param_a = src.field_slice::<f32>(SensorProps::PARAM_A).expect("soa-vec dense");
    let param_b = src.field_slice::<f32>(SensorProps::PARAM_B).expect("soa-vec dense");
    let noise_a = src.field_slice::<f32>(SensorProps::NOISE_A).expect("soa-vec dense");
    let noise_b = src.field_slice::<f32>(SensorProps::NOISE_B).expect("soa-vec dense");

    let recs = aos_records_mut(dst, n);
    for (i, r) in recs.iter_mut().enumerate() {
        *r = SensorRecord {
            type_id: type_id[i],
            counts: counts[i],
            energy: energy[i],
            noise: noise[i],
            sig: sig[i],
            noisy: noisy[i],
            param_a: param_a[i],
            param_b: param_b[i],
            noise_a: noise_a[i],
            noise_b: noise_b[i],
        };
    }
    sensor_bytes(n)
}

/// Handwritten one-pass AoS → SoA: read the record view, fill every
/// dense column (the loop `RawEvent::fill_hw_soa` runs by hand).
fn aos_sensors_to_soavec(src: &RawCollection<AoS>, dst: &mut RawCollection<SoAVec>) -> usize {
    let n = src.len();
    if dst.len() != n {
        dst.resize(0);
        dst.resize(n);
    }
    copy_globals(src, dst);
    if n == 0 {
        return sensor_bytes(0);
    }

    let recs = aos_records(src, n);
    macro_rules! fill_column {
        ($meta:expr, $ty:ty, $field:ident) => {{
            let p = dst.plane_mut($meta, 0).expect("soa-vec dense plane");
            debug_assert_eq!(p.stride, ::std::mem::size_of::<$ty>());
            // SAFETY: dense plane of `n` `$ty` elements, derived from a
            // mutable borrow of `dst`; `recs` borrows `src`.
            let out =
                unsafe { ::std::slice::from_raw_parts_mut(p.base as *mut $ty, n) };
            for (o, r) in out.iter_mut().zip(recs) {
                *o = r.$field;
            }
        }};
    }
    fill_column!(SensorProps::TYPE_ID, i32, type_id);
    fill_column!(SensorProps::COUNTS, i32, counts);
    fill_column!(SensorProps::ENERGY, f32, energy);
    fill_column!(SensorProps::NOISE, f32, noise);
    fill_column!(SensorProps::SIG, f32, sig);
    fill_column!(SensorProps::NOISY, u8, noisy);
    fill_column!(SensorProps::PARAM_A, f32, param_a);
    fill_column!(SensorProps::PARAM_B, f32, param_b);
    fill_column!(SensorProps::NOISE_A, f32, noise_a);
    fill_column!(SensorProps::NOISE_B, f32, noise_b);
    sensor_bytes(n)
}

/// The AoS record view of a raw sensor collection (what the generated
/// `records()` exposes on the typed collection).
fn aos_records(src: &RawCollection<AoS>, n: usize) -> &[SensorRecord] {
    debug_assert_eq!(
        SensorProps::FIRST_ITEM_META.record_size as usize,
        std::mem::size_of::<SensorRecord>()
    );
    let p = src.plane(SensorProps::TYPE_ID, 0).expect("aos record plane");
    debug_assert_eq!(p.stride, std::mem::size_of::<SensorRecord>());
    // SAFETY: the AoS blob stores `n` records byte-identical to
    // `SensorRecord` starting at the first field's plane base minus its
    // record offset (0 for the leading field).
    unsafe {
        let base = p.base.sub(SensorProps::TYPE_ID.aos_offset as usize);
        std::slice::from_raw_parts(base as *const SensorRecord, n)
    }
}

/// Mutable record view; see [`aos_records`].
fn aos_records_mut(dst: &mut RawCollection<AoS>, n: usize) -> &mut [SensorRecord] {
    let p = dst.plane_mut(SensorProps::TYPE_ID, 0).expect("aos record plane");
    debug_assert_eq!(p.stride, std::mem::size_of::<SensorRecord>());
    // SAFETY: as `aos_records`, derived from a mutable borrow.
    unsafe {
        let base = (p.base as *mut u8).sub(SensorProps::TYPE_ID.aos_offset as usize);
        std::slice::from_raw_parts_mut(base as *mut SensorRecord, n)
    }
}

#[cfg(test)]
mod tests {
    use super::super::generator::{EventConfig, EventGenerator};
    use super::super::sensor::SensorCollection;
    use super::*;
    use crate::marionette::transfer::{copy_collection_stats, TransferPriority};

    fn event_collections() -> (SensorCollection<SoAVec>, SensorCollection<AoS>) {
        let ev = EventGenerator::new(EventConfig::grid(24, 24, 3), 5).generate();
        let soa = ev.to_collection::<SoAVec>();
        let aos = ev.to_collection::<AoS>();
        (soa, aos)
    }

    fn assert_sensors_equal<LA, LB>(a: &SensorCollection<LA>, b: &SensorCollection<LB>)
    where
        LA: crate::marionette::layout::Layout,
        LB: crate::marionette::layout::Layout,
    {
        assert_eq!(a.len(), b.len());
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.cols(), b.cols());
        assert_eq!(a.event_id(), b.event_id());
        for i in 0..a.len() {
            assert_eq!(a.type_id(i), b.type_id(i), "sensor {i}");
            assert_eq!(a.counts(i), b.counts(i), "sensor {i}");
            assert_eq!(a.energy(i), b.energy(i), "sensor {i}");
            assert_eq!(a.noise(i), b.noise(i), "sensor {i}");
            assert_eq!(a.sig(i), b.sig(i), "sensor {i}");
            assert_eq!(a.noisy(i), b.noisy(i), "sensor {i}");
            assert_eq!(a.param_a(i), b.param_a(i), "sensor {i}");
            assert_eq!(a.param_b(i), b.param_b(i), "sensor {i}");
            assert_eq!(a.noise_a(i), b.noise_a(i), "sensor {i}");
            assert_eq!(a.noise_b(i), b.noise_b(i), "sensor {i}");
        }
    }

    #[test]
    fn specialized_sensor_pair_outranks_the_ladder() {
        register_edm_specializations();
        let (soa, aos_truth) = event_collections();

        let mut aos = SensorCollection::<AoS>::new();
        let stats = copy_collection_stats(soa.raw(), aos.raw_mut());
        assert_eq!(stats.priority, TransferPriority::Specialized);
        assert_eq!(stats.ops, 1);
        assert!(stats.bytes > 0);
        assert_sensors_equal(&aos, &aos_truth);

        // Round trip through the reverse specialization.
        let mut back = SensorCollection::<SoAVec>::new();
        let stats = copy_collection_stats(aos.raw(), back.raw_mut());
        assert_eq!(stats.priority, TransferPriority::Specialized);
        assert_sensors_equal(&back, &soa);
    }

    #[test]
    fn specialized_pair_reuses_destination() {
        register_edm_specializations();
        let (soa, _) = event_collections();
        let mut aos = SensorCollection::<AoS>::new();
        for _ in 0..3 {
            let rung = soa.stage_into(&mut aos).priority;
            assert_eq!(rung, TransferPriority::Specialized);
            assert_sensors_equal(&aos, &soa);
        }
    }

    #[test]
    fn unregistered_pairs_stay_generic() {
        register_edm_specializations();
        let (soa, _) = event_collections();
        // SoAVec -> SoABlob has no registered converter.
        let mut blob =
            SensorCollection::<crate::marionette::layout::SoABlob>::new();
        let stats = copy_collection_stats(soa.raw(), blob.raw_mut());
        assert_eq!(stats.priority, TransferPriority::Plane);
        assert_sensors_equal(&blob, &soa);
    }
}

//! # Marionette-RS
//!
//! A reproduction of *"Marionette: Data Structure Description and Management
//! for Heterogeneous Computing"* (CS.DC 2025) as a three-layer
//! Rust + JAX/Pallas + XLA/PJRT system.
//!
//! The paper's contribution — describing a data structure's *interface* once
//! and materialising it under interchangeable memory *layouts* and memory
//! *contexts*, with efficient transfers between them — lives in
//! [`marionette`]. The original C++17 library does this with template
//! metaprogramming; here the same design is expressed with traits, const
//! evaluation and declarative macros, with identical zero-runtime-cost
//! goals (validated by `benches/zero_cost.rs`).
//!
//! The crate layers:
//!
//! * [`marionette`] — the core library: property schemas, layouts
//!   (SoA-vec, AoS blob, SoA blob, AoSoA), memory contexts, transfers,
//!   jagged vectors, and the `marionette_collection!` macro.
//! * [`edm`] — the paper's motivating event-data-model (§III): `Sensor` /
//!   `Particle` collections, handwritten AoS/SoA baselines, the synthetic
//!   event generator, and the host calibration + reconstruction algorithms.
//! * [`runtime`] — the PJRT bridge: loads the AOT HLO artifacts produced by
//!   `python/compile/aot.py` and executes them on the XLA CPU device (the
//!   reproduction's "accelerator"; see DESIGN.md §2).
//! * [`coordinator`] — the event-processing pipeline: batching, host/device
//!   routing, backpressure and metrics.
//! * [`bench_support`] — the paper-methodology timing harness (mean of the
//!   10 fastest of 50 runs) and figure/table printers.
//! * [`util`] — in-tree substrate: JSON, PRNG, a mini property-testing
//!   framework and a thread pool (the image has no network access, so
//!   these are implemented rather than imported; DESIGN.md §3).

pub mod bench_support;
pub mod coordinator;
pub mod edm;
pub mod marionette;
pub mod runtime;
pub mod util;

pub use marionette::prelude;

// Crate-root re-exports of the substrate types downstream code kept
// deep-importing: the object-recycling pair from `util::pool` and the
// pipeline's shared staging pool (API hygiene; examples and tests use
// these paths instead of reaching into the module tree).
pub use coordinator::StagePool;
pub use util::pool::{ObjectPool, ObjectPoolStats, Recycler, ThreadPool, ThreadPoolStats};

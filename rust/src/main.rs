//! `repro` — the Marionette-RS command-line launcher.
//!
//! Commands:
//!   demo                  quick end-to-end tour (host + device paths)
//!   run-pipeline [...]    run the event-processing coordinator
//!   fig1 / fig2 [...]     regenerate the paper's figures
//!   zero-cost             the zero-cost-abstraction table
//!   transfers             the transfer matrix (§VII)
//!   ablation              layout / fusion / routing ablations
//!   bench-report [...]    emit machine-readable BENCH_run.json, gate
//!                         against a committed baseline (DESIGN.md §7)
//!   saturate [...]        host-path saturation sweep over worker
//!                         counts: events/s + p50/p95/p99 tail latency
//!                         (--adaptive hands the batch knob to the
//!                         AIMD controller and compares vs fixed)
//!   autotune [...]        measured-feedback autotuner: traced access
//!                         heatmaps per route + layout ablation check
//!   chaos [...]           fault-injection chaos run: kill a device
//!                         worker / fail allocations on a seeded
//!                         schedule, assert exactly-once delivery and
//!                         golden-output equivalence vs the clean run
//!   serve [...]           reconstruction endpoint: bind a Unix socket,
//!                         accept N framed ingest streams, attach each
//!                         frame zero-copy, assert exactly-once +
//!                         golden equivalence vs the in-process run
//!   ingest [...]          ingest endpoint: connect to a serve socket
//!                         and stream this shard's stripe of the
//!                         seeded event stream as wire frames
//!   doctor                environment + artifact checks
//!
//! Shared flags: --quick (small grids, short harness), --grid N,
//! --events N, --particles a,b,c, --no-device, --csv NAME.
//! bench-report flags: --out PATH, --gate BASELINE, --write-baseline.
//!
//! Argument parsing is hand-rolled (clap is not in the vendored set).

use std::process::ExitCode;

use anyhow::{anyhow, bail, Result};

use marionette::bench_support::figures::{self, FigOpts};
use marionette::bench_support::Harness;
use marionette::coordinator::{run_pipeline, PipelineConfig, RoutePolicy};
use marionette::edm::generator::EventConfig;
use marionette::runtime::{client, Engine};

#[derive(Debug, Default)]
struct Args {
    command: String,
    quick: bool,
    grid: Option<usize>,
    events: Option<usize>,
    particles: Option<Vec<usize>>,
    grids: Option<Vec<usize>>,
    no_device: bool,
    csv: Option<String>,
    policy: Option<String>,
    workers: Option<Vec<usize>>,
    dev_workers: Option<usize>,
    out: Option<String>,
    gate: Option<String>,
    write_baseline: bool,
    adaptive: bool,
    p99_target_us: Option<u64>,
    seed: Option<u64>,
    kill_device_at: Option<u64>,
    alloc_fail_every: Option<u64>,
    socket: Option<String>,
    procs: Option<usize>,
    index: Option<usize>,
    staging_layout: Option<String>,
}

fn parse_args() -> Result<Args> {
    let mut args = Args::default();
    let mut it = std::env::args().skip(1);
    args.command = it.next().unwrap_or_else(|| "help".to_string());
    while let Some(a) = it.next() {
        let mut val = |name: &str| {
            it.next().ok_or_else(|| anyhow!("{name} requires a value"))
        };
        match a.as_str() {
            "--quick" => args.quick = true,
            "--no-device" => args.no_device = true,
            "--grid" => args.grid = Some(val("--grid")?.parse()?),
            "--events" => args.events = Some(val("--events")?.parse()?),
            "--workers" => {
                args.workers = Some(
                    val("--workers")?
                        .split(',')
                        .map(|s| s.trim().parse())
                        .collect::<Result<_, _>>()?,
                )
            }
            "--dev-workers" => args.dev_workers = Some(val("--dev-workers")?.parse()?),
            "--csv" => args.csv = Some(val("--csv")?),
            "--policy" => args.policy = Some(val("--policy")?),
            "--out" => args.out = Some(val("--out")?),
            "--gate" => args.gate = Some(val("--gate")?),
            "--write-baseline" => args.write_baseline = true,
            "--adaptive" => args.adaptive = true,
            "--p99-target-us" => args.p99_target_us = Some(val("--p99-target-us")?.parse()?),
            "--seed" => args.seed = Some(val("--seed")?.parse()?),
            "--kill-device-at" => {
                args.kill_device_at = Some(val("--kill-device-at")?.parse()?)
            }
            "--alloc-fail-every" => {
                args.alloc_fail_every = Some(val("--alloc-fail-every")?.parse()?)
            }
            "--socket" => args.socket = Some(val("--socket")?),
            "--procs" => args.procs = Some(val("--procs")?.parse()?),
            "--index" => args.index = Some(val("--index")?.parse()?),
            "--staging-layout" => args.staging_layout = Some(val("--staging-layout")?),
            "--particles" => {
                args.particles = Some(
                    val("--particles")?
                        .split(',')
                        .map(|s| s.trim().parse())
                        .collect::<Result<_, _>>()?,
                )
            }
            "--grids" => {
                args.grids = Some(
                    val("--grids")?
                        .split(',')
                        .map(|s| s.trim().parse())
                        .collect::<Result<_, _>>()?,
                )
            }
            other => bail!("unknown flag {other} (see `repro help`)"),
        }
    }
    Ok(args)
}

fn fig_opts(args: &Args) -> FigOpts {
    let mut opts = if args.quick { FigOpts::quick() } else { FigOpts::default() };
    if let Some(g) = &args.grids {
        opts.grids = g.clone();
    }
    if let Some(g) = args.grid {
        opts.fig2_grid = g;
    }
    if let Some(p) = &args.particles {
        opts.particles = p.clone();
    }
    if args.no_device {
        opts.device = false;
    }
    opts
}

fn emit(table: marionette::bench_support::Table, csv: &Option<String>) -> Result<()> {
    println!("{}", table.render());
    if let Some(name) = csv {
        let path = table.save_csv(name)?;
        println!("csv -> {}", path.display());
    }
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    let grid = args.grid.unwrap_or(64);
    println!("== Marionette-RS demo (grid {grid}x{grid}) ==");
    println!("device: {}", client::device_description());

    let mut cfg = PipelineConfig::new(EventConfig::grid(grid, grid, 4), args.events.unwrap_or(16));
    cfg.device = !args.no_device;
    cfg.policy = RoutePolicy::DeviceOnly;
    if args.no_device {
        cfg.policy = RoutePolicy::HostOnly;
    }
    let rep = run_pipeline(&cfg)?;
    println!("{}", rep.report());
    for r in rep.results.iter().take(4) {
        println!(
            "  event {}: {:?} -> {} particles, E={:.1}",
            r.event_id, r.route, r.n_particles, r.total_energy
        );
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let grid = args.grid.unwrap_or(256);
    let events = args.events.unwrap_or(64);
    let mut cfg = PipelineConfig::new(
        EventConfig::grid(grid, grid, (grid / 32).max(1).pow(2)),
        events,
    );
    cfg.device = !args.no_device;
    if let Some(w) = args.workers.as_ref().and_then(|w| w.first()) {
        cfg.host_workers = *w;
    }
    if let Some(d) = args.dev_workers {
        cfg.device_workers = d.max(1);
    }
    cfg.policy = match args.policy.as_deref() {
        Some("host") => RoutePolicy::HostOnly,
        Some("device") => RoutePolicy::DeviceOnly,
        Some("auto") | None => RoutePolicy::default(),
        Some(p) => bail!("unknown policy {p} (host|device|auto)"),
    };
    cfg.staging_layout = staging_choice(args)?;
    let rep = run_pipeline(&cfg)?;
    println!("{}", rep.report());
    Ok(())
}

/// Parse `--staging-layout` into the autotuner's [`LayoutChoice`].
fn staging_choice(args: &Args) -> Result<Option<marionette::prelude::LayoutChoice>> {
    match args.staging_layout.as_deref() {
        None => Ok(None),
        Some(name) => marionette::prelude::LayoutChoice::from_name(name)
            .map(Some)
            .ok_or_else(|| anyhow!("unknown staging layout {name} (aos|soavec|soablob|aosoa8)")),
    }
}

/// The seeded workload both wire endpoints derive from the same flags —
/// serve and ingest must agree on it exactly for the striping union and
/// the golden comparison to line up.
fn wire_workload(args: &Args) -> (EventConfig, usize, u64) {
    let grid = args.grid.unwrap_or(24);
    let events = args.events.unwrap_or(48);
    let seed = args.seed.unwrap_or(0xA71A5);
    (EventConfig::grid(grid, grid, 3), events, seed)
}

/// Reconstruction endpoint of the wire pair (DESIGN.md §11): accept
/// `--procs` framed ingest streams on `--socket`, reconstruct with
/// zero-copy frame attach, then fail loudly unless the run is
/// exactly-once AND bit-identical to the in-process generator.
fn cmd_serve(args: &Args) -> Result<()> {
    use marionette::coordinator::{golden_compare, serve_unix, ServeOpts};

    let (event, events, seed) = wire_workload(args);
    let socket = args.socket.clone().ok_or_else(|| anyhow!("serve requires --socket PATH"))?;
    let procs = args.procs.unwrap_or(1).max(1);
    let mut opts = ServeOpts::default();
    if let Some(w) = args.workers.as_ref().and_then(|w| w.first()) {
        opts.workers = (*w).max(1);
    }
    opts.staging = staging_choice(args)?;
    println!(
        "== serve: {procs} ingest proc(s) -> {socket}, {events} events of {}x{}, seed {seed} ==",
        event.rows, event.cols
    );
    let report = serve_unix(std::path::Path::new(&socket), procs, &opts)?;
    println!(
        "received {} frames / {} bytes in {:?} ({:.1} ev/s, {:.2} MB/s, peak ring {})",
        report.frames,
        report.bytes,
        report.wall,
        report.events_per_sec(),
        report.bytes_per_sec() / 1e6,
        report.peak_ring_depth,
    );
    golden_compare(&report, &event, events, seed)?;
    println!(
        "golden equivalence OK: {events} events exactly-once, bit-identical to the \
         in-process run, 0 poisoned / 0 quarantined"
    );
    Ok(())
}

/// Ingest endpoint of the wire pair: connect to the serve socket and
/// stream this process's stripe (`event_id % --procs == --index`) of
/// the seeded event stream as zero-copy frames.
fn cmd_ingest(args: &Args) -> Result<()> {
    use marionette::coordinator::{connect_unix, run_ingest, IngestOpts};

    let (event, events, seed) = wire_workload(args);
    let socket = args.socket.clone().ok_or_else(|| anyhow!("ingest requires --socket PATH"))?;
    let shards = args.procs.unwrap_or(1).max(1);
    let index = args.index.unwrap_or(0);
    let mut stream = connect_unix(
        std::path::Path::new(&socket),
        std::time::Duration::from_secs(10),
    )?;
    let stats = run_ingest(
        &mut stream,
        &IngestOpts { event, n_events: events, seed, shards, index },
    )?;
    println!(
        "ingest[{index}/{shards}]: sent {} frames / {} bytes to {socket}",
        stats.frames, stats.bytes
    );
    Ok(())
}

fn cmd_bench_report(args: &Args) -> Result<()> {
    use marionette::bench_support::report::{self, BenchReport, ReportOpts};

    let mut opts = if args.quick { ReportOpts::quick() } else { ReportOpts::full() };
    if let Some(g) = args.grid {
        opts.grid = g;
    }
    if let Some(e) = args.events {
        opts.events = e;
    }
    if let Some(w) = &args.workers {
        opts.workers = w.clone();
    }

    println!(
        "collecting BENCH report ({} profile, grid {}x{}) ...",
        if opts.quick { "quick" } else { "full" },
        opts.grid,
        opts.grid
    );
    let run = report::collect(&opts)?;
    println!("{}", run.render());

    let out = std::path::PathBuf::from(args.out.as_deref().unwrap_or("BENCH_run.json"));
    run.save(&out)?;
    println!("wrote {}", out.display());

    if args.write_baseline {
        // Committed baselines carry *where* they were measured so a
        // gate failure on a different host is interpretable. collect()
        // itself always stamps plain "measured" — only the baseline
        // write path adds provenance detail.
        let mut stamped = run.clone();
        stamped.provenance = format!(
            "measured:host={},workers={}",
            hostname(),
            opts.workers.iter().map(|w| w.to_string()).collect::<Vec<_>>().join("/")
        );
        let base_path = std::path::PathBuf::from("BENCH_baseline.json");
        stamped.save(&base_path)?;
        println!(
            "baseline updated -> {} (provenance {}; commit it)",
            base_path.display(),
            stamped.provenance
        );
    }

    if let Some(gate) = &args.gate {
        let baseline = BenchReport::load(std::path::Path::new(gate))?;
        if baseline.provenance == "estimated-unmeasured-seed" {
            eprintln!(
                "WARNING: baseline {gate} is hand-estimated (provenance \
                 'estimated-unmeasured-seed'), not measured — gate numbers are \
                 guesses; run `repro bench-report --write-baseline` on a quiet \
                 host and commit the result"
            );
        }
        let failures = report::compare(&run, &baseline);
        if failures.is_empty() {
            println!(
                "gate vs {gate}: OK ({} series, baseline provenance {})",
                baseline.series.len(),
                baseline.provenance
            );
        } else {
            for f in &failures {
                eprintln!("GATE FAIL: {f}");
            }
            bail!("{} BENCH regression(s) vs {gate}", failures.len());
        }
    }
    Ok(())
}

/// Best-effort host name for baseline provenance stamps.
fn hostname() -> String {
    std::process::Command::new("uname")
        .arg("-n")
        .output()
        .ok()
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown-host".to_string())
}

/// Adaptive saturation: the same small-event host sweep, but with the
/// AIMD controller steering the batch bound. Per worker count this
/// runs the fixed-dispatch reference first (on the host-only path the
/// fixed `max_batch` knob bounds only the *device* batcher, so one
/// per-event-dispatch run IS the whole fixed ladder), then the
/// adaptive run, and bails when the controller never moved, when
/// adaptive throughput falls catastrophically below fixed, or when
/// p99 overshoots the target by more than 10%.
fn cmd_saturate_adaptive(args: &Args) -> Result<()> {
    use marionette::bench_support::report::{
        run_saturation, run_saturation_adaptive, BenchPoint, BenchReport, BenchSeries, Better,
        SERIES_ADAPTIVE, SERIES_ADAPTIVE_P99,
    };
    use marionette::coordinator::AdaptiveBatch;

    let grid = args.grid.unwrap_or(if args.quick { 32 } else { 64 });
    let events = args.events.unwrap_or(if args.quick { 4_000 } else { 20_000 });
    let workers = args.workers.clone().unwrap_or_else(|| vec![1, 2, 4]);
    if workers.is_empty() || workers.contains(&0) {
        bail!("--workers needs a comma list of counts >= 1");
    }
    let target_us = args.p99_target_us.unwrap_or(AdaptiveBatch::default().p99_target_us);

    println!(
        "== adaptive saturation: {events} events of {grid}x{grid}, \
         workers {workers:?}, p99 target {target_us}us =="
    );
    let mut tp = Vec::new();
    let mut p99 = Vec::new();
    for &w in &workers {
        let fixed = run_saturation(grid, events, w)?;
        let fixed_evs = fixed.events_per_sec();
        let rep = run_saturation_adaptive(grid, events, w, Some(target_us))?;
        let evs = rep.events_per_sec();
        let m = &rep.metrics;
        let p99_us = m.e2e_p99.as_micros() as f64;
        println!(
            "workers={w}: adaptive {evs:.1} ev/s vs fixed {fixed_evs:.1} ev/s \
             ({:.2}x) | p99={:?} | grows={} shrinks={} max-batch-final={}",
            evs / fixed_evs.max(1e-9),
            m.e2e_p99,
            m.batch_grows,
            m.batch_shrinks,
            m.max_batch_final,
        );
        if m.batch_grows + m.batch_shrinks == 0 {
            bail!("workers={w}: controller never moved the batch bound (grows+shrinks == 0)");
        }
        if p99_us > target_us as f64 * 1.1 {
            bail!(
                "workers={w}: p99 {p99_us:.0}us exceeds target {target_us}us by more than 10%"
            );
        }
        if evs < fixed_evs * 0.8 {
            bail!(
                "workers={w}: adaptive {evs:.1} ev/s fell below 0.8x of the fixed \
                 dispatch {fixed_evs:.1} ev/s"
            );
        }
        tp.push(BenchPoint { label: format!("workers={w}"), value: evs });
        p99.push(BenchPoint { label: format!("workers={w}"), value: p99_us });
    }

    let report = BenchReport {
        quick: args.quick,
        provenance: "measured".to_string(),
        series: vec![
            BenchSeries {
                name: SERIES_ADAPTIVE.to_string(),
                unit: "events_per_sec".to_string(),
                better: Better::Higher,
                tolerance: 0.3,
                points: tp,
            },
            BenchSeries {
                name: SERIES_ADAPTIVE_P99.to_string(),
                unit: "microseconds".to_string(),
                better: Better::Lower,
                tolerance: 0.0,
                points: p99,
            },
        ],
    };
    let out = std::path::PathBuf::from(args.out.as_deref().unwrap_or("BENCH_run.json"));
    report.save(&out)?;
    println!("wrote {}", out.display());
    Ok(())
}

/// The measured-feedback autotuner: traced pipeline run -> per-route
/// access heatmaps -> layout recommendation -> timed ablation check
/// (DESIGN.md §9).
fn cmd_autotune(args: &Args) -> Result<()> {
    let outcome = marionette::bench_support::autotune::run_autotune(args.quick)?;
    println!("{}", outcome.rendered);
    let mismatches = outcome.ablation.iter().filter(|r| !r.matched()).count();
    if mismatches > 0 {
        println!(
            "note: {mismatches}/{} routes where the traced recommendation was not \
             within 1.25x of the measured-best layout (timing noise on small \
             kernels; see the per-layout times above)",
            outcome.ablation.len()
        );
    }
    Ok(())
}

/// Saturation sweep: many small host-only events per worker count;
/// reports events/s + tail latency per count, bails on catastrophic
/// scaling loss (< 0.8x from 1 worker to the max), and writes the
/// saturation series as a BENCH report.
fn cmd_saturate(args: &Args) -> Result<()> {
    use marionette::bench_support::report::{
        run_saturation, BenchPoint, BenchReport, BenchSeries, Better, SERIES_SATURATION,
        SERIES_SATURATION_P99,
    };

    if args.adaptive {
        return cmd_saturate_adaptive(args);
    }

    let grid = args.grid.unwrap_or(if args.quick { 32 } else { 64 });
    let events = args.events.unwrap_or(if args.quick { 4_000 } else { 20_000 });
    let workers = args.workers.clone().unwrap_or_else(|| vec![1, 2, 4]);
    if workers.is_empty() || workers.contains(&0) {
        bail!("--workers needs a comma list of counts >= 1");
    }

    println!("== saturation sweep: {events} events of {grid}x{grid}, workers {workers:?} ==");
    let mut tp = Vec::new();
    let mut p99 = Vec::new();
    let mut evs_per_sec = Vec::new();
    for &w in &workers {
        let rep = run_saturation(grid, events, w)?;
        let evs = rep.events_per_sec();
        let m = &rep.metrics;
        println!(
            "workers={w}: {evs:.1} ev/s | latency p50={:?} p95={:?} p99={:?} \
             | sched injected={} local={} steals={}",
            m.e2e_p50, m.e2e_p95, m.e2e_p99, m.sched_injected, m.sched_local_pushes,
            m.sched_steals,
        );
        tp.push(BenchPoint { label: format!("workers={w}"), value: evs });
        p99.push(BenchPoint {
            label: format!("workers={w}"),
            value: m.e2e_p99.as_micros() as f64,
        });
        evs_per_sec.push(evs);
    }

    let report = BenchReport {
        quick: args.quick,
        provenance: "measured".to_string(),
        series: vec![
            BenchSeries {
                name: SERIES_SATURATION.to_string(),
                unit: "events_per_sec".to_string(),
                better: Better::Higher,
                tolerance: 0.3,
                points: tp,
            },
            BenchSeries {
                name: SERIES_SATURATION_P99.to_string(),
                unit: "microseconds".to_string(),
                better: Better::Lower,
                tolerance: 0.0,
                points: p99,
            },
        ],
    };
    let out = std::path::PathBuf::from(args.out.as_deref().unwrap_or("BENCH_run.json"));
    report.save(&out)?;
    println!("wrote {}", out.display());

    if evs_per_sec.len() > 1 {
        let (first, last) = (evs_per_sec[0], *evs_per_sec.last().unwrap());
        let ratio = last / first.max(1e-9);
        println!(
            "scaling: {:.1} -> {:.1} ev/s ({ratio:.2}x from {} -> {} workers)",
            first,
            last,
            workers[0],
            workers.last().unwrap()
        );
        if ratio < 0.8 {
            bail!(
                "catastrophic scaling loss: {ratio:.2}x from {} to {} workers (floor 0.8x)",
                workers[0],
                workers.last().unwrap()
            );
        }
    }
    Ok(())
}

/// Fault-injection chaos run (DESIGN.md §10): run the same seeded
/// workload clean and with an armed `FaultPlan` (device-worker kill
/// mid-run, optionally allocation faults), then assert no event was
/// lost — everything completes or is reported quarantined — and that
/// every completed event matches the clean run's physics.
fn cmd_chaos(args: &Args) -> Result<()> {
    use marionette::coordinator::FaultPlan;

    let grid = args.grid.unwrap_or(if args.quick { 32 } else { 64 });
    let events = args.events.unwrap_or(if args.quick { 100 } else { 400 });
    let seed = args.seed.unwrap_or(7);

    // One host + one device worker: every fault trigger is
    // count-driven, so a single-worker run makes the fired schedule
    // (and the counters) deterministic for a given seed.
    let mk = || {
        let mut cfg = PipelineConfig::new(EventConfig::grid(grid, grid, 3), events);
        cfg.device = !args.no_device;
        cfg.policy =
            if args.no_device { RoutePolicy::HostOnly } else { RoutePolicy::DeviceOnly };
        cfg.host_workers = 1;
        cfg.device_workers = 1;
        cfg.seed = seed;
        cfg
    };

    let mut plan = FaultPlan::new(seed);
    if !args.no_device {
        // Default: kill the device worker halfway through the stream.
        plan.kill_device_at =
            Some(args.kill_device_at.unwrap_or((events as u64 / 2).max(1)));
    }
    plan.alloc_fail_every = args.alloc_fail_every;

    println!("== chaos: {events} events of {grid}x{grid}, seed {seed} ==");
    println!("plan: {plan:?}");

    // Golden reference: the identical event stream, clean, host-only.
    let mut clean_cfg = mk();
    clean_cfg.device = false;
    clean_cfg.policy = RoutePolicy::HostOnly;
    let clean = run_pipeline(&clean_cfg)?;

    let mut chaos_cfg = mk();
    chaos_cfg.fault = Some(plan);
    let chaos = run_pipeline(&chaos_cfg)?;
    println!("{}", chaos.report());

    // Exactly-once: every submitted event in exactly one of
    // {completed, quarantined}.
    let mut seen: Vec<u64> = chaos.results.iter().map(|r| r.event_id).collect();
    seen.extend(chaos.quarantined.iter().copied());
    seen.sort_unstable();
    seen.dedup();
    let expect: Vec<u64> = (0..events as u64).collect();
    if seen != expect {
        bail!(
            "exactly-once violated: {} completed + {} quarantined != {events} submitted",
            chaos.results.len(),
            chaos.quarantined.len()
        );
    }

    // Golden equivalence for every completed event.
    for r in &chaos.results {
        let g = &clean.results[r.event_id as usize];
        if g.n_particles != r.n_particles {
            bail!(
                "event {}: {} particles vs clean {}",
                r.event_id,
                r.n_particles,
                g.n_particles
            );
        }
        let rel = (g.total_energy - r.total_energy).abs() / g.total_energy.abs().max(1.0);
        if rel > 1e-3 {
            bail!("event {}: energy drift {rel:.2e} vs clean run", r.event_id);
        }
    }
    println!(
        "chaos OK: {}/{events} completed with clean-run physics, {} quarantined \
         (reported), no event lost",
        chaos.results.len(),
        chaos.quarantined.len()
    );
    Ok(())
}

fn cmd_doctor() -> Result<()> {
    println!("PJRT: {}", client::device_description());
    match Engine::load_default() {
        Ok(eng) => {
            let m = eng.manifest();
            println!("artifacts: {} programs in {}", m.records().count(), m.dir.display());
            for entry in ["sensor_stage", "particle_stage", "full_event"] {
                println!("  {entry}: buckets {:?}", m.buckets(entry));
            }
            let d = eng.warm("sensor_stage", 16, 16)?;
            println!("compile smoke (sensor_stage 16x16): {d:?}");
        }
        Err(e) => println!("artifacts: NOT AVAILABLE ({e:#}) - run `make artifacts`"),
    }
    match marionette::edm::golden::load_golden() {
        Some(g) => println!("golden: {}x{} event, {} tensors", g.rows, g.cols, g.tensors.len()),
        None => println!("golden: not built"),
    }
    Ok(())
}

fn run() -> Result<()> {
    let args = parse_args()?;
    match args.command.as_str() {
        "demo" => cmd_demo(&args),
        "run-pipeline" => cmd_pipeline(&args),
        "fig1" => emit(figures::fig1(&fig_opts(&args))?, &args.csv),
        "fig2" => emit(figures::fig2(&fig_opts(&args))?, &args.csv),
        "zero-cost" => {
            let h = if args.quick { Harness::quick() } else { Harness::default() };
            emit(figures::zero_cost(args.grid.unwrap_or(512), h)?, &args.csv)
        }
        "transfers" => {
            let h = if args.quick { Harness::quick() } else { Harness::default() };
            emit(figures::transfers(args.grid.unwrap_or(256), h)?, &args.csv)
        }
        "ablation" => {
            let h = if args.quick { Harness::quick() } else { Harness::default() };
            let grid = args.grid.unwrap_or(if args.quick { 64 } else { 256 });
            emit(figures::ablation_layouts(grid, (grid / 32).max(1).pow(2), h)?, &args.csv)?;
            if !args.no_device {
                let grids = args.grids.clone().unwrap_or_else(|| {
                    if args.quick { vec![16, 32, 64] } else { vec![64, 128, 256, 512] }
                });
                emit(figures::ablation_fused(&grids, h)?, &args.csv)?;
                emit(
                    figures::ablation_routing(grid, args.events.unwrap_or(16))?,
                    &args.csv,
                )?;
            }
            Ok(())
        }
        "bench-report" => cmd_bench_report(&args),
        "saturate" => cmd_saturate(&args),
        "autotune" => cmd_autotune(&args),
        "chaos" => cmd_chaos(&args),
        "serve" => cmd_serve(&args),
        "ingest" => cmd_ingest(&args),
        "doctor" => cmd_doctor(),
        "help" | "--help" | "-h" => {
            println!(
                "repro <command> [flags]\n\
                 commands: demo | run-pipeline | fig1 | fig2 | zero-cost | \
                 transfers | ablation | bench-report | saturate | autotune | \
                 chaos | serve | ingest | doctor\n\
                 flags: --quick --grid N --grids a,b,c --events N \
                 --particles a,b,c --workers a,b,c --dev-workers N \
                 --policy host|device|auto --no-device --csv NAME\n\
                 bench-report: --out PATH --gate BASELINE --write-baseline\n\
                 saturate: --events N --workers a,b,c --out PATH (events/s + \
                 p50/p95/p99 tail-latency sweep over host worker counts); \
                 --adaptive [--p99-target-us N] steers the batch bound with \
                 the AIMD controller and compares against fixed dispatch\n\
                 autotune: --quick (traced access heatmaps per route + \
                 layout-selection ablation; writes \
                 bench_results/autotune_heatmap.csv)\n\
                 chaos: --seed S --kill-device-at K --alloc-fail-every N \
                 (seeded fault injection; asserts exactly-once delivery and \
                 golden-output equivalence vs the clean run)\n\
                 serve: --socket PATH --procs N [--events N --grid N --seed S \
                 --workers W --staging-layout aos|soavec|soablob|aosoa8] \
                 (accept N ingest streams, zero-copy reconstruct, assert \
                 exactly-once + bit-identical golden equivalence)\n\
                 ingest: --socket PATH --procs N --index I [--events N \
                 --grid N --seed S] (stream stripe I of the seeded events \
                 as wire frames)\n\
                 run-pipeline also takes --staging-layout (route the \
                 autotuner's recommendation into the live staging path)"
            );
            Ok(())
        }
        other => bail!("unknown command {other:?} (see `repro help`)"),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

//! `repro` — the Marionette-RS command-line launcher.
//!
//! Commands:
//!   demo                  quick end-to-end tour (host + device paths)
//!   run-pipeline [...]    run the event-processing coordinator
//!   fig1 / fig2 [...]     regenerate the paper's figures
//!   zero-cost             the zero-cost-abstraction table
//!   transfers             the transfer matrix (§VII)
//!   ablation              layout / fusion / routing ablations
//!   bench-report [...]    emit machine-readable BENCH_run.json, gate
//!                         against a committed baseline (DESIGN.md §7)
//!   doctor                environment + artifact checks
//!
//! Shared flags: --quick (small grids, short harness), --grid N,
//! --events N, --particles a,b,c, --no-device, --csv NAME.
//! bench-report flags: --out PATH, --gate BASELINE, --write-baseline.
//!
//! Argument parsing is hand-rolled (clap is not in the vendored set).

use std::process::ExitCode;

use anyhow::{anyhow, bail, Result};

use marionette::bench_support::figures::{self, FigOpts};
use marionette::bench_support::Harness;
use marionette::coordinator::{run_pipeline, PipelineConfig, RoutePolicy};
use marionette::edm::generator::EventConfig;
use marionette::runtime::{client, Engine};

#[derive(Debug, Default)]
struct Args {
    command: String,
    quick: bool,
    grid: Option<usize>,
    events: Option<usize>,
    particles: Option<Vec<usize>>,
    grids: Option<Vec<usize>>,
    no_device: bool,
    csv: Option<String>,
    policy: Option<String>,
    workers: Option<usize>,
    out: Option<String>,
    gate: Option<String>,
    write_baseline: bool,
}

fn parse_args() -> Result<Args> {
    let mut args = Args::default();
    let mut it = std::env::args().skip(1);
    args.command = it.next().unwrap_or_else(|| "help".to_string());
    while let Some(a) = it.next() {
        let mut val = |name: &str| {
            it.next().ok_or_else(|| anyhow!("{name} requires a value"))
        };
        match a.as_str() {
            "--quick" => args.quick = true,
            "--no-device" => args.no_device = true,
            "--grid" => args.grid = Some(val("--grid")?.parse()?),
            "--events" => args.events = Some(val("--events")?.parse()?),
            "--workers" => args.workers = Some(val("--workers")?.parse()?),
            "--csv" => args.csv = Some(val("--csv")?),
            "--policy" => args.policy = Some(val("--policy")?),
            "--out" => args.out = Some(val("--out")?),
            "--gate" => args.gate = Some(val("--gate")?),
            "--write-baseline" => args.write_baseline = true,
            "--particles" => {
                args.particles = Some(
                    val("--particles")?
                        .split(',')
                        .map(|s| s.trim().parse())
                        .collect::<Result<_, _>>()?,
                )
            }
            "--grids" => {
                args.grids = Some(
                    val("--grids")?
                        .split(',')
                        .map(|s| s.trim().parse())
                        .collect::<Result<_, _>>()?,
                )
            }
            other => bail!("unknown flag {other} (see `repro help`)"),
        }
    }
    Ok(args)
}

fn fig_opts(args: &Args) -> FigOpts {
    let mut opts = if args.quick { FigOpts::quick() } else { FigOpts::default() };
    if let Some(g) = &args.grids {
        opts.grids = g.clone();
    }
    if let Some(g) = args.grid {
        opts.fig2_grid = g;
    }
    if let Some(p) = &args.particles {
        opts.particles = p.clone();
    }
    if args.no_device {
        opts.device = false;
    }
    opts
}

fn emit(table: marionette::bench_support::Table, csv: &Option<String>) -> Result<()> {
    println!("{}", table.render());
    if let Some(name) = csv {
        let path = table.save_csv(name)?;
        println!("csv -> {}", path.display());
    }
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    let grid = args.grid.unwrap_or(64);
    println!("== Marionette-RS demo (grid {grid}x{grid}) ==");
    println!("device: {}", client::device_description());

    let mut cfg = PipelineConfig::new(EventConfig::grid(grid, grid, 4), args.events.unwrap_or(16));
    cfg.device = !args.no_device;
    cfg.policy = RoutePolicy::DeviceOnly;
    if args.no_device {
        cfg.policy = RoutePolicy::HostOnly;
    }
    let rep = run_pipeline(&cfg)?;
    println!("{}", rep.report());
    for r in rep.results.iter().take(4) {
        println!(
            "  event {}: {:?} -> {} particles, E={:.1}",
            r.event_id, r.route, r.n_particles, r.total_energy
        );
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let grid = args.grid.unwrap_or(256);
    let events = args.events.unwrap_or(64);
    let mut cfg = PipelineConfig::new(
        EventConfig::grid(grid, grid, (grid / 32).max(1).pow(2)),
        events,
    );
    cfg.device = !args.no_device;
    if let Some(w) = args.workers {
        cfg.host_workers = w;
    }
    cfg.policy = match args.policy.as_deref() {
        Some("host") => RoutePolicy::HostOnly,
        Some("device") => RoutePolicy::DeviceOnly,
        Some("auto") | None => RoutePolicy::default(),
        Some(p) => bail!("unknown policy {p} (host|device|auto)"),
    };
    let rep = run_pipeline(&cfg)?;
    println!("{}", rep.report());
    Ok(())
}

fn cmd_bench_report(args: &Args) -> Result<()> {
    use marionette::bench_support::report::{self, BenchReport, ReportOpts};

    let mut opts = if args.quick { ReportOpts::quick() } else { ReportOpts::full() };
    if let Some(g) = args.grid {
        opts.grid = g;
    }
    if let Some(e) = args.events {
        opts.events = e;
    }
    if let Some(w) = args.workers {
        opts.workers = vec![w];
    }

    println!(
        "collecting BENCH report ({} profile, grid {}x{}) ...",
        if opts.quick { "quick" } else { "full" },
        opts.grid,
        opts.grid
    );
    let run = report::collect(&opts)?;
    println!("{}", run.render());

    let out = std::path::PathBuf::from(args.out.as_deref().unwrap_or("BENCH_run.json"));
    run.save(&out)?;
    println!("wrote {}", out.display());

    if args.write_baseline {
        let base_path = std::path::PathBuf::from("BENCH_baseline.json");
        run.save(&base_path)?;
        println!("baseline updated -> {} (commit it)", base_path.display());
    }

    if let Some(gate) = &args.gate {
        let baseline = BenchReport::load(std::path::Path::new(gate))?;
        let failures = report::compare(&run, &baseline);
        if failures.is_empty() {
            println!(
                "gate vs {gate}: OK ({} series, baseline provenance {})",
                baseline.series.len(),
                baseline.provenance
            );
        } else {
            for f in &failures {
                eprintln!("GATE FAIL: {f}");
            }
            bail!("{} BENCH regression(s) vs {gate}", failures.len());
        }
    }
    Ok(())
}

fn cmd_doctor() -> Result<()> {
    println!("PJRT: {}", client::device_description());
    match Engine::load_default() {
        Ok(eng) => {
            let m = eng.manifest();
            println!("artifacts: {} programs in {}", m.records().count(), m.dir.display());
            for entry in ["sensor_stage", "particle_stage", "full_event"] {
                println!("  {entry}: buckets {:?}", m.buckets(entry));
            }
            let d = eng.warm("sensor_stage", 16, 16)?;
            println!("compile smoke (sensor_stage 16x16): {d:?}");
        }
        Err(e) => println!("artifacts: NOT AVAILABLE ({e:#}) - run `make artifacts`"),
    }
    match marionette::edm::golden::load_golden() {
        Some(g) => println!("golden: {}x{} event, {} tensors", g.rows, g.cols, g.tensors.len()),
        None => println!("golden: not built"),
    }
    Ok(())
}

fn run() -> Result<()> {
    let args = parse_args()?;
    match args.command.as_str() {
        "demo" => cmd_demo(&args),
        "run-pipeline" => cmd_pipeline(&args),
        "fig1" => emit(figures::fig1(&fig_opts(&args))?, &args.csv),
        "fig2" => emit(figures::fig2(&fig_opts(&args))?, &args.csv),
        "zero-cost" => {
            let h = if args.quick { Harness::quick() } else { Harness::default() };
            emit(figures::zero_cost(args.grid.unwrap_or(512), h)?, &args.csv)
        }
        "transfers" => {
            let h = if args.quick { Harness::quick() } else { Harness::default() };
            emit(figures::transfers(args.grid.unwrap_or(256), h)?, &args.csv)
        }
        "ablation" => {
            let h = if args.quick { Harness::quick() } else { Harness::default() };
            let grid = args.grid.unwrap_or(if args.quick { 64 } else { 256 });
            emit(figures::ablation_layouts(grid, (grid / 32).max(1).pow(2), h)?, &args.csv)?;
            if !args.no_device {
                let grids = args.grids.clone().unwrap_or_else(|| {
                    if args.quick { vec![16, 32, 64] } else { vec![64, 128, 256, 512] }
                });
                emit(figures::ablation_fused(&grids, h)?, &args.csv)?;
                emit(
                    figures::ablation_routing(grid, args.events.unwrap_or(16))?,
                    &args.csv,
                )?;
            }
            Ok(())
        }
        "bench-report" => cmd_bench_report(&args),
        "doctor" => cmd_doctor(),
        "help" | "--help" | "-h" => {
            println!(
                "repro <command> [flags]\n\
                 commands: demo | run-pipeline | fig1 | fig2 | zero-cost | \
                 transfers | ablation | bench-report | doctor\n\
                 flags: --quick --grid N --grids a,b,c --events N \
                 --particles a,b,c --workers N --policy host|device|auto \
                 --no-device --csv NAME\n\
                 bench-report: --out PATH --gate BASELINE --write-baseline"
            );
            Ok(())
        }
        other => bail!("unknown command {other:?} (see `repro help`)"),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

//! Benchmark harness: the paper's timing methodology + table printers.
//!
//! §VIII: "the average of the ten fastest times out of 50 executions of
//! 10 different events". [`Harness::measure`] reproduces exactly that
//! protocol (configurable via `MARIONETTE_BENCH_RUNS` / `_KEEP` for quick
//! smoke runs), and [`Series`]/[`Table`] print figure data as aligned
//! text tables + CSV for plotting.

pub mod autotune;
pub mod figures;
pub mod report;

use std::time::{Duration, Instant};

/// Best-k-of-n timing harness.
#[derive(Clone, Copy, Debug)]
pub struct Harness {
    /// Total measured executions.
    pub runs: usize,
    /// The fastest `keep` are averaged.
    pub keep: usize,
    /// Untimed warmup executions.
    pub warmup: usize,
}

impl Default for Harness {
    fn default() -> Self {
        let env = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        // Paper protocol: 50 runs, keep 10.
        Harness { runs: env("MARIONETTE_BENCH_RUNS", 50), keep: env("MARIONETTE_BENCH_KEEP", 10), warmup: 3 }
    }
}

impl Harness {
    pub fn quick() -> Harness {
        Harness { runs: 10, keep: 3, warmup: 1 }
    }

    /// Measure `f` under the paper's protocol; returns mean of the
    /// fastest `keep` runs.
    pub fn measure<F: FnMut()>(&self, mut f: F) -> Duration {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.runs);
        for _ in 0..self.runs {
            let t = Instant::now();
            f();
            times.push(t.elapsed());
        }
        times.sort();
        let keep = self.keep.min(times.len()).max(1);
        let sum: Duration = times[..keep].iter().sum();
        sum / keep as u32
    }
}

/// One figure series: label + (x, time) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, Duration)>,
}

impl Series {
    pub fn new(label: impl Into<String>) -> Series {
        Series { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, t: Duration) {
        self.points.push((x, t));
    }
}

/// A whole figure: x-axis label + several series over shared x values.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub x_label: String,
    pub series: Vec<Series>,
}

impl Table {
    pub fn new(title: impl Into<String>, x_label: impl Into<String>) -> Table {
        Table { title: title.into(), x_label: x_label.into(), series: Vec::new() }
    }

    pub fn push(&mut self, s: Series) {
        self.series.push(s);
    }

    fn xs(&self) -> Vec<f64> {
        let mut xs: Vec<f64> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|&(x, _)| x))
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs.dedup();
        xs
    }

    fn lookup(s: &Series, x: f64) -> Option<Duration> {
        s.points.iter().find(|&&(px, _)| px == x).map(|&(_, t)| t)
    }

    /// Aligned human-readable table (µs).
    pub fn render(&self) -> String {
        let xs = self.xs();
        let mut out = format!("## {}\n", self.title);
        out += &format!("{:>12}", self.x_label);
        for s in &self.series {
            out += &format!(" {:>18}", s.label);
        }
        out += "\n";
        for &x in &xs {
            out += &format!("{:>12}", trim_float(x));
            for s in &self.series {
                match Self::lookup(s, x) {
                    Some(t) => out += &format!(" {:>16.1}us", t.as_secs_f64() * 1e6),
                    None => out += &format!(" {:>18}", "-"),
                }
            }
            out += "\n";
        }
        out
    }

    /// CSV (seconds), one row per x.
    pub fn to_csv(&self) -> String {
        let xs = self.xs();
        let mut out = format!(
            "{},{}\n",
            self.x_label,
            self.series.iter().map(|s| s.label.clone()).collect::<Vec<_>>().join(",")
        );
        for &x in &xs {
            out += &trim_float(x);
            for s in &self.series {
                match Self::lookup(s, x) {
                    Some(t) => out += &format!(",{:.9}", t.as_secs_f64()),
                    None => out += ",",
                }
            }
            out += "\n";
        }
        out
    }

    /// Write the CSV next to the repo (`bench_results/<name>.csv`).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

fn trim_float(x: f64) -> String {
    if x == x.trunc() {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// Relative difference helper used by zero-cost assertions.
pub fn rel_diff(a: Duration, b: Duration) -> f64 {
    let (a, b) = (a.as_secs_f64(), b.as_secs_f64());
    (a - b).abs() / a.max(b).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_keeps_fastest() {
        let mut calls = 0;
        let h = Harness { runs: 10, keep: 2, warmup: 1 };
        let t = h.measure(|| {
            calls += 1;
            std::thread::sleep(Duration::from_micros(200));
        });
        assert_eq!(calls, 11);
        assert!(t >= Duration::from_micros(150));
    }

    #[test]
    fn table_renders_and_csvs() {
        let mut t = Table::new("Fig X", "grid");
        let mut s1 = Series::new("cpu");
        s1.push(16.0, Duration::from_micros(10));
        s1.push(32.0, Duration::from_micros(40));
        let mut s2 = Series::new("dev");
        s2.push(16.0, Duration::from_micros(100));
        t.push(s1);
        t.push(s2);
        let r = t.render();
        assert!(r.contains("cpu"));
        assert!(r.contains("16"));
        let csv = t.to_csv();
        assert!(csv.starts_with("grid,cpu,dev"));
        assert!(csv.contains("32,0.000040000,"));
    }

    #[test]
    fn rel_diff_symmetric() {
        let a = Duration::from_micros(100);
        let b = Duration::from_micros(105);
        assert!(rel_diff(a, b) < 0.05);
        assert_eq!(rel_diff(a, b), rel_diff(b, a));
    }
}

//! The measured-feedback layout autotuner (`repro autotune`).
//!
//! Closes the DESIGN.md §9 loop end-to-end on the host:
//!
//! 1. **Measure** — run a traced pipeline (staging + reco tapes fed by
//!    the real host path), emulate the device-download gather over a
//!    [`SlicePlanes`] store, and tape the particle fill-back reads, so
//!    every route of the event flow has a per-field/per-lane heatmap.
//! 2. **Decide** — [`recommend_layout`] turns each route's stride
//!    fractions into a [`LayoutChoice`], and [`warm_staging_plan`]
//!    pre-compiles the matching `TransferPlan` so the retuned route
//!    pays no first-use plan build.
//! 3. **Check** — an ablation times the route's representative kernel
//!    over all four layout families and reports whether the
//!    recommendation lands on (or within noise of) the measured best.
//!
//! The heatmap is written as `bench_results/autotune_heatmap.csv`
//! (route,field,lane,reads,writes,seq_fraction) for plotting alongside
//! the figure CSVs.

use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

use crate::coordinator::{run_pipeline, PipelineConfig, RoutePolicy, RouteTapes};
use crate::edm::constants::NUM_SENSOR_TYPES;
use crate::edm::generator::{EventConfig, EventGenerator, RawEvent};
use crate::edm::particle::{ParticleProps, ParticleView};
use crate::edm::sensor::{SensorCollection, SensorProps, SensorView};
use crate::edm::{calib, reco};
use crate::marionette::interface::{SlicePlanes, TracingSource};
use crate::marionette::layout::{AoS, AoSoA, Layout, SoABlob, SoAVec};
use crate::marionette::memory::{HostContext, TraceInfo, TracingContext};
use crate::marionette::trace::{
    recommend_layout, warm_staging_plan, LayoutChoice, RouteTraceSummary, TraceTape,
};

use super::Harness;

/// One route's ablation result: the recommendation vs the timed truth.
#[derive(Clone, Debug)]
pub struct AblationRow {
    pub route: &'static str,
    pub recommended: LayoutChoice,
    pub measured_best: LayoutChoice,
    /// Recommended layout's time over the best layout's time (1.0 =
    /// the recommendation IS the measured best).
    pub ratio: f64,
    pub times_us: Vec<(LayoutChoice, f64)>,
}

impl AblationRow {
    /// Within-noise match: the recommended layout costs at most 25%
    /// more than the timed best (layout times cluster tightly on small
    /// grids; a hard equality gate would just measure scheduler noise).
    pub fn matched(&self) -> bool {
        self.ratio <= 1.25
    }
}

/// Everything one autotune pass produced.
#[derive(Debug)]
pub struct AutotuneOutcome {
    pub routes: Vec<RouteTraceSummary>,
    pub ablation: Vec<AblationRow>,
    pub heatmap_path: std::path::PathBuf,
    /// Human-readable report (what `repro autotune` prints).
    pub rendered: String,
}

fn time_calibrate<L: Layout>(h: &Harness, ev: &RawEvent) -> f64
where
    crate::marionette::collection::InfoOf<L>: Default,
{
    let mut c = ev.to_collection::<L>();
    h.measure(|| calib::calibrate_collection(&mut c)).as_secs_f64() * 1e6
}

fn time_accessor_scan<L: Layout>(h: &Harness, ev: &RawEvent) -> f64
where
    crate::marionette::collection::InfoOf<L>: Default,
{
    let mut c = ev.to_collection::<L>();
    h.measure(|| calib::calibrate_collection_accessors(&mut c)).as_secs_f64() * 1e6
}

fn time_reco<L: Layout>(h: &Harness, ev: &RawEvent) -> f64
where
    crate::marionette::collection::InfoOf<L>: Default,
{
    let mut c = ev.to_collection::<L>();
    calib::calibrate_collection(&mut c);
    let c = c;
    h.measure(|| {
        std::hint::black_box(reco::reconstruct_collection(&c).len());
    })
    .as_secs_f64()
        * 1e6
}

fn time_fillback<L: Layout>(h: &Harness, ev: &RawEvent) -> f64
where
    crate::marionette::collection::InfoOf<L>: Default,
{
    let mut c = ev.to_collection::<SoAVec>();
    calib::calibrate_collection(&mut c);
    let particles = reco::reconstruct_collection(&c);
    let pc = reco::into_collection::<L>(ev.event_id, &particles);
    h.measure(|| {
        std::hint::black_box(reco::fill_back_aos(&pc).data.len());
    })
    .as_secs_f64()
        * 1e6
}

/// Time one route's representative kernel over the four layout
/// families and score the recommendation against the measured best.
fn ablate(
    route: &'static str,
    recommended: LayoutChoice,
    h: &Harness,
    ev: &RawEvent,
    op: fn(&Harness, &RawEvent, LayoutChoice) -> f64,
) -> AblationRow {
    let times_us: Vec<(LayoutChoice, f64)> =
        [LayoutChoice::AoS, LayoutChoice::SoAVec, LayoutChoice::SoABlob, LayoutChoice::AoSoA8]
            .into_iter()
            .map(|c| (c, op(h, ev, c)))
            .collect();
    let best = times_us
        .iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .expect("four candidates")
        .0;
    let t_of = |c: LayoutChoice| times_us.iter().find(|&&(x, _)| x == c).unwrap().1;
    let ratio = t_of(recommended) / t_of(best).max(1e-9);
    AblationRow { route, recommended, measured_best: best, ratio, times_us }
}

// Monomorphisation tables: map the runtime choice onto the statically
// typed kernels (function pointers keep `ablate` itself simple).
macro_rules! layout_table {
    ($name:ident, $f:ident) => {
        fn $name(h: &Harness, ev: &RawEvent, c: LayoutChoice) -> f64 {
            match c {
                LayoutChoice::AoS => $f::<AoS>(h, ev),
                LayoutChoice::SoAVec => $f::<SoAVec>(h, ev),
                LayoutChoice::SoABlob => $f::<SoABlob>(h, ev),
                LayoutChoice::AoSoA8 => $f::<AoSoA<8>>(h, ev),
            }
        }
    };
}

layout_table!(ablate_calibrate, time_calibrate);
layout_table!(ablate_accessors, time_accessor_scan);
layout_table!(ablate_reco, time_reco);
layout_table!(ablate_fillback, time_fillback);

fn write_heatmap(routes: &[RouteTraceSummary]) -> Result<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir).context("creating bench_results")?;
    let path = dir.join("autotune_heatmap.csv");
    let mut out = String::from("route,field,lane,reads,writes,seq_fraction\n");
    for r in routes {
        for f in &r.per_field {
            let _ = writeln!(
                out,
                "{},{},{},{},{},{:.4}",
                r.route, f.name, f.lane, f.reads, f.writes, f.seq_fraction
            );
        }
    }
    std::fs::write(&path, out).with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// Run the full measure → decide → check loop.
pub fn run_autotune(quick: bool) -> Result<AutotuneOutcome> {
    let (grid, events) = if quick { (32, 6) } else { (64, 24) };
    let harness = if quick { Harness { runs: 5, keep: 2, warmup: 1 } } else { Harness::quick() };

    // ---- measure: traced pipeline (staging + reco routes) -----------
    let tapes = RouteTapes::new();
    let mut cfg = PipelineConfig::new(EventConfig::grid(grid, grid, 4), events);
    cfg.device = false;
    cfg.policy = RoutePolicy::HostOnly;
    cfg.host_workers = 2;
    cfg.seed = 20260808;
    cfg.trace = Some(tapes.clone());
    let rep = run_pipeline(&cfg).context("traced measurement run")?;

    // ---- measure: emulated device-download gather -------------------
    // The gather route reads a download-shaped borrowed store (exactly
    // what `runtime::devmem::downloaded_planes` binds); without a
    // device we bind host-calibrated planes into the same store shape
    // and run the same reconstruction gather over it, traced.
    let ev = EventGenerator::new(EventConfig::grid(grid, grid, 4), 99).generate();
    let mut cal = ev.to_collection::<SoAVec>();
    calib::calibrate_collection(&mut cal);
    let n = cal.len();
    let energy: Vec<f32> = (0..n).map(|i| cal.energy(i)).collect();
    let noise: Vec<f32> = (0..n).map(|i| cal.noise(i)).collect();
    let sig: Vec<f32> = (0..n).map(|i| cal.sig(i)).collect();
    {
        let planes = SlicePlanes::new(SensorProps::schema(), n)
            .bind("type_id", &ev.types)?
            .bind("counts", &ev.counts)?
            .bind("energy", &energy)?
            .bind("noise", &noise)?
            .bind("sig", &sig)?
            .bind("noisy", &ev.noisy)?
            .bind("param_a", &ev.a)?
            .bind("param_b", &ev.b)?
            .bind("noise_a", &ev.na)?
            .bind("noise_b", &ev.nb)?
            .set_global("rows", ev.rows as u32)?
            .set_global("cols", ev.cols as u32)?
            .set_global("event_id", ev.event_id)?;
        let traced = TracingSource::new(&planes, &tapes.gather);
        let view = SensorView::attach(&traced).context("traced gather attach")?;
        std::hint::black_box(reco::reconstruct(&view).len());
    }

    // ---- measure: particle fill-back reads (particle schema tape) ---
    // Scalar + fixed-array reads only: the jagged `sensors` accessor
    // needs a contiguous values plane, which a tracing source refuses
    // by design (it hides planes to count element accesses).
    let fill_tape = TraceTape::new("fillback", &ParticleProps::schema());
    {
        let particles = reco::reconstruct_collection(&cal);
        let pc = reco::into_collection::<SoAVec>(ev.event_id, &particles);
        let src = pc.traced(&fill_tape);
        let v = ParticleView::attach(&src).context("traced fillback attach")?;
        let mut acc = 0f64;
        for i in 0..v.len() {
            acc += v.energy(i) as f64 + v.x(i) as f64 + v.y(i) as f64;
            for k in 0..NUM_SENSOR_TYPES {
                acc += v.significance(i, k) as f64;
            }
        }
        std::hint::black_box(acc);
    }

    // ---- decide ------------------------------------------------------
    let mut routes = tapes.summaries();
    routes.push(fill_tape.snapshot());
    if routes.len() < 4 {
        bail!(
            "autotune measurement produced only {} non-empty routes \
             (want staging/gather/reco/fillback) — instrumentation broken",
            routes.len()
        );
    }
    for r in &routes {
        let schema =
            if r.route == "fillback" { ParticleProps::schema() } else { SensorProps::schema() };
        warm_staging_plan(r.choice, &schema);
    }

    // ---- check: per-route layout ablation ---------------------------
    let choice_of = |route: &str| routes.iter().find(|r| r.route == route).map(|r| r.choice);
    let mut ablation = Vec::new();
    if let Some(c) = choice_of("staging") {
        ablation.push(ablate("staging", c, &harness, &ev, ablate_calibrate));
    }
    if let Some(c) = choice_of("gather") {
        ablation.push(ablate("gather", c, &harness, &ev, ablate_accessors));
    }
    if let Some(c) = choice_of("reco") {
        ablation.push(ablate("reco", c, &harness, &ev, ablate_reco));
    }
    if let Some(c) = choice_of("fillback") {
        ablation.push(ablate("fillback", c, &harness, &ev, ablate_fillback));
    }

    // ---- tracing memory context demo --------------------------------
    // The context-level half of the instrumentation story: stage into a
    // collection whose *memory context* books traffic, proving the
    // same decorator pattern works below the accessor layer.
    let info: TraceInfo<HostContext> = TraceInfo::default();
    let mut ctx_staged =
        SensorCollection::<SoAVec<TracingContext<HostContext>>>::new_in(info.clone());
    let up = cal.stage_into(&mut ctx_staged);
    let ctx_allocs = info.stats.allocs.load(std::sync::atomic::Ordering::Relaxed);
    if ctx_allocs == 0 {
        bail!("TracingContext booked no allocations staging {} bytes", up.bytes);
    }

    let heatmap_path = write_heatmap(&routes)?;

    // ---- render ------------------------------------------------------
    let mut out = format!(
        "autotune: {} traced events ({:.1} ev/s under tracing)\n",
        rep.results.len(),
        rep.events_per_sec()
    );
    for r in &routes {
        let _ = writeln!(
            out,
            "route {:<8} reads={:<8} writes={:<8} seq={:.2} record={:.2} -> {}",
            r.route,
            r.total_reads,
            r.total_writes,
            r.seq_fraction,
            r.record_fraction,
            r.choice.as_str()
        );
    }
    for a in &ablation {
        let verdict = if a.matched() { "MATCH" } else { "MISMATCH" };
        let _ = write!(
            out,
            "ablation {:<8} recommended={:<7} measured-best={:<7} ratio={:.2} {}\n    ",
            a.route,
            a.recommended.as_str(),
            a.measured_best.as_str(),
            a.ratio,
            verdict
        );
        for (c, t) in &a.times_us {
            let _ = write!(out, "{}={:.1}us ", c.as_str(), t);
        }
        out.push('\n');
    }
    let _ = writeln!(
        out,
        "tracing-context: allocs={} moved={}B memsets={} (staged {}B through it)",
        ctx_allocs,
        info.stats.moved_bytes(),
        info.stats.memset_calls.load(std::sync::atomic::Ordering::Relaxed),
        up.bytes
    );
    let _ = writeln!(out, "heatmap: {}", heatmap_path.display());

    // The recommendations are re-derivable from the summaries — assert
    // internal consistency so a drifted policy shows up here first.
    for r in &routes {
        assert_eq!(r.choice, recommend_layout(r), "snapshot/policy drift on {}", r.route);
    }

    Ok(AutotuneOutcome { routes, ablation, heatmap_path, rendered: out })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autotune_quick_produces_all_routes_and_heatmap() {
        let out = run_autotune(true).unwrap();
        let names: Vec<&str> = out.routes.iter().map(|r| r.route).collect();
        for want in ["staging", "gather", "reco", "fillback"] {
            assert!(names.contains(&want), "route {want} missing: {names:?}");
        }
        // Calibration walks whole records (read 6 fields, write 3 per
        // sensor): the staging route must read as record-coherent.
        let staging = out.routes.iter().find(|r| r.route == "staging").unwrap();
        assert!(
            staging.record_fraction > staging.seq_fraction,
            "staging not record-coherent: seq={} record={}",
            staging.seq_fraction,
            staging.record_fraction
        );
        assert_eq!(staging.choice, LayoutChoice::AoS);
        assert!(staging.total_writes > 0, "calibration writes not taped");
        // Ablation covered every route and timed all four layouts.
        assert_eq!(out.ablation.len(), 4);
        for a in &out.ablation {
            assert_eq!(a.times_us.len(), 4);
            assert!(a.ratio >= 1.0, "{}: best beat itself? {}", a.route, a.ratio);
        }
        assert!(out.heatmap_path.exists());
        let csv = std::fs::read_to_string(&out.heatmap_path).unwrap();
        assert!(csv.starts_with("route,field,lane,reads,writes,seq_fraction"));
        assert!(csv.contains("staging,"));
        assert!(csv.contains("fillback,"));
        assert!(out.rendered.contains("tracing-context: allocs="));
    }
}

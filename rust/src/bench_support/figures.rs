//! Figure/table runners: the code that regenerates every evaluation
//! artefact of the paper (see DESIGN.md experiment index).
//!
//! * [`fig1`] — sensor-stage time vs grid side (fill + transfer-if-device
//!   + calibrate), series {CPU-AoS, CPU-SoA} × {handwritten, Marionette}
//!   + device.
//! * [`fig2`] — particle-stage time vs injected particle count at a fixed
//!   grid (reconstruct + transfer-back-if-device + fill original AoS).
//! * [`zero_cost`] — accessor/algorithm micro-comparison, Marionette vs
//!   handwritten per layout (the "PTX-identical" claim, host edition).
//! * [`transfers`] — `memcopy_with_context` matrix and layout-conversion
//!   ladder (§VII transfers).
//! * [`ablation`] — layout sweep, fused-vs-staged device execution,
//!   routing/batching policies.
//!
//! Each returns [`Table`]s; callers render and/or CSV them. All runners
//! use the paper's best-10-of-50 protocol via [`Harness`].

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{run_pipeline, PipelineConfig, RoutePolicy};
use crate::edm::generator::{EventConfig, EventGenerator, RawEvent};
use crate::edm::handwritten::{HwParticlesAoS, HwSensorsAoS, HwSensorsSoA};
use crate::edm::{calib, reco};
use crate::marionette::layout::{AoS, AoSoA, SoABlob, SoAVec};
use crate::marionette::memory::{StagingContext, StagingInfo};
use crate::marionette::transfer::{copy_collection, copy_collection_unplanned, plan_for};
use crate::runtime::Engine;

use super::{Harness, Series, Table};

/// Options shared by the figure runners.
#[derive(Clone, Debug)]
pub struct FigOpts {
    /// Grid sides for fig1 (must be AOT buckets for the device series).
    pub grids: Vec<usize>,
    /// Fixed grid side for fig2.
    pub fig2_grid: usize,
    /// Particle counts for fig2.
    pub particles: Vec<usize>,
    /// Timing protocol.
    pub harness: Harness,
    /// Include device series (requires artifacts).
    pub device: bool,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            grids: vec![16, 32, 64, 128, 256, 512, 1024],
            fig2_grid: 1024,
            particles: vec![100, 300, 1000, 3000, 10000],
            harness: Harness::default(),
            device: true,
        }
    }
}

impl FigOpts {
    /// Small configuration for smoke tests / CI.
    pub fn quick() -> FigOpts {
        FigOpts {
            grids: vec![16, 32, 64],
            fig2_grid: 64,
            particles: vec![5, 10, 20],
            harness: Harness::quick(),
            device: true,
        }
    }
}

fn event_for_grid(n: usize, particles: usize, seed: u64) -> RawEvent {
    EventGenerator::new(EventConfig::grid(n, n, particles), seed).generate()
}

// ---------------------------------------------------------------------
// Figure 1 — sensor stage vs grid size
// ---------------------------------------------------------------------

/// Figure 1: fill + (transfer) + calibrate, as a function of grid side.
pub fn fig1(opts: &FigOpts) -> Result<Table> {
    let mut table = Table::new(
        "Figure 1 - sensor-stage time vs grid side (fill + transfer + calibrate)",
        "grid",
    );
    let engine = if opts.device { Engine::load_default().ok() } else { None };
    let h = opts.harness;

    let mut cpu_aos_hw = Series::new("cpu-aos-hw");
    let mut cpu_aos_m = Series::new("cpu-aos-marionette");
    let mut cpu_soa_hw = Series::new("cpu-soa-hw");
    let mut cpu_soa_m = Series::new("cpu-soa-marionette");
    let mut dev = Series::new("device");

    for &n in &opts.grids {
        // ~1 deposit per 32x32 cells keeps event content proportional.
        let ev = event_for_grid(n, (n / 32).max(1) * (n / 32).max(1), 1000 + n as u64);
        let x = n as f64;

        // CPU AoS handwritten.
        let mut hw_aos = HwSensorsAoS::default();
        cpu_aos_hw.push(
            x,
            h.measure(|| {
                ev.fill_hw_aos(&mut hw_aos);
                calib::calibrate_hw_aos(&mut hw_aos);
            }),
        );

        // CPU AoS Marionette.
        let mut m_aos = crate::edm::SensorCollection::<AoS>::new();
        cpu_aos_m.push(
            x,
            h.measure(|| {
                ev.fill_collection(&mut m_aos);
                calib::calibrate_collection(&mut m_aos);
            }),
        );

        // CPU SoA handwritten.
        let mut hw_soa = HwSensorsSoA::default();
        cpu_soa_hw.push(
            x,
            h.measure(|| {
                ev.fill_hw_soa(&mut hw_soa);
                calib::calibrate_hw_soa(&mut hw_soa);
            }),
        );

        // CPU SoA Marionette.
        let mut m_soa = crate::edm::SensorCollection::<SoAVec>::new();
        cpu_soa_m.push(
            x,
            h.measure(|| {
                ev.fill_collection(&mut m_soa);
                calib::calibrate_collection(&mut m_soa);
            }),
        );

        // Device: upload + calibrate kernel + download.
        if let Some(eng) = &engine {
            if eng.manifest().get("sensor_stage", n, n).is_ok() {
                eng.warm("sensor_stage", n, n)?;
                dev.push(
                    x,
                    h.measure(|| {
                        let _ = eng.run_sensor_stage(&ev).expect("device run");
                    }),
                );
            }
        }
    }

    table.push(cpu_aos_hw);
    table.push(cpu_aos_m);
    table.push(cpu_soa_hw);
    table.push(cpu_soa_m);
    if !dev.points.is_empty() {
        table.push(dev);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// Figure 2 — particle stage vs particle count
// ---------------------------------------------------------------------

/// Figure 2: reconstruct + (transfer back) + fill original AoS, as a
/// function of injected particle count at a fixed grid.
pub fn fig2(opts: &FigOpts) -> Result<Table> {
    let n = opts.fig2_grid;
    let mut table = Table::new(
        format!(
            "Figure 2 - particle-stage time vs particles (grid {n}x{n}; \
             reconstruct + transfer back + fill AoS)"
        ),
        "particles",
    );
    let engine = if opts.device { Engine::load_default().ok() } else { None };
    let h = opts.harness;

    let mut cpu_aos_hw = Series::new("cpu-aos-hw");
    let mut cpu_aos_m = Series::new("cpu-aos-marionette");
    let mut cpu_soa_hw = Series::new("cpu-soa-hw");
    let mut cpu_soa_m = Series::new("cpu-soa-marionette");
    let mut dev = Series::new("device");

    for &p in &opts.particles {
        let ev = event_for_grid(n, p, 2000 + p as u64);
        let x = p as f64;

        // Calibrated inputs prepared once, outside the timed region.
        let mut hw_aos = HwSensorsAoS::default();
        ev.fill_hw_aos(&mut hw_aos);
        calib::calibrate_hw_aos(&mut hw_aos);

        let mut hw_soa = HwSensorsSoA::default();
        ev.fill_hw_soa(&mut hw_soa);
        calib::calibrate_hw_soa(&mut hw_soa);

        let mut m_aos = ev.to_collection::<AoS>();
        calib::calibrate_collection(&mut m_aos);
        let mut m_soa = ev.to_collection::<SoAVec>();
        calib::calibrate_collection(&mut m_soa);

        // CPU handwritten AoS: reconstruct straight into the original AoS.
        cpu_aos_hw.push(
            x,
            h.measure(|| {
                let ps = reco::reconstruct(&hw_aos);
                let out = HwParticlesAoS { event_id: hw_aos.event_id, data: ps };
                std::hint::black_box(&out);
            }),
        );

        // CPU Marionette AoS: reconstruct into the marionette structure,
        // then fill back the original AoS (paper protocol: each solution
        // produces its own structure, then converts back).
        cpu_aos_m.push(
            x,
            h.measure(|| {
                let pc = reco::reconstruct_into_collection(&m_aos);
                let out = reco::fill_back_aos(&pc);
                std::hint::black_box(&out);
            }),
        );

        // CPU handwritten SoA: reconstruct into the handwritten SoA
        // structure, then fill back the original AoS.
        cpu_soa_hw.push(
            x,
            h.measure(|| {
                let ps = reco::reconstruct_to_hw_soa(&hw_soa);
                let out = reco::hw_soa_fill_back_aos(&ps);
                std::hint::black_box(&out);
            }),
        );

        // CPU Marionette SoA.
        cpu_soa_m.push(
            x,
            h.measure(|| {
                let pc = reco::reconstruct_into_collection(&m_soa);
                let out = reco::fill_back_aos(&pc);
                std::hint::black_box(&out);
            }),
        );

        // Device: upload calibrated planes + stencil kernels + download
        // + gather + fill back.
        if let Some(eng) = &engine {
            if eng.manifest().get("particle_stage", n, n).is_ok() {
                eng.warm("particle_stage", n, n)?;
                let energy: Vec<f32> = (0..m_soa.len()).map(|i| m_soa.energy(i)).collect();
                let sig: Vec<f32> = (0..m_soa.len()).map(|i| m_soa.sig(i)).collect();
                let noisy: Vec<i32> = ev.noisy.iter().map(|&v| v as i32).collect();
                dev.push(
                    x,
                    h.measure(|| {
                        let (out, _) = eng
                            .run_particle_stage(n, n, &energy, &sig, &ev.types, &noisy)
                            .expect("device run");
                        let pc = reco::particles_from_planes::<SoAVec>(
                            n, n, ev.event_id, &out.seeds, &out.sums, &sig,
                        );
                        let back = reco::fill_back_aos(&pc);
                        std::hint::black_box(&back);
                    }),
                );
            }
        }
    }

    table.push(cpu_aos_hw);
    table.push(cpu_aos_m);
    table.push(cpu_soa_hw);
    table.push(cpu_soa_m);
    if !dev.points.is_empty() {
        table.push(dev);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// Zero-cost micro-benchmark
// ---------------------------------------------------------------------

/// Zero-cost table: per-element read (energy sum) and calibrate times,
/// Marionette vs handwritten, per layout. X axis encodes the operation:
/// 0 = read-sum, 1 = calibrate.
pub fn zero_cost(grid: usize, harness: Harness) -> Result<Table> {
    let ev = event_for_grid(grid, (grid / 32).max(1).pow(2), 31);
    let mut table = Table::new(
        format!("Zero-cost abstractions - {grid}x{grid} grid (0=read-sum, 1=calibrate)"),
        "op",
    );

    macro_rules! marionette_series {
        ($label:expr, $layout:ty) => {{
            let mut s = Series::new($label);
            let mut col = ev.to_collection::<$layout>();
            calib::calibrate_collection(&mut col);
            s.push(
                0.0,
                harness.measure(|| {
                    let mut acc = 0f32;
                    for i in 0..col.len() {
                        acc += col.energy(i);
                    }
                    std::hint::black_box(acc);
                }),
            );
            s.push(1.0, harness.measure(|| calib::calibrate_collection(&mut col)));
            s
        }};
    }

    // Per-element accessor series: the dense-view-free baseline that
    // quantifies the abstraction penalty the record/column fast paths
    // avoid (EXPERIMENTS.md §Perf-1) — and the apples-to-apples
    // baseline the borrowed-view series are pinned against.
    macro_rules! accessor_series {
        ($label:expr, $layout:ty) => {{
            let mut s = Series::new($label);
            let mut col = ev.to_collection::<$layout>();
            calib::calibrate_collection(&mut col);
            s.push(
                0.0,
                harness.measure(|| {
                    let mut acc = 0f32;
                    for i in 0..col.len() {
                        acc += col.energy(i);
                    }
                    std::hint::black_box(acc);
                }),
            );
            s.push(
                1.0,
                harness.measure(|| calib::calibrate_collection_accessors(&mut col)),
            );
            s
        }};
    }

    // Borrowed-view series: the same loops through the source-erased
    // typed view (attach once per run — dense spans resolved there —
    // then raw-offset reads/writes). The guard test pins these to
    // owned-accessor cost.
    macro_rules! view_series {
        ($label:expr, $layout:ty) => {{
            let mut s = Series::new($label);
            let mut col = ev.to_collection::<$layout>();
            calib::calibrate_collection(&mut col);
            s.push(
                0.0,
                harness.measure(|| {
                    let v = col.view();
                    let mut acc = 0f32;
                    for i in 0..v.len() {
                        acc += v.energy(i);
                    }
                    std::hint::black_box(acc);
                }),
            );
            s.push(
                1.0,
                harness.measure(|| calib::calibrate_view(&mut col.view_mut())),
            );
            s
        }};
    }

    // Handwritten AoS.
    let mut s = Series::new("hw-aos");
    let mut hw_aos = HwSensorsAoS::default();
    ev.fill_hw_aos(&mut hw_aos);
    calib::calibrate_hw_aos(&mut hw_aos);
    s.push(
        0.0,
        harness.measure(|| {
            let mut acc = 0f32;
            for rec in &hw_aos.data {
                acc += rec.energy;
            }
            std::hint::black_box(acc);
        }),
    );
    s.push(1.0, harness.measure(|| calib::calibrate_hw_aos(&mut hw_aos)));
    table.push(s);

    table.push(marionette_series!("m-aos", AoS));
    table.push(accessor_series!("m-aos-accessor", AoS));
    table.push(view_series!("m-aos-view", AoS));

    // Handwritten SoA.
    let mut s = Series::new("hw-soa");
    let mut hw_soa = HwSensorsSoA::default();
    ev.fill_hw_soa(&mut hw_soa);
    calib::calibrate_hw_soa(&mut hw_soa);
    s.push(
        0.0,
        harness.measure(|| {
            let mut acc = 0f32;
            for &e in &hw_soa.energy {
                acc += e;
            }
            std::hint::black_box(acc);
        }),
    );
    s.push(1.0, harness.measure(|| calib::calibrate_hw_soa(&mut hw_soa)));
    table.push(s);

    table.push(marionette_series!("m-soavec", SoAVec));
    table.push(accessor_series!("m-soavec-accessor", SoAVec));
    table.push(view_series!("m-soavec-view", SoAVec));
    table.push(marionette_series!("m-soablob", SoABlob));
    table.push(marionette_series!("m-aosoa8", AoSoA<8>));

    Ok(table)
}

// ---------------------------------------------------------------------
// Transfer benchmarks (§VII)
// ---------------------------------------------------------------------

/// Series labels of the planned-vs-unplanned comparison in
/// [`transfers`] (shared with `benches/transfers.rs`, which prints the
/// amortisation ratio).
pub const PLANNED_SERIES: &str = "planned-exec";
pub const UNPLANNED_SERIES: &str = "ladder-per-call";

/// Transfer table: layout-conversion times for a fixed collection size,
/// plus raw `memcopy_with_context` bandwidth points and the
/// planned-vs-unplanned amortisation comparison. X encodes bytes.
pub fn transfers(grid: usize, harness: Harness) -> Result<Table> {
    let ev = event_for_grid(grid, 4, 17);
    let mut table = Table::new(
        format!("Transfers - sensor collection {grid}x{grid} + raw memcopy"),
        "bytes",
    );
    let src = ev.to_collection::<SoAVec>();
    let bytes = (src.len() * 30) as f64; // ~30B per sensor across planes

    macro_rules! conv {
        ($label:expr, $src:ty, $dst:ty) => {{
            let s0 = ev.to_collection::<$src>();
            let mut d = crate::edm::SensorCollection::<$dst>::new();
            let mut s = Series::new($label);
            s.push(bytes, harness.measure(|| {
                copy_collection(s0.raw(), d.raw_mut());
            }));
            table.push(s);
        }};
    }

    conv!("soavec->soavec", SoAVec, SoAVec);
    conv!("soavec->aos", SoAVec, AoS);
    conv!("aos->soavec", AoS, SoAVec);
    conv!("aos->soablob", AoS, SoABlob);
    conv!("soavec->aosoa8", SoAVec, AoSoA<8>);

    // Host -> staging (the H2D analogue) at the same payload.
    {
        let s0 = ev.to_collection::<SoAVec>();
        let info = StagingInfo::default();
        let mut d = crate::edm::SensorCollection::<SoAVec<StagingContext>>::new_in(info);
        let mut s = Series::new("host->staging");
        s.push(bytes, harness.measure(|| {
            copy_collection(s0.raw(), d.raw_mut());
        }));
        table.push(s);
    }

    // Plan amortisation: the multi-field SoAVec -> staging SoABlob case,
    // per-call ladder walk (strategy re-derived + destination rebuilt
    // every call) vs one cached plan executed into a reused staging
    // buffer. A deliberately small grid, so the per-call overhead the
    // plan removes is visible next to the memcpy floor.
    {
        let small = event_for_grid(32, 2, 19);
        let s0 = small.to_collection::<SoAVec>();
        let xbytes = (s0.len() * 30) as f64;
        let info = StagingInfo::default();
        let mut d =
            crate::edm::SensorCollection::<SoABlob<StagingContext>>::new_in(info);
        let mut unplanned = Series::new(UNPLANNED_SERIES);
        unplanned.push(
            xbytes,
            harness.measure(|| {
                copy_collection_unplanned(s0.raw(), d.raw_mut());
            }),
        );
        table.push(unplanned);
        let plan = plan_for::<SoAVec, SoABlob<StagingContext>>(s0.schema());
        let mut planned = Series::new(PLANNED_SERIES);
        planned.push(
            xbytes,
            harness.measure(|| {
                plan.execute(s0.raw(), d.raw_mut());
            }),
        );
        table.push(planned);
    }

    // Raw byte-bandwidth points.
    let mut raw = Series::new("raw-memcpy");
    for size in [4 << 10, 1 << 20, 16 << 20] {
        let srcb = vec![1u8; size];
        let mut dstb = vec![0u8; size];
        raw.push(
            size as f64,
            harness.measure(|| unsafe {
                crate::marionette::transfer::memcopy_with_context::<
                    crate::marionette::memory::HostContext,
                    crate::marionette::memory::HostContext,
                >(&(), srcb.as_ptr(), &(), dstb.as_mut_ptr(), size);
                std::hint::black_box(&dstb);
            }),
        );
    }
    table.push(raw);

    Ok(table)
}

// ---------------------------------------------------------------------
// Ablations
// ---------------------------------------------------------------------

/// Ablation 1: host algorithm time per layout (calibrate at 0, reconstruct
/// at 1) — the "experiment with different data layouts" motivation.
pub fn ablation_layouts(grid: usize, particles: usize, harness: Harness) -> Result<Table> {
    let ev = event_for_grid(grid, particles, 23);
    let mut table = Table::new(
        format!("Ablation - layout sweep at {grid}x{grid}, {particles} particles \
                 (0=calibrate, 1=reconstruct)"),
        "op",
    );

    macro_rules! layout_series {
        ($label:expr, $layout:ty) => {{
            let mut s = Series::new($label);
            let mut col = ev.to_collection::<$layout>();
            s.push(0.0, harness.measure(|| calib::calibrate_collection(&mut col)));
            s.push(1.0, harness.measure(|| {
                std::hint::black_box(reco::reconstruct_collection(&col));
            }));
            table.push(s);
        }};
    }

    layout_series!("soavec", SoAVec);
    layout_series!("aos", AoS);
    layout_series!("soablob", SoABlob);
    layout_series!("aosoa4", AoSoA<4>);
    layout_series!("aosoa16", AoSoA<16>);
    Ok(table)
}

/// Ablation 2: fused vs staged device execution (the "sidestepping
/// unnecessary conversions" claim, §VIII).
pub fn ablation_fused(grids: &[usize], harness: Harness) -> Result<Table> {
    let engine = Engine::load_default()?;
    let mut table = Table::new(
        "Ablation - fused full_event vs staged sensor+particle (device)",
        "grid",
    );
    let mut fused = Series::new("fused");
    let mut staged = Series::new("staged");
    for &n in grids {
        if engine.manifest().get("full_event", n, n).is_err() {
            continue;
        }
        let ev = event_for_grid(n, (n / 32).max(1).pow(2), 41);
        engine.warm("full_event", n, n)?;
        engine.warm("sensor_stage", n, n)?;
        engine.warm("particle_stage", n, n)?;
        fused.push(
            n as f64,
            harness.measure(|| {
                let _ = engine.run_full_event(&ev).expect("fused");
            }),
        );
        let noisy: Vec<i32> = ev.noisy.iter().map(|&v| v as i32).collect();
        staged.push(
            n as f64,
            harness.measure(|| {
                let (s, _) = engine.run_sensor_stage(&ev).expect("staged-1");
                let _ = engine
                    .run_particle_stage(n, n, &s.energy, &s.sig, &ev.types, &noisy)
                    .expect("staged-2");
            }),
        );
    }
    table.push(fused);
    table.push(staged);
    Ok(table)
}

/// Ablation 3: routing policies through the full coordinator (throughput
/// in events/s encoded as a Duration of 1/throughput for table reuse).
pub fn ablation_routing(grid: usize, n_events: usize) -> Result<Table> {
    let mut table = Table::new(
        format!("Ablation - routing policy at {grid}x{grid}, {n_events} events \
                 (per-event wall time)"),
        "policy",
    );
    let policies: [(&str, RoutePolicy, bool); 3] = [
        ("host-only", RoutePolicy::HostOnly, false),
        ("device-only", RoutePolicy::DeviceOnly, true),
        ("auto", RoutePolicy::default(), true),
    ];
    for (idx, (label, policy, device)) in policies.into_iter().enumerate() {
        let mut cfg = PipelineConfig::new(
            EventConfig::grid(grid, grid, (grid / 32).max(1).pow(2)),
            n_events,
        );
        cfg.policy = policy;
        cfg.device = device;
        let rep = run_pipeline(&cfg)?;
        let mut s = Series::new(label);
        s.push(idx as f64, Duration::from_secs_f64(rep.wall.as_secs_f64() / n_events as f64));
        table.push(s);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig1_has_expected_shape() {
        let mut opts = FigOpts::quick();
        opts.harness = Harness { runs: 2, keep: 1, warmup: 0 };
        let t = fig1(&opts).unwrap();
        assert!(t.series.len() >= 4);
        for s in &t.series {
            assert_eq!(s.points.len(), opts.grids.len(), "series {}", s.label);
        }
        assert!(t.render().contains("cpu-aos-hw"));
    }

    #[test]
    fn quick_zero_cost_within_bounds() {
        let h = Harness { runs: 5, keep: 2, warmup: 1 };
        let t = zero_cost(64, h).unwrap();
        assert_eq!(t.series.len(), 10);
        assert!(t.series.iter().any(|s| s.label == "m-aos-view"));
        assert!(t.series.iter().any(|s| s.label == "m-aos-accessor"));
        assert!(t.series.iter().any(|s| s.label == "m-soavec-view"));
        // Each series has both ops measured.
        for s in &t.series {
            assert_eq!(s.points.len(), 2);
            assert!(s.points.iter().all(|&(_, d)| d > Duration::ZERO));
        }
    }

    #[test]
    fn quick_transfers_table() {
        let h = Harness { runs: 2, keep: 1, warmup: 0 };
        let t = transfers(32, h).unwrap();
        assert!(t.series.iter().any(|s| s.label == "host->staging"));
        assert!(t.series.iter().any(|s| s.label == PLANNED_SERIES));
        assert!(t.series.iter().any(|s| s.label == UNPLANNED_SERIES));
        assert!(t.to_csv().contains("raw-memcpy"));
    }

    #[test]
    fn quick_layout_ablation() {
        let h = Harness { runs: 2, keep: 1, warmup: 0 };
        let t = ablation_layouts(48, 3, h).unwrap();
        assert_eq!(t.series.len(), 5);
    }
}

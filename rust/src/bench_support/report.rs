//! Machine-readable BENCH reporting and regression gating.
//!
//! Turns the paper-figure benches into a committed performance
//! trajectory: [`collect`] measures the eleven series ROADMAP calls
//! for (plan-cache hit rate, bytes/s per transfer route, events/s per
//! worker count, view-vs-owned accessor ratios, the saturation
//! events/s + p99 tail-latency sweep, the same sweep under the
//! adaptive AIMD batch controller, degraded-mode throughput with a
//! device worker killed mid-run, wire-format encode/decode bytes/s,
//! and single- vs multi-process ingestion events/s),
//! [`BenchReport::to_json`]
//! emits them as `BENCH_run.json`, and [`compare`] gates a fresh run
//! against a committed `BENCH_baseline.json` within per-series
//! tolerances. The JSON format and the baseline-update policy are
//! documented in DESIGN.md §7; `ci.sh` runs the `--quick` profile as a
//! bench-smoke stage on every CI pass.

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{run_pipeline, AdaptiveBatch, FaultPlan, PipelineConfig, RoutePolicy};
use crate::edm::generator::{EventConfig, EventGenerator};
use crate::edm::SensorCollection;
use crate::marionette::layout::{AoS, SoAVec};
use crate::marionette::transfer::{copy_collection, plan_cache_stats};
use crate::util::json::{self, Value};

use super::figures;
use super::Harness;

/// Format version stamped into every report (`"marionette_bench"` key).
pub const SCHEMA_VERSION: u64 = 1;

/// Plan-cache hit rate per transfer route (unit `ratio`, higher better).
pub const SERIES_PLAN_CACHE: &str = "plan_cache_hit_rate";
/// Copy throughput per transfer route (unit `bytes_per_sec`).
pub const SERIES_TRANSFER: &str = "transfer_bytes_per_sec";
/// End-to-end pipeline throughput per worker count (unit `events_per_sec`).
pub const SERIES_PIPELINE: &str = "pipeline_events_per_sec";
/// Borrowed-view time over owned-accessor time (unit `ratio`, lower better).
pub const SERIES_VIEW_RATIO: &str = "view_accessor_ratio";
/// Small-event host-path saturation throughput per worker count (unit
/// `events_per_sec`): many tiny events stress the scheduler and queues
/// rather than per-event compute (the `repro saturate` sweep).
pub const SERIES_SATURATION: &str = "saturation_events_per_sec";
/// p99 end-to-end latency of the saturation sweep per worker count
/// (unit `microseconds`, lower better; informational — machine noise
/// makes tail latency a poor hard gate).
pub const SERIES_SATURATION_P99: &str = "saturation_p99_latency_us";
/// Saturation throughput with the AIMD batch controller steering
/// `max_batch` instead of a fixed value (unit `events_per_sec`): the
/// measured-feedback autotuner's headline series (DESIGN.md §9).
pub const SERIES_ADAPTIVE: &str = "adaptive_events_per_sec";
/// p99 end-to-end latency of the adaptive sweep per worker count (unit
/// `microseconds`, lower better; informational like the fixed-batch
/// p99 — tail latency is machine noise).
pub const SERIES_ADAPTIVE_P99: &str = "adaptive_p99_latency_us";
/// Graceful-degradation throughput (unit `events_per_sec`): the same
/// device-routed stream run clean and with a chaos plan that kills the
/// device worker halfway through (DESIGN.md §10). Both points require
/// exactly-once delivery; the `kill-at-50%` point gates how much
/// throughput survives a worker death.
pub const SERIES_DEGRADED: &str = "degraded_events_per_sec";
/// Wire-format throughput (unit `bytes_per_sec`, DESIGN.md §11): frame
/// a staged sensor event with `encode_frame` (point `encode`) and
/// decode + schema-check + zero-copy-attach it back (point
/// `decode-attach`, including the socket-read-equivalent buffer copy).
pub const SERIES_WIRE: &str = "wire_bytes_per_sec";
/// Multi-process ingestion throughput (unit `events_per_sec`): the
/// socketpair-fed reconstruction topology with one vs two ingest
/// producers (points `procs=1` / `procs=2`), golden-checked against
/// the in-process run before the numbers are booked.
pub const SERIES_INGEST: &str = "ingest_events_per_sec";

/// Every report must carry all eleven series to pass
/// [`BenchReport::validate`].
pub const REQUIRED_SERIES: [&str; 11] = [
    SERIES_PLAN_CACHE,
    SERIES_TRANSFER,
    SERIES_PIPELINE,
    SERIES_VIEW_RATIO,
    SERIES_SATURATION,
    SERIES_SATURATION_P99,
    SERIES_ADAPTIVE,
    SERIES_ADAPTIVE_P99,
    SERIES_DEGRADED,
    SERIES_WIRE,
    SERIES_INGEST,
];

/// Which direction is an improvement for a series.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Better {
    Higher,
    Lower,
}

impl Better {
    fn as_str(self) -> &'static str {
        match self {
            Better::Higher => "higher",
            Better::Lower => "lower",
        }
    }

    fn from_str(s: &str) -> Result<Better> {
        match s {
            "higher" => Ok(Better::Higher),
            "lower" => Ok(Better::Lower),
            other => bail!("unknown better direction {other:?}"),
        }
    }
}

/// One measured point: a route / worker count / layout label plus the
/// measured value in the series unit.
#[derive(Clone, Debug)]
pub struct BenchPoint {
    pub label: String,
    pub value: f64,
}

/// One named series of labelled points, with its gating contract.
#[derive(Clone, Debug)]
pub struct BenchSeries {
    pub name: String,
    pub unit: String,
    pub better: Better,
    /// Relative slack for [`compare`]: a `Higher` series fails when
    /// `run < base * (1 - tolerance)`, a `Lower` series when
    /// `run > base * (1 + tolerance)`. `0.0` marks the series
    /// informational (never gated).
    pub tolerance: f64,
    pub points: Vec<BenchPoint>,
}

impl BenchSeries {
    fn point(&self, label: &str) -> Option<&BenchPoint> {
        self.points.iter().find(|p| p.label == label)
    }
}

/// A full BENCH run: schema version, run profile, provenance and the
/// measured series.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub quick: bool,
    /// `"measured"` for reports produced by [`collect`];
    /// `"estimated-unmeasured-seed"` marks a hand-authored baseline
    /// that has not yet been replaced by a real run (DESIGN.md §7).
    pub provenance: String,
    pub series: Vec<BenchSeries>,
}

impl BenchReport {
    pub fn series(&self, name: &str) -> Option<&BenchSeries> {
        self.series.iter().find(|s| s.name == name)
    }

    /// Structural contract: all [`REQUIRED_SERIES`] present and
    /// non-empty, units declared, every value finite.
    pub fn validate(&self) -> Result<()> {
        for name in REQUIRED_SERIES {
            let s = self
                .series(name)
                .ok_or_else(|| anyhow!("required series {name:?} missing"))?;
            if s.unit.is_empty() {
                bail!("series {name:?} has no unit");
            }
            if s.points.is_empty() {
                bail!("series {name:?} has no points");
            }
            for p in &s.points {
                if !p.value.is_finite() {
                    bail!("series {name:?} point {:?} is not finite: {}", p.label, p.value);
                }
            }
        }
        Ok(())
    }

    /// Serialise to the DESIGN.md §7 JSON format (stable key order,
    /// one series per line block — diff-friendly for committed files).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"marionette_bench\": {SCHEMA_VERSION},\n"));
        out.push_str(&format!("  \"quick\": {},\n", self.quick));
        out.push_str(&format!("  \"provenance\": {},\n", esc(&self.provenance)));
        out.push_str("  \"series\": [\n");
        for (i, s) in self.series.iter().enumerate() {
            out.push_str("    {\n");
            out.push_str(&format!("      \"name\": {},\n", esc(&s.name)));
            out.push_str(&format!("      \"unit\": {},\n", esc(&s.unit)));
            out.push_str(&format!("      \"better\": {},\n", esc(s.better.as_str())));
            out.push_str(&format!("      \"tolerance\": {},\n", fmt_f64(s.tolerance)));
            out.push_str("      \"points\": [\n");
            for (j, p) in s.points.iter().enumerate() {
                out.push_str(&format!(
                    "        {{\"label\": {}, \"value\": {}}}{}\n",
                    esc(&p.label),
                    fmt_f64(p.value),
                    if j + 1 == s.points.len() { "" } else { "," }
                ));
            }
            out.push_str("      ]\n");
            out.push_str(&format!(
                "    }}{}\n",
                if i + 1 == self.series.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse a report produced by [`BenchReport::to_json`] (or a
    /// hand-maintained baseline in the same format).
    pub fn from_json(src: &str) -> Result<BenchReport> {
        let v = json::parse(src).map_err(|e| anyhow!("BENCH json: {e}"))?;
        let version = v
            .req("marionette_bench")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize()
            .ok_or_else(|| anyhow!("marionette_bench must be an integer"))?;
        if version as u64 != SCHEMA_VERSION {
            bail!("unsupported BENCH schema version {version} (want {SCHEMA_VERSION})");
        }
        let quick = v.get("quick").and_then(Value::as_bool).unwrap_or(false);
        let provenance = v
            .get("provenance")
            .and_then(Value::as_str)
            .unwrap_or("unknown")
            .to_string();
        let mut series = Vec::new();
        let arr = v
            .req("series")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("series must be an array"))?;
        for sv in arr {
            let name = str_field(sv, "name")?;
            let unit = str_field(sv, "unit")?;
            let better = Better::from_str(&str_field(sv, "better")?)
                .with_context(|| format!("series {name:?}"))?;
            let tolerance = sv
                .get("tolerance")
                .and_then(Value::as_f64)
                .ok_or_else(|| anyhow!("series {name:?}: tolerance must be a number"))?;
            let mut points = Vec::new();
            let parr = sv
                .req("points")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .ok_or_else(|| anyhow!("series {name:?}: points must be an array"))?;
            for pv in parr {
                let label = str_field(pv, "label")?;
                let value = pv
                    .get("value")
                    .and_then(Value::as_f64)
                    .ok_or_else(|| anyhow!("series {name:?} point {label:?}: bad value"))?;
                points.push(BenchPoint { label, value });
            }
            series.push(BenchSeries { name, unit, better, tolerance, points });
        }
        Ok(BenchReport { quick, provenance, series })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<BenchReport> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        BenchReport::from_json(&src).with_context(|| format!("parsing {}", path.display()))
    }

    /// Human-readable summary for terminal output.
    pub fn render(&self) -> String {
        let mut out = format!(
            "BENCH report (quick={}, provenance={})\n",
            self.quick, self.provenance
        );
        for s in &self.series {
            out += &format!("  {} [{}], better={}:\n", s.name, s.unit, s.better.as_str());
            for p in &s.points {
                out += &format!("    {:<24} {:>14.4}\n", p.label, p.value);
            }
        }
        out
    }
}

fn str_field(v: &Value, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing string field {key:?}"))
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out += &format!("\\u{:04x}", c as u32),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn fmt_f64(v: f64) -> String {
    debug_assert!(v.is_finite(), "non-finite BENCH value");
    if !v.is_finite() {
        return "0".to_string();
    }
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}.0", v.trunc() as i64)
    } else {
        format!("{v}")
    }
}

// ---------------------------------------------------------------------
// Collection
// ---------------------------------------------------------------------

/// Run profile for [`collect`].
#[derive(Clone, Debug)]
pub struct ReportOpts {
    pub quick: bool,
    pub grid: usize,
    pub events: usize,
    pub workers: Vec<usize>,
    pub harness: Harness,
}

impl ReportOpts {
    /// CI bench-smoke profile: small grids, short harness, ~seconds.
    pub fn quick() -> ReportOpts {
        ReportOpts {
            quick: true,
            grid: 64,
            events: 24,
            workers: vec![1, 2],
            harness: Harness::quick(),
        }
    }

    /// Full trajectory profile (paper-protocol harness).
    pub fn full() -> ReportOpts {
        ReportOpts {
            quick: false,
            grid: 256,
            events: 200,
            workers: vec![1, 2, 4, 8],
            harness: Harness::default(),
        }
    }
}

// Default gate tolerances (DESIGN.md §7) stamped into emitted runs —
// i.e. the contract the *next* committed baseline will enforce. The
// machine-independent series gate tightly; throughput series carry the
// §7 target tolerance of 0.3. (Gating reads the committed baseline's
// tolerances, so the still-estimated seed baseline keeps its looser
// catastrophic-only floor until a measured one replaces it.)
const TOL_HIT_RATE: f64 = 0.10;
const TOL_VIEW_RATIO: f64 = 0.60; // matches the 1.6x zero-cost guard bound
const TOL_THROUGHPUT: f64 = 0.30;

/// Measure all eleven required series and return a validated report.
pub fn collect(opts: &ReportOpts) -> Result<BenchReport> {
    let (sat_tp, sat_p99) = saturation_series(opts)?;
    let (ada_tp, ada_p99) = adaptive_series(opts)?;
    let report = BenchReport {
        quick: opts.quick,
        provenance: "measured".to_string(),
        series: vec![
            plan_cache_series(opts)?,
            transfer_series(opts)?,
            pipeline_series(opts)?,
            view_ratio_series(opts)?,
            sat_tp,
            sat_p99,
            ada_tp,
            ada_p99,
            degraded_series(opts)?,
            wire_series(opts)?,
            ingest_series(opts)?,
        ],
    };
    report.validate()?;
    Ok(report)
}

/// Steady-state plan-cache hit rate per route: after one warmup copy
/// compiles the plan, every further lookup must hit. Counters are
/// process-global, so measure a delta over enough repetitions that
/// concurrent first-compiles elsewhere cannot drag the rate below the
/// gate floor.
fn plan_cache_series(opts: &ReportOpts) -> Result<BenchSeries> {
    let reps = if opts.quick { 256 } else { 1024 };
    let ev = EventGenerator::new(EventConfig::grid(opts.grid, opts.grid, 4), 17).generate();
    let mut points = Vec::new();

    macro_rules! route {
        ($label:expr, $src:ty, $dst:ty) => {{
            let src = ev.to_collection::<$src>();
            let mut dst = SensorCollection::<$dst>::new();
            copy_collection(src.raw(), dst.raw_mut()); // warm: compile the plan
            let before = plan_cache_stats();
            for _ in 0..reps {
                copy_collection(src.raw(), dst.raw_mut());
            }
            let after = plan_cache_stats();
            let hits = after.hits.saturating_sub(before.hits);
            let misses = after.misses.saturating_sub(before.misses);
            let rate = hits as f64 / (hits + misses).max(1) as f64;
            points.push(BenchPoint { label: $label.to_string(), value: rate });
        }};
    }

    route!("soavec->aos", SoAVec, AoS);
    route!("aos->soavec", AoS, SoAVec);

    Ok(BenchSeries {
        name: SERIES_PLAN_CACHE.to_string(),
        unit: "ratio".to_string(),
        better: Better::Higher,
        tolerance: TOL_HIT_RATE,
        points,
    })
}

/// Bytes/s per transfer route, from the §VII transfer figure: each
/// series point there is (payload bytes, best-k-of-n time).
fn transfer_series(opts: &ReportOpts) -> Result<BenchSeries> {
    let table = figures::transfers(opts.grid, opts.harness)?;
    let mut points = Vec::new();
    for s in &table.series {
        // raw-memcpy carries several sizes; take the largest payload —
        // the steady-bandwidth point.
        let Some(&(bytes, t)) = s.points.iter().max_by(|a, b| a.0.total_cmp(&b.0)) else {
            continue;
        };
        let secs = t.as_secs_f64().max(1e-9);
        points.push(BenchPoint { label: s.label.clone(), value: bytes / secs });
    }
    Ok(BenchSeries {
        name: SERIES_TRANSFER.to_string(),
        unit: "bytes_per_sec".to_string(),
        better: Better::Higher,
        tolerance: TOL_THROUGHPUT,
        points,
    })
}

/// Host-only pipeline throughput per worker count (device routing is
/// environment-dependent; the host path is always comparable).
fn pipeline_series(opts: &ReportOpts) -> Result<BenchSeries> {
    let mut points = Vec::new();
    for &w in &opts.workers {
        let mut cfg = PipelineConfig::new(EventConfig::grid(opts.grid, opts.grid, 4), opts.events);
        cfg.device = false;
        cfg.policy = RoutePolicy::HostOnly;
        cfg.host_workers = w;
        cfg.seed = 20260808;
        let rep = run_pipeline(&cfg)?;
        points.push(BenchPoint {
            label: format!("workers={w}"),
            value: rep.events_per_sec(),
        });
    }
    Ok(BenchSeries {
        name: SERIES_PIPELINE.to_string(),
        unit: "events_per_sec".to_string(),
        better: Better::Higher,
        tolerance: TOL_THROUGHPUT,
        points,
    })
}

/// The saturation sweep: many *small* (32×32) host-only events per
/// worker count, so scheduler dispatch, gate backpressure, and plan/
/// stage-pool lookups dominate per-event compute. Returns the
/// (events/s, p99 latency µs) series pair — the `repro saturate`
/// command runs the same sweep standalone at larger event counts.
pub fn saturation_series(opts: &ReportOpts) -> Result<(BenchSeries, BenchSeries)> {
    let events = if opts.quick { 300 } else { 2000 };
    let mut tp = Vec::new();
    let mut p99 = Vec::new();
    for &w in &opts.workers {
        let rep = run_saturation(32, events, w)?;
        tp.push(BenchPoint { label: format!("workers={w}"), value: rep.events_per_sec() });
        p99.push(BenchPoint {
            label: format!("workers={w}"),
            value: rep.metrics.e2e_p99.as_micros() as f64,
        });
    }
    Ok((
        BenchSeries {
            name: SERIES_SATURATION.to_string(),
            unit: "events_per_sec".to_string(),
            better: Better::Higher,
            tolerance: TOL_THROUGHPUT,
            points: tp,
        },
        BenchSeries {
            name: SERIES_SATURATION_P99.to_string(),
            unit: "microseconds".to_string(),
            better: Better::Lower,
            tolerance: 0.0, // informational: tail latency is machine noise
            points: p99,
        },
    ))
}

/// One host-only saturation run (shared by [`saturation_series`] and
/// `repro saturate`).
pub fn run_saturation(
    grid: usize,
    events: usize,
    workers: usize,
) -> Result<crate::coordinator::PipelineReport> {
    let mut cfg = PipelineConfig::new(EventConfig::grid(grid, grid, 4), events);
    cfg.device = false;
    cfg.policy = RoutePolicy::HostOnly;
    cfg.host_workers = workers;
    cfg.seed = 20260808;
    run_pipeline(&cfg)
}

/// The adaptive saturation sweep: the same workload as
/// [`saturation_series`], but with the AIMD controller steering the
/// batch bound instead of the fixed config value. Series pair is
/// (events/s, p99 µs) per worker count, mirroring the fixed sweep so
/// the two are directly comparable in a committed trajectory.
pub fn adaptive_series(opts: &ReportOpts) -> Result<(BenchSeries, BenchSeries)> {
    let events = if opts.quick { 300 } else { 2000 };
    let mut tp = Vec::new();
    let mut p99 = Vec::new();
    for &w in &opts.workers {
        let rep = run_saturation_adaptive(32, events, w, None)?;
        tp.push(BenchPoint { label: format!("workers={w}"), value: rep.events_per_sec() });
        p99.push(BenchPoint {
            label: format!("workers={w}"),
            value: rep.metrics.e2e_p99.as_micros() as f64,
        });
    }
    Ok((
        BenchSeries {
            name: SERIES_ADAPTIVE.to_string(),
            unit: "events_per_sec".to_string(),
            better: Better::Higher,
            tolerance: TOL_THROUGHPUT,
            points: tp,
        },
        BenchSeries {
            name: SERIES_ADAPTIVE_P99.to_string(),
            unit: "microseconds".to_string(),
            better: Better::Lower,
            tolerance: 0.0, // informational: tail latency is machine noise
            points: p99,
        },
    ))
}

/// One adaptive host-only saturation run (shared by [`adaptive_series`]
/// and `repro saturate --adaptive`). `p99_target_us` overrides the
/// default controller target when given.
pub fn run_saturation_adaptive(
    grid: usize,
    events: usize,
    workers: usize,
    p99_target_us: Option<u64>,
) -> Result<crate::coordinator::PipelineReport> {
    let mut cfg = PipelineConfig::new(EventConfig::grid(grid, grid, 4), events);
    cfg.device = false;
    cfg.policy = RoutePolicy::HostOnly;
    cfg.host_workers = workers;
    cfg.seed = 20260808;
    let defaults = AdaptiveBatch::default();
    cfg.adaptive = Some(AdaptiveBatch {
        // Observe often enough to move on short smoke runs, without
        // making the controller thrash on full sweeps.
        observe_every: (events / 16).clamp(8, 64),
        p99_target_us: p99_target_us.map_or(defaults.p99_target_us, |t| t.max(1)),
        ..defaults
    });
    run_pipeline(&cfg)
}

/// Graceful-degradation throughput (DESIGN.md §10): the same
/// device-routed workload run clean and with a chaos plan that kills
/// the device worker halfway through the stream. Both runs must
/// account for every event (completed or reported quarantined; the
/// chaos run recovers in-flight events from the supervisor ledger and
/// respawns the worker). Single host + device worker so the
/// count-driven kill schedule is deterministic. Uses only the per-run
/// kill injector — never the process-global transfer hook, which would
/// cross-fire into concurrent benches.
pub fn degraded_series(opts: &ReportOpts) -> Result<BenchSeries> {
    let events = if opts.quick { 60 } else { 300 };
    let run = |fault: Option<FaultPlan>| -> Result<crate::coordinator::PipelineReport> {
        let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 4), events);
        cfg.device = true;
        cfg.policy = RoutePolicy::DeviceOnly;
        cfg.host_workers = 1;
        cfg.device_workers = 1;
        cfg.seed = 20260808;
        cfg.fault = fault;
        let rep = run_pipeline(&cfg)?;
        let accounted = rep.results.len() + rep.quarantined.len();
        if accounted != events {
            bail!("degraded series lost events: {accounted} of {events} accounted for");
        }
        Ok(rep)
    };
    let clean = run(None)?;
    let kill =
        run(Some(FaultPlan::new(20260808).kill_device_at((events as u64 / 2).max(1))))?;
    Ok(BenchSeries {
        name: SERIES_DEGRADED.to_string(),
        unit: "events_per_sec".to_string(),
        better: Better::Higher,
        tolerance: TOL_THROUGHPUT,
        points: vec![
            BenchPoint { label: "clean".to_string(), value: clean.events_per_sec() },
            BenchPoint { label: "kill-at-50%".to_string(), value: kill.events_per_sec() },
        ],
    })
}

/// Wire-format throughput (DESIGN.md §11): `encode` frames one staged
/// sensor event into the zero-copy format; `decode-attach` replays the
/// receive path — buffer copy (the socket read's stand-in), header +
/// CRC validation, schema check, and a zero-copy view attach with one
/// element read to keep the optimizer honest.
pub fn wire_series(opts: &ReportOpts) -> Result<BenchSeries> {
    use crate::edm::sensor::{SensorProps, SensorView};
    use crate::marionette::wire::{encode_frame, Frame};
    use std::time::Instant;

    let reps = if opts.quick { 64 } else { 512 };
    let ev = EventGenerator::new(EventConfig::grid(opts.grid, opts.grid, 4), 17).generate();
    let mut sensors = SensorCollection::<SoAVec>::new();
    ev.fill_collection(&mut sensors);
    let frame = encode_frame(&sensors, ev.event_id);
    let frame_bytes = frame.len() as f64;

    let t = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(encode_frame(&sensors, ev.event_id).len());
    }
    let encode_bps = frame_bytes * reps as f64 / t.elapsed().as_secs_f64().max(1e-9);

    let schema = SensorProps::schema();
    let t = Instant::now();
    for _ in 0..reps {
        let decoded = Frame::decode_slice(frame.as_slice())
            .map_err(|e| anyhow!("wire series decode: {e}"))?;
        let src = decoded
            .source(&schema)
            .map_err(|e| anyhow!("wire series attach: {e}"))?;
        let v = SensorView::attach(&src).map_err(|e| anyhow!("wire series view: {e:?}"))?;
        std::hint::black_box(v.counts(0));
    }
    let decode_bps = frame_bytes * reps as f64 / t.elapsed().as_secs_f64().max(1e-9);

    Ok(BenchSeries {
        name: SERIES_WIRE.to_string(),
        unit: "bytes_per_sec".to_string(),
        better: Better::Higher,
        tolerance: TOL_THROUGHPUT,
        points: vec![
            BenchPoint { label: "encode".to_string(), value: encode_bps },
            BenchPoint { label: "decode-attach".to_string(), value: decode_bps },
        ],
    })
}

/// Multi-process ingestion throughput: the full socketpair topology
/// (N ingest threads striping the seeded stream, bounded reassembly
/// ring, zero-copy frame attach) at one and two producers. Each run is
/// golden-compared against the in-process generator before its
/// events/s is booked — a fast-but-wrong number can never land in the
/// trajectory.
pub fn ingest_series(opts: &ReportOpts) -> Result<BenchSeries> {
    use crate::coordinator::{golden_compare, run_socketpair_ingest, ServeOpts};

    let events = if opts.quick { 48 } else { 200 };
    let event = EventConfig::grid(32, 32, 4);
    let seed = 20260808;
    let mut points = Vec::new();
    for procs in [1usize, 2] {
        let report =
            run_socketpair_ingest(&event, events, seed, procs, &ServeOpts::default())?;
        golden_compare(&report, &event, events, seed)
            .with_context(|| format!("ingest series procs={procs}"))?;
        points.push(BenchPoint {
            label: format!("procs={procs}"),
            value: report.events_per_sec(),
        });
    }
    Ok(BenchSeries {
        name: SERIES_INGEST.to_string(),
        unit: "events_per_sec".to_string(),
        better: Better::Higher,
        tolerance: TOL_THROUGHPUT,
        points,
    })
}

/// Borrowed-view cost over owned-accessor cost per layout, from the
/// zero-cost figure (mean across its per-op points).
fn view_ratio_series(opts: &ReportOpts) -> Result<BenchSeries> {
    let table = figures::zero_cost(opts.grid, opts.harness)?;
    let mean = |label: &str| -> Result<f64> {
        let s = table
            .series
            .iter()
            .find(|s| s.label == label)
            .ok_or_else(|| anyhow!("zero-cost table missing series {label:?}"))?;
        if s.points.is_empty() {
            bail!("zero-cost series {label:?} is empty");
        }
        let sum: f64 = s.points.iter().map(|&(_, t)| t.as_secs_f64()).sum();
        Ok((sum / s.points.len() as f64).max(1e-12))
    };
    let mut points = Vec::new();
    for (label, view, accessor) in [
        ("aos", "m-aos-view", "m-aos-accessor"),
        ("soavec", "m-soavec-view", "m-soavec-accessor"),
    ] {
        points.push(BenchPoint {
            label: label.to_string(),
            value: mean(view)? / mean(accessor)?,
        });
    }
    Ok(BenchSeries {
        name: SERIES_VIEW_RATIO.to_string(),
        unit: "ratio".to_string(),
        better: Better::Lower,
        tolerance: TOL_VIEW_RATIO,
        points,
    })
}

// ---------------------------------------------------------------------
// Gating
// ---------------------------------------------------------------------

/// Gate `run` against `baseline`. Returns one message per violation;
/// empty means the run is within tolerance of the baseline on every
/// gated series. The baseline's per-series `tolerance` and `better`
/// direction define the contract; series with `tolerance == 0` are
/// informational.
pub fn compare(run: &BenchReport, baseline: &BenchReport) -> Vec<String> {
    let mut failures = Vec::new();
    for base in &baseline.series {
        if base.tolerance <= 0.0 {
            continue;
        }
        let Some(rs) = run.series(&base.name) else {
            failures.push(format!("series {:?} missing from run", base.name));
            continue;
        };
        if rs.unit != base.unit {
            failures.push(format!(
                "series {:?}: unit {:?} != baseline {:?}",
                base.name, rs.unit, base.unit
            ));
            continue;
        }
        for bp in &base.points {
            let Some(rp) = rs.point(&bp.label) else {
                failures.push(format!(
                    "series {:?}: point {:?} missing from run",
                    base.name, bp.label
                ));
                continue;
            };
            if !rp.value.is_finite() {
                failures.push(format!(
                    "series {:?} point {:?}: run value is not finite",
                    base.name, bp.label
                ));
                continue;
            }
            let (bad, bound) = match base.better {
                Better::Higher => {
                    let floor = bp.value * (1.0 - base.tolerance);
                    (rp.value < floor, floor)
                }
                Better::Lower => {
                    let ceil = bp.value * (1.0 + base.tolerance);
                    (rp.value > ceil, ceil)
                }
            };
            if bad {
                failures.push(format!(
                    "series {:?} point {:?}: {} {:.4} vs baseline {:.4} \
                     (tolerance {:.0}%, bound {:.4}) — regression",
                    base.name,
                    bp.label,
                    rs.unit,
                    rp.value,
                    bp.value,
                    base.tolerance * 100.0,
                    bound
                ));
            }
        }
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BenchReport {
        BenchReport {
            quick: true,
            provenance: "measured".to_string(),
            series: vec![
                BenchSeries {
                    name: SERIES_PLAN_CACHE.to_string(),
                    unit: "ratio".to_string(),
                    better: Better::Higher,
                    tolerance: 0.1,
                    points: vec![BenchPoint { label: "soavec->aos".into(), value: 1.0 }],
                },
                BenchSeries {
                    name: SERIES_VIEW_RATIO.to_string(),
                    unit: "ratio".to_string(),
                    better: Better::Lower,
                    tolerance: 0.6,
                    points: vec![BenchPoint { label: "aos".into(), value: 1.0 }],
                },
            ],
        }
    }

    #[test]
    fn json_round_trips() {
        let r = tiny();
        let parsed = BenchReport::from_json(&r.to_json()).unwrap();
        assert!(parsed.quick);
        assert_eq!(parsed.provenance, "measured");
        assert_eq!(parsed.series.len(), 2);
        let s = parsed.series(SERIES_PLAN_CACHE).unwrap();
        assert_eq!(s.unit, "ratio");
        assert_eq!(s.better, Better::Higher);
        assert_eq!(s.points[0].label, "soavec->aos");
        assert_eq!(s.points[0].value, 1.0);
    }

    #[test]
    fn compare_passes_identical_and_fails_regressions() {
        let base = tiny();
        assert!(compare(&base, &base).is_empty());

        // Higher-is-better series degrades beyond tolerance.
        let mut bad = base.clone();
        bad.series[0].points[0].value = 0.5;
        let fails = compare(&bad, &base);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("plan_cache_hit_rate"));

        // Lower-is-better series degrades beyond tolerance.
        let mut slow = base.clone();
        slow.series[1].points[0].value = 2.0;
        assert_eq!(compare(&slow, &base).len(), 1);

        // Within tolerance: no failure.
        let mut ok = base.clone();
        ok.series[0].points[0].value = 0.95;
        ok.series[1].points[0].value = 1.5;
        assert!(compare(&ok, &base).is_empty());

        // Missing series and missing point both fail.
        let mut missing = base.clone();
        missing.series.remove(1);
        assert_eq!(compare(&missing, &base).len(), 1);
        let mut nolabel = base.clone();
        nolabel.series[0].points[0].label = "other".into();
        assert_eq!(compare(&nolabel, &base).len(), 1);
    }

    #[test]
    fn rejects_wrong_version() {
        let src = "{\"marionette_bench\": 999, \"series\": []}";
        assert!(BenchReport::from_json(src).is_err());
    }

    #[test]
    fn escapes_strings() {
        let mut r = tiny();
        r.provenance = "a\"b\\c\nd".to_string();
        let parsed = BenchReport::from_json(&r.to_json()).unwrap();
        assert_eq!(parsed.provenance, "a\"b\\c\nd");
    }
}

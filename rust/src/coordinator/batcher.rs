//! Device-side batching: drain the device queue in grid-bucket groups.
//!
//! The device executes one fixed-shape executable per event, so the win
//! from batching is not kernel fusion but *locality*: draining a run of
//! same-bucket events keeps one compiled executable hot and amortises
//! queue synchronisation. The batcher reorders the pending window by
//! bucket (bounded, so no starvation) — the standard continuous-batching
//! trick adapted to shape-bucketed AOT executables.
//!
//! Two bounds govern a drain (DESIGN.md §9):
//!
//! * `max_batch` — the most items one batch may carry. Runtime-mutable
//!   via [`Batcher::set_max_batch`], which is what the
//!   [`AimdBatchController`] drives.
//! * `reorder_window` — how far past the queue head the drain may scan
//!   for same-bucket items. This bounds both the per-drain work (the old
//!   implementation rebuilt the whole queue on every drain, O(n) even
//!   for a 1-item batch) and the no-starvation guarantee: every drain
//!   removes the queue head, so an item admitted at position `p` drains
//!   within `p + 1` drains, and total overtaking by younger items is
//!   bounded by `(w-1)(w-2)/2` for window `w` — independent of backlog
//!   depth, unlike the old full-queue scan whose overtaking grew with
//!   the backlog. Both bounds are pinned by the fairness property test
//!   below.

use std::collections::VecDeque;

/// Default reorder window: far enough to form full batches out of
/// interleaved buckets, small enough that a drain never walks a deep
/// backlog.
pub const DEFAULT_REORDER_WINDOW: usize = 64;

/// Generic bucket-grouping batcher over items with a shape key.
#[derive(Debug)]
pub struct Batcher<T> {
    pending: VecDeque<(usize, T)>,
    max_batch: usize,
    reorder_window: usize,
    formed: usize,
    /// Reused scratch for skipped-over items (no per-drain allocation
    /// in steady state).
    scratch: Vec<(usize, T)>,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize) -> Self {
        Self::with_window(max_batch, DEFAULT_REORDER_WINDOW)
    }

    /// Batcher with an explicit reorder window (`0` is clamped to 1:
    /// the head item always drains).
    pub fn with_window(max_batch: usize, reorder_window: usize) -> Self {
        Batcher {
            pending: VecDeque::new(),
            max_batch: max_batch.max(1),
            reorder_window: reorder_window.max(1),
            formed: 0,
            scratch: Vec::new(),
        }
    }

    pub fn push(&mut self, bucket: usize, item: T) {
        self.pending.push_back((bucket, item));
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Current batch-size cap.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Retarget the batch-size cap (the adaptive controller's knob).
    /// Takes effect on the next [`Self::drain_batch`].
    pub fn set_max_batch(&mut self, max_batch: usize) {
        self.max_batch = max_batch.max(1);
    }

    /// The reorder window (starvation bound).
    pub fn reorder_window(&self) -> usize {
        self.reorder_window
    }

    /// Bucket of the batch the next [`Self::drain_batch`] call would
    /// return. The device worker peeks this to warm the bucket's
    /// executable (and its staging plan) before the batch lands.
    pub fn next_bucket(&self) -> Option<usize> {
        self.pending.front().map(|&(b, _)| b)
    }

    /// Non-empty batches drained so far.
    pub fn batches_formed(&self) -> usize {
        self.formed
    }

    /// Drain the next batch: items sharing the bucket of the oldest
    /// pending item, up to `max_batch`, preserving arrival order within
    /// the bucket. Items of other buckets keep their positions.
    ///
    /// The scan is bounded: at most `reorder_window` items are examined
    /// and it stops early once `max_batch` matches are found, so a
    /// drain is O(min(window, pending)) regardless of backlog depth.
    pub fn drain_batch(&mut self) -> Vec<(usize, T)> {
        let Some(&(lead, _)) = self.pending.front() else {
            return Vec::new();
        };
        let mut batch = Vec::new();
        let mut scanned = 0usize;
        while scanned < self.reorder_window && batch.len() < self.max_batch {
            let Some((b, item)) = self.pending.pop_front() else { break };
            scanned += 1;
            if b == lead {
                batch.push((b, item));
            } else {
                self.scratch.push((b, item));
            }
        }
        // Skipped items return to the front in their original relative
        // order (reverse push_front of the scratch stack).
        while let Some(entry) = self.scratch.pop() {
            self.pending.push_front(entry);
        }
        if !batch.is_empty() {
            self.formed += 1;
        }
        batch
    }
}

// ---------------------------------------------------------------------
// AIMD batch-size controller
// ---------------------------------------------------------------------

/// Configuration-independent AIMD controller for the dispatch batch
/// size (DESIGN.md §9). Pure decision logic — the pipeline feeds it
/// `(queue depth, windowed p99)` observations and publishes the result
/// to the shared `max_batch` knob; the controller holds no clock, no
/// locks and no references, so it is trivially testable.
///
/// Invariants:
///
/// * `current` stays within `[min_batch, ceiling]`;
/// * **additive increase** — grows by `grow_step` only while the queue
///   is deep (`depth >= depth_threshold`) AND the measured p99 sits
///   below `p99_target_us * grow_headroom` (the deadband that prevents
///   grow/shrink oscillation at the target);
/// * **multiplicative decrease** — on a p99 breach the batch halves
///   (times `shrink_factor`) at most once per `cooldown_obs`
///   observations, so one long-tail window cannot collapse the batch to
///   the floor before its effect is even measurable;
/// * with depth below the threshold and p99 under target the
///   controller holds (no drift in either direction).
#[derive(Debug, Clone)]
pub struct AimdBatchController {
    min_batch: usize,
    ceiling: usize,
    grow_step: usize,
    shrink_factor: f64,
    p99_target_us: u64,
    grow_headroom: f64,
    depth_threshold: usize,
    cooldown_obs: u32,
    current: usize,
    cooldown: u32,
    grows: u64,
    shrinks: u64,
}

impl AimdBatchController {
    pub fn new(cfg: &crate::coordinator::config::AdaptiveBatch) -> Self {
        let min = cfg.min_batch.max(1);
        AimdBatchController {
            min_batch: min,
            ceiling: cfg.max_batch.max(min),
            grow_step: cfg.grow_step.max(1),
            shrink_factor: cfg.shrink_factor.clamp(0.1, 0.99),
            p99_target_us: cfg.p99_target_us.max(1),
            grow_headroom: cfg.grow_headroom.clamp(0.1, 1.0),
            depth_threshold: cfg.depth_threshold.max(1),
            cooldown_obs: cfg.cooldown_obs,
            current: min,
            cooldown: 0,
            grows: 0,
            shrinks: 0,
        }
    }

    /// The batch size the controller currently recommends.
    pub fn current(&self) -> usize {
        self.current
    }

    pub fn grows(&self) -> u64 {
        self.grows
    }

    pub fn shrinks(&self) -> u64 {
        self.shrinks
    }

    /// The configured p99 target in microseconds.
    pub fn p99_target_us(&self) -> u64 {
        self.p99_target_us
    }

    /// Feed one observation window: current queue depth plus the p99
    /// latency measured over the window (`None` = no completions in the
    /// window — depth alone then drives growth). Returns the new batch
    /// size.
    pub fn observe(&mut self, depth: usize, p99_us: Option<u64>) -> usize {
        self.cooldown = self.cooldown.saturating_sub(1);
        let breach = p99_us.is_some_and(|p| p > self.p99_target_us);
        let headroom = p99_us
            .map(|p| (p as f64) <= self.p99_target_us as f64 * self.grow_headroom)
            .unwrap_or(true);
        if breach {
            if self.cooldown == 0 && self.current > self.min_batch {
                let shrunk = (self.current as f64 * self.shrink_factor).floor() as usize;
                self.current = shrunk.max(self.min_batch);
                self.shrinks += 1;
                self.cooldown = self.cooldown_obs;
            }
        } else if depth >= self.depth_threshold && headroom && self.current < self.ceiling {
            self.current = (self.current + self.grow_step).min(self.ceiling);
            self.grows += 1;
        }
        self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::AdaptiveBatch;
    use crate::util::prop::Cases;

    #[test]
    fn groups_by_leading_bucket() {
        let mut b = Batcher::new(8);
        for (bucket, id) in [(64, 0), (128, 1), (64, 2), (64, 3), (128, 4)] {
            b.push(bucket, id);
        }
        let batch = b.drain_batch();
        assert_eq!(batch, vec![(64, 0), (64, 2), (64, 3)]);
        let batch = b.drain_batch();
        assert_eq!(batch, vec![(128, 1), (128, 4)]);
        assert!(b.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.push(32, i);
        }
        assert_eq!(b.drain_batch().len(), 2);
        assert_eq!(b.drain_batch().len(), 2);
        assert_eq!(b.drain_batch().len(), 1);
    }

    #[test]
    fn no_starvation_across_buckets() {
        // Bucket 1 arrives first; a flood of bucket 2 must not jump it.
        let mut b = Batcher::new(100);
        b.push(1, 0);
        for i in 1..50 {
            b.push(2, i);
        }
        let first = b.drain_batch();
        assert_eq!(first, vec![(1, 0)]);
        assert_eq!(b.drain_batch().len(), 49);
    }

    #[test]
    fn empty_drain() {
        let mut b: Batcher<u32> = Batcher::new(4);
        assert!(b.drain_batch().is_empty());
        assert_eq!(b.batches_formed(), 0);
    }

    #[test]
    fn peeks_next_bucket_and_counts_batches() {
        let mut b = Batcher::new(8);
        assert_eq!(b.next_bucket(), None);
        b.push(64, 0);
        b.push(128, 1);
        assert_eq!(b.next_bucket(), Some(64));
        b.drain_batch();
        assert_eq!(b.next_bucket(), Some(128));
        b.drain_batch();
        assert_eq!(b.next_bucket(), None);
        assert_eq!(b.batches_formed(), 2);
    }

    #[test]
    fn scan_stops_at_window() {
        // Window 4: items past the window keep their place even when
        // they match the lead bucket.
        let mut b = Batcher::with_window(100, 4);
        for i in 0..3 {
            b.push(7, i);
        }
        b.push(9, 100);
        b.push(7, 3); // 5th item: outside the window
        assert_eq!(b.drain_batch(), vec![(7, 0), (7, 1), (7, 2)]);
        assert_eq!(b.len(), 2);
        // Skipped item kept its position ahead of the out-of-window one.
        assert_eq!(b.drain_batch(), vec![(9, 100)]);
        assert_eq!(b.drain_batch(), vec![(7, 3)]);
    }

    #[test]
    fn scan_stops_at_max_batch_without_disturbing_tail() {
        // max_batch 2 with a large window: the scan must stop after two
        // matches, leaving the rest untouched and in order.
        let mut b = Batcher::with_window(2, 64);
        for (bucket, id) in [(5, 0), (5, 1), (6, 2), (5, 3)] {
            b.push(bucket, id);
        }
        assert_eq!(b.drain_batch(), vec![(5, 0), (5, 1)]);
        assert_eq!(b.drain_batch(), vec![(6, 2)]);
        assert_eq!(b.drain_batch(), vec![(5, 3)]);
    }

    #[test]
    fn set_max_batch_takes_effect_next_drain() {
        let mut b = Batcher::new(1);
        for i in 0..4 {
            b.push(3, i);
        }
        assert_eq!(b.drain_batch().len(), 1);
        b.set_max_batch(3);
        assert_eq!(b.max_batch(), 3);
        assert_eq!(b.drain_batch().len(), 3);
        // Clamped at 1.
        b.set_max_batch(0);
        assert_eq!(b.max_batch(), 1);
    }

    /// Fairness bounds (satellite): under adversarial bucket
    /// interleavings, (a) every item drains within `position + 1`
    /// drains of the batcher (each drain removes the queue head), and
    /// (b) no item is overtaken by more than `(w-1)(w-2)/2` items that
    /// arrived after it — the windowed scan's overtaking bound, flat in
    /// the backlog depth (the pre-window full-queue scan had no such
    /// bound).
    #[test]
    fn prop_fairness_bounded_wait_and_overtaking() {
        Cases::default().check("batcher_fairness", |rng| {
            let window = 1 + (rng.next_u64() % 16) as usize;
            let max_batch = 1 + (rng.next_u64() % 8) as usize;
            let n = 40 + (rng.next_u64() % 60) as usize;
            let buckets = 1 + (rng.next_u64() % 4) as usize;
            let overtake_bound =
                window.saturating_sub(1) * window.saturating_sub(2) / 2;
            let mut b = Batcher::with_window(max_batch, window);
            for id in 0..n {
                b.push((rng.next_u64() as usize) % buckets, id);
            }
            // (id, drain index it came out in), in completion order.
            let mut drained: Vec<(usize, usize)> = Vec::new();
            let mut drains = 0usize;
            while !b.is_empty() {
                let batch = b.drain_batch();
                if batch.is_empty() {
                    return Err("drain made no progress on non-empty queue".into());
                }
                drains += 1;
                for (_, id) in batch {
                    drained.push((id, drains));
                }
            }
            if drained.len() != n {
                return Err(format!("lost items: {} of {}", drained.len(), n));
            }
            for (pos, &(id, drain_idx)) in drained.iter().enumerate() {
                // (a) bounded waiting: arrival ids are 0..n in push
                // order, so `id` IS the initial queue position.
                if drain_idx > id + 1 {
                    return Err(format!(
                        "item {id} waited {drain_idx} drains > position bound {} \
                         (window={window}, max_batch={max_batch})",
                        id + 1
                    ));
                }
                // (b) bounded overtaking.
                let overtakers =
                    drained[..pos].iter().filter(|&&(other, _)| other > id).count();
                if overtakers > overtake_bound {
                    return Err(format!(
                        "item {id} overtaken by {overtakers} > bound {overtake_bound} \
                         (window={window}, max_batch={max_batch}, n={n}, \
                         buckets={buckets})"
                    ));
                }
            }
            Ok(())
        });
    }

    fn test_cfg() -> AdaptiveBatch {
        AdaptiveBatch {
            min_batch: 1,
            max_batch: 16,
            grow_step: 2,
            shrink_factor: 0.5,
            p99_target_us: 10_000,
            grow_headroom: 0.8,
            depth_threshold: 8,
            observe_every: 64,
            cooldown_obs: 2,
        }
    }

    #[test]
    fn controller_grows_under_deep_queue_and_settles_at_ceiling() {
        let mut c = AimdBatchController::new(&test_cfg());
        assert_eq!(c.current(), 1);
        for _ in 0..20 {
            c.observe(100, Some(1_000)); // deep queue, fast p99
        }
        assert_eq!(c.current(), 16, "reaches the ceiling");
        let grows = c.grows();
        c.observe(100, Some(1_000));
        assert_eq!(c.current(), 16, "settles: no growth past the ceiling");
        assert_eq!(c.grows(), grows);
    }

    #[test]
    fn controller_shrinks_on_p99_breach_with_cooldown() {
        let mut c = AimdBatchController::new(&test_cfg());
        for _ in 0..20 {
            c.observe(100, Some(1_000));
        }
        assert_eq!(c.current(), 16);
        // Breach: multiplicative shrink...
        assert_eq!(c.observe(100, Some(50_000)), 8);
        assert_eq!(c.shrinks(), 1);
        // ...but a second breach inside the cooldown must NOT shrink
        // again (one bad window, one cut).
        assert_eq!(c.observe(100, Some(50_000)), 8);
        assert_eq!(c.shrinks(), 1);
        // After the cooldown expires a persistent breach cuts again,
        // bottoming out at min_batch.
        for _ in 0..20 {
            c.observe(100, Some(50_000));
        }
        assert_eq!(c.current(), 1);
    }

    #[test]
    fn controller_holds_in_deadband_no_oscillation() {
        let mut c = AimdBatchController::new(&test_cfg());
        for _ in 0..6 {
            c.observe(100, Some(1_000));
        }
        let settled = c.current();
        assert!(settled > 1);
        // p99 between headroom (8 ms) and target (10 ms), queue still
        // deep: the deadband holds the batch size steady — no
        // grow/shrink churn around the target.
        let (g, s) = (c.grows(), c.shrinks());
        for _ in 0..50 {
            assert_eq!(c.observe(100, Some(9_000)), settled);
        }
        assert_eq!((c.grows(), c.shrinks()), (g, s));
    }

    #[test]
    fn controller_holds_on_shallow_queue() {
        let mut c = AimdBatchController::new(&test_cfg());
        // Shallow queue: no reason to batch deeper, even with fast p99.
        for _ in 0..10 {
            assert_eq!(c.observe(2, Some(100)), 1);
        }
        assert_eq!(c.grows(), 0);
    }
}

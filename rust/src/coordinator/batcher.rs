//! Device-side batching: drain the device queue in grid-bucket groups.
//!
//! The device executes one fixed-shape executable per event, so the win
//! from batching is not kernel fusion but *locality*: draining a run of
//! same-bucket events keeps one compiled executable hot and amortises
//! queue synchronisation. The batcher reorders the pending window by
//! bucket (bounded, so no starvation) — the standard continuous-batching
//! trick adapted to shape-bucketed AOT executables.

use std::collections::VecDeque;

/// Generic bucket-grouping batcher over items with a shape key.
#[derive(Debug)]
pub struct Batcher<T> {
    pending: VecDeque<(usize, T)>,
    max_batch: usize,
    formed: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize) -> Self {
        Batcher { pending: VecDeque::new(), max_batch: max_batch.max(1), formed: 0 }
    }

    pub fn push(&mut self, bucket: usize, item: T) {
        self.pending.push_back((bucket, item));
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Bucket of the batch the next [`Self::drain_batch`] call would
    /// return. The device worker peeks this to warm the bucket's
    /// executable (and its staging plan) before the batch lands.
    pub fn next_bucket(&self) -> Option<usize> {
        self.pending.front().map(|&(b, _)| b)
    }

    /// Non-empty batches drained so far.
    pub fn batches_formed(&self) -> usize {
        self.formed
    }

    /// Drain the next batch: items sharing the bucket of the oldest
    /// pending item, up to `max_batch`, preserving arrival order within
    /// the bucket. Items of other buckets keep their positions.
    pub fn drain_batch(&mut self) -> Vec<(usize, T)> {
        let Some(&(lead, _)) = self.pending.front() else {
            return Vec::new();
        };
        let mut batch = Vec::new();
        let mut rest = VecDeque::with_capacity(self.pending.len());
        while let Some((b, item)) = self.pending.pop_front() {
            if b == lead && batch.len() < self.max_batch {
                batch.push((b, item));
            } else {
                rest.push_back((b, item));
            }
        }
        self.pending = rest;
        if !batch.is_empty() {
            self.formed += 1;
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_by_leading_bucket() {
        let mut b = Batcher::new(8);
        for (bucket, id) in [(64, 0), (128, 1), (64, 2), (64, 3), (128, 4)] {
            b.push(bucket, id);
        }
        let batch = b.drain_batch();
        assert_eq!(batch, vec![(64, 0), (64, 2), (64, 3)]);
        let batch = b.drain_batch();
        assert_eq!(batch, vec![(128, 1), (128, 4)]);
        assert!(b.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.push(32, i);
        }
        assert_eq!(b.drain_batch().len(), 2);
        assert_eq!(b.drain_batch().len(), 2);
        assert_eq!(b.drain_batch().len(), 1);
    }

    #[test]
    fn no_starvation_across_buckets() {
        // Bucket 1 arrives first; a flood of bucket 2 must not jump it.
        let mut b = Batcher::new(100);
        b.push(1, 0);
        for i in 1..50 {
            b.push(2, i);
        }
        let first = b.drain_batch();
        assert_eq!(first, vec![(1, 0)]);
        assert_eq!(b.drain_batch().len(), 49);
    }

    #[test]
    fn empty_drain() {
        let mut b: Batcher<u32> = Batcher::new(4);
        assert!(b.drain_batch().is_empty());
        assert_eq!(b.batches_formed(), 0);
    }

    #[test]
    fn peeks_next_bucket_and_counts_batches() {
        let mut b = Batcher::new(8);
        assert_eq!(b.next_bucket(), None);
        b.push(64, 0);
        b.push(128, 1);
        assert_eq!(b.next_bucket(), Some(64));
        b.drain_batch();
        assert_eq!(b.next_bucket(), Some(128));
        b.drain_batch();
        assert_eq!(b.next_bucket(), None);
        assert_eq!(b.batches_formed(), 2);
    }
}

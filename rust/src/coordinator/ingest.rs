//! Multi-process ingestion: framed events over Unix sockets into the
//! reconstruction pipeline (DESIGN.md §11).
//!
//! Topology: N **ingest** processes each run the seeded
//! [`EventGenerator`], frame their stripe of the event stream with
//! [`encode_frame`], and stream the frames over a socket. One
//! **reconstruction** process accepts the N streams, reassembles frames
//! through a bounded [`ReassemblyRing`] (the backpressure edge: a full
//! ring stalls the reader threads, the kernel socket buffers fill, the
//! ingest writers block), and worker threads attach each frame
//! **in place** — calibration writes into the received buffer through
//! [`FrameSourceMut`]; the sensor planes are never copied after the
//! socket read. Reconstruction output then feeds the same pooled
//! staging path the in-process pipeline uses.
//!
//! Striping: every ingest process runs the *same* seeded generator and
//! sends only the events with `event_id % shards == index`. The union
//! over shards is exactly the in-process stream — which is what makes
//! the golden-equivalence check ([`golden_compare`]) exact: the
//! socket-fed run must reproduce the in-process run bit for bit.
//!
//! Poisoned frames never panic the receiver: decode failures are typed
//! [`WireError`]s counted as `poisoned` (identity unknown) and
//! attach/processing failures quarantine the frame id — the same
//! report-never-drop contract as the PR 9 fault path.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::pipeline::{process_host_staged, StagePool, StagedParticles};
use crate::coordinator::router::QueueGauge;
use crate::edm::generator::{EventConfig, EventGenerator};
use crate::edm::particle::ParticleCollection;
use crate::edm::reco;
use crate::edm::sensor::{SensorCollection, SensorProps, SensorView, SensorViewMut};
use crate::edm::calib;
use crate::marionette::collection::InfoOf;
use crate::marionette::layout::{AoS, AoSoA, Layout, SoABlob, SoAVec};
use crate::marionette::trace::LayoutChoice;
use crate::marionette::wire::{encode_frame, AlignedBytes, Frame, WireError};
use crate::runtime::transport::{write_frame, FrameReader, ReassemblyRing};

// ---------------------------------------------------------------------
// Ingest (sender) side.
// ---------------------------------------------------------------------

/// Parameters of one ingest process.
#[derive(Clone, Debug)]
pub struct IngestOpts {
    pub event: EventConfig,
    /// Total events in the stream (across all shards).
    pub n_events: usize,
    pub seed: u64,
    /// Number of ingest processes sharing the stream.
    pub shards: usize,
    /// This process's stripe: sends events with
    /// `event_id % shards == index`.
    pub index: usize,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct IngestStats {
    pub frames: usize,
    pub bytes: usize,
}

/// Generate and frame this shard's stripe of the event stream onto a
/// byte sink. One reused staging collection; one frame per event; no
/// per-element serialization beyond the dense plane writes.
pub fn run_ingest<W: Write + ?Sized>(w: &mut W, opts: &IngestOpts) -> Result<IngestStats> {
    let shards = opts.shards.max(1);
    ensure!(opts.index < shards, "ingest index {} out of {} shards", opts.index, shards);
    let mut gen = EventGenerator::new(opts.event.clone(), opts.seed);
    let mut sensors = SensorCollection::<SoAVec>::new();
    let mut stats = IngestStats::default();
    for _ in 0..opts.n_events {
        let ev = gen.generate();
        if ev.event_id % shards as u64 != opts.index as u64 {
            continue;
        }
        ev.fill_collection(&mut sensors);
        let frame = encode_frame(&sensors, ev.event_id);
        write_frame(w, frame.as_slice())
            .with_context(|| format!("sending frame {}", ev.event_id))?;
        stats.frames += 1;
        stats.bytes += frame.len();
    }
    Ok(stats)
}

// ---------------------------------------------------------------------
// Reconstruction (receiver) side.
// ---------------------------------------------------------------------

/// Per-frame reconstruction outcome (the wire twin of the pipeline's
/// `EventResult`).
#[derive(Clone, Copy, Debug)]
pub struct FrameResult {
    pub event_id: u64,
    pub n_particles: usize,
    pub total_energy: f64,
    /// Bytes booked by the particle staging transfer — the *only*
    /// copied payload on the receive path (sensor planes attach in
    /// place), which is what the zero-copy test pins.
    pub staged_bytes: usize,
}

/// Receiver parameters.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Reassembly ring capacity (frames).
    pub ring_depth: usize,
    /// Reconstruction worker threads.
    pub workers: usize,
    /// Staging layout override — the autotuner's [`LayoutChoice`]
    /// routed through the live staging path (`None` = pooled AoS).
    pub staging: Option<LayoutChoice>,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts { ring_depth: 64, workers: 2, staging: None }
    }
}

/// Whole-run receiver outcome.
#[derive(Debug, Default)]
pub struct ReconstructionReport {
    /// Per-event results, sorted by event id.
    pub results: Vec<FrameResult>,
    /// Frame ids that decoded but failed attach/processing.
    pub quarantined: Vec<u64>,
    /// Frames that failed decode (identity unknown) or streams that
    /// died mid-frame.
    pub poisoned: usize,
    /// Frames received intact.
    pub frames: usize,
    /// Total frame bytes read off the sockets.
    pub bytes: usize,
    /// Peak reassembly-ring depth observed (backpressure telemetry).
    pub peak_ring_depth: usize,
    pub wall: Duration,
}

impl ReconstructionReport {
    pub fn events_per_sec(&self) -> f64 {
        if self.wall.as_secs_f64() <= 0.0 {
            return 0.0;
        }
        self.results.len() as f64 / self.wall.as_secs_f64()
    }

    pub fn bytes_per_sec(&self) -> f64 {
        if self.wall.as_secs_f64() <= 0.0 {
            return 0.0;
        }
        self.bytes as f64 / self.wall.as_secs_f64()
    }
}

fn attach_to_wire(e: crate::marionette::interface::AttachError) -> WireError {
    WireError::Malformed { what: format!("attach: {e:?}") }
}

fn process_with_staged<L: Layout>(
    frame: &mut Frame,
    staged: &mut ParticleCollection<L>,
) -> Result<FrameResult, WireError> {
    let event_id = frame.frame_id();
    let schema = SensorProps::schema();
    let mut src = frame.source_mut(&schema)?;
    {
        // Calibrate in place: energy/noise/sig land in the received
        // buffer's own planes.
        let mut v = SensorViewMut::attach(&mut src).map_err(attach_to_wire)?;
        calib::calibrate_view(&mut v);
    }
    let particles = {
        let v = SensorView::attach(&src).map_err(attach_to_wire)?;
        reco::reconstruct(&v)
    };
    let pc = reco::into_collection::<SoAVec>(event_id, &particles);
    let stats = pc.stage_into(staged);
    let back = reco::fill_back_aos(staged);
    let energy = back.data.iter().map(|p| p.energy as f64).sum();
    Ok(FrameResult {
        event_id,
        n_particles: back.data.len(),
        total_energy: energy,
        staged_bytes: stats.bytes,
    })
}

fn process_fresh<L: Layout>(frame: &mut Frame) -> Result<FrameResult, WireError>
where
    InfoOf<L>: Default,
{
    let mut staged = ParticleCollection::<L>::new();
    process_with_staged(frame, &mut staged)
}

/// Reconstruct one received frame: schema-checked zero-copy attach,
/// in-place calibration, reconstruction, particle staging through the
/// pooled path (or the autotuner-selected layout).
pub fn process_frame(
    frame: &mut Frame,
    staging: Option<LayoutChoice>,
    pool: &StagePool,
) -> Result<FrameResult, WireError> {
    match staging {
        None => {
            let mut staged = pool.checkout();
            let s: &mut StagedParticles = &mut staged;
            process_with_staged(frame, s)
        }
        Some(LayoutChoice::AoS) => process_fresh::<AoS>(frame),
        Some(LayoutChoice::SoAVec) => process_fresh::<SoAVec>(frame),
        Some(LayoutChoice::SoABlob) => process_fresh::<SoABlob>(frame),
        Some(LayoutChoice::AoSoA8) => process_fresh::<AoSoA<8>>(frame),
    }
}

/// Drive reconstruction over N frame streams: one reader thread per
/// stream feeding the bounded ring, `opts.workers` processing threads
/// draining it. Returns when every stream has closed and the ring has
/// drained.
pub fn run_reconstruction<R: Read + Send>(
    streams: Vec<R>,
    opts: &ServeOpts,
) -> Result<ReconstructionReport> {
    let ring = ReassemblyRing::<AlignedBytes>::new(opts.ring_depth);
    let gauge = QueueGauge::default();
    let poisoned = AtomicUsize::new(0);
    let bytes = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let results: Mutex<Vec<FrameResult>> = Mutex::new(Vec::new());
    let quarantined: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    let pool = StagePool::shared();
    let staging = opts.staging;
    let start = Instant::now();

    std::thread::scope(|s| {
        let ring = &ring;
        let gauge = &gauge;
        let poisoned = &poisoned;
        let bytes = &bytes;
        let peak = &peak;
        let results = &results;
        let quarantined = &quarantined;
        let pool = &pool;

        let readers: Vec<_> = streams
            .into_iter()
            .map(|stream| {
                s.spawn(move || {
                    let mut rd = FrameReader::new(stream);
                    loop {
                        match rd.read_frame() {
                            Ok(Some(buf)) => {
                                gauge.inc();
                                peak.fetch_max(gauge.depth(), Relaxed);
                                if !ring.push(buf) {
                                    break;
                                }
                            }
                            Ok(None) => break,
                            Err(_) => {
                                // Mid-frame death or garbage header: the
                                // stream cannot be resynced; count and stop.
                                poisoned.fetch_add(1, Relaxed);
                                break;
                            }
                        }
                    }
                    bytes.fetch_add(rd.bytes_read(), Relaxed);
                })
            })
            .collect();

        let workers: Vec<_> = (0..opts.workers.max(1))
            .map(|_| {
                s.spawn(move || {
                    while let Some(buf) = ring.pop() {
                        gauge.dec();
                        match Frame::decode(buf) {
                            Ok(mut frame) => {
                                match process_frame(&mut frame, staging, pool) {
                                    Ok(r) => results.lock().unwrap().push(r),
                                    Err(_) => {
                                        quarantined.lock().unwrap().push(frame.frame_id());
                                    }
                                }
                            }
                            Err(_) => {
                                poisoned.fetch_add(1, Relaxed);
                            }
                        }
                    }
                })
            })
            .collect();

        for r in readers {
            let _ = r.join();
        }
        ring.close();
        for w in workers {
            let _ = w.join();
        }
    });

    let mut results = results.into_inner().unwrap();
    results.sort_unstable_by_key(|r| r.event_id);
    let mut quarantined = quarantined.into_inner().unwrap();
    quarantined.sort_unstable();
    let frames = results.len() + quarantined.len();
    Ok(ReconstructionReport {
        results,
        quarantined,
        poisoned: poisoned.into_inner(),
        frames,
        bytes: bytes.into_inner(),
        peak_ring_depth: peak.into_inner(),
        wall: start.elapsed(),
    })
}

// ---------------------------------------------------------------------
// Accounting and golden equivalence.
// ---------------------------------------------------------------------

/// Exactly-once accounting: every event id in `0..expected` appears in
/// exactly one of {results, quarantined}, and nothing was poisoned.
pub fn verify_exactly_once(report: &ReconstructionReport, expected: usize) -> Result<()> {
    ensure!(report.poisoned == 0, "{} poisoned frames", report.poisoned);
    let mut ids: Vec<u64> = report
        .results
        .iter()
        .map(|r| r.event_id)
        .chain(report.quarantined.iter().copied())
        .collect();
    ids.sort_unstable();
    ensure!(
        ids.len() == expected,
        "expected {expected} events, accounted {} ({} completed, {} quarantined)",
        ids.len(),
        report.results.len(),
        report.quarantined.len()
    );
    for (i, id) in ids.iter().enumerate() {
        ensure!(*id == i as u64, "event id {i} missing or duplicated (saw {id})");
    }
    Ok(())
}

/// Bit-identical golden equivalence versus the in-process generator:
/// re-run the same seeded stream through [`process_host_staged`] and
/// require exact agreement — particle counts equal and total energies
/// equal to the last bit (both paths execute the identical kernels in
/// the identical order).
pub fn golden_compare(
    report: &ReconstructionReport,
    event: &EventConfig,
    n_events: usize,
    seed: u64,
) -> Result<()> {
    verify_exactly_once(report, n_events)?;
    ensure!(
        report.quarantined.is_empty(),
        "clean run quarantined {} frames",
        report.quarantined.len()
    );
    let by_id: HashMap<u64, &FrameResult> =
        report.results.iter().map(|r| (r.event_id, r)).collect();
    let mut gen = EventGenerator::new(event.clone(), seed);
    let mut staged = ParticleCollection::<AoS>::new();
    for _ in 0..n_events {
        let ev = gen.generate();
        let (n, energy, _bytes) = process_host_staged(&ev, &mut staged);
        let got = by_id
            .get(&ev.event_id)
            .with_context(|| format!("event {} missing from wire run", ev.event_id))?;
        ensure!(
            got.n_particles == n,
            "event {}: {} particles over the wire, {} in-process",
            ev.event_id,
            got.n_particles,
            n
        );
        ensure!(
            got.total_energy.to_bits() == energy.to_bits(),
            "event {}: energy {} over the wire != {} in-process (not bit-identical)",
            ev.event_id,
            got.total_energy,
            energy
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------
// In-process harness (benches, tests) and Unix-socket endpoints (CLI).
// ---------------------------------------------------------------------

/// Run the full topology in-process over socketpairs: `senders` ingest
/// threads stripe the same seeded stream, one reconstruction drives
/// them. This is the bench/test harness; the CLI pair exercises the
/// identical code across real process boundaries.
pub fn run_socketpair_ingest(
    event: &EventConfig,
    n_events: usize,
    seed: u64,
    senders: usize,
    opts: &ServeOpts,
) -> Result<ReconstructionReport> {
    use std::os::unix::net::UnixStream;
    let senders = senders.max(1);
    let mut writers = Vec::new();
    let mut readers = Vec::new();
    for _ in 0..senders {
        let (a, b) = UnixStream::pair().context("socketpair")?;
        writers.push(a);
        readers.push(b);
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = writers
            .into_iter()
            .enumerate()
            .map(|(index, mut w)| {
                let ingest = IngestOpts {
                    event: event.clone(),
                    n_events,
                    seed,
                    shards: senders,
                    index,
                };
                s.spawn(move || run_ingest(&mut w, &ingest))
            })
            .collect();
        let report = run_reconstruction(readers, opts)?;
        for h in handles {
            h.join().expect("ingest thread panicked")?;
        }
        Ok(report)
    })
}

/// Bind a Unix socket, accept `procs` ingest connections, reconstruct.
pub fn serve_unix(path: &Path, procs: usize, opts: &ServeOpts) -> Result<ReconstructionReport> {
    use std::os::unix::net::UnixListener;
    let _ = std::fs::remove_file(path);
    let listener =
        UnixListener::bind(path).with_context(|| format!("bind {}", path.display()))?;
    let mut streams = Vec::new();
    for _ in 0..procs.max(1) {
        let (stream, _) = listener.accept().context("accept")?;
        streams.push(stream);
    }
    let report = run_reconstruction(streams, opts);
    let _ = std::fs::remove_file(path);
    report
}

/// Connect to a serve socket, retrying until `timeout` (the server may
/// still be binding when the ingest process starts).
pub fn connect_unix(path: &Path, timeout: Duration) -> Result<std::os::unix::net::UnixStream> {
    use std::os::unix::net::UnixStream;
    let start = Instant::now();
    loop {
        match UnixStream::connect(path) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if start.elapsed() >= timeout {
                    bail!("connect {} timed out: {e}", path.display());
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn socketpair_run_is_golden_and_exactly_once() {
        let event = EventConfig::grid(24, 24, 3);
        let n = 16;
        let seed = 0xFEED;
        let report =
            run_socketpair_ingest(&event, n, seed, 2, &ServeOpts::default()).unwrap();
        assert_eq!(report.results.len(), n);
        assert!(report.bytes > 0);
        golden_compare(&report, &event, n, seed).unwrap();
    }

    #[test]
    fn selected_staging_layout_is_golden_too() {
        let event = EventConfig::grid(16, 16, 2);
        let n = 8;
        let seed = 0xBEEF;
        for staging in [
            Some(LayoutChoice::SoAVec),
            Some(LayoutChoice::SoABlob),
            Some(LayoutChoice::AoSoA8),
        ] {
            let opts = ServeOpts { staging, ..ServeOpts::default() };
            let report = run_socketpair_ingest(&event, n, seed, 1, &opts).unwrap();
            golden_compare(&report, &event, n, seed).unwrap();
        }
    }

    #[test]
    fn poisoned_frame_is_counted_never_dropped_silently() {
        use std::os::unix::net::UnixStream;
        let event = EventConfig::grid(8, 8, 1);
        let mut gen = EventGenerator::new(event.clone(), 1);
        let ev = gen.generate();
        let mut sensors = SensorCollection::<SoAVec>::new();
        ev.fill_collection(&mut sensors);
        let good = encode_frame(&sensors, ev.event_id);
        let mut bad = good.clone();
        let n = bad.len();
        bad.as_mut_slice()[n - 1] ^= 0x01; // CRC breaks

        let (mut a, b) = UnixStream::pair().unwrap();
        let t = std::thread::spawn(move || {
            use std::io::Write;
            a.write_all(bad.as_slice()).unwrap();
            a.write_all(good.as_slice()).unwrap();
        });
        let report = run_reconstruction(vec![b], &ServeOpts::default()).unwrap();
        t.join().unwrap();
        assert_eq!(report.poisoned, 1, "corrupt frame must be counted");
        assert_eq!(report.results.len(), 1, "intact frame still processes");
    }
}

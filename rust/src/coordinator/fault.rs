//! The chaos harness's control plane: a seeded, schedule-driven
//! [`FaultPlan`] plus the per-run [`FaultState`] that arms the
//! injectors and collects what they fired (DESIGN.md §10).
//!
//! Every trigger in the plan is a *count* (the Nth allocation, the Kth
//! device dequeue, every Nth plan execution), never a time or a race:
//! with a fixed work sequence the set of fired faults is a pure
//! function of the plan, which is what lets `tests/chaos.rs` assert
//! that two same-seed runs produce bit-identical fault counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::marionette::memory::FaultCell;
use crate::marionette::transfer;
use crate::runtime::FaultFuse;
use crate::util::rng::Rng;

/// A deterministic fault schedule for one pipeline run. Inert fields
/// (`None` / `false`) inject nothing; [`FaultPlan::new`] is fully
/// inert, [`FaultPlan::from_seed`] derives a randomized-but-seeded
/// schedule for property tests.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Seed recorded for provenance (and used by [`Self::from_seed`]).
    pub seed: u64,
    /// Kill the device worker (panic in the worker thread) when the
    /// `K`-th event is dequeued from the device queue, counted across
    /// all device workers and respawns (1-based; fires once).
    pub kill_device_at: Option<u64>,
    /// `FaultyEngine`: every `N`-th device event returns an injected
    /// "short planes" `Err` (recovered by the worker's host fallback).
    pub engine_fail_every: Option<u64>,
    /// `FaultyContext`: every `N`-th allocation in the chaos staging
    /// context panics mid-`stage_into`.
    pub alloc_fail_every: Option<u64>,
    /// Transfer rung: every `N`-th `TransferPlan` execution panics.
    /// NOTE: this hook is process-global — callers must not run other
    /// transfer work concurrently in the same process while it is
    /// armed (`tests/chaos.rs` serialises on a shared lock).
    pub transfer_fail_every: Option<u64>,
    /// Per-event retries before the event is quarantined.
    pub retry_budget: u32,
    /// Exponential-backoff base between retries (doubles per attempt,
    /// capped at [`FaultPlan::BACKOFF_CAP_MS`]).
    pub backoff_base_ms: u64,
    /// Test-only knob: let a worker panic escape supervision so the
    /// pipeline's join path must report it as an `Err` (the
    /// `coordinator/pipeline.rs` shutdown regression test).
    pub worker_abort: bool,
}

impl FaultPlan {
    pub const BACKOFF_CAP_MS: u64 = 16;

    /// An inert plan: nothing fires until fields are set.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            kill_device_at: None,
            engine_fail_every: None,
            alloc_fail_every: None,
            transfer_fail_every: None,
            retry_budget: 3,
            backoff_base_ms: 1,
            worker_abort: false,
        }
    }

    /// A randomized schedule, deterministic in `seed`: most runs kill a
    /// worker somewhere early, roughly half also fail engine events,
    /// allocations and/or transfers on small periods, so recovery,
    /// retry and quarantine paths all get exercised across seeds.
    pub fn from_seed(seed: u64) -> FaultPlan {
        let mut rng = Rng::seed_from_u64(seed ^ 0xFA01_71A5);
        let mut plan = FaultPlan::new(seed);
        if rng.bool(0.7) {
            plan.kill_device_at = Some(rng.range_u64(1, 17));
        }
        if rng.bool(0.5) {
            plan.engine_fail_every = Some(rng.range_u64(2, 9));
        }
        if rng.bool(0.6) {
            plan.alloc_fail_every = Some(rng.range_u64(5, 14));
        }
        if rng.bool(0.4) {
            plan.transfer_fail_every = Some(rng.range_u64(9, 25));
        }
        plan.retry_budget = 2 + (rng.next_u32() % 2);
        plan
    }

    pub fn kill_device_at(mut self, k: u64) -> FaultPlan {
        self.kill_device_at = Some(k);
        self
    }

    pub fn engine_fail_every(mut self, n: u64) -> FaultPlan {
        self.engine_fail_every = Some(n);
        self
    }

    pub fn alloc_fail_every(mut self, n: u64) -> FaultPlan {
        self.alloc_fail_every = Some(n);
        self
    }

    pub fn transfer_fail_every(mut self, n: u64) -> FaultPlan {
        self.transfer_fail_every = Some(n);
        self
    }

    pub fn retry_budget(mut self, n: u32) -> FaultPlan {
        self.retry_budget = n;
        self
    }

    pub fn worker_abort(mut self, yes: bool) -> FaultPlan {
        self.worker_abort = yes;
        self
    }

    /// Backoff before retry `attempt` (1-based), in milliseconds.
    pub fn backoff_ms(&self, attempt: u32) -> u64 {
        let shift = attempt.saturating_sub(1).min(10);
        (self.backoff_base_ms << shift).min(Self::BACKOFF_CAP_MS)
    }

    /// True when any injector is armed (worker_abort alone also counts:
    /// it changes supervision behaviour).
    pub fn any_armed(&self) -> bool {
        self.kill_device_at.is_some()
            || self.engine_fail_every.is_some()
            || self.alloc_fail_every.is_some()
            || self.transfer_fail_every.is_some()
            || self.worker_abort
    }

    /// True when *host-side* event processing can be hit by an injector
    /// and must therefore run the guarded retry/quarantine path. A plan
    /// that only kills device workers leaves the host fast path alone.
    pub fn guard_host(&self) -> bool {
        self.alloc_fail_every.is_some() || self.transfer_fail_every.is_some()
    }
}

/// Per-run armed state: owns the shared triggers, the device-dequeue
/// kill counter and the quarantine ledger. Created by `run_pipeline`
/// when `PipelineConfig::fault` is set; dropped (and the process-global
/// transfer hook disarmed) when the run ends.
pub struct FaultState {
    pub plan: FaultPlan,
    /// Allocation-fault trigger, shared into every chaos staging
    /// collection's `FaultyInfo`.
    pub alloc_cell: Arc<FaultCell>,
    /// Engine-fault trigger, shared across device workers and respawns.
    pub engine_fuse: Arc<FaultFuse>,
    /// Global transfer-fault total at arm time (the per-run count is
    /// the difference against it).
    transfer_base: u64,
    /// Device-queue dequeues so far (drives `kill_device_at`).
    dev_dequeued: AtomicU64,
    kill_injected: AtomicU64,
    /// Events given up on after the retry budget: reported, never
    /// silently dropped.
    quarantined: Mutex<Vec<u64>>,
}

impl FaultState {
    /// Arm every injector the plan asks for. The transfer hook is
    /// process-global; [`FaultState::disarm`] must be called when the
    /// run ends (run_pipeline does, on every exit path it returns from).
    pub fn arm(plan: FaultPlan) -> Arc<FaultState> {
        let alloc_cell = match plan.alloc_fail_every {
            Some(n) => FaultCell::armed_every(n),
            None => FaultCell::disarmed(),
        };
        let engine_fuse = Arc::new(FaultFuse::default());
        if let Some(n) = plan.engine_fail_every {
            engine_fuse.arm(n, false);
        }
        // Only touch the process-global transfer hook when this plan
        // actually uses it: clean runs (inert plans) must not stomp a
        // hook armed by a concurrent chaos run elsewhere in the process.
        if let Some(n) = plan.transfer_fail_every {
            transfer::arm_transfer_fault(n);
        }
        let transfer_base = transfer::transfer_faults_injected();
        Arc::new(FaultState {
            plan,
            alloc_cell,
            engine_fuse,
            transfer_base,
            dev_dequeued: AtomicU64::new(0),
            kill_injected: AtomicU64::new(0),
            quarantined: Mutex::new(Vec::new()),
        })
    }

    /// Disarm the process-global hooks this run armed.
    pub fn disarm(&self) {
        if self.plan.transfer_fail_every.is_some() {
            transfer::disarm_transfer_fault();
        }
        self.alloc_cell.disarm();
        self.engine_fuse.disarm();
    }

    /// Book one device-queue dequeue; panics (killing the worker) when
    /// the plan's `kill_device_at` count is reached. Fires exactly once
    /// per run: the respawned worker continues the same counter.
    pub fn on_device_dequeue(&self) {
        let n = self.dev_dequeued.fetch_add(1, Ordering::Relaxed) + 1;
        if self.plan.kill_device_at == Some(n) {
            self.kill_injected.fetch_add(1, Ordering::Relaxed);
            panic!("injected device-worker kill at dequeue #{n}");
        }
    }

    /// Record an event as poison-quarantined.
    pub fn quarantine(&self, event_id: u64) {
        self.quarantined.lock().unwrap().push(event_id);
    }

    /// Drain the quarantine ledger (sorted by event id).
    pub fn take_quarantined(&self) -> Vec<u64> {
        let mut q = std::mem::take(&mut *self.quarantined.lock().unwrap());
        q.sort_unstable();
        q
    }

    /// Total faults this run injected across all four layers.
    pub fn injected_total(&self) -> u64 {
        self.alloc_cell.injected()
            + self.engine_fuse.injected()
            + self.kill_injected.load(Ordering::Relaxed)
            + (transfer::transfer_faults_injected() - self.transfer_base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_seed_is_deterministic_and_varied() {
        let a = FaultPlan::from_seed(7);
        let b = FaultPlan::from_seed(7);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        // Across a seed range, every injector fires on some seed and
        // stays off on another — the property test needs the mix.
        let plans: Vec<FaultPlan> = (0..64).map(FaultPlan::from_seed).collect();
        assert!(plans.iter().any(|p| p.kill_device_at.is_some()));
        assert!(plans.iter().any(|p| p.kill_device_at.is_none()));
        assert!(plans.iter().any(|p| p.alloc_fail_every.is_some()));
        assert!(plans.iter().any(|p| p.transfer_fail_every.is_some()));
        assert!(plans.iter().all(|p| !p.worker_abort));
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let plan = FaultPlan::new(0);
        assert_eq!(plan.backoff_ms(1), 1);
        assert_eq!(plan.backoff_ms(2), 2);
        assert_eq!(plan.backoff_ms(3), 4);
        assert_eq!(plan.backoff_ms(30), FaultPlan::BACKOFF_CAP_MS);
    }

    #[test]
    fn kill_fires_exactly_once_at_k() {
        let state = FaultState::arm(FaultPlan::new(1).kill_device_at(3));
        state.on_device_dequeue();
        state.on_device_dequeue();
        let killed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            state.on_device_dequeue()
        }));
        assert!(killed.is_err(), "third dequeue must kill");
        // Subsequent dequeues (the respawned worker) pass.
        state.on_device_dequeue();
        state.on_device_dequeue();
        assert_eq!(state.injected_total(), 1);
        state.disarm();
    }
}

//! The pipeline: source → router → {host pool | device worker} → collector.
//!
//! * Source: synthetic event stream (`edm::generator`), routed as it is
//!   produced.
//! * Host workers: the CPU path — fill a Marionette SoA collection,
//!   calibrate, reconstruct, stage the particle collection into the
//!   handwritten-AoS output form through a cached [`TransferPlan`], fill
//!   back (exactly the Figure 1+2 CPU pipeline).
//! * Device worker: one dedicated thread owning a `runtime::Engine`
//!   (PJRT handles are single-threaded); drains its bounded queue
//!   through the bucket [`Batcher`], stages each event through its
//!   pinned staging buffer (DMA-accounted, DESIGN.md §2), runs the fused
//!   `full_event` executable, gathers particles from the returned
//!   planes, fills back.
//! * Collector: aggregates per-event results + metrics.
//!
//! Transfer strategy is **compiled once**: workers warm the staging
//! plans at startup and every per-event copy goes through the fluent
//! `stage_into` sugar — a plan-cache hit that executes into a reused
//! destination collection (no re-derivation of the ladder, no
//! reallocation in steady state). Plan-level byte counters feed
//! [`metrics`](super::metrics). The device path reads its downloaded
//! planes through the borrowed typed `SensorView`
//! (`runtime::devmem::downloaded_planes` + `particles_from_download`;
//! DESIGN.md §6), the same interface description the host path's owned
//! collections use.
//!
//! Staging memory is **pooled** (DESIGN.md §5): workers draw their
//! per-event staging destination from a shared [`StagePool`] — an
//! object pool of warm collections over a recycling
//! [`PoolContext`]`<CountingContext>` byte pool — and check it back in
//! on drop. After warmup every checkout is a hit and no per-event
//! allocation reaches the heap; the pool counters in
//! [`metrics`](super::metrics) (and `tests/pipeline_integration.rs`)
//! pin that steady state.
//!
//! Every queue is a bounded `sync_channel`: a slow stage backpressures
//! the source instead of growing memory.
//!
//! [`TransferPlan`]: crate::marionette::transfer::TransferPlan
//! [`PoolContext`]: crate::marionette::memory::PoolContext

use std::sync::mpsc::{channel, sync_channel};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::edm::generator::{EventGenerator, RawEvent};
use crate::edm::particle::{ParticleCollection, ParticleProps};
use crate::edm::sensor::{SensorCollection, SensorProps, SensorView};
use crate::edm::{calib, reco};
use crate::marionette::layout::{AoS, Layout, SoAVec};
use crate::marionette::memory::{
    CountingContext, CountingInfo, Pool, PoolContext, PoolInfo, PoolSnapshot, StagingContext,
    StagingInfo,
};
use crate::marionette::transfer;
use crate::runtime::Engine;
use crate::util::pool::{ObjectPool, ObjectPoolStats, Recycler};

use super::batcher::Batcher;
use super::config::PipelineConfig;
use super::metrics::{MetricsSnapshot, PipelineMetrics};
use super::router::{QueueGauge, Router};

/// Which path processed an event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    Host,
    Device,
}

/// Per-event outcome.
#[derive(Clone, Debug)]
pub struct EventResult {
    pub event_id: u64,
    pub route: Route,
    pub n_particles: usize,
    pub total_energy: f64,
    pub latency: Duration,
}

/// Whole-run outcome.
#[derive(Debug)]
pub struct PipelineReport {
    pub wall: Duration,
    pub results: Vec<EventResult>,
    pub metrics: MetricsSnapshot,
}

impl PipelineReport {
    pub fn events_per_sec(&self) -> f64 {
        self.results.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn total_particles(&self) -> usize {
        self.results.iter().map(|r| r.n_particles).sum()
    }

    pub fn report(&self) -> String {
        format!(
            "pipeline: {} events in {:?} ({:.1} ev/s), {} particles\n{}",
            self.results.len(),
            self.wall,
            self.events_per_sec(),
            self.total_particles(),
            self.metrics.report()
        )
    }
}

struct Task {
    ev: RawEvent,
    enqueued: Instant,
}

/// Memory context of pooled staging collections: a recycling size-class
/// pool over a counting heap, so the steady-state zero-alloc claim is
/// observable (pool hit/miss counters + inner `live_allocs`).
pub type StageCtx = PoolContext<CountingContext>;

/// The pooled per-event staging destination workers draw and return.
pub type StagedParticles = ParticleCollection<AoS<StageCtx>>;

/// Shared pool of per-event staging destinations: an object pool of
/// warm [`StagedParticles`] collections whose storage comes from one
/// recycling byte pool. Checkouts return on drop (capacity intact), so
/// after warmup neither level touches the heap again.
pub struct StagePool {
    bytes: PoolInfo<CountingContext>,
    collections: Arc<ObjectPool<StagedParticles>>,
}

impl StagePool {
    /// A fresh, private pool (tests; production runs share
    /// [`StagePool::shared`] so warmup amortises across runs).
    pub fn new() -> Arc<StagePool> {
        let bytes = PoolInfo(Pool::<CountingContext>::with_inner(CountingInfo::default()));
        let info = bytes.clone();
        // Fluent build of the pooled staging destinations: the AoS
        // layout over the recycling byte-pool context.
        let collections = ObjectPool::new(move || {
            ParticleCollection::build()
                .layout::<AoS<StageCtx>>()
                .context(info.clone())
                .finish()
        });
        Arc::new(StagePool { bytes, collections })
    }

    /// The process-wide stage pool (the default when
    /// `PipelineConfig::stage_pool` is `None`).
    pub fn shared() -> Arc<StagePool> {
        static POOL: OnceLock<Arc<StagePool>> = OnceLock::new();
        POOL.get_or_init(StagePool::new).clone()
    }

    /// Draw a staging collection; it checks back in on drop.
    pub fn checkout(&self) -> Recycler<StagedParticles> {
        self.collections.clone().checkout()
    }

    /// Byte-pool counters (hits/misses/trims/held/outstanding).
    pub fn byte_stats(&self) -> PoolSnapshot {
        self.bytes.0.stats()
    }

    /// Collection-pool counters (checkout hits/misses/returns).
    pub fn collection_stats(&self) -> ObjectPoolStats {
        self.collections.stats()
    }

    /// Net allocations of the inner counting heap: flat in steady state.
    pub fn live_allocs(&self) -> isize {
        self.bytes.0.inner().0.live_allocs()
    }

    /// The byte-pool context info (for building extra pooled storage).
    pub fn byte_info(&self) -> &PoolInfo<CountingContext> {
        &self.bytes
    }
}

impl std::fmt::Debug for StagePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "StagePool(bytes={:?}, collections={:?})",
            self.byte_stats(),
            self.collection_stats()
        )
    }
}

/// Process one event on the host path (shared by workers and benches).
pub fn process_host(ev: &RawEvent) -> (usize, f64) {
    let mut staged = ParticleCollection::<AoS>::new();
    let (n, energy, _bytes) = process_host_staged(ev, &mut staged);
    (n, energy)
}

/// Host path with an explicit reusable staging collection: fill +
/// calibrate + reconstruct over SoA, then stage the particle collection
/// into the staged output form through the cached transfer plan and
/// fill back through its dense record view. Generic over the staging
/// layout/context so the pipeline's pooled destinations
/// ([`StagedParticles`]) and the benches' plain `AoS` both fit.
/// Returns (particles, energy, staged bytes).
pub fn process_host_staged<L: Layout>(
    ev: &RawEvent,
    staged: &mut ParticleCollection<L>,
) -> (usize, f64, usize) {
    let mut col = ev.to_collection::<SoAVec>();
    calib::calibrate_collection(&mut col);
    let particles = reco::reconstruct_collection(&col);
    let pc = reco::into_collection::<SoAVec>(ev.event_id, &particles);
    let stats = pc.stage_into(staged);
    let back = reco::fill_back_aos(staged);
    let energy = back.data.iter().map(|p| p.energy as f64).sum();
    (back.data.len(), energy, stats.bytes)
}

/// Process one event on the device path (engine-owning thread only).
pub fn process_device(
    engine: &Engine,
    ev: &RawEvent,
) -> Result<(usize, f64, crate::runtime::ExecTiming)> {
    let mut staged = ParticleCollection::<AoS>::new();
    let (n, energy, timing, _bytes) = process_device_staged(engine, ev, &mut staged)?;
    Ok((n, energy, timing))
}

/// Device path with an explicit reusable staging collection; see
/// [`process_host_staged`]. Returns (particles, energy, timing, staged
/// bytes).
pub fn process_device_staged<L: Layout>(
    engine: &Engine,
    ev: &RawEvent,
    staged: &mut ParticleCollection<L>,
) -> Result<(usize, f64, crate::runtime::ExecTiming, usize)> {
    let (s, p, timing) = engine.run_full_event(ev)?;
    // The downloaded planes attach the one generated sensor view; the
    // gather reads grid geometry and significance through it — the same
    // interface description that serves owned and pooled stores
    // (DESIGN.md §6).
    let planes = crate::runtime::downloaded_planes(ev, &s)?;
    let view = SensorView::attach(&planes)?;
    let pc = reco::particles_from_download::<SoAVec, _>(&view, &p.seeds, &p.sums);
    let stats = pc.stage_into(staged);
    let back = reco::fill_back_aos(staged);
    let energy = back.data.iter().map(|p| p.energy as f64).sum();
    Ok((back.data.len(), energy, timing, stats.bytes))
}

/// Run the full pipeline to completion.
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineReport> {
    // Compile-once setup: register the EDM's specialized rungs and warm
    // the staging plans before any worker starts, so every per-event
    // plan lookup below is a cache hit.
    crate::edm::convert::register_edm_specializations();
    let _ = transfer::plan_for::<SoAVec, AoS>(&ParticleProps::schema());
    let _ = transfer::plan_for::<SoAVec, AoS<StageCtx>>(&ParticleProps::schema());
    if cfg.device {
        let _ = transfer::plan_for::<SoAVec, SoAVec<StagingContext>>(&SensorProps::schema());
    }

    // Amortise-once setup: the stage pool every worker draws per-event
    // staging destinations from (shared across runs unless the config
    // injects a private one).
    let stage_pool = cfg.stage_pool.clone().unwrap_or_else(StagePool::shared);

    let metrics = Arc::new(PipelineMetrics::default());
    let gauge = QueueGauge::default();
    let router = Router::new(cfg.policy, cfg.device, gauge.clone());

    let (host_tx, host_rx) = sync_channel::<Task>(cfg.queue_depth);
    let (dev_tx, dev_rx) = sync_channel::<Task>(cfg.queue_depth);
    // Results are unbounded: the collector (this thread) only starts
    // draining after the source loop finishes, so a bounded results
    // channel would deadlock under tight input backpressure.
    let (res_tx, res_rx) = channel::<EventResult>();
    let host_rx = Arc::new(Mutex::new(host_rx));

    let start = Instant::now();
    let mut workers = Vec::new();

    // Host worker pool.
    for _ in 0..cfg.host_workers.max(1) {
        let rx = host_rx.clone();
        let tx = res_tx.clone();
        let metrics = metrics.clone();
        let pool = stage_pool.clone();
        workers.push(std::thread::spawn(move || {
            loop {
                let task = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(task) = task else { break };
                // Draw the staging destination from the pool: after
                // warmup this is a warm collection whose capacity
                // already fits the workload — the cached plan executes
                // into it with zero allocations.
                let mut staged = pool.checkout();
                let (n, energy, bytes) = process_host_staged(&task.ev, &mut *staged);
                let latency = task.enqueued.elapsed();
                use std::sync::atomic::Ordering::Relaxed;
                metrics.events_host.fetch_add(1, Relaxed);
                metrics.particles_out.fetch_add(n, Relaxed);
                metrics.planned_transfers.fetch_add(1, Relaxed);
                metrics.planned_bytes.fetch_add(bytes, Relaxed);
                metrics.host_latency.record(latency);
                metrics.e2e_latency.record(latency);
                let _ = tx.send(EventResult {
                    event_id: task.ev.event_id,
                    route: Route::Host,
                    n_particles: n,
                    total_energy: energy,
                    latency,
                });
            }
        }));
    }

    // Device worker: owns the engine, drains through the batcher.
    if cfg.device {
        let tx = res_tx.clone();
        let metrics = metrics.clone();
        let gauge = gauge.clone();
        let max_batch = cfg.max_batch;
        let warm_buckets = cfg.warm_buckets.clone();
        let pool = stage_pool.clone();
        workers.push(std::thread::spawn(move || {
            use std::sync::atomic::Ordering::Relaxed;
            let engine = match Engine::load_default() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("device worker disabled: {e:#}");
                    // Drain and bounce everything to nowhere: the router
                    // already sent events here, so process on host path.
                    while let Ok(task) = dev_rx.recv() {
                        gauge.dec();
                        let mut staged = pool.checkout();
                        let (n, energy, bytes) =
                            process_host_staged(&task.ev, &mut *staged);
                        let latency = task.enqueued.elapsed();
                        metrics.events_host.fetch_add(1, Relaxed);
                        metrics.particles_out.fetch_add(n, Relaxed);
                        metrics.planned_transfers.fetch_add(1, Relaxed);
                        metrics.planned_bytes.fetch_add(bytes, Relaxed);
                        metrics.e2e_latency.record(latency);
                        let _ = tx.send(EventResult {
                            event_id: task.ev.event_id,
                            route: Route::Host,
                            n_particles: n,
                            total_energy: energy,
                            latency,
                        });
                    }
                    return;
                }
            };
            // Pre-compile expected buckets so the first event does not
            // pay XLA compilation (EXPERIMENTS.md §Perf-4).
            for b in warm_buckets {
                if let Err(e) = engine.warm("full_event", b, b) {
                    eprintln!("device warmup for {b}x{b} skipped: {e:#}");
                }
            }
            // Staging state built once at worker startup and reused per
            // event: the host-side sensor collection and the pinned
            // staging buffer its planned copy lands in (the
            // DMA-accounted upload analogue, DESIGN.md §2). The particle
            // output staging is drawn from the stage pool per event.
            let staging_info = StagingInfo::default();
            let mut sensors_host = SensorCollection::<SoAVec>::new();
            let mut sensors_staged =
                SensorCollection::<SoAVec<StagingContext>>::new_in(staging_info.clone());
            let mut warmed_bucket = None;
            let mut batcher: Batcher<Task> = Batcher::new(max_batch);
            loop {
                // Block for one task, then opportunistically drain more.
                match dev_rx.recv() {
                    Ok(t) => {
                        batcher.push(t.ev.rows, t);
                        while let Ok(t) = dev_rx.try_recv() {
                            batcher.push(t.ev.rows, t);
                        }
                    }
                    Err(_) if batcher.is_empty() => break,
                    Err(_) => {}
                }
                while !batcher.is_empty() {
                    // Peek the upcoming bucket and pre-compile its
                    // executable off the per-event path (warm_buckets
                    // may not have covered it).
                    if let Some(b) = batcher.next_bucket() {
                        if warmed_bucket != Some(b) {
                            let _ = engine.warm("full_event", b, b);
                            warmed_bucket = Some(b);
                        }
                    }
                    let batch = batcher.drain_batch();
                    metrics.device_batches.fetch_add(1, Relaxed);
                    for (_, task) in batch {
                        gauge.dec();
                        // Stage the event through the pinned buffer: the
                        // cached host→staging plan reuses the buffer and
                        // books the H2D traffic the upload represents.
                        task.ev.fill_collection(&mut sensors_host);
                        let up = sensors_host.stage_into(&mut sensors_staged);
                        metrics.planned_transfers.fetch_add(1, Relaxed);
                        metrics.planned_bytes.fetch_add(up.bytes, Relaxed);
                        let mut particles_staged = pool.checkout();
                        match process_device_staged(&engine, &task.ev, &mut *particles_staged)
                        {
                            Ok((n, energy, timing, bytes)) => {
                                let latency = task.enqueued.elapsed();
                                metrics.events_device.fetch_add(1, Relaxed);
                                metrics.particles_out.fetch_add(n, Relaxed);
                                metrics.planned_transfers.fetch_add(1, Relaxed);
                                metrics.planned_bytes.fetch_add(bytes, Relaxed);
                                metrics
                                    .device_upload_us
                                    .fetch_add(timing.upload.as_micros() as u64, Relaxed);
                                metrics
                                    .device_execute_us
                                    .fetch_add(timing.execute.as_micros() as u64, Relaxed);
                                metrics
                                    .device_download_us
                                    .fetch_add(timing.download.as_micros() as u64, Relaxed);
                                metrics.device_latency.record(latency);
                                metrics.e2e_latency.record(latency);
                                let _ = tx.send(EventResult {
                                    event_id: task.ev.event_id,
                                    route: Route::Device,
                                    n_particles: n,
                                    total_energy: energy,
                                    latency,
                                });
                            }
                            Err(e) => {
                                eprintln!(
                                    "device failed on event {}: {e:#}; host fallback",
                                    task.ev.event_id
                                );
                                let (n, energy, bytes) =
                                    process_host_staged(&task.ev, &mut *particles_staged);
                                let latency = task.enqueued.elapsed();
                                metrics.events_host.fetch_add(1, Relaxed);
                                metrics.particles_out.fetch_add(n, Relaxed);
                                metrics.planned_transfers.fetch_add(1, Relaxed);
                                metrics.planned_bytes.fetch_add(bytes, Relaxed);
                                metrics.e2e_latency.record(latency);
                                let _ = tx.send(EventResult {
                                    event_id: task.ev.event_id,
                                    route: Route::Host,
                                    n_particles: n,
                                    total_energy: energy,
                                    latency,
                                });
                            }
                        }
                    }
                }
            }
        }));
    }
    drop(res_tx);

    // Source + router (this thread).
    let mut gen = EventGenerator::new(cfg.event.clone(), cfg.seed);
    for _ in 0..cfg.n_events {
        let ev = gen.generate();
        metrics.events_in.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let d = router.decide(ev.rows, ev.cols);
        if d.spilled {
            metrics.events_spilled.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        let task = Task { ev, enqueued: Instant::now() };
        match d.route {
            Route::Host => host_tx.send(task).context("host queue closed")?,
            Route::Device => {
                gauge.inc();
                dev_tx.send(task).context("device queue closed")?;
            }
        }
    }
    drop(host_tx);
    drop(dev_tx);

    // Collector.
    let mut results: Vec<EventResult> = res_rx.iter().collect();
    for w in workers {
        w.join().expect("worker panicked");
    }
    results.sort_by_key(|r| r.event_id);
    let wall = start.elapsed();

    metrics.set_pool_counters(&stage_pool);
    Ok(PipelineReport { wall, results, metrics: metrics.snapshot() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::RoutePolicy;
    use crate::edm::generator::EventConfig;

    fn base_cfg(n: usize) -> PipelineConfig {
        let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 3), n);
        cfg.host_workers = 2;
        cfg.seed = 77;
        cfg
    }

    #[test]
    fn host_only_processes_everything() {
        let mut cfg = base_cfg(12);
        cfg.device = false;
        cfg.policy = RoutePolicy::HostOnly;
        let rep = run_pipeline(&cfg).unwrap();
        assert_eq!(rep.results.len(), 12);
        assert_eq!(rep.metrics.events_host, 12);
        assert_eq!(rep.metrics.events_device, 0);
        assert!(rep.total_particles() > 0, "3 deposits per event must seed");
        // One planned staging transfer per event, through the cache.
        assert_eq!(rep.metrics.planned_transfers, 12);
        assert!(rep.metrics.planned_bytes > 0);
        // Every event drew its staging destination from the stage pool
        // (counters are shared-pool cumulative, so only lower bounds).
        assert!(
            rep.metrics.stage_hits + rep.metrics.stage_misses >= 12,
            "stage pool not used: {} hits + {} misses",
            rep.metrics.stage_hits,
            rep.metrics.stage_misses,
        );
        // Results are sorted and complete.
        for (i, r) in rep.results.iter().enumerate() {
            assert_eq!(r.event_id, i as u64);
        }
    }

    #[test]
    fn private_stage_pool_reaches_steady_state() {
        let pool = StagePool::new();
        let mk = |n: usize| {
            let mut cfg = base_cfg(n);
            cfg.device = false;
            cfg.policy = RoutePolicy::HostOnly;
            cfg.host_workers = 1;
            cfg.stage_pool = Some(pool.clone());
            cfg
        };
        run_pipeline(&mk(10)).unwrap();
        let warm_b = pool.byte_stats();
        let warm_c = pool.collection_stats();
        let warm_live = pool.live_allocs();
        // Same workload again: the single worker replays the identical
        // event stream through the warm collection — no fresh
        // collections, no byte-pool misses, no net allocations.
        let rep = run_pipeline(&mk(10)).unwrap();
        assert_eq!(rep.results.len(), 10);
        let b = pool.byte_stats();
        let c = pool.collection_stats();
        assert_eq!(c.misses, warm_c.misses, "fresh staging collections built");
        assert!(c.hits >= warm_c.hits + 10);
        assert_eq!(b.misses, warm_b.misses, "byte-pool misses in steady state");
        assert_eq!(pool.live_allocs(), warm_live, "net allocations in steady state");
        // The run's metrics surface the same counters.
        assert_eq!(rep.metrics.pool_misses, b.misses);
        assert_eq!(rep.metrics.stage_misses, c.misses);
    }

    #[test]
    fn device_only_matches_host_physics() {
        if Engine::load_default().is_err() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut host_cfg = base_cfg(6);
        host_cfg.device = false;
        host_cfg.policy = RoutePolicy::HostOnly;
        let host = run_pipeline(&host_cfg).unwrap();

        let mut dev_cfg = base_cfg(6);
        dev_cfg.policy = RoutePolicy::DeviceOnly;
        let dev = run_pipeline(&dev_cfg).unwrap();

        assert_eq!(dev.metrics.events_device, 6);
        assert_eq!(host.results.len(), dev.results.len());
        for (h, d) in host.results.iter().zip(&dev.results) {
            assert_eq!(h.event_id, d.event_id);
            assert_eq!(h.n_particles, d.n_particles, "event {}", h.event_id);
            let rel = (h.total_energy - d.total_energy).abs()
                / h.total_energy.abs().max(1.0);
            assert!(rel < 1e-3, "energy drift {rel} on event {}", h.event_id);
        }
    }

    #[test]
    fn auto_policy_routes_small_grids_to_host() {
        let mut cfg = base_cfg(8);
        cfg.policy = RoutePolicy::Auto { min_device_cells: 128 * 128, max_device_queue: 4 };
        // 32x32 events: all below the crossover.
        let rep = run_pipeline(&cfg).unwrap();
        assert_eq!(rep.metrics.events_host, 8);
        assert_eq!(rep.metrics.events_device, 0);
    }

    #[test]
    fn throughput_reported() {
        let mut cfg = base_cfg(4);
        cfg.device = false;
        let rep = run_pipeline(&cfg).unwrap();
        assert!(rep.events_per_sec() > 0.0);
        assert!(rep.report().contains("events"));
        assert!(rep.report().contains("plan-cache"));
        assert!(rep.report().contains("pool: stage"));
    }
}

//! The pipeline: source → router → {host pool | device worker} → collector.
//!
//! * Source: synthetic event stream (`edm::generator`), routed as it is
//!   produced.
//! * Host workers: the CPU path — fill a Marionette SoA collection,
//!   calibrate, reconstruct, stage the particle collection into the
//!   handwritten-AoS output form through a cached [`TransferPlan`], fill
//!   back (exactly the Figure 1+2 CPU pipeline).
//! * Device worker: one dedicated thread owning a `runtime::Engine`
//!   (PJRT handles are single-threaded); drains its bounded queue
//!   through the bucket [`Batcher`], stages each event through its
//!   pinned staging buffer (DMA-accounted, DESIGN.md §2), runs the fused
//!   `full_event` executable, gathers particles from the returned
//!   planes, fills back.
//! * Collector: aggregates per-event results + metrics.
//!
//! Transfer strategy is **compiled once**: workers warm the staging
//! plans at startup and every per-event copy is a plan-cache hit that
//! executes into a reused destination collection (no re-derivation of
//! the ladder, no reallocation in steady state). Plan-level byte
//! counters feed [`metrics`](super::metrics).
//!
//! Every queue is a bounded `sync_channel`: a slow stage backpressures
//! the source instead of growing memory.
//!
//! [`TransferPlan`]: crate::marionette::transfer::TransferPlan

use std::sync::mpsc::{channel, sync_channel};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::edm::generator::{EventGenerator, RawEvent};
use crate::edm::particle::{ParticleCollection, ParticleProps};
use crate::edm::sensor::{SensorCollection, SensorProps};
use crate::edm::{calib, reco};
use crate::marionette::layout::{AoS, SoAVec};
use crate::marionette::memory::{StagingContext, StagingInfo};
use crate::marionette::transfer;
use crate::runtime::Engine;

use super::batcher::Batcher;
use super::config::PipelineConfig;
use super::metrics::{MetricsSnapshot, PipelineMetrics};
use super::router::{QueueGauge, Router};

/// Which path processed an event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    Host,
    Device,
}

/// Per-event outcome.
#[derive(Clone, Debug)]
pub struct EventResult {
    pub event_id: u64,
    pub route: Route,
    pub n_particles: usize,
    pub total_energy: f64,
    pub latency: Duration,
}

/// Whole-run outcome.
#[derive(Debug)]
pub struct PipelineReport {
    pub wall: Duration,
    pub results: Vec<EventResult>,
    pub metrics: MetricsSnapshot,
}

impl PipelineReport {
    pub fn events_per_sec(&self) -> f64 {
        self.results.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn total_particles(&self) -> usize {
        self.results.iter().map(|r| r.n_particles).sum()
    }

    pub fn report(&self) -> String {
        format!(
            "pipeline: {} events in {:?} ({:.1} ev/s), {} particles\n{}",
            self.results.len(),
            self.wall,
            self.events_per_sec(),
            self.total_particles(),
            self.metrics.report()
        )
    }
}

struct Task {
    ev: RawEvent,
    enqueued: Instant,
}

/// Process one event on the host path (shared by workers and benches).
pub fn process_host(ev: &RawEvent) -> (usize, f64) {
    let mut staged = ParticleCollection::<AoS>::new();
    let (n, energy, _bytes) = process_host_staged(ev, &mut staged);
    (n, energy)
}

/// Host path with an explicit reusable staging collection: fill +
/// calibrate + reconstruct over SoA, then stage the particle collection
/// into the handwritten-AoS output form through the cached transfer
/// plan and fill back through its dense record view. Returns
/// (particles, energy, staged bytes).
pub fn process_host_staged(
    ev: &RawEvent,
    staged: &mut ParticleCollection<AoS>,
) -> (usize, f64, usize) {
    let mut col = ev.to_collection::<SoAVec>();
    calib::calibrate_collection(&mut col);
    let particles = reco::reconstruct_collection(&col);
    let pc = reco::into_collection::<SoAVec>(ev.event_id, &particles);
    let stats = staged.transfer_from_stats(&pc);
    let back = reco::fill_back_aos(staged);
    let energy = back.data.iter().map(|p| p.energy as f64).sum();
    (back.data.len(), energy, stats.bytes)
}

/// Process one event on the device path (engine-owning thread only).
pub fn process_device(
    engine: &Engine,
    ev: &RawEvent,
) -> Result<(usize, f64, crate::runtime::ExecTiming)> {
    let mut staged = ParticleCollection::<AoS>::new();
    let (n, energy, timing, _bytes) = process_device_staged(engine, ev, &mut staged)?;
    Ok((n, energy, timing))
}

/// Device path with an explicit reusable staging collection; see
/// [`process_host_staged`]. Returns (particles, energy, timing, staged
/// bytes).
pub fn process_device_staged(
    engine: &Engine,
    ev: &RawEvent,
    staged: &mut ParticleCollection<AoS>,
) -> Result<(usize, f64, crate::runtime::ExecTiming, usize)> {
    let (s, p, timing) = engine.run_full_event(ev)?;
    let pc = reco::particles_from_planes::<SoAVec>(
        ev.rows, ev.cols, ev.event_id, &p.seeds, &p.sums, &s.sig,
    );
    let stats = staged.transfer_from_stats(&pc);
    let back = reco::fill_back_aos(staged);
    let energy = back.data.iter().map(|p| p.energy as f64).sum();
    Ok((back.data.len(), energy, timing, stats.bytes))
}

/// Run the full pipeline to completion.
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineReport> {
    // Compile-once setup: register the EDM's specialized rungs and warm
    // the staging plans before any worker starts, so every per-event
    // plan lookup below is a cache hit.
    crate::edm::convert::register_edm_specializations();
    let _ = transfer::plan_for::<SoAVec, AoS>(&ParticleProps::schema());
    if cfg.device {
        let _ = transfer::plan_for::<SoAVec, SoAVec<StagingContext>>(&SensorProps::schema());
    }

    let metrics = Arc::new(PipelineMetrics::default());
    let gauge = QueueGauge::default();
    let router = Router::new(cfg.policy, cfg.device, gauge.clone());

    let (host_tx, host_rx) = sync_channel::<Task>(cfg.queue_depth);
    let (dev_tx, dev_rx) = sync_channel::<Task>(cfg.queue_depth);
    // Results are unbounded: the collector (this thread) only starts
    // draining after the source loop finishes, so a bounded results
    // channel would deadlock under tight input backpressure.
    let (res_tx, res_rx) = channel::<EventResult>();
    let host_rx = Arc::new(Mutex::new(host_rx));

    let start = Instant::now();
    let mut workers = Vec::new();

    // Host worker pool.
    for _ in 0..cfg.host_workers.max(1) {
        let rx = host_rx.clone();
        let tx = res_tx.clone();
        let metrics = metrics.clone();
        workers.push(std::thread::spawn(move || {
            // Staging built once per worker: the cached plan executes
            // into this reused collection for every event.
            let mut staged = ParticleCollection::<AoS>::new();
            loop {
                let task = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(task) = task else { break };
                let (n, energy, bytes) = process_host_staged(&task.ev, &mut staged);
                let latency = task.enqueued.elapsed();
                use std::sync::atomic::Ordering::Relaxed;
                metrics.events_host.fetch_add(1, Relaxed);
                metrics.particles_out.fetch_add(n, Relaxed);
                metrics.planned_transfers.fetch_add(1, Relaxed);
                metrics.planned_bytes.fetch_add(bytes, Relaxed);
                metrics.host_latency.record(latency);
                metrics.e2e_latency.record(latency);
                let _ = tx.send(EventResult {
                    event_id: task.ev.event_id,
                    route: Route::Host,
                    n_particles: n,
                    total_energy: energy,
                    latency,
                });
            }
        }));
    }

    // Device worker: owns the engine, drains through the batcher.
    if cfg.device {
        let tx = res_tx.clone();
        let metrics = metrics.clone();
        let gauge = gauge.clone();
        let max_batch = cfg.max_batch;
        let warm_buckets = cfg.warm_buckets.clone();
        workers.push(std::thread::spawn(move || {
            use std::sync::atomic::Ordering::Relaxed;
            let engine = match Engine::load_default() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("device worker disabled: {e:#}");
                    // Drain and bounce everything to nowhere: the router
                    // already sent events here, so process on host path.
                    let mut staged = ParticleCollection::<AoS>::new();
                    while let Ok(task) = dev_rx.recv() {
                        gauge.dec();
                        let (n, energy, bytes) = process_host_staged(&task.ev, &mut staged);
                        let latency = task.enqueued.elapsed();
                        metrics.events_host.fetch_add(1, Relaxed);
                        metrics.particles_out.fetch_add(n, Relaxed);
                        metrics.planned_transfers.fetch_add(1, Relaxed);
                        metrics.planned_bytes.fetch_add(bytes, Relaxed);
                        metrics.e2e_latency.record(latency);
                        let _ = tx.send(EventResult {
                            event_id: task.ev.event_id,
                            route: Route::Host,
                            n_particles: n,
                            total_energy: energy,
                            latency,
                        });
                    }
                    return;
                }
            };
            // Pre-compile expected buckets so the first event does not
            // pay XLA compilation (EXPERIMENTS.md §Perf-4).
            for b in warm_buckets {
                if let Err(e) = engine.warm("full_event", b, b) {
                    eprintln!("device warmup for {b}x{b} skipped: {e:#}");
                }
            }
            // Staging state built once at worker startup and reused per
            // event: the host-side sensor collection, the pinned staging
            // buffer its planned copy lands in (the DMA-accounted upload
            // analogue, DESIGN.md §2), and the particle output staging.
            let staging_info = StagingInfo::default();
            let mut sensors_host = SensorCollection::<SoAVec>::new();
            let mut sensors_staged =
                SensorCollection::<SoAVec<StagingContext>>::new_in(staging_info.clone());
            let mut particles_staged = ParticleCollection::<AoS>::new();
            let mut warmed_bucket = None;
            let mut batcher: Batcher<Task> = Batcher::new(max_batch);
            loop {
                // Block for one task, then opportunistically drain more.
                match dev_rx.recv() {
                    Ok(t) => {
                        batcher.push(t.ev.rows, t);
                        while let Ok(t) = dev_rx.try_recv() {
                            batcher.push(t.ev.rows, t);
                        }
                    }
                    Err(_) if batcher.is_empty() => break,
                    Err(_) => {}
                }
                while !batcher.is_empty() {
                    // Peek the upcoming bucket and pre-compile its
                    // executable off the per-event path (warm_buckets
                    // may not have covered it).
                    if let Some(b) = batcher.next_bucket() {
                        if warmed_bucket != Some(b) {
                            let _ = engine.warm("full_event", b, b);
                            warmed_bucket = Some(b);
                        }
                    }
                    let batch = batcher.drain_batch();
                    metrics.device_batches.fetch_add(1, Relaxed);
                    for (_, task) in batch {
                        gauge.dec();
                        // Stage the event through the pinned buffer: the
                        // cached host→staging plan reuses the buffer and
                        // books the H2D traffic the upload represents.
                        task.ev.fill_collection(&mut sensors_host);
                        let up = sensors_staged.transfer_from_stats(&sensors_host);
                        metrics.planned_transfers.fetch_add(1, Relaxed);
                        metrics.planned_bytes.fetch_add(up.bytes, Relaxed);
                        match process_device_staged(&engine, &task.ev, &mut particles_staged)
                        {
                            Ok((n, energy, timing, bytes)) => {
                                let latency = task.enqueued.elapsed();
                                metrics.events_device.fetch_add(1, Relaxed);
                                metrics.particles_out.fetch_add(n, Relaxed);
                                metrics.planned_transfers.fetch_add(1, Relaxed);
                                metrics.planned_bytes.fetch_add(bytes, Relaxed);
                                metrics
                                    .device_upload_us
                                    .fetch_add(timing.upload.as_micros() as u64, Relaxed);
                                metrics
                                    .device_execute_us
                                    .fetch_add(timing.execute.as_micros() as u64, Relaxed);
                                metrics
                                    .device_download_us
                                    .fetch_add(timing.download.as_micros() as u64, Relaxed);
                                metrics.device_latency.record(latency);
                                metrics.e2e_latency.record(latency);
                                let _ = tx.send(EventResult {
                                    event_id: task.ev.event_id,
                                    route: Route::Device,
                                    n_particles: n,
                                    total_energy: energy,
                                    latency,
                                });
                            }
                            Err(e) => {
                                eprintln!(
                                    "device failed on event {}: {e:#}; host fallback",
                                    task.ev.event_id
                                );
                                let (n, energy, bytes) =
                                    process_host_staged(&task.ev, &mut particles_staged);
                                let latency = task.enqueued.elapsed();
                                metrics.events_host.fetch_add(1, Relaxed);
                                metrics.particles_out.fetch_add(n, Relaxed);
                                metrics.planned_transfers.fetch_add(1, Relaxed);
                                metrics.planned_bytes.fetch_add(bytes, Relaxed);
                                metrics.e2e_latency.record(latency);
                                let _ = tx.send(EventResult {
                                    event_id: task.ev.event_id,
                                    route: Route::Host,
                                    n_particles: n,
                                    total_energy: energy,
                                    latency,
                                });
                            }
                        }
                    }
                }
            }
        }));
    }
    drop(res_tx);

    // Source + router (this thread).
    let mut gen = EventGenerator::new(cfg.event.clone(), cfg.seed);
    for _ in 0..cfg.n_events {
        let ev = gen.generate();
        metrics.events_in.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let d = router.decide(ev.rows, ev.cols);
        if d.spilled {
            metrics.events_spilled.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        let task = Task { ev, enqueued: Instant::now() };
        match d.route {
            Route::Host => host_tx.send(task).context("host queue closed")?,
            Route::Device => {
                gauge.inc();
                dev_tx.send(task).context("device queue closed")?;
            }
        }
    }
    drop(host_tx);
    drop(dev_tx);

    // Collector.
    let mut results: Vec<EventResult> = res_rx.iter().collect();
    for w in workers {
        w.join().expect("worker panicked");
    }
    results.sort_by_key(|r| r.event_id);
    let wall = start.elapsed();

    Ok(PipelineReport { wall, results, metrics: metrics.snapshot() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::RoutePolicy;
    use crate::edm::generator::EventConfig;

    fn base_cfg(n: usize) -> PipelineConfig {
        let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 3), n);
        cfg.host_workers = 2;
        cfg.seed = 77;
        cfg
    }

    #[test]
    fn host_only_processes_everything() {
        let mut cfg = base_cfg(12);
        cfg.device = false;
        cfg.policy = RoutePolicy::HostOnly;
        let rep = run_pipeline(&cfg).unwrap();
        assert_eq!(rep.results.len(), 12);
        assert_eq!(rep.metrics.events_host, 12);
        assert_eq!(rep.metrics.events_device, 0);
        assert!(rep.total_particles() > 0, "3 deposits per event must seed");
        // One planned staging transfer per event, through the cache.
        assert_eq!(rep.metrics.planned_transfers, 12);
        assert!(rep.metrics.planned_bytes > 0);
        // Results are sorted and complete.
        for (i, r) in rep.results.iter().enumerate() {
            assert_eq!(r.event_id, i as u64);
        }
    }

    #[test]
    fn device_only_matches_host_physics() {
        if Engine::load_default().is_err() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut host_cfg = base_cfg(6);
        host_cfg.device = false;
        host_cfg.policy = RoutePolicy::HostOnly;
        let host = run_pipeline(&host_cfg).unwrap();

        let mut dev_cfg = base_cfg(6);
        dev_cfg.policy = RoutePolicy::DeviceOnly;
        let dev = run_pipeline(&dev_cfg).unwrap();

        assert_eq!(dev.metrics.events_device, 6);
        assert_eq!(host.results.len(), dev.results.len());
        for (h, d) in host.results.iter().zip(&dev.results) {
            assert_eq!(h.event_id, d.event_id);
            assert_eq!(h.n_particles, d.n_particles, "event {}", h.event_id);
            let rel = (h.total_energy - d.total_energy).abs()
                / h.total_energy.abs().max(1.0);
            assert!(rel < 1e-3, "energy drift {rel} on event {}", h.event_id);
        }
    }

    #[test]
    fn auto_policy_routes_small_grids_to_host() {
        let mut cfg = base_cfg(8);
        cfg.policy = RoutePolicy::Auto { min_device_cells: 128 * 128, max_device_queue: 4 };
        // 32x32 events: all below the crossover.
        let rep = run_pipeline(&cfg).unwrap();
        assert_eq!(rep.metrics.events_host, 8);
        assert_eq!(rep.metrics.events_device, 0);
    }

    #[test]
    fn throughput_reported() {
        let mut cfg = base_cfg(4);
        cfg.device = false;
        let rep = run_pipeline(&cfg).unwrap();
        assert!(rep.events_per_sec() > 0.0);
        assert!(rep.report().contains("events"));
        assert!(rep.report().contains("plan-cache"));
    }
}

//! The pipeline: source → router → {host pool | device worker} → collector.
//!
//! * Source: synthetic event stream (`edm::generator`), routed as it is
//!   produced.
//! * Host workers: the CPU path — one spawned task per event on a
//!   per-run work-stealing [`ThreadPool`] (no shared receiver mutex; an
//!   in-flight gate provides the `queue_depth` backpressure) — fill a
//!   Marionette SoA collection, calibrate, reconstruct, stage the
//!   particle collection into the handwritten-AoS output form through a
//!   cached [`TransferPlan`], fill back (exactly the Figure 1+2 CPU
//!   pipeline).
//! * Device workers: `PipelineConfig::device_workers` dedicated
//!   threads, each owning its own `runtime::Engine` (PJRT handles are
//!   single-threaded), bounded queue, bucket [`Batcher`], and pinned
//!   staging buffer (DMA-accounted, DESIGN.md §2); each stages its
//!   events, runs the fused `full_event` executable, gathers particles
//!   from the returned planes, fills back. The router spills on the
//!   aggregate queue depth across workers.
//! * Collector: aggregates per-event results + metrics.
//!
//! Transfer strategy is **compiled once**: workers warm the staging
//! plans at startup and every per-event copy goes through the fluent
//! `stage_into` sugar — a plan-cache hit that executes into a reused
//! destination collection (no re-derivation of the ladder, no
//! reallocation in steady state). Plan-level byte counters feed
//! [`metrics`](super::metrics). The device path reads its downloaded
//! planes through the borrowed typed `SensorView`
//! (`runtime::devmem::downloaded_planes` + `particles_from_download`;
//! DESIGN.md §6), the same interface description the host path's owned
//! collections use.
//!
//! Staging memory is **pooled** (DESIGN.md §5): workers draw their
//! per-event staging destination from a shared [`StagePool`] — an
//! object pool of warm collections over a recycling
//! [`PoolContext`]`<CountingContext>` byte pool — and check it back in
//! on drop. After warmup every checkout is a hit and no per-event
//! allocation reaches the heap; the pool counters in
//! [`metrics`](super::metrics) (and `tests/pipeline_integration.rs`)
//! pin that steady state.
//!
//! Every queue is a bounded `sync_channel`: a slow stage backpressures
//! the source instead of growing memory.
//!
//! [`TransferPlan`]: crate::marionette::transfer::TransferPlan
//! [`PoolContext`]: crate::marionette::memory::PoolContext

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc::{channel, sync_channel};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::edm::generator::{EventGenerator, RawEvent};
use crate::edm::particle::{ParticleCollection, ParticleProps};
use crate::edm::sensor::{SensorCollection, SensorProps, SensorView, SensorViewMut};
use crate::edm::{calib, reco};
use crate::marionette::collection::InfoOf;
use crate::marionette::interface::TracingSource;
use crate::marionette::layout::{AoS, AoSoA, Layout, SoABlob, SoAVec};
use crate::marionette::trace::LayoutChoice;
use crate::marionette::memory::{
    CountingContext, CountingInfo, FaultyContext, FaultyInfo, Pool, PoolContext, PoolInfo,
    PoolSnapshot, StagingContext, StagingInfo,
};
use crate::marionette::trace::{RouteTraceSummary, TraceTape};
use crate::marionette::transfer;
use crate::runtime::{Engine, FaultyEngine, FullEventRunner};
use crate::util::pool::{ObjectPool, ObjectPoolStats, Recycler, ThreadPool};

use super::batcher::{AimdBatchController, Batcher};
use super::config::PipelineConfig;
use super::fault::{FaultPlan, FaultState};
use super::metrics::{quantile_between, MetricsSnapshot, PipelineMetrics};
use super::router::{QueueGauge, Router};

/// Which path processed an event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    Host,
    Device,
}

/// Per-event outcome.
#[derive(Clone, Debug)]
pub struct EventResult {
    pub event_id: u64,
    pub route: Route,
    pub n_particles: usize,
    pub total_energy: f64,
    pub latency: Duration,
}

/// Whole-run outcome.
#[derive(Debug)]
pub struct PipelineReport {
    pub wall: Duration,
    pub results: Vec<EventResult>,
    pub metrics: MetricsSnapshot,
    /// Events given up on after the chaos retry budget (DESIGN.md §10):
    /// reported here, never silently dropped. Empty on clean runs, and
    /// disjoint from `results` — every submitted event is in exactly
    /// one of the two.
    pub quarantined: Vec<u64>,
}

impl PipelineReport {
    pub fn events_per_sec(&self) -> f64 {
        self.results.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn total_particles(&self) -> usize {
        self.results.iter().map(|r| r.n_particles).sum()
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "pipeline: {} events in {:?} ({:.1} ev/s), {} particles\n{}",
            self.results.len(),
            self.wall,
            self.events_per_sec(),
            self.total_particles(),
            self.metrics.report()
        );
        if !self.quarantined.is_empty() {
            out.push_str(&format!("\nquarantined events: {:?}", self.quarantined));
        }
        out
    }
}

/// A device worker's panic escaped supervision (real supervisor-layer
/// failure, or deliberately via `FaultPlan::worker_abort`). The run
/// still drains, joins and snapshots; this error carries the partial
/// [`PipelineReport`] so callers keep the metrics and every result
/// that completed before shutdown. Downcast with
/// `err.downcast_ref::<PipelineError>()`.
#[derive(Debug)]
pub struct PipelineError {
    pub panicked_workers: usize,
    pub report: PipelineReport,
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} device worker(s) panicked; {} of {} events completed before shutdown",
            self.panicked_workers,
            self.report.results.len(),
            self.report.metrics.events_in,
        )
    }
}

impl std::error::Error for PipelineError {}

struct Task {
    ev: RawEvent,
    enqueued: Instant,
}

/// Memory context of pooled staging collections: a recycling size-class
/// pool over a counting heap, so the steady-state zero-alloc claim is
/// observable (pool hit/miss counters + inner `live_allocs`).
pub type StageCtx = PoolContext<CountingContext>;

/// The pooled per-event staging destination workers draw and return.
pub type StagedParticles = ParticleCollection<AoS<StageCtx>>;

/// One shard of the stage pool: its own byte pool + collection pool.
struct StageShard {
    bytes: PoolInfo<CountingContext>,
    collections: Arc<ObjectPool<StagedParticles>>,
}

impl StageShard {
    fn new() -> StageShard {
        let bytes = PoolInfo(Pool::<CountingContext>::with_inner(CountingInfo::default()));
        let info = bytes.clone();
        // Fluent build of the pooled staging destinations: the AoS
        // layout over the recycling byte-pool context.
        let collections = ObjectPool::new(move || {
            ParticleCollection::build()
                .layout::<AoS<StageCtx>>()
                .context(info.clone())
                .finish()
        });
        StageShard { bytes, collections }
    }
}

/// Shared pool of per-event staging destinations: sharded object pools
/// of warm [`StagedParticles`] collections, each shard over its own
/// recycling byte pool. Threads hash onto a shard (DESIGN.md §8), so
/// concurrent workers never contend on one checkout mutex; checkouts
/// return on drop (capacity intact), so after warmup neither level
/// touches the heap again. Stats aggregate across shards.
pub struct StagePool {
    shards: Vec<StageShard>,
}

impl StagePool {
    /// A fresh, private single-shard pool (tests want deterministic
    /// per-thread steady state; production runs share
    /// [`StagePool::shared`] so warmup amortises across runs).
    pub fn new() -> Arc<StagePool> {
        StagePool::with_shards(1)
    }

    /// A pool with `n` shards (one per expected concurrent worker).
    pub fn with_shards(n: usize) -> Arc<StagePool> {
        Arc::new(StagePool { shards: (0..n.max(1)).map(|_| StageShard::new()).collect() })
    }

    /// The process-wide stage pool (the default when
    /// `PipelineConfig::stage_pool` is `None`): one shard per expected
    /// concurrent worker, capped at 8.
    pub fn shared() -> Arc<StagePool> {
        static POOL: OnceLock<Arc<StagePool>> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
            StagePool::with_shards(n.min(8))
        })
        .clone()
    }

    /// Shard count.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// This thread's shard (stable per thread: hashed thread id).
    fn shard(&self) -> &StageShard {
        if self.shards.len() == 1 {
            return &self.shards[0];
        }
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Draw a staging collection from this thread's shard; it checks
    /// back in on drop.
    pub fn checkout(&self) -> Recycler<StagedParticles> {
        self.shard().collections.clone().checkout()
    }

    /// Byte-pool counters (hits/misses/trims/held/outstanding), summed
    /// over the shards.
    pub fn byte_stats(&self) -> PoolSnapshot {
        let mut s = PoolSnapshot::default();
        for sh in &self.shards {
            let b = sh.bytes.0.stats();
            s.hits += b.hits;
            s.misses += b.misses;
            s.returns += b.returns;
            s.trims += b.trims;
            s.outstanding += b.outstanding;
            s.held_bytes += b.held_bytes;
        }
        s
    }

    /// Collection-pool counters (checkout hits/misses/returns), summed
    /// over the shards.
    pub fn collection_stats(&self) -> ObjectPoolStats {
        let mut s = ObjectPoolStats::default();
        for sh in &self.shards {
            let c = sh.collections.stats();
            s.hits += c.hits;
            s.misses += c.misses;
            s.returns += c.returns;
            s.dropped += c.dropped;
        }
        s
    }

    /// Net allocations of the inner counting heaps: flat in steady state.
    pub fn live_allocs(&self) -> isize {
        self.shards.iter().map(|sh| sh.bytes.0.inner().0.live_allocs()).sum()
    }

    /// This thread's shard's byte-pool context info (for building extra
    /// pooled storage).
    pub fn byte_info(&self) -> &PoolInfo<CountingContext> {
        &self.shard().bytes
    }
}

impl std::fmt::Debug for StagePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "StagePool(bytes={:?}, collections={:?})",
            self.byte_stats(),
            self.collection_stats()
        )
    }
}

/// Process one event on the host path (shared by workers and benches).
pub fn process_host(ev: &RawEvent) -> (usize, f64) {
    let mut staged = ParticleCollection::<AoS>::new();
    let (n, energy, _bytes) = process_host_staged(ev, &mut staged);
    (n, energy)
}

/// Host path with an explicit reusable staging collection: fill +
/// calibrate + reconstruct over SoA, then stage the particle collection
/// into the staged output form through the cached transfer plan and
/// fill back through its dense record view. Generic over the staging
/// layout/context so the pipeline's pooled destinations
/// ([`StagedParticles`]) and the benches' plain `AoS` both fit.
/// Returns (particles, energy, staged bytes).
pub fn process_host_staged<L: Layout>(
    ev: &RawEvent,
    staged: &mut ParticleCollection<L>,
) -> (usize, f64, usize) {
    let mut col = ev.to_collection::<SoAVec>();
    calib::calibrate_collection(&mut col);
    let particles = reco::reconstruct_collection(&col);
    let pc = reco::into_collection::<SoAVec>(ev.event_id, &particles);
    let stats = pc.stage_into(staged);
    let back = reco::fill_back_aos(staged);
    let energy = back.data.iter().map(|p| p.energy as f64).sum();
    (back.data.len(), energy, stats.bytes)
}

/// Process one event on the device path (engine-owning thread only).
pub fn process_device(
    engine: &Engine,
    ev: &RawEvent,
) -> Result<(usize, f64, crate::runtime::ExecTiming)> {
    let mut staged = ParticleCollection::<AoS>::new();
    let (n, energy, timing, _bytes) = process_device_staged(engine, ev, &mut staged)?;
    Ok((n, energy, timing))
}

/// Device path with an explicit reusable staging collection; see
/// [`process_host_staged`]. Generic over the runner so the chaos
/// harness's [`FaultyEngine`] slots in without touching the clean
/// path. Returns (particles, energy, timing, staged bytes).
pub fn process_device_staged<L: Layout, E: FullEventRunner>(
    engine: &E,
    ev: &RawEvent,
    staged: &mut ParticleCollection<L>,
) -> Result<(usize, f64, crate::runtime::ExecTiming, usize)> {
    let (s, p, timing) = engine.run_full_event(ev)?;
    // The downloaded planes attach the one generated sensor view; the
    // gather reads grid geometry and significance through it — the same
    // interface description that serves owned and pooled stores
    // (DESIGN.md §6).
    let planes = crate::runtime::downloaded_planes(ev, &s)?;
    let view = SensorView::attach(&planes)?;
    let pc = reco::particles_from_download::<SoAVec, _>(&view, &p.seeds, &p.sums);
    let stats = pc.stage_into(staged);
    let back = reco::fill_back_aos(staged);
    let energy = back.data.iter().map(|p| p.energy as f64).sum();
    Ok((back.data.len(), energy, timing, stats.bytes))
}

/// Per-route access-pattern tapes for the autotuner's measurement runs
/// (DESIGN.md §9): `staging` counts the calibration pass's reads and
/// writes, `gather` the device-download gather reads, `reco` the
/// reconstruction stencil reads. All three tape the one sensor schema;
/// [`RouteTapes::summaries`] drops routes that never executed (a
/// host-only run reports no `gather` heatmap).
#[derive(Debug)]
pub struct RouteTapes {
    pub staging: TraceTape,
    pub gather: TraceTape,
    pub reco: TraceTape,
}

impl RouteTapes {
    pub fn new() -> Arc<RouteTapes> {
        let schema = SensorProps::schema();
        Arc::new(RouteTapes {
            staging: TraceTape::new("staging", &schema),
            gather: TraceTape::new("gather", &schema),
            reco: TraceTape::new("reco", &schema),
        })
    }

    /// Snapshots of the routes that recorded at least one access.
    pub fn summaries(&self) -> Vec<RouteTraceSummary> {
        [&self.staging, &self.gather, &self.reco]
            .into_iter()
            .filter(|t| !t.is_empty())
            .map(|t| t.snapshot())
            .collect()
    }
}

/// [`process_host_staged`] with the calibration and reconstruction
/// accessor traffic routed through tracing sources onto the autotuner
/// tapes. Measurement runs only: a tracing source advertises no cached
/// plane, so every access takes the per-element path the tape counts —
/// the untraced entry points compile exactly as before.
pub fn process_host_staged_traced<L: Layout>(
    ev: &RawEvent,
    staged: &mut ParticleCollection<L>,
    tapes: &RouteTapes,
) -> (usize, f64, usize) {
    let mut col = ev.to_collection::<SoAVec>();
    {
        let mut src = col.traced_mut(&tapes.staging);
        let mut v = SensorViewMut::attach(&mut src).expect("traced staging attach");
        calib::calibrate_view(&mut v);
    }
    let particles = {
        let src = col.traced(&tapes.reco);
        let v = SensorView::attach(&src).expect("traced reco attach");
        reco::reconstruct(&v)
    };
    let pc = reco::into_collection::<SoAVec>(ev.event_id, &particles);
    let stats = pc.stage_into(staged);
    let back = reco::fill_back_aos(staged);
    let energy = back.data.iter().map(|p| p.energy as f64).sum();
    (back.data.len(), energy, stats.bytes)
}

/// Host-path processing with the staging layout chosen at run time —
/// the autotuner's [`LayoutChoice`] recommendation routed into the live
/// path via [`PipelineConfig::staging_layout`]. Stages into a fresh
/// collection of the selected layout (its transfer plan is pre-warmed
/// by [`run_pipeline`], so the per-event cost is the allocation, not a
/// plan build). The physics is layout-invariant: every choice must
/// produce bit-identical results to the pooled default.
pub fn process_host_selected(
    ev: &RawEvent,
    choice: LayoutChoice,
    tapes: Option<&RouteTapes>,
) -> (usize, f64, usize) {
    fn go<L: Layout>(ev: &RawEvent, tapes: Option<&RouteTapes>) -> (usize, f64, usize)
    where
        InfoOf<L>: Default,
    {
        let mut staged = ParticleCollection::<L>::new();
        match tapes {
            Some(t) => process_host_staged_traced(ev, &mut staged, t),
            None => process_host_staged(ev, &mut staged),
        }
    }
    match choice {
        LayoutChoice::AoS => go::<AoS>(ev, tapes),
        LayoutChoice::SoAVec => go::<SoAVec>(ev, tapes),
        LayoutChoice::SoABlob => go::<SoABlob>(ev, tapes),
        LayoutChoice::AoSoA8 => go::<AoSoA<8>>(ev, tapes),
    }
}

/// [`process_device_staged`] with the download gather reads taped; see
/// [`process_host_staged_traced`].
pub fn process_device_staged_traced<L: Layout, E: FullEventRunner>(
    engine: &E,
    ev: &RawEvent,
    staged: &mut ParticleCollection<L>,
    tapes: &RouteTapes,
) -> Result<(usize, f64, crate::runtime::ExecTiming, usize)> {
    let (s, p, timing) = engine.run_full_event(ev)?;
    let planes = crate::runtime::downloaded_planes(ev, &s)?;
    let traced = TracingSource::new(&planes, &tapes.gather);
    let view = SensorView::attach(&traced)?;
    let pc = reco::particles_from_download::<SoAVec, _>(&view, &p.seeds, &p.sums);
    let stats = pc.stage_into(staged);
    let back = reco::fill_back_aos(staged);
    let energy = back.data.iter().map(|p| p.energy as f64).sum();
    Ok((back.data.len(), energy, timing, stats.bytes))
}

/// Bounded-in-flight gate for the host path: the source acquires one
/// permit per dispatched task, the task's RAII permit releases on
/// completion (a panicking task cannot leak its permit). This replaces
/// the old bounded host channel's backpressure now that host tasks go
/// straight to the work-stealing pool.
struct Gate {
    state: Mutex<usize>,
    cv: Condvar,
    limit: usize,
}

impl Gate {
    fn new(limit: usize) -> Arc<Gate> {
        Arc::new(Gate { state: Mutex::new(0), cv: Condvar::new(), limit: limit.max(1) })
    }

    fn acquire(self: &Arc<Gate>) -> GatePermit {
        let mut g = self.state.lock().unwrap();
        while *g >= self.limit {
            g = self.cv.wait(g).unwrap();
        }
        *g += 1;
        GatePermit(self.clone())
    }

    /// Currently outstanding permits (the host path's queue depth; the
    /// adaptive controller reads this as part of its load signal).
    fn in_flight(&self) -> usize {
        *self.state.lock().unwrap()
    }
}

struct GatePermit(Arc<Gate>);

impl Drop for GatePermit {
    fn drop(&mut self) {
        let mut g = self.0.state.lock().unwrap();
        *g -= 1;
        drop(g);
        self.0.cv.notify_one();
    }
}

/// One dequeued-but-unfinished device event, held by the worker's
/// supervisor so a dying worker strands nothing: entries are admitted
/// at dequeue time and settled right before their result is sent, so
/// whatever is in the ledger when a panic unwinds is exactly the set of
/// in-flight events to recover.
struct LedgerEntry {
    ev: RawEvent,
    enqueued: Instant,
}

/// The in-flight ledger one supervisor shares with its worker loop.
/// Locks are held only for push/retain — never across processing — so
/// a worker panic can never poison the mutex.
#[derive(Default)]
struct WorkerLedger(Mutex<Vec<LedgerEntry>>);

impl WorkerLedger {
    fn admit(&self, ev: &RawEvent, enqueued: Instant) {
        self.0.lock().unwrap().push(LedgerEntry { ev: ev.clone(), enqueued });
    }

    fn settle(&self, event_id: u64) {
        self.0.lock().unwrap().retain(|e| e.ev.event_id != event_id);
    }

    fn drain(&self) -> Vec<LedgerEntry> {
        std::mem::take(&mut *self.0.lock().unwrap())
    }
}

/// Host-process one event under chaos: when allocation faults are
/// armed, stage into a fresh `FaultyContext` collection wired to the
/// run's shared trigger (fresh per attempt — a half-built collection
/// from a failed attempt is simply dropped, never retried into);
/// otherwise a plain owned staging destination. Deliberately not drawn
/// from the stage pool: injected panics must not be able to wedge
/// pooled state.
fn process_host_chaos(ev: &RawEvent, fault: &FaultState) -> (usize, f64, usize) {
    if fault.plan.alloc_fail_every.is_some() {
        let info = FaultyInfo::<CountingContext> {
            inner: CountingInfo::default(),
            faults: fault.alloc_cell.clone(),
        };
        let mut staged = ParticleCollection::build()
            .layout::<AoS<FaultyContext<CountingContext>>>()
            .context(info)
            .finish();
        process_host_staged(ev, &mut staged)
    } else {
        let mut staged = ParticleCollection::<AoS>::new();
        process_host_staged(ev, &mut staged)
    }
}

/// The guarded retry/quarantine path (DESIGN.md §10): process one event
/// on the host with every attempt under `catch_unwind`, backing off
/// exponentially between attempts; past the plan's retry budget the
/// event is poison-quarantined (reported in the run's
/// [`PipelineReport::quarantined`], never silently dropped).
/// `prior_fault` marks events that already hit an injector upstream
/// (worker kill, device error, dead queue) so a first-attempt success
/// still counts as a recovery.
fn recover_event(
    entry: LedgerEntry,
    fault: &FaultState,
    tx: &std::sync::mpsc::Sender<EventResult>,
    metrics: &Arc<PipelineMetrics>,
    prior_fault: bool,
) {
    use std::sync::atomic::Ordering::Relaxed;
    let mut attempt: u32 = 0;
    loop {
        let outcome = catch_unwind(AssertUnwindSafe(|| process_host_chaos(&entry.ev, fault)));
        match outcome {
            Ok((n, energy, bytes)) => {
                let latency = entry.enqueued.elapsed();
                metrics.events_host.fetch_add(1, Relaxed);
                metrics.particles_out.fetch_add(n, Relaxed);
                metrics.planned_transfers.fetch_add(1, Relaxed);
                metrics.planned_bytes.fetch_add(bytes, Relaxed);
                metrics.host_latency.record(latency);
                metrics.e2e_latency.record(latency);
                if prior_fault || attempt > 0 {
                    metrics.fault_recovered.fetch_add(1, Relaxed);
                }
                let _ = tx.send(EventResult {
                    event_id: entry.ev.event_id,
                    route: Route::Host,
                    n_particles: n,
                    total_energy: energy,
                    latency,
                });
                return;
            }
            Err(_) => {
                attempt += 1;
                if attempt > fault.plan.retry_budget {
                    metrics.fault_quarantined.fetch_add(1, Relaxed);
                    fault.quarantine(entry.ev.event_id);
                    eprintln!(
                        "event {} quarantined after {attempt} failed attempts",
                        entry.ev.event_id
                    );
                    return;
                }
                metrics.fault_requeued.fetch_add(1, Relaxed);
                std::thread::sleep(Duration::from_millis(fault.plan.backoff_ms(attempt)));
            }
        }
    }
}

/// Body of one device worker thread: owns its own `Engine` (PJRT
/// handles are single-threaded), event staging state, and `Batcher`;
/// drains its own bounded queue. On engine-load failure it degrades to
/// a host-path drain (the router already committed events here); on a
/// per-event device error it falls back to the host path for that
/// event. Runs under the supervisor in `run_pipeline`, which recovers
/// the shared ledger's in-flight events and respawns the loop (fresh
/// engine) if this body panics.
#[allow(clippy::too_many_arguments)]
fn device_worker_loop(
    dev_rx: &std::sync::mpsc::Receiver<Task>,
    tx: &std::sync::mpsc::Sender<EventResult>,
    metrics: &Arc<PipelineMetrics>,
    gauge: &QueueGauge,
    max_batch: &Arc<AtomicUsize>,
    warm_buckets: &[usize],
    pool: &Arc<StagePool>,
    tapes: Option<&Arc<RouteTapes>>,
    fault: &Arc<FaultState>,
    ledger: &WorkerLedger,
) {
    use std::sync::atomic::Ordering::Relaxed;
    // Every dequeue is admitted to the ledger *before* anything can
    // fail (including the injected kill below), so a worker death never
    // strands an event. The gauge is decremented here too: once off the
    // channel the event no longer occupies device-queue depth, whether
    // it ends up processed, recovered or quarantined.
    let admit = |t: Task| -> Task {
        gauge.dec();
        ledger.admit(&t.ev, t.enqueued);
        fault.on_device_dequeue(); // may panic: the injected worker kill
        t
    };
    let engine = match Engine::load_default() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("device worker disabled: {e:#}");
            // Drain and bounce everything to nowhere: the router
            // already sent events here, so process on host path.
            while let Ok(task) = dev_rx.recv() {
                let task = admit(task);
                let mut staged = pool.checkout();
                let (n, energy, bytes) = process_host_staged(&task.ev, &mut *staged);
                let latency = task.enqueued.elapsed();
                metrics.events_host.fetch_add(1, Relaxed);
                metrics.particles_out.fetch_add(n, Relaxed);
                metrics.planned_transfers.fetch_add(1, Relaxed);
                metrics.planned_bytes.fetch_add(bytes, Relaxed);
                metrics.e2e_latency.record(latency);
                ledger.settle(task.ev.event_id);
                let _ = tx.send(EventResult {
                    event_id: task.ev.event_id,
                    route: Route::Host,
                    n_particles: n,
                    total_energy: energy,
                    latency,
                });
            }
            return;
        }
    };
    // Wrap the engine in the chaos fuse (one relaxed load per event
    // when disarmed). The fuse is shared through `FaultState`, so a
    // respawned worker's fresh engine continues the same schedule.
    let engine = FaultyEngine::with_fuse(engine, fault.engine_fuse.clone());
    // Pre-compile expected buckets so the first event does not pay XLA
    // compilation (EXPERIMENTS.md §Perf-4).
    for &b in warm_buckets {
        if let Err(e) = engine.inner().warm("full_event", b, b) {
            eprintln!("device warmup for {b}x{b} skipped: {e:#}");
        }
    }
    // Staging state built once at worker startup and reused per event:
    // the host-side sensor collection and the pinned staging buffer its
    // planned copy lands in (the DMA-accounted upload analogue,
    // DESIGN.md §2). The particle output staging is drawn from the
    // stage pool per event.
    let staging_info = StagingInfo::default();
    let mut sensors_host = SensorCollection::<SoAVec>::new();
    let mut sensors_staged =
        SensorCollection::<SoAVec<StagingContext>>::new_in(staging_info.clone());
    let mut warmed_bucket = None;
    let mut batcher: Batcher<Task> = Batcher::new(max_batch.load(Relaxed).max(1));
    loop {
        // Refresh the (possibly adaptive) batch bound before each
        // wakeup; with a fixed config the load returns the same value
        // every iteration.
        batcher.set_max_batch(max_batch.load(Relaxed).max(1));
        // Block for one task, then opportunistically drain more.
        match dev_rx.recv() {
            Ok(t) => {
                let t = admit(t);
                batcher.push(t.ev.rows, t);
                while let Ok(t) = dev_rx.try_recv() {
                    let t = admit(t);
                    batcher.push(t.ev.rows, t);
                }
            }
            Err(_) if batcher.is_empty() => break,
            Err(_) => {}
        }
        while !batcher.is_empty() {
            // Peek the upcoming bucket and pre-compile its executable
            // off the per-event path (warm_buckets may not have covered
            // it).
            if let Some(b) = batcher.next_bucket() {
                if warmed_bucket != Some(b) {
                    let _ = engine.inner().warm("full_event", b, b);
                    warmed_bucket = Some(b);
                }
            }
            let batch = batcher.drain_batch();
            metrics.device_batches.fetch_add(1, Relaxed);
            for (_, task) in batch {
                // Stage the event through the pinned buffer: the cached
                // host→staging plan reuses the buffer and books the H2D
                // traffic the upload represents.
                task.ev.fill_collection(&mut sensors_host);
                let up = sensors_host.stage_into(&mut sensors_staged);
                metrics.planned_transfers.fetch_add(1, Relaxed);
                metrics.planned_bytes.fetch_add(up.bytes, Relaxed);
                let mut particles_staged = pool.checkout();
                let outcome = match tapes {
                    Some(t) => {
                        process_device_staged_traced(&engine, &task.ev, &mut *particles_staged, t)
                    }
                    None => process_device_staged(&engine, &task.ev, &mut *particles_staged),
                };
                match outcome {
                    Ok((n, energy, timing, bytes)) => {
                        let latency = task.enqueued.elapsed();
                        metrics.events_device.fetch_add(1, Relaxed);
                        metrics.particles_out.fetch_add(n, Relaxed);
                        metrics.planned_transfers.fetch_add(1, Relaxed);
                        metrics.planned_bytes.fetch_add(bytes, Relaxed);
                        metrics
                            .device_upload_us
                            .fetch_add(timing.upload.as_micros() as u64, Relaxed);
                        metrics
                            .device_execute_us
                            .fetch_add(timing.execute.as_micros() as u64, Relaxed);
                        metrics
                            .device_download_us
                            .fetch_add(timing.download.as_micros() as u64, Relaxed);
                        metrics.device_latency.record(latency);
                        metrics.e2e_latency.record(latency);
                        ledger.settle(task.ev.event_id);
                        let _ = tx.send(EventResult {
                            event_id: task.ev.event_id,
                            route: Route::Device,
                            n_particles: n,
                            total_energy: energy,
                            latency,
                        });
                    }
                    Err(e) if fault.plan.any_armed() => {
                        // Chaos runs route device errors (injected or
                        // real) through the guarded retry/quarantine
                        // path, which sends or quarantines the event
                        // itself.
                        eprintln!(
                            "device failed on event {}: {e:#}; guarded host recovery",
                            task.ev.event_id
                        );
                        recover_event(
                            LedgerEntry { ev: task.ev.clone(), enqueued: task.enqueued },
                            fault,
                            tx,
                            metrics,
                            true,
                        );
                        ledger.settle(task.ev.event_id);
                    }
                    Err(e) => {
                        eprintln!(
                            "device failed on event {}: {e:#}; host fallback",
                            task.ev.event_id
                        );
                        let (n, energy, bytes) =
                            process_host_staged(&task.ev, &mut *particles_staged);
                        let latency = task.enqueued.elapsed();
                        metrics.events_host.fetch_add(1, Relaxed);
                        metrics.particles_out.fetch_add(n, Relaxed);
                        metrics.planned_transfers.fetch_add(1, Relaxed);
                        metrics.planned_bytes.fetch_add(bytes, Relaxed);
                        metrics.e2e_latency.record(latency);
                        ledger.settle(task.ev.event_id);
                        let _ = tx.send(EventResult {
                            event_id: task.ev.event_id,
                            route: Route::Host,
                            n_particles: n,
                            total_energy: energy,
                            latency,
                        });
                    }
                }
            }
        }
    }
}

/// Dispatch one adaptive host group: a single pool task processes the
/// buffered events back-to-back over one pooled staging destination,
/// releasing each event's gate permit as it completes. Grouping trades
/// per-event spawn overhead against tail latency; the AIMD controller
/// moves the group size along exactly that trade-off.
#[allow(clippy::too_many_arguments)]
fn flush_host_group(
    group: Vec<(Task, GatePermit)>,
    host_pool: &ThreadPool,
    res_tx: &std::sync::mpsc::Sender<EventResult>,
    metrics: &Arc<PipelineMetrics>,
    stage_pool: &Arc<StagePool>,
    tapes: Option<Arc<RouteTapes>>,
    fault: &Arc<FaultState>,
    staging: Option<LayoutChoice>,
) {
    if group.is_empty() {
        return;
    }
    let tx = res_tx.clone();
    let metrics = metrics.clone();
    let pool = stage_pool.clone();
    let fault = fault.clone();
    host_pool.spawn(move || {
        use std::sync::atomic::Ordering::Relaxed;
        if fault.plan.guard_host() {
            // Chaos: per-event guarded retry/quarantine instead of the
            // grouped fast path (permits still release per event).
            for (task, permit) in group {
                recover_event(
                    LedgerEntry { ev: task.ev, enqueued: task.enqueued },
                    &fault,
                    &tx,
                    &metrics,
                    false,
                );
                drop(permit);
            }
            return;
        }
        let mut staged = pool.checkout();
        for (task, permit) in group {
            let (n, energy, bytes) = match staging {
                Some(choice) => process_host_selected(&task.ev, choice, tapes.as_deref()),
                None => match &tapes {
                    Some(t) => process_host_staged_traced(&task.ev, &mut *staged, t),
                    None => process_host_staged(&task.ev, &mut *staged),
                },
            };
            let latency = task.enqueued.elapsed();
            metrics.events_host.fetch_add(1, Relaxed);
            metrics.particles_out.fetch_add(n, Relaxed);
            metrics.planned_transfers.fetch_add(1, Relaxed);
            metrics.planned_bytes.fetch_add(bytes, Relaxed);
            metrics.host_latency.record(latency);
            metrics.e2e_latency.record(latency);
            let _ = tx.send(EventResult {
                event_id: task.ev.event_id,
                route: Route::Host,
                n_particles: n,
                total_energy: energy,
                latency,
            });
            drop(permit);
        }
    });
}

/// Run the full pipeline to completion.
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineReport> {
    // Compile-once setup: register the EDM's specialized rungs and warm
    // the staging plans before any worker starts, so every per-event
    // plan lookup below is a cache hit.
    crate::edm::convert::register_edm_specializations();
    let _ = transfer::plan_for::<SoAVec, AoS>(&ParticleProps::schema());
    let _ = transfer::plan_for::<SoAVec, AoS<StageCtx>>(&ParticleProps::schema());
    if cfg.device {
        let _ = transfer::plan_for::<SoAVec, SoAVec<StagingContext>>(&SensorProps::schema());
    }
    if let Some(choice) = cfg.staging_layout {
        // Autotuner-selected staging layout (satellite of the tuning
        // loop): warm its plan so the per-event cost is allocation only.
        let _ = crate::marionette::trace::warm_staging_plan(choice, &ParticleProps::schema());
    }
    // Pre-compile the chaos staging plan before faults arm, so the
    // first guarded recovery doesn't pay (or trip on) plan compilation.
    if cfg.fault.as_ref().map_or(false, |p| p.alloc_fail_every.is_some()) {
        let _ = transfer::plan_for::<SoAVec, AoS<FaultyContext<CountingContext>>>(
            &ParticleProps::schema(),
        );
    }

    // Amortise-once setup: the stage pool every worker draws per-event
    // staging destinations from (shared across runs unless the config
    // injects a private one).
    let stage_pool = cfg.stage_pool.clone().unwrap_or_else(StagePool::shared);

    // Chaos control plane (DESIGN.md §10): always present so the
    // supervision and recovery paths have one shape; an inert plan
    // (clean run) arms nothing and costs one relaxed counter bump per
    // device dequeue.
    let fault = FaultState::arm(cfg.fault.clone().unwrap_or_else(|| FaultPlan::new(cfg.seed)));

    let metrics = Arc::new(PipelineMetrics::default());
    let gauge = QueueGauge::default();
    let router = Router::new(cfg.policy, cfg.device, gauge.clone());

    // Results are unbounded: the collector (this thread) only starts
    // draining after the source loop finishes, so a bounded results
    // channel would deadlock under tight input backpressure.
    let (res_tx, res_rx) = channel::<EventResult>();

    let start = Instant::now();

    // Host path: a per-run work-stealing pool. Each routed event is one
    // spawned task (stealable by any idle worker — no shared receiver
    // mutex); the gate bounds in-flight tasks to `queue_depth`, which is
    // the backpressure the old bounded host channel provided.
    let host_pool = ThreadPool::new(cfg.host_workers.max(1));
    let host_gate = Gate::new(cfg.queue_depth);

    // Adaptive batch control (DESIGN.md §9): one shared knob, read by
    // every device batcher and by the host group dispatcher below. The
    // effective ceiling is clamped to half the gate depth so the source
    // can never hold every permit while still waiting to fill a group
    // (buffered permits < gate limit ⇒ some in-flight task can always
    // finish and wake the source: deadlock-free by construction).
    let adaptive = cfg.adaptive.clone().map(|mut a| {
        a.max_batch = a.max_batch.clamp(1, (cfg.queue_depth / 2).max(1));
        a.min_batch = a.min_batch.clamp(1, a.max_batch);
        a
    });
    let mut controller = adaptive.as_ref().map(AimdBatchController::new);
    let shared_max_batch = Arc::new(AtomicUsize::new(
        controller.as_ref().map(|c| c.current()).unwrap_or(cfg.max_batch.max(1)),
    ));

    // Device path: N worker threads, each owning its own engine and
    // bounded queue (the engine's PJRT handles are single-threaded).
    // The router spills on the *aggregate* gauge across workers.
    let mut dev_txs = Vec::new();
    let mut dev_threads = Vec::new();
    if cfg.device {
        for _ in 0..cfg.device_workers.max(1) {
            let (dev_tx, dev_rx) = sync_channel::<Task>(cfg.queue_depth);
            let tx = res_tx.clone();
            let metrics = metrics.clone();
            let gauge = gauge.clone();
            let max_batch = shared_max_batch.clone();
            let warm_buckets = cfg.warm_buckets.clone();
            let pool = stage_pool.clone();
            let tapes = cfg.trace.clone();
            let fault = fault.clone();
            dev_txs.push(dev_tx);
            dev_threads.push(std::thread::spawn(move || {
                // Supervisor (DESIGN.md §10): the worker body runs under
                // catch_unwind; on a panic the in-flight ledger is
                // recovered onto the host path and the loop respawns
                // with a fresh engine, continuing the same queue. With
                // `worker_abort` the panic is re-raised instead so the
                // join path's error reporting can be regression-tested.
                let ledger = WorkerLedger::default();
                loop {
                    let run = catch_unwind(AssertUnwindSafe(|| {
                        device_worker_loop(
                            &dev_rx,
                            &tx,
                            &metrics,
                            &gauge,
                            &max_batch,
                            &warm_buckets,
                            &pool,
                            tapes.as_ref(),
                            &fault,
                            &ledger,
                        )
                    }));
                    match run {
                        Ok(()) => break,
                        Err(payload) => {
                            if fault.plan.worker_abort {
                                resume_unwind(payload);
                            }
                            metrics
                                .fault_respawns
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            eprintln!(
                                "device worker panicked; recovering {} in-flight event(s) \
                                 and respawning",
                                ledger.0.lock().unwrap().len()
                            );
                            for entry in ledger.drain() {
                                recover_event(entry, &fault, &tx, &metrics, true);
                            }
                        }
                    }
                }
            }));
        }
    }

    // Source + router (this thread).
    let mut gen = EventGenerator::new(cfg.event.clone(), cfg.seed);
    let mut next_dev = 0usize;
    let mut host_buffer: Vec<(Task, GatePermit)> = Vec::new();
    let mut prev_buckets = metrics.e2e_latency.bucket_counts();
    let observe_every = adaptive.as_ref().map(|a| a.observe_every.max(1)).unwrap_or(1);
    for produced in 0..cfg.n_events {
        let ev = gen.generate();
        metrics.events_in.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let d = router.decide(ev.rows, ev.cols);
        if d.spilled {
            metrics.events_spilled.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        let task = Task { ev, enqueued: Instant::now() };
        match d.route {
            Route::Host if controller.is_some() => {
                // Adaptive host path: buffer up to the controlled batch
                // size, then dispatch the group as one pool task. The
                // permits are acquired here (backpressure holds) and
                // released per event inside the group.
                let permit = host_gate.acquire();
                host_buffer.push((task, permit));
                let bound = shared_max_batch.load(std::sync::atomic::Ordering::Relaxed);
                if host_buffer.len() >= bound.max(1) {
                    flush_host_group(
                        std::mem::take(&mut host_buffer),
                        &host_pool,
                        &res_tx,
                        &metrics,
                        &stage_pool,
                        cfg.trace.clone(),
                        &fault,
                        cfg.staging_layout,
                    );
                }
            }
            Route::Host => {
                let permit = host_gate.acquire();
                let tx = res_tx.clone();
                let metrics = metrics.clone();
                let pool = stage_pool.clone();
                let tapes = cfg.trace.clone();
                let fault = fault.clone();
                let staging = cfg.staging_layout;
                host_pool.spawn(move || {
                    let _permit = permit;
                    if fault.plan.guard_host() {
                        // Chaos: host events can hit the armed
                        // allocation/transfer injectors, so they run
                        // the guarded retry/quarantine path. The pool's
                        // own catch_unwind would otherwise swallow an
                        // injected panic and silently lose the event.
                        recover_event(
                            LedgerEntry { ev: task.ev, enqueued: task.enqueued },
                            &fault,
                            &tx,
                            &metrics,
                            false,
                        );
                        return;
                    }
                    // Draw the staging destination from this thread's
                    // pool shard: after warmup this is a warm collection
                    // whose capacity already fits the workload — the
                    // cached plan (a lock-free per-thread handle hit)
                    // executes into it with zero allocations.
                    let mut staged = pool.checkout();
                    let (n, energy, bytes) = match staging {
                        Some(choice) => {
                            process_host_selected(&task.ev, choice, tapes.as_deref())
                        }
                        None => match &tapes {
                            Some(t) => process_host_staged_traced(&task.ev, &mut *staged, t),
                            None => process_host_staged(&task.ev, &mut *staged),
                        },
                    };
                    let latency = task.enqueued.elapsed();
                    use std::sync::atomic::Ordering::Relaxed;
                    metrics.events_host.fetch_add(1, Relaxed);
                    metrics.particles_out.fetch_add(n, Relaxed);
                    metrics.planned_transfers.fetch_add(1, Relaxed);
                    metrics.planned_bytes.fetch_add(bytes, Relaxed);
                    metrics.host_latency.record(latency);
                    metrics.e2e_latency.record(latency);
                    let _ = tx.send(EventResult {
                        event_id: task.ev.event_id,
                        route: Route::Host,
                        n_particles: n,
                        total_energy: energy,
                        latency,
                    });
                });
            }
            Route::Device => {
                gauge.inc();
                let w = next_dev % dev_txs.len();
                next_dev += 1;
                if let Err(send_err) = dev_txs[w].send(task) {
                    // The worker died unrecoverably (its supervisor
                    // aborted): the event comes back in the error and
                    // is re-routed to the guarded host path instead of
                    // failing the whole run.
                    gauge.dec();
                    let task = send_err.0;
                    metrics
                        .fault_requeued
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    recover_event(
                        LedgerEntry { ev: task.ev, enqueued: task.enqueued },
                        &fault,
                        &res_tx,
                        &metrics,
                        true,
                    );
                }
            }
        }
        // Measured feedback: every `observe_every` dispatched events the
        // controller reads the load (outstanding host permits + device
        // queue depth) and the *windowed* e2e p99 (bucket delta since
        // the last observation — the cumulative histogram would be far
        // too sluggish to steer with), then publishes the next bound.
        if let Some(c) = controller.as_mut() {
            if (produced + 1) % observe_every == 0 {
                let cur = metrics.e2e_latency.bucket_counts();
                let p99 = quantile_between(&prev_buckets, &cur, 0.99)
                    .map(|d| d.as_micros() as u64);
                prev_buckets = cur;
                let depth = host_gate.in_flight() + gauge.depth();
                let next = c.observe(depth, p99);
                shared_max_batch.store(next, std::sync::atomic::Ordering::Relaxed);
            }
        }
    }
    // Tail group: whatever is still buffered below the batch bound.
    flush_host_group(
        host_buffer,
        &host_pool,
        &res_tx,
        &metrics,
        &stage_pool,
        cfg.trace.clone(),
        &fault,
        cfg.staging_layout,
    );
    drop(res_tx);
    drop(dev_txs);

    // Collector: terminates once every host task and device worker has
    // dropped its result sender. A worker whose panic escaped
    // supervision must not abort the run here — it is counted and
    // reported as a `PipelineError` carrying the partial report.
    let mut results: Vec<EventResult> = res_rx.iter().collect();
    let mut panicked_workers = 0usize;
    for w in dev_threads {
        if w.join().is_err() {
            panicked_workers += 1;
        }
    }
    results.sort_by_key(|r| r.event_id);
    let wall = start.elapsed();

    // The transfer hook is process-global: disarm before anything else
    // in this process runs transfers again.
    fault.disarm();
    let quarantined = fault.take_quarantined();

    metrics.set_pool_counters(&stage_pool);
    metrics.set_sched_counters(&host_pool.stats());
    {
        use std::sync::atomic::Ordering::Relaxed;
        metrics.fault_injected.store(fault.injected_total(), Relaxed);
        match &controller {
            Some(c) => {
                metrics.batch_grows.store(c.grows(), Relaxed);
                metrics.batch_shrinks.store(c.shrinks(), Relaxed);
                metrics.max_batch_final.store(c.current(), Relaxed);
            }
            None => metrics.max_batch_final.store(cfg.max_batch.max(1), Relaxed),
        }
    }
    let mut snapshot = metrics.snapshot();
    if let Some(t) = &cfg.trace {
        snapshot.trace_routes = t.summaries();
    }
    let report = PipelineReport { wall, results, metrics: snapshot, quarantined };
    if panicked_workers > 0 {
        return Err(anyhow::Error::new(PipelineError { panicked_workers, report }));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{AdaptiveBatch, RoutePolicy};
    use crate::edm::generator::EventConfig;

    fn base_cfg(n: usize) -> PipelineConfig {
        let mut cfg = PipelineConfig::new(EventConfig::grid(32, 32, 3), n);
        cfg.host_workers = 2;
        cfg.seed = 77;
        cfg
    }

    #[test]
    fn host_only_processes_everything() {
        let mut cfg = base_cfg(12);
        cfg.device = false;
        cfg.policy = RoutePolicy::HostOnly;
        let rep = run_pipeline(&cfg).unwrap();
        assert_eq!(rep.results.len(), 12);
        assert_eq!(rep.metrics.events_host, 12);
        assert_eq!(rep.metrics.events_device, 0);
        assert!(rep.total_particles() > 0, "3 deposits per event must seed");
        // One planned staging transfer per event, through the cache.
        assert_eq!(rep.metrics.planned_transfers, 12);
        assert!(rep.metrics.planned_bytes > 0);
        // Every event drew its staging destination from the stage pool
        // (counters are shared-pool cumulative, so only lower bounds).
        assert!(
            rep.metrics.stage_hits + rep.metrics.stage_misses >= 12,
            "stage pool not used: {} hits + {} misses",
            rep.metrics.stage_hits,
            rep.metrics.stage_misses,
        );
        // Results are sorted and complete.
        for (i, r) in rep.results.iter().enumerate() {
            assert_eq!(r.event_id, i as u64);
        }
    }

    #[test]
    fn selected_staging_layout_matches_default_physics() {
        // Satellite of the autotuning loop: routing the layout
        // selector's recommendation through `staging_layout` must not
        // change any observable physics — the staging layout only moves
        // bytes around. Compare every per-event result bit-for-bit
        // against the pooled default across all four choices.
        let run = |staging: Option<LayoutChoice>| {
            let mut cfg = base_cfg(10);
            cfg.device = false;
            cfg.policy = RoutePolicy::HostOnly;
            cfg.staging_layout = staging;
            run_pipeline(&cfg).unwrap()
        };
        let base = run(None);
        for choice in [
            LayoutChoice::AoS,
            LayoutChoice::SoAVec,
            LayoutChoice::SoABlob,
            LayoutChoice::AoSoA8,
        ] {
            let rep = run(Some(choice));
            assert_eq!(rep.results.len(), base.results.len(), "{choice:?}");
            for (got, want) in rep.results.iter().zip(&base.results) {
                assert_eq!(got.event_id, want.event_id, "{choice:?}");
                assert_eq!(got.n_particles, want.n_particles, "{choice:?}");
                assert_eq!(
                    got.total_energy.to_bits(),
                    want.total_energy.to_bits(),
                    "{choice:?} drifted on event {}",
                    want.event_id,
                );
            }
        }
    }

    #[test]
    fn private_stage_pool_reaches_steady_state() {
        let pool = StagePool::new();
        let mk = |n: usize| {
            let mut cfg = base_cfg(n);
            cfg.device = false;
            cfg.policy = RoutePolicy::HostOnly;
            cfg.host_workers = 1;
            cfg.stage_pool = Some(pool.clone());
            cfg
        };
        run_pipeline(&mk(10)).unwrap();
        let warm_b = pool.byte_stats();
        let warm_c = pool.collection_stats();
        let warm_live = pool.live_allocs();
        // Same workload again: the single worker replays the identical
        // event stream through the warm collection — no fresh
        // collections, no byte-pool misses, no net allocations.
        let rep = run_pipeline(&mk(10)).unwrap();
        assert_eq!(rep.results.len(), 10);
        let b = pool.byte_stats();
        let c = pool.collection_stats();
        assert_eq!(c.misses, warm_c.misses, "fresh staging collections built");
        assert!(c.hits >= warm_c.hits + 10);
        assert_eq!(b.misses, warm_b.misses, "byte-pool misses in steady state");
        assert_eq!(pool.live_allocs(), warm_live, "net allocations in steady state");
        // The run's metrics surface the same counters.
        assert_eq!(rep.metrics.pool_misses, b.misses);
        assert_eq!(rep.metrics.stage_misses, c.misses);
    }

    #[test]
    fn device_only_matches_host_physics() {
        if Engine::load_default().is_err() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut host_cfg = base_cfg(6);
        host_cfg.device = false;
        host_cfg.policy = RoutePolicy::HostOnly;
        let host = run_pipeline(&host_cfg).unwrap();

        let mut dev_cfg = base_cfg(6);
        dev_cfg.policy = RoutePolicy::DeviceOnly;
        let dev = run_pipeline(&dev_cfg).unwrap();

        assert_eq!(dev.metrics.events_device, 6);
        assert_eq!(host.results.len(), dev.results.len());
        for (h, d) in host.results.iter().zip(&dev.results) {
            assert_eq!(h.event_id, d.event_id);
            assert_eq!(h.n_particles, d.n_particles, "event {}", h.event_id);
            let rel = (h.total_energy - d.total_energy).abs()
                / h.total_energy.abs().max(1.0);
            assert!(rel < 1e-3, "energy drift {rel} on event {}", h.event_id);
        }
    }

    #[test]
    fn auto_policy_routes_small_grids_to_host() {
        let mut cfg = base_cfg(8);
        cfg.policy = RoutePolicy::Auto { min_device_cells: 128 * 128, max_device_queue: 4 };
        // 32x32 events: all below the crossover.
        let rep = run_pipeline(&cfg).unwrap();
        assert_eq!(rep.metrics.events_host, 8);
        assert_eq!(rep.metrics.events_device, 0);
    }

    #[test]
    fn adaptive_host_run_completes_and_moves_the_knob() {
        let mut cfg = base_cfg(64);
        cfg.device = false;
        cfg.policy = RoutePolicy::HostOnly;
        cfg.queue_depth = 16;
        cfg.adaptive = Some(AdaptiveBatch {
            min_batch: 1,
            max_batch: 8,
            grow_step: 2,
            shrink_factor: 0.5,
            // Unreachable target: growth is gated only on depth here.
            p99_target_us: u64::MAX / 4,
            grow_headroom: 0.8,
            depth_threshold: 0,
            observe_every: 8,
            cooldown_obs: 2,
        });
        let rep = run_pipeline(&cfg).unwrap();
        assert_eq!(rep.results.len(), 64);
        assert_eq!(rep.metrics.events_host, 64);
        // depth_threshold 0: every observation window grows until the
        // (queue-depth-clamped) ceiling, so the knob must have moved.
        assert!(rep.metrics.batch_grows >= 1, "controller never grew");
        assert!(rep.metrics.max_batch_final >= 1);
        assert!(rep.metrics.max_batch_final <= 8, "ceiling violated");
        // Nothing lost or duplicated by group dispatch.
        for (i, r) in rep.results.iter().enumerate() {
            assert_eq!(r.event_id, i as u64);
        }
        assert!(rep.report().contains("adaptive:"));
    }

    #[test]
    fn traced_run_fills_route_summaries_and_matches_untraced_physics() {
        let mut cfg = base_cfg(6);
        cfg.device = false;
        cfg.policy = RoutePolicy::HostOnly;
        cfg.trace = Some(RouteTapes::new());
        let rep = run_pipeline(&cfg).unwrap();
        assert_eq!(rep.results.len(), 6);
        let routes: Vec<&str> = rep.metrics.trace_routes.iter().map(|r| r.route).collect();
        assert!(routes.contains(&"staging"), "staging tape empty: {routes:?}");
        assert!(routes.contains(&"reco"), "reco tape empty: {routes:?}");
        assert!(!routes.contains(&"gather"), "gather taped on a host-only run");
        for r in &rep.metrics.trace_routes {
            assert!(r.total_reads > 0, "route {} recorded no reads", r.route);
            assert!(!r.per_field.is_empty());
        }
        // Calibration writes energy/noise/sig per sensor.
        let staging =
            rep.metrics.trace_routes.iter().find(|r| r.route == "staging").unwrap();
        assert!(staging.total_writes > 0, "calibration writes not taped");

        let mut plain = base_cfg(6);
        plain.device = false;
        plain.policy = RoutePolicy::HostOnly;
        let pl = run_pipeline(&plain).unwrap();
        for (a, b) in rep.results.iter().zip(&pl.results) {
            assert_eq!(a.event_id, b.event_id);
            assert_eq!(a.n_particles, b.n_particles, "tracing changed physics");
            assert!((a.total_energy - b.total_energy).abs() < 1e-9);
        }
    }

    #[test]
    fn throughput_reported() {
        let mut cfg = base_cfg(4);
        cfg.device = false;
        let rep = run_pipeline(&cfg).unwrap();
        assert!(rep.events_per_sec() > 0.0);
        assert!(rep.report().contains("events"));
        assert!(rep.report().contains("plan-cache"));
        assert!(rep.report().contains("pool: stage"));
        assert!(rep.report().contains("fault:"));
        assert!(rep.quarantined.is_empty(), "clean run must quarantine nothing");
    }

    /// The shutdown regression (was `w.join().expect(...)` — a worker
    /// panic aborted the whole process): an unsupervised worker death
    /// must surface as a typed `Err` that still carries the partial
    /// metrics and every result that completed.
    #[test]
    fn worker_panic_returns_err_with_partial_metrics() {
        use crate::coordinator::fault::FaultPlan;
        let mut cfg = base_cfg(8);
        cfg.policy = RoutePolicy::DeviceOnly;
        cfg.device_workers = 1;
        cfg.host_workers = 1;
        cfg.fault = Some(FaultPlan::new(1).kill_device_at(2).worker_abort(true));
        let err = run_pipeline(&cfg).unwrap_err();
        let pe = err
            .downcast_ref::<PipelineError>()
            .expect("worker panic must downcast to PipelineError");
        assert_eq!(pe.panicked_workers, 1);
        assert_eq!(pe.report.metrics.events_in, 8, "partial metrics lost");
        assert!(pe.report.results.len() < 8, "the killed batch cannot have completed");
        assert!(pe.report.metrics.fault_injected >= 1);
        assert!(format!("{err}").contains("device worker(s) panicked"));
    }

    /// Supervised kill: the worker dies mid-run, in-flight events are
    /// recovered from the ledger onto the host path, the worker
    /// respawns, and every submitted event lands in exactly one of
    /// {results, quarantined} with clean-run physics.
    #[test]
    fn chaos_kill_recovers_every_event() {
        use crate::coordinator::fault::FaultPlan;
        let mut cfg = base_cfg(12);
        cfg.policy = RoutePolicy::DeviceOnly;
        cfg.device_workers = 1;
        cfg.host_workers = 1;
        cfg.fault = Some(FaultPlan::new(9).kill_device_at(3));
        let rep = run_pipeline(&cfg).unwrap();
        let mut seen: Vec<u64> = rep.results.iter().map(|r| r.event_id).collect();
        seen.extend(rep.quarantined.iter().copied());
        seen.sort_unstable();
        assert_eq!(seen, (0..12).collect::<Vec<u64>>(), "exactly-once accounting");
        assert!(rep.metrics.fault_injected >= 1, "kill never fired");
        assert!(rep.metrics.fault_respawns >= 1, "worker never respawned");
        assert!(rep.metrics.fault_recovered >= 1, "in-flight events not recovered");

        let mut clean = base_cfg(12);
        clean.device = false;
        clean.policy = RoutePolicy::HostOnly;
        clean.host_workers = 1;
        let golden = run_pipeline(&clean).unwrap();
        for r in &rep.results {
            let g = &golden.results[r.event_id as usize];
            assert_eq!(g.event_id, r.event_id);
            assert_eq!(g.n_particles, r.n_particles, "event {}", r.event_id);
            let rel =
                (g.total_energy - r.total_energy).abs() / g.total_energy.abs().max(1.0);
            assert!(rel < 1e-3, "energy drift {rel} on event {}", r.event_id);
        }
    }
}

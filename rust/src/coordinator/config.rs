//! Pipeline configuration.

use std::sync::Arc;

use crate::edm::generator::EventConfig;

use super::pipeline::{RouteTapes, StagePool};

/// Adaptive (AIMD) batch-size control for event dispatch (DESIGN.md §9).
/// `None` on [`PipelineConfig::adaptive`] keeps the fixed `max_batch`
/// behaviour; `Some` hands the knob to an
/// [`super::batcher::AimdBatchController`] fed by queue depth and the
/// windowed end-to-end p99.
#[derive(Clone, Debug)]
pub struct AdaptiveBatch {
    /// Floor (and starting point) of the controlled batch size.
    pub min_batch: usize,
    /// Ceiling of the controlled batch size.
    pub max_batch: usize,
    /// Additive increase per observation window while the queue is deep.
    pub grow_step: usize,
    /// Multiplicative decrease factor on a p99 breach (e.g. 0.5).
    pub shrink_factor: f64,
    /// End-to-end p99 target in microseconds; above it the batch shrinks.
    pub p99_target_us: u64,
    /// Growth is allowed only while p99 <= target * headroom (deadband
    /// between grow and shrink thresholds; prevents oscillation).
    pub grow_headroom: f64,
    /// Queue depth (in-flight + queued events) required before growing.
    pub depth_threshold: usize,
    /// Controller observation cadence, in completed events.
    pub observe_every: usize,
    /// Observation windows to wait after a shrink before shrinking again.
    pub cooldown_obs: u32,
}

impl Default for AdaptiveBatch {
    fn default() -> Self {
        AdaptiveBatch {
            min_batch: 1,
            max_batch: 64,
            grow_step: 2,
            shrink_factor: 0.5,
            // The histogram buckets latencies by power of two, so the
            // target is generous; the smoke run checks p99 stays within
            // 1.1x of it.
            p99_target_us: 50_000,
            grow_headroom: 0.8,
            depth_threshold: 8,
            observe_every: 64,
            cooldown_obs: 2,
        }
    }
}

/// Where events may execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Everything on CPU workers.
    HostOnly,
    /// Everything on the device worker.
    DeviceOnly,
    /// Grid-size crossover + device-queue spill (the Figure-1 insight:
    /// device wins only above ~100×100, and a saturated device queue
    /// should spill to the host rather than grow latency).
    Auto {
        /// Route to the device when `rows * cols >= min_device_cells`.
        min_device_cells: usize,
        /// Spill to host when the device queue is deeper than this.
        max_device_queue: usize,
    },
}

impl Default for RoutePolicy {
    fn default() -> Self {
        // 100x100 crossover per Figure 1, snapped to our bucket grid.
        RoutePolicy::Auto { min_device_cells: 128 * 128, max_device_queue: 64 }
    }
}

/// Full pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Synthetic workload description.
    pub event: EventConfig,
    /// Number of events to stream.
    pub n_events: usize,
    /// Generator seed.
    pub seed: u64,
    /// CPU worker count.
    pub host_workers: usize,
    /// Enable the device worker.
    pub device: bool,
    /// Device worker count. Each worker owns its own `runtime::Engine`
    /// (the engine is `!Send`), event pool, and warmed plans; the
    /// router spills on the *aggregate* queue depth across workers.
    pub device_workers: usize,
    /// Routing policy.
    pub policy: RoutePolicy,
    /// Bounded queue depth between stages (backpressure).
    pub queue_depth: usize,
    /// Device batcher: max events drained per wakeup.
    pub max_batch: usize,
    /// Grid buckets the device worker pre-compiles before accepting
    /// work (XLA compilation would otherwise land on the first event's
    /// latency).
    pub warm_buckets: Vec<usize>,
    /// Stage pool workers draw per-event staging destinations from.
    /// `None` (the default) shares the process-wide pool so warmup
    /// amortises across runs; tests inject a private pool to observe
    /// its counters in isolation.
    pub stage_pool: Option<Arc<StagePool>>,
    /// Adaptive batch-size control; `None` keeps the fixed `max_batch`.
    pub adaptive: Option<AdaptiveBatch>,
    /// Per-route access-pattern tapes; `None` (the default) runs the
    /// untraced fast paths. `Some` routes staging/reco accessor
    /// traffic through tracing sources feeding these tapes (autotuner
    /// measurement runs only — tracing bypasses the cached-plane fast
    /// path by design).
    pub trace: Option<Arc<RouteTapes>>,
    /// Seeded fault-injection schedule (chaos harness, DESIGN.md §10).
    /// `None` (the default) runs clean with zero overhead; `Some` arms
    /// the memory/engine/transfer injectors and switches event
    /// processing to the guarded retry/quarantine paths.
    pub fault: Option<super::fault::FaultPlan>,
    /// Host staging layout override — the autotuner's recommendation
    /// ([`crate::marionette::trace::recommend_layout`]) routed into the
    /// live staging path. `None` (the default) keeps the pooled AoS
    /// staging collections (the zero-alloc steady-state path); `Some`
    /// stages each host event into a fresh collection of the selected
    /// layout, with its transfer plan pre-warmed at run start.
    pub staging_layout: Option<crate::marionette::trace::LayoutChoice>,
}

impl PipelineConfig {
    pub fn new(event: EventConfig, n_events: usize) -> Self {
        let bucket = event.rows.max(event.cols);
        PipelineConfig {
            event,
            n_events,
            seed: 0xA71A5,
            host_workers: std::thread::available_parallelism()
                .map(|n| (n.get() / 2).max(1))
                .unwrap_or(2),
            device: true,
            device_workers: 1,
            policy: RoutePolicy::default(),
            queue_depth: 128,
            max_batch: 16,
            warm_buckets: vec![bucket],
            stage_pool: None,
            adaptive: None,
            trace: None,
            fault: None,
            staging_layout: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = PipelineConfig::new(EventConfig::grid(64, 64, 3), 10);
        assert!(c.host_workers >= 1);
        assert!(c.queue_depth > 0);
        assert!(matches!(c.policy, RoutePolicy::Auto { .. }));
    }
}

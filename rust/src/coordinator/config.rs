//! Pipeline configuration.

use std::sync::Arc;

use crate::edm::generator::EventConfig;

use super::pipeline::StagePool;

/// Where events may execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Everything on CPU workers.
    HostOnly,
    /// Everything on the device worker.
    DeviceOnly,
    /// Grid-size crossover + device-queue spill (the Figure-1 insight:
    /// device wins only above ~100×100, and a saturated device queue
    /// should spill to the host rather than grow latency).
    Auto {
        /// Route to the device when `rows * cols >= min_device_cells`.
        min_device_cells: usize,
        /// Spill to host when the device queue is deeper than this.
        max_device_queue: usize,
    },
}

impl Default for RoutePolicy {
    fn default() -> Self {
        // 100x100 crossover per Figure 1, snapped to our bucket grid.
        RoutePolicy::Auto { min_device_cells: 128 * 128, max_device_queue: 64 }
    }
}

/// Full pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Synthetic workload description.
    pub event: EventConfig,
    /// Number of events to stream.
    pub n_events: usize,
    /// Generator seed.
    pub seed: u64,
    /// CPU worker count.
    pub host_workers: usize,
    /// Enable the device worker.
    pub device: bool,
    /// Device worker count. Each worker owns its own `runtime::Engine`
    /// (the engine is `!Send`), event pool, and warmed plans; the
    /// router spills on the *aggregate* queue depth across workers.
    pub device_workers: usize,
    /// Routing policy.
    pub policy: RoutePolicy,
    /// Bounded queue depth between stages (backpressure).
    pub queue_depth: usize,
    /// Device batcher: max events drained per wakeup.
    pub max_batch: usize,
    /// Grid buckets the device worker pre-compiles before accepting
    /// work (XLA compilation would otherwise land on the first event's
    /// latency).
    pub warm_buckets: Vec<usize>,
    /// Stage pool workers draw per-event staging destinations from.
    /// `None` (the default) shares the process-wide pool so warmup
    /// amortises across runs; tests inject a private pool to observe
    /// its counters in isolation.
    pub stage_pool: Option<Arc<StagePool>>,
}

impl PipelineConfig {
    pub fn new(event: EventConfig, n_events: usize) -> Self {
        let bucket = event.rows.max(event.cols);
        PipelineConfig {
            event,
            n_events,
            seed: 0xA71A5,
            host_workers: std::thread::available_parallelism()
                .map(|n| (n.get() / 2).max(1))
                .unwrap_or(2),
            device: true,
            device_workers: 1,
            policy: RoutePolicy::default(),
            queue_depth: 128,
            max_batch: 16,
            warm_buckets: vec![bucket],
            stage_pool: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = PipelineConfig::new(EventConfig::grid(64, 64, 3), 10);
        assert!(c.host_workers >= 1);
        assert!(c.queue_depth > 0);
        assert!(matches!(c.policy, RoutePolicy::Auto { .. }));
    }
}

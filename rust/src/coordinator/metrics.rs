//! Pipeline metrics: lock-free counters + log₂-bucket latency histograms.

use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

/// Power-of-two latency histogram from 1 µs to ~1 h.
#[derive(Debug)]
pub struct LatencyHisto {
    /// bucket b counts latencies in [2^b, 2^(b+1)) µs.
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        LatencyHisto {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHisto {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count().max(1);
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (b, c) in self.buckets.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1 << (b + 1));
            }
        }
        self.max()
    }

    /// Point-in-time copy of the raw bucket counters. The adaptive
    /// controller diffs two of these to compute a *windowed* quantile —
    /// the cumulative [`Self::quantile`] is too sluggish for control
    /// once the histogram holds a long history.
    pub fn bucket_counts(&self) -> [u64; 32] {
        std::array::from_fn(|b| self.buckets[b].load(Ordering::Relaxed))
    }
}

/// Quantile of the *delta* between two bucket snapshots (`prev` taken
/// before the window, `cur` after). Returns `None` when no observation
/// landed in the window. Same upper-bound convention as
/// [`LatencyHisto::quantile`].
pub fn quantile_between(prev: &[u64; 32], cur: &[u64; 32], q: f64) -> Option<Duration> {
    let deltas: [u64; 32] = std::array::from_fn(|b| cur[b].saturating_sub(prev[b]));
    let total: u64 = deltas.iter().sum();
    if total == 0 {
        return None;
    }
    let target = (total as f64 * q).ceil() as u64;
    let mut seen = 0;
    for (b, &d) in deltas.iter().enumerate() {
        seen += d;
        if seen >= target {
            return Some(Duration::from_micros(1u64 << (b + 1)));
        }
    }
    Some(Duration::from_micros(1u64 << 32))
}

/// All pipeline counters (shared by reference across threads).
#[derive(Debug, Default)]
pub struct PipelineMetrics {
    pub events_in: AtomicUsize,
    pub events_host: AtomicUsize,
    pub events_device: AtomicUsize,
    pub events_spilled: AtomicUsize,
    pub particles_out: AtomicUsize,
    /// Planned layout/context transfers executed by the workers
    /// (staging copies through cached `TransferPlan`s).
    pub planned_transfers: AtomicUsize,
    /// Payload bytes those planned transfers moved.
    pub planned_bytes: AtomicUsize,
    pub device_batches: AtomicUsize,
    pub device_upload_us: AtomicU64,
    pub device_execute_us: AtomicU64,
    pub device_download_us: AtomicU64,
    /// Staging-collection checkouts served warm from the stage pool.
    pub stage_hits: AtomicUsize,
    /// Staging-collection checkouts that built a fresh collection.
    pub stage_misses: AtomicUsize,
    /// Byte-pool allocations served from a recycled block.
    pub pool_hits: AtomicUsize,
    /// Byte-pool allocations that fell through to the inner allocator.
    pub pool_misses: AtomicUsize,
    /// Byte-pool blocks released by high-water trimming.
    pub pool_trims: AtomicUsize,
    /// Idle bytes parked in the byte pool at snapshot time.
    pub pool_held_bytes: AtomicUsize,
    /// Byte-pool blocks checked out at snapshot time.
    pub pool_outstanding: AtomicUsize,
    /// Net inner allocations of the stage pool's counting heap: flat in
    /// steady state (the zero-alloc-per-event invariant).
    pub pool_live_allocs: AtomicI64,
    /// Work-stealing scheduler counters of the per-run host pool
    /// (stored once at end of run from `ThreadPool::stats`).
    pub sched_injected: AtomicUsize,
    pub sched_local_pushes: AtomicUsize,
    pub sched_steals: AtomicUsize,
    /// Adaptive batch controller: additive grow steps taken.
    pub batch_grows: AtomicU64,
    /// Adaptive batch controller: multiplicative shrinks taken.
    pub batch_shrinks: AtomicU64,
    /// Batch size the controller settled on (fixed `max_batch` when the
    /// controller is off).
    pub max_batch_final: AtomicUsize,
    /// Chaos-harness counters (DESIGN.md §10); all zero on clean runs.
    /// Faults fired by the armed injectors (all four layers).
    pub fault_injected: AtomicU64,
    /// Events that completed successfully after at least one fault hit
    /// them (host reroute, retry success, engine-fault fallback).
    pub fault_recovered: AtomicU64,
    /// Retry attempts made (an event re-submitted after a failure).
    pub fault_requeued: AtomicU64,
    /// Events given up on after the retry budget: reported in
    /// `PipelineReport::quarantined`, never silently dropped.
    pub fault_quarantined: AtomicU64,
    /// Device-worker supervisor restarts (fresh engine after a kill).
    pub fault_respawns: AtomicU64,
    pub host_latency: LatencyHisto,
    pub device_latency: LatencyHisto,
    pub e2e_latency: LatencyHisto,
}

impl PipelineMetrics {
    /// Record the stage pool's counters (called once at end of run; the
    /// pool is shared and monotone, so these are point-in-time values).
    pub fn set_pool_counters(&self, pool: &super::pipeline::StagePool) {
        let b = pool.byte_stats();
        let c = pool.collection_stats();
        self.stage_hits.store(c.hits, Ordering::Relaxed);
        self.stage_misses.store(c.misses, Ordering::Relaxed);
        self.pool_hits.store(b.hits, Ordering::Relaxed);
        self.pool_misses.store(b.misses, Ordering::Relaxed);
        self.pool_trims.store(b.trims, Ordering::Relaxed);
        self.pool_held_bytes.store(b.held_bytes, Ordering::Relaxed);
        self.pool_outstanding.store(b.outstanding, Ordering::Relaxed);
        self.pool_live_allocs.store(pool.live_allocs() as i64, Ordering::Relaxed);
    }

    /// Record the host pool's scheduler counters (end of run).
    pub fn set_sched_counters(&self, s: &crate::util::pool::ThreadPoolStats) {
        self.sched_injected.store(s.injected, Ordering::Relaxed);
        self.sched_local_pushes.store(s.local_pushes, Ordering::Relaxed);
        self.sched_steals.store(s.steals, Ordering::Relaxed);
    }
}

/// Plain-data snapshot for reports.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub events_in: usize,
    pub events_host: usize,
    pub events_device: usize,
    pub events_spilled: usize,
    pub particles_out: usize,
    pub planned_transfers: usize,
    pub planned_bytes: usize,
    /// Process-wide transfer-plan-cache hits at snapshot time.
    pub plan_cache_hits: u64,
    /// Process-wide transfer-plan-cache misses at snapshot time.
    pub plan_cache_misses: u64,
    pub device_batches: usize,
    pub device_upload: Duration,
    pub device_execute: Duration,
    pub device_download: Duration,
    /// Stage-pool collection checkouts served warm / built fresh.
    pub stage_hits: usize,
    pub stage_misses: usize,
    /// Byte-pool hits / misses / trims and point-in-time gauges.
    pub pool_hits: usize,
    pub pool_misses: usize,
    pub pool_trims: usize,
    pub pool_held_bytes: usize,
    pub pool_outstanding: usize,
    /// Net allocations of the stage pool's inner counting heap.
    pub pool_live_allocs: i64,
    pub host_mean: Duration,
    pub device_mean: Duration,
    pub e2e_mean: Duration,
    pub e2e_p50: Duration,
    pub e2e_p95: Duration,
    pub e2e_p99: Duration,
    /// Scheduler counters of the host worker pool (zero on the shared
    /// global pool path or when no host work ran).
    pub sched_injected: usize,
    pub sched_local_pushes: usize,
    pub sched_steals: usize,
    /// Adaptive batch controller activity (zero when the controller is
    /// off).
    pub batch_grows: u64,
    pub batch_shrinks: u64,
    /// Final batch size (the fixed `max_batch` when the controller is
    /// off).
    pub max_batch_final: usize,
    /// Chaos-harness counters (zero on clean runs; DESIGN.md §10).
    pub fault_injected: u64,
    pub fault_recovered: u64,
    pub fault_requeued: u64,
    pub fault_quarantined: u64,
    pub fault_respawns: u64,
    /// Per-route access-pattern summaries; empty unless the run traced
    /// (`PipelineConfig::trace`). Filled by `run_pipeline` after the
    /// counter snapshot.
    pub trace_routes: Vec<crate::marionette::trace::RouteTraceSummary>,
    /// Per-shard plan-cache counters at snapshot time (process-wide).
    pub plan_cache_shards: [crate::marionette::transfer::PlanCacheShardStats;
        crate::marionette::transfer::PLAN_CACHE_SHARDS],
}

impl PipelineMetrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        // One consistent read of the process-wide plan-cache counters.
        let plan_cache = crate::marionette::transfer::plan_cache_stats();
        MetricsSnapshot {
            events_in: self.events_in.load(Ordering::Relaxed),
            events_host: self.events_host.load(Ordering::Relaxed),
            events_device: self.events_device.load(Ordering::Relaxed),
            events_spilled: self.events_spilled.load(Ordering::Relaxed),
            particles_out: self.particles_out.load(Ordering::Relaxed),
            planned_transfers: self.planned_transfers.load(Ordering::Relaxed),
            planned_bytes: self.planned_bytes.load(Ordering::Relaxed),
            plan_cache_hits: plan_cache.hits,
            plan_cache_misses: plan_cache.misses,
            device_batches: self.device_batches.load(Ordering::Relaxed),
            device_upload: Duration::from_micros(self.device_upload_us.load(Ordering::Relaxed)),
            device_execute: Duration::from_micros(
                self.device_execute_us.load(Ordering::Relaxed),
            ),
            device_download: Duration::from_micros(
                self.device_download_us.load(Ordering::Relaxed),
            ),
            stage_hits: self.stage_hits.load(Ordering::Relaxed),
            stage_misses: self.stage_misses.load(Ordering::Relaxed),
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
            pool_trims: self.pool_trims.load(Ordering::Relaxed),
            pool_held_bytes: self.pool_held_bytes.load(Ordering::Relaxed),
            pool_outstanding: self.pool_outstanding.load(Ordering::Relaxed),
            pool_live_allocs: self.pool_live_allocs.load(Ordering::Relaxed),
            host_mean: self.host_latency.mean(),
            device_mean: self.device_latency.mean(),
            e2e_mean: self.e2e_latency.mean(),
            e2e_p50: self.e2e_latency.quantile(0.50),
            e2e_p95: self.e2e_latency.quantile(0.95),
            e2e_p99: self.e2e_latency.quantile(0.99),
            sched_injected: self.sched_injected.load(Ordering::Relaxed),
            sched_local_pushes: self.sched_local_pushes.load(Ordering::Relaxed),
            sched_steals: self.sched_steals.load(Ordering::Relaxed),
            batch_grows: self.batch_grows.load(Ordering::Relaxed),
            batch_shrinks: self.batch_shrinks.load(Ordering::Relaxed),
            max_batch_final: self.max_batch_final.load(Ordering::Relaxed),
            fault_injected: self.fault_injected.load(Ordering::Relaxed),
            fault_recovered: self.fault_recovered.load(Ordering::Relaxed),
            fault_requeued: self.fault_requeued.load(Ordering::Relaxed),
            fault_quarantined: self.fault_quarantined.load(Ordering::Relaxed),
            fault_respawns: self.fault_respawns.load(Ordering::Relaxed),
            trace_routes: Vec::new(),
            plan_cache_shards: crate::marionette::transfer::plan_cache_shard_stats(),
        }
    }
}

impl MetricsSnapshot {
    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        let mut out = format!(
            "events: in={} host={} device={} spilled={}\n\
             particles: {}\n\
             transfers: planned={} bytes={} plan-cache hits={} misses={}\n\
             pool: stage hits={} misses={} | bytes hits={} misses={} trims={} \
             held={} outstanding={} live-allocs={}\n\
             device: batches={} upload={:?} execute={:?} download={:?}\n\
             latency: host-mean={:?} device-mean={:?} e2e-mean={:?} \
             e2e-p50={:?} e2e-p95={:?} e2e-p99={:?}\n\
             sched: injected={} local={} steals={} | cache-shards hot={}/{}",
            self.events_in,
            self.events_host,
            self.events_device,
            self.events_spilled,
            self.particles_out,
            self.planned_transfers,
            self.planned_bytes,
            self.plan_cache_hits,
            self.plan_cache_misses,
            self.stage_hits,
            self.stage_misses,
            self.pool_hits,
            self.pool_misses,
            self.pool_trims,
            self.pool_held_bytes,
            self.pool_outstanding,
            self.pool_live_allocs,
            self.device_batches,
            self.device_upload,
            self.device_execute,
            self.device_download,
            self.host_mean,
            self.device_mean,
            self.e2e_mean,
            self.e2e_p50,
            self.e2e_p95,
            self.e2e_p99,
            self.sched_injected,
            self.sched_local_pushes,
            self.sched_steals,
            self.plan_cache_shards.iter().filter(|s| s.hits + s.misses > 0).count(),
            self.plan_cache_shards.len(),
        );
        out.push_str(&format!(
            "\nadaptive: grows={} shrinks={} max-batch-final={}",
            self.batch_grows, self.batch_shrinks, self.max_batch_final
        ));
        out.push_str(&format!(
            "\nfault: injected={} recovered={} requeued={} quarantined={} respawns={}",
            self.fault_injected,
            self.fault_recovered,
            self.fault_requeued,
            self.fault_quarantined,
            self.fault_respawns
        ));
        for r in &self.trace_routes {
            out.push_str(&format!(
                "\ntrace[{}]: reads={} writes={} seq={:.2} record={:.2} -> {}",
                r.route,
                r.total_reads,
                r.total_writes,
                r.seq_fraction,
                r.record_fraction,
                r.choice.as_str()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_statistics() {
        let h = LatencyHisto::default();
        for us in [10u64, 20, 40, 80, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max(), Duration::from_micros(10_000));
        assert_eq!(h.mean(), Duration::from_micros((10 + 20 + 40 + 80 + 10_000) / 5));
        // p50 upper bound must be <= 64us bucket ceiling.
        assert!(h.quantile(0.5) <= Duration::from_micros(64));
        assert!(h.quantile(1.0) >= Duration::from_micros(10_000));
    }

    #[test]
    fn quantile_of_empty_is_zero() {
        let h = LatencyHisto::default();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn windowed_quantile_sees_only_the_delta() {
        let h = LatencyHisto::default();
        // History: a thousand fast events.
        for _ in 0..1000 {
            h.record(Duration::from_micros(10));
        }
        let prev = h.bucket_counts();
        // Empty window: no observations.
        assert_eq!(quantile_between(&prev, &h.bucket_counts(), 0.99), None);
        // Window holds only slow events; the cumulative quantile would
        // still report the fast history, the windowed one must not.
        for _ in 0..10 {
            h.record(Duration::from_micros(5_000));
        }
        let cur = h.bucket_counts();
        let windowed = quantile_between(&prev, &cur, 0.99).unwrap();
        assert!(windowed >= Duration::from_micros(5_000), "windowed={windowed:?}");
        assert!(h.quantile(0.99) <= Duration::from_micros(64), "cumulative stays fast");
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = PipelineMetrics::default();
        m.events_in.store(7, Ordering::Relaxed);
        m.e2e_latency.record(Duration::from_millis(3));
        let s = m.snapshot();
        assert_eq!(s.events_in, 7);
        assert!(s.report().contains("in=7"));
    }
}

//! Pipeline metrics: lock-free counters + log₂-bucket latency histograms.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

/// Power-of-two latency histogram from 1 µs to ~1 h.
#[derive(Debug)]
pub struct LatencyHisto {
    /// bucket b counts latencies in [2^b, 2^(b+1)) µs.
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        LatencyHisto {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHisto {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count().max(1);
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (b, c) in self.buckets.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1 << (b + 1));
            }
        }
        self.max()
    }
}

/// All pipeline counters (shared by reference across threads).
#[derive(Debug, Default)]
pub struct PipelineMetrics {
    pub events_in: AtomicUsize,
    pub events_host: AtomicUsize,
    pub events_device: AtomicUsize,
    pub events_spilled: AtomicUsize,
    pub particles_out: AtomicUsize,
    /// Planned layout/context transfers executed by the workers
    /// (staging copies through cached `TransferPlan`s).
    pub planned_transfers: AtomicUsize,
    /// Payload bytes those planned transfers moved.
    pub planned_bytes: AtomicUsize,
    pub device_batches: AtomicUsize,
    pub device_upload_us: AtomicU64,
    pub device_execute_us: AtomicU64,
    pub device_download_us: AtomicU64,
    pub host_latency: LatencyHisto,
    pub device_latency: LatencyHisto,
    pub e2e_latency: LatencyHisto,
}

/// Plain-data snapshot for reports.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub events_in: usize,
    pub events_host: usize,
    pub events_device: usize,
    pub events_spilled: usize,
    pub particles_out: usize,
    pub planned_transfers: usize,
    pub planned_bytes: usize,
    /// Process-wide transfer-plan-cache hits at snapshot time.
    pub plan_cache_hits: u64,
    /// Process-wide transfer-plan-cache misses at snapshot time.
    pub plan_cache_misses: u64,
    pub device_batches: usize,
    pub device_upload: Duration,
    pub device_execute: Duration,
    pub device_download: Duration,
    pub host_mean: Duration,
    pub device_mean: Duration,
    pub e2e_mean: Duration,
    pub e2e_p99: Duration,
}

impl PipelineMetrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        // One consistent read of the process-wide plan-cache counters.
        let plan_cache = crate::marionette::transfer::plan_cache_stats();
        MetricsSnapshot {
            events_in: self.events_in.load(Ordering::Relaxed),
            events_host: self.events_host.load(Ordering::Relaxed),
            events_device: self.events_device.load(Ordering::Relaxed),
            events_spilled: self.events_spilled.load(Ordering::Relaxed),
            particles_out: self.particles_out.load(Ordering::Relaxed),
            planned_transfers: self.planned_transfers.load(Ordering::Relaxed),
            planned_bytes: self.planned_bytes.load(Ordering::Relaxed),
            plan_cache_hits: plan_cache.hits,
            plan_cache_misses: plan_cache.misses,
            device_batches: self.device_batches.load(Ordering::Relaxed),
            device_upload: Duration::from_micros(self.device_upload_us.load(Ordering::Relaxed)),
            device_execute: Duration::from_micros(
                self.device_execute_us.load(Ordering::Relaxed),
            ),
            device_download: Duration::from_micros(
                self.device_download_us.load(Ordering::Relaxed),
            ),
            host_mean: self.host_latency.mean(),
            device_mean: self.device_latency.mean(),
            e2e_mean: self.e2e_latency.mean(),
            e2e_p99: self.e2e_latency.quantile(0.99),
        }
    }
}

impl MetricsSnapshot {
    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "events: in={} host={} device={} spilled={}\n\
             particles: {}\n\
             transfers: planned={} bytes={} plan-cache hits={} misses={}\n\
             device: batches={} upload={:?} execute={:?} download={:?}\n\
             latency: host-mean={:?} device-mean={:?} e2e-mean={:?} e2e-p99={:?}",
            self.events_in,
            self.events_host,
            self.events_device,
            self.events_spilled,
            self.particles_out,
            self.planned_transfers,
            self.planned_bytes,
            self.plan_cache_hits,
            self.plan_cache_misses,
            self.device_batches,
            self.device_upload,
            self.device_execute,
            self.device_download,
            self.host_mean,
            self.device_mean,
            self.e2e_mean,
            self.e2e_p99,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_statistics() {
        let h = LatencyHisto::default();
        for us in [10u64, 20, 40, 80, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max(), Duration::from_micros(10_000));
        assert_eq!(h.mean(), Duration::from_micros((10 + 20 + 40 + 80 + 10_000) / 5));
        // p50 upper bound must be <= 64us bucket ceiling.
        assert!(h.quantile(0.5) <= Duration::from_micros(64));
        assert!(h.quantile(1.0) >= Duration::from_micros(10_000));
    }

    #[test]
    fn quantile_of_empty_is_zero() {
        let h = LatencyHisto::default();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = PipelineMetrics::default();
        m.events_in.store(7, Ordering::Relaxed);
        m.e2e_latency.record(Duration::from_millis(3));
        let s = m.snapshot();
        assert_eq!(s.events_in, 7);
        assert!(s.report().contains("in=7"));
    }
}

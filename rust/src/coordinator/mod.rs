//! The event-processing coordinator: the L3 system around the EDM.
//!
//! The paper's library exists to let host and accelerator code paths
//! coexist over one data model during a gradual port (§I, §III); the
//! coordinator operationalises that: a multi-threaded pipeline that
//! routes events between CPU workers (running the host algorithms over
//! Marionette collections) and a dedicated device worker (running the
//! AOT executables through `runtime::Engine`), with dynamic routing,
//! device-side batching, bounded-queue backpressure and metrics.
//!
//! Threading model: `std::thread` + bounded `mpsc` channels (tokio is
//! not in the vendored dependency set; the pipeline is CPU/device-bound,
//! not I/O-bound, so blocking channels with explicit backpressure are a
//! faithful substitute). The device worker owns its `Engine` because
//! PJRT handles are `Rc`-based and single-threaded.

pub mod batcher;
pub mod config;
pub mod fault;
pub mod ingest;
pub mod metrics;
pub mod pipeline;
pub mod router;

pub use batcher::{AimdBatchController, Batcher};
pub use config::{AdaptiveBatch, PipelineConfig, RoutePolicy};
pub use fault::{FaultPlan, FaultState};
pub use ingest::{
    connect_unix, golden_compare, run_ingest, run_reconstruction, run_socketpair_ingest,
    serve_unix, verify_exactly_once, FrameResult, IngestOpts, IngestStats, ReconstructionReport,
    ServeOpts,
};
pub use metrics::{MetricsSnapshot, PipelineMetrics};
pub use pipeline::{
    run_pipeline, EventResult, PipelineError, PipelineReport, Route, RouteTapes, StageCtx,
    StagePool, StagedParticles,
};

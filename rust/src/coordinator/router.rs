//! Routing: decide per event whether the host pool or the device worker
//! should process it.
//!
//! The `Auto` policy encodes Figure 1's crossover: small grids lose on
//! the device (fixed upload/launch overheads dominate), large grids win;
//! and a saturated device queue spills to the host to bound latency —
//! the "host and accelerator code coexist" story of the paper made
//! operational.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::config::RoutePolicy;
use super::pipeline::Route;

/// Shared device-queue depth gauge (incremented on enqueue, decremented
/// by the device worker).
#[derive(Clone, Debug, Default)]
pub struct QueueGauge(Arc<AtomicUsize>);

impl QueueGauge {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement: a stray extra `dec` (e.g. a worker draining
    /// an event the router never gauged) must not wrap the depth to
    /// `usize::MAX` and permanently spill all traffic.
    pub fn dec(&self) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    pub fn depth(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

/// Stateless-per-event router (gauge carries the cross-event state).
#[derive(Clone, Debug)]
pub struct Router {
    policy: RoutePolicy,
    device_available: bool,
    gauge: QueueGauge,
}

/// Routing decision plus whether it was a spill (device-preferred but
/// sent to host).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    pub route: Route,
    pub spilled: bool,
}

impl Router {
    pub fn new(policy: RoutePolicy, device_available: bool, gauge: QueueGauge) -> Router {
        Router { policy, device_available, gauge }
    }

    pub fn gauge(&self) -> &QueueGauge {
        &self.gauge
    }

    /// Decide where an event of `rows x cols` goes.
    pub fn decide(&self, rows: usize, cols: usize) -> Decision {
        if !self.device_available {
            return Decision { route: Route::Host, spilled: false };
        }
        match self.policy {
            RoutePolicy::HostOnly => Decision { route: Route::Host, spilled: false },
            RoutePolicy::DeviceOnly => Decision { route: Route::Device, spilled: false },
            RoutePolicy::Auto { min_device_cells, max_device_queue } => {
                if rows * cols < min_device_cells {
                    Decision { route: Route::Host, spilled: false }
                } else if self.gauge.depth() > max_device_queue {
                    Decision { route: Route::Host, spilled: true }
                } else {
                    Decision { route: Route::Device, spilled: false }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn auto(min_cells: usize, max_q: usize) -> Router {
        Router::new(
            RoutePolicy::Auto { min_device_cells: min_cells, max_device_queue: max_q },
            true,
            QueueGauge::default(),
        )
    }

    #[test]
    fn size_crossover() {
        let r = auto(128 * 128, 8);
        assert_eq!(r.decide(64, 64).route, Route::Host);
        assert_eq!(r.decide(128, 128).route, Route::Device);
        assert_eq!(r.decide(1024, 1024).route, Route::Device);
    }

    #[test]
    fn queue_spill() {
        let r = auto(0, 2);
        for _ in 0..3 {
            r.gauge().inc();
        }
        let d = r.decide(512, 512);
        assert_eq!(d.route, Route::Host);
        assert!(d.spilled);
        r.gauge().dec();
        let d = r.decide(512, 512);
        assert_eq!(d.route, Route::Device);
        assert!(!d.spilled);
    }

    /// Regression: `dec` on an empty gauge used to wrap to
    /// `usize::MAX`, making every later `Auto` decision a spill.
    #[test]
    fn gauge_dec_saturates_at_zero() {
        let g = QueueGauge::default();
        g.dec();
        assert_eq!(g.depth(), 0);
        g.inc();
        g.dec();
        g.dec();
        assert_eq!(g.depth(), 0);
        let r = Router::new(
            RoutePolicy::Auto { min_device_cells: 0, max_device_queue: 2 },
            true,
            g,
        );
        let d = r.decide(512, 512);
        assert_eq!(d.route, Route::Device);
        assert!(!d.spilled);
    }

    #[test]
    fn no_device_forces_host() {
        let r = Router::new(RoutePolicy::DeviceOnly, false, QueueGauge::default());
        assert_eq!(r.decide(1024, 1024).route, Route::Host);
    }

    #[test]
    fn fixed_policies() {
        let h = Router::new(RoutePolicy::HostOnly, true, QueueGauge::default());
        assert_eq!(h.decide(1024, 1024).route, Route::Host);
        let d = Router::new(RoutePolicy::DeviceOnly, true, QueueGauge::default());
        assert_eq!(d.decide(8, 8).route, Route::Device);
    }
}

//! Typed execution of the AOT artifacts, with explicit
//! upload / execute / download phases.
//!
//! The figures decompose device time into transfer and compute; to keep
//! that decomposition honest the engine uploads inputs to device buffers
//! first (`buffer_from_host_buffer`, timed as H2D), runs the executable
//! over buffers (`execute_b`, timed as compute), and reads outputs back
//! as literals (`to_literal_sync` + copy-out, timed as D2H).
//!
//! Executables are compiled once per (entry, bucket) and cached; the
//! first call pays XLA compilation (reported separately via [`Engine::warm`]).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::edm::constants::NUM_PLANES;
use crate::edm::generator::RawEvent;

use super::artifact::Manifest;
use super::client::client;

/// Wall-clock decomposition of one device call.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecTiming {
    pub upload: Duration,
    pub execute: Duration,
    pub download: Duration,
}

impl ExecTiming {
    pub fn total(&self) -> Duration {
        self.upload + self.execute + self.download
    }

    pub fn add(&mut self, o: &ExecTiming) {
        self.upload += o.upload;
        self.execute += o.execute;
        self.download += o.download;
    }
}

/// Outputs of the device sensor stage (Figure 1).
#[derive(Debug)]
pub struct SensorStageOut {
    pub energy: Vec<f32>,
    pub noise: Vec<f32>,
    pub sig: Vec<f32>,
}

/// Outputs of the device particle stage (Figure 2).
#[derive(Debug)]
pub struct ParticleStageOut {
    pub seeds: Vec<i32>,
    /// `NUM_PLANES` stacked window-sum planes, plane-major.
    pub sums: Vec<f32>,
}

/// Compiled-executable cache keyed by (entry, rows, cols).
///
/// `Engine` is deliberately single-threaded (`!Send`): PJRT handles in
/// the `xla` crate are `Rc`-based, so each device-driving thread owns
/// its own engine (see `coordinator::pipeline`'s dedicated device
/// worker).
pub struct Engine {
    manifest: Manifest,
    cache: RefCell<HashMap<(String, usize, usize), Rc<xla::PjRtLoadedExecutable>>>,
    /// Reused upload staging buffer for the `noisy` u8→i32 conversion
    /// plane: grown once to the event size, then recycled per call so
    /// steady-state uploads allocate nothing host-side (DESIGN.md §5).
    noisy_scratch: RefCell<Vec<i32>>,
}

impl Engine {
    pub fn new(manifest: Manifest) -> Engine {
        Engine {
            manifest,
            cache: RefCell::new(HashMap::new()),
            noisy_scratch: RefCell::new(Vec::new()),
        }
    }

    /// Engine over the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Ok(Engine::new(Manifest::load_default()?))
    }

    pub fn load(dir: &Path) -> Result<Engine> {
        Ok(Engine::new(Manifest::load(dir)?))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch) the executable for an entry/bucket.
    fn executable(
        &self,
        entry: &str,
        rows: usize,
        cols: usize,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = (entry.to_string(), rows, cols);
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let rec = self.manifest.get(entry, rows, cols)?;
        let path = rec
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client()
            .compile(&comp)
            .with_context(|| format!("compiling {entry} {rows}x{cols}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Pre-compile an entry/bucket; returns the compile wall time (zero
    /// when already cached).
    pub fn warm(&self, entry: &str, rows: usize, cols: usize) -> Result<Duration> {
        let key = (entry.to_string(), rows, cols);
        if self.cache.borrow().contains_key(&key) {
            return Ok(Duration::ZERO);
        }
        let t = Instant::now();
        self.executable(entry, rows, cols)?;
        Ok(t.elapsed())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }

    // ------------------------------------------------------------------
    // Marshalling helpers
    // ------------------------------------------------------------------

    fn upload_f32(&self, data: &[f32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        Ok(client().buffer_from_host_buffer(data, &[rows, cols], None)?)
    }

    fn upload_i32(&self, data: &[i32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        Ok(client().buffer_from_host_buffer(data, &[rows, cols], None)?)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::PjRtBuffer],
        timing: &mut ExecTiming,
    ) -> Result<Vec<xla::Literal>> {
        let t = Instant::now();
        let out = exe.execute_b(inputs)?;
        timing.execute += t.elapsed();

        let t = Instant::now();
        let lit = out
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("executable produced no output"))?
            .to_literal_sync()?;
        // Lowered with return_tuple=True: always a tuple.
        let parts = lit.to_tuple()?;
        timing.download += t.elapsed();
        Ok(parts)
    }

    // ------------------------------------------------------------------
    // Entry points
    // ------------------------------------------------------------------

    /// Device sensor stage: counts + calibration planes → energy/noise/sig.
    pub fn run_sensor_stage(&self, ev: &RawEvent) -> Result<(SensorStageOut, ExecTiming)> {
        let (rows, cols) = (ev.rows, ev.cols);
        let exe = self.executable("sensor_stage", rows, cols)?;
        let mut timing = ExecTiming::default();

        let t = Instant::now();
        let mut noisy = self.noisy_scratch.borrow_mut();
        noisy.clear();
        noisy.extend(ev.noisy.iter().map(|&x| x as i32));
        let inputs = vec![
            self.upload_i32(&ev.counts, rows, cols)?,
            self.upload_f32(&ev.a, rows, cols)?,
            self.upload_f32(&ev.b, rows, cols)?,
            self.upload_f32(&ev.na, rows, cols)?,
            self.upload_f32(&ev.nb, rows, cols)?,
            self.upload_i32(noisy.as_slice(), rows, cols)?,
        ];
        drop(noisy);
        timing.upload += t.elapsed();

        let parts = self.run(&exe, &inputs, &mut timing)?;
        if parts.len() != 3 {
            bail!("sensor_stage returned {} outputs", parts.len());
        }
        let t = Instant::now();
        let out = SensorStageOut {
            energy: parts[0].to_vec::<f32>()?,
            noise: parts[1].to_vec::<f32>()?,
            sig: parts[2].to_vec::<f32>()?,
        };
        timing.download += t.elapsed();
        Ok((out, timing))
    }

    /// Device particle stage: calibrated planes → seed mask + window sums.
    pub fn run_particle_stage(
        &self,
        rows: usize,
        cols: usize,
        energy: &[f32],
        sig: &[f32],
        types: &[i32],
        noisy: &[i32],
    ) -> Result<(ParticleStageOut, ExecTiming)> {
        let exe = self.executable("particle_stage", rows, cols)?;
        let mut timing = ExecTiming::default();

        let t = Instant::now();
        let inputs = vec![
            self.upload_f32(energy, rows, cols)?,
            self.upload_f32(sig, rows, cols)?,
            self.upload_i32(types, rows, cols)?,
            self.upload_i32(noisy, rows, cols)?,
        ];
        timing.upload += t.elapsed();

        let parts = self.run(&exe, &inputs, &mut timing)?;
        if parts.len() != 2 {
            bail!("particle_stage returned {} outputs", parts.len());
        }
        let t = Instant::now();
        let out = ParticleStageOut {
            seeds: parts[0].to_vec::<i32>()?,
            sums: parts[1].to_vec::<f32>()?,
        };
        timing.download += t.elapsed();
        debug_assert_eq!(out.sums.len(), NUM_PLANES * rows * cols);
        Ok((out, timing))
    }

    /// Fused pipeline: raw event → calibrated planes + seeds + sums with
    /// no intermediate host round-trip (the paper's "sidestepping
    /// unnecessary conversions").
    pub fn run_full_event(
        &self,
        ev: &RawEvent,
    ) -> Result<(SensorStageOut, ParticleStageOut, ExecTiming)> {
        let (rows, cols) = (ev.rows, ev.cols);
        let exe = self.executable("full_event", rows, cols)?;
        let mut timing = ExecTiming::default();

        let t = Instant::now();
        let mut noisy = self.noisy_scratch.borrow_mut();
        noisy.clear();
        noisy.extend(ev.noisy.iter().map(|&x| x as i32));
        let inputs = vec![
            self.upload_i32(&ev.counts, rows, cols)?,
            self.upload_f32(&ev.a, rows, cols)?,
            self.upload_f32(&ev.b, rows, cols)?,
            self.upload_f32(&ev.na, rows, cols)?,
            self.upload_f32(&ev.nb, rows, cols)?,
            self.upload_i32(noisy.as_slice(), rows, cols)?,
            self.upload_i32(&ev.types, rows, cols)?,
        ];
        drop(noisy);
        timing.upload += t.elapsed();

        let parts = self.run(&exe, &inputs, &mut timing)?;
        if parts.len() != 5 {
            bail!("full_event returned {} outputs", parts.len());
        }
        let t = Instant::now();
        let sensor = SensorStageOut {
            energy: parts[0].to_vec::<f32>()?,
            noise: parts[1].to_vec::<f32>()?,
            sig: parts[2].to_vec::<f32>()?,
        };
        let particle = ParticleStageOut {
            seeds: parts[3].to_vec::<i32>()?,
            sums: parts[4].to_vec::<f32>()?,
        };
        timing.download += t.elapsed();
        Ok((sensor, particle, timing))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edm::calib;
    use crate::edm::generator::{EventConfig, EventGenerator};
    use crate::edm::reco;
    use crate::marionette::layout::SoAVec;

    fn engine() -> Option<Engine> {
        Engine::load_default().ok()
    }

    #[test]
    fn sensor_stage_matches_host() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ev = EventGenerator::new(EventConfig::grid(32, 32, 3), 42).generate();
        let (dev, timing) = eng.run_sensor_stage(&ev).unwrap();
        assert!(timing.total() > Duration::ZERO);

        // The downloaded planes read through the same typed view as any
        // other sensor store (devmem::downloaded_planes, DESIGN.md §6).
        let planes = super::super::devmem::downloaded_planes(&ev, &dev).unwrap();
        let view = crate::edm::sensor::SensorView::attach(&planes).unwrap();
        assert_eq!(view.len(), ev.num_sensors());
        assert_eq!(view.event_id(), ev.event_id);

        let mut host = ev.to_collection::<SoAVec>();
        calib::calibrate_collection(&mut host);
        for i in 0..ev.num_sensors() {
            assert!(
                (view.energy(i) - host.energy(i)).abs()
                    <= 1e-3 * host.energy(i).abs().max(1.0),
                "energy[{i}]: dev={} host={}",
                view.energy(i),
                host.energy(i)
            );
            assert!((view.sig(i) - host.sig(i)).abs() <= 1e-3 * host.sig(i).abs().max(1.0));
        }
    }

    #[test]
    fn particle_stage_matches_host_reco() {
        let Some(eng) = engine() else { return };
        let ev = EventGenerator::new(EventConfig::grid(64, 64, 4), 7).generate();
        let mut host = ev.to_collection::<SoAVec>();
        calib::calibrate_collection(&mut host);
        let host_particles = reco::reconstruct_collection(&host);

        let (s, _) = eng.run_sensor_stage(&ev).unwrap();
        let noisy: Vec<i32> = ev.noisy.iter().map(|&x| x as i32).collect();
        let (p, _) = eng
            .run_particle_stage(64, 64, &s.energy, &s.sig, &ev.types, &noisy)
            .unwrap();
        let dev_particles = reco::particles_from_planes::<SoAVec>(
            64, 64, ev.event_id, &p.seeds, &p.sums, &s.sig,
        );

        assert_eq!(dev_particles.len(), host_particles.len());
        for (i, hp) in host_particles.iter().enumerate() {
            assert_eq!(dev_particles.origin(i), hp.origin);
            let rel = |a: f32, b: f32| (a - b).abs() <= 2e-3 * b.abs().max(1.0);
            assert!(rel(dev_particles.energy(i), hp.energy));
            assert!(rel(dev_particles.x(i), hp.x));
            assert!(rel(dev_particles.y(i), hp.y));
            assert_eq!(dev_particles.sensors(i).to_vec(), hp.sensors);
            for t in 0..3 {
                assert!(rel(dev_particles.e_contribution(i, t), hp.e_contribution[t]));
                assert_eq!(dev_particles.noisy_count(i, t), hp.noisy_count[t]);
            }
        }
    }

    #[test]
    fn full_event_equals_staged() {
        let Some(eng) = engine() else { return };
        let ev = EventGenerator::new(EventConfig::grid(32, 32, 2), 5).generate();
        let (s1, _) = eng.run_sensor_stage(&ev).unwrap();
        let noisy: Vec<i32> = ev.noisy.iter().map(|&x| x as i32).collect();
        let (p1, _) = eng
            .run_particle_stage(32, 32, &s1.energy, &s1.sig, &ev.types, &noisy)
            .unwrap();
        let (s2, p2, _) = eng.run_full_event(&ev).unwrap();
        assert_eq!(s1.energy, s2.energy);
        assert_eq!(p1.seeds, p2.seeds);
        assert_eq!(p1.sums, p2.sums);
    }

    #[test]
    fn executable_cache_reused() {
        let Some(eng) = engine() else { return };
        let d1 = eng.warm("sensor_stage", 16, 16).unwrap();
        let d2 = eng.warm("sensor_stage", 16, 16).unwrap();
        assert!(d1 > Duration::ZERO);
        assert_eq!(d2, Duration::ZERO);
        assert_eq!(eng.cached(), 1);
    }

    #[test]
    fn golden_event_through_device() {
        let Some(eng) = engine() else { return };
        let Some(g) = crate::edm::golden::load_golden() else { return };
        let ev = RawEvent {
            event_id: 0,
            rows: g.rows,
            cols: g.cols,
            counts: g.tensor("counts").as_i32(),
            types: g.tensor("types").as_i32(),
            noisy: g.tensor("noisy").as_i32().iter().map(|&x| x as u8).collect(),
            a: g.tensor("a").as_f32(),
            b: g.tensor("b").as_f32(),
            na: g.tensor("na").as_f32(),
            nb: g.tensor("nb").as_f32(),
            truth: vec![],
        };
        let (s, p, _) = eng.run_full_event(&ev).unwrap();
        let want_energy = g.tensor("energy").as_f32();
        let want_seeds = g.tensor("seeds").as_i32();
        let want_sums = g.tensor("sums").as_f32();
        for i in 0..s.energy.len() {
            assert!((s.energy[i] - want_energy[i]).abs() <= 1e-3 * want_energy[i].abs().max(1.0));
        }
        assert_eq!(p.seeds, want_seeds);
        for i in 0..p.sums.len() {
            assert!((p.sums[i] - want_sums[i]).abs() <= 1e-2 * want_sums[i].abs().max(1.0));
        }
    }
}

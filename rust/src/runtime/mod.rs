//! The device runtime: loads AOT artifacts and runs them on PJRT.
//!
//! This is the "accelerator" half of the reproduction (DESIGN.md §2):
//! `python/compile/aot.py` lowers the JAX + Pallas compute graph to HLO
//! text once at build time; this module loads those artifacts
//! ([`artifact`]), compiles them on the XLA CPU PJRT client ([`client`]),
//! and executes them from the Rust request path ([`executor`]) with
//! genuine upload/execute/download phases. Python never runs here.
//!
//! [`devmem`] keeps event planes resident on the device between stages
//! (the paper's device-side collections, whose interface is transfers and
//! kernel launches rather than element access).

pub mod artifact;
pub mod client;
pub mod devmem;
pub mod executor;
pub mod fault;
pub mod transport;

pub use artifact::{ArtifactRecord, Manifest, TensorSpec};
pub use devmem::{downloaded_planes, DeviceEvent, DeviceEventPool, ResidentEvent};
pub use executor::{Engine, ExecTiming, ParticleStageOut, SensorStageOut};
pub use fault::{FaultFuse, FaultyEngine, FullEventRunner};
pub use transport::{write_frame, FrameReader, ReassemblyRing, TransportError, MAX_FRAME_BYTES};

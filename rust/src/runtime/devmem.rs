//! Device-resident event planes (the paper's device-side collections).
//!
//! A [`DeviceEvent`] is the device twin of a `SensorCollection`: its data
//! lives in PJRT buffers and its interface is *transfers and kernel
//! launches only* — exactly the paper's point that a collection's
//! `interface_properties` differ per execution context (§VII-B). Upload
//! once, run both stages against the resident buffers, download results.
//!
//! [`DeviceEventPool`] bounds how many events may be device-resident at
//! once (device memory is the scarce resource the paper's contexts
//! manage) and recycles the host-side upload staging buffer — the i32
//! conversion plane every upload marshals `noisy` through — across
//! events, so steady-state uploads stop allocating on the host side
//! (DESIGN.md §5).
//!
//! [`downloaded_planes`] is the D2H counterpart for the typed interface
//! layer: it assembles the planes an executed event leaves on the host
//! (the raw upload planes plus the downloaded calibration outputs) into
//! a schema-shaped [`SlicePlanes`] store, so the generated
//! `SensorView` attaches to a device *download* exactly as it attaches
//! to an owned collection (DESIGN.md §6).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::edm::generator::RawEvent;
use crate::edm::sensor::SensorProps;
use crate::marionette::interface::{AttachError, SlicePlanes};

use super::client::client;
use super::executor::SensorStageOut;

/// Raw sensor planes resident on the PJRT device.
pub struct DeviceEvent {
    pub event_id: u64,
    pub rows: usize,
    pub cols: usize,
    pub counts: xla::PjRtBuffer,
    pub a: xla::PjRtBuffer,
    pub b: xla::PjRtBuffer,
    pub na: xla::PjRtBuffer,
    pub nb: xla::PjRtBuffer,
    pub noisy: xla::PjRtBuffer,
    pub types: xla::PjRtBuffer,
    /// Wall time of the H2D upload that created this event.
    pub upload_time: Duration,
}

impl DeviceEvent {
    /// Upload a raw event's planes to the device.
    pub fn upload(ev: &RawEvent) -> Result<DeviceEvent> {
        let mut scratch = Vec::new();
        Self::upload_with_scratch(ev, &mut scratch)
    }

    /// As [`Self::upload`], marshalling through a caller-provided
    /// staging buffer (the `noisy` u8→i32 conversion plane). Reusing
    /// `scratch` across events removes the per-upload host allocation;
    /// [`DeviceEventPool`] owns a shelf of these.
    pub fn upload_with_scratch(ev: &RawEvent, scratch: &mut Vec<i32>) -> Result<DeviceEvent> {
        let c = client();
        let dims = [ev.rows, ev.cols];
        let t = Instant::now();
        scratch.clear();
        scratch.extend(ev.noisy.iter().map(|&x| x as i32));
        let out = DeviceEvent {
            event_id: ev.event_id,
            rows: ev.rows,
            cols: ev.cols,
            counts: c.buffer_from_host_buffer(&ev.counts, &dims, None)?,
            a: c.buffer_from_host_buffer(&ev.a, &dims, None)?,
            b: c.buffer_from_host_buffer(&ev.b, &dims, None)?,
            na: c.buffer_from_host_buffer(&ev.na, &dims, None)?,
            nb: c.buffer_from_host_buffer(&ev.nb, &dims, None)?,
            noisy: c.buffer_from_host_buffer(scratch.as_slice(), &dims, None)?,
            types: c.buffer_from_host_buffer(&ev.types, &dims, None)?,
            upload_time: Duration::ZERO,
        };
        let mut out = out;
        out.upload_time = t.elapsed();
        Ok(out)
    }

    /// H2D bytes this event occupies (7 planes of 4-byte elements).
    pub fn device_bytes(&self) -> usize {
        7 * self.rows * self.cols * 4
    }

    /// Input buffers of the fused `full_event` entry, in signature order.
    pub fn full_event_inputs(&self) -> [&xla::PjRtBuffer; 7] {
        [&self.counts, &self.a, &self.b, &self.na, &self.nb, &self.noisy, &self.types]
    }
}

/// Assemble the host-side planes of an executed device event — the raw
/// upload planes still held by `ev` plus the calibration outputs
/// downloaded in `out` — into a schema-shaped [`SlicePlanes`] store
/// matching the sensor collection's property list.
///
/// Attach the generated `SensorView` to the result and the downloaded
/// event reads exactly like an owned collection:
///
/// ```text
/// let (out, _timing) = engine.run_sensor_stage(&ev)?;
/// let planes = downloaded_planes(&ev, &out)?;
/// let view = SensorView::attach(&planes)?;   // one impl serves all
/// let particles = reco::reconstruct(&view);
/// ```
///
/// Every bind is dtype- and length-checked against the schema; the
/// result is fully bound, so the subsequent attach cannot fail on a
/// missing field.
pub fn downloaded_planes<'a>(
    ev: &'a RawEvent,
    out: &'a SensorStageOut,
) -> Result<SlicePlanes<'a>, AttachError> {
    SlicePlanes::new(SensorProps::schema(), ev.num_sensors())
        .bind("type_id", &ev.types)?
        .bind("counts", &ev.counts)?
        .bind("energy", &out.energy)?
        .bind("noise", &out.noise)?
        .bind("sig", &out.sig)?
        .bind("noisy", &ev.noisy)?
        .bind("param_a", &ev.a)?
        .bind("param_b", &ev.b)?
        .bind("noise_a", &ev.na)?
        .bind("noise_b", &ev.nb)?
        .set_global("rows", ev.rows as u32)?
        .set_global("cols", ev.cols as u32)?
        .set_global("event_id", ev.event_id)
}

/// Counters of a [`DeviceEventPool`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeviceEventPoolStats {
    /// Successful uploads through the pool.
    pub uploads: usize,
    /// Uploads whose staging scratch came off the shelf.
    pub scratch_hits: usize,
    /// Uploads that had to grow a fresh staging scratch.
    pub scratch_misses: usize,
    /// Uploads rejected because the residency bound was reached.
    pub rejected: usize,
}

/// Bounded device-event residency pool.
///
/// Device memory is the scarce resource; the pool caps how many
/// [`DeviceEvent`]s may be resident at once (each [`ResidentEvent`]
/// releases its slot on drop, which also drops the PJRT buffers) and
/// recycles the host-side upload staging scratch across events.
pub struct DeviceEventPool {
    max_resident: usize,
    resident: Arc<AtomicUsize>,
    scratch: Mutex<Vec<Vec<i32>>>,
    uploads: AtomicUsize,
    scratch_hits: AtomicUsize,
    scratch_misses: AtomicUsize,
    rejected: AtomicUsize,
}

impl DeviceEventPool {
    /// Pool admitting at most `max_resident` simultaneous device events.
    pub fn new(max_resident: usize) -> DeviceEventPool {
        DeviceEventPool {
            max_resident: max_resident.max(1),
            resident: Arc::new(AtomicUsize::new(0)),
            scratch: Mutex::new(Vec::new()),
            uploads: AtomicUsize::new(0),
            scratch_hits: AtomicUsize::new(0),
            scratch_misses: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
        }
    }

    /// Events currently resident on the device through this pool.
    pub fn resident(&self) -> usize {
        self.resident.load(Ordering::Relaxed)
    }

    /// The residency bound.
    pub fn capacity(&self) -> usize {
        self.max_resident
    }

    /// Whether an upload would be admitted right now.
    pub fn has_capacity(&self) -> bool {
        self.resident() < self.max_resident
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> DeviceEventPoolStats {
        DeviceEventPoolStats {
            uploads: self.uploads.load(Ordering::Relaxed),
            scratch_hits: self.scratch_hits.load(Ordering::Relaxed),
            scratch_misses: self.scratch_misses.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
        }
    }

    /// Upload an event within the residency bound. Fails (without
    /// touching the device) when the bound is reached — callers drop or
    /// finish older [`ResidentEvent`]s first; the device worker is
    /// single-threaded, so this surfaces as backpressure, not a race.
    pub fn upload(&self, ev: &RawEvent) -> Result<ResidentEvent> {
        // Single device thread: check-then-reserve does not race.
        if !self.has_capacity() {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            bail!(
                "device-event pool at residency bound ({} events)",
                self.max_resident
            );
        }
        let mut scratch = match self.scratch.lock().unwrap().pop() {
            Some(s) => {
                self.scratch_hits.fetch_add(1, Ordering::Relaxed);
                s
            }
            None => {
                self.scratch_misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        };
        let result = DeviceEvent::upload_with_scratch(ev, &mut scratch);
        {
            let mut shelf = self.scratch.lock().unwrap();
            if shelf.len() < self.max_resident {
                shelf.push(scratch);
            }
        }
        let dev = result?;
        self.resident.fetch_add(1, Ordering::Relaxed);
        self.uploads.fetch_add(1, Ordering::Relaxed);
        Ok(ResidentEvent { dev, resident: self.resident.clone() })
    }
}

/// A [`DeviceEvent`] occupying a [`DeviceEventPool`] residency slot;
/// dropping it frees the slot (and the PJRT buffers with it).
pub struct ResidentEvent {
    dev: DeviceEvent,
    resident: Arc<AtomicUsize>,
}

impl std::ops::Deref for ResidentEvent {
    type Target = DeviceEvent;
    fn deref(&self) -> &DeviceEvent {
        &self.dev
    }
}

impl Drop for ResidentEvent {
    fn drop(&mut self) {
        self.resident.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edm::generator::{EventConfig, EventGenerator};

    #[test]
    fn upload_and_shapes() {
        let ev = EventGenerator::new(EventConfig::grid(16, 16, 1), 2).generate();
        let Ok(dev) = DeviceEvent::upload(&ev) else {
            eprintln!("skipping: no PJRT");
            return;
        };
        assert_eq!(dev.device_bytes(), 7 * 16 * 16 * 4);
        assert!(dev.upload_time > Duration::ZERO);
        // Round-trip one plane to prove residency.
        let lit = dev.counts.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), ev.counts);
    }

    /// The D2H interface bridge is pure host state: a downloaded event
    /// attaches the one generated sensor view and reads (and
    /// reconstructs) exactly like the owned collection. No PJRT needed
    /// — the host calibration stands in for the device download.
    #[test]
    fn downloaded_planes_attach_and_read() {
        use crate::edm::sensor::SensorView;
        use crate::edm::{calib, reco};
        use crate::marionette::layout::SoAVec;

        let ev = EventGenerator::new(EventConfig::grid(24, 24, 2), 9).generate();
        let mut col = ev.to_collection::<SoAVec>();
        calib::calibrate_collection(&mut col);
        let out = SensorStageOut {
            energy: (0..col.len()).map(|i| col.energy(i)).collect(),
            noise: (0..col.len()).map(|i| col.noise(i)).collect(),
            sig: (0..col.len()).map(|i| col.sig(i)).collect(),
        };
        let planes = downloaded_planes(&ev, &out).unwrap();
        let v = SensorView::attach(&planes).unwrap();
        assert_eq!(v.rows(), 24);
        assert_eq!(v.cols(), 24);
        assert_eq!(v.event_id(), ev.event_id);
        for i in (0..col.len()).step_by(37) {
            assert_eq!(v.energy(i), col.energy(i));
            assert_eq!(v.sig(i), col.sig(i));
            assert_eq!(v.counts(i), ev.counts[i]);
            assert_eq!(v.noisy(i), ev.noisy[i]);
        }
        assert_eq!(reco::reconstruct(&v), reco::reconstruct_collection(&col));
    }

    #[test]
    fn pool_accounting_without_device() {
        // The bound and counters are pure host state; no PJRT needed.
        let pool = DeviceEventPool::new(0); // clamps to 1
        assert_eq!(pool.capacity(), 1);
        assert_eq!(pool.resident(), 0);
        assert!(pool.has_capacity());
        assert_eq!(pool.stats(), DeviceEventPoolStats::default());
    }

    #[test]
    fn pool_bounds_residency_and_recycles_scratch() {
        let mut gen = EventGenerator::new(EventConfig::grid(16, 16, 1), 3);
        let ev = gen.generate();
        let pool = DeviceEventPool::new(2);
        let Ok(first) = pool.upload(&ev) else {
            eprintln!("skipping: no PJRT");
            return;
        };
        assert_eq!(pool.resident(), 1);
        assert_eq!(first.device_bytes(), 7 * 16 * 16 * 4);
        let second = pool.upload(&gen.generate()).unwrap();
        assert_eq!(pool.resident(), 2);
        // Bound reached: the third upload is rejected without touching
        // the device.
        assert!(pool.upload(&gen.generate()).is_err());
        assert_eq!(pool.stats().rejected, 1);
        // Dropping a resident event frees its slot...
        drop(first);
        assert_eq!(pool.resident(), 1);
        let third = pool.upload(&gen.generate()).unwrap();
        // ...and later uploads reuse the parked staging scratch.
        let s = pool.stats();
        assert_eq!(s.uploads, 3);
        assert!(s.scratch_hits >= 2, "scratch not recycled: {s:?}");
        drop((second, third));
        assert_eq!(pool.resident(), 0);
    }
}

//! Device-resident event planes (the paper's device-side collections).
//!
//! A [`DeviceEvent`] is the device twin of a `SensorCollection`: its data
//! lives in PJRT buffers and its interface is *transfers and kernel
//! launches only* — exactly the paper's point that a collection's
//! `interface_properties` differ per execution context (§VII-B). Upload
//! once, run both stages against the resident buffers, download results.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::edm::generator::RawEvent;

use super::client::client;

/// Raw sensor planes resident on the PJRT device.
pub struct DeviceEvent {
    pub event_id: u64,
    pub rows: usize,
    pub cols: usize,
    pub counts: xla::PjRtBuffer,
    pub a: xla::PjRtBuffer,
    pub b: xla::PjRtBuffer,
    pub na: xla::PjRtBuffer,
    pub nb: xla::PjRtBuffer,
    pub noisy: xla::PjRtBuffer,
    pub types: xla::PjRtBuffer,
    /// Wall time of the H2D upload that created this event.
    pub upload_time: Duration,
}

impl DeviceEvent {
    /// Upload a raw event's planes to the device.
    pub fn upload(ev: &RawEvent) -> Result<DeviceEvent> {
        let c = client();
        let dims = [ev.rows, ev.cols];
        let t = Instant::now();
        let noisy: Vec<i32> = ev.noisy.iter().map(|&x| x as i32).collect();
        let out = DeviceEvent {
            event_id: ev.event_id,
            rows: ev.rows,
            cols: ev.cols,
            counts: c.buffer_from_host_buffer(&ev.counts, &dims, None)?,
            a: c.buffer_from_host_buffer(&ev.a, &dims, None)?,
            b: c.buffer_from_host_buffer(&ev.b, &dims, None)?,
            na: c.buffer_from_host_buffer(&ev.na, &dims, None)?,
            nb: c.buffer_from_host_buffer(&ev.nb, &dims, None)?,
            noisy: c.buffer_from_host_buffer(&noisy, &dims, None)?,
            types: c.buffer_from_host_buffer(&ev.types, &dims, None)?,
            upload_time: Duration::ZERO,
        };
        let mut out = out;
        out.upload_time = t.elapsed();
        Ok(out)
    }

    /// H2D bytes this event occupies (7 planes of 4-byte elements).
    pub fn device_bytes(&self) -> usize {
        7 * self.rows * self.cols * 4
    }

    /// Input buffers of the fused `full_event` entry, in signature order.
    pub fn full_event_inputs(&self) -> [&xla::PjRtBuffer; 7] {
        [&self.counts, &self.a, &self.b, &self.na, &self.nb, &self.noisy, &self.types]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edm::generator::{EventConfig, EventGenerator};

    #[test]
    fn upload_and_shapes() {
        let ev = EventGenerator::new(EventConfig::grid(16, 16, 1), 2).generate();
        let Ok(dev) = DeviceEvent::upload(&ev) else {
            eprintln!("skipping: no PJRT");
            return;
        };
        assert_eq!(dev.device_bytes(), 7 * 16 * 16 * 4);
        assert!(dev.upload_time > Duration::ZERO);
        // Round-trip one plane to prove residency.
        let lit = dev.counts.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), ev.counts);
    }
}

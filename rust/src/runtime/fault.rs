//! Device-side fault injection: a schedule-driven [`FaultyEngine`]
//! wrapper for the chaos harness (DESIGN.md §10).
//!
//! The wrapper intercepts `run_full_event` and, on the armed schedule,
//! either returns an `Err` ("short planes": the recoverable shape the
//! device worker's existing host-fallback path already handles) or
//! panics mid-batch (the shape only the worker supervisor's
//! `catch_unwind` can contain). Disarmed, it is one relaxed load per
//! event on top of the real engine.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::edm::generator::RawEvent;

use super::executor::{Engine, ExecTiming, ParticleStageOut, SensorStageOut};

/// Anything that can run one raw event end-to-end on the device path.
/// Implemented by the real [`Engine`] and by [`FaultyEngine`]; the
/// coordinator's `process_device_staged*` helpers are generic over it,
/// so the fault wrapper slots into the device worker without touching
/// the clean path.
pub trait FullEventRunner {
    fn run_full_event(
        &self,
        ev: &RawEvent,
    ) -> Result<(SensorStageOut, ParticleStageOut, ExecTiming)>;
}

impl FullEventRunner for Engine {
    fn run_full_event(
        &self,
        ev: &RawEvent,
    ) -> Result<(SensorStageOut, ParticleStageOut, ExecTiming)> {
        Engine::run_full_event(self, ev)
    }
}

/// The schedule half of [`FaultyEngine`], split out so the trigger
/// arithmetic is testable without PJRT artifacts: counts events and
/// fires on every `every`-th one while armed.
#[derive(Debug, Default)]
pub struct FaultFuse {
    armed: AtomicBool,
    every: AtomicU64,
    count: AtomicU64,
    injected: AtomicU64,
    /// Fire as a panic instead of an `Err` (exercises the supervisor
    /// instead of the in-worker host fallback).
    panic_mode: AtomicBool,
}

impl FaultFuse {
    /// Arm to fire on every `every`-th event (0 disarms); resets the
    /// event counter so equal schedules fire identically.
    pub fn arm(&self, every: u64, panic_mode: bool) {
        self.count.store(0, Ordering::Relaxed);
        self.every.store(every, Ordering::Relaxed);
        self.panic_mode.store(panic_mode, Ordering::Relaxed);
        self.armed.store(every > 0, Ordering::Relaxed);
    }

    pub fn disarm(&self) {
        self.armed.store(false, Ordering::Relaxed);
    }

    /// Faults fired since creation.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Count one event; `Some(panic_mode)` when the fault must fire.
    pub fn trip(&self) -> Option<bool> {
        if !self.armed.load(Ordering::Relaxed) {
            return None;
        }
        let every = self.every.load(Ordering::Relaxed);
        if every == 0 {
            return None;
        }
        let n = self.count.fetch_add(1, Ordering::Relaxed) + 1;
        if n % every == 0 {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return Some(self.panic_mode.load(Ordering::Relaxed));
        }
        None
    }
}

/// Fault-injecting engine wrapper. Owns the real [`Engine`] (engines
/// are single-threaded and worker-owned, so the wrapper is too) and
/// consults a shared [`FaultFuse`] before each event. The fuse is
/// `Arc`ed so a chaos run keeps one schedule across worker respawns —
/// a fresh engine after a kill continues the old fuse's count instead
/// of restarting the schedule.
pub struct FaultyEngine {
    inner: Engine,
    fuse: Arc<FaultFuse>,
}

impl FaultyEngine {
    /// Wrap an engine with a fresh, disarmed fuse (pass-through).
    pub fn new(inner: Engine) -> FaultyEngine {
        FaultyEngine { inner, fuse: Arc::new(FaultFuse::default()) }
    }

    /// Wrap an engine around an existing (usually armed, shared) fuse.
    pub fn with_fuse(inner: Engine, fuse: Arc<FaultFuse>) -> FaultyEngine {
        FaultyEngine { inner, fuse }
    }

    pub fn fuse(&self) -> &FaultFuse {
        &self.fuse
    }

    pub fn inner(&self) -> &Engine {
        &self.inner
    }
}

impl FullEventRunner for FaultyEngine {
    fn run_full_event(
        &self,
        ev: &RawEvent,
    ) -> Result<(SensorStageOut, ParticleStageOut, ExecTiming)> {
        match self.fuse.trip() {
            Some(true) => panic!(
                "injected device fault (panic) on event {} after {} faults",
                ev.event_id,
                self.fuse.injected()
            ),
            Some(false) => bail!(
                "injected device fault: short planes on event {}",
                ev.event_id
            ),
            None => self.inner.run_full_event(ev),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fuse_fires_on_schedule() {
        let fuse = FaultFuse::default();
        assert_eq!(fuse.trip(), None, "disarmed fuse never fires");
        fuse.arm(3, false);
        let fired: Vec<bool> = (0..9).map(|_| fuse.trip().is_some()).collect();
        assert_eq!(
            fired,
            [false, false, true, false, false, true, false, false, true]
        );
        assert_eq!(fuse.injected(), 3);
        // Re-arming resets the phase, so equal schedules fire equally.
        fuse.arm(3, true);
        assert_eq!(fuse.trip(), None);
        assert_eq!(fuse.trip(), None);
        assert_eq!(fuse.trip(), Some(true), "panic mode is reported to the caller");
        fuse.disarm();
        assert_eq!(fuse.trip(), None);
        assert_eq!(fuse.injected(), 4);
    }
}

//! Per-thread PJRT client (the "device" of this reproduction).
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and not `Send`, so the
//! client is thread-local: whichever thread drives the device (the
//! coordinator's dedicated device worker, a bench, a test) lazily gets
//! its own client. `PjRtClient` is a cheap `Rc` clone.
//!
//! The client is the boundary that gives the figures their genuine
//! transfer costs: inputs cross it as host buffers, outputs come back
//! via `to_literal_sync`.

use std::cell::OnceCell;

use anyhow::{Context, Result};

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// This thread's PJRT client (created on first use). Panics if the XLA
/// runtime cannot initialise — the device path is first-class, not
/// optional.
pub fn client() -> xla::PjRtClient {
    try_client().expect("PJRT CPU client must initialise")
}

/// Non-panicking variant (tests, the CLI `doctor` command).
pub fn try_client() -> Result<xla::PjRtClient> {
    CLIENT.with(|c| {
        if c.get().is_none() {
            let made = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let _ = c.set(made);
        }
        Ok(c.get().unwrap().clone())
    })
}

/// Human-readable device description.
pub fn device_description() -> String {
    let c = client();
    format!("{} ({} devices)", c.platform_name(), c.device_count())
}

#[cfg(test)]
mod tests {
    #[test]
    fn client_initialises_and_describes() {
        assert!(super::device_description().contains("cpu"));
        // Second call reuses the thread-local.
        let _ = super::client();
    }
}

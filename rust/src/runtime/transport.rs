//! Frame transport over byte streams (Unix sockets, pipes) plus the
//! bounded reassembly ring the multi-process ingestion mode drains
//! (DESIGN.md §11).
//!
//! Frames are self-delimiting — the fixed header carries the total
//! length — so the stream protocol is simply back-to-back frames.
//! [`FrameReader`] reads the fixed prefix, validates what is checkable
//! early (magic, version, length sanity, a hard size cap against
//! hostile headers), then reads the body **directly into 8-aligned
//! storage** ([`AlignedBytes`]): the socket read is the only copy the
//! plane bytes ever see on the receive side.

use std::collections::VecDeque;
use std::fmt;
use std::io::{self, Read, Write};
use std::sync::{Condvar, Mutex};

use crate::marionette::wire::{self, AlignedBytes, WireError, FIXED_HEADER};

/// Hard cap on a single frame (defense against corrupt/hostile length
/// fields driving unbounded allocation).
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Transport failures: stream I/O or typed wire errors.
#[derive(Debug)]
pub enum TransportError {
    Io(io::Error),
    Wire(WireError),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Io(e) => write!(f, "transport: io: {e}"),
            TransportError::Wire(e) => write!(f, "transport: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<io::Error> for TransportError {
    fn from(e: io::Error) -> TransportError {
        TransportError::Io(e)
    }
}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> TransportError {
        TransportError::Wire(e)
    }
}

/// Send one encoded frame (the frame is self-delimiting; no extra
/// length prefix is needed).
pub fn write_frame<W: Write + ?Sized>(w: &mut W, frame_bytes: &[u8]) -> io::Result<()> {
    w.write_all(frame_bytes)
}

/// Reads back-to-back frames from a byte stream into aligned buffers.
pub struct FrameReader<R: Read> {
    inner: R,
    /// Total frame bytes read so far (reported by the ingest drivers).
    bytes: usize,
}

enum HeadRead {
    Eof,
    Partial(usize),
    Full,
}

impl<R: Read> FrameReader<R> {
    pub fn new(inner: R) -> FrameReader<R> {
        FrameReader { inner, bytes: 0 }
    }

    pub fn bytes_read(&self) -> usize {
        self.bytes
    }

    pub fn into_inner(self) -> R {
        self.inner
    }

    fn read_head(&mut self, head: &mut [u8; FIXED_HEADER]) -> io::Result<HeadRead> {
        let mut got = 0;
        while got < head.len() {
            match self.inner.read(&mut head[got..]) {
                Ok(0) if got == 0 => return Ok(HeadRead::Eof),
                Ok(0) => return Ok(HeadRead::Partial(got)),
                Ok(n) => got += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(HeadRead::Full)
    }

    /// Read the next frame. `Ok(None)` on a clean end of stream (the
    /// peer closed between frames); a stream ending mid-frame is a
    /// typed [`WireError::Truncated`].
    pub fn read_frame(&mut self) -> Result<Option<AlignedBytes>, TransportError> {
        let mut head = [0u8; FIXED_HEADER];
        match self.read_head(&mut head)? {
            HeadRead::Eof => return Ok(None),
            HeadRead::Partial(got) => {
                return Err(WireError::Truncated { need: FIXED_HEADER, have: got }.into());
            }
            HeadRead::Full => {}
        }
        let total = wire::peek_total_len(&head)?;
        if total > MAX_FRAME_BYTES {
            return Err(WireError::Malformed {
                what: format!("frame of {total} bytes exceeds cap {MAX_FRAME_BYTES}"),
            }
            .into());
        }
        let mut buf = AlignedBytes::with_len(total);
        buf.as_mut_slice()[..FIXED_HEADER].copy_from_slice(&head);
        let mut got = FIXED_HEADER;
        {
            let body = &mut buf.as_mut_slice()[FIXED_HEADER..];
            let mut off = 0;
            while off < body.len() {
                match self.inner.read(&mut body[off..]) {
                    Ok(0) => {
                        return Err(WireError::Truncated { need: total, have: got + off }.into());
                    }
                    Ok(n) => off += n,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e.into()),
                }
            }
            got += off;
        }
        self.bytes += got;
        Ok(Some(buf))
    }
}

/// Bounded, blocking MPMC queue: N reader threads push received
/// buffers, reconstruction workers pop them. A full ring blocks the
/// pushers — that is the backpressure that propagates through the
/// socket to the ingest processes (their writes stall once the kernel
/// buffer fills).
pub struct ReassemblyRing<T> {
    state: Mutex<RingState<T>>,
    push_cv: Condvar,
    pop_cv: Condvar,
    cap: usize,
}

struct RingState<T> {
    q: VecDeque<T>,
    closed: bool,
}

impl<T> ReassemblyRing<T> {
    pub fn new(cap: usize) -> ReassemblyRing<T> {
        ReassemblyRing {
            state: Mutex::new(RingState { q: VecDeque::new(), closed: false }),
            push_cv: Condvar::new(),
            pop_cv: Condvar::new(),
            cap: cap.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    /// Blocking push; returns `false` (dropping the item) if the ring
    /// was closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.state.lock().unwrap();
        while g.q.len() >= self.cap && !g.closed {
            g = self.push_cv.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.q.push_back(item);
        drop(g);
        self.pop_cv.notify_one();
        true
    }

    /// Blocking pop; `None` once the ring is closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                drop(g);
                self.push_cv.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.pop_cv.wait(g).unwrap();
        }
    }

    /// Close the ring: pending items still drain, further pushes fail,
    /// blocked poppers wake with `None` once empty.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.push_cv.notify_all();
        self.pop_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ring_bounds_and_drains() {
        let ring = Arc::new(ReassemblyRing::<usize>::new(2));
        assert!(ring.push(1));
        assert!(ring.push(2));
        assert_eq!(ring.depth(), 2);
        let r2 = ring.clone();
        let t = std::thread::spawn(move || r2.push(3)); // blocks until a pop
        assert_eq!(ring.pop(), Some(1));
        t.join().unwrap();
        ring.close();
        assert_eq!(ring.pop(), Some(2));
        assert_eq!(ring.pop(), Some(3));
        assert_eq!(ring.pop(), None);
        assert!(!ring.push(9), "push after close must fail");
    }

    #[test]
    fn reader_round_trips_frames_over_a_pipe() {
        use crate::marionette::schema::Schema;
        use crate::marionette::wire::{encode_frame, Frame};
        use std::os::unix::net::UnixStream;

        let schema = Arc::new(Schema::builder("t").per_item::<u32>("x").build());
        let xs = [5u32, 6, 7];
        let src = crate::marionette::interface::SlicePlanes::new(schema.clone(), 3)
            .bind("x", &xs)
            .unwrap();
        let f1 = encode_frame(&src, 1);
        let f2 = encode_frame(&src, 2);

        let (mut a, b) = UnixStream::pair().unwrap();
        let writer = std::thread::spawn(move || {
            write_frame(&mut a, f1.as_slice()).unwrap();
            write_frame(&mut a, f2.as_slice()).unwrap();
            // a drops: clean EOF.
        });
        let mut rd = FrameReader::new(b);
        let got1 = Frame::decode(rd.read_frame().unwrap().unwrap()).unwrap();
        let got2 = Frame::decode(rd.read_frame().unwrap().unwrap()).unwrap();
        assert!(rd.read_frame().unwrap().is_none(), "clean EOF expected");
        writer.join().unwrap();
        assert_eq!(got1.frame_id(), 1);
        assert_eq!(got2.frame_id(), 2);
        assert_eq!(got2.items(), 3);
    }

    #[test]
    fn mid_frame_eof_is_truncation() {
        use crate::marionette::schema::Schema;
        use crate::marionette::wire::encode_frame;
        use std::os::unix::net::UnixStream;

        let schema = Arc::new(Schema::builder("t").per_item::<u32>("x").build());
        let xs = [1u32; 16];
        let src = crate::marionette::interface::SlicePlanes::new(schema.clone(), 16)
            .bind("x", &xs)
            .unwrap();
        let f = encode_frame(&src, 7);

        let (mut a, b) = UnixStream::pair().unwrap();
        let half = f.len() / 2;
        let writer = std::thread::spawn(move || {
            a.write_all(&f.as_slice()[..half]).unwrap();
        });
        let mut rd = FrameReader::new(b);
        match rd.read_frame() {
            Err(TransportError::Wire(WireError::Truncated { .. })) => {}
            r => panic!("expected Truncated, got {:?}", r.map(|o| o.map(|b| b.len()))),
        }
        writer.join().unwrap();
    }
}

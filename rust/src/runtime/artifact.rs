//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! `artifacts/manifest.json` lists every lowered HLO program with its
//! grid bucket and tensor signature, plus the physics constants both
//! languages must agree on; [`Manifest::load`] re-validates those against
//! `edm::constants` so drift is a hard error, not a silent wrong answer.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::edm::constants;
use crate::marionette::pod::Dtype;
use crate::util::json::{self, Value};

/// Dtype + shape of one artifact input/output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn num_elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn num_bytes(&self) -> usize {
        self.num_elems() * self.dtype.size()
    }

    fn from_json(v: &Value) -> Result<TensorSpec> {
        let dt = v.req("dtype")?.as_str().ok_or_else(|| anyhow!("dtype not a string"))?;
        Ok(TensorSpec {
            dtype: Dtype::from_name(dt).ok_or_else(|| anyhow!("unknown dtype {dt}"))?,
            shape: v
                .req("shape")?
                .as_arr()
                .ok_or_else(|| anyhow!("shape not an array"))?
                .iter()
                .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
        })
    }
}

/// One lowered HLO program.
#[derive(Clone, Debug)]
pub struct ArtifactRecord {
    pub entry: String,
    pub rows: usize,
    pub cols: usize,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    records: BTreeMap<(String, usize, usize), ArtifactRecord>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let v = json::parse(&src).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let version = v.req("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        Self::check_constants(v.req("constants")?)?;

        let mut records = BTreeMap::new();
        for a in v.req("artifacts")?.as_arr().ok_or_else(|| anyhow!("artifacts"))? {
            let rec = ArtifactRecord {
                entry: a.req("entry")?.as_str().unwrap_or_default().to_string(),
                rows: a.req("rows")?.as_usize().unwrap_or(0),
                cols: a.req("cols")?.as_usize().unwrap_or(0),
                file: dir.join(a.req("file")?.as_str().unwrap_or_default()),
                inputs: a
                    .req("inputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: a
                    .req("outputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                sha256: a
                    .get("sha256")
                    .and_then(|s| s.as_str())
                    .unwrap_or_default()
                    .to_string(),
            };
            records.insert((rec.entry.clone(), rec.rows, rec.cols), rec);
        }
        if records.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { dir: dir.to_path_buf(), records })
    }

    /// Load from the default artifacts directory
    /// (`$MARIONETTE_ARTIFACTS` or `<crate>/artifacts`).
    pub fn load_default() -> Result<Manifest> {
        Self::load(&crate::edm::golden::artifacts_dir())
    }

    fn check_constants(c: &Value) -> Result<()> {
        let pairs: [(&str, f64); 5] = [
            ("num_sensor_types", constants::NUM_SENSOR_TYPES as f64),
            ("window", constants::WINDOW as f64),
            ("halo", constants::HALO as f64),
            ("seed_significance", constants::SEED_SIGNIFICANCE as f64),
            ("contrib_significance", constants::CONTRIB_SIGNIFICANCE as f64),
        ];
        for (key, want) in pairs {
            let got = c.req(key)?.as_f64().unwrap_or(f64::NAN);
            if (got - want).abs() > 1e-9 {
                bail!("constant {key} drifted: python={got}, rust={want}");
            }
        }
        let planes = c.req("num_planes")?.as_usize().unwrap_or(0);
        if planes != constants::NUM_PLANES {
            bail!("num_planes drifted: python={planes}, rust={}", constants::NUM_PLANES);
        }
        Ok(())
    }

    /// Look up an artifact by entry point and exact grid bucket.
    pub fn get(&self, entry: &str, rows: usize, cols: usize) -> Result<&ArtifactRecord> {
        self.records
            .get(&(entry.to_string(), rows, cols))
            .ok_or_else(|| anyhow!("no artifact {entry} for {rows}x{cols} (rebuild with --grids)"))
    }

    /// The grid buckets available for an entry point, ascending.
    pub fn buckets(&self, entry: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<_> = self
            .records
            .keys()
            .filter(|(e, _, _)| e == entry)
            .map(|&(_, r, c)| (r, c))
            .collect();
        v.sort();
        v
    }

    /// Smallest bucket that fits a `rows x cols` grid, if any.
    pub fn bucket_for(&self, entry: &str, rows: usize, cols: usize) -> Option<(usize, usize)> {
        self.buckets(entry)
            .into_iter()
            .find(|&(r, c)| r >= rows && c >= cols)
    }

    pub fn records(&self) -> impl Iterator<Item = &ArtifactRecord> {
        self.records.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        Manifest::load_default().ok()
    }

    #[test]
    fn loads_and_validates() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rec = m.get("sensor_stage", 64, 64).unwrap();
        assert_eq!(rec.inputs.len(), 6);
        assert_eq!(rec.inputs[0].dtype, Dtype::I32);
        assert_eq!(rec.outputs.len(), 3);
        assert!(rec.file.exists());
        assert_eq!(rec.inputs[0].num_bytes(), 64 * 64 * 4);
    }

    #[test]
    fn particle_stage_signature() {
        let Some(m) = manifest() else { return };
        let rec = m.get("particle_stage", 32, 32).unwrap();
        assert_eq!(rec.outputs[0].dtype, Dtype::I32); // seeds
        assert_eq!(
            rec.outputs[1].shape,
            vec![crate::edm::constants::NUM_PLANES, 32, 32]
        );
    }

    #[test]
    fn bucket_selection() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.bucket_for("sensor_stage", 50, 50), Some((64, 64)));
        assert_eq!(m.bucket_for("sensor_stage", 16, 16), Some((16, 16)));
        assert_eq!(m.bucket_for("sensor_stage", 5000, 5000), None);
        assert!(m.buckets("full_event").len() >= 5);
    }

    #[test]
    fn missing_artifact_is_error() {
        let Some(m) = manifest() else { return };
        assert!(m.get("sensor_stage", 17, 17).is_err());
        assert!(m.get("nonexistent", 16, 16).is_err());
    }
}

//! Deterministic PRNG + distributions (substrate for the event generator,
//! property tests and benchmarks; the `rand` crate is not vendored).
//!
//! The core generator is xoshiro256++ (Blackman & Vigna), seeded via
//! SplitMix64 — exactly the construction `rand`'s `SmallRng` family uses.
//! Distributions: uniform ranges, Box-Muller normals, and Knuth/normal-
//! approximation Poisson draws for the sensor-count background.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Rng {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[lo, hi)` (Lemire-style rejection-free bias is
    /// negligible for our ranges; use 64-bit multiply-shift).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range");
        let span = hi - lo;
        lo + ((self.next_u64() as u128 * span as u128) >> 64) as u64
    }

    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Poisson(λ): Knuth's product method for small λ, normal
    /// approximation for large λ.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = lambda + lambda.sqrt() * self.normal();
            v.max(0.0).round() as u64
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::seed_from_u64(1);
        let mut sum = 0.0;
        const N: usize = 20_000;
        for _ in 0..N {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_hits_all_values() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.range_usize(0, 10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
        for _ in 0..100 {
            let v = r.range_u64(5, 8);
            assert!((5..8).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        const N: usize = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..N {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= N as f64;
        v = v / N as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::seed_from_u64(4);
        for lambda in [0.5, 3.0, 80.0] {
            const N: usize = 20_000;
            let mut sum = 0.0;
            for _ in 0..N {
                sum += r.poisson(lambda) as f64;
            }
            let mean = sum / N as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda {lambda} mean {mean}"
            );
        }
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}

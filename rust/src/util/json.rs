//! Minimal JSON parser (substrate: serde_json is not vendored).
//!
//! Parses the full JSON grammar into a [`Value`] tree; enough for
//! `artifacts/manifest.json` and `artifacts/golden/golden.json`. Numbers
//! are kept as `f64` (the manifest only contains small integers and
//! floats). Strings support the standard escape set including `\uXXXX`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object member access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that errors with the key name (for manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key {key:?}")))
    }
}

#[derive(Debug)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(src: &str) -> Result<Value, JsonError> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => self.string().map(Value::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: JSON encodes astral chars
                            // as two \u escapes.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if !self.b[self.i..].starts_with(b"\\u") {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.i += 2;
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i..self.i + 4])
                                        .map_err(|_| self.err("bad surrogate"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad surrogate"))?;
                                self.i += 4;
                                let c =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(ch);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\n\t\"\\b""#).unwrap(),
            Value::Str("a\n\t\"\\b".into())
        );
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": {"d": true}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Value::Null)
        );
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn manifest_like() {
        let v = parse(
            r#"{"version": 1, "constants": {"window": 5},
                "artifacts": [{"entry": "sensor_stage", "rows": 16,
                               "inputs": [{"dtype": "int32", "shape": [16, 16]}]}]}"#,
        )
        .unwrap();
        assert_eq!(v.req("version").unwrap().as_usize(), Some(1));
        let a = &v.req("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("entry").unwrap().as_str(), Some("sensor_stage"));
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn real_manifest_parses() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(src) = std::fs::read_to_string(path) {
            let v = parse(&src).expect("manifest must parse");
            assert!(v.req("artifacts").unwrap().as_arr().unwrap().len() >= 1);
        }
    }
}

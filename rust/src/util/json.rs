//! Minimal JSON parser (substrate: serde_json is not vendored).
//!
//! Parses the full JSON grammar into a [`Value`] tree; enough for
//! `artifacts/manifest.json` and `artifacts/golden/golden.json`. Numbers
//! are kept as `f64` (the manifest only contains small integers and
//! floats). Strings support the standard escape set including `\uXXXX`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object member access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that errors with the key name (for manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }
}

/// Typed parse failure. Every malformed input — truncated, garbage, or
/// hostile (deep nesting, lone surrogates) — maps to one of these; the
/// parser never panics and never overflows the stack.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JsonError {
    /// Input ended before the value did.
    Truncated { at: usize, what: &'static str },
    /// A byte that cannot continue the expected production.
    Unexpected { at: usize, what: &'static str },
    /// Syntactically placed but unrepresentable content (bad escape,
    /// bad codepoint, unparseable number, nesting past the depth cap).
    Invalid { at: usize, what: &'static str },
    /// [`Value::req`]: a required object key was absent.
    MissingKey(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Truncated { at, what } => {
                write!(f, "json error: truncated input ({what}) at byte {at}")
            }
            JsonError::Unexpected { at, what } => {
                write!(f, "json error: {what} at byte {at}")
            }
            JsonError::Invalid { at, what } => {
                write!(f, "json error: {what} at byte {at}")
            }
            JsonError::MissingKey(key) => write!(f, "json error: missing key {key:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

/// Nesting cap: recursive-descent depth is bounded so adversarial
/// `[[[[...` input returns [`JsonError::Invalid`] instead of blowing
/// the stack. 128 is far beyond any manifest this crate reads.
const MAX_DEPTH: usize = 128;

pub fn parse(src: &str) -> Result<Value, JsonError> {
    let mut p = Parser { b: src.as_bytes(), i: 0, depth: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.unexpected("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn truncated(&self, what: &'static str) -> JsonError {
        JsonError::Truncated { at: self.i, what }
    }

    fn unexpected(&self, what: &'static str) -> JsonError {
        JsonError::Unexpected { at: self.i, what }
    }

    fn invalid(&self, what: &'static str) -> JsonError {
        JsonError::Invalid { at: self.i, what }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8, what: &'static str) -> Result<(), JsonError> {
        match self.peek() {
            Some(got) if got == c => {
                self.i += 1;
                Ok(())
            }
            Some(_) => Err(self.unexpected(what)),
            None => Err(self.truncated(what)),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.unexpected("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.invalid("nesting deeper than the 128-level cap"));
        }
        self.depth += 1;
        let v = match self.peek().ok_or_else(|| self.truncated("value expected"))? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => self.string().map(Value::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.unexpected("unexpected character")),
        };
        self.depth -= 1;
        v
    }

    /// Four hex digits of a `\u` escape; bounds-checked so a string
    /// truncated mid-escape errors instead of slicing out of range.
    fn hex4(&mut self, what: &'static str) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.truncated(what));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.invalid(what))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.invalid(what))?;
        self.i += 4;
        Ok(cp)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"', "expected '\"'")?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.truncated("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.truncated("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4("bad \\u escape")?;
                            // Surrogate pairs: JSON encodes astral chars
                            // as two \u escapes.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if !self.b[self.i..].starts_with(b"\\u") {
                                    return Err(self.invalid("lone surrogate"));
                                }
                                self.i += 2;
                                let lo = self.hex4("bad surrogate")?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.invalid("lone surrogate"));
                                }
                                let c =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                                    .ok_or_else(|| self.invalid("bad codepoint"))?
                            } else {
                                char::from_u32(cp)
                                    .ok_or_else(|| self.invalid("bad codepoint"))?
                            };
                            out.push(ch);
                        }
                        _ => return Err(self.invalid("unknown escape")),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.invalid("invalid utf8"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.invalid("invalid number"))
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.eat(b'[', "expected '['")?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                Some(_) => return Err(self.unexpected("expected , or ]")),
                None => return Err(self.truncated("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.eat(b'{', "expected '{'")?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':', "expected ':'")?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                Some(_) => return Err(self.unexpected("expected , or }")),
                None => return Err(self.truncated("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\n\t\"\\b""#).unwrap(),
            Value::Str("a\n\t\"\\b".into())
        );
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": {"d": true}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Value::Null)
        );
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn manifest_like() {
        let v = parse(
            r#"{"version": 1, "constants": {"window": 5},
                "artifacts": [{"entry": "sensor_stage", "rows": 16,
                               "inputs": [{"dtype": "int32", "shape": [16, 16]}]}]}"#,
        )
        .unwrap();
        assert_eq!(v.req("version").unwrap().as_usize(), Some(1));
        let a = &v.req("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("entry").unwrap().as_str(), Some("sensor_stage"));
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn typed_errors_carry_positions() {
        assert!(matches!(parse(""), Err(JsonError::Truncated { .. })));
        assert!(matches!(parse("[1, 2"), Err(JsonError::Truncated { .. })));
        assert!(matches!(parse("[1 2]"), Err(JsonError::Unexpected { .. })));
        assert!(matches!(parse(r#""\ud800\u12"#), Err(JsonError::Truncated { .. })));
        assert!(matches!(parse(r#""\ud800x""#), Err(JsonError::Invalid { .. })));
        assert!(matches!(parse(r#""\ud800A""#), Err(JsonError::Invalid { .. })));
        assert!(matches!(
            parse(r#"{"a": true"#),
            Err(JsonError::Truncated { .. })
        ));
        assert!(matches!(
            Value::Null.req("k"),
            Err(JsonError::MissingKey(_))
        ));
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        let deep: String = "[".repeat(4096);
        assert!(matches!(parse(&deep), Err(JsonError::Invalid { .. })));
        let mut ok = "[".repeat(100);
        ok.push('1');
        ok.push_str(&"]".repeat(100));
        assert!(parse(&ok).is_ok());
    }

    /// Fuzz-style property: for any truncation or byte mutation of a
    /// valid document, `parse` returns a typed error or a value — it
    /// must never panic (the harness would abort the test process).
    #[test]
    fn fuzzed_corruptions_never_panic() {
        use crate::util::rng::Rng;
        let valid = r#"{"version": 1, "xs": [1, -2.5e3, true, null,
            "aA😀\n", {"k": [{}, []]}], "s": "héllo"}"#;
        // Every prefix must fail cleanly (truncated mid-token included).
        for cut in 0..valid.len() {
            if !valid.is_char_boundary(cut) {
                continue;
            }
            let _ = parse(&valid[..cut]);
        }
        // Seeded random single-byte mutations, re-checked as UTF-8 so
        // the input stays a &str (parse's contract).
        let mut rng = Rng::seed_from_u64(0x1A7E57);
        let mut hits = 0;
        while hits < 500 {
            let mut bytes = valid.as_bytes().to_vec();
            let at = rng.range_usize(0, bytes.len());
            bytes[at] = rng.next_u64() as u8;
            if let Ok(s) = std::str::from_utf8(&bytes) {
                let _ = parse(s);
                hits += 1;
            }
        }
    }

    #[test]
    fn real_manifest_parses() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(src) = std::fs::read_to_string(path) {
            let v = parse(&src).expect("manifest must parse");
            assert!(v.req("artifacts").unwrap().as_arr().unwrap().len() >= 1);
        }
    }
}

//! In-tree substrate utilities.
//!
//! The build image has no network access and only the `xla` crate's
//! vendored dependency set, so the usual ecosystem crates (serde_json,
//! rand, criterion, proptest, rayon) are unavailable; these modules
//! provide the small slices of them this project needs (DESIGN.md §3):
//! JSON ([`json`]), a PRNG ([`rng`]), a mini property-testing framework
//! ([`prop`]) and a scoped thread pool ([`pool`]).

pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

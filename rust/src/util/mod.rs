//! In-tree substrate utilities.
//!
//! The build image has no network access and only the `xla` crate's
//! vendored dependency set, so the usual ecosystem crates (serde_json,
//! rand, criterion, proptest) are unavailable; these modules provide the
//! small slices of them this project needs (DESIGN.md §3).

pub mod json;
pub mod prop;
pub mod rng;
